//! End-to-end driver (DESIGN.md E10): proves all three layers compose.
//!
//!   1. The Rust coordinator trains an MNIST-like MLP by driving the
//!      AOT-compiled PJRT train-step artifact for a few hundred steps,
//!      logging the loss curve (L3 owns the loop, L2's XLA owns the math).
//!   2. The trained f32 network is quantized to every 8-bit format.
//!   3. Quantized inference runs through the AOT quantized-datapath
//!      artifact (L1 Pallas kernels inside) AND the bit-exact Rust EMAC
//!      simulator; accuracies are reported side by side.
//!
//! Run (needs `make artifacts`):
//!   cargo run --release --example train_and_quantize -- [dataset] [epochs] [scale]
//! Defaults: mnist 12 small. The EXPERIMENTS.md run used `mnist 12 full`.

use std::time::Instant;

use deep_positron::coordinator::{experiments, trainer, Engine};
use deep_positron::datasets::{self, Scale};
use deep_positron::formats::FormatSpec;
use deep_positron::runtime::{artifacts_dir, Runtime};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("mnist").to_string();
    let epochs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let scale = match args.get(2).map(String::as_str) {
        Some("full") => Scale::Full,
        _ => Scale::Small,
    };

    println!("== Deep Positron end-to-end: {dataset}, {epochs} epochs, {scale:?} ==\n");
    let rt = Runtime::new(&artifacts_dir())?;
    println!("PJRT platform: {}", rt.platform());
    let ds = datasets::load(&dataset, 7, scale);
    println!(
        "dataset: {} train / {} test, {} features, {} classes\n",
        ds.train_len(),
        ds.test_len(),
        ds.num_features,
        ds.num_classes
    );

    // ---- 1. train through the PJRT artifact ----
    let cfg = trainer::LoopConfig { epochs, lr: 0.05, momentum: 0.9, seed: 7, log_every: 10 };
    let t0 = Instant::now();
    let (state, log) = trainer::train_via_pjrt(&rt, &ds, &cfg)?;
    println!("loss curve (every 10 steps):");
    for (step, loss) in log.losses.iter() {
        println!("  step {step:>5}  loss {loss:.4}");
    }
    println!("{}", log.render());
    let mlp = state.to_mlp();
    let baseline = mlp.accuracy(&ds);
    println!("f32 baseline accuracy: {:.2}%  (trained in {:.1}s)\n", baseline * 100.0, t0.elapsed().as_secs_f64());

    // ---- 2 & 3. quantize to every 8-bit format; eval on both engines ----
    println!("{:<12} {:>10} {:>10} {:>12}", "format", "sim acc", "xla acc", "degradation");
    for family in ["posit", "float", "fixed"] {
        for spec in FormatSpec::sweep_family(8, family) {
            let t = Instant::now();
            let xla = experiments::eval_xla(&rt, &mlp, &ds, spec)?;
            let sim = if ds.test_len() <= 500 {
                experiments::eval_sim(&mlp, &ds, spec)
            } else {
                xla // full-scale: sim path is the benchmark's job
            };
            println!(
                "{:<12} {:>9.2}% {:>9.2}% {:>11.2}%   ({:.1}s)",
                spec.name(),
                sim * 100.0,
                xla * 100.0,
                (baseline - xla) * 100.0,
                t.elapsed().as_secs_f64()
            );
        }
    }

    // ---- summary row for EXPERIMENTS.md ----
    let (best_acc, best_spec) = experiments::best_accuracy(Engine::Xla, Some(&rt), &mlp, &ds, "posit", 8)?;
    println!(
        "\nbest 8-bit posit: {} at {:.2}% (baseline {:.2}%)",
        best_spec.name(),
        best_acc * 100.0,
        baseline * 100.0
    );
    Ok(())
}
