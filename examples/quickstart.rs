//! Quickstart: the pure-Rust core API in ~60 lines — formats, quantization,
//! and the exact multiply-and-accumulate. Needs no artifacts.
//!
//! Run: `cargo run --release --example quickstart`

use deep_positron::formats::{Emac, Format, FormatSpec, Quantizer};

fn main() {
    // 1. Pick a format the paper studies: 8-bit posit with es=1.
    let spec = FormatSpec::parse("posit8es1").unwrap();
    let fmt = spec.build();
    let q = Quantizer::new(fmt.as_ref());
    println!("format        : {}", fmt.name());
    println!("values        : {} distinct", q.len());
    println!("max / minpos  : {} / {}", fmt.max_value(), fmt.min_pos());

    // 2. Quantize a real number (round-to-nearest, ties to even code).
    let (code, value) = q.quantize_f64(0.3);
    println!("quantize(0.3) : code {code:#04x} -> {value}");

    // 3. An exact dot product through the EMAC (Kulisch quire): products
    //    accumulate without rounding; ONE deferred round at the end.
    let xs = [0.5, -0.25, 0.125, 1.5];
    let ws = [1.0, 0.75, -2.0, 0.5];
    let (xc, _): (Vec<u16>, Vec<f64>) = q.quantize_slice(&xs);
    let (wc, _): (Vec<u16>, Vec<f64>) = q.quantize_slice(&ws);
    let mut emac = Emac::new(fmt.as_ref(), &q, xs.len());
    let out = emac.dot(&wc, &xc, None, false);
    let exact: f64 = xs.iter().zip(&ws).map(|(x, w)| x * w).sum();
    println!("EMAC dot      : {} (exact {})", q.decode(out).unwrap().to_f64(), exact);

    // 4. Compare format families at the same bit-width (the paper's point).
    println!("\n8-bit format comparison:");
    println!("{:<12} {:>10} {:>14} {:>8}", "format", "values", "max", "minpos");
    for name in ["posit8es0", "posit8es1", "posit8es2", "float8we4", "fixed8q5"] {
        let spec = FormatSpec::parse(name).unwrap();
        let f = spec.build();
        let q = Quantizer::new(f.as_ref());
        println!("{:<12} {:>10} {:>14.3e} {:>8.1e}", name, q.len(), f.max_value(), f.min_pos());
    }
}
