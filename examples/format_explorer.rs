//! Format explorer: dump the value lattice, dynamic range, tapered-precision
//! profile, and Eq.(2) quire width of any format — the numeric-format
//! domain's "show me the representation" tool.
//!
//! Run: `cargo run --release --example format_explorer -- posit8es1 [k]`

use deep_positron::formats::{quire_width_bits, Format, FormatSpec, Quantizer};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args.first().map(String::as_str).unwrap_or("posit8es0");
    let k: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(784);
    let Some(spec) = FormatSpec::parse(name) else {
        eprintln!("unparseable format {name}; try posit8es1 / float8we4 / fixed8q5");
        std::process::exit(1);
    };
    let fmt = spec.build();
    let q = Quantizer::new(fmt.as_ref());

    println!("=== {} ===", fmt.name());
    println!("bit-width          : {}", fmt.n());
    println!("distinct values    : {}", q.len());
    println!("dynamic range      : {:.3e} .. {:.3e}", fmt.min_pos(), fmt.max_value());
    println!("decades            : {:.1}", (fmt.max_value() / fmt.min_pos()).log10());
    println!("quire width (k={k}): {} bits  [paper Eq. (2)]", quire_width_bits(k, fmt.max_value(), fmt.min_pos()));

    // Tapered precision: relative gap between adjacent values by magnitude.
    println!("\ntapered-precision profile (relative step at each decade):");
    let mut mag = fmt.min_pos();
    while mag <= fmt.max_value() {
        let (_, v) = q.quantize_f64(mag);
        let idx = q.values().partition_point(|&u| u < v);
        if idx + 1 < q.len() {
            let gap = q.values()[idx + 1] - v;
            if v > 0.0 {
                println!("  near {:>12.4e}: step {:>12.4e}  ({:.2} significant digits)", v, gap, -(gap / v).log10());
            }
        }
        mag *= 10.0;
    }

    // Density histogram (Fig 1a's story).
    println!("\nvalue density over [-2, 2] (the DNN-parameter range):");
    let hist = deep_positron::util::stats::histogram(q.values(), -2.0, 2.0, 16);
    for (i, h) in hist.iter().enumerate() {
        let lo = -2.0 + 4.0 * i as f64 / 16.0;
        println!("  {lo:>6.2} | {}", "#".repeat(*h));
    }

    // The first few positive values.
    println!("\nsmallest positive values:");
    let zero = q.values().partition_point(|&u| u < 0.0);
    for &v in q.values().iter().skip(zero + 1).take(8) {
        let (code, _) = q.quantize_f64(v);
        println!("  {code:#06x} -> {v:.6e}");
    }
}
