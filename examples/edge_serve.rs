//! Edge-deployment serving demo: a quantized Deep Positron model behind the
//! dynamic-batching inference server, under raw open-loop load.
//!
//! The client needs no pacing, sleeps, or in-flight window any more: the
//! engine self-protects with bounded admission (a full worker queue sheds
//! the submission as `ServeError::Overloaded` instead of queueing without
//! limit) and per-request deadlines (queued work that outlives its latency
//! budget is dropped uncomputed). This demo floods, counts sheds and
//! expiries, and reports accuracy over the requests that were answered.
//!
//! Run (sim engine needs no artifacts; xla engine needs `make artifacts`):
//!   cargo run --release --example edge_serve -- [dataset] [format] [requests] [engine]
//! Defaults: iris posit8es1 500 xla

use std::time::Duration;

use deep_positron::coordinator::{experiments, server, Engine};
use deep_positron::datasets::{self, Scale};
use deep_positron::formats::FormatSpec;
use deep_positron::serve::ServeError;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("iris").to_string();
    let format = args.get(1).map(String::as_str).unwrap_or("posit8es1");
    let requests: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(500);
    let engine = match args.get(3).map(String::as_str) {
        Some("sim") => Engine::Sim,
        _ => Engine::Xla,
    };
    let spec = FormatSpec::parse(format).expect("bad format name");

    println!("== edge serving: {dataset} on {format}, {requests} requests, {engine:?} engine ==\n");
    let ds = datasets::load(&dataset, 7, Scale::Small);
    println!("training the model (Rust substrate trainer)…");
    let mlp = experiments::train_model(&ds, 7);
    let baseline = mlp.accuracy(&ds);

    // A deliberately small queue bound so overload behaviour is visible at
    // demo scale; edge deployments size this to their latency budget.
    let cfg = server::ServeConfig {
        engine,
        spec,
        max_batch_wait: Duration::from_millis(1),
        max_queue: 256,
    };
    let handle = server::serve(&ds, mlp, cfg)?;

    // Open-loop flood: submit everything as fast as the client can, with a
    // generous per-request latency budget. The engine admits what fits,
    // sheds the rest, and drops anything that goes stale in the queue.
    let deadline = Duration::from_millis(500);
    let mut shed = 0usize;
    let mut accepted = Vec::with_capacity(requests);
    for i in 0..requests {
        let row = i % ds.test_len();
        match handle.submit_with_deadline(ds.test_row(row).to_vec(), deadline) {
            Ok(rx) => accepted.push((row, rx)),
            Err(ServeError::Overloaded { .. }) => shed += 1,
            Err(e) => return Err(e.into()),
        }
    }
    let peak_depth = handle.metrics().queue_depths.iter().copied().max().unwrap_or(0);

    let mut correct = 0usize;
    let mut answered = 0usize;
    let mut expired = 0usize;
    for (row, rx) in accepted {
        match rx.recv() {
            Ok(reply) => {
                answered += 1;
                if reply.class == ds.y_test[row] as usize {
                    correct += 1;
                }
            }
            Err(_) => expired += 1, // reply channel dropped: deadline passed in queue
        }
    }
    let metrics = handle.shutdown();
    println!("\n{}", metrics.render());
    println!(
        "\nsubmitted {requests}: answered {answered}, shed {shed}, expired {expired} \
         (queue depth seen after flood: {peak_depth})"
    );
    if answered > 0 {
        println!(
            "served accuracy : {:.2}% (f64 baseline {:.2}%)",
            correct as f64 / answered as f64 * 100.0,
            baseline * 100.0
        );
    }
    println!("batch occupancy : {:.2} rows/batch (max {})", metrics.occupancy(), metrics.max_batch);
    assert_eq!(
        metrics.served + metrics.shed + metrics.expired,
        requests,
        "every submission must be accounted for as served, shed, or expired"
    );
    Ok(())
}
