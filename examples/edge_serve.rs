//! Edge-deployment serving demo: a quantized Deep Positron model behind the
//! dynamic-batching inference server, under open-loop load.
//!
//! Run (sim engine needs no artifacts; xla engine needs `make artifacts`):
//!   cargo run --release --example edge_serve -- [dataset] [format] [requests] [engine]
//! Defaults: iris posit8es1 500 xla

use std::time::Duration;

use deep_positron::coordinator::{experiments, server, Engine};
use deep_positron::datasets::{self, Scale};
use deep_positron::formats::FormatSpec;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("iris").to_string();
    let format = args.get(1).map(String::as_str).unwrap_or("posit8es1");
    let requests: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(500);
    let engine = match args.get(3).map(String::as_str) {
        Some("sim") => Engine::Sim,
        _ => Engine::Xla,
    };
    let spec = FormatSpec::parse(format).expect("bad format name");

    println!("== edge serving: {dataset} on {format}, {requests} requests, {engine:?} engine ==\n");
    let ds = datasets::load(&dataset, 7, Scale::Small);
    println!("training the model (Rust substrate trainer)…");
    let mlp = experiments::train_model(&ds, 7);
    let baseline = mlp.accuracy(&ds);

    let cfg = server::ServeConfig { engine, spec, max_batch_wait: Duration::from_millis(1) };
    let handle = server::serve(&ds, mlp, cfg)?;

    // Paced open-loop load (~70% of the fast path's measured capacity) in
    // bursts of 32, with a bounded in-flight window so reported latency
    // reflects batching + compute rather than unbounded queueing.
    let mut correct = 0usize;
    let mut pending = std::collections::VecDeque::new();
    for i in 0..requests {
        let row = i % ds.test_len();
        pending.push_back((row, handle.submit(ds.test_row(row).to_vec())));
        if i % 32 == 31 {
            std::thread::sleep(Duration::from_millis(3));
        }
        while pending.len() > 512 {
            let (row, rx) = pending.pop_front().unwrap();
            if rx.recv()?.class == ds.y_test[row] as usize {
                correct += 1;
            }
        }
    }
    for (row, rx) in pending {
        let reply = rx.recv()?;
        if reply.class == ds.y_test[row] as usize {
            correct += 1;
        }
    }
    let metrics = handle.shutdown();
    println!("\n{}", metrics.render());
    println!(
        "\nserved accuracy : {:.2}% (f64 baseline {:.2}%)",
        correct as f64 / requests as f64 * 100.0,
        baseline * 100.0
    );
    println!("batch sizes     : {:?}…", &metrics.batch_sizes[..metrics.batch_sizes.len().min(12)]);
    Ok(())
}
