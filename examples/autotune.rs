//! Mixed-precision auto-tuning walkthrough (DESIGN.md §10): train iris and
//! wdbc, search the per-layer format space under an accuracy budget, print
//! the Pareto frontier, and stand up a serving shard straight from the
//! tuned plan.
//!
//! The story in three acts per task:
//!   1. TUNE  — hold accuracy within one point of the best uniform 8-bit
//!      posit while minimizing the modeled network energy-delay product.
//!   2. PLAN  — serialize the winning `TunePlan` and parse it back (this
//!      text block is what a deployment would check in).
//!   3. SERVE — start a `ServeEngine` shard from the plan: its workers
//!      compile the heterogeneous execution plan, and the routing key is
//!      the assignment's `+`-joined name.
//!
//! Run: cargo run --release --example autotune

use deep_positron::coordinator::experiments;
use deep_positron::datasets::{self, Scale};
use deep_positron::serve::ServeEngine;
use deep_positron::tune::{self, TuneConfig, TunePlan};

fn main() -> anyhow::Result<()> {
    for dataset in ["iris", "wdbc"] {
        println!("==== {dataset} ====\n");
        let ds = datasets::load(dataset, 7, Scale::Small);
        println!("training the model (Rust substrate trainer)…");
        let mlp = experiments::train_model(&ds, 7);

        // Act 1: tune under the Cheetah-style budget.
        let budget = tune::default_budget(&ds, &mlp, usize::MAX);
        let report = tune::tune(&ds, &mlp, &TuneConfig::new(budget).with_beam(2));
        println!("{}", report.render());

        // Act 2: the plan round-trips through its serialized form.
        let text = report.plan.to_text();
        let parsed = TunePlan::parse(&text).expect("a plan we just emitted parses back");
        assert_eq!(parsed.assignment, report.plan.assignment);
        assert_eq!(parsed.cost, report.plan.cost, "cost recomputes identically from the assignment");

        // Act 3: serve from the plan — workers compile the mixed plan.
        let engine = ServeEngine::start(vec![parsed.shard_config(&ds, mlp.clone()).with_workers(2)])
            .map_err(|e| anyhow::anyhow!("serve: {e}"))?;
        let key = engine.shard_keys().into_iter().next().expect("one shard");
        println!("serving shard {} from the tuned plan…", key.label());
        let n = ds.test_len().min(64);
        let rxs: Vec<_> = (0..n).map(|i| engine.submit(&key, ds.test_row(i).to_vec()).expect("admitted")).collect();
        let mut correct = 0usize;
        for (i, rx) in rxs.into_iter().enumerate() {
            if rx.recv()?.class == ds.y_test[i] as usize {
                correct += 1;
            }
        }
        println!(
            "served {n} requests at {:.1}% accuracy (tuner measured {:.1}%)",
            correct as f64 / n as f64 * 100.0,
            report.plan.accuracy * 100.0
        );
        println!("{}", engine.shutdown().render());
    }
    Ok(())
}
