//! Mixed-precision auto-tuning walkthrough (DESIGN.md §10, §16): train iris
//! and wdbc, search the per-layer format space under an accuracy budget,
//! print the Pareto frontier, stand up a serving shard straight from the
//! tuned plan, then freeze the tuned network into a packed `.dpz` artifact
//! and cold-start a second shard from it.
//!
//! The story in five acts per task:
//!   1. TUNE  — hold accuracy within one point of the best uniform 8-bit
//!      posit while minimizing the modeled network energy-delay product.
//!   2. PLAN  — serialize the winning `TunePlan` and parse it back (this
//!      text block is what a deployment would check in).
//!   3. SERVE — start a `ServeEngine` shard from the plan: its workers
//!      compile the heterogeneous execution plan, and the routing key is
//!      the assignment's `+`-joined name.
//!   4. PACK  — freeze the tuned mixed-precision network into a `.dpz`
//!      deployable artifact, provenance riding along.
//!   5. COLD-START — boot a fresh shard from the artifact alone (no
//!      dataset, no trainer, no f64 pass) and verify it answers exactly
//!      like the plan-booted shard.
//!
//! Run: cargo run --release --example autotune

use std::sync::Arc;

use deep_positron::accel::DeepPositron;
use deep_positron::artifact::Artifact;
use deep_positron::coordinator::experiments;
use deep_positron::datasets::{self, Scale};
use deep_positron::serve::{ServeEngine, ShardConfig};
use deep_positron::tune::{self, TuneConfig, TunePlan};

fn main() -> anyhow::Result<()> {
    for dataset in ["iris", "wdbc"] {
        println!("==== {dataset} ====\n");
        let ds = datasets::load(dataset, 7, Scale::Small);
        println!("training the model (Rust substrate trainer)…");
        let mlp = experiments::train_model(&ds, 7);

        // Act 1: tune under the Cheetah-style budget.
        let budget = tune::default_budget(&ds, &mlp, usize::MAX);
        let report = tune::tune(&ds, &mlp, &TuneConfig::new(budget).with_beam(2));
        println!("{}", report.render());

        // Act 2: the plan round-trips through its serialized form.
        let text = report.plan.to_text();
        let parsed = TunePlan::parse(&text).expect("a plan we just emitted parses back");
        assert_eq!(parsed.assignment, report.plan.assignment);
        assert_eq!(parsed.cost, report.plan.cost, "cost recomputes identically from the assignment");

        // Act 3: serve from the plan — workers compile the mixed plan.
        let engine = ServeEngine::start(vec![parsed.shard_config(&ds, mlp.clone()).with_workers(2)])
            .map_err(|e| anyhow::anyhow!("serve: {e}"))?;
        let key = engine.shard_keys().into_iter().next().expect("one shard");
        println!("serving shard {} from the tuned plan…", key.label());
        let n = ds.test_len().min(64);
        let rxs: Vec<_> = (0..n).map(|i| engine.submit(&key, ds.test_row(i).to_vec()).expect("admitted")).collect();
        let mut correct = 0usize;
        for (i, rx) in rxs.into_iter().enumerate() {
            if rx.recv()?.class == ds.y_test[i] as usize {
                correct += 1;
            }
        }
        println!(
            "served {n} requests at {:.1}% accuracy (tuner measured {:.1}%)",
            correct as f64 / n as f64 * 100.0,
            report.plan.accuracy * 100.0
        );
        println!("{}", engine.shutdown().render());

        // Act 4: pack — freeze the tuned network into a `.dpz` deployable.
        let dp = DeepPositron::compile_mixed(&mlp, report.plan.assignment.clone());
        let artifact = Artifact::from_network(dataset, &dp)
            .with_provenance(report.plan.accuracy, report.plan.pruned.clone());
        let path = std::env::temp_dir().join(format!("autotune_{dataset}.dpz"));
        artifact.save(&path)?;
        let loaded = Artifact::load(&path).map_err(|e| anyhow::anyhow!("artifact: {e}"))?;
        assert_eq!(loaded.weight_codes(), artifact.weight_codes(), "packed code streams round-trip");
        assert_eq!(
            loaded.compile().forward_codes(ds.test_row(0)),
            dp.forward_codes(ds.test_row(0)),
            "the artifact-booted plan is bit-identical to the freshly compiled one"
        );
        println!(
            "packed {} into {} ({} bytes, provenance acc {:.1}%)",
            loaded.mixed().name(),
            path.display(),
            std::fs::metadata(&path)?.len(),
            loaded.accuracy().expect("provenance rode along") * 100.0
        );

        // Act 5: cold-start serve — the shard boots from packed codes alone.
        let t0 = std::time::Instant::now();
        let cold = ServeEngine::start(vec![ShardConfig::from_artifact(Arc::new(loaded)).with_workers(2)])
            .map_err(|e| anyhow::anyhow!("serve: {e}"))?;
        println!("cold-started the artifact shard in {:.2} ms", t0.elapsed().as_secs_f64() * 1e3);
        let key = cold.shard_keys().into_iter().next().expect("one shard");
        let rxs: Vec<_> = (0..n).map(|i| cold.submit(&key, ds.test_row(i).to_vec()).expect("admitted")).collect();
        let mut cold_correct = 0usize;
        for (i, rx) in rxs.into_iter().enumerate() {
            if rx.recv()?.class == ds.y_test[i] as usize {
                cold_correct += 1;
            }
        }
        assert_eq!(cold_correct, correct, "the artifact-booted shard must answer exactly like the plan-booted one");
        println!(
            "served {n} requests from the artifact at the same {:.1}% accuracy\n",
            cold_correct as f64 / n as f64 * 100.0
        );
        let _ = cold.shutdown();
    }
    Ok(())
}
