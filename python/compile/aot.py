"""AOT pipeline: lower the L2 graphs to HLO **text** artifacts.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md and gen_hlo.py).

Usage (from ``make artifacts``):
    cd python && python -m compile.aot --out ../artifacts [--small]

Emits, per dataset topology:
  q_infer_<ds>_b<B>.hlo.txt   quantized datapath, B ∈ {1, 64, 256}
  f32_infer_<ds>_b256.hlo.txt 32-bit baseline, eval batch
  train_<ds>_b128.hlo.txt     SGD-momentum train step
plus ``manifest.txt`` describing every artifact (parsed by rust/src/runtime).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

#: dataset -> full layer dims (input, hidden..., classes). Must match
#: rust/src/datasets::hidden_layers.
TOPOLOGIES = {
    "wdbc": (30, 16, 8, 2),
    "iris": (4, 10, 8, 3),
    "mushroom": (117, 32, 2),
    "mnist": (784, 100, 10),
    "fashion": (784, 100, 10),
}

#: Batch sizes for the quantized-inference artifacts. The Rust coordinator
#: pads/chunks request batches to one of these.
Q_BATCHES = (1, 64, 256)
EVAL_BATCH = 256
TRAIN_BATCH = 128
TABLE = 256


def to_hlo_text(fn, specs):
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f64(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float64)


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def q_infer_specs(dims, batch):
    specs = [f64(batch, dims[0])]
    for i in range(len(dims) - 1):
        specs += [f64(dims[i], dims[i + 1]), f64(dims[i + 1])]
    specs += [f64(TABLE), f64(TABLE), f64(TABLE), f64(2)]
    return specs


def f32_infer_specs(dims, batch):
    specs = [f32(batch, dims[0])]
    for i in range(len(dims) - 1):
        specs += [f32(dims[i], dims[i + 1]), f32(dims[i + 1])]
    return specs


def train_specs(dims, batch):
    specs = [f32(batch, dims[0]), f32(batch, dims[-1]), f32(), f32()]
    params = []
    for i in range(len(dims) - 1):
        params += [f32(dims[i], dims[i + 1]), f32(dims[i + 1])]
    return specs + params + params  # params then velocities


def emit(out_dir, fname, text, manifest, desc):
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    manifest.append(f"{desc} file={fname}")
    print(f"  wrote {fname} ({len(text) / 1024:.0f} KiB)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--datasets", default=",".join(TOPOLOGIES))
    ap.add_argument(
        "--small", action="store_true", help="only emit the b=64 quantized artifacts (quick smoke builds)"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = []
    for ds in args.datasets.split(","):
        dims = TOPOLOGIES[ds]
        dim_str = "-".join(map(str, dims))
        print(f"[{ds}] dims={dim_str}")
        q_batches = (64,) if args.small else Q_BATCHES
        for b in q_batches:
            text = to_hlo_text(model.make_quantized_infer(dims), q_infer_specs(dims, b))
            emit(args.out, f"q_infer_{ds}_b{b}.hlo.txt", text, manifest,
                 f"kind=q_infer dataset={ds} batch={b} dims={dim_str}")
        if not args.small:
            text = to_hlo_text(model.make_f32_infer(dims), f32_infer_specs(dims, EVAL_BATCH))
            emit(args.out, f"f32_infer_{ds}_b{EVAL_BATCH}.hlo.txt", text, manifest,
                 f"kind=f32_infer dataset={ds} batch={EVAL_BATCH} dims={dim_str}")
            text = to_hlo_text(model.make_train_step(dims), train_specs(dims, TRAIN_BATCH))
            emit(args.out, f"train_{ds}_b{TRAIN_BATCH}.hlo.txt", text, manifest,
                 f"kind=train dataset={ds} batch={TRAIN_BATCH} dims={dim_str}")
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
