"""EMAC matmul kernel: the paper's compute hot-spot (§4.1) on the TPU model.

The FPGA EMAC accumulates every product of a neuron's weighted sum exactly in
a wide Kulisch quire and rounds once at the end. On the accelerator model
this maps to: operands are (dequantized) format values — exactly
representable in f64 — and the dot product accumulates in f64, which is
error-free whenever the format's quire width fits f64's 53-bit window
(every swept format except posit8 es=2; DESIGN.md §2). The terminal
rounding lives in the companion ``quantize_lut`` kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the FPGA's
three-stage pipeline (multiply / accumulate / round) becomes a tiled GEMM —
the grid streams (block_m × K) activation tiles and the full (K × N) weight
panel through VMEM, accumulating per-tile in registers, i.e. the
HBM↔VMEM schedule replaces the FPGA's operand registers.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    # One grid step: (bm, K) @ (K, N) + b -> (bm, N), all in f64.
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float64)
    acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("relu", "block_m"))
def emac_matmul(x, w, b, *, relu: bool = False, block_m: int = 64):
    """Exact-accumulation dense layer: ``relu?(x @ w + b)`` in f64.

    Args:
      x: (batch, k) activations (dequantized format values).
      w: (k, n) weights (dequantized format values).
      b: (n,) bias (dequantized format values).
      relu: apply the hidden-layer ReLU stage.
      block_m: activation rows per grid step (must divide batch, or exceed it).

    Returns:
      (batch, n) exact pre-round sums.
    """
    batch, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch: {x.shape} @ {w.shape}"
    bm = min(block_m, batch)
    assert batch % bm == 0, f"batch {batch} not divisible by block_m {bm}"
    grid = (batch // bm,)
    return pl.pallas_call(
        functools.partial(_kernel, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n), jnp.float64),
        interpret=True,
    )(x, w, b)
