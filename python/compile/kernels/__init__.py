"""L1 Pallas kernels (build-time only; lowered into the AOT'd HLO).

All kernels run with ``interpret=True`` — the CPU PJRT plugin cannot execute
real-TPU Mosaic custom-calls (see /opt/xla-example/README.md). Correctness is
pinned against the pure-jnp oracles in :mod:`ref` by the pytest suite.
"""

from .emac_matmul import emac_matmul
from .quantize_lut import quantize_lut

__all__ = ["emac_matmul", "quantize_lut"]
