"""Table-driven round-to-nearest kernel: the EMAC's deferred rounding stage.

Quantizes a tensor of exact sums onto a numeric format given as *data*
(DESIGN.md §2): a sorted value table ``values[256]``, round-to-nearest
decision boundaries ``bounds[256]`` (padded with +inf), and tie directions
``ties[256]`` (1.0 = an exact midpoint rounds up; "ties to even code").
Because the format is an input, ONE compiled artifact serves every
format × bit-width × sub-parameter combination.

Posit semantics (`is_posit=1.0`): nonzero reals never round to zero — they
clamp to ±minpos (the posit standard's no-underflow rule, which the Rust
golden model implements in ``Quantizer::finish``).

The kernel keeps the three 256-entry tables resident in VMEM and streams
activation row-tiles past them; the rounding decision is a broadcast
compare-and-sum (a 256-lane popcount per element), which maps onto the VPU
rather than the MXU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TABLE = 256


def _kernel(x_ref, v_ref, b_ref, t_ref, flags_ref, o_ref):
    x = x_ref[...]  # (bm, d)
    bounds = b_ref[...]  # (256,)
    ties = t_ref[...]  # (256,)
    values = v_ref[...]  # (256,)
    is_posit = flags_ref[0]
    minpos = flags_ref[1]
    # Branchless binary search for lower_bound(bounds, x): number of
    # boundaries strictly below x. 8 gather+compare rounds over the
    # 256-entry table (perf pass iteration 1: replaces a 256-lane broadcast
    # compare-and-sum that cost ~512 VPU ops/element with ~11 gathers —
    # see EXPERIMENTS.md §Perf).
    pos = jnp.zeros(x.shape, dtype=jnp.int32)
    for step in (128, 64, 32, 16, 8, 4, 2, 1):
        cand = pos + step
        probe = jnp.take(bounds, cand - 1)
        pos = jnp.where(probe < x, cand, pos)
    # Exact tie at bounds[pos]: round up when the tie table says so.
    tie_bound = jnp.take(bounds, jnp.minimum(pos, TABLE - 1))
    tie_up = jnp.take(ties, jnp.minimum(pos, TABLE - 1)) > 0.5
    idx = jnp.where((tie_bound == x) & tie_up, pos + 1, pos)
    q = jnp.take(values, idx)
    # Posit no-underflow rule: nonzero x that rounded to 0 -> ±minpos.
    clamp = jnp.sign(x) * minpos
    q = jnp.where((is_posit > 0.5) & (x != 0.0) & (q == 0.0), clamp, q)
    o_ref[...] = q


@functools.partial(jax.jit, static_argnames=("block_m",))
def quantize_lut(x, values, bounds, ties, flags, *, block_m: int = 32):
    """Round each element of ``x`` to the nearest format value.

    Args:
      x: (batch, d) exact sums.
      values: (256,) sorted representable values (padded with max).
      bounds: (256,) midpoint decision boundaries (padded with +inf).
      ties: (256,) 1.0 where an exact midpoint rounds up.
      flags: (2,) = [is_posit, minpos].
      block_m: rows per grid step.

    Returns:
      (batch, d) rounded values.
    """
    batch, d = x.shape
    assert values.shape == (TABLE,) and bounds.shape == (TABLE,) and ties.shape == (TABLE,)
    bm = min(block_m, batch)
    assert batch % bm == 0, f"batch {batch} not divisible by block_m {bm}"
    grid = (batch // bm,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((TABLE,), lambda i: (0,)),
            pl.BlockSpec((TABLE,), lambda i: (0,)),
            pl.BlockSpec((TABLE,), lambda i: (0,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, d), jnp.float64),
        interpret=True,
    )(x, values, bounds, ties, flags)
