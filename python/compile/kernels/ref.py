"""Pure-jnp correctness oracles for the L1 kernels.

These implement the same mathematics with none of the tiling/kernel
machinery: ``ref_emac_matmul`` is a plain f64 einsum; ``ref_quantize`` uses
``jnp.searchsorted`` (a completely different algorithm from the kernel's
broadcast compare-and-sum, which makes the pytest agreement a strong
cross-check).
"""

import jax.numpy as jnp


def ref_emac_matmul(x, w, b, *, relu: bool = False):
    """f64 dense layer: relu?(x @ w + b)."""
    acc = jnp.dot(x.astype(jnp.float64), w.astype(jnp.float64), preferred_element_type=jnp.float64)
    acc = acc + b[None, :]
    return jnp.maximum(acc, 0.0) if relu else acc


def ref_quantize(x, values, bounds, ties, flags):
    """Round-to-nearest (ties by table) via binary search.

    ``searchsorted(side='left')`` counts bounds strictly below x;
    ``side='right'`` also counts exact hits. They differ only on ties, where
    the ``ties`` table arbitrates.
    """
    lo = jnp.searchsorted(bounds, x, side="left")
    hi = jnp.searchsorted(bounds, x, side="right")
    tie = hi > lo  # x exactly equals bounds[lo]
    tie_up = jnp.take(ties, jnp.clip(lo, 0, ties.shape[0] - 1)) > 0.5
    idx = jnp.where(tie & tie_up, lo + 1, lo)
    q = jnp.take(values, idx)
    is_posit, minpos = flags[0], flags[1]
    clamp = jnp.sign(x) * minpos
    return jnp.where((is_posit > 0.5) & (x != 0.0) & (q == 0.0), clamp, q)
