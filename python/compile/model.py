"""L2: the Deep Positron network graphs, composed from the L1 kernels.

Two graph families, both AOT-lowered to HLO text by :mod:`aot`:

* ``make_quantized_infer(dims)`` — the accelerator datapath: quantize input →
  per layer (EMAC matmul → deferred round → ReLU) → logits. The numeric
  format arrives **as data** (value/boundary/tie tables + flags), so one
  artifact per topology serves every format (DESIGN.md §2).
* ``make_train_step(dims)`` / ``make_f32_infer(dims)`` — the 32-bit-float
  baseline: standard f32 forward and an SGD-with-momentum training step
  (softmax cross-entropy), run from the Rust coordinator's training loop.

Python never runs at inference time; these functions exist to be lowered.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels import emac_matmul, quantize_lut  # noqa: E402

#: Weight-decay used by the baseline trainer (matches the Rust substrate).
WEIGHT_DECAY = 1e-4


def make_quantized_infer(dims):
    """Quantized-inference graph for an MLP with layer sizes ``dims``.

    Flat signature (AOT-friendly):
      fn(x, w1, b1, ..., wL, bL, values, bounds, ties, flags) -> (logits,)

    where ``x`` is (batch, dims[0]) f64, each ``wi`` is the **dequantized**
    (dims[i], dims[i+1]) weight matrix, and the last four args are the format
    tables from ``Quantizer::padded_tables`` plus ``[is_posit, minpos]``.
    """
    n_layers = len(dims) - 1

    def fn(x, *rest):
        params = rest[: 2 * n_layers]
        values, bounds, ties, flags = rest[2 * n_layers :]
        act = quantize_lut(x, values, bounds, ties, flags)
        for i in range(n_layers):
            w, b = params[2 * i], params[2 * i + 1]
            hidden = i + 1 < n_layers
            # EMAC: exact f64 accumulation, then one deferred round. The
            # ReLU stage clamps after rounding (ordering is equivalent on
            # the zero boundary; see accel::positron).
            z = emac_matmul(act, w, b, relu=False)
            act = quantize_lut(z, values, bounds, ties, flags)
            if hidden:
                act = jnp.maximum(act, 0.0)
        return (act,)

    return fn


def make_f32_infer(dims):
    """Standard 32-bit float forward pass (the paper's baseline column)."""
    n_layers = len(dims) - 1

    def fn(x, *params):
        act = x
        for i in range(n_layers):
            w, b = params[2 * i], params[2 * i + 1]
            act = act @ w + b[None, :]
            if i + 1 < n_layers:
                act = jnp.maximum(act, 0.0)
        return (act,)

    return fn


def _forward_f32(params, x, n_layers):
    act = x
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        act = act @ w + b[None, :]
        if i + 1 < n_layers:
            act = jnp.maximum(act, 0.0)
    return act


def make_train_step(dims):
    """One SGD-with-momentum step on softmax cross-entropy.

    Flat signature:
      fn(x, y_onehot, lr, momentum,
         w1, b1, ..., wL, bL, vw1, vb1, ..., vwL, vbL)
        -> (loss, w1', b1', ..., vw1', vb1', ...)

    Update rule (matches the Rust trainer in accel::mlp):
      v ← momentum·v − lr·(∇ + decay·w);  w ← w + v
    """
    n_layers = len(dims) - 1
    n_params = 2 * n_layers

    def loss_fn(params, x, y):
        logits = _forward_f32(params, x, n_layers)
        logz = jax.scipy.special.logsumexp(logits, axis=-1, keepdims=True)
        ll = jnp.sum(y * (logits - logz), axis=-1)
        return -jnp.mean(ll)

    def fn(x, y, lr, momentum, *state):
        params = list(state[:n_params])
        vels = list(state[n_params:])
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        out_params = []
        out_vels = []
        for i, (p, v, g) in enumerate(zip(params, vels, grads)):
            decay = WEIGHT_DECAY if i % 2 == 0 else 0.0  # no decay on biases
            v_new = momentum * v - lr * (g + decay * p)
            out_params.append(p + v_new)
            out_vels.append(v_new)
        return tuple([loss] + out_params + out_vels)

    return fn
