"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracles.

hypothesis sweeps shapes and value distributions; exact agreement
(assert_allclose with rtol=0) is required — both paths compute in f64 and
must round identically.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import emac_matmul, quantize_lut
from compile.kernels.ref import ref_emac_matmul, ref_quantize

TABLE = 256


def make_tables(seed=0, kind="posit8es0"):
    """Build (values, bounds, ties, flags) the way the Rust Quantizer does,
    for a posit(8,0)-like value set (enough structure for kernel tests; the
    Rust integration tests cover every real format)."""
    if kind == "posit8es0":
        # A tapered, posit-like value set: ±1.f × 2^k with fewer fraction
        # steps so the whole set fits the 256-entry table (the Rust
        # integration tests cover the true per-format tables).
        vals = {0.0}
        for k in range(-6, 7):
            for frac in range(0, 8):
                v = (1 + frac / 8) * 2.0**k
                vals.add(v)
                vals.add(-v)
        vals = sorted(vals)
        assert len(vals) <= TABLE
        is_posit, minpos = 1.0, min(v for v in vals if v > 0)
    else:
        step = 2.0**-4
        vals = [i * step for i in range(-128, 128)]
        is_posit, minpos = 0.0, step
    values = np.array(vals, dtype=np.float64)
    values = np.pad(values, (0, TABLE - len(values)), mode="edge")
    bounds = (values[:-1] + values[1:]) / 2.0
    bounds = np.append(bounds, np.inf)
    # ties: round up iff the upper candidate has even index (proxy for even
    # code; the Rust side supplies real code parity).
    ties = np.array([(i + 1) % 2 == 0 for i in range(TABLE)], dtype=np.float64)
    flags = np.array([is_posit, minpos], dtype=np.float64)
    return values, bounds, ties, flags


class TestEmacMatmul:
    @given(
        batch=st.sampled_from([1, 2, 4, 8]),
        k=st.integers(1, 40),
        n=st.integers(1, 24),
        relu=st.booleans(),
        seed=st.integers(0, 2**31),
    )
    @settings(max_examples=30, deadline=None)
    def test_matches_ref(self, batch, k, n, relu, seed):
        # Operands are dyadic format values (the deployment domain): every
        # product and partial sum is exact in f64, so kernel and oracle must
        # agree BIT-EXACTLY regardless of accumulation order or FMA fusion.
        # (With arbitrary reals the two XLA fusions differ by 1 ulp.)
        rng = np.random.default_rng(seed)
        dyadic = lambda shape: np.round(rng.normal(size=shape) * 16.0) / 16.0
        x = dyadic((batch, k))
        w = dyadic((k, n))
        b = dyadic((n,))
        got = emac_matmul(x, w, b, relu=relu, block_m=batch)
        want = ref_emac_matmul(x, w, b, relu=relu)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_tiled_equals_untiled(self):
        rng = np.random.default_rng(7)
        x = rng.normal(size=(64, 30))
        w = rng.normal(size=(30, 16))
        b = rng.normal(size=(16,))
        a = emac_matmul(x, w, b, block_m=16)
        c = emac_matmul(x, w, b, block_m=64)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    def test_relu_clamps(self):
        x = -np.ones((1, 4))
        w = np.eye(4)
        b = np.zeros(4)
        out = emac_matmul(x, w, b, relu=True)
        np.testing.assert_array_equal(np.asarray(out), np.zeros((1, 4)))

    def test_accumulation_is_exact(self):
        # 64 products of 2^-12 must survive: 64 × 2^-12 = 2^-6 exactly.
        x = np.full((1, 64), 2.0**-6)
        w = np.full((64, 1), 2.0**-6)
        b = np.zeros(1)
        out = np.asarray(emac_matmul(x, w, b))
        assert out[0, 0] == 2.0**-6

    def test_f64_dtype(self):
        out = emac_matmul(np.ones((1, 3)), np.ones((3, 2)), np.zeros(2))
        assert out.dtype == jnp.float64


class TestQuantizeLut:
    @given(
        batch=st.sampled_from([1, 2, 4]),
        d=st.integers(1, 50),
        kind=st.sampled_from(["posit8es0", "fixed8q4"]),
        seed=st.integers(0, 2**31),
    )
    @settings(max_examples=30, deadline=None)
    def test_matches_ref(self, batch, d, kind, seed):
        values, bounds, ties, flags = make_tables(kind=kind)
        rng = np.random.default_rng(seed)
        # Mix of smooth values and exact ties (midpoints).
        x = rng.normal(scale=2.0, size=(batch, d))
        mids = (values[:-1] + values[1:]) / 2.0
        tie_picks = rng.choice(mids, size=(batch, d))
        use_tie = rng.random((batch, d)) < 0.3
        x = np.where(use_tie, tie_picks, x)
        got = quantize_lut(x, values, bounds, ties, flags, block_m=batch)
        want = ref_quantize(x, values, bounds, ties, flags)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_representable_is_identity(self):
        values, bounds, ties, flags = make_tables()
        x = np.unique(values)[None, :]
        out = np.asarray(quantize_lut(x, values, bounds, ties, flags, block_m=1))
        np.testing.assert_array_equal(out, x)

    def test_posit_never_underflows_to_zero(self):
        values, bounds, ties, flags = make_tables(kind="posit8es0")
        x = np.array([[1e-12, -1e-12, 0.0]])
        out = np.asarray(quantize_lut(x, values, bounds, ties, flags, block_m=1))
        minpos = flags[1]
        np.testing.assert_array_equal(out, [[minpos, -minpos, 0.0]])

    def test_fixed_underflows_to_zero(self):
        values, bounds, ties, flags = make_tables(kind="fixed8q4")
        x = np.array([[1e-12, -1e-12]])
        out = np.asarray(quantize_lut(x, values, bounds, ties, flags, block_m=1))
        np.testing.assert_array_equal(out, [[0.0, 0.0]])

    def test_saturates_at_extremes(self):
        values, bounds, ties, flags = make_tables()
        x = np.array([[1e30, -1e30]])
        out = np.asarray(quantize_lut(x, values, bounds, ties, flags, block_m=1))
        assert out[0, 0] == values.max()
        assert out[0, 1] == values.min()

    def test_tiled_equals_untiled(self):
        values, bounds, ties, flags = make_tables()
        rng = np.random.default_rng(3)
        x = rng.normal(size=(64, 10))
        a = quantize_lut(x, values, bounds, ties, flags, block_m=8)
        c = quantize_lut(x, values, bounds, ties, flags, block_m=64)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
