"""L2 graph tests: quantized MLP composition, train step semantics, and AOT
lowering round-trips (HLO text parses and mentions the right shapes)."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels.ref import ref_emac_matmul, ref_quantize
from tests.test_kernels import make_tables


def rand_params(dims, seed=0, dtype=np.float64):
    rng = np.random.default_rng(seed)
    params = []
    for i in range(len(dims) - 1):
        params.append(rng.normal(scale=0.4, size=(dims[i], dims[i + 1])).astype(dtype))
        params.append(rng.normal(scale=0.1, size=(dims[i + 1],)).astype(dtype))
    return params


class TestQuantizedInfer:
    def test_matches_layerwise_reference(self):
        dims = (6, 5, 3)
        fn = model.make_quantized_infer(dims)
        params = rand_params(dims, seed=1)
        values, bounds, ties, flags = make_tables()
        # Quantize params onto the table first (as the Rust side does).
        qparams = [np.asarray(ref_quantize(p, values, bounds, ties, flags)) for p in params]
        x = np.random.default_rng(2).normal(size=(4, 6))
        (got,) = fn(x, *qparams, values, bounds, ties, flags)
        # Layer-by-layer oracle.
        act = ref_quantize(x, values, bounds, ties, flags)
        for i in range(2):
            z = ref_emac_matmul(act, qparams[2 * i], qparams[2 * i + 1])
            act = ref_quantize(z, values, bounds, ties, flags)
            if i == 0:
                act = jnp.maximum(act, 0.0)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(act))

    def test_outputs_are_representable(self):
        dims = (4, 8, 3)
        fn = model.make_quantized_infer(dims)
        params = rand_params(dims, seed=3)
        values, bounds, ties, flags = make_tables()
        x = np.random.default_rng(4).normal(size=(2, 4))
        (out,) = fn(x, *params, values, bounds, ties, flags)
        out = np.asarray(out).ravel()
        vset = set(np.asarray(values).tolist())
        assert all(v in vset for v in out), "logits must be format values"


class TestTrainStep:
    def test_loss_decreases(self):
        dims = (8, 6, 3)
        step = jax.jit(model.make_train_step(dims))
        rng = np.random.default_rng(5)
        params = rand_params(dims, seed=5, dtype=np.float32)
        vels = [np.zeros_like(p) for p in params]
        x = rng.normal(size=(32, 8)).astype(np.float32)
        labels = rng.integers(0, 3, size=32)
        y = np.eye(3, dtype=np.float32)[labels]
        lr = np.float32(0.1)
        mom = np.float32(0.9)
        losses = []
        for _ in range(30):
            out = step(x, y, lr, mom, *params, *vels)
            loss, rest = out[0], out[1:]
            params = [np.asarray(p) for p in rest[: len(params)]]
            vels = [np.asarray(v) for v in rest[len(params) :]]
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.5, f"loss barely moved: {losses[0]} -> {losses[-1]}"

    def test_momentum_zero_is_plain_sgd(self):
        dims = (4, 2)
        step = jax.jit(model.make_train_step(dims))
        rng = np.random.default_rng(6)
        params = rand_params(dims, seed=6, dtype=np.float32)
        vels = [np.zeros_like(p) for p in params]
        x = rng.normal(size=(8, 4)).astype(np.float32)
        y = np.eye(2, dtype=np.float32)[rng.integers(0, 2, size=8)]
        out = step(x, y, np.float32(0.05), np.float32(0.0), *params, *vels)
        new_w, new_vw = np.asarray(out[1]), np.asarray(out[3])
        # v' = -lr * (grad + decay*w), w' = w + v'
        np.testing.assert_allclose(new_w, params[0] + new_vw, rtol=1e-6)


class TestAot:
    def test_hlo_text_emits_and_mentions_shapes(self):
        dims = (4, 3, 2)
        text = aot.to_hlo_text(model.make_quantized_infer(dims), aot.q_infer_specs(dims, 8))
        assert "HloModule" in text
        assert "f64[8,4]" in text  # input
        assert "f64[8,2]" in text  # logits
        text32 = aot.to_hlo_text(model.make_f32_infer(dims), aot.f32_infer_specs(dims, 8))
        assert "f32[8,4]" in text32

    def test_train_specs_arity(self):
        dims = (4, 3, 2)
        specs = aot.train_specs(dims, 16)
        # x, y, lr, mom + 2 layers × (w,b) × (param+vel)
        assert len(specs) == 4 + 4 + 4

    def test_topologies_match_rust_registry(self):
        # Input/output dims implied by the dataset definitions.
        assert aot.TOPOLOGIES["mnist"] == (784, 100, 10)
        assert aot.TOPOLOGIES["wdbc"][0] == 30 and aot.TOPOLOGIES["wdbc"][-1] == 2
        assert aot.TOPOLOGIES["mushroom"][0] == 117


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
