//! Property tests over coordinator invariants: the batching server and the
//! sweep/report plumbing.

use std::time::Duration;

use deep_positron::coordinator::experiments::train_model;
use deep_positron::coordinator::{serve, ServeConfig};
use deep_positron::datasets::{self, Scale};
use deep_positron::formats::FormatSpec;
use deep_positron::util::prop::forall;

#[test]
fn prop_server_serves_every_request_exactly_once() {
    // Runs a fresh server per case with random burst patterns; every request
    // must receive exactly one reply and metrics must account for all.
    std::env::set_var("PROP_CASES", std::env::var("PROP_CASES").unwrap_or_else(|_| "8".into()));
    let ds = datasets::load("iris", 3, Scale::Small);
    let mlp = train_model(&ds, 3);
    forall("server accounts for all requests", |rng| {
        let cfg = ServeConfig { max_batch_wait: Duration::from_micros(rng.below(3000) as u64), ..Default::default() };
        let handle = serve(&ds, mlp.clone(), cfg).unwrap();
        let n = 1 + rng.below(40);
        let rxs: Vec<_> =
            (0..n).map(|i| handle.submit(ds.test_row(i % ds.test_len()).to_vec()).expect("admitted")).collect();
        let mut replies = 0;
        for rx in rxs {
            let reply = rx.recv().expect("no reply");
            assert!(reply.class < ds.num_classes);
            replies += 1;
        }
        let metrics = handle.shutdown();
        assert_eq!(replies, n);
        assert_eq!(metrics.served, n);
        assert_eq!(metrics.latency.count(), n as u64);
        assert!(metrics.max_batch <= n, "largest batch cannot exceed the requests submitted");
        assert!(metrics.batches <= n);
    });
}

#[test]
fn prop_best_accuracy_is_max_of_family_sweep() {
    std::env::set_var("PROP_CASES", std::env::var("PROP_CASES").unwrap_or_else(|_| "6".into()));
    let ds = datasets::load("iris", 9, Scale::Small);
    let mlp = train_model(&ds, 9);
    forall("best_accuracy = max over sweep", |rng| {
        let family = ["posit", "float", "fixed"][rng.below(3)];
        let n = 5 + rng.below(4) as u32;
        let engine = deep_positron::coordinator::Engine::Sim;
        let (best, spec) =
            deep_positron::coordinator::experiments::best_accuracy(engine, None, &mlp, &ds, family, n).unwrap();
        assert_eq!(spec.family(), family);
        assert_eq!(spec.n(), n);
        for s in FormatSpec::sweep_family(n, family) {
            let acc = deep_positron::coordinator::experiments::eval_sim(&mlp, &ds, s);
            assert!(acc <= best + 1e-12, "{s} beats reported best");
        }
    });
}
