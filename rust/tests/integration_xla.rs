//! Integration tests across the three layers: AOT artifacts → PJRT runtime
//! → agreement with the bit-exact Rust simulator (the repository's central
//! correctness claim), plus the PJRT training loop.
//!
//! These tests need `artifacts/` (run `make artifacts` first); they skip
//! with a notice when artifacts are missing so plain `cargo test` works in
//! a fresh checkout.

use deep_positron::accel::DeepPositron;
use deep_positron::coordinator::{experiments, trainer, Engine};
use deep_positron::datasets::{self, Scale};
use deep_positron::formats::FormatSpec;
use deep_positron::runtime::{artifacts_dir, Runtime};

fn runtime_or_skip() -> Option<Runtime> {
    let dir = artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

#[test]
fn xla_and_sim_engines_agree_on_iris() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = datasets::load("iris", 11, Scale::Small);
    let mlp = experiments::train_model(&ds, 11);
    for spec_name in ["posit8es1", "posit8es0", "float8we4", "float8we3", "fixed8q4", "posit5es0", "float6we3"] {
        let spec = FormatSpec::parse(spec_name).unwrap();
        let sim = experiments::eval_sim(&mlp, &ds, spec);
        let xla = experiments::eval_xla(&rt, &mlp, &ds, spec).expect("xla eval");
        assert!(
            (sim - xla).abs() < 1e-12,
            "engine disagreement for {spec_name}: sim {sim} vs xla {xla}"
        );
    }
}

#[test]
fn xla_logits_match_sim_values_exactly() {
    // Stronger than accuracy agreement: per-sample output values must match
    // the simulator's decoded EMAC outputs bit-for-bit in the exact regimes.
    let Some(rt) = runtime_or_skip() else { return };
    let ds = datasets::load("iris", 11, Scale::Small);
    let mlp = experiments::train_model(&ds, 11);
    for spec_name in ["posit8es1", "float8we4", "fixed8q4"] {
        let spec = FormatSpec::parse(spec_name).unwrap();
        let dp = DeepPositron::compile(&mlp, spec);
        let xla_acc = experiments::eval_xla(&rt, &mlp, &ds, spec).unwrap();
        let mut mismatches = 0usize;
        for i in 0..ds.test_len() {
            let codes = dp.forward_codes(ds.test_row(i));
            let sim_vals: Vec<f64> =
                codes.iter().map(|&c| dp.quantizer().decode(c).unwrap().to_f64()).collect();
            let deq = dp.forward_dequantized(ds.test_row(i));
            if sim_vals != deq {
                mismatches += 1;
            }
        }
        assert_eq!(mismatches, 0, "{spec_name}: EMAC vs dequantized-f64 path diverged");
        // Accuracy floor only for the robust formats: narrow fixed-point Qs
        // legitimately collapse on raw-scale inputs (the paper's WDBC row).
        if !spec_name.starts_with("fixed") {
            assert!(xla_acc > 0.5, "{spec_name} collapsed: {xla_acc}");
        }
    }
}

#[test]
fn posit8_es2_argmax_agreement() {
    // posit8 es=2's quire exceeds f64's exact window; we only require
    // argmax-level agreement between the two engines (DESIGN.md §2).
    let Some(rt) = runtime_or_skip() else { return };
    let ds = datasets::load("iris", 11, Scale::Small);
    let mlp = experiments::train_model(&ds, 11);
    let spec = FormatSpec::parse("posit8es2").unwrap();
    let sim = experiments::eval_sim(&mlp, &ds, spec);
    let xla = experiments::eval_xla(&rt, &mlp, &ds, spec).unwrap();
    assert!((sim - xla).abs() <= 2.0 / ds.test_len() as f64, "sim {sim} vs xla {xla}");
}

#[test]
fn pjrt_training_loop_reduces_loss() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = datasets::load("iris", 4, Scale::Small);
    let cfg = trainer::LoopConfig { epochs: 40, lr: 0.05, momentum: 0.9, seed: 4, log_every: 0 };
    let (state, log) = trainer::train_via_pjrt(&rt, &ds, &cfg).expect("train");
    assert!(log.steps > 0);
    let first = log.epoch_loss.first().unwrap();
    let last = log.epoch_loss.last().unwrap();
    assert!(last < &(first * 0.7), "loss barely moved: {first} -> {last}");
    // The PJRT-trained network must actually classify.
    let mlp = state.to_mlp();
    let acc = mlp.accuracy(&ds);
    assert!(acc > 0.85, "PJRT-trained iris accuracy {acc}");
}

#[test]
fn xla_and_sim_agree_across_all_topologies() {
    // Every dataset topology (2-, 3-, and 4-layer; 4..784 inputs) through
    // both engines at a representative format.
    let Some(rt) = runtime_or_skip() else { return };
    let spec = FormatSpec::parse("posit8es1").unwrap();
    for name in ["wdbc", "mushroom", "fashion"] {
        let ds = datasets::load(name, 11, Scale::Small);
        let mlp = experiments::train_model(&ds, 11);
        let sim = experiments::eval_sim(&mlp, &ds, spec);
        let xla = experiments::eval_xla(&rt, &mlp, &ds, spec).expect("xla eval");
        assert!((sim - xla).abs() < 1e-12, "{name}: sim {sim} vs xla {xla}");
        assert!(sim > 0.5, "{name} collapsed: {sim}");
    }
}

#[test]
fn ablation_datapaths_are_consistent() {
    // EMAC == NarrowQuire(126) (wide enough never to wrap); the inexact MAC
    // never *exceeds* a wide-margin sanity bound of the exact one.
    let ds = datasets::load("iris", 11, Scale::Small);
    let mlp = experiments::train_model(&ds, 11);
    let dp = deep_positron::accel::DeepPositron::compile(&mlp, FormatSpec::parse("posit8es1").unwrap());
    use deep_positron::accel::Datapath;
    let exact = dp.accuracy_with(&ds, Datapath::Emac);
    let wide = dp.accuracy_with(&ds, Datapath::NarrowQuire(126));
    assert_eq!(exact, wide, "a never-wrapping narrow quire must equal the EMAC");
    let inexact = dp.accuracy_with(&ds, Datapath::InexactMac);
    assert!(inexact <= exact + 0.15, "inexact MAC implausibly better: {inexact} vs {exact}");
}

#[test]
fn xla_batching_pads_partial_batches() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = datasets::load("iris", 11, Scale::Small);
    let mlp = experiments::train_model(&ds, 11);
    let spec = FormatSpec::parse("posit8es1").unwrap();
    let dp = DeepPositron::compile(&mlp, spec);
    let tables = deep_positron::runtime::FormatTables::new(spec, dp.quantizer());
    // python-layout weights
    let wq = dp.dequantized_weights();
    let bq = dp.dequantized_biases();
    let mut weights = Vec::new();
    for (l, w) in mlp.layers.iter().zip(&wq) {
        let mut wio = vec![0.0; l.in_dim * l.out_dim];
        for o in 0..l.out_dim {
            for i in 0..l.in_dim {
                wio[i * l.out_dim + o] = w[o * l.in_dim + i];
            }
        }
        weights.push(wio);
    }
    let exe = rt.quantized_infer("iris", 64).expect("exe");
    // 3 rows through a 64-batch artifact: padding must not disturb results.
    let rows = 3;
    let x = &ds.x_test[..rows * ds.num_features];
    let logits = exe.run(x, rows, &weights, &bq, &tables).expect("run");
    assert_eq!(logits.len(), rows * ds.num_classes);
    for r in 0..rows {
        let expect = dp.forward_dequantized(ds.test_row(r));
        let got = &logits[r * ds.num_classes..(r + 1) * ds.num_classes];
        assert_eq!(got, &expect[..], "row {r}");
    }
}
