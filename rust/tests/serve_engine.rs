//! Integration tests for the sharded serving engine (`rust/src/serve`):
//! format-shard routing, worker-pool spreading, shared-table caching, and
//! the serving edge cases (zero-length request, partial-batch deadline
//! expiry, shutdown with in-flight requests, Sim fallback without
//! artifacts).

use std::sync::Arc;
use std::time::Duration;

use deep_positron::coordinator::experiments::{train_model, Engine};
use deep_positron::datasets::{self, Dataset, Scale};
use deep_positron::formats::{FormatSpec, Quantizer};
use deep_positron::serve::{ServeEngine, ServeError, ShardConfig, ShardKey, WorkerConfig};

fn iris() -> (Dataset, deep_positron::accel::Mlp) {
    let ds = datasets::load("iris", 3, Scale::Small);
    let mlp = train_model(&ds, 3);
    (ds, mlp)
}

#[test]
fn routes_across_format_shards() {
    let (ds, mlp) = iris();
    let specs = [FormatSpec::parse("posit8es1").unwrap(), FormatSpec::parse("fixed8q5").unwrap()];
    let shards = specs.iter().map(|&s| ShardConfig::new(&ds, mlp.clone(), s)).collect();
    let engine = ServeEngine::start(shards).unwrap();
    assert_eq!(engine.shard_keys().len(), 2);

    for &spec in &specs {
        let key = ShardKey::new("iris", spec);
        let rxs: Vec<_> = (0..10).map(|i| engine.submit(&key, ds.test_row(i).to_vec()).unwrap()).collect();
        for rx in rxs {
            let reply = rx.recv().unwrap();
            assert!(reply.class < ds.num_classes);
        }
    }
    // Unknown shard key is an error, not a panic.
    let missing = ShardKey::new("iris", FormatSpec::parse("float8we4").unwrap());
    assert!(matches!(engine.submit(&missing, ds.test_row(0).to_vec()), Err(ServeError::UnknownShard(_))));

    let metrics = engine.shutdown();
    assert_eq!(metrics.shards.len(), 2);
    for shard in &metrics.shards {
        assert_eq!(shard.served, 10, "{}", shard.shard);
        assert_eq!(shard.latency.count(), 10);
    }
    assert_eq!(metrics.total_served(), 20);
}

#[test]
fn zero_length_request_is_rejected_not_fatal() {
    let (ds, mlp) = iris();
    let spec = FormatSpec::parse("posit8es1").unwrap();
    let engine = ServeEngine::start(vec![ShardConfig::new(&ds, mlp, spec)]).unwrap();
    let key = ShardKey::new("iris", spec);

    let err = engine.submit(&key, Vec::new()).unwrap_err();
    assert_eq!(err, ServeError::BadRequest { got: 0, want: ds.num_features });
    // Wrong (nonzero) dimension is rejected the same way.
    let err = engine.submit(&key, vec![0.0; ds.num_features + 1]).unwrap_err();
    assert_eq!(err, ServeError::BadRequest { got: ds.num_features + 1, want: ds.num_features });

    // The engine keeps serving after rejected requests.
    let reply = engine.submit(&key, ds.test_row(0).to_vec()).unwrap().recv().unwrap();
    assert!(reply.class < ds.num_classes);
    let metrics = engine.shutdown();
    assert_eq!(metrics.total_served(), 1, "rejected requests must not be counted");
}

#[test]
fn partial_batch_flushes_on_deadline() {
    let (ds, mlp) = iris();
    let spec = FormatSpec::parse("posit8es1").unwrap();
    let mut shard = ShardConfig::new(&ds, mlp, spec);
    // Large batch cap + long-ish deadline: 3 requests can never fill the
    // batch, so replies prove the deadline flush path works.
    shard.worker = WorkerConfig { max_batch_wait: Duration::from_millis(25), sim_batch: 64, ..WorkerConfig::default() };
    let engine = ServeEngine::start(vec![shard]).unwrap();
    let key = ShardKey::new("iris", spec);

    let rxs: Vec<_> = (0..3).map(|i| engine.submit(&key, ds.test_row(i).to_vec()).unwrap()).collect();
    for rx in rxs {
        rx.recv().expect("partial batch must flush at the deadline");
    }
    let metrics = engine.shutdown();
    let shard = &metrics.shards[0];
    assert_eq!(shard.served, 3);
    assert!(shard.batches >= 1);
    assert!(shard.max_batch <= 3, "largest batch {} exceeds the 3 requests submitted", shard.max_batch);
}

#[test]
fn shutdown_serves_in_flight_requests() {
    let (ds, mlp) = iris();
    let spec = FormatSpec::parse("posit8es1").unwrap();
    let mut shard = ShardConfig::new(&ds, mlp, spec);
    // Long deadline so the batch is still open when shutdown arrives.
    shard.worker =
        WorkerConfig { max_batch_wait: Duration::from_millis(200), sim_batch: 64, ..WorkerConfig::default() };
    let engine = ServeEngine::start(vec![shard]).unwrap();
    let key = ShardKey::new("iris", spec);

    let n = 25;
    let rxs: Vec<_> = (0..n).map(|i| engine.submit(&key, ds.test_row(i % ds.test_len()).to_vec()).unwrap()).collect();
    // Shut down immediately, without consuming a single reply.
    let metrics = engine.shutdown();
    assert_eq!(metrics.total_served(), n, "every in-flight request must be served before shutdown");
    for rx in rxs {
        let reply = rx.recv().expect("reply must have been sent before the worker exited");
        assert!(reply.class < ds.num_classes);
    }
}

#[test]
fn xla_shard_falls_back_to_sim_without_artifacts() {
    // Point the artifact lookup at an empty directory: the Xla-preferring
    // shard must degrade to Sim per worker and still serve correctly.
    let dir = std::env::temp_dir().join("dp_serve_no_artifacts");
    let _ = std::fs::create_dir_all(&dir);
    std::env::set_var("REPRO_ARTIFACTS", &dir);

    let (ds, mlp) = iris();
    let spec = FormatSpec::parse("posit8es1").unwrap();
    let shard = ShardConfig::new(&ds, mlp, spec).with_engine(Engine::Xla).with_workers(2);
    let engine = ServeEngine::start(vec![shard]).unwrap();
    let key = ShardKey::new("iris", spec);

    let rxs: Vec<_> = (0..8).map(|i| engine.submit(&key, ds.test_row(i).to_vec()).unwrap()).collect();
    for rx in rxs {
        assert!(rx.recv().unwrap().class < ds.num_classes);
    }
    let metrics = engine.shutdown();
    let shard = &metrics.shards[0];
    assert_eq!(shard.served, 8);
    assert_eq!(shard.xla_workers, 0, "no artifacts -> every worker must report the Sim fallback");
}

#[test]
fn round_robin_spreads_load_and_affinity_pins() {
    let (ds, mlp) = iris();
    let spec = FormatSpec::parse("posit8es1").unwrap();
    let shard = ShardConfig::new(&ds, mlp, spec).with_workers(4);
    let engine = ServeEngine::start(vec![shard]).unwrap();
    let key = ShardKey::new("iris", spec);

    // Sequential round-robin: 40 requests over 4 workers = 10 each.
    for i in 0..40 {
        let reply = engine.submit(&key, ds.test_row(i % ds.test_len()).to_vec()).unwrap().recv().unwrap();
        assert_eq!(reply.worker, i % 4, "round-robin must cycle workers deterministically");
    }
    // Affinity: one session hash always lands on one worker.
    let workers: Vec<usize> = (0..10)
        .map(|i| {
            engine
                .submit_with_affinity(&key, 0xFEED, ds.test_row(i).to_vec())
                .unwrap()
                .recv()
                .unwrap()
                .worker
        })
        .collect();
    assert!(workers.windows(2).all(|w| w[0] == w[1]), "affinity must pin a worker: {workers:?}");

    let metrics = engine.shutdown();
    let shard = &metrics.shards[0];
    assert_eq!(shard.per_worker.iter().sum::<usize>(), 50);
    assert!(shard.per_worker.iter().all(|&c| c >= 10), "per-worker spread: {:?}", shard.per_worker);
}

#[test]
fn flushed_batch_matches_per_sample_submission() {
    // The Sim engine now executes a flushed multi-request batch through the
    // accelerator's compiled plan (`forward_batch`); the classes must be
    // identical to per-sample prediction — dynamic batching is a throughput
    // optimization, never a semantic one.
    let (ds, mlp) = iris();
    let spec = FormatSpec::parse("posit8es1").unwrap();
    let dp = deep_positron::accel::DeepPositron::compile(&mlp, spec);
    let n = 16;
    let expected: Vec<usize> = (0..n).map(|i| dp.predict(ds.test_row(i))).collect();

    let mut shard = ShardConfig::new(&ds, mlp, spec);
    // Batch cap = n with a generous deadline: the burst below coalesces into
    // (at least one) multi-request batch.
    shard.worker = WorkerConfig { max_batch_wait: Duration::from_millis(50), sim_batch: n, ..WorkerConfig::default() };
    let engine = ServeEngine::start(vec![shard]).unwrap();
    let key = ShardKey::new("iris", spec);
    let rxs: Vec<_> = (0..n).map(|i| engine.submit(&key, ds.test_row(i).to_vec()).unwrap()).collect();
    let classes: Vec<usize> = rxs.into_iter().map(|rx| rx.recv().unwrap().class).collect();
    assert_eq!(classes, expected, "batched serving must match per-sample prediction");

    let metrics = engine.shutdown();
    let shard = &metrics.shards[0];
    assert_eq!(shard.served, n);
    assert!(
        shard.max_batch > 1,
        "burst of {n} never coalesced into a multi-request batch (max batch {})",
        shard.max_batch
    );
}

#[test]
fn worker_replicas_share_one_quantizer_table() {
    // Pre-build the table for a spec nothing else in this binary uses, then
    // start 4 worker replicas: every replica must attach to the SAME cached
    // table (pointer-stable across engine start), never rebuild it. (The
    // global build counter is shared with concurrently running tests, so
    // this asserts pointer identity rather than a counter delta; the
    // once-per-spec counter semantics are covered by the lib test in
    // formats::tables.)
    let spec = FormatSpec::parse("float7we3").unwrap();
    let prewarmed = Quantizer::shared(spec);
    let (ds, mlp) = iris();
    let engine = ServeEngine::start(vec![ShardConfig::new(&ds, mlp, spec).with_workers(4)]).unwrap();
    assert!(
        Arc::ptr_eq(&prewarmed, &Quantizer::shared(spec)),
        "starting 4 replicas must reuse the prewarmed shared table"
    );

    let key = ShardKey::new("iris", spec);
    let reply = engine.submit(&key, ds.test_row(0).to_vec()).unwrap().recv().unwrap();
    assert!(reply.class < ds.num_classes);
    engine.shutdown();
}
