//! Property tests over the FPGA cost model — the tuner's hardware axis
//! (DESIGN.md §10). Two families of guarantees:
//!
//! * every swept spec's accumulator width equals the paper's Eq. (2)
//!   closed form, recomputed here independently from the format's own
//!   max/min magnitudes;
//! * LUTs / energy / EDP are monotonically non-decreasing in bit-width `n`
//!   at fixed family, sub-parameter, and `k` — so a calibration-constant
//!   regression cannot silently invert the tuner's cost orderings.

use deep_positron::formats::{quire_width_bits, Format, FormatSpec};
use deep_positron::hw;

const KS: [usize; 5] = [4, 16, 100, 256, 784];

#[test]
fn quire_bits_match_eq2_closed_form_for_every_swept_spec() {
    for &k in &KS {
        for n in 5..=8u32 {
            for spec in FormatSpec::sweep(n) {
                let fmt = spec.build();
                // Eq. (2), recomputed from scratch:
                //   w_a = ceil(log2 k) + 2·ceil(log2(max/min)) + 2
                let kk = k.max(2) as f64;
                let range = (fmt.max_value() / fmt.min_pos()).log2().ceil() as u32;
                let closed_form = kk.log2().ceil() as u32 + 2 * range + 2;
                let r = hw::synthesize(spec, k);
                assert_eq!(r.quire_bits, closed_form, "{spec} at k={k}");
                assert_eq!(
                    r.quire_bits,
                    quire_width_bits(k, fmt.max_value(), fmt.min_pos()),
                    "{spec} at k={k}: synthesize and quire_width_bits disagree"
                );
            }
        }
    }
}

#[test]
fn quire_bits_are_monotone_in_k() {
    for n in 5..=8u32 {
        for spec in FormatSpec::sweep(n) {
            for w in KS.windows(2) {
                let small = hw::synthesize(spec, w[0]);
                let big = hw::synthesize(spec, w[1]);
                assert!(big.quire_bits >= small.quire_bits, "{spec}: k={} vs k={}", w[0], w[1]);
            }
        }
    }
}

/// All (family, sub-parameter) chains the sweep contains, as constructors.
fn chain_spec(family: &str, n: u32, sub: u32) -> FormatSpec {
    match family {
        "posit" => FormatSpec::Posit { n, es: sub },
        "float" => FormatSpec::Float { n, we: sub },
        "fixed" => FormatSpec::Fixed { n, q: sub },
        _ => unreachable!(),
    }
}

#[test]
fn cost_is_monotone_in_bit_width_at_fixed_sub_parameter() {
    for &k in &[16usize, 784] {
        for (family, subs) in [("posit", 0u32..=2), ("float", 2..=5), ("fixed", 1..=6)] {
            for sub in subs {
                let mut prev: Option<(u32, hw::SynthReport)> = None;
                for n in 5..=8u32 {
                    let spec = chain_spec(family, n, sub);
                    // Only chain through configs the paper actually sweeps
                    // (e.g. float we=5 first exists at n=7, fixed q ≤ n−2).
                    if !FormatSpec::sweep(n).contains(&spec) {
                        continue;
                    }
                    let r = hw::synthesize(spec, k);
                    if let Some((pn, p)) = &prev {
                        assert!(r.luts >= p.luts, "{family} sub={sub} k={k}: LUTs fell from n={pn} to n={n}");
                        assert!(
                            r.energy_pj >= p.energy_pj,
                            "{family} sub={sub} k={k}: energy fell from n={pn} to n={n}"
                        );
                        assert!(
                            r.edp_pj_ns >= p.edp_pj_ns,
                            "{family} sub={sub} k={k}: EDP fell from n={pn} to n={n}"
                        );
                    }
                    prev = Some((n, r));
                }
                // End-to-end the growth must be strict: an 8-bit EMAC is
                // never as cheap as the 5/6-bit one of the same config.
                let first_n = (5..=8u32).find(|&n| FormatSpec::sweep(n).contains(&chain_spec(family, n, sub)));
                if let (Some(fnn), Some((ln, last))) = (first_n, &prev) {
                    if fnn < *ln {
                        let first = hw::synthesize(chain_spec(family, fnn, sub), k);
                        assert!(last.luts > first.luts, "{family} sub={sub} k={k}: no net LUT growth");
                        assert!(last.edp_pj_ns > first.edp_pj_ns, "{family} sub={sub} k={k}: no net EDP growth");
                    }
                }
            }
        }
    }
}
