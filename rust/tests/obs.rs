//! Observability-layer integration gates (DESIGN.md §15):
//!
//! * **Quantile fidelity** — on random sample clouds, `LogHistogram`
//!   quantiles match `util::stats::percentile`'s exact nearest-rank answer
//!   to within one bucket (relative error ≤ 1/SUB_BUCKETS), never above it.
//! * **Merge algebra** — bucket-wise merge is associative, so shard-level
//!   roll-ups are order-independent.
//! * **Concurrency determinism** — the same sample multiset recorded under
//!   different thread interleavings yields bit-identical snapshots.
//! * **Bounded memory** — 1M recorded samples grow the histogram by zero
//!   bytes (the fix for the unbounded `Vec<f64>` latency logs the serving
//!   engine used to keep).
//! * **Codecs** — the trace-dump and snapshot JSON codecs round-trip and
//!   reject corrupted artifacts.

use std::sync::Arc;

use deep_positron::obs::hist::{bucket_low, bucket_of, bucket_width, SUB_BUCKETS};
use deep_positron::obs::recorder::{dump_to_string, parse_dump, TraceEvent};
use deep_positron::obs::{HistSnapshot, LogHistogram, ObsSnapshot};
use deep_positron::util::{stats, Rng};

/// One random sample cloud: mixed scales so buckets from the exact zone
/// (< 2·SUB_BUCKETS) up through multi-millisecond octaves all get hit.
fn cloud(rng: &mut Rng, n: usize) -> Vec<u64> {
    (0..n)
        .map(|_| {
            let octave = rng.below(30) as u32;
            let base = 1u64 << octave;
            base + (rng.next_u64() % base.max(1))
        })
        .collect()
}

#[test]
fn quantiles_track_exact_percentiles_within_one_bucket() {
    let mut rng = Rng::new(0xB0B5);
    for case in 0..20 {
        let samples = cloud(&mut rng, 257 + case * 31);
        let h = LogHistogram::new();
        for &s in &samples {
            h.record(s);
        }
        let exact: Vec<f64> = samples.iter().map(|&s| s as f64).collect();
        for p in [1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            let q = h.quantile_ns(p);
            let e = stats::percentile(&exact, p) as u64;
            assert!(q <= e, "case {case} p{p}: histogram {q} above exact {e}");
            let width = bucket_width(bucket_of(e));
            assert!(e - q < width, "case {case} p{p}: {q} vs exact {e}, off by more than a bucket ({width})");
            assert!(
                (e - q) as f64 <= e as f64 / SUB_BUCKETS as f64,
                "case {case} p{p}: relative error {q} vs {e} above 1/{SUB_BUCKETS}"
            );
        }
    }
}

#[test]
fn bucket_low_inverts_bucket_of_across_octaves() {
    let mut rng = Rng::new(7);
    for _ in 0..10_000 {
        let v = rng.next_u64() >> (rng.below(64) as u32);
        let idx = bucket_of(v);
        let low = bucket_low(idx);
        assert!(low <= v && v - low < bucket_width(idx), "v={v} idx={idx} low={low}");
    }
}

#[test]
fn merge_is_associative() {
    let mut rng = Rng::new(42);
    let parts: Vec<Vec<u64>> = (0..3).map(|_| cloud(&mut rng, 100)).collect();
    let hists: Vec<LogHistogram> = parts
        .iter()
        .map(|p| {
            let h = LogHistogram::new();
            for &s in p {
                h.record(s);
            }
            h
        })
        .collect();
    // (a ⊕ b) ⊕ c
    let left = LogHistogram::new();
    left.merge(&hists[0]);
    left.merge(&hists[1]);
    left.merge(&hists[2]);
    // a ⊕ (b ⊕ c)
    let bc = LogHistogram::new();
    bc.merge(&hists[1]);
    bc.merge(&hists[2]);
    let right = LogHistogram::new();
    right.merge(&hists[0]);
    right.merge(&bc);
    assert_eq!(left.snapshot(), right.snapshot());
    // And the merged snapshot equals recording everything into one histogram.
    let flat = LogHistogram::new();
    for p in &parts {
        for &s in p {
            flat.record(s);
        }
    }
    assert_eq!(left.snapshot(), flat.snapshot());
}

#[test]
fn concurrent_recording_is_bit_deterministic() {
    let mut rng = Rng::new(0xC0FFEE);
    let samples = Arc::new(cloud(&mut rng, 4000));
    let build = |order: Vec<usize>| {
        let h = Arc::new(LogHistogram::new());
        let mut joins = Vec::new();
        for chunk in order.chunks(order.len() / 4) {
            let h = Arc::clone(&h);
            let samples = Arc::clone(&samples);
            let chunk = chunk.to_vec();
            joins.push(std::thread::spawn(move || {
                for i in chunk {
                    h.record(samples[i]);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        h.snapshot()
    };
    let forward: Vec<usize> = (0..samples.len()).collect();
    let backward: Vec<usize> = (0..samples.len()).rev().collect();
    let mut shuffled: Vec<usize> = forward.clone();
    Rng::new(9).shuffle(&mut shuffled);
    let a = build(forward);
    let b = build(backward);
    let c = build(shuffled);
    assert_eq!(a, b, "same multiset, different interleaving, different snapshot");
    assert_eq!(a, c);
    assert_eq!(a.count(), samples.len() as u64);
}

#[test]
fn memory_is_o1_across_a_million_samples() {
    let h = LogHistogram::new();
    let mut rng = Rng::new(31337);
    for _ in 0..1_000 {
        h.record(rng.next_u64() >> 20);
    }
    let early = h.snapshot().len_buckets();
    for _ in 1_000..1_000_000u64 {
        h.record(rng.next_u64() >> 20);
    }
    let snap = h.snapshot();
    assert_eq!(snap.count(), 1_000_000, "every sample counted");
    assert_eq!(snap.len_buckets(), early, "bucket storage grew with sample count");
}

#[test]
fn empty_and_merged_snapshots_behave() {
    let mut a = HistSnapshot::default();
    let h = LogHistogram::new();
    h.record(500);
    h.record(700);
    a.merge_from(&h.snapshot());
    assert_eq!(a.count(), 2);
    assert_eq!(a.nonzero().iter().map(|&(_, n)| n).sum::<u64>(), 2);
}

#[test]
fn trace_and_snapshot_codecs_round_trip_and_reject() {
    let events: Vec<TraceEvent> = (1..=5u64)
        .map(|i| TraceEvent {
            trace: i,
            shard: "iris/posit8es0".into(),
            worker: i % 2,
            rows: 4,
            queue_ns: 10 * i,
            compute_ns: 100 * i,
            reply_ns: i,
            total_ns: 111 * i,
        })
        .collect();
    let text = dump_to_string(&events);
    assert_eq!(parse_dump(&text).unwrap(), events);
    // Any phase perturbation breaks the telescoping invariant.
    let broken = text.replace("\"total_ns\":111}", "\"total_ns\":112}");
    assert!(parse_dump(&broken).is_err());

    let snap = ObsSnapshot::default();
    assert_eq!(ObsSnapshot::from_json(&snap.to_json()).unwrap(), snap);
    assert!(ObsSnapshot::from_json("{\"schema\": 1}").is_err());
}
