// Seeded violation: a bench source with no `[[bench]]` entry in Cargo.toml,
// never run in CI, and recording a perf trajectory with no committed
// baseline. All three must be flagged as [bench-unwired] when this file is
// audited (as `orphan_bench`) against the repository's real wiring.

use deep_positron::util::bench_log::{self, BenchLog};

fn main() {
    let mut log = BenchLog::new("orphan_bench");
    log.push("synthetic/throughput", 123.0).expect("finite measurement");
    bench_log::record_and_gate(&log, bench_log::DEFAULT_TOLERANCE);
}
