// lint-corpus: zone=serve
// Seeded violation: an unannotated `.unwrap()` on the serve request path.
// Workers shed load on bad input, they never abort; this must be flagged
// as [panic-on-serve-path].

fn route(shards: &[usize], key: usize) -> usize {
    *shards.get(key % shards.len()).unwrap()
}
