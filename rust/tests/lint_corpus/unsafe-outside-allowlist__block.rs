// lint-corpus: zone=none
// Seeded violation: an `unsafe` block in an ordinary module. Only
// util::pool is allowlisted; everywhere else this must be flagged as
// [unsafe-outside-allowlist].

fn read_raw(p: *const u8) -> u8 {
    unsafe { *p }
}
