// lint-corpus: zone=exact
// Seeded violation: a float cast on the accumulation path. The quire zones
// (formats::emac, accel::positron) are integer-only; `as f64` here must be
// flagged as [float-in-exact-zone].

fn accumulate(codes: &[u16]) -> i128 {
    let mut quire: i128 = 0;
    for &c in codes {
        quire += (c as f64 * 2.0) as i128;
    }
    quire
}
