// lint-corpus: zone=exact
// Seeded violation: an exact-lint annotation with no reason. Boundaries
// must say WHY they are exempt; this must be flagged as [bad-annotation].

// exact-lint: allow(float)
fn readout(q: i128) -> f64 {
    q as f64
}
