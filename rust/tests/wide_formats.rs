//! 16-bit format coverage: the codecs generalize beyond the paper's [5,8]
//! sweep — IEEE-754 half (float16 we5), bfloat16 (float16 we8), posit16 —
//! including the EMAC-width guard that rejects quires wider than the i128
//! accumulator.

use deep_positron::formats::{Emac, Format, FormatSpec, Quantizer};

#[test]
fn half_precision_known_values() {
    // IEEE binary16 layout (we=5, wf=10), minus Inf/NaN per Deep Positron.
    let half = FormatSpec::Float { n: 16, we: 5 }.build();
    let q = Quantizer::new(half.as_ref());
    for x in [1.0, -1.5, 0.333251953125, 1024.0, 6.103515625e-5] {
        let (_, v) = q.quantize_f64(x);
        assert_eq!(v, x, "half must represent {x} exactly");
    }
    // max = 2^15 × (2 − 2^-10) = 65504
    assert_eq!(half.max_value(), 65504.0);
    // smallest subnormal = 2^-24
    assert_eq!(half.min_pos(), 2.0f64.powi(-24));
    // 1/3 rounds to the nearest half value
    let (_, v) = q.quantize_f64(1.0 / 3.0);
    assert!((v - 1.0 / 3.0).abs() < 2.0f64.powi(-11));
}

#[test]
fn bfloat16_known_values() {
    let bf16 = FormatSpec::Float { n: 16, we: 8 }.build();
    let q = Quantizer::new(bf16.as_ref());
    // bf16 has f32's exponent range (bias 127, exp_max 254): max =
    // 2^127 × (2 − 2^-7).
    assert_eq!(bf16.max_value(), 2.0f64.powi(127) * (2.0 - 2.0f64.powi(-7)));
    let (_, v) = q.quantize_f64(3.141592653589793);
    assert_eq!(v, 3.140625, "π in bfloat16");
}

#[test]
fn posit16_es1_structure() {
    let p16 = FormatSpec::Posit { n: 16, es: 1 }.build();
    let q = Quantizer::new(p16.as_ref());
    assert_eq!(q.len(), 65535); // 2^16 − NaR
    assert_eq!(p16.max_value(), 2.0f64.powi(28)); // useed^14 = 4^14
    let (_, v) = q.quantize_f64(1.0);
    assert_eq!(v, 1.0);
    // Tapered: step near 1.0 is 2^-12 (12 fraction bits at regime 01/10).
    let (_, v) = q.quantize_f64(1.0 + 2.0f64.powi(-12));
    assert_eq!(v, 1.0 + 2.0f64.powi(-12));
}

#[test]
fn half_precision_emac_works() {
    // Quire for half at k=64: ceil(log2 64) + 2×ceil(log2(65504/2^-24)) + 2
    // = 6 + 2×40 + 2 = 88 bits — fits i128.
    let half = FormatSpec::Float { n: 16, we: 5 }.build();
    let q = Quantizer::new(half.as_ref());
    let mut emac = Emac::new(half.as_ref(), &q, 64);
    let (c, _) = q.quantize_f64(0.125);
    for _ in 0..64 {
        emac.mac(c, c);
    }
    let out = emac.result(false);
    assert_eq!(q.decode(out).unwrap().to_f64(), 1.0); // 64 × 0.125²
}

#[test]
#[should_panic(expected = "quire needs")]
fn posit16_es2_emac_exceeds_i128_and_is_rejected() {
    // posit16 es=2: max/min ratio = useed^(2n−4) = 16^28 = 2^112; Eq. (2)
    // demands far beyond 127 bits. The constructor must refuse loudly
    // rather than silently wrap.
    let p16 = FormatSpec::Posit { n: 16, es: 2 }.build();
    let q = Quantizer::new(p16.as_ref());
    let _ = Emac::new(p16.as_ref(), &q, 784);
}

#[test]
fn wide_quantizer_is_still_correct_nearest() {
    let p16 = FormatSpec::Posit { n: 16, es: 1 }.build();
    let q = Quantizer::new(p16.as_ref());
    let mut rng = deep_positron::util::Rng::new(5);
    for _ in 0..2000 {
        let x = rng.range(-100.0, 100.0);
        let (_, v) = q.quantize_f64(x);
        let err = (x - v).abs();
        // Binary-search the two neighbors and verify nearest.
        let idx = q.values().partition_point(|&u| u < v);
        for j in idx.saturating_sub(1)..(idx + 2).min(q.len()) {
            assert!((x - q.values()[j]).abs() >= err - 1e-18);
        }
    }
}
