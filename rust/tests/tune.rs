//! Mixed-precision tuner integration tests (DESIGN.md §10):
//!
//! * **Uniform parity** — a `MixedSpec` with every layer set to the same
//!   format is bit-identical to the uniform `DeepPositron` path, scalar
//!   and batched, for every `FormatSpec::sweep(5..=8)` format on iris and
//!   wdbc, under all three datapath modes.
//! * **Pareto/tuner invariants** — the extracted frontier contains no
//!   dominated point, the greedy/beam descent is deterministic, and the
//!   tuned assignment meets the uniform 8-bit posit accuracy within one
//!   point at strictly lower modeled network EDP.
//! * **Serve integration** — a shard started from a `TunePlan` compiles
//!   the mixed plan, routes under the assignment's joined name, serves
//!   the same predictions the compiled plan computes, and carries the
//!   plan's pruning provenance through the text codec.
//! * **Pruning/parallelism invariants (DESIGN.md §13)** — the
//!   sensitivity-pruned plan stays inside the unpruned search's feasible
//!   set under randomized accuracy budgets, every assigned format sits at
//!   or above its layer's sensitivity floor, and the tuner's output is
//!   bit-identical at fan-out widths 1, 2, and 8.

use deep_positron::accel::{Datapath, DeepPositron};
use deep_positron::coordinator::experiments::train_model;
use deep_positron::datasets::{self, Dataset, Scale};
use deep_positron::formats::{FormatSpec, MixedSpec};
use deep_positron::serve::{ServeEngine, ServeError, ShardKey};
use deep_positron::tune::{self, Budget, TuneConfig, TuneReport};

const MODES: [Datapath; 3] = [Datapath::Emac, Datapath::NarrowQuire(32), Datapath::InexactMac];

fn assert_uniform_parity(ds: &Dataset, samples: usize) {
    let mlp = train_model(ds, 9);
    let nlayers = mlp.layers.len();
    let rows: Vec<&[f64]> = (0..samples).map(|i| ds.test_row(i)).collect();
    for n in 5..=8u32 {
        for spec in FormatSpec::sweep(n) {
            let uniform = DeepPositron::compile(&mlp, spec);
            let mixed = DeepPositron::compile_mixed(&mlp, MixedSpec::uniform(spec, nlayers));
            for mode in MODES {
                let a = uniform.forward_batch(&rows, mode);
                let b = mixed.forward_batch(&rows, mode);
                assert_eq!(a, b, "{spec} {mode:?} {}: batched mixed != uniform", ds.name);
                // Scalar wrappers agree too (batch-of-one case).
                assert_eq!(
                    uniform.forward_codes_with(rows[0], mode),
                    mixed.forward_codes_with(rows[0], mode),
                    "{spec} {mode:?} {}: scalar mixed != uniform",
                    ds.name
                );
            }
        }
    }
}

#[test]
fn uniform_mixedspec_is_bit_identical_on_iris() {
    let ds = datasets::load("iris", 9, Scale::Small);
    assert_uniform_parity(&ds, 4);
}

#[test]
fn uniform_mixedspec_is_bit_identical_on_wdbc() {
    let ds = datasets::load("wdbc", 9, Scale::Small);
    assert_uniform_parity(&ds, 3);
}

/// One tuned run under the acceptance budget (accuracy within 1 pt of the
/// best uniform 8-bit posit, EDP minimized).
fn tuned(ds: &Dataset, eval_rows: usize) -> (TuneReport, deep_positron::accel::Mlp) {
    let mlp = train_model(ds, 7);
    let budget = tune::default_budget(ds, &mlp, eval_rows);
    let cfg = TuneConfig::new(budget).with_beam(2).with_eval_rows(eval_rows);
    (tune::tune(ds, &mlp, &cfg), mlp)
}

fn assert_acceptance(report: &TuneReport, task: &str) {
    let plan = &report.plan;
    let reference = &report.reference;
    assert!(plan.feasible, "{task}: tuner could not satisfy its own default budget");
    assert!(
        plan.accuracy >= reference.accuracy - 0.01 - 1e-12,
        "{task}: tuned {} < uniform posit8 {} - 1pt",
        plan.accuracy,
        reference.accuracy
    );
    assert!(
        plan.cost.edp_pj_ns < reference.cost.edp_pj_ns,
        "{task}: tuned EDP {} not strictly below uniform posit8 {}",
        plan.cost.edp_pj_ns,
        reference.cost.edp_pj_ns
    );
    // Frontier invariants: non-empty, ascending EDP, strictly increasing
    // accuracy, and no point dominated by any other frontier point.
    assert!(!report.frontier.is_empty());
    for w in report.frontier.windows(2) {
        assert!(w[0].cost.edp_pj_ns < w[1].cost.edp_pj_ns, "{task}: frontier not ascending in EDP");
        assert!(w[0].accuracy < w[1].accuracy, "{task}: frontier not ascending in accuracy");
    }
    for a in &report.frontier {
        for b in &report.frontier {
            assert!(!a.dominates(b), "{task}: frontier point {} dominates {}", a.mixed.name(), b.mixed.name());
        }
    }
}

#[test]
fn tuned_plan_beats_uniform_posit8_on_iris() {
    let ds = datasets::load("iris", 7, Scale::Small);
    let (report, _) = tuned(&ds, usize::MAX);
    assert_acceptance(&report, "iris");
}

#[test]
fn tuned_plan_beats_uniform_posit8_on_wdbc() {
    let ds = datasets::load("wdbc", 7, Scale::Small);
    // 96 validation rows keep the debug-mode search affordable; the 1-pt
    // budget is still sub-sample-strict (1/96 > 1pt).
    let (report, _) = tuned(&ds, 96);
    assert_acceptance(&report, "wdbc");
}

#[test]
fn tuner_is_deterministic() {
    let ds = datasets::load("iris", 7, Scale::Small);
    let (a, _) = tuned(&ds, usize::MAX);
    let (b, _) = tuned(&ds, usize::MAX);
    assert_eq!(a.plan.assignment, b.plan.assignment, "descent must be deterministic");
    assert_eq!(a.plan.to_text(), b.plan.to_text());
    assert_eq!(a.evaluated, b.evaluated);
    assert_eq!(a.rounds, b.rounds);
    let names = |r: &TuneReport| r.frontier.iter().map(|p| p.mixed.name()).collect::<Vec<_>>();
    assert_eq!(names(&a), names(&b), "frontier extraction must be deterministic");
}

#[test]
fn infeasible_budget_reports_closest_point() {
    let ds = datasets::load("iris", 7, Scale::Small);
    let mlp = train_model(&ds, 7);
    // Nothing reaches 200% accuracy: the tuner must say so, not pretend.
    let cfg = TuneConfig::new(Budget::MinAcc(2.0)).with_beam(1);
    let report = tune::tune(&ds, &mlp, &cfg);
    assert!(!report.plan.feasible);
    // The closest point to an unattainable accuracy floor is the most
    // accurate assignment seen.
    assert!(report.plan.accuracy >= report.reference.accuracy - 1e-12);
}

#[test]
fn serve_shard_starts_from_tune_plan() {
    let ds = datasets::load("iris", 7, Scale::Small);
    let (report, mlp) = tuned(&ds, usize::MAX);
    let plan = &report.plan;
    // The default config prunes, so the deployed plan carries provenance —
    // and it survives the text codec a shard would be started from.
    let provenance = plan.pruned.as_deref().expect("default tune config prunes");
    assert!(provenance.starts_with("sensitivity drop<="), "odd provenance line: {provenance}");
    let parsed = tune::TunePlan::parse(&plan.to_text()).expect("plan text round-trips");
    assert_eq!(parsed.pruned, plan.pruned, "pruning provenance lost in the plan codec");
    assert_eq!(parsed.assignment, plan.assignment);
    let engine = ServeEngine::start(vec![plan.shard_config(&ds, mlp.clone()).with_workers(2)]).unwrap();
    // The routing key carries the assignment's joined name.
    let key = ShardKey::for_mixed("iris", &plan.assignment);
    assert_eq!(engine.shard_keys(), vec![key.clone()]);
    // Served predictions match the compiled mixed plan exactly.
    let dp = DeepPositron::compile_mixed(&mlp, plan.assignment.clone());
    let n = ds.test_len().min(32);
    let rxs: Vec<_> = (0..n).map(|i| engine.submit(&key, ds.test_row(i).to_vec()).expect("admitted")).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx.recv().expect("reply");
        assert_eq!(reply.class, dp.predict(ds.test_row(i)), "sample {i}");
    }
    let metrics = engine.shutdown();
    assert_eq!(metrics.total_served(), n);
}

#[test]
fn mismatched_mixed_assignment_is_rejected_at_start() {
    let ds = datasets::load("iris", 7, Scale::Small);
    let mlp = train_model(&ds, 7);
    let spec = FormatSpec::parse("posit8es1").unwrap();
    // iris nets have 3 layers; a 2-layer assignment must be a BadShard.
    let bad = deep_positron::serve::ShardConfig::new(&ds, mlp, spec).with_mixed(MixedSpec::uniform(spec, 2));
    match ServeEngine::start(vec![bad]) {
        Err(ServeError::BadShard { shard, reason }) => {
            assert_eq!(shard, "iris/posit8es1+posit8es1");
            assert!(reason.contains("2 formats"), "{reason}");
        }
        Err(other) => panic!("expected BadShard, got {other}"),
        Ok(_) => panic!("expected BadShard, engine started"),
    }
}

/// Satellite (PR 5): `MixedSpec` machine names are a faithful codec —
/// `parse(name()) == self` for EVERY per-layer assignment drawn from the
/// tuner's full `FormatSpec::sweep(5..=8)` candidate pool, at every layer
/// count the repo ships (including the 4-node conv IR, whose weightless
/// pool/flatten slots carry formats too — they are recode points).
#[test]
fn prop_mixedspec_names_round_trip() {
    use deep_positron::util::prop::forall;
    std::env::set_var("PROP_CASES", std::env::var("PROP_CASES").unwrap_or_else(|_| "64".into()));
    let candidates: Vec<FormatSpec> = (5..=8u32).flat_map(FormatSpec::sweep).collect();
    assert!(candidates.len() > 30, "sweep pool unexpectedly small");
    // Deterministic part: every candidate as a uniform assignment at the
    // conv net's IR length (one format per node, weightless slots included).
    let conv_layers = deep_positron::coordinator::experiments::conv_model(7).layers.len();
    assert_eq!(conv_layers, 4, "conv IR is conv+pool+flatten+dense");
    for &spec in &candidates {
        let m = MixedSpec::uniform(spec, conv_layers);
        assert_eq!(MixedSpec::parse(&m.name()), Some(m.clone()), "uniform {} did not round-trip", m.name());
    }
    // Randomized part: arbitrary assignments of arbitrary length.
    forall("MixedSpec::parse(name()) == self", |rng| {
        let len = 1 + rng.below(6);
        let layers: Vec<FormatSpec> = (0..len).map(|_| candidates[rng.below(candidates.len())]).collect();
        let m = MixedSpec::new(layers);
        let name = m.name();
        assert_eq!(MixedSpec::parse(&name), Some(m), "{name} did not round-trip");
        // The name is the serve routing key: exactly one format per '+'.
        assert_eq!(name.split('+').count(), len);
    });
}

/// Satellite (PR 7): sensitivity pruning is conservative. Under randomized
/// accuracy budgets that at least one uniform satisfies, the pruned plan
/// stays inside the unpruned search's feasible set — it satisfies the same
/// budget, with every layer's format drawn from the full sweep pool — and
/// every assigned format sits at or above its layer's sensitivity floor.
/// Each search is a full tuner run, so the case count stays small; the
/// seeds are fixed and the tuner is deterministic.
#[test]
fn prop_pruned_plan_stays_inside_the_unpruned_feasible_set() {
    use deep_positron::util::rng::Rng;
    let ds = datasets::load("iris", 7, Scale::Small);
    let mlp = train_model(&ds, 7);
    let candidates: Vec<FormatSpec> = (5..=8u32).flat_map(FormatSpec::sweep).collect();
    // The default budget is MinAcc(best uniform posit8 − 1pt) at this
    // fidelity; drawing floors at or below it keeps a feasible uniform in
    // phase 1, so both searches must land on a feasible plan.
    let Budget::MinAcc(default_floor) = tune::default_budget(&ds, &mlp, 96) else {
        panic!("default budget is an accuracy floor")
    };
    let best8 = default_floor + 0.01;
    let mut rng = Rng::new(0x7007);
    for case in 0..3 {
        let budget = Budget::MinAcc(best8 - rng.range(0.01, 0.25));
        let base = TuneConfig::new(budget).with_beam(1).with_eval_rows(96);
        let unpruned = tune::tune(&ds, &mlp, &base.clone().with_prune(None));
        let pruned = tune::tune(&ds, &mlp, &base.with_prune(Some(0.05)));
        assert!(unpruned.plan.feasible, "case {case}: unpruned search lost a satisfiable budget");
        assert!(pruned.plan.feasible, "case {case}: pruning lost a budget the unpruned search satisfies");
        // Inside the unpruned feasible set: the same budget holds (never
        // worse than the budget on accuracy) over full-pool formats.
        assert!(
            budget.feasible(pruned.plan.accuracy, &pruned.plan.cost),
            "case {case}: pruned plan does not satisfy its own budget"
        );
        for f in pruned.plan.assignment.layers() {
            assert!(candidates.contains(f), "case {case}: pruned plan uses {} from outside the sweep pool", f.name());
        }
        // The plan respects the floors its own sensitivity table set.
        let table = pruned.sensitivity.as_ref().expect("pruned run carries its sensitivity table");
        assert!(unpruned.sensitivity.is_none(), "unpruned run must skip the pre-pass");
        for (f, layer) in pruned.plan.assignment.layers().iter().zip(&table.layers) {
            assert!(
                f.n() >= layer.floor,
                "case {case}: layer {} assigned {} below its {}b floor",
                layer.layer,
                f.name(),
                layer.floor
            );
        }
    }
}

/// Satellite (PR 7): fan-out width never changes the answer. Scoring is
/// pure and the evaluator merges results in submission order with
/// name-keyed dedup, so the whole report — plan text, rendered sensitivity
/// table, frontier, eval counts — is bit-identical at widths 1, 2, and 8.
/// (`DEEP_POSITRON_POOL` is read once per process through a `OnceLock`, so
/// an in-process test cannot vary the env var; `TuneConfig::with_threads`
/// pins the exact pool width the env var would.)
#[test]
fn tuner_output_is_bit_identical_at_any_pool_width() {
    let ds = datasets::load("iris", 7, Scale::Small);
    let mlp = train_model(&ds, 7);
    let budget = tune::default_budget(&ds, &mlp, 96);
    let run = |threads: usize| {
        let cfg = TuneConfig::new(budget).with_beam(2).with_eval_rows(96).with_threads(threads);
        tune::tune(&ds, &mlp, &cfg)
    };
    let serial = run(1);
    for threads in [2usize, 8] {
        let wide = run(threads);
        assert_eq!(wide.plan.to_text(), serial.plan.to_text(), "plan differs at width {threads}");
        assert_eq!(wide.render(), serial.render(), "report differs at width {threads}");
        assert_eq!(wide.evaluated, serial.evaluated, "eval count differs at width {threads}");
        assert_eq!(wide.rounds, serial.rounds, "round count differs at width {threads}");
        let names = |r: &TuneReport| r.frontier.iter().map(|p| p.mixed.name()).collect::<Vec<_>>();
        assert_eq!(names(&wide), names(&serial), "frontier differs at width {threads}");
    }
}

/// Satellite (PR 8): `TunePlan::parse` is total over garbage. Plan files
/// are untrusted deployment artifacts — hand-edited, truncated by broken
/// copies, or outright wrong — and the parser's contract is a typed
/// `Option`, never a panic. The property mutates a valid plan text through
/// a stack of adversarial edits (field corruption, truncation, line
/// shuffles, unsupported format names, absurd widths, raw byte noise) and
/// asserts the parser always returns; when it does accept, the plan it
/// returns must satisfy its own invariants.
#[test]
fn plan_parser_never_panics_on_mutated_text() {
    use deep_positron::tune::TunePlan;
    use deep_positron::util::prop::forall;

    let base = "dataset=iris\ndims=4,8,3\nir=4:dense8+dense3\nlayers=posit8es1+posit6es1+posit8es1\n\
                accuracy=0.933333\nfeasible=true\npruned=sensitivity drop<=1.0% floors=6,5,6 screen_rows=96\n";
    assert!(TunePlan::parse(base).is_some(), "the seed text must itself be valid");
    let glyphs: &[&str] = &["=", ",", "x", "+", ":", "0", "9", "-", "e", "NaN", "inf", "\u{221e}", "\0", "dense"];
    forall("TunePlan::parse is panic-free", |rng| {
        let mut text = base.to_string();
        for _ in 0..=rng.below(4) {
            match rng.below(8) {
                // Truncate anywhere (mid-line, mid-number, mid-name).
                0 => {
                    let mut at = rng.below(text.len() + 1);
                    while !text.is_char_boundary(at) {
                        at -= 1;
                    }
                    text.truncate(at);
                }
                // Drop one whole line (loses a required key, or the ir= line
                // — the legacy dense path must also hold).
                1 => {
                    let keep = rng.below(7);
                    text = text
                        .lines()
                        .enumerate()
                        .filter(|(i, _)| *i != keep)
                        .map(|(_, l)| format!("{l}\n"))
                        .collect();
                }
                // Replace one line's value with an adversarial scalar.
                2 => {
                    let victim = rng.below(7);
                    let junk =
                        ["", "0", "-1", "NaN", "1e308", "99999999999999999999", "true", "posit64es9", "0,0", "2.5"];
                    let junk = junk[rng.below(junk.len())];
                    text = text
                        .lines()
                        .enumerate()
                        .map(|(i, l)| {
                            if i == victim {
                                let key = l.split('=').next().unwrap_or(l);
                                format!("{key}={junk}\n")
                            } else {
                                format!("{l}\n")
                            }
                        })
                        .collect();
                }
                // Splice a random glyph at a random byte-safe position.
                3 => {
                    let mut at = rng.below(text.len() + 1);
                    while !text.is_char_boundary(at) {
                        at -= 1;
                    }
                    text.insert_str(at, glyphs[rng.below(glyphs.len())]);
                }
                // Duplicate a line (duplicate keys must not confuse it).
                4 => {
                    let dup = text.lines().nth(rng.below(7)).unwrap_or("").to_string();
                    text.push_str(&dup);
                    text.push('\n');
                }
                // Blow up a dimension to the overflow-probing range.
                5 => text = text.replace("dims=4,8,3", "dims=4,18446744073709551615,3"),
                // An unsupported-but-parseable format name: must be None,
                // not a constructor assert.
                6 => text = text.replace("posit6es1", "posit64es1"),
                // Pure binary noise.
                _ => {
                    text = (0..rng.below(64)).map(|_| (rng.below(256) as u8) as char).collect();
                }
            }
        }
        // The parser must return (no panic — forall catches and reports),
        // and an accepted plan must be internally consistent.
        if let Some(plan) = TunePlan::parse(&text) {
            assert!(plan.dims.len() >= 2);
            assert_eq!(plan.ir.dims(), plan.dims);
            assert_eq!(plan.assignment.len(), plan.ir.len());
            assert!((0.0..=1.0).contains(&plan.accuracy));
            assert!(plan.assignment.layers().iter().all(|s| s.is_supported()));
        }
    });
}
