//! Property tests over the format/EMAC invariants (util::prop's seeded
//! forall in lieu of the unavailable proptest crate — DESIGN.md
//! §Substitutions).

use std::cmp::Ordering;

use deep_positron::formats::pack::{crc32, PackedCodes};
use deep_positron::formats::{Emac, Exact, Format, FormatSpec, Quantizer};
use deep_positron::util::prop::{arb_f64, forall};
use deep_positron::util::Rng;

fn arb_spec(rng: &mut Rng) -> FormatSpec {
    let n = 5 + rng.below(4) as u32; // 5..=8
    match rng.below(3) {
        0 => FormatSpec::Posit { n, es: rng.below(3) as u32 },
        1 => FormatSpec::Float { n, we: 2 + rng.below((n - 3) as usize).min(3) as u32 },
        _ => FormatSpec::Fixed { n, q: 1 + rng.below((n - 2) as usize) as u32 },
    }
}

#[test]
fn prop_encode_decode_identity_on_codes() {
    forall("encode(decode(c)) == c", |rng| {
        let spec = arb_spec(rng);
        let fmt = spec.build();
        let q = Quantizer::new(fmt.as_ref());
        let code = q.codes()[rng.below(q.len())];
        let v = q.decode(code).unwrap();
        let (c2, _) = q.quantize_exact(&v);
        assert_eq!(c2, code, "{spec}: code {code:#x} decodes to {v:?} but re-encodes to {c2:#x}");
    });
}

#[test]
fn prop_quantize_returns_nearest_value() {
    forall("quantize is nearest", |rng| {
        let spec = arb_spec(rng);
        let fmt = spec.build();
        let q = Quantizer::new(fmt.as_ref());
        let x = arb_f64(rng);
        let (_, v) = q.quantize_f64(x);
        let err = (x - v).abs();
        // Posit-only exception: tiny nonzero x clamps to ±minpos even though
        // 0 is closer (the no-underflow rule) — exclude 0 from the check.
        let skip_zero = !fmt.underflows_to_zero() && x != 0.0;
        if skip_zero {
            assert_ne!(v, 0.0, "{spec}: posit rounded nonzero {x} to zero");
        }
        // No other representable value may be strictly closer (ties allowed).
        for &u in q.values() {
            if skip_zero && u == 0.0 {
                continue;
            }
            assert!(
                (x - u).abs() >= err * (1.0 - 1e-15),
                "{spec}: quantize({x}) = {v} but {u} is closer"
            );
        }
    });
}

#[test]
fn prop_decode_monotone_in_value_order() {
    forall("table strictly increasing", |rng| {
        let spec = arb_spec(rng);
        let q = Quantizer::new(spec.build().as_ref());
        let i = rng.below(q.len() - 1);
        assert!(q.values()[i] < q.values()[i + 1], "{spec}: table not strictly increasing at {i}");
    });
}

#[test]
fn prop_exact_and_f64_quantize_agree() {
    forall("quantize_exact == quantize_f64", |rng| {
        let spec = arb_spec(rng);
        let q = Quantizer::new(spec.build().as_ref());
        let x = arb_f64(rng);
        assert_eq!(q.quantize_f64(x), q.quantize_exact(&Exact::from_f64(x)), "{spec} at {x}");
    });
}

#[test]
fn prop_emac_matches_exact_reference() {
    forall("EMAC == exact rational dot", |rng| {
        let spec = arb_spec(rng);
        let fmt = spec.build();
        let q = Quantizer::new(fmt.as_ref());
        let k = 1 + rng.below(48);
        let mut emac = Emac::new(fmt.as_ref(), &q, 64);
        let mut exact_sum = Exact::ZERO;
        for _ in 0..k {
            let w = q.codes()[rng.below(q.len())];
            let a = q.codes()[rng.below(q.len())];
            emac.mac(w, a);
            exact_sum = exact_sum.add(q.decode(w).unwrap().mul(q.decode(a).unwrap()));
        }
        // The quire must hold the exact rational sum.
        assert_eq!(
            emac.quire_value().canonical().cmp_exact(&exact_sum.canonical()),
            Ordering::Equal,
            "{spec}: quire diverged from exact sum"
        );
        // And the terminal round must be the correctly-rounded result.
        let code = emac.result(false);
        let (expect, _) = q.quantize_exact(&exact_sum);
        assert_eq!(code, expect, "{spec}: terminal rounding wrong");
    });
}

#[test]
fn prop_emac_relu_equals_post_round_clamp() {
    forall("relu(round(x)) == round-then-clamp", |rng| {
        let spec = arb_spec(rng);
        let fmt = spec.build();
        let q = Quantizer::new(fmt.as_ref());
        let mut emac = Emac::new(fmt.as_ref(), &q, 16);
        let mut emac2 = Emac::new(fmt.as_ref(), &q, 16);
        let k = 1 + rng.below(8);
        for _ in 0..k {
            let w = q.codes()[rng.below(q.len())];
            let a = q.codes()[rng.below(q.len())];
            emac.mac(w, a);
            emac2.mac(w, a);
        }
        let with_relu = emac.result(true);
        let without = emac2.result(false);
        let v = q.decode(without).unwrap().to_f64();
        let rv = q.decode(with_relu).unwrap().to_f64();
        assert_eq!(rv, v.max(0.0), "{spec}");
    });
}

#[test]
fn prop_quantization_error_bounded_by_neighbor_gap() {
    forall("|x - q(x)| ≤ gap/2 within range", |rng| {
        let spec = arb_spec(rng);
        let fmt = spec.build();
        let q = Quantizer::new(fmt.as_ref());
        // In-range x only (outside the range saturation error is unbounded).
        let x = rng.range(-fmt.max_value(), fmt.max_value());
        let (_, v) = q.quantize_f64(x);
        let idx = q.values().partition_point(|&u| u < v);
        let gap_lo = if idx > 0 { q.values()[idx] - q.values()[idx - 1] } else { f64::INFINITY };
        let gap_hi = if idx + 1 < q.len() { q.values()[idx + 1] - q.values()[idx] } else { f64::INFINITY };
        let bound = gap_lo.max(gap_hi) / 2.0 + 1e-15;
        // Posit minpos clamp can exceed the local gap at zero — skip there.
        if fmt.underflows_to_zero() || v != 0.0 && x.abs() >= fmt.min_pos() {
            assert!((x - v).abs() <= bound, "{spec}: |{x} - {v}| > {bound}");
        }
    });
}

#[test]
fn prop_packed_codes_round_trip_every_sweep_format() {
    forall("pack -> unpack identity over sweep(5..=8)", |rng| {
        let n = 5 + rng.below(4) as u32;
        for &spec in &FormatSpec::sweep(n) {
            let q = Quantizer::shared(spec);
            let len = rng.below(65); // includes the zero-length stream
            let codes: Vec<u16> = (0..len).map(|_| q.codes()[rng.below(q.len())]).collect();
            let p = PackedCodes::pack(&codes, spec.n());
            assert_eq!(p.unpack(), codes, "{spec}: lossy pack");
            assert_eq!(
                p.bytes().len(),
                (codes.len() * spec.n() as usize).div_ceil(8),
                "{spec}: wrong packed size"
            );
            // The artifact-reader path rebuilds losslessly from stored parts.
            let r = PackedCodes::from_parts(p.width(), p.len(), p.bytes().to_vec(), p.crc())
                .unwrap_or_else(|e| panic!("{spec}: from_parts rejected its own emitter: {e}"));
            assert_eq!(r.unpack(), codes, "{spec}: from_parts round trip");
        }
    });
}

#[test]
fn prop_packed_codes_reject_any_bit_flip() {
    forall("one flipped bit never parses", |rng| {
        let spec = arb_spec(rng);
        let q = Quantizer::shared(spec);
        let len = 1 + rng.below(64);
        let codes: Vec<u16> = (0..len).map(|_| q.codes()[rng.below(q.len())]).collect();
        let p = PackedCodes::pack(&codes, spec.n());
        let mut bytes = p.bytes().to_vec();
        bytes[rng.below(bytes.len())] ^= 1u8 << rng.below(8);
        // A data-bit flip fails the CRC; a padding-bit flip fails the
        // all-ones padding check. Either way the reader must refuse.
        assert!(
            PackedCodes::from_parts(p.width(), p.len(), bytes, p.crc()).is_err(),
            "{spec}: a corrupted stream parsed"
        );
    });
}

#[test]
fn packed_codes_byte_boundary_and_padding_edges() {
    // Widths 5 and 7 are coprime with 8: every field position relative to
    // the byte grid occurs, so these streams cross byte boundaries in all
    // the ways an 8-bit-wide stream never would.
    for width in [5u32, 7] {
        let max = (1u16 << width) - 1;
        let codes: Vec<u16> = (0..17u16).map(|i| (i * 11) & max).collect();
        let p = PackedCodes::pack(&codes, width);
        assert_eq!(p.unpack(), codes, "width {width}");
        let r = PackedCodes::from_parts(width, codes.len(), p.bytes().to_vec(), p.crc()).unwrap();
        assert_eq!(r.unpack(), codes, "width {width} via from_parts");
    }
    // Zero-length stream: zero bytes, CRC of the empty buffer.
    let p = PackedCodes::pack(&[], 5);
    assert!(p.is_empty() && p.bytes().is_empty());
    assert_eq!(PackedCodes::from_parts(5, 0, Vec::new(), p.crc()).unwrap().unpack(), Vec::<u16>::new());
    // Padding is all-ONES by contract: a zeroed pad bit must be rejected by
    // the padding check itself (the CRC below is recomputed to match).
    let codes = [0b10110u16, 0b00001, 0b11111]; // 15 bits -> 2 bytes + 1 pad bit
    let p = PackedCodes::pack(&codes, 5);
    let mut bytes = p.bytes().to_vec();
    *bytes.last_mut().unwrap() &= !1;
    let crc = crc32(&bytes);
    let err = PackedCodes::from_parts(5, codes.len(), bytes, crc).unwrap_err();
    assert!(err.contains("padding"), "expected a padding rejection, got: {err}");
}

#[test]
fn prop_sweep_family_nonempty_and_distinct() {
    forall("sweeps well-formed", |rng| {
        let n = 5 + rng.below(4) as u32;
        let sweep = FormatSpec::sweep(n);
        let names: std::collections::HashSet<String> = sweep.iter().map(|s| s.name()).collect();
        assert_eq!(names.len(), sweep.len(), "duplicate specs in sweep({n})");
        assert!(sweep.iter().all(|s| s.n() == n));
    });
}
