//! Conv layer-IR integration tests (DESIGN.md §11):
//!
//! * **f64-reference-quantized oracle** — the conv EMAC's decoded outputs
//!   equal an independent, in-test f64 forward pass over dequantized
//!   weights with per-layer table rounding, bit for bit, on formats whose
//!   quire fits f64's exact window.
//! * **Scalar-primitive oracle** — `forward_batch` on a conv net is
//!   bit-identical to driving the public `Emac`/`ScalarAlu` primitives one
//!   sample, one output element at a time, across formats × all three
//!   datapaths (EMAC, narrow quire, inexact MAC).
//! * **Exhaustive sweep parity** — the same oracle over EVERY
//!   `FormatSpec::sweep(5..=8)` format × all three datapaths on a tiny 8×8
//!   conv net at an odd batch size, so the §12 tiled conv kernels are pinned
//!   across the whole format space, not just the 8-bit flagships.
//! * **Uniform-mixed parity** — a uniform `MixedSpec` conv plan equals the
//!   uniform compile path exactly (the §10 invariant, now on conv).
//! * **Tune → serve pipeline** — `tune::tune` on the conv MNIST net
//!   produces a mixed-precision `TunePlan` that serializes (with its `ir=`
//!   topology line), parses back, and starts a serving shard whose replies
//!   match the compiled mixed plan.
//! * **IR validation at serve start** — a shape-inconsistent conv model is
//!   rejected as a typed `BadShard`, not a worker panic.

use deep_positron::accel::{Datapath, DeepPositron, Layer, LayerKind, Mlp, Shape};
use deep_positron::coordinator::experiments::{conv_model, train_conv_model};
use deep_positron::datasets::{self, Dataset, Scale};
use deep_positron::formats::ops::ScalarAlu;
use deep_positron::formats::{Emac, Exact, FormatSpec, MixedSpec, Quantizer};
use deep_positron::serve::{ServeEngine, ServeError, ShardConfig, ShardKey};
use deep_positron::tune::{self, Budget, TuneConfig, TunePlan};
use deep_positron::util::Rng;

fn mnist() -> Dataset {
    datasets::load("mnist", 9, Scale::Small)
}

/// Independent f64 reference: dequantized weights, exact f64 accumulation,
/// one table-round per layer output into the layer's (uniform) format.
/// Reimplements the dataflow from the public `Layer` geometry — it shares
/// no kernel code with the accelerator.
fn f64_oracle(mlp: &Mlp, q: &Quantizer, weights: &[Vec<f64>], biases: &[Vec<f64>], x: &[f64]) -> Vec<f64> {
    let mut act: Vec<f64> = x.iter().map(|&v| q.quantize_f64(v).1).collect();
    let nl = mlp.layers.len();
    for (li, layer) in mlp.layers.iter().enumerate() {
        let relu = layer.kind.has_weights() && li + 1 < nl;
        let mut next = vec![0.0; layer.out_dim];
        match layer.kind {
            LayerKind::Dense => {
                for o in 0..layer.out_dim {
                    let mut acc = biases[li][o];
                    for i in 0..layer.in_dim {
                        acc += weights[li][o * layer.in_dim + i] * act[i];
                    }
                    let r = q.quantize_f64(acc).1;
                    next[o] = if relu { r.max(0.0) } else { r };
                }
            }
            LayerKind::Conv2d { kh, kw, stride, in_ch, out_ch } => {
                let (ih, iw, oh, ow) = conv_dims(layer.in_dim, layer.out_dim, in_ch, out_ch);
                for oc in 0..out_ch {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let mut acc = biases[li][oc];
                            for ic in 0..in_ch {
                                for ky in 0..kh {
                                    for kx in 0..kw {
                                        let i = ic * ih * iw + (oy * stride + ky) * iw + (ox * stride + kx);
                                        let wi = oc * in_ch * kh * kw + ic * kh * kw + ky * kw + kx;
                                        acc += weights[li][wi] * act[i];
                                    }
                                }
                            }
                            let r = q.quantize_f64(acc).1;
                            next[oc * oh * ow + oy * ow + ox] = if relu { r.max(0.0) } else { r };
                        }
                    }
                }
            }
            LayerKind::AvgPool { k, stride } => {
                let c = channels(layer);
                let ih = side(layer.in_dim / c);
                let iw = ih;
                let oh = side(layer.out_dim / c);
                let ow = oh;
                for ch in 0..c {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let mut acc = 0.0;
                            for ky in 0..k {
                                for kx in 0..k {
                                    acc += act[ch * ih * iw + (oy * stride + ky) * iw + (ox * stride + kx)];
                                }
                            }
                            next[ch * oh * ow + oy * ow + ox] = q.quantize_f64(acc / (k * k) as f64).1;
                        }
                    }
                }
            }
            LayerKind::Flatten => next.copy_from_slice(&act[..layer.in_dim]),
        }
        act = next;
    }
    act
}

/// Square side length (the conv test nets use square blocks).
fn side(n: usize) -> usize {
    let s = (n as f64).sqrt().round() as usize;
    assert_eq!(s * s, n, "non-square block in test net");
    s
}

fn channels(layer: &deep_positron::accel::Layer) -> usize {
    layer.in_shape.channels()
}

fn conv_dims(in_dim: usize, out_dim: usize, in_ch: usize, out_ch: usize) -> (usize, usize, usize, usize) {
    let ih = side(in_dim / in_ch);
    let oh = side(out_dim / out_ch);
    (ih, ih, oh, oh)
}

#[test]
fn conv_emac_matches_independent_f64_quantized_oracle() {
    // Exact-EMAC conv output vs the f64-reference-quantized oracle, bit for
    // bit, on formats whose quire fits f64's exact window at these value
    // ranges (the DESIGN.md §2 exactness argument).
    let ds = mnist();
    let mlp = conv_model(9);
    for spec in ["posit8es1", "float8we4", "fixed8q4"] {
        let dp = DeepPositron::compile(&mlp, FormatSpec::parse(spec).unwrap());
        let weights = dp.dequantized_weights();
        let biases = dp.dequantized_biases();
        for i in 0..6 {
            let x = ds.test_row(i);
            let codes = dp.forward_codes(x);
            let vals: Vec<f64> = codes.iter().map(|&c| dp.quantizer().decode(c).unwrap().to_f64()).collect();
            let oracle = f64_oracle(&mlp, dp.quantizer(), &weights, &biases, x);
            assert_eq!(vals, oracle, "{spec} sample {i}");
        }
    }
}

/// The scalar-primitive oracle: one sample, one output element at a time,
/// through the public `Emac` (EMAC / narrow-quire) or `ScalarAlu` (inexact
/// MAC) — the per-element loop the conv accelerator batches.
fn scalar_conv_oracle(
    mlp: &Mlp,
    q: &Quantizer,
    w_codes: &[Vec<u16>],
    b_exact: &[Vec<Exact>],
    x: &[f64],
    mode: Datapath,
) -> Vec<u16> {
    let fmt = FormatSpec::parse(q.name()).unwrap().build();
    let max_k = mlp.layers.iter().map(|l| l.eq2_k()).max().unwrap().max(2);
    let mut emac = Emac::new(fmt.as_ref(), q, max_k);
    if let Datapath::NarrowQuire(bits) = mode {
        emac.set_width_limit(bits);
    }
    let alu = ScalarAlu::new(q);
    let zero = q.zero_code();
    let (mut act, _) = q.quantize_slice(x);
    let nl = mlp.layers.len();
    for (li, layer) in mlp.layers.iter().enumerate() {
        let relu = layer.kind.has_weights() && li + 1 < nl;
        let mut next = vec![0u16; layer.out_dim];
        match layer.kind {
            LayerKind::Dense => {
                for o in 0..layer.out_dim {
                    let row = &w_codes[li][o * layer.in_dim..(o + 1) * layer.in_dim];
                    next[o] = match mode {
                        Datapath::Emac | Datapath::NarrowQuire(_) => emac.dot(row, &act, Some(b_exact[li][o]), relu),
                        Datapath::InexactMac => {
                            let mut acc = alu.inexact_dot(row, &act);
                            acc = alu.add(acc, q.quantize_exact(&b_exact[li][o]).0);
                            let v = q.decode(acc).unwrap();
                            if relu && v.sign {
                                zero
                            } else {
                                acc
                            }
                        }
                    };
                }
            }
            LayerKind::Conv2d { kh, kw, stride, in_ch, out_ch } => {
                let (ih, iw, oh, ow) = conv_dims(layer.in_dim, layer.out_dim, in_ch, out_ch);
                for oc in 0..out_ch {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            // Gather the receptive field, then run it as one
                            // scalar dot product.
                            let mut wrow = Vec::with_capacity(kh * kw * in_ch);
                            let mut arow = Vec::with_capacity(kh * kw * in_ch);
                            for ic in 0..in_ch {
                                for ky in 0..kh {
                                    for kx in 0..kw {
                                        wrow.push(w_codes[li][oc * in_ch * kh * kw + ic * kh * kw + ky * kw + kx]);
                                        arow.push(act[ic * ih * iw + (oy * stride + ky) * iw + (ox * stride + kx)]);
                                    }
                                }
                            }
                            let o = oc * oh * ow + oy * ow + ox;
                            next[o] = match mode {
                                Datapath::Emac | Datapath::NarrowQuire(_) => {
                                    emac.dot(&wrow, &arow, Some(b_exact[li][oc]), relu)
                                }
                                Datapath::InexactMac => {
                                    let mut acc = alu.inexact_dot(&wrow, &arow);
                                    acc = alu.add(acc, q.quantize_exact(&b_exact[li][oc]).0);
                                    let v = q.decode(acc).unwrap();
                                    if relu && v.sign {
                                        zero
                                    } else {
                                        acc
                                    }
                                }
                            };
                        }
                    }
                }
            }
            LayerKind::AvgPool { k, stride } => {
                let c = channels(layer);
                let ih = side(layer.in_dim / c);
                let oh = side(layer.out_dim / c);
                let down = ((k * k).trailing_zeros()) as i32;
                let (recip, _) = q.quantize_f64(1.0 / (k * k) as f64);
                for ch in 0..c {
                    for oy in 0..oh {
                        for ox in 0..oh {
                            let o = ch * oh * oh + oy * oh + ox;
                            match mode {
                                Datapath::Emac | Datapath::NarrowQuire(_) => {
                                    for ky in 0..k {
                                        for kx in 0..k {
                                            let code = act[ch * ih * ih + (oy * stride + ky) * ih + (ox * stride + kx)];
                                            emac.accumulate_exact(q.decode(code).unwrap());
                                        }
                                    }
                                    let v = emac.quire_value();
                                    // Exact divide by k² = exponent shift.
                                    let avg =
                                        if v.is_zero() { v } else { Exact::new(v.sign, v.mag, v.exp - down) };
                                    next[o] = q.quantize_exact(&avg).0;
                                    // Clear the quire for the next element
                                    // (result() also resets the MAC audit).
                                    let _ = emac.result(false);
                                }
                                Datapath::InexactMac => {
                                    let mut acc = zero;
                                    for ky in 0..k {
                                        for kx in 0..k {
                                            let code = act[ch * ih * ih + (oy * stride + ky) * ih + (ox * stride + kx)];
                                            acc = alu.add(acc, code);
                                        }
                                    }
                                    let acc = alu.mul(acc, recip);
                                    let v = q.decode(acc).unwrap();
                                    next[o] = q.quantize_exact(&v).0;
                                }
                            }
                        }
                    }
                }
            }
            LayerKind::Flatten => next.copy_from_slice(&act[..layer.in_dim]),
        }
        act = next;
    }
    act
}

/// Recover the compiled model's quantized parameters through the public
/// accessors (quantize-of-representable is the identity).
fn quantized_params(dp: &DeepPositron) -> (Vec<Vec<u16>>, Vec<Vec<Exact>>) {
    let q = dp.quantizer();
    let weights = dp.dequantized_weights().iter().map(|w| q.quantize_slice(w).0).collect();
    let biases = dp
        .dequantized_biases()
        .iter()
        .map(|bs| bs.iter().map(|&b| q.decode(q.quantize_f64(b).0).unwrap_or(Exact::ZERO)).collect())
        .collect();
    (weights, biases)
}

#[test]
fn conv_batch_is_bit_identical_to_the_scalar_primitive_oracle() {
    let ds = mnist();
    let mlp = conv_model(9);
    for spec_name in ["posit8es1", "float8we4", "fixed8q5"] {
        let spec = FormatSpec::parse(spec_name).unwrap();
        let dp = DeepPositron::compile(&mlp, spec);
        let (w_codes, b_exact) = quantized_params(&dp);
        let rows: Vec<&[f64]> = (0..3).map(|i| ds.test_row(i)).collect();
        for mode in [Datapath::Emac, Datapath::NarrowQuire(40), Datapath::InexactMac] {
            let batched = dp.forward_batch(&rows, mode);
            for (i, row) in rows.iter().enumerate() {
                let expect = scalar_conv_oracle(&mlp, dp.quantizer(), &w_codes, &b_exact, row, mode);
                assert_eq!(batched[i], expect, "{spec_name} {mode:?} sample {i} (batched)");
                if i == 0 {
                    assert_eq!(
                        dp.forward_codes_with(row, mode),
                        expect,
                        "{spec_name} {mode:?} sample {i} (scalar wrapper)"
                    );
                }
            }
        }
    }
}

/// A tiny untrained 8×8 conv net (conv2k3x3s1 + pool2s2 + flatten + dense3)
/// cheap enough to sweep exhaustively: its bit behaviour is what the parity
/// argument is about, and random He-initialized weights exercise the full
/// code space better than a trained net's clustered values.
fn tiny_conv_net(seed: u64) -> Mlp {
    let mut rng = Rng::new(seed);
    let conv = Layer::conv2d(Shape::Chw { c: 1, h: 8, w: 8 }, 2, 3, 3, 1, &mut rng);
    let pool = Layer::avg_pool(conv.out_shape, 2, 2);
    let flat = Layer::flatten(pool.out_shape);
    let dense = Layer::dense(flat.out_dim, 3, &mut rng);
    Mlp::from_layers(vec![conv, pool, flat, dense])
}

#[test]
fn exhaustive_sweep_conv_parity_against_the_scalar_oracle() {
    // The §12 satellite: EVERY swept format (5..=8 bits, all three
    // families) × all three datapaths, tiled conv kernels vs the
    // scalar-primitive oracle, at an odd batch size (5) that doesn't divide
    // the tile geometry.
    let mlp = tiny_conv_net(0xC0DE);
    let mut rng = Rng::new(11);
    let inputs: Vec<Vec<f64>> = (0..5).map(|_| (0..64).map(|_| rng.normal(0.3, 0.4)).collect()).collect();
    let rows: Vec<&[f64]> = inputs.iter().map(Vec::as_slice).collect();
    for n in 5..=8u32 {
        for spec in FormatSpec::sweep(n) {
            let dp = DeepPositron::compile(&mlp, spec);
            let (w_codes, b_exact) = quantized_params(&dp);
            for mode in [Datapath::Emac, Datapath::NarrowQuire(32), Datapath::InexactMac] {
                let batched = dp.forward_batch(&rows, mode);
                for (i, row) in rows.iter().enumerate() {
                    let expect = scalar_conv_oracle(&mlp, dp.quantizer(), &w_codes, &b_exact, row, mode);
                    assert_eq!(batched[i], expect, "{spec} {mode:?} sample {i} (tiled conv)");
                }
            }
        }
    }
}

#[test]
fn uniform_mixedspec_is_bit_identical_on_the_conv_net() {
    let ds = mnist();
    let mlp = conv_model(9);
    let nlayers = mlp.layers.len();
    let rows: Vec<&[f64]> = (0..3).map(|i| ds.test_row(i)).collect();
    for spec_name in ["posit8es1", "float7we3", "fixed8q5"] {
        let spec = FormatSpec::parse(spec_name).unwrap();
        let uniform = DeepPositron::compile(&mlp, spec);
        let mixed = DeepPositron::compile_mixed(&mlp, MixedSpec::uniform(spec, nlayers));
        for mode in [Datapath::Emac, Datapath::NarrowQuire(40), Datapath::InexactMac] {
            assert_eq!(
                uniform.forward_batch(&rows, mode),
                mixed.forward_batch(&rows, mode),
                "{spec_name} {mode:?}: uniform mixed conv plan diverged"
            );
        }
    }
}

#[test]
fn tune_produces_and_serve_loads_a_mixed_conv_plan() {
    // The acceptance pipeline: tune the conv MNIST net under a trivially
    // feasible accuracy floor (the descent then minimizes network EDP),
    // round-trip the plan text (with its ir= topology), and serve from it.
    let ds = mnist();
    let mlp = train_conv_model(&ds, 7, 2);
    let cfg = TuneConfig::new(Budget::MinAcc(0.0)).with_beam(1).with_bits(8..=8).with_eval_rows(8);
    let report = tune::tune(&ds, &mlp, &cfg);
    let plan = &report.plan;
    assert!(plan.feasible);
    assert_eq!(plan.ir, mlp.ir());
    assert_eq!(plan.assignment.len(), mlp.layers.len());
    assert!(!plan.ir.is_dense());

    // Serialized plan carries the conv topology and parses back with the
    // identical recomputed cost.
    let text = plan.to_text();
    assert!(text.contains("ir=1x28x28:conv4k5x5s2+pool2s2+flatten+dense10"), "{text}");
    let parsed = TunePlan::parse(&text).expect("conv plan parses");
    assert_eq!(parsed.assignment, plan.assignment);
    assert_eq!(parsed.ir, plan.ir);
    assert_eq!(parsed.cost, plan.cost);

    // Serve from the parsed plan: the shard compiles the mixed conv plan
    // (Sim-native) and replies match the compiled plan's predictions.
    let engine = ServeEngine::start(vec![parsed.shard_config(&ds, mlp.clone()).with_workers(2)]).unwrap();
    let key = ShardKey::for_mixed("mnist", &plan.assignment);
    assert_eq!(engine.shard_keys(), vec![key.clone()]);
    let dp = DeepPositron::compile_mixed(&mlp, plan.assignment.clone());
    let n = 8;
    let rxs: Vec<_> = (0..n).map(|i| engine.submit(&key, ds.test_row(i).to_vec()).expect("admitted")).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx.recv().expect("reply");
        assert_eq!(reply.class, dp.predict(ds.test_row(i)), "sample {i}");
    }
    let metrics = engine.shutdown();
    assert_eq!(metrics.total_served(), n);
}

#[test]
fn shape_inconsistent_conv_model_is_a_typed_bad_shard() {
    let ds = mnist();
    let mut mlp = conv_model(3);
    // Corrupt the chain after construction: the serve-side IR validation
    // must reject it as BadShard instead of letting a worker panic.
    mlp.layers[1].out_dim += 1;
    let spec = FormatSpec::parse("posit8es1").unwrap();
    match ServeEngine::start(vec![ShardConfig::new(&ds, mlp, spec)]) {
        Err(ServeError::BadShard { reason, .. }) => {
            assert!(reason.contains("layer IR rejected"), "{reason}");
        }
        Err(other) => panic!("expected BadShard, got {other}"),
        Ok(_) => panic!("expected BadShard, engine started"),
    }
}

#[test]
fn conv_eq2_k_is_the_receptive_field() {
    let mlp = conv_model(1);
    let ks: Vec<usize> = mlp.layers.iter().map(|l| l.eq2_k()).collect();
    // conv 5·5·1+1, pool 2², flatten 0, dense 144+1 — never the 784 input.
    assert_eq!(ks, vec![26, 4, 0, 145]);
    assert_eq!(mlp.max_fan_in(), 144);
}
