//! Batched-vs-scalar EMAC parity: `DeepPositron::forward_batch` must be
//! bit-identical to per-sample execution for EVERY swept format
//! (`FormatSpec::sweep(5..=8)`) under all three `Datapath` ablation modes,
//! on real trained networks (iris and wdbc — the latter's raw-scale inputs
//! exercise the widest quire dynamics and the narrow-quire wrap).
//!
//! The reference is an *independent* scalar oracle driving the public
//! `Emac`/`ScalarAlu` primitives one sample at a time — the exact loop the
//! accelerator ran before the compiled-plan refactor — so a systematic bug
//! in the batched kernel cannot hide behind a shared implementation.
//!
//! The §12 tiled kernels add edge geometry worth pinning down explicitly:
//! batch sizes that don't divide `LANE_BLOCK`, batches that cross a full
//! lane block, worker pools wider than the batch, and all-NaR output rows
//! through `decoded_argmax`.

use deep_positron::accel::positron::{LANE_BLOCK, ROW_TILE};
use deep_positron::accel::{Datapath, DeepPositron, Mlp};
use deep_positron::coordinator::experiments::train_model;
use deep_positron::datasets::{self, Dataset, Scale};
use deep_positron::formats::ops::ScalarAlu;
use deep_positron::formats::{Emac, Exact, FormatSpec, Quantizer};
use deep_positron::util::pool::WorkerPool;

/// The pre-refactor per-sample datapath, reconstructed from the public
/// format primitives: quantize the input, run one `Emac` (or per-step
/// `ScalarAlu` chain) per neuron, layer by layer.
fn scalar_oracle(
    q: &Quantizer,
    spec: FormatSpec,
    dims: &[usize],
    weights: &[Vec<u16>],
    biases: &[Vec<Exact>],
    x: &[f64],
    mode: Datapath,
) -> Vec<u16> {
    let fmt = spec.build();
    let (mut act, _) = q.quantize_slice(x);
    let max_k = *dims.iter().max().unwrap();
    let mut emac = Emac::new(fmt.as_ref(), q, max_k + 1);
    if let Datapath::NarrowQuire(bits) = mode {
        emac.set_width_limit(bits);
    }
    let alu = ScalarAlu::new(q);
    let zero = q.quantize_f64(0.0).0;
    let last = weights.len() - 1;
    for (li, (w, b)) in weights.iter().zip(biases).enumerate() {
        let in_dim = dims[li];
        let out_dim = dims[li + 1];
        let relu = li < last;
        let mut next = Vec::with_capacity(out_dim);
        for o in 0..out_dim {
            let row = &w[o * in_dim..(o + 1) * in_dim];
            let code = match mode {
                Datapath::Emac | Datapath::NarrowQuire(_) => emac.dot(row, &act, Some(b[o]), relu),
                Datapath::InexactMac => {
                    let mut acc = alu.inexact_dot(row, &act);
                    let (bcode, _) = q.quantize_exact(&b[o]);
                    acc = alu.add(acc, bcode);
                    let v = q.decode(acc).unwrap();
                    if relu && v.sign {
                        zero
                    } else {
                        acc
                    }
                }
            };
            next.push(code);
        }
        act = next;
    }
    act
}

/// Recover the compiled model's quantized parameters through the public
/// accessors (quantize-of-representable is the identity, so these are the
/// exact codes/exacts the plan was built from).
fn quantized_params(dp: &DeepPositron) -> (Vec<Vec<u16>>, Vec<Vec<Exact>>) {
    let q = dp.quantizer();
    let weights = dp.dequantized_weights().iter().map(|w| q.quantize_slice(w).0).collect();
    let biases = dp
        .dequantized_biases()
        .iter()
        .map(|bs| bs.iter().map(|&b| q.decode(q.quantize_f64(b).0).unwrap_or(Exact::ZERO)).collect())
        .collect();
    (weights, biases)
}

fn assert_parity(ds: &Dataset, mlp: &Mlp, samples: usize) {
    let dims = mlp.dims();
    for n in 5..=8u32 {
        for spec in FormatSpec::sweep(n) {
            let dp = DeepPositron::compile(mlp, spec);
            let (weights, biases) = quantized_params(&dp);
            let rows: Vec<&[f64]> = (0..samples).map(|i| ds.test_row(i % ds.test_len())).collect();
            for mode in [Datapath::Emac, Datapath::NarrowQuire(32), Datapath::InexactMac] {
                let batched = dp.forward_batch(&rows, mode);
                assert_eq!(batched.len(), rows.len());
                for (i, row) in rows.iter().enumerate() {
                    let expect = scalar_oracle(dp.quantizer(), spec, &dims, &weights, &biases, row, mode);
                    assert_eq!(batched[i], expect, "{spec} {mode:?} {} sample {i} (batched)", ds.name);
                    if i == 0 {
                        // The scalar entry point is the B=1 case of the same
                        // kernel; one sample per (spec, mode) covers it.
                        let scalar = dp.forward_codes_with(row, mode);
                        assert_eq!(scalar, expect, "{spec} {mode:?} {} sample {i} (scalar wrapper)", ds.name);
                    }
                }
            }
        }
    }
}

#[test]
fn batched_path_is_bit_identical_on_iris() {
    let ds = datasets::load("iris", 9, Scale::Small);
    let mlp = train_model(&ds, 9);
    assert_parity(&ds, &mlp, 6);
}

#[test]
fn batched_path_is_bit_identical_on_wdbc() {
    let ds = datasets::load("wdbc", 9, Scale::Small);
    let mlp = train_model(&ds, 9);
    // wdbc's net is ~4× the MAC count of iris; 4 samples keep the debug-mode
    // inexact-MAC oracle affordable while still exercising batch > 1.
    assert_parity(&ds, &mlp, 4);
}

#[test]
fn empty_and_singleton_batches() {
    let ds = datasets::load("iris", 9, Scale::Small);
    let mlp = train_model(&ds, 9);
    let dp = DeepPositron::compile(&mlp, FormatSpec::parse("posit8es1").unwrap());
    assert!(dp.forward_batch(&[], Datapath::Emac).is_empty());
    let row = ds.test_row(0);
    assert_eq!(dp.forward_batch(&[row], Datapath::Emac), vec![dp.forward_codes(row)]);
    // The flat entry points must also survive B = 0: clear a stale buffer
    // and return without touching a kernel.
    let mut flat = vec![0xFFFFu16; 5];
    dp.forward_batch_into(&[], Datapath::Emac, &mut flat);
    assert!(flat.is_empty());
    dp.forward_batch_into_with(&[], Datapath::Emac, &WorkerPool::new(4), &mut flat);
    assert!(flat.is_empty());
    assert!(dp.predict_batch(&[]).is_empty());
}

/// Batch sizes that don't divide the tile geometry — odd remainders below
/// `ROW_TILE`/`LANE_BLOCK` and sizes that cross a full lane block — must be
/// bit-identical to the per-sample wrapper under every datapath. The tile
/// loops carry `min()`-clamped edge lanes; this is the test that keeps
/// those clamps honest.
#[test]
fn odd_and_lane_crossing_batch_sizes_match_per_sample() {
    assert_eq!(LANE_BLOCK, 32, "update the lane-crossing sizes below if the tile geometry changes");
    let ds = datasets::load("iris", 9, Scale::Small);
    let mlp = train_model(&ds, 9);
    let dp = DeepPositron::compile(&mlp, FormatSpec::parse("posit8es1").unwrap());
    let mut flat = Vec::new();
    // ROW_TILE−1 and 7: partial first lane block; 33 and 37: one full block
    // plus an odd tail (both exceed the iris test split, exercising repeats).
    for b in [ROW_TILE - 1, 7, LANE_BLOCK + 1, LANE_BLOCK + 5] {
        let rows: Vec<&[f64]> = (0..b).map(|i| ds.test_row(i % ds.test_len())).collect();
        for mode in [Datapath::Emac, Datapath::NarrowQuire(32), Datapath::InexactMac] {
            let nested = dp.forward_batch(&rows, mode);
            for (i, row) in rows.iter().enumerate() {
                assert_eq!(nested[i], dp.forward_codes_with(row, mode), "B={b} {mode:?} sample {i}");
            }
            dp.forward_batch_into(&rows, mode, &mut flat);
            assert_eq!(flat.len(), b * dp.out_dim());
            for (i, chunk) in flat.chunks(dp.out_dim()).enumerate() {
                assert_eq!(chunk, &nested[i][..], "B={b} {mode:?} sample {i} (flat layout)");
            }
        }
    }
}

/// A worker pool wider than the batch: every thread gets at most one row
/// (most get none), and the result must still be bit-identical to the
/// sequential kernel — chunked fan-out must never change a sample's own
/// accumulation order.
#[test]
fn pool_wider_than_the_batch_is_bit_identical() {
    let ds = datasets::load("iris", 9, Scale::Small);
    let mlp = train_model(&ds, 9);
    let dp = DeepPositron::compile(&mlp, FormatSpec::parse("posit8es1").unwrap());
    let pool = WorkerPool::new(8);
    let mut flat = Vec::new();
    for b in [1usize, 3, LANE_BLOCK + 5] {
        let rows: Vec<&[f64]> = (0..b).map(|i| ds.test_row(i % ds.test_len())).collect();
        for mode in [Datapath::Emac, Datapath::NarrowQuire(32), Datapath::InexactMac] {
            let nested = dp.forward_batch(&rows, mode);
            dp.forward_batch_into_with(&rows, mode, &pool, &mut flat);
            assert_eq!(flat.len(), b * dp.out_dim());
            for (i, chunk) in flat.chunks(dp.out_dim()).enumerate() {
                assert_eq!(chunk, &nested[i][..], "B={b} {mode:?} sample {i} (pool of 8)");
            }
        }
    }
}

/// `decoded_argmax` on all-NaR rows: an output row where no code decodes to
/// a real value must come back `None`, never class 0 — and a single real
/// value among NaRs must win regardless of position.
#[test]
fn all_nar_rows_through_decoded_argmax() {
    let ds = datasets::load("iris", 9, Scale::Small);
    let mlp = train_model(&ds, 9);
    let dp = DeepPositron::compile(&mlp, FormatSpec::parse("posit8es1").unwrap());
    let q = dp.quantizer();
    // Hunt for a non-canonical code through the public decoder (posit NaR
    // plus any gap codes) instead of hard-coding a format's bit pattern.
    let nar = (0u16..1 << 8).find(|&c| q.decode(c).is_none()).expect("an 8-bit format has a non-canonical code");
    let out_dim = dp.out_dim();
    assert_eq!(dp.decoded_argmax(&vec![nar; out_dim]), None, "an all-NaR row must not decode to a class");
    // One decodable code among NaRs wins at every position.
    let real = q.quantize_f64(1.0).0;
    for slot in 0..out_dim {
        let mut row = vec![nar; out_dim];
        row[slot] = real;
        assert_eq!(dp.decoded_argmax(&row), Some(slot), "the lone real value must win at slot {slot}");
    }
    // The datapaths themselves never emit NaR: every produced code decodes.
    let mut flat = Vec::new();
    let rows: Vec<&[f64]> = (0..5).map(|i| ds.test_row(i)).collect();
    dp.forward_batch_into(&rows, Datapath::Emac, &mut flat);
    assert!(flat.iter().all(|&c| q.decode(c).is_some()), "EMAC output rows must be canonical codes");
}
