//! Batched-vs-scalar EMAC parity: `DeepPositron::forward_batch` must be
//! bit-identical to per-sample execution for EVERY swept format
//! (`FormatSpec::sweep(5..=8)`) under all three `Datapath` ablation modes,
//! on real trained networks (iris and wdbc — the latter's raw-scale inputs
//! exercise the widest quire dynamics and the narrow-quire wrap).
//!
//! The reference is an *independent* scalar oracle driving the public
//! `Emac`/`ScalarAlu` primitives one sample at a time — the exact loop the
//! accelerator ran before the compiled-plan refactor — so a systematic bug
//! in the batched kernel cannot hide behind a shared implementation.

use deep_positron::accel::{Datapath, DeepPositron, Mlp};
use deep_positron::coordinator::experiments::train_model;
use deep_positron::datasets::{self, Dataset, Scale};
use deep_positron::formats::ops::ScalarAlu;
use deep_positron::formats::{Emac, Exact, FormatSpec, Quantizer};

/// The pre-refactor per-sample datapath, reconstructed from the public
/// format primitives: quantize the input, run one `Emac` (or per-step
/// `ScalarAlu` chain) per neuron, layer by layer.
fn scalar_oracle(
    q: &Quantizer,
    spec: FormatSpec,
    dims: &[usize],
    weights: &[Vec<u16>],
    biases: &[Vec<Exact>],
    x: &[f64],
    mode: Datapath,
) -> Vec<u16> {
    let fmt = spec.build();
    let (mut act, _) = q.quantize_slice(x);
    let max_k = *dims.iter().max().unwrap();
    let mut emac = Emac::new(fmt.as_ref(), q, max_k + 1);
    if let Datapath::NarrowQuire(bits) = mode {
        emac.set_width_limit(bits);
    }
    let alu = ScalarAlu::new(q);
    let zero = q.quantize_f64(0.0).0;
    let last = weights.len() - 1;
    for (li, (w, b)) in weights.iter().zip(biases).enumerate() {
        let in_dim = dims[li];
        let out_dim = dims[li + 1];
        let relu = li < last;
        let mut next = Vec::with_capacity(out_dim);
        for o in 0..out_dim {
            let row = &w[o * in_dim..(o + 1) * in_dim];
            let code = match mode {
                Datapath::Emac | Datapath::NarrowQuire(_) => emac.dot(row, &act, Some(b[o]), relu),
                Datapath::InexactMac => {
                    let mut acc = alu.inexact_dot(row, &act);
                    let (bcode, _) = q.quantize_exact(&b[o]);
                    acc = alu.add(acc, bcode);
                    let v = q.decode(acc).unwrap();
                    if relu && v.sign {
                        zero
                    } else {
                        acc
                    }
                }
            };
            next.push(code);
        }
        act = next;
    }
    act
}

/// Recover the compiled model's quantized parameters through the public
/// accessors (quantize-of-representable is the identity, so these are the
/// exact codes/exacts the plan was built from).
fn quantized_params(dp: &DeepPositron) -> (Vec<Vec<u16>>, Vec<Vec<Exact>>) {
    let q = dp.quantizer();
    let weights = dp.dequantized_weights().iter().map(|w| q.quantize_slice(w).0).collect();
    let biases = dp
        .dequantized_biases()
        .iter()
        .map(|bs| bs.iter().map(|&b| q.decode(q.quantize_f64(b).0).unwrap_or(Exact::ZERO)).collect())
        .collect();
    (weights, biases)
}

fn assert_parity(ds: &Dataset, mlp: &Mlp, samples: usize) {
    let dims = mlp.dims();
    for n in 5..=8u32 {
        for spec in FormatSpec::sweep(n) {
            let dp = DeepPositron::compile(mlp, spec);
            let (weights, biases) = quantized_params(&dp);
            let rows: Vec<&[f64]> = (0..samples).map(|i| ds.test_row(i % ds.test_len())).collect();
            for mode in [Datapath::Emac, Datapath::NarrowQuire(32), Datapath::InexactMac] {
                let batched = dp.forward_batch(&rows, mode);
                assert_eq!(batched.len(), rows.len());
                for (i, row) in rows.iter().enumerate() {
                    let expect = scalar_oracle(dp.quantizer(), spec, &dims, &weights, &biases, row, mode);
                    assert_eq!(batched[i], expect, "{spec} {mode:?} {} sample {i} (batched)", ds.name);
                    if i == 0 {
                        // The scalar entry point is the B=1 case of the same
                        // kernel; one sample per (spec, mode) covers it.
                        let scalar = dp.forward_codes_with(row, mode);
                        assert_eq!(scalar, expect, "{spec} {mode:?} {} sample {i} (scalar wrapper)", ds.name);
                    }
                }
            }
        }
    }
}

#[test]
fn batched_path_is_bit_identical_on_iris() {
    let ds = datasets::load("iris", 9, Scale::Small);
    let mlp = train_model(&ds, 9);
    assert_parity(&ds, &mlp, 6);
}

#[test]
fn batched_path_is_bit_identical_on_wdbc() {
    let ds = datasets::load("wdbc", 9, Scale::Small);
    let mlp = train_model(&ds, 9);
    // wdbc's net is ~4× the MAC count of iris; 4 samples keep the debug-mode
    // inexact-MAC oracle affordable while still exercising batch > 1.
    assert_parity(&ds, &mlp, 4);
}

#[test]
fn empty_and_singleton_batches() {
    let ds = datasets::load("iris", 9, Scale::Small);
    let mlp = train_model(&ds, 9);
    let dp = DeepPositron::compile(&mlp, FormatSpec::parse("posit8es1").unwrap());
    assert!(dp.forward_batch(&[], Datapath::Emac).is_empty());
    let row = ds.test_row(0);
    assert_eq!(dp.forward_batch(&[row], Datapath::Emac), vec![dp.forward_codes(row)]);
}
