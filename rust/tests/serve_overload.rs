//! Saturation tests for the serving engine's overload behaviour (ISSUE 3):
//! bounded admission (flooding a shard sheds with a typed error instead of
//! growing memory or deadlocking), exact shed/expired/served accounting,
//! deadline expiry without compute, shutdown answering every accepted
//! request, and least-loaded two-choice routing around a jammed worker.

use std::time::Duration;

use deep_positron::accel::Mlp;
use deep_positron::coordinator::experiments::train_model;
use deep_positron::datasets::{self, Dataset, Scale};
use deep_positron::formats::FormatSpec;
use deep_positron::serve::{ServeEngine, ServeError, ShardConfig, ShardKey, WorkerConfig};

fn iris() -> (Dataset, Mlp) {
    let ds = datasets::load("iris", 3, Scale::Small);
    let mlp = train_model(&ds, 3);
    (ds, mlp)
}

/// A shard whose worker coalesces for `wait` with an effectively unbounded
/// batch cap, so queued requests sit (and count against `max_queue`) until
/// the anchored window expires — overload behaviour becomes deterministic.
fn slow_shard(ds: &Dataset, mlp: Mlp, workers: usize, max_queue: usize, wait: Duration) -> ShardConfig {
    let mut shard = ShardConfig::new(ds, mlp, FormatSpec::parse("posit8es1").unwrap()).with_workers(workers);
    shard.worker = WorkerConfig { max_batch_wait: wait, sim_batch: 4096, max_queue };
    shard
}

#[test]
fn flood_sheds_with_typed_error_and_shutdown_answers_every_accepted_request() {
    let (ds, mlp) = iris();
    let max_queue = 8;
    let total = 40;
    let shard = slow_shard(&ds, mlp, 1, max_queue, Duration::from_secs(2));
    let engine = ServeEngine::start(vec![shard]).unwrap();
    let key = ShardKey::new("iris", FormatSpec::parse("posit8es1").unwrap());

    let mut accepted = Vec::new();
    let mut shed = 0usize;
    for i in 0..total {
        match engine.submit(&key, ds.test_row(i % ds.test_len()).to_vec()) {
            Ok(rx) => accepted.push(rx),
            Err(ServeError::Overloaded { shard, depth }) => {
                assert_eq!(depth, max_queue, "shed must report the saturated depth");
                assert_eq!(shard, "iris/posit8es1");
                shed += 1;
            }
            Err(e) => panic!("flood must shed, not fail with {e}"),
        }
    }
    // The queue is bounded — exactly max_queue admitted, the flood shed,
    // nothing queued beyond the bound (no unbounded memory, no deadlock).
    assert_eq!(accepted.len(), max_queue, "exactly max_queue submissions fit");
    assert_eq!(shed, total - max_queue);
    let live = engine.shard_metrics(&key).expect("shard exists");
    assert!(live.queue_depths.iter().all(|&d| d <= max_queue), "depth leak: {:?}", live.queue_depths);
    assert_eq!(live.shed, shed);

    // Shutdown before consuming a single reply: every accepted request must
    // still be answered.
    let metrics = engine.shutdown();
    let m = &metrics.shards[0];
    assert_eq!(m.served, max_queue);
    assert_eq!(m.shed, shed);
    assert_eq!(m.expired, 0);
    assert_eq!(m.submissions(), total, "served + shed + expired must account for every submission");
    assert_eq!(m.queue_depths, vec![0], "shutdown drains the queue");
    for rx in accepted {
        rx.recv().expect("accepted request must be answered before shutdown completes");
    }
}

#[test]
fn queue_slots_free_after_flush_and_serving_recovers() {
    let (ds, mlp) = iris();
    let max_queue = 4;
    let shard = slow_shard(&ds, mlp, 1, max_queue, Duration::from_millis(100));
    let engine = ServeEngine::start(vec![shard]).unwrap();
    let key = ShardKey::new("iris", FormatSpec::parse("posit8es1").unwrap());

    let mut accepted = Vec::new();
    let mut shed = 0usize;
    for i in 0..12 {
        match engine.submit(&key, ds.test_row(i % ds.test_len()).to_vec()) {
            Ok(rx) => accepted.push(rx),
            Err(ServeError::Overloaded { .. }) => shed += 1,
            Err(e) => panic!("unexpected {e}"),
        }
    }
    assert!(shed > 0, "12 instant submissions over a 4-deep queue must shed");
    // The anchored window flushes the accepted batch without any shutdown;
    // replies arrive and the queue drains…
    for rx in &accepted {
        rx.recv().expect("bounded queue must still serve accepted requests");
    }
    // …so the engine accepts traffic again after overload passes.
    let rx = engine.submit(&key, ds.test_row(0).to_vec()).expect("queue slot must be free after flush");
    rx.recv().expect("post-overload request is served");
    let metrics = engine.shutdown();
    let m = &metrics.shards[0];
    assert_eq!(m.served, accepted.len() + 1);
    assert_eq!(m.shed, shed);
    assert_eq!(m.submissions(), 13);
}

#[test]
fn expired_deadline_requests_get_no_compute() {
    let (ds, mlp) = iris();
    let shard = slow_shard(&ds, mlp, 1, 1024, Duration::from_millis(200));
    let engine = ServeEngine::start(vec![shard]).unwrap();
    let key = ShardKey::new("iris", FormatSpec::parse("posit8es1").unwrap());

    // Interleave hopeless requests (zero latency budget: expired by any
    // flush) with normal ones.
    let mut doomed = Vec::new();
    let mut healthy = Vec::new();
    for i in 0..10 {
        let x = ds.test_row(i % ds.test_len()).to_vec();
        if i % 2 == 0 {
            doomed.push(engine.submit_with_deadline(&key, x, Duration::ZERO).unwrap());
        } else {
            healthy.push(engine.submit(&key, x).unwrap());
        }
    }
    for rx in healthy {
        rx.recv().expect("no-deadline requests must be served normally");
    }
    for rx in doomed {
        rx.recv().expect_err("expired request must be dropped, not answered");
    }
    let metrics = engine.shutdown();
    let m = &metrics.shards[0];
    assert_eq!(m.served, 5);
    assert_eq!(m.expired, 5);
    assert_eq!(m.shed, 0);
    assert_eq!(m.submissions(), 10);
    // "No compute" is visible in the latency histogram: only served rows
    // were ever executed and timed.
    assert_eq!(m.latency.count(), 5, "expired rows must never reach an executed batch");
    assert!(m.batches >= 1 && m.max_batch <= 5, "served rows arrive in at most 5-row batches");
}

#[test]
fn flood_run_dumps_traces_whose_phases_telescope_to_the_end_to_end_latency() {
    let (ds, mlp) = iris();
    let shard = slow_shard(&ds, mlp, 1, 1024, Duration::from_millis(50));
    let engine = ServeEngine::start(vec![shard]).unwrap();
    let key = ShardKey::new("iris", FormatSpec::parse("posit8es1").unwrap());
    let dump = std::env::temp_dir().join(format!("overload_{}.trace.jsonl", std::process::id()));
    // Threshold 1: the first shed-or-expired request triggers the spike dump.
    engine.arm_trace_dump(&dump, 1);

    let total = 64;
    let rxs: Vec<_> =
        (0..total).map(|i| engine.submit(&key, ds.test_row(i % ds.test_len()).to_vec()).unwrap()).collect();
    let mut latency_ns = std::collections::HashMap::new();
    for rx in rxs {
        let reply = rx.recv().expect("flood request answered");
        let prev = latency_ns.insert(reply.trace, reply.latency_s * 1e9);
        assert!(prev.is_none(), "trace ids must be unique per request");
    }
    // One hopeless request expires at the next flush — the drop spike that
    // fires the armed flight-recorder dump.
    let doomed = engine.submit_with_deadline(&key, ds.test_row(0).to_vec(), Duration::ZERO).unwrap();
    doomed.recv().expect_err("zero-budget request must expire");

    let snapshot = engine.observe();
    let metrics = engine.shutdown();

    // The dump is strict JSONL; parse_dump enforces the schema and the
    // telescoping invariant (queue + compute + reply == total) per event.
    let text = std::fs::read_to_string(&dump).expect("expired spike must have dumped the flight recorder");
    let events = deep_positron::obs::recorder::parse_dump(&text).expect("dump must satisfy the strict codec");
    std::fs::remove_file(&dump).ok();
    assert_eq!(events.len(), total, "every served request leaves one trace event");
    for ev in &events {
        assert_eq!(ev.queue_ns + ev.compute_ns + ev.reply_ns, ev.total_ns);
        let client = latency_ns[&ev.trace];
        // The client clock stops just before the reply is sent; the trace's
        // reply phase extends past the send, so the trace total bounds the
        // client-observed latency from above, within a loose scheduling slack.
        assert!(
            ev.total_ns as f64 >= client,
            "trace {} total {} below client latency {client}",
            ev.trace,
            ev.total_ns
        );
        assert!(
            (ev.total_ns as f64 - client) < 250e6,
            "trace {} total {} drifts > 250ms past client latency {client}",
            ev.trace,
            ev.total_ns
        );
    }

    // Histogram fidelity on real serving traffic: p50/p99 within one
    // bucket's relative error (1/16) of the exact percentile over the very
    // latencies the clients observed (same Duration feeds both paths).
    let m = &metrics.shards[0];
    assert_eq!(m.served, total);
    assert_eq!(m.expired, 1);
    assert_eq!(m.latency.count() as usize, total);
    let exact_samples: Vec<f64> = latency_ns.values().copied().collect();
    for p in [50.0, 99.0] {
        let q = m.latency.quantile_ns(p) as f64;
        let exact = deep_positron::util::stats::percentile(&exact_samples, p);
        assert!(q <= exact * (1.0 + 1e-9), "p{p}: histogram {q} above exact {exact}");
        assert!(q >= exact * (1.0 - 1.0 / 16.0) - 1.0, "p{p}: histogram {q} under exact {exact} by over a bucket");
    }

    // The exported snapshot agrees with the shutdown metrics and passes its
    // own strict codec round-trip (the same check `repro lint` runs on
    // committed artifacts).
    let shard_obs = &snapshot.shards[0];
    assert_eq!(shard_obs.served as usize, total);
    assert_eq!(shard_obs.samples as usize, total);
    let reparsed = deep_positron::obs::ObsSnapshot::from_json(&snapshot.to_json()).expect("snapshot codec");
    assert_eq!(reparsed, snapshot);
    assert!(snapshot.to_prometheus().contains("deep_positron_served_total"));
}

#[test]
fn least_loaded_two_choice_routing_beats_blind_round_robin_on_skew() {
    let (ds, mlp) = iris();
    let shard = slow_shard(&ds, mlp, 2, 64, Duration::from_millis(700));
    let engine = ServeEngine::start(vec![shard]).unwrap();
    let key = ShardKey::new("iris", FormatSpec::parse("posit8es1").unwrap());

    // Jam one worker through affinity pinning (affinity bypasses the
    // balancer on purpose): 20 requests pile onto a single queue.
    let jam_n = 20;
    let jammed: Vec<_> = (0..jam_n)
        .map(|i| engine.submit_with_affinity(&key, 0xFEED, ds.test_row(i % ds.test_len()).to_vec()).unwrap())
        .collect();
    let depths = engine.queue_depths(&key).unwrap();
    let jam = if depths[0] >= depths[1] { 0 } else { 1 };
    let idle = 1 - jam;
    assert_eq!(depths[jam], jam_n, "affinity must pile onto one worker: {depths:?}");
    assert_eq!(depths[idle], 0);

    // Plain submissions now choose between the two candidates by live queue
    // depth: every one must dodge the jammed worker. Blind round-robin
    // would have sent half (6 of 12) into the 20-deep queue.
    let spread_n = 12;
    let routed: Vec<_> =
        (0..spread_n).map(|i| engine.submit(&key, ds.test_row(i % ds.test_len()).to_vec()).unwrap()).collect();
    let depths = engine.queue_depths(&key).unwrap();
    assert_eq!(depths[idle], spread_n, "least-loaded routing must fill the idle worker: {depths:?}");
    assert_eq!(depths[jam], jam_n, "the jammed worker must attract nothing new: {depths:?}");

    let metrics = engine.shutdown();
    for rx in routed {
        let reply = rx.recv().expect("routed request answered");
        assert_eq!(reply.worker, idle, "every balanced request must land on the idle worker");
    }
    for rx in jammed {
        rx.recv().expect("jammed requests are still answered eventually");
    }
    let m = &metrics.shards[0];
    assert_eq!(m.per_worker[jam], jam_n);
    assert_eq!(m.per_worker[idle], spread_n);
    assert_eq!(m.served, jam_n + spread_n);
}

#[test]
fn inconsistent_shard_configs_are_rejected_at_start() {
    let (ds, mlp) = iris();
    let spec = FormatSpec::parse("posit8es1").unwrap();

    let mut bad = ShardConfig::new(&ds, mlp.clone(), spec);
    bad.num_features += 1;
    match ServeEngine::start(vec![bad]).map(|_| ()) {
        Err(ServeError::BadShard { shard, reason }) => {
            assert_eq!(shard, "iris/posit8es1");
            assert!(reason.contains("num_features"), "{reason}");
        }
        other => panic!("feature-dim mismatch must be rejected, got {other:?}"),
    }

    let mut bad = ShardConfig::new(&ds, mlp.clone(), spec);
    bad.num_classes = 99;
    match ServeEngine::start(vec![bad]).map(|_| ()) {
        Err(ServeError::BadShard { reason, .. }) => assert!(reason.contains("num_classes"), "{reason}"),
        other => panic!("class-count mismatch must be rejected, got {other:?}"),
    }

    let mut bad = ShardConfig::new(&ds, mlp, spec);
    bad.worker.max_queue = 0;
    match ServeEngine::start(vec![bad]).map(|_| ()) {
        Err(ServeError::BadShard { reason, .. }) => assert!(reason.contains("max_queue"), "{reason}"),
        other => panic!("zero queue bound must be rejected, got {other:?}"),
    }
}
