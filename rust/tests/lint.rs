//! `repro lint` integration gates (DESIGN.md §14):
//!
//! * **Clean tree** — the full two-layer lint over this very repository
//!   reports zero findings. Every declared float/panic boundary in the
//!   exact zones and on the serve path is annotated with its reason, every
//!   bench is wired into Cargo.toml + CI + its committed baseline, and
//!   every committed `BENCH_*.json` passes the strict codec.
//! * **Corpus coverage** — every seeded-violation fixture under
//!   `rust/tests/lint_corpus/` is caught by exactly the rule its filename
//!   prefix declares. A lint that stops firing is itself a regression; the
//!   corpus is the lint's own test set.

use std::path::Path;

use deep_positron::lint;

fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn the_tree_is_lint_clean() {
    let findings = lint::lint_tree(repo_root()).expect("tree walk");
    assert!(
        findings.is_empty(),
        "repro lint found {} violation(s) in the committed tree:\n{}",
        findings.len(),
        findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
}

#[test]
fn every_corpus_fixture_is_caught() {
    let corpus = repo_root().join("rust/tests/lint_corpus");
    let report = lint::check_corpus(repo_root(), &corpus).expect("corpus run");
    assert!(
        report.missed.is_empty(),
        "{} fixture(s) not caught by their declared rule:\n{}",
        report.missed.len(),
        report.missed.join("\n")
    );
    // One line per fixture, and the corpus actually exercises every layer:
    // token rules, wiring rules, bench-log codec, the plan auditor, the
    // packed-artifact codec, and the obs snapshot/trace codecs.
    assert!(report.lines.len() >= 15, "corpus shrank to {} fixture(s)", report.lines.len());
    for slug in [
        "float-in-exact-zone",
        "unsafe-outside-allowlist",
        "panic-on-serve-path",
        "bad-annotation",
        "bench-unwired",
        "orphan-bench-baseline",
        "bench-log-invalid",
        "plan-invalid",
        "plan-quire-overflow",
        "plan-bad-provenance",
        "obs-snapshot-invalid",
        "obs-trace-invalid",
        "artifact-invalid",
        "artifact-quire-overflow",
    ] {
        assert!(
            report.lines.iter().any(|l| l.contains(&format!("{slug}__"))),
            "no fixture exercises [{slug}]: {:?}",
            report.lines
        );
    }
}

#[test]
fn corpus_fixtures_fail_an_injected_clean_file() {
    // A fixture with a rule prefix whose violation is NOT present must be
    // reported as missed, not silently passed — the corpus gate is only
    // meaningful if a rotted fixture trips it.
    let dir = std::env::temp_dir().join(format!("lint_corpus_negative_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("float-in-exact-zone__actually_clean.rs"),
        "// lint-corpus: zone=exact\nfn f() -> u32 { 1 }\n",
    )
    .unwrap();
    let report = lint::check_corpus(repo_root(), &dir).expect("corpus run");
    assert_eq!(report.missed.len(), 1, "{:?}", report.lines);
    assert!(report.missed[0].starts_with("MISSED"), "{:?}", report.missed);
    std::fs::remove_dir_all(&dir).ok();
}
