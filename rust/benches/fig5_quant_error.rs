//! Bench E3 — regenerates Fig. 5: layer-wise best-of-sweep quantization-MSE
//! heatmaps for the MNIST and Fashion-MNIST networks, bits 5–8, as
//! MSE_posit − MSE_fixed and MSE_posit − MSE_float.
//!
//! Paper shape: posit suffers the least quantization error, most visibly at
//! ≤5-bit precision (differences increasingly negative as bits shrink).

use deep_positron::coordinator::experiments;
use deep_positron::datasets::Scale;
use deep_positron::quant::{self, HeatCell};
use deep_positron::util::stats::BenchTimer;

fn main() {
    let ns = [5u32, 6, 7, 8];
    for dataset in ["mnist", "fashion"] {
        println!("== bench: Fig 5 — {dataset} ==\n");
        let mut timer = BenchTimer::new("fig5/train+heatmap");
        let cells = timer.sample(|| experiments::fig5(dataset, Scale::Small, 7));
        let fixed_title = format!("{dataset}: MSE_posit − MSE_fixed (negative ⇒ posit better)");
        let float_title = format!("{dataset}: MSE_posit − MSE_float (negative ⇒ posit better)");
        println!("{}", quant::render_heatmap(&cells, &ns, HeatCell::posit_minus_fixed, &fixed_title));
        println!("{}", quant::render_heatmap(&cells, &ns, HeatCell::posit_minus_float, &float_title));
        // Shape checks on the MNIST-scale network (peaked weights).
        let avg5 = cells.iter().find(|c| c.layer == "avg" && c.n == 5).unwrap();
        let avg8 = cells.iter().find(|c| c.layer == "avg" && c.n == 8).unwrap();
        println!("posit beats fixed on avg @5bit: {}", if avg5.posit_minus_fixed() < 0.0 { "OK" } else { "VIOLATED" });
        println!(
            "posit ≤ float on avg @5bit   : {}",
            if avg5.posit_minus_float() <= 1e-12 { "OK" } else { "VIOLATED" }
        );
        println!("error shrinks with bits      : {}", if avg8.mse_posit < avg5.mse_posit { "OK" } else { "VIOLATED" });
        println!("{}\n", timer.report());
    }
}
