//! Artifact cold-start bench (DESIGN.md §16, ISSUE 10 acceptance): boots
//! per second of an inference plan from the packed `.dpz` artifact versus
//! the status-quo path (dataset load + f64 training + quantizing compile),
//! on the iris task at posit8es1.
//!
//! Asserted claims:
//! * the artifact-booted plan is BIT-IDENTICAL to the freshly compiled one
//!   (`forward_codes` parity over the whole test split) — a faster boot
//!   that computes different codes proves nothing;
//! * booting from the artifact is at least 10× faster than the f64 path
//!   (in practice it is orders of magnitude: no dataset, no trainer, no
//!   f64 pass — just parse the packed code streams and build LUT plans);
//! * packing itself (`Artifact::from_network` + serialization) is cheap
//!   enough to run inline at deploy time.
//!
//! Throughput results land in the schema-versioned `BENCH_artifact.json`
//! trajectory at the repo root and are gated against the committed baseline
//! (`util::bench_log`).

use std::path::Path;

use deep_positron::accel::DeepPositron;
use deep_positron::artifact::Artifact;
use deep_positron::coordinator::experiments;
use deep_positron::datasets::{self, Scale};
use deep_positron::formats::FormatSpec;
use deep_positron::util::bench_log::{self, BenchLog};
use deep_positron::util::stats::{mean, BenchTimer};

/// The timed section, separated from artifact prep so
/// [`bench_log::record_and_gate`] can draw fresh best-of samples without
/// rebuilding the on-disk artifact.
fn measure(dp: &DeepPositron, path: &Path, budget: f64) -> BenchLog {
    let mut log = BenchLog::new("artifact");
    let probe = [0.1f64, 0.2, 0.3, 0.4];
    let mut sink = 0u32;

    // Status quo: everything `repro serve` used to do before it could take
    // --artifact — load the dataset, train the f64 net, quantize-compile.
    let mut timer = BenchTimer::new("iris/boot from f64 (load + train + compile)");
    timer.run(budget, || {
        let ds = datasets::load("iris", 7, Scale::Small);
        let mlp = experiments::train_model(&ds, 7);
        let booted = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 });
        sink = sink.wrapping_add(booted.forward_codes(&probe)[0] as u32);
    });
    let f64_boots = 1.0 / mean(timer.samples());
    println!("{}", timer.report());
    println!("  -> {f64_boots:.2} boots/s from f64  [sink {sink}]");
    log.push("iris/boots_per_s/from_f64", f64_boots).expect("finite boot rate");

    // The §16 path: read the .dpz text, parse + CRC-check it, compile the
    // packed code streams straight into an execution plan.
    let mut timer = BenchTimer::new("iris/boot from .dpz (load + parse + compile)");
    timer.run(budget, || {
        let booted = Artifact::load(path).expect("bench artifact loads").compile();
        sink = sink.wrapping_add(booted.forward_codes(&probe)[0] as u32);
    });
    let art_boots = 1.0 / mean(timer.samples());
    println!("{}", timer.report());
    println!("  -> {art_boots:.0} boots/s from the artifact (×{:.0} vs f64)  [sink {sink}]", art_boots / f64_boots);
    log.push("iris/boots_per_s/from_artifact", art_boots).expect("finite boot rate");

    // Deploy-time cost of producing the artifact from a compiled network.
    let mut timer = BenchTimer::new("iris/pack (from_network + serialize)");
    timer.run(budget, || {
        sink = sink.wrapping_add(Artifact::from_network("iris", dp).to_text().len() as u32);
    });
    let packs = 1.0 / mean(timer.samples());
    println!("{}", timer.report());
    println!("  -> {packs:.0} packs/s  [sink {sink}]");
    log.push("iris/packs_per_s", packs).expect("finite pack rate");

    assert!(
        art_boots >= 10.0 * f64_boots,
        "artifact cold start ({art_boots:.1} boots/s) must be >= 10x the f64 path ({f64_boots:.2} boots/s)"
    );
    log
}

fn main() {
    let budget = bench_log::bench_budget(0.4);
    let ds = datasets::load("iris", 7, Scale::Small);
    let mlp = experiments::train_model(&ds, 7);
    let dp = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 });
    let path = std::env::temp_dir().join("deep_positron_bench_iris.dpz");
    Artifact::from_network("iris", &dp).save(&path).expect("write bench artifact");
    let bytes = std::fs::metadata(&path).expect("artifact metadata").len();

    // Bit-identity before any timing: the artifact-booted plan must agree
    // with the fresh compile on every test row.
    let cold = Artifact::load(&path).expect("load bench artifact").compile();
    for i in 0..ds.test_len() {
        let row = ds.test_row(i);
        assert_eq!(cold.forward_codes(row), dp.forward_codes(row), "artifact-booted plan diverged at row {i}");
    }
    println!("artifact: {bytes} B on disk, bit-identical to the fresh compile across {} test rows\n", ds.test_len());

    let log = measure(&dp, &path, budget);
    println!("\nartifact boot is >= 10x faster than the f64 path and bit-identical — OK");
    bench_log::record_and_gate(log, || measure(&dp, &path, budget), bench_log::DEFAULT_TOLERANCE);
}
