//! Bench E4 — regenerates Table 1: 8-bit EMAC inference accuracy on the five
//! tasks (best sub-parameter per family) vs the high-precision baseline.
//!
//! Paper reference rows (accuracy, sub-parameter):
//!   WDBC     posit 85.9 (2) | float 77.4 (4) | fixed 57.8 (5) | base 90.1
//!   Iris     posit 98.0 (1) | float 96.0 (3) | fixed 92.0 (4) | base 98.0
//!   Mushroom posit 96.4 (1) | float 96.4 (4) | fixed 95.9 (5) | base 96.8
//!   MNIST    posit 98.5 (1) | float 98.4 (4) | fixed 98.3 (5) | base 98.5
//!   Fashion  posit 89.6 (1) | float 89.6 (4) | fixed 89.2 (4) | base 89.5
//!
//! Our absolute numbers differ (synthetic data, own training); the SHAPE to
//! check: posit ≥ float ≥ fixed at 8 bits, posit near baseline.

use deep_positron::coordinator::{experiments, report, Engine};
use deep_positron::datasets::Scale;
use deep_positron::util::stats::BenchTimer;

fn main() {
    let scale = if std::env::var("BENCH_FULL").is_ok() { Scale::Full } else { Scale::Small };
    println!("== bench: Table 1 (engine=sim, scale={scale:?}; BENCH_FULL=1 for paper-sized) ==\n");
    let mut timer = BenchTimer::new("table1/all-five-tasks");
    let rows = timer.sample(|| experiments::table1(Engine::Sim, None, scale, 7).expect("table1"));
    println!("{}", report::render_table1(&rows));
    let mut shape_ok = true;
    for r in &rows {
        // At 8 bits the paper's posit-vs-fixed gaps are sub-1% on the easy
        // tasks (e.g. 98.5 vs 98.3 on MNIST) — allow that noise band, but a
        // real collapse (WDBC-style 57.8 vs 85.9) must show posit ahead.
        if r.posit.0 + 0.01 < r.fixed.0 {
            println!("!! SHAPE VIOLATION: {} posit {:.3} < fixed {:.3}", r.dataset, r.posit.0, r.fixed.0);
            shape_ok = false;
        }
    }
    println!("shape (posit ≥ fixed − 1% on every task): {}", if shape_ok { "OK" } else { "VIOLATED" });
    println!("{}", timer.report());
}
