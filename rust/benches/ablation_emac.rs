//! Ablation bench (DESIGN.md design-choice studies):
//!
//! 1. **EMAC vs conventional MAC** — the paper's central premise (§4.1):
//!    per-step rounding "accumulates error that becomes substantial at
//!    low precision". We instantiate the same quantized networks on both
//!    datapaths and measure the accuracy gap per format and bit-width.
//! 2. **Quire-width sensitivity** — Eq. (2) sizes the accumulator; an
//!    undersized register wraps. Sweeping the width shows the accuracy
//!    knee exactly where Eq. (2) predicts.

use deep_positron::accel::{Datapath, DeepPositron};
use deep_positron::coordinator::experiments;
use deep_positron::datasets::{self, Scale};
use deep_positron::formats::{quire_width_bits, Format, FormatSpec};
use deep_positron::util::stats::BenchTimer;

fn main() {
    println!("== ablation 1: EMAC vs per-step-rounded MAC ==\n");
    let mut timer = BenchTimer::new("ablation/emac-vs-inexact");
    timer.sample(|| {
        for name in ["iris", "wdbc"] {
            let ds = datasets::load(name, 7, Scale::Small);
            let mlp = experiments::train_model(&ds, 7);
            println!("{name} (baseline {:.1}%):", mlp.accuracy(&ds) * 100.0);
            println!("{:<12} {:>8} {:>8} {:>8}", "config", "EMAC", "inexact", "gap");
            for n in [5u32, 6, 8] {
                for spec in [
                    FormatSpec::Posit { n, es: 1 },
                    FormatSpec::Float { n, we: 3.min(n - 2) },
                    FormatSpec::Fixed { n, q: n / 2 },
                ] {
                    let dp = DeepPositron::compile(&mlp, spec);
                    let exact = dp.accuracy_with(&ds, Datapath::Emac);
                    let inexact = dp.accuracy_with(&ds, Datapath::InexactMac);
                    println!(
                        "{:<12} {:>7.1}% {:>7.1}% {:>+7.1}%",
                        spec.name(),
                        exact * 100.0,
                        inexact * 100.0,
                        (exact - inexact) * 100.0
                    );
                }
            }
            println!();
        }
    });
    println!("{}\n", timer.report());

    println!("== ablation 2: quire width vs Eq.(2) ==\n");
    let ds = datasets::load("iris", 7, Scale::Small);
    let mlp = experiments::train_model(&ds, 7);
    let spec = FormatSpec::Posit { n: 8, es: 1 };
    let fmt = spec.build();
    let eq2 = quire_width_bits(10, fmt.max_value(), fmt.min_pos()); // iris fan-in ≤ 10
    let dp = DeepPositron::compile(&mlp, spec);
    let full = dp.accuracy_with(&ds, Datapath::Emac);
    println!("posit8es1 on iris; Eq.(2) width for k=10: {eq2} bits; full-quire accuracy {:.1}%", full * 100.0);
    println!("{:<10} {:>10}", "width", "accuracy");
    let mut timer2 = BenchTimer::new("ablation/quire-width-sweep");
    timer2.sample(|| {
        for w in [16u32, 24, 32, 40, 48, 56, 64, 80] {
            let acc = dp.accuracy_with(&ds, Datapath::NarrowQuire(w));
            let marker = if w >= eq2 { " (≥ Eq.2)" } else { "" };
            println!("{w:<10} {:>9.1}%{marker}", acc * 100.0);
        }
    });
    println!("\nexpected shape: accuracy recovers to the full-quire value at/above Eq.(2)'s width.");
    println!("{}", timer2.report());
}
