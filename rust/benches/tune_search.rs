//! Tuner search throughput and frontier quality (DESIGN.md §10): runs the
//! full greedy/beam descent on iris and wdbc under the acceptance budget
//! (accuracy within 1 pt of the best uniform 8-bit posit, EDP minimized)
//! and reports assignments-evaluated-per-second plus the frontier size.
//!
//! Asserted claims: the frontier is non-empty and contains no dominated
//! point, the descent converges to a feasible plan, and the tuned mixed
//! assignment undercuts the uniform 8-bit posit's modeled network EDP
//! strictly while staying within one accuracy point of it.

use deep_positron::coordinator::experiments;
use deep_positron::datasets::{self, Scale};
use deep_positron::tune::{self, TuneConfig};
use deep_positron::util::stats::{mean, BenchTimer};

fn main() {
    for dataset in ["iris", "wdbc"] {
        let ds = datasets::load(dataset, 7, Scale::Small);
        let mlp = experiments::train_model(&ds, 7);
        let budget = tune::default_budget(&ds, &mlp, usize::MAX);
        let mut timer = BenchTimer::new(&format!("tune/{dataset} beam=2"));
        let report = timer.sample(|| tune::tune(&ds, &mlp, &TuneConfig::new(budget).with_beam(2)));
        let secs = mean(timer.samples());
        println!("{}", timer.report());
        println!(
            "  -> {dataset}: {} assignments in {:.2}s = {:.0} assignments/s, {} rounds, frontier size {}",
            report.evaluated,
            secs,
            report.evaluated as f64 / secs,
            report.rounds,
            report.frontier.len()
        );
        println!(
            "  -> tuned {} @ {:.2}% acc, EDP {:.3e} (uniform posit8 {}: {:.2}%, EDP {:.3e})",
            report.plan.assignment.name(),
            report.plan.accuracy * 100.0,
            report.plan.cost.edp_pj_ns,
            report.reference.mixed.name(),
            report.reference.accuracy * 100.0,
            report.reference.cost.edp_pj_ns,
        );

        assert!(!report.frontier.is_empty(), "{dataset}: empty Pareto frontier");
        for a in &report.frontier {
            for b in &report.frontier {
                assert!(
                    !a.dominates(b),
                    "{dataset}: frontier point {} dominates {}",
                    a.mixed.name(),
                    b.mixed.name()
                );
            }
        }
        assert!(report.plan.feasible, "{dataset}: default budget must be attainable");
        assert!(
            report.plan.accuracy >= report.reference.accuracy - 0.01 - 1e-12,
            "{dataset}: tuned accuracy {} fell more than 1pt below uniform posit8 {}",
            report.plan.accuracy,
            report.reference.accuracy
        );
        assert!(
            report.plan.cost.edp_pj_ns < report.reference.cost.edp_pj_ns,
            "{dataset}: tuned EDP {} not strictly below uniform posit8 {}",
            report.plan.cost.edp_pj_ns,
            report.reference.cost.edp_pj_ns
        );
    }
    println!("\ntuned mixed plans undercut uniform posit8 EDP within 1 accuracy pt on iris + wdbc — OK");
}
