//! Tuner search throughput (DESIGN.md §10, §13): the sensitivity-pruned,
//! pool-parallel descent against the serial unpruned baseline on the conv
//! MNIST task, plus a pruned-parallel frontier-quality run on iris.
//!
//! Each measurement is ONE full search (assignments-evaluated-per-second),
//! so `BENCH_BUDGET` does not scale this bench — a search's cost is set by
//! its candidate pools, not a timer budget. The conv net trains for 2
//! epochs here (accuracy is relative to its own uniform posit8 reference,
//! so a lightly-trained net still exercises the full search).
//!
//! Asserted claims:
//! * the pruned+parallel conv search evaluates strictly fewer assignments
//!   than the serial unpruned search, and finishes at least 5× faster;
//! * its plan still satisfies the acceptance budget (accuracy within 1 pt
//!   of the best uniform 8-bit posit) at a network EDP no worse than the
//!   serial plan's, and carries its pruning provenance;
//! * the iris run keeps the PR-5 frontier-quality claims: feasible plan,
//!   EDP strictly below uniform posit8, non-dominated frontier.
//!
//! Throughput results land in the schema-versioned `BENCH_tune_search.json`
//! trajectory at the repo root and are gated against the committed baseline
//! (`util::bench_log`).

use deep_positron::accel::Mlp;
use deep_positron::coordinator::experiments;
use deep_positron::datasets::{self, Dataset, Scale};
use deep_positron::tune::{self, TuneConfig, TuneReport};
use deep_positron::util::bench_log::{self, BenchLog};
use deep_positron::util::stats::{mean, BenchTimer};

/// One full search, timed; returns the report and wall-clock seconds.
fn timed_search(label: &str, ds: &Dataset, mlp: &Mlp, cfg: &TuneConfig) -> (TuneReport, f64) {
    let mut timer = BenchTimer::new(label);
    let report = timer.sample(|| tune::tune(ds, mlp, cfg));
    let secs = mean(timer.samples());
    println!("{}", timer.report());
    println!(
        "  -> {label}: {} assignments in {secs:.2}s = {:.0} assignments/s, {} rounds, frontier size {}",
        report.evaluated,
        report.evaluated as f64 / secs,
        report.rounds,
        report.frontier.len()
    );
    (report, secs)
}

fn assert_frontier_clean(report: &TuneReport, task: &str) {
    assert!(!report.frontier.is_empty(), "{task}: empty Pareto frontier");
    for a in &report.frontier {
        for b in &report.frontier {
            assert!(!a.dominates(b), "{task}: frontier point {} dominates {}", a.mixed.name(), b.mixed.name());
        }
    }
}

fn main() {
    let mut log = BenchLog::new("tune_search");

    // --- Conv MNIST: serial unpruned vs sensitivity-pruned + parallel. ---
    let conv_ds = datasets::load("mnist", 7, Scale::Small);
    println!("training the conv net (conv4k5x5s2+pool2s2+flatten+dense10, 2 epochs)…");
    let conv_mlp = experiments::train_conv_model(&conv_ds, 7, 2);
    const EVAL_ROWS: usize = 48; // == sensitivity::SCREEN_ROWS: screening at search fidelity
    let budget = tune::default_budget(&conv_ds, &conv_mlp, EVAL_ROWS);
    let base = TuneConfig::new(budget).with_beam(1).with_eval_rows(EVAL_ROWS);

    let serial_cfg = base.clone().with_threads(1).with_prune(None);
    let (serial, serial_secs) = timed_search("tune/conv-mnist serial unpruned", &conv_ds, &conv_mlp, &serial_cfg);
    log.push("conv-mnist/serial-unpruned", serial.evaluated as f64 / serial_secs).expect("finite search rate");

    let pruned_cfg = base.with_prune(Some(0.01));
    let (pruned, pruned_secs) = timed_search("tune/conv-mnist pruned parallel", &conv_ds, &conv_mlp, &pruned_cfg);
    log.push("conv-mnist/pruned-parallel", pruned.evaluated as f64 / pruned_secs).expect("finite search rate");

    let table = pruned.sensitivity.as_ref().expect("pruned run must carry its sensitivity table");
    println!("\n{}", table.render());
    assert!(serial.sensitivity.is_none(), "unpruned run must not run the pre-pass");

    let speedup = serial_secs / pruned_secs;
    println!(
        "conv-mnist: pruned+parallel {} evals vs serial {} ({:.1}% pruned away), {speedup:.1}× faster",
        pruned.evaluated,
        serial.evaluated,
        100.0 * (1.0 - pruned.evaluated as f64 / serial.evaluated as f64)
    );
    assert!(
        pruned.evaluated < serial.evaluated,
        "pruned search evaluated {} assignments, serial {} — pruning must cut the pool",
        pruned.evaluated,
        serial.evaluated
    );
    assert!(
        speedup >= 5.0,
        "pruned+parallel search must be >= 5x faster than serial unpruned on conv \
         ({pruned_secs:.2}s vs {serial_secs:.2}s = {speedup:.1}x)"
    );
    assert!(pruned.plan.feasible, "pruned conv plan must satisfy the acceptance budget");
    assert!(
        pruned.plan.accuracy >= pruned.reference.accuracy - 0.01 - 1e-12,
        "pruned tuned accuracy {} fell more than 1pt below uniform posit8 {}",
        pruned.plan.accuracy,
        pruned.reference.accuracy
    );
    assert!(
        pruned.plan.cost.edp_pj_ns <= serial.plan.cost.edp_pj_ns,
        "pruned plan EDP {} exceeds the serial unpruned plan's {}",
        pruned.plan.cost.edp_pj_ns,
        serial.plan.cost.edp_pj_ns
    );
    let provenance = pruned.plan.pruned.as_deref().expect("pruned plan must carry provenance");
    assert!(provenance.starts_with("sensitivity drop<="), "odd provenance line: {provenance}");
    assert_frontier_clean(&pruned, "conv-mnist");
    println!(
        "  -> tuned {} @ {:.2}% acc, EDP {:.3e} ({provenance})",
        pruned.plan.assignment.name(),
        pruned.plan.accuracy * 100.0,
        pruned.plan.cost.edp_pj_ns
    );

    // --- Iris: the PR-5 frontier-quality run, now pruned + parallel. ---
    let iris_ds = datasets::load("iris", 7, Scale::Small);
    let iris_mlp = experiments::train_model(&iris_ds, 7);
    let budget = tune::default_budget(&iris_ds, &iris_mlp, usize::MAX);
    let iris_cfg = TuneConfig::new(budget).with_beam(2);
    let (report, secs) = timed_search("tune/iris pruned parallel beam=2", &iris_ds, &iris_mlp, &iris_cfg);
    log.push("iris/pruned-parallel", report.evaluated as f64 / secs).expect("finite search rate");
    println!(
        "  -> tuned {} @ {:.2}% acc, EDP {:.3e} (uniform posit8 {}: {:.2}%, EDP {:.3e})",
        report.plan.assignment.name(),
        report.plan.accuracy * 100.0,
        report.plan.cost.edp_pj_ns,
        report.reference.mixed.name(),
        report.reference.accuracy * 100.0,
        report.reference.cost.edp_pj_ns,
    );
    assert_frontier_clean(&report, "iris");
    assert!(report.plan.feasible, "iris: default budget must be attainable");
    assert!(
        report.plan.accuracy >= report.reference.accuracy - 0.01 - 1e-12,
        "iris: tuned accuracy {} fell more than 1pt below uniform posit8 {}",
        report.plan.accuracy,
        report.reference.accuracy
    );
    assert!(
        report.plan.cost.edp_pj_ns < report.reference.cost.edp_pj_ns,
        "iris: tuned EDP {} not strictly below uniform posit8 {}",
        report.plan.cost.edp_pj_ns,
        report.reference.cost.edp_pj_ns
    );

    println!("\npruned+parallel search cuts the conv candidate pool and wall clock without losing the plan — OK");
    bench_log::record_and_gate(
        log,
        || {
            // Best-of re-measurement: re-run the three timed searches on the
            // already-trained models (a search's rate is what is gated; its
            // quality claims were already asserted above).
            let mut log = BenchLog::new("tune_search");
            let (serial, secs) = timed_search("tune/conv-mnist serial unpruned", &conv_ds, &conv_mlp, &serial_cfg);
            log.push("conv-mnist/serial-unpruned", serial.evaluated as f64 / secs).expect("finite search rate");
            let (pruned, secs) = timed_search("tune/conv-mnist pruned parallel", &conv_ds, &conv_mlp, &pruned_cfg);
            log.push("conv-mnist/pruned-parallel", pruned.evaluated as f64 / secs).expect("finite search rate");
            let (report, secs) = timed_search("tune/iris pruned parallel beam=2", &iris_ds, &iris_mlp, &iris_cfg);
            log.push("iris/pruned-parallel", report.evaluated as f64 / secs).expect("finite search rate");
            log
        },
        bench_log::DEFAULT_TOLERANCE,
    );
}
