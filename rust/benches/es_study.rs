//! Bench E7 — regenerates §5.1: the posit es-parameter trade-off.
//!
//! Paper claims: EDP(es=0) is ≈3× and ≈1.4× smaller than es=2 / es=1; DNN
//! accuracy with es=1 is ~2% / ~4% better than es=2 / es=0 over [5,7]-bit;
//! hence es=1 is the energy-accuracy sweet spot below 8 bits.

use deep_positron::coordinator::{experiments, report, Engine};
use deep_positron::datasets::Scale;
use deep_positron::util::stats::BenchTimer;

fn main() {
    let scale = if std::env::var("BENCH_FULL").is_ok() { Scale::Full } else { Scale::Small };
    println!("== bench: §5.1 es study (scale={scale:?}) ==\n");
    let tasks = ["wdbc", "iris", "mushroom", "mnist", "fashion"];
    let mut timer = BenchTimer::new("es-study/5-tasks");
    let study = timer.sample(|| experiments::es_study(Engine::Sim, None, scale, 7, &tasks).expect("es study"));
    println!("{}", report::render_es_study(&study));
    let best_es = (0..3).max_by(|&a, &b| study.avg_acc[a].partial_cmp(&study.avg_acc[b]).unwrap()).unwrap();
    println!("accuracy-best es over [5,7] bits: {best_es} (paper: 1)");
    println!(
        "EDP ordering es0 < es1 < es2   : {}",
        if study.edp_ratio[1] > 1.0 && study.edp_ratio[2] > study.edp_ratio[1] { "OK" } else { "VIOLATED" }
    );
    println!("{}", timer.report());
}
