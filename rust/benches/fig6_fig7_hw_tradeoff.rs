//! Bench E5/E6 — regenerates Figs. 6 and 7: average accuracy degradation
//! (five tasks) vs the EMAC's energy-delay product (Fig 6), delay and
//! dynamic power (Fig 7), per format family × bit-width 5–8.
//!
//! Paper shape: posit lowest degradation (stars) at a slight power cost;
//! fixed lowest delay/EDP but worst accuracy; posit lower latency than
//! float; posit ≈ float EDP.

use deep_positron::coordinator::{experiments, report, Engine};
use deep_positron::datasets::Scale;
use deep_positron::util::stats::BenchTimer;

fn main() {
    let scale = if std::env::var("BENCH_FULL").is_ok() { Scale::Full } else { Scale::Small };
    println!("== bench: Figs 6 & 7 (scale={scale:?}) ==\n");
    let tasks = ["wdbc", "iris", "mushroom", "mnist", "fashion"];
    let mut timer = BenchTimer::new("fig6-7/tradeoff-sweep");
    let points = timer.sample(|| experiments::tradeoff_sweep(Engine::Sim, None, scale, 7, &tasks).expect("sweep"));

    println!("{}", report::render_tradeoff(&points, "edp"));
    println!("{}", report::render_tradeoff(&points, "delay"));
    println!("{}", report::render_tradeoff(&points, "power"));

    // Shape checks.
    let by = |fam: &str, n: u32| points.iter().find(|p| p.spec.family() == fam && p.spec.n() == n).unwrap();
    let mut ok = true;
    for n in 5..=8u32 {
        let (p, f, x) = (by("posit", n), by("float", n), by("fixed", n));
        if !(x.delay_ns < f.delay_ns && x.delay_ns < p.delay_ns) {
            println!("!! fixed not fastest at n={n}");
            ok = false;
        }
        if p.avg_degradation > x.avg_degradation + 1e-9 {
            println!("!! posit degrades more than fixed at n={n}");
            ok = false;
        }
    }
    let stars_posit = points.iter().filter(|p| p.star && p.spec.family() == "posit").count();
    println!("stars won by posit: {stars_posit}/4 bit-widths");
    println!("shape: {}", if ok { "OK" } else { "VIOLATED" });
    println!("{}", timer.report());
}
