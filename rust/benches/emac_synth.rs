//! Bench E8/E9 — the EMAC synthesis study (§5 prose + Table 2 context):
//! resources, latency, Fmax, energy, and EDP for every format configuration
//! at bit-widths 5–8 on the modeled Virtex-7 fabric.
//!
//! Paper shape: fixed uncontested in resources/latency; posit competitive
//! with float in energy & EDP while using more LUTs at equal precision;
//! posit offers a superior Fmax to float.

use deep_positron::coordinator::report::render_table2;
use deep_positron::formats::FormatSpec;
use deep_positron::hw;
use deep_positron::util::stats::BenchTimer;

fn main() {
    println!("== bench: EMAC synthesis sweep (k = {}) ==\n", hw::DEFAULT_K);
    let mut timer = BenchTimer::new("emac-synth/sweep-5..8");
    let reports = timer.sample(|| hw::sweep(&[5, 6, 7, 8], hw::DEFAULT_K));
    println!("{}", hw::render_table(&reports));

    // Shape checks at n=8.
    let get = |name: &str| reports.iter().find(|r| r.spec == FormatSpec::parse(name).unwrap()).unwrap();
    let (p1, f4, x5) = (get("posit8es1"), get("float8we4"), get("fixed8q5"));
    println!(
        "fixed fewest LUTs           : {}",
        if x5.luts < f4.luts && x5.luts < p1.luts { "OK" } else { "VIOLATED" }
    );
    println!("posit more LUTs than float  : {}", if p1.luts > f4.luts { "OK" } else { "VIOLATED" });
    println!("posit Fmax ≥ float Fmax     : {}", if p1.fmax_mhz >= f4.fmax_mhz { "OK" } else { "VIOLATED (model)" });
    println!("posit EDP within 2× of float: {}", if p1.edp_pj_ns < 2.0 * f4.edp_pj_ns { "OK" } else { "VIOLATED" });

    println!("\n== Table 2 (posit hardware implementations) ==\n");
    println!("{}", render_table2());
    println!("{}", timer.report());
}
