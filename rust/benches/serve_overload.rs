//! Overload-collapse bench (ISSUE 3 acceptance): one Sim worker under 4×
//! its measured capacity of open-loop offered load, with bounded admission
//! (`max_queue`) versus the pre-fix unbounded queue (`max_queue = usize::MAX`
//! reproduces the old enqueue-forever behaviour) measured in the same
//! harness.
//!
//! Asserts, at 4× capacity for the bounded run:
//!   * sampled worker queue depth never exceeds `max_queue`;
//!   * excess submissions come back as `ServeError::Overloaded` (shed > 0)
//!     rather than queueing;
//!   * `served + shed + expired` accounts for every submission exactly,
//!     and the engine's counts agree with the client's;
//!   * accepted-request p99 latency is strictly better than the unbounded
//!     run's p99 in the same bench.
//!
//! Run: `cargo bench --bench serve_overload`

use std::time::{Duration, Instant};

use deep_positron::accel::Mlp;
use deep_positron::coordinator::experiments::Engine;
use deep_positron::formats::FormatSpec;
use deep_positron::obs::ObsSnapshot;
use deep_positron::serve::{ServeEngine, ServeError, ShardConfig, ShardKey, ShardMetrics, WorkerConfig};
use deep_positron::util::bench_log::{self, BenchLog};
use deep_positron::util::Rng;

const FEATURES: usize = 64;
const CLASSES: usize = 10;
const MAX_QUEUE: usize = 64;
const OVERLOAD_FACTOR: f64 = 4.0;
const OFFERED_SECONDS: f64 = 1.0;
/// Latency budget attached to every 8th request, to exercise deadline
/// expiry under pressure (reported, not asserted — whether any expire
/// depends on queue delay vs the budget on this machine).
const DEADLINE_SLICE: usize = 8;
const DEADLINE_BUDGET: Duration = Duration::from_millis(20);

fn shard(mlp: &Mlp, max_queue: usize) -> ShardConfig {
    ShardConfig {
        dataset: "synth".into(),
        num_features: FEATURES,
        num_classes: CLASSES,
        mlp: mlp.clone(),
        spec: FormatSpec::Posit { n: 8, es: 1 },
        mixed: None,
        artifact: None,
        engine: Engine::Sim,
        workers: 1,
        worker: WorkerConfig { max_batch_wait: Duration::from_micros(200), sim_batch: 16, max_queue },
    }
}

fn rows(rng: &mut Rng, n: usize) -> Vec<Vec<f64>> {
    (0..n).map(|_| (0..FEATURES).map(|_| rng.normal(0.0, 1.0)).collect()).collect()
}

/// Closed-loop capacity probe: sequential submit→recv, no queueing.
fn measure_capacity(mlp: &Mlp, pool: &[Vec<f64>]) -> f64 {
    let engine = ServeEngine::start(vec![shard(mlp, MAX_QUEUE)]).expect("probe engine");
    let key = ShardKey::new("synth", FormatSpec::Posit { n: 8, es: 1 });
    let n = 400;
    let t0 = Instant::now();
    for i in 0..n {
        let rx = engine.submit(&key, pool[i % pool.len()].clone()).expect("probe submit");
        rx.recv().expect("probe reply");
    }
    let rps = n as f64 / t0.elapsed().as_secs_f64();
    engine.shutdown();
    rps
}

struct OverloadRun {
    metrics: ShardMetrics,
    snapshot: ObsSnapshot,
    submitted: usize,
    client_shed: usize,
    client_expired: usize,
    max_depth_seen: usize,
    drain: Duration,
}

/// Offer `offered_rps` of open-loop load for [`OFFERED_SECONDS`], sampling
/// the live queue depth, then drain every accepted reply and shut down.
fn run_overload(mlp: &Mlp, pool: &[Vec<f64>], max_queue: usize, offered_rps: f64) -> OverloadRun {
    let engine = ServeEngine::start(vec![shard(mlp, max_queue)]).expect("engine start");
    let key = ShardKey::new("synth", FormatSpec::Posit { n: 8, es: 1 });
    let total = (offered_rps * OFFERED_SECONDS) as usize;
    let mut accepted = Vec::with_capacity(total);
    let mut client_shed = 0usize;
    let mut submitted = 0usize;
    let mut max_depth_seen = 0usize;
    let t0 = Instant::now();
    while submitted < total {
        // Paced open-loop arrivals: submit whatever the offered rate says
        // is due by now, never blocking on replies.
        let due = ((t0.elapsed().as_secs_f64() * offered_rps) as usize).min(total);
        while submitted < due {
            let x = pool[submitted % pool.len()].clone();
            let sub = if submitted % DEADLINE_SLICE == 0 {
                engine.submit_with_deadline(&key, x, DEADLINE_BUDGET)
            } else {
                engine.submit(&key, x)
            };
            match sub {
                Ok(rx) => accepted.push(rx),
                Err(ServeError::Overloaded { .. }) => client_shed += 1,
                Err(e) => panic!("overload must shed, not fail: {e}"),
            }
            submitted += 1;
        }
        // Lock-free gauge: sampling must not contend with the worker's
        // metrics recording or clone the latency history.
        let depths = engine.queue_depths(&key).expect("shard exists");
        max_depth_seen = max_depth_seen.max(depths.iter().copied().max().unwrap_or(0));
        std::thread::sleep(Duration::from_micros(500));
    }
    let t_drain = Instant::now();
    let mut client_expired = 0usize;
    for rx in accepted {
        if rx.recv().is_err() {
            client_expired += 1;
        }
    }
    let drain = t_drain.elapsed();
    // Live snapshot through the exporter before shutdown tears the engine
    // down — the same path `repro serve --obs-out` uses.
    let snapshot = engine.observe();
    let metrics = engine.shutdown().shards.into_iter().next().expect("one shard");
    OverloadRun { metrics, snapshot, submitted, client_shed, client_expired, max_depth_seen, drain }
}

fn report(label: &str, run: &OverloadRun) {
    println!("--- {label} ---");
    println!("{}", run.metrics.render());
    println!(
        "submitted {} | max sampled depth {} | drain {:.2}s | p99 {:.1} ms\n",
        run.submitted,
        run.max_depth_seen,
        run.drain.as_secs_f64(),
        run.metrics.latency_percentile(99.0) * 1e3
    );
}

fn main() {
    // Untrained synthetic MLP: predictions are meaningless but the EMAC
    // compute per request (≈37k MACs) is exactly the serving hot path.
    let mut rng = Rng::new(7);
    let mlp = Mlp::new(&[FEATURES, 192, 128, CLASSES], &mut rng);
    let pool = rows(&mut rng, 64);

    let capacity = measure_capacity(&mlp, &pool);
    let offered = capacity * OVERLOAD_FACTOR;
    println!(
        "serve_overload: 1 Sim worker, closed-loop capacity {capacity:.0} req/s, \
         offering {offered:.0} req/s ({OVERLOAD_FACTOR}x) for {OFFERED_SECONDS}s\n"
    );

    let unbounded = run_overload(&mlp, &pool, usize::MAX, offered);
    report("unbounded queue (pre-fix behaviour)", &unbounded);

    let bounded = run_overload(&mlp, &pool, MAX_QUEUE, offered);
    report(&format!("bounded admission (max_queue = {MAX_QUEUE})"), &bounded);

    // 1. Depth stays bounded.
    assert!(
        bounded.max_depth_seen <= MAX_QUEUE,
        "sampled queue depth {} exceeded max_queue {MAX_QUEUE}",
        bounded.max_depth_seen
    );
    // 2. Excess load sheds instead of queueing.
    assert!(bounded.metrics.shed > 0, "4x offered load must shed at a {MAX_QUEUE}-deep queue");
    // 3. Exact accounting, client and engine agreeing.
    assert_eq!(bounded.metrics.submissions(), bounded.submitted, "served + shed + expired must equal submissions");
    assert_eq!(bounded.metrics.shed, bounded.client_shed, "engine and client shed counts must agree");
    assert_eq!(bounded.metrics.expired, bounded.client_expired, "engine and client expiry counts must agree");
    assert_eq!(unbounded.metrics.submissions(), unbounded.submitted, "unbounded run must account for all too");
    assert_eq!(unbounded.metrics.shed, 0, "an unbounded queue never sheds — that is the bug being fixed");
    // 4. Accepted-request tail latency is strictly better than the pre-fix
    //    unbounded-queue run measured in this same bench.
    let (p99_b, p99_u) = (bounded.metrics.latency_percentile(99.0), unbounded.metrics.latency_percentile(99.0));
    assert!(
        p99_b < p99_u,
        "bounded p99 ({:.1} ms) must beat the unbounded queue's p99 ({:.1} ms)",
        p99_b * 1e3,
        p99_u * 1e3
    );
    println!(
        "PASS: depth <= {MAX_QUEUE}, shed {} of {} submissions, accounting exact, p99 {:.1} ms vs {:.1} ms unbounded",
        bounded.metrics.shed,
        bounded.submitted,
        p99_b * 1e3,
        p99_u * 1e3
    );

    // 5. The observability exporter agrees with the engine: one shard,
    //    counts bounded by the final shutdown metrics (the snapshot is taken
    //    live, just before shutdown), and a strict JSON round-trip — the
    //    same codec `repro lint` runs over committed *.obs.json artifacts.
    let obs = &bounded.snapshot;
    assert_eq!(obs.shards.len(), 1, "one shard must export one entry");
    assert!(obs.shards[0].served as usize <= bounded.metrics.served, "exporter cannot overcount served");
    assert_eq!(ObsSnapshot::from_json(&obs.to_json()).expect("snapshot codec"), *obs);
    assert!(obs.to_prometheus().contains("deep_positron_served_total"));

    // Perf trajectory: record into BENCH_serve_overload.json and gate. The
    // tolerance is deliberately loose (50%) — end-to-end serving throughput
    // on a shared machine is far noisier than the pure kernel benches, and
    // this gate exists to catch collapses, not jitter.
    let measure = |capacity: f64, run: &OverloadRun| {
        let mut log = BenchLog::new("serve_overload");
        log.push("synth/closed_loop_capacity", capacity).expect("finite capacity measurement");
        log.push(
            "synth/bounded_served_per_s",
            run.metrics.served as f64 / (OFFERED_SECONDS + run.drain.as_secs_f64()),
        )
        .expect("finite throughput measurement");
        log
    };
    bench_log::record_and_gate(
        measure(capacity, &bounded),
        || {
            // Best-of re-measurement: fresh capacity probe + fresh bounded
            // overload run (fresh engines, same knobs as the gated run).
            let capacity = measure_capacity(&mlp, &pool);
            let rerun = run_overload(&mlp, &pool, MAX_QUEUE, capacity * OVERLOAD_FACTOR);
            measure(capacity, &rerun)
        },
        0.5,
    );
}
