//! Serving-engine scaling bench (ISSUE 1 acceptance): the sharded engine on
//! the Sim datapath at 1 vs 4 workers, closed-loop load from 8 client
//! threads. Reports per-shard p50/p95/p99 latency, batch occupancy, and
//! aggregate throughput, and asserts the 4-worker aggregate throughput is
//! strictly higher than 1-worker (near-linear on ≥4 cores: per-request EMAC
//! compute dominates, workers share the quantization tables and nothing
//! else).
//!
//! Run: `cargo bench --bench serve_throughput`

use std::sync::Arc;
use std::time::Duration;

use deep_positron::accel::Mlp;
use deep_positron::coordinator::experiments::Engine;
use deep_positron::formats::{FormatSpec, Quantizer};
use deep_positron::serve::{ServeEngine, ShardConfig, ShardKey, ShardMetrics, WorkerConfig};
use deep_positron::util::Rng;

const FEATURES: usize = 64;
const CLASSES: usize = 10;
const CLIENTS: usize = 8;
const REQS_PER_CLIENT: usize = 50;

/// Serve CLIENTS × REQS_PER_CLIENT requests through one synthetic shard
/// with `workers` Sim workers; return the shard's final metrics.
fn run(workers: usize, mlp: &Mlp) -> ShardMetrics {
    let spec = FormatSpec::Posit { n: 8, es: 1 };
    let shard = ShardConfig {
        dataset: "synth".into(),
        num_features: FEATURES,
        num_classes: CLASSES,
        mlp: mlp.clone(),
        spec,
        mixed: None,
        artifact: None,
        engine: Engine::Sim,
        workers,
        worker: WorkerConfig { max_batch_wait: Duration::from_micros(200), sim_batch: 16, ..WorkerConfig::default() },
    };
    let engine = Arc::new(ServeEngine::start(vec![shard]).expect("engine start"));
    let key = ShardKey::new("synth", spec);
    let mut clients = Vec::with_capacity(CLIENTS);
    for c in 0..CLIENTS {
        let engine = Arc::clone(&engine);
        let key = key.clone();
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xC0FFEE ^ c as u64);
            for _ in 0..REQS_PER_CLIENT {
                let x: Vec<f64> = (0..FEATURES).map(|_| rng.normal(0.0, 1.0)).collect();
                let rx = engine.submit(&key, x).expect("submit");
                let _ = rx.recv().expect("reply");
            }
        }));
    }
    for c in clients {
        c.join().expect("client thread");
    }
    let engine = match Arc::try_unwrap(engine) {
        Ok(engine) => engine,
        Err(_) => unreachable!("all clients joined; bench holds the sole Arc"),
    };
    engine.shutdown().shards.into_iter().next().expect("one shard")
}

fn main() {
    // Untrained synthetic MLP: predictions are meaningless but the EMAC
    // compute per request (≈37k MACs) is exactly the serving hot path.
    let mut rng = Rng::new(7);
    let mlp = Mlp::new(&[FEATURES, 192, 128, CLASSES], &mut rng);
    println!(
        "serve_throughput: {} clients × {} closed-loop reqs, synthetic {FEATURES}-192-128-{CLASSES} MLP, Sim engine\n",
        CLIENTS, REQS_PER_CLIENT
    );

    let builds_before = Quantizer::shared_builds();
    let m1 = run(1, &mlp);
    let m4 = run(4, &mlp);
    let builds_after = Quantizer::shared_builds();

    println!("{}\n", m1.render());
    println!("{}\n", m4.render());
    let (t1, t4) = (m1.throughput(), m4.throughput());
    println!("1 worker : {t1:.1} req/s");
    println!("4 workers: {t4:.1} req/s");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("scaling  : {:.2}× (ideal 4.00×, machine has {cores} cores)", t4 / t1);
    println!(
        "shared quantizer-table builds across all 5 workers: {} (cache hits for every replica)",
        builds_after - builds_before
    );

    assert!(
        t4 > t1,
        "4-worker aggregate throughput ({t4:.1} req/s) must be strictly higher than 1-worker ({t1:.1} req/s)"
    );
    println!("\nPASS: 4-worker throughput strictly higher than 1-worker");
}
