//! Bench E1/E2 — regenerates Fig. 1: (a) the posit8(es=0) value
//! distribution; (b) a trained network's parameter distribution overlaid
//! with squared quantization error. Paper claim: both are dense in
//! [-0.5, +0.5], making posit a natural fit for DNN parameters.

use deep_positron::coordinator::experiments;
use deep_positron::datasets::{self, Scale};
use deep_positron::formats::FormatSpec;
use deep_positron::quant;
use deep_positron::util::stats::BenchTimer;

fn main() {
    println!("== bench: Fig 1 ==\n");
    let spec = FormatSpec::Posit { n: 8, es: 0 };
    let mut timer = BenchTimer::new("fig1/value-distribution");
    let hist = timer.sample(|| quant::value_distribution(spec, 4.0, 16));
    println!("(a) posit8 es=0 value histogram over [-4,4]:");
    for (i, h) in hist.iter().enumerate() {
        println!("{:>6.2} | {}", -4.0 + 8.0 * i as f64 / 16.0, "#".repeat(*h));
    }
    let central: usize = hist[6..10].iter().sum();
    let total: usize = hist.iter().sum();
    println!("\ndensity in central [-0.5,1.5) band: {central}/{total} in-range values");

    let ds = datasets::load("wdbc", 7, Scale::Small);
    let mlp = experiments::train_model(&ds, 7);
    let params = mlp.named_tensors().last().unwrap().data.clone();
    let mut timer2 = BenchTimer::new("fig1/param-error-profile");
    let (ph, pe) = timer2.sample(|| quant::param_error_profile(spec, &params, 1.5, 20));
    println!("\n(b) trained parameter histogram | squared error per bucket:");
    let maxh = *ph.iter().max().unwrap() as f64;
    let maxe = pe.iter().cloned().fold(1e-300, f64::max);
    for i in 0..ph.len() {
        println!(
            "{:>6.2} | {:<20} | {}",
            -1.5 + 3.0 * i as f64 / 20.0,
            "#".repeat((ph[i] as f64 / maxh * 20.0) as usize),
            "*".repeat((pe[i] / maxe * 20.0) as usize)
        );
    }
    // Shape check: most parameters fall in [-0.5, 0.5].
    let in_band: usize = ph[6..14].iter().sum();
    let all: usize = ph.iter().sum();
    println!(
        "\nparams in [-0.6,0.6]: {:.0}% (paper: 'high density in [-0.5,+0.5]')",
        in_band as f64 / all as f64 * 100.0
    );
    println!("{}\n{}", timer.report(), timer2.report());
}
