//! Scalar vs batched Sim inference (the compiled-execution-plan payoff,
//! DESIGN.md §8, tiled + monomorphized in §12): samples/sec of per-sample
//! `forward_codes` against `forward_batch` at growing batch sizes, on the
//! tiny iris net (overhead-bound) and the mnist-scale net (the real hot
//! path, where the weight rows streaming across the batch are the win).
//!
//! Asserts three things the kernels promise:
//!
//! 1. the batched path strictly wins at batch ≥ 8 on the mnist-scale net
//!    (iris numbers are informational — its per-sample cost is dominated by
//!    the terminal rounds, identical on both paths);
//! 2. the tiled kernel strictly beats the pre-tiling **element-wise**
//!    kernel (reconstructed below from the public format primitives: one
//!    bounds-checked LUT hit per weight×activation pair, no row tiling, no
//!    pre-decoded activation block) at every batch ≥ 8 — after first
//!    proving the two bit-identical;
//! 3. the inference loop performs ZERO decode-LUT builds
//!    (`DecodeLut::shared_builds` must not move while samples flow).
//!
//! Results are recorded into the schema-versioned `BENCH_batch_forward.json`
//! perf trajectory at the repo root and gated against the committed baseline
//! (`util::bench_log`): a >10% samples/s regression fails the bench.

use std::sync::Arc;

use deep_positron::accel::{Datapath, DeepPositron, Mlp};
use deep_positron::coordinator::experiments;
use deep_positron::datasets::{self, Scale};
use deep_positron::formats::{DecodeLut, Exact, FormatSpec, Quantizer};
use deep_positron::util::bench_log::{self, BenchLog};
use deep_positron::util::stats::{mean, BenchTimer};

/// The PR-2 era element-wise batched kernel, reconstructed from the public
/// format primitives so the tiled kernel has an honest in-process rival:
/// feature-major activation blocks, one quire column per output neuron, and
/// — the part the tiled kernel removed — a bounds-checked `ops[code]` LUT
/// lookup for EVERY weight×activation pair.
struct ElementwisePlan {
    dims: Vec<usize>,
    w_codes: Vec<Vec<u16>>,
    bias_q: Vec<Vec<i128>>,
    lut: Arc<DecodeLut>,
    q: Arc<Quantizer>,
    zero: u16,
}

impl ElementwisePlan {
    fn build(dp: &DeepPositron, mlp: &Mlp, spec: FormatSpec) -> ElementwisePlan {
        let q = Quantizer::shared(spec);
        let lut = DecodeLut::shared(spec);
        let w_codes: Vec<Vec<u16>> = dp.dequantized_weights().iter().map(|w| q.quantize_slice(w).0).collect();
        let bias_q: Vec<Vec<i128>> = dp
            .dequantized_biases()
            .iter()
            .map(|bs| {
                bs.iter()
                    .map(|&b| {
                        let e = q.decode(q.quantize_f64(b).0).unwrap_or(Exact::ZERO);
                        lut.to_quire(&e)
                    })
                    .collect()
            })
            .collect();
        let zero = q.zero_code();
        ElementwisePlan { dims: mlp.dims(), w_codes, bias_q, lut, q, zero }
    }

    fn forward_batch(&self, rows: &[&[f64]]) -> Vec<Vec<u16>> {
        let b = rows.len();
        let max_dim = *self.dims.iter().max().unwrap();
        let mut act = vec![0u16; b * max_dim];
        let mut next = vec![0u16; b * max_dim];
        let mut quires = vec![0i128; b];
        for (s, row) in rows.iter().enumerate() {
            for (i, &x) in row.iter().enumerate() {
                act[i * b + s] = self.q.quantize_f64(x).0;
            }
        }
        let ops = self.lut.ops();
        let lsb = self.lut.lsb_exp();
        let last = self.w_codes.len() - 1;
        for (li, (codes, biasq)) in self.w_codes.iter().zip(&self.bias_q).enumerate() {
            let (in_dim, out_dim) = (self.dims[li], self.dims[li + 1]);
            let relu = li < last;
            for o in 0..out_dim {
                quires.fill(biasq[o]);
                for i in 0..in_dim {
                    let w = ops[codes[o * in_dim + i] as usize];
                    if w.mag == 0 {
                        continue;
                    }
                    for (s, quire) in quires.iter_mut().enumerate() {
                        // The per-pair LUT hit the tiled kernel hoisted out.
                        let a = ops[act[i * b + s] as usize];
                        if a.mag == 0 {
                            continue;
                        }
                        let mag = w.mag * a.mag;
                        let shift = (w.exp + a.exp - lsb) as u32;
                        let term = (mag as i128) << shift;
                        *quire += if w.neg ^ a.neg { -term } else { term };
                    }
                }
                for (s, &qv) in quires.iter().enumerate() {
                    next[o * b + s] = if relu && qv < 0 {
                        self.zero
                    } else {
                        self.q.quantize_exact(&Exact::new(qv < 0, qv.unsigned_abs(), lsb)).0
                    };
                }
            }
            std::mem::swap(&mut act, &mut next);
        }
        let out_dim = *self.dims.last().unwrap();
        (0..b).map(|s| (0..out_dim).map(|o| act[o * b + s]).collect()).collect()
    }
}

/// One dataset's models, built once so the best-of gate can re-measure
/// without re-training or re-compiling anything.
struct Prepared {
    dataset: &'static str,
    ds: deep_positron::datasets::Dataset,
    dp: DeepPositron,
    ew: ElementwisePlan,
}

/// The timed section, separated from model prep so [`bench_log::record_and_gate`]
/// can draw fresh samples for its best-of gate.
fn measure(preps: &[Prepared], budget: f64) -> BenchLog {
    let mut log = BenchLog::new("batch_forward");
    for p in preps {
        let (dataset, ds, dp, ew) = (p.dataset, &p.ds, &p.dp, &p.ew);
        let nrows = ds.test_len().min(64);
        let rows: Vec<&[f64]> = (0..nrows).map(|i| ds.test_row(i)).collect();

        // Warm every cache (tables, LUT, plan) before the counter snapshot.
        let _ = dp.forward_batch(&rows[..1], Datapath::Emac);
        let lut_builds_before = DecodeLut::shared_builds();

        let mut sink = 0u32;
        let mut timer = BenchTimer::new(&format!("{dataset}/scalar forward_codes ×{nrows}"));
        timer.run(budget, || {
            for r in &rows {
                sink = sink.wrapping_add(dp.forward_codes(r)[0] as u32);
            }
        });
        let scalar_sps = nrows as f64 / mean(timer.samples());
        println!("{}", timer.report());
        println!("  -> {scalar_sps:.0} samples/s scalar  [sink {sink}]");
        log.push(&format!("{dataset}/scalar"), scalar_sps).expect("finite throughput measurement");

        let mut flat = Vec::new();
        let mut wins = Vec::new();
        for b in [8usize, 32, 64] {
            let batch = &rows[..b.min(nrows)];
            let mut timer = BenchTimer::new(&format!("{dataset}/forward_batch B={b}"));
            timer.run(budget, || {
                dp.forward_batch_into(batch, Datapath::Emac, &mut flat);
                sink = sink.wrapping_add(flat[0] as u32);
            });
            let sps = batch.len() as f64 / mean(timer.samples());
            let mut timer_ew = BenchTimer::new(&format!("{dataset}/elementwise B={b}"));
            timer_ew.run(budget, || {
                sink = sink.wrapping_add(ew.forward_batch(batch)[0][0] as u32);
            });
            let ew_sps = batch.len() as f64 / mean(timer_ew.samples());
            println!("{}", timer.report());
            println!("  -> {sps:.0} samples/s tiled (×{:.2} vs scalar)  [sink {sink}]", sps / scalar_sps);
            println!("{}", timer_ew.report());
            println!("  -> {ew_sps:.0} samples/s element-wise (tiled is ×{:.2})", sps / ew_sps);
            log.push(&format!("{dataset}/forward_batch/B={b}"), sps).expect("finite throughput measurement");
            wins.push((b, sps, ew_sps));
        }
        assert_eq!(
            DecodeLut::shared_builds(),
            lut_builds_before,
            "{dataset}: inference rebuilt a decode LUT — the compile-once contract is broken"
        );
        for (b, sps, ew_sps) in wins {
            if dataset == "mnist" {
                assert!(
                    sps > scalar_sps,
                    "{dataset}: forward_batch at B={b} ({sps:.0}/s) must beat the scalar path ({scalar_sps:.0}/s)"
                );
                assert!(
                    sps > ew_sps,
                    "{dataset}: tiled kernel at B={b} ({sps:.0}/s) must strictly beat the \
                     PR-2 element-wise path ({ew_sps:.0}/s)"
                );
            } else if sps <= scalar_sps {
                println!("  (note: {dataset} B={b} did not beat scalar — tiny-net overheads, not the hot path)");
            }
        }
    }
    log
}

fn main() {
    let spec = FormatSpec::parse("posit8es1").unwrap();
    let budget = bench_log::bench_budget(0.4);
    let preps: Vec<Prepared> = ["iris", "mnist"]
        .into_iter()
        .map(|dataset| {
            let ds = datasets::load(dataset, 7, Scale::Small);
            let mlp = experiments::train_model(&ds, 7);
            let dp = DeepPositron::compile(&mlp, spec);
            let ew = ElementwisePlan::build(&dp, &mlp, spec);
            // The element-wise rival must be bit-identical before it is
            // timed — a faster wrong kernel proves nothing.
            let rows: Vec<&[f64]> = (0..ds.test_len().min(64)).map(|i| ds.test_row(i)).collect();
            assert_eq!(
                dp.forward_batch(&rows, Datapath::Emac),
                ew.forward_batch(&rows),
                "{dataset}: element-wise baseline diverged from the tiled kernel"
            );
            drop(rows);
            Prepared { dataset, ds, dp, ew }
        })
        .collect();
    let log = measure(&preps, budget);
    println!("\ntiled kernel beats scalar AND the element-wise path at every B >= 8 on the mnist-scale net — OK");
    bench_log::record_and_gate(log, || measure(&preps, budget), bench_log::DEFAULT_TOLERANCE);
}
