//! Scalar vs batched Sim inference (the compiled-execution-plan payoff,
//! DESIGN.md §8): samples/sec of per-sample `forward_codes` against
//! `forward_batch` at growing batch sizes, on the tiny iris net
//! (overhead-bound) and the mnist-scale net (the real hot path, where the
//! weight row streaming across the batch is the win).
//!
//! Asserts two things the refactor promises: the batched path strictly wins
//! at batch ≥ 8 on the mnist-scale net (iris numbers are informational —
//! its per-sample cost is dominated by the terminal rounds, identical on
//! both paths), and the inference loop performs ZERO decode-LUT builds
//! (`DecodeLut::shared_builds` must not move while samples flow).

use deep_positron::accel::{Datapath, DeepPositron};
use deep_positron::coordinator::experiments;
use deep_positron::datasets::{self, Scale};
use deep_positron::formats::{DecodeLut, FormatSpec};
use deep_positron::util::stats::{mean, BenchTimer};

fn main() {
    let spec = FormatSpec::parse("posit8es1").unwrap();
    for dataset in ["iris", "mnist"] {
        let ds = datasets::load(dataset, 7, Scale::Small);
        let mlp = experiments::train_model(&ds, 7);
        let dp = DeepPositron::compile(&mlp, spec);
        let nrows = ds.test_len().min(64);
        let rows: Vec<&[f64]> = (0..nrows).map(|i| ds.test_row(i)).collect();

        // Warm every cache (tables, LUT, plan) before the counter snapshot.
        let _ = dp.forward_batch(&rows[..1], Datapath::Emac);
        let lut_builds_before = DecodeLut::shared_builds();

        let mut sink = 0u32;
        let mut timer = BenchTimer::new(&format!("{dataset}/scalar forward_codes ×{nrows}"));
        timer.run(0.4, || {
            for r in &rows {
                sink = sink.wrapping_add(dp.forward_codes(r)[0] as u32);
            }
        });
        let scalar_sps = nrows as f64 / mean(timer.samples());
        println!("{}", timer.report());
        println!("  -> {scalar_sps:.0} samples/s scalar  [sink {sink}]");

        let mut wins = Vec::new();
        for b in [8usize, 32, 64] {
            let b = b.min(nrows);
            let batch = &rows[..b];
            let mut timer = BenchTimer::new(&format!("{dataset}/forward_batch B={b}"));
            timer.run(0.4, || {
                sink = sink.wrapping_add(dp.forward_batch(batch, Datapath::Emac)[0][0] as u32);
            });
            let sps = b as f64 / mean(timer.samples());
            println!("{}", timer.report());
            println!("  -> {sps:.0} samples/s batched (×{:.2} vs scalar)  [sink {sink}]", sps / scalar_sps);
            wins.push((b, sps));
        }
        assert_eq!(
            DecodeLut::shared_builds(),
            lut_builds_before,
            "{dataset}: inference rebuilt a decode LUT — the compile-once contract is broken"
        );
        for (b, sps) in wins {
            if dataset == "mnist" {
                assert!(
                    sps > scalar_sps,
                    "{dataset}: forward_batch at B={b} ({sps:.0}/s) must beat the scalar path ({scalar_sps:.0}/s)"
                );
            } else if sps <= scalar_sps {
                println!("  (note: {dataset} B={b} did not beat scalar — tiny-net overheads, not the hot path)");
            }
        }
    }
    println!("\nbatched execution plan beats the per-sample path at every B >= 8 on the mnist-scale net — OK");
}
