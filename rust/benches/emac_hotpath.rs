//! Hot-path microbenchmarks (the §Perf instrumentation): Rust EMAC MAC
//! throughput, quantizer throughput, full Deep Positron sample latency, and
//! the XLA fast path (when artifacts exist). These are the numbers the
//! performance pass iterates on (EXPERIMENTS.md §Perf).
//!
//! Every table and plan is built ONCE before the measured closures (timing
//! `DecodeLut`/`Quantizer` construction would measure the wrong thing), and
//! the bench asserts zero shared-LUT rebuilds across the whole measured
//! region — the compile-once contract, enforced where it is easiest to
//! break silently.

use deep_positron::accel::DeepPositron;
use deep_positron::coordinator::experiments;
use deep_positron::datasets::{self, Scale};
use deep_positron::formats::{DecodeLut, Emac, FormatSpec, Quantizer};
use deep_positron::runtime::{artifacts_dir, FormatTables, Runtime};
use deep_positron::util::stats::{fmt_time, mean, BenchTimer};
use deep_positron::util::Rng;

fn main() {
    let spec = FormatSpec::parse("posit8es1").unwrap();
    // Shared process-wide tables, exactly like production callers — NOT a
    // private `Quantizer::new`/`DecodeLut::new` pair, which would sidestep
    // the cache this bench asserts on.
    let q = Quantizer::shared(spec);
    let lut = DecodeLut::shared(spec);

    // --- EMAC MAC ops/s ---
    let mut rng = Rng::new(1);
    let codes: Vec<u16> = (0..784).map(|_| q.codes()[rng.below(q.len())]).collect();
    let weights: Vec<u16> = (0..784).map(|_| q.codes()[rng.below(q.len())]).collect();
    let mut emac = Emac::with_lut(lut, &q, 785);
    let lut_builds_before = DecodeLut::shared_builds();
    let mut timer = BenchTimer::new("emac/dot-784 (posit8es1)");
    let mut sink = 0u32;
    timer.run(0.5, || {
        sink = sink.wrapping_add(emac.dot(&weights, &codes, None, false) as u32);
    });
    let per_mac = mean(timer.samples()) / 784.0;
    println!("{}", timer.report());
    println!("  -> {:.1} M MAC/s ({}/MAC)  [sink {sink}]", 1e-6 / per_mac, fmt_time(per_mac));

    // --- quantizer throughput ---
    let xs: Vec<f64> = (0..4096).map(|_| rng.normal(0.0, 0.5)).collect();
    let mut timer = BenchTimer::new("quantizer/4096-f64 (posit8es1)");
    let mut acc = 0u32;
    timer.run(0.5, || {
        for &x in &xs {
            acc = acc.wrapping_add(q.quantize_f64(x).0 as u32);
        }
    });
    println!("{}", timer.report());
    println!("  -> {:.1} M quantize/s  [sink {acc}]", 4096.0 / mean(timer.samples()) / 1e6);

    // --- whole-sample accelerator latency (iris net) ---
    let ds = datasets::load("iris", 7, Scale::Small);
    let mlp = experiments::train_model(&ds, 7);
    let dp = DeepPositron::compile(&mlp, spec);
    let row = ds.test_row(0).to_vec();
    let mut timer = BenchTimer::new("positron/iris-sample (sim)");
    let mut hits = 0usize;
    timer.run(0.5, || {
        hits += dp.predict(&row);
    });
    println!("{}", timer.report());

    // --- mnist-scale sample (the real hot path) ---
    let dsm = datasets::load("mnist", 7, Scale::Small);
    let mlpm = experiments::train_model(&dsm, 7);
    let dpm = DeepPositron::compile(&mlpm, spec);
    let rowm = dsm.test_row(0).to_vec();
    let mut timer = BenchTimer::new("positron/mnist-sample (sim)");
    timer.run(1.0, || {
        hits += dpm.predict(&rowm);
    });
    let sim_per_sample = mean(timer.samples());
    println!("{}", timer.report());
    println!("  -> {:.1} samples/s  [sink {hits}]", 1.0 / sim_per_sample);

    // The whole measured region above — MAC loop, quantizer loop, both
    // compiled-plan walks — must not have rebuilt a single shared decode
    // LUT (compiles are cache hits; inference decodes through the plan).
    assert_eq!(
        DecodeLut::shared_builds(),
        lut_builds_before,
        "measured region rebuilt a decode LUT — the compile-once contract is broken"
    );

    // --- XLA fast path, when artifacts exist ---
    let dir = artifacts_dir();
    if dir.join("manifest.txt").exists() {
        let rt = Runtime::new(&dir).expect("runtime");
        let tables = FormatTables::new(spec, dpm.quantizer());
        let wq = dpm.dequantized_weights();
        let bq = dpm.dequantized_biases();
        let mut weights = Vec::new();
        for (l, w) in mlpm.layers.iter().zip(&wq) {
            let mut wio = vec![0.0; l.in_dim * l.out_dim];
            for o in 0..l.out_dim {
                for i in 0..l.in_dim {
                    wio[i * l.out_dim + o] = w[o * l.in_dim + i];
                }
            }
            weights.push(wio);
        }
        let exe = rt.quantized_infer("mnist", 256).expect("exe");
        let x: Vec<f64> = dsm.x_test[..256 * 784].to_vec();
        // warm-up (compile)
        let _ = exe.run(&x, 256, &weights, &bq, &tables).expect("run");
        let mut timer = BenchTimer::new("xla/q_infer mnist b256 (fast path)");
        let mut total = 0.0f64;
        timer.run(2.0, || {
            let logits = exe.run(&x, 256, &weights, &bq, &tables).expect("run");
            total += logits[0];
        });
        let per_sample = mean(timer.samples()) / 256.0;
        println!("{}", timer.report());
        println!("  -> {:.0} samples/s via XLA ({}/sample)  [sink {total:.1}]", 1.0 / per_sample, fmt_time(per_sample));
        println!("  -> fast-path speedup over sim: {:.1}×", sim_per_sample / per_sample);
    } else {
        println!("(no artifacts — XLA fast-path bench skipped; run `make artifacts`)");
    }
}
