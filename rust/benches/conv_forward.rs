//! Batched conv EMAC inference throughput + per-layer Eq. (2) sizing
//! (DESIGN.md §11): trains the small conv net on the raster MNIST task,
//! reports scalar vs `forward_batch` samples/sec through the conv quire
//! kernels, and asserts the layer-IR hardware claims.
//!
//! Asserted claims:
//! * the conv layer's Eq. (2) accumulation length is its RECEPTIVE FIELD
//!   (`k = 5·5·1 + 1 = 26`), so the compiled plan and the cost model
//!   provision a strictly narrower quire than a dense-on-pixels net
//!   (`k = 785`) pays for at the same format;
//! * the compile-time quire guard is live (an absurd `k` panics);
//! * the batched conv path strictly beats per-sample execution at B = 32
//!   with zero decode-LUT rebuilds on the inference loop;
//! * the quantized conv net tracks its own f64 baseline (Table 1's story,
//!   conv edition).
//!
//! Throughput results land in the schema-versioned `BENCH_conv_forward.json`
//! trajectory at the repo root and are gated against the committed baseline
//! (`util::bench_log`).

use deep_positron::accel::{Datapath, DeepPositron};
use deep_positron::coordinator::experiments;
use deep_positron::datasets::{self, Scale};
use deep_positron::formats::{DecodeLut, FormatSpec, MixedSpec};
use deep_positron::hw;
use deep_positron::tune::network_cost_ir;
use deep_positron::util::bench_log::{self, BenchLog};
use deep_positron::util::stats::{mean, BenchTimer};

fn main() {
    let spec = FormatSpec::parse("posit8es1").unwrap();
    let ds = datasets::load("mnist", 7, Scale::Small);
    println!("training the conv net (conv4k5x5s2+pool2s2+flatten+dense10, {} epochs)…", experiments::CONV_EPOCHS);
    let mlp = experiments::train_conv_model(&ds, 7, experiments::CONV_EPOCHS);
    let baseline = mlp.accuracy(&ds);
    println!("f64 conv baseline accuracy: {:.2}%", baseline * 100.0);

    // --- Eq. (2) fires at the conv receptive-field fan-in, per layer. ---
    let ks: Vec<usize> = mlp.layers.iter().map(|l| l.eq2_k()).collect();
    assert_eq!(ks, vec![26, 4, 0, 145], "per-layer Eq.(2) k must follow the receptive field");
    assert_eq!(mlp.max_fan_in(), 144, "widest dot product is the dense head, not the 784-pixel input");
    let conv_quire = hw::synthesize(spec, 26).quire_bits;
    let dense_on_pixels_quire = hw::synthesize(spec, 785).quire_bits;
    assert!(
        conv_quire < dense_on_pixels_quire,
        "26-term conv quire ({conv_quire}b) must undercut the dense-on-pixels quire ({dense_on_pixels_quire}b)"
    );
    let ir = mlp.ir();
    let cost = network_cost_ir(&MixedSpec::uniform(spec, ir.len()), &ir);
    assert_eq!(
        cost.max_quire_bits,
        hw::synthesize(spec, 145).quire_bits,
        "network-wide max quire must be the dense head's 145-term one"
    );
    println!(
        "Eq.(2) per layer: k = {ks:?}; conv quire {conv_quire}b vs dense-on-pixels {dense_on_pixels_quire}b, \
         network max {}b",
        cost.max_quire_bits
    );
    // The guard itself is live: a quire that cannot fit i128 panics at
    // compile/synthesis time instead of silently wrapping.
    let lut = DecodeLut::shared(FormatSpec::parse("posit8es2").unwrap());
    let fired = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| lut.assert_quire_fits(usize::MAX))).is_err();
    assert!(fired, "the Eq.(2) quire guard must fire on an absurd k");

    // --- Throughput: scalar vs batched conv plan walks. The timed section
    // lives in a closure so the best-of gate can draw fresh samples without
    // retraining or recompiling. ---
    let budget = bench_log::bench_budget(0.4);
    let dp = DeepPositron::compile(&mlp, spec);
    let measure = || {
        let mut log = BenchLog::new("conv_forward");
        let nrows = ds.test_len().min(64);
        let rows: Vec<&[f64]> = (0..nrows).map(|i| ds.test_row(i)).collect();
        let _ = dp.forward_batch(&rows[..1], Datapath::Emac); // warm every cache
        let lut_builds_before = DecodeLut::shared_builds();

        let mut sink = 0u32;
        let mut timer = BenchTimer::new(&format!("conv-mnist/scalar forward_codes ×{nrows}"));
        timer.run(budget, || {
            for r in &rows {
                sink = sink.wrapping_add(dp.forward_codes(r)[0] as u32);
            }
        });
        let scalar_sps = nrows as f64 / mean(timer.samples());
        println!("{}", timer.report());
        println!("  -> {scalar_sps:.0} samples/s scalar  [sink {sink}]");
        log.push("conv-mnist/scalar", scalar_sps).expect("finite throughput measurement");

        let mut flat = Vec::new();
        let mut batched_at_32 = 0.0;
        for b in [8usize, 32] {
            let batch = &rows[..b.min(nrows)];
            let mut timer = BenchTimer::new(&format!("conv-mnist/forward_batch B={b}"));
            timer.run(budget, || {
                dp.forward_batch_into(batch, Datapath::Emac, &mut flat);
                sink = sink.wrapping_add(flat[0] as u32);
            });
            let sps = batch.len() as f64 / mean(timer.samples());
            println!("{}", timer.report());
            println!("  -> {sps:.0} samples/s batched (×{:.2} vs scalar)  [sink {sink}]", sps / scalar_sps);
            log.push(&format!("conv-mnist/forward_batch/B={b}"), sps).expect("finite throughput measurement");
            if b == 32 {
                batched_at_32 = sps;
            }
        }
        assert_eq!(
            DecodeLut::shared_builds(),
            lut_builds_before,
            "conv inference rebuilt a decode LUT — the compile-once contract is broken"
        );
        assert!(
            batched_at_32 > scalar_sps,
            "batched conv path at B=32 ({batched_at_32:.0}/s) must beat per-sample execution ({scalar_sps:.0}/s)"
        );
        log
    };
    let log = measure();

    // --- Accuracy: the conv EMAC tracks the f64 conv baseline. ---
    let acc = dp.accuracy(&ds);
    println!("posit8es1 conv EMAC accuracy: {:.2}% (f64 baseline {:.2}%)", acc * 100.0, baseline * 100.0);
    assert!(baseline > 0.5, "conv baseline collapsed: {baseline}");
    assert!(acc >= baseline - 0.08, "posit8 conv EMAC lost too much: {acc} vs {baseline}");

    println!("\nconv EMAC provisions the 26-term receptive-field quire and batching wins at B=32 — OK");
    bench_log::record_and_gate(log, measure, bench_log::DEFAULT_TOLERANCE);
}
