//! The token scanner under the exactness lint: a line-oriented pass that
//! blanks comments and string/char literals (so a banned token inside a doc
//! comment or an error message never counts), tracks brace depth across
//! lines, and surfaces line comments verbatim so the rule layer can read
//! `exact-lint:` annotations.
//!
//! This is deliberately NOT a Rust parser. Like the hand-rolled JSON codec
//! in [`crate::util::bench_log`], it understands exactly the subset it
//! needs: line/block/doc comments (blocks nest), plain/byte/raw string
//! literals, char literals vs. lifetimes, and `{`/`}` nesting. Everything
//! else passes through untouched for the token rules to inspect.

/// One scanned source line.
#[derive(Debug, Clone)]
pub struct CodeLine {
    /// 1-based line number.
    pub line: usize,
    /// The line with comments and string/char-literal contents blanked to
    /// spaces — token rules run over this, never over raw source.
    pub code: String,
    /// Text of the line comment on this line (after `//`, `///` or `//!`),
    /// if any — where `exact-lint:` annotations live.
    pub comment: Option<String>,
    /// Brace depth at the start of the line.
    pub depth_start: i32,
    /// Brace depth after the line.
    pub depth_end: i32,
}

impl CodeLine {
    /// Whether the line carries any code tokens at all (blank and
    /// comment-only lines answer false).
    pub fn has_code(&self) -> bool {
        !self.code.trim().is_empty()
    }
}

/// Cross-line scanner state.
enum Mode {
    /// Plain code.
    Code,
    /// Inside a (possibly nested) `/* */` block comment, at this nest depth.
    Block(u32),
    /// Inside a `"…"` (or `b"…"`) string literal.
    Str,
    /// Inside a raw string literal closed by `"` followed by this many `#`s.
    RawStr(u32),
}

/// Scan a whole source file into [`CodeLine`]s.
pub fn scan(src: &str) -> Vec<CodeLine> {
    let mut mode = Mode::Code;
    let mut depth: i32 = 0;
    let mut out = Vec::new();
    for (idx, raw) in src.lines().enumerate() {
        let chars: Vec<char> = raw.chars().collect();
        let mut code = String::with_capacity(raw.len());
        let mut comment = None;
        let depth_start = depth;
        let mut i = 0;
        while i < chars.len() {
            let c = chars[i];
            match mode {
                Mode::Block(ref mut nest) => {
                    if c == '*' && chars.get(i + 1) == Some(&'/') {
                        *nest -= 1;
                        if *nest == 0 {
                            mode = Mode::Code;
                        }
                        code.push_str("  ");
                        i += 2;
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        *nest += 1;
                        code.push_str("  ");
                        i += 2;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                Mode::Str => {
                    if c == '\\' {
                        code.push_str("  ");
                        i += 2;
                    } else {
                        if c == '"' {
                            mode = Mode::Code;
                        }
                        code.push(' ');
                        i += 1;
                    }
                }
                Mode::RawStr(hashes) => {
                    if c == '"' && closes_raw(&chars, i + 1, hashes) {
                        mode = Mode::Code;
                        code.push(' ');
                        for _ in 0..hashes {
                            code.push(' ');
                        }
                        i += 1 + hashes as usize;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                Mode::Code => {
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        // Line comment (covers /// and //! too): capture its
                        // text for the annotation layer and stop the line.
                        let text: String = chars[i + 2..].iter().collect();
                        comment = Some(text.trim_start_matches(['/', '!']).trim().to_string());
                        break;
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        mode = Mode::Block(1);
                        code.push_str("  ");
                        i += 2;
                    } else if let Some(hashes) = raw_string_start(&chars, i) {
                        mode = Mode::RawStr(hashes);
                        let span = raw_prefix_len(&chars, i);
                        for _ in 0..span {
                            code.push(' ');
                        }
                        i += span;
                    } else if c == '"' || (c == 'b' && chars.get(i + 1) == Some(&'"') && !ident_before(&chars, i)) {
                        let span = if c == 'b' { 2 } else { 1 };
                        for _ in 0..span {
                            code.push(' ');
                        }
                        i += span;
                        mode = Mode::Str;
                    } else if c == '\'' {
                        // Char literal vs. lifetime: 'x' / '\n' are
                        // literals; 'a in `&'a T` has no closing quote.
                        if chars.get(i + 1) == Some(&'\\') {
                            let close = chars[i + 1..].iter().position(|&c| c == '\'').map(|p| i + 1 + p);
                            let end = close.unwrap_or(chars.len() - 1) + 1;
                            for _ in i..end {
                                code.push(' ');
                            }
                            i = end;
                        } else if chars.get(i + 2) == Some(&'\'') {
                            code.push_str("   ");
                            i += 3;
                        } else {
                            code.push(c);
                            i += 1;
                        }
                    } else {
                        if c == '{' {
                            depth += 1;
                        } else if c == '}' {
                            depth -= 1;
                        }
                        code.push(c);
                        i += 1;
                    }
                }
            }
        }
        out.push(CodeLine { line: idx + 1, code, comment, depth_start, depth_end: depth });
    }
    out
}

/// Whether the raw-string close quote at `quote_end` is followed by
/// `hashes` `#` characters.
fn closes_raw(chars: &[char], quote_end: usize, hashes: u32) -> bool {
    (0..hashes as usize).all(|k| chars.get(quote_end + k) == Some(&'#'))
}

/// Detect `r"`, `r#"`, `br"`, … at `i` (not preceded by an identifier
/// character); returns the `#` count.
fn raw_string_start(chars: &[char], i: usize) -> Option<u32> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') || ident_before(chars, i) {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    (chars.get(j) == Some(&'"')).then_some(hashes)
}

/// Length of the raw-string opening (`r##"` → 4, `br"` → 3).
fn raw_prefix_len(chars: &[char], i: usize) -> usize {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    j += 1; // the r
    while chars.get(j) == Some(&'#') {
        j += 1;
    }
    j + 1 - i // the opening quote
}

/// Whether the character before index `i` continues an identifier (so `r`
/// inside `for"` or `attr"` never opens a raw string).
fn ident_before(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

/// Whether `word` occurs in `code` as a standalone token (not embedded in a
/// longer identifier like `quantize_f64` or `unsafe_code`).
pub fn has_word(code: &str, word: &str) -> bool {
    word_at(code, word).is_some()
}

/// Column (0-based) of the first standalone occurrence of `word`.
pub fn word_at(code: &str, word: &str) -> Option<usize> {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let at = from + pos;
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let end = at + word.len();
        let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            return Some(at);
        }
        from = at + word.len();
    }
    None
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Whether the stripped code contains a floating-point literal: `1.5`,
/// `2e-3`, `1.0f64`, … Integer literals, ranges (`0..2`), tuple accesses
/// (`x.0`) and hex/octal/binary literals do not match.
pub fn has_float_literal(code: &str) -> bool {
    float_literal_at(code).is_some()
}

/// Column of the first floating-point literal, if any.
pub fn float_literal_at(code: &str) -> Option<usize> {
    let b = code.as_bytes();
    let mut i = 0;
    while i < b.len() {
        if !b[i].is_ascii_digit() || (i > 0 && is_ident_byte(b[i - 1])) || (i > 0 && b[i - 1] == b'.') {
            i += 1;
            continue;
        }
        let start = i;
        // Radix-prefixed literals never contain a float: skip whole token.
        if b[i] == b'0' && matches!(b.get(i + 1), Some(b'x' | b'o' | b'b')) {
            i += 2;
            while i < b.len() && (is_ident_byte(b[i]) || b[i] == b'_') {
                i += 1;
            }
            continue;
        }
        while i < b.len() && (b[i].is_ascii_digit() || b[i] == b'_') {
            i += 1;
        }
        // `12.5` — a dot followed by a digit (two dots are a range, an
        // identifier is a method call on an integer).
        if i < b.len() && b[i] == b'.' && b.get(i + 1).is_some_and(u8::is_ascii_digit) {
            return Some(start);
        }
        // `1e9` / `2E-3` exponent form without a dot.
        if i < b.len()
            && (b[i] == b'e' || b[i] == b'E')
            && match b.get(i + 1) {
                Some(b'+' | b'-') => b.get(i + 2).is_some_and(u8::is_ascii_digit),
                Some(d) => d.is_ascii_digit(),
                None => false,
            }
        {
            return Some(start);
        }
        i += 1;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_blanked() {
        let src = "let x = 1.5; // trailing 2.5\nlet s = \"3.5 f64\"; /* 4.5\n5.5 */ let y = 0;\n";
        let lines = scan(src);
        assert!(has_float_literal(&lines[0].code));
        assert_eq!(lines[0].comment.as_deref(), Some("trailing 2.5"));
        assert!(!has_float_literal(&lines[1].code), "{:?}", lines[1].code);
        assert!(!has_float_literal(&lines[2].code), "{:?}", lines[2].code);
        assert!(lines[2].code.contains("let y = 0;"));
    }

    #[test]
    fn depth_tracks_braces_outside_literals() {
        let lines = scan("fn f() {\n    let c = '{';\n    if true { g(); }\n}\n");
        assert_eq!((lines[0].depth_start, lines[0].depth_end), (0, 1));
        assert_eq!((lines[1].depth_start, lines[1].depth_end), (1, 1), "char literal brace must not count");
        assert_eq!((lines[2].depth_start, lines[2].depth_end), (1, 1));
        assert_eq!((lines[3].depth_start, lines[3].depth_end), (1, 0));
    }

    #[test]
    fn lifetimes_do_not_open_char_literals() {
        let lines = scan("impl<'q> Emac<'q> { fn f(&'q self) -> f64 { 0.0 } }\n");
        assert!(has_word(&lines[0].code, "f64"));
        assert!(has_float_literal(&lines[0].code));
        assert_eq!(lines[0].depth_end, 0);
    }

    #[test]
    fn raw_strings_are_blanked() {
        let lines = scan("let p = r#\"has 1.5 and \"quotes\" and f64\"#; let q = 2.5;\n");
        assert!(!has_word(&lines[0].code, "f64"));
        let col = float_literal_at(&lines[0].code).expect("2.5 survives");
        assert!(lines[0].code[col..].starts_with("2.5"), "{:?}", &lines[0].code);
    }

    #[test]
    fn word_boundaries_reject_embedded_matches() {
        assert!(!has_word("quantize_f64(x)", "f64"));
        assert!(!has_word("#![deny(unsafe_code)]", "unsafe"));
        assert!(has_word("x as f64", "f64"));
        assert!(has_word("unsafe { }", "unsafe"));
        assert!(has_word("v.to_f64()", "to_f64"));
    }

    #[test]
    fn float_literal_shapes() {
        for yes in ["let x = 1.5;", "a * 1e-300", "f(2.0f32)", "0.5 + y", "x >= 1.0E9"] {
            assert!(has_float_literal(yes), "{yes}");
        }
        for no in ["for i in 0..2 {}", "let t = x.0;", "let m = 0xFF;", "let k = 12;", "b[i + 1]", "0x1E5", "i128"] {
            assert!(!has_float_literal(no), "{no}");
        }
    }
}
