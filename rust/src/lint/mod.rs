//! `repro lint` — the repository's own static-analysis pass (DESIGN.md
//! §14), hand-rolled in the same no-new-deps idiom as the
//! [`crate::util::bench_log`] codec.
//!
//! The paper's headline claim rests on *exact* multiply-accumulate: Deep
//! Positron wins at ≤8 bits only because the quire path never rounds until
//! the terminal readout. This module enforces that invariant (and its
//! neighbors) statically, in two layers:
//!
//! - **Layer 1, exactness scan** ([`exactness`]): token-level rules over
//!   `rust/src` — float types/literals/conversions banned inside the
//!   declared exact zones (`formats::emac`, `accel::positron`), `unsafe`
//!   banned outside the allowlist (`util::pool`), `panic!`/`unwrap`/
//!   `expect` banned on the serve request path (`serve::worker`,
//!   `serve::router`), plus bench-wiring checks. Boundaries are declared
//!   in source with `// exact-lint: allow(<rule>, <reason>)`.
//! - **Layer 2, artifact audit** ([`audit`]): committed `BENCH_*.json`
//!   baselines, `*.plan` texts, and packed `*.dpz` model artifacts
//!   re-validated at rest — schema, filename agreement, shape inference
//!   over the `ir=` line, format names, provenance grammar, framing
//!   checksums, and Eq. (2) quire widths recomputed per layer.
//!
//! The CLI (`repro lint`) exits non-zero on any finding; `repro lint
//! --corpus rust/tests/lint_corpus` runs the seeded-violation corpus and
//! exits non-zero unless *every* fixture is caught. CI gates on both.

pub mod audit;
pub mod exactness;
pub mod lexer;

use std::fmt;
use std::path::{Path, PathBuf};

/// Every rule `repro lint` can report, with a stable kebab-case slug (the
/// corpus encodes the expected rule of each fixture in its filename prefix,
/// `<slug>__<desc>.<ext>`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LintRule {
    /// A float type, literal or conversion inside an exact zone.
    FloatInExactZone,
    /// An `unsafe` token outside the allowlisted module(s).
    UnsafeOutsideAllowlist,
    /// A panicking construct on the serve request path.
    PanicOnServePath,
    /// A malformed `exact-lint:` annotation (unknown rule, missing reason).
    BadAnnotation,
    /// A bench source not wired into Cargo.toml / CI / its baseline.
    BenchUnwired,
    /// A committed `BENCH_*.json` with no bench recording it.
    OrphanBenchBaseline,
    /// A committed `BENCH_*.json` that fails the strict codec or its
    /// filename/uniqueness invariants.
    BenchLogInvalid,
    /// A tune-plan text with a malformed or inconsistent field.
    PlanInvalid,
    /// A plan whose Eq. (2) quire width exceeds the `i128` path.
    PlanQuireOverflow,
    /// A plan `pruned=` line that does not match the provenance grammar.
    PlanBadProvenance,
    /// A dumped `*.obs.json` snapshot that fails the strict exporter codec
    /// (schema pin, exact key sets, quantile monotonicity).
    ObsSnapshotInvalid,
    /// A dumped `*.trace.jsonl` flight-recorder trace that fails the strict
    /// codec (header, key sets, or the phase-sum invariant).
    ObsTraceInvalid,
    /// A packed `*.dpz` model artifact that fails the strict
    /// [`crate::artifact::Artifact`] codec (magic/version, framing or field
    /// checksums, topology/format agreement, packed-stream shape).
    ArtifactInvalid,
    /// A `*.dpz` artifact whose re-derived Eq. (2) quire width exceeds the
    /// `i128` path — serve-compile from it would abort.
    ArtifactQuireOverflow,
}

impl LintRule {
    /// The stable kebab-case slug used in findings and corpus filenames.
    pub fn slug(&self) -> &'static str {
        match self {
            LintRule::FloatInExactZone => "float-in-exact-zone",
            LintRule::UnsafeOutsideAllowlist => "unsafe-outside-allowlist",
            LintRule::PanicOnServePath => "panic-on-serve-path",
            LintRule::BadAnnotation => "bad-annotation",
            LintRule::BenchUnwired => "bench-unwired",
            LintRule::OrphanBenchBaseline => "orphan-bench-baseline",
            LintRule::BenchLogInvalid => "bench-log-invalid",
            LintRule::PlanInvalid => "plan-invalid",
            LintRule::PlanQuireOverflow => "plan-quire-overflow",
            LintRule::PlanBadProvenance => "plan-bad-provenance",
            LintRule::ObsSnapshotInvalid => "obs-snapshot-invalid",
            LintRule::ObsTraceInvalid => "obs-trace-invalid",
            LintRule::ArtifactInvalid => "artifact-invalid",
            LintRule::ArtifactQuireOverflow => "artifact-quire-overflow",
        }
    }

    /// Inverse of [`LintRule::slug`].
    pub fn from_slug(s: &str) -> Option<LintRule> {
        const ALL: [LintRule; 14] = [
            LintRule::FloatInExactZone,
            LintRule::UnsafeOutsideAllowlist,
            LintRule::PanicOnServePath,
            LintRule::BadAnnotation,
            LintRule::BenchUnwired,
            LintRule::OrphanBenchBaseline,
            LintRule::BenchLogInvalid,
            LintRule::PlanInvalid,
            LintRule::PlanQuireOverflow,
            LintRule::PlanBadProvenance,
            LintRule::ObsSnapshotInvalid,
            LintRule::ObsTraceInvalid,
            LintRule::ArtifactInvalid,
            LintRule::ArtifactQuireOverflow,
        ];
        ALL.into_iter().find(|r| r.slug() == s)
    }
}

/// One typed violation: where, which rule, and why.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Repo-relative path of the offending file.
    pub file: String,
    /// 1-based line the finding anchors to.
    pub line: usize,
    /// The violated rule.
    pub rule: LintRule,
    /// Human-readable explanation.
    pub message: String,
}

impl Finding {
    /// Build a finding.
    pub fn new(file: &str, line: usize, rule: LintRule, message: String) -> Finding {
        Finding { file: file.to_string(), line, rule, message }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule.slug(), self.message)
    }
}

/// Zone classification of one source file — which token rules apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Zone {
    /// Float tokens are banned (quire accumulation path).
    pub exact: bool,
    /// Panicking constructs are banned (serve request path).
    pub serve: bool,
    /// `unsafe` is permitted (allowlisted module).
    pub unsafe_ok: bool,
}

/// The exact-zone map: classify a repo-relative source path. The zones are
/// whole files on purpose — a kernel that wants a float boundary declares
/// it with an annotation instead of moving out of the zone.
pub fn classify(rel: &str) -> Zone {
    Zone {
        exact: matches!(rel, "rust/src/formats/emac.rs" | "rust/src/accel/positron.rs"),
        serve: matches!(rel, "rust/src/serve/worker.rs" | "rust/src/serve/router.rs"),
        unsafe_ok: rel == "rust/src/util/pool.rs",
    }
}

/// Run the full lint (both layers) over the repository at `root`. Returns
/// findings sorted by file then line; `Err` only on an unreadable tree.
pub fn lint_tree(root: &Path) -> Result<Vec<Finding>, String> {
    let mut findings = Vec::new();

    let src_root = root.join("rust/src");
    for path in rust_sources(&src_root)? {
        let rel = rel_path(root, &path);
        let src = std::fs::read_to_string(&path).map_err(|e| format!("{}: {e}", path.display()))?;
        findings.extend(exactness::scan_file(&rel, &src, classify(&rel)));
    }

    findings.extend(audit::audit_bench_wiring(root));

    for name in top_level_files(root) {
        if name.starts_with("BENCH_") && name.ends_with(".json") {
            let text = std::fs::read_to_string(root.join(&name)).map_err(|e| format!("{name}: {e}"))?;
            findings.extend(audit::audit_bench_json(&name, &name, &text));
        }
    }
    for path in plan_files(root) {
        let rel = rel_path(root, &path);
        let text = std::fs::read_to_string(&path).map_err(|e| format!("{rel}: {e}"))?;
        findings.extend(audit::audit_plan(&rel, &text));
    }
    for path in artifact_files(root) {
        let rel = rel_path(root, &path);
        let text = std::fs::read_to_string(&path).map_err(|e| format!("{rel}: {e}"))?;
        findings.extend(audit::audit_artifact(&rel, &text));
    }
    for path in obs_files(root) {
        let rel = rel_path(root, &path);
        let text = std::fs::read_to_string(&path).map_err(|e| format!("{rel}: {e}"))?;
        if rel.ends_with(".obs.json") {
            findings.extend(audit::audit_obs_snapshot(&rel, &text));
        } else {
            findings.extend(audit::audit_trace_dump(&rel, &text));
        }
    }

    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(findings)
}

/// Per-fixture outcome of a corpus run ([`check_corpus`]).
#[derive(Debug, Clone)]
pub struct CorpusReport {
    /// One `CAUGHT`/`MISSED` line per fixture, in filename order.
    pub lines: Vec<String>,
    /// Fixtures whose expected rule was *not* reported (must be empty for
    /// the corpus gate to pass).
    pub missed: Vec<String>,
}

/// Run every seeded-violation fixture under `corpus` against the lint,
/// asserting each is caught by the rule its filename prefix declares
/// (`<rule-slug>__<desc>.<ext>`). `root` supplies the real Cargo.toml / CI
/// / benches context for the wiring rules.
pub fn check_corpus(root: &Path, corpus: &Path) -> Result<CorpusReport, String> {
    let mut report = CorpusReport { lines: Vec::new(), missed: Vec::new() };
    let mut names: Vec<String> = std::fs::read_dir(corpus)
        .map_err(|e| format!("{}: {e}", corpus.display()))?
        .filter_map(|e| e.ok().map(|e| e.file_name().to_string_lossy().into_owned()))
        .filter(|n| !n.starts_with('.') && !n.ends_with(".md"))
        .collect();
    names.sort();
    if names.is_empty() {
        return Err(format!("{}: corpus is empty", corpus.display()));
    }
    for name in names {
        let display = format!("{}/{name}", corpus.display());
        let outcome = check_fixture(root, &corpus.join(&name), &name, &display)?;
        match outcome {
            Ok(line) => report.lines.push(line),
            Err(line) => {
                report.lines.push(line.clone());
                report.missed.push(line);
            }
        }
    }
    Ok(report)
}

/// Run one fixture; `Ok(line)` when its expected rule fired, `Err(line)`
/// when it was missed. The outer `Result` is for unreadable fixtures.
#[allow(clippy::result_large_err)] // both arms carry the same report line
fn check_fixture(root: &Path, path: &Path, name: &str, display: &str) -> Result<Result<String, String>, String> {
    let Some((slug, rest)) = name.split_once("__") else {
        return Ok(Err(format!("MISSED {display}: filename has no `<rule>__` prefix")));
    };
    let Some(expected) = LintRule::from_slug(slug) else {
        return Ok(Err(format!("MISSED {display}: unknown rule slug `{slug}`")));
    };
    let text = std::fs::read_to_string(path).map_err(|e| format!("{display}: {e}"))?;

    let findings: Vec<Finding> = if name.ends_with(".rs") {
        if expected == LintRule::BenchUnwired {
            // The fixture poses as a bench source named after `rest`,
            // audited against the repository's real Cargo.toml and CI.
            let bench_name = rest.trim_end_matches(".rs");
            let cargo = std::fs::read_to_string(root.join("Cargo.toml")).unwrap_or_default();
            let ci = std::fs::read_to_string(root.join(".github/workflows/ci.yml")).unwrap_or_default();
            audit::audit_bench_source(root, display, bench_name, &text, &cargo, &ci)
        } else {
            let zone = match fixture_zone(&text) {
                Some(z) => z,
                None => return Ok(Err(format!("MISSED {display}: no `lint-corpus: zone=` header"))),
            };
            exactness::scan_file(display, &text, zone)
        }
    } else if name.ends_with(".obs.json") {
        // Before the generic `.json` arm on purpose: an obs snapshot is
        // audited by the exporter codec, not the bench-log codec.
        audit::audit_obs_snapshot(display, &text)
    } else if name.ends_with(".trace.jsonl") {
        audit::audit_trace_dump(display, &text)
    } else if name.ends_with(".json") {
        let mut fs = audit::audit_bench_json(display, rest, &text);
        if expected == LintRule::OrphanBenchBaseline {
            // The fixture poses as a committed baseline named after `rest`.
            if let Some(bench) = rest.strip_prefix("BENCH_").and_then(|n| n.strip_suffix(".json")) {
                if !audit::bench_records(root, bench) {
                    let msg = format!("no bench under rust/benches/ records `{bench}`");
                    fs.push(Finding::new(display, 1, LintRule::OrphanBenchBaseline, msg));
                }
            }
        }
        fs
    } else if name.ends_with(".plan") {
        audit::audit_plan(display, &text)
    } else if name.ends_with(".dpz") {
        audit::audit_artifact(display, &text)
    } else {
        return Ok(Err(format!("MISSED {display}: unknown fixture extension")));
    };

    if findings.iter().any(|f| f.rule == expected) {
        Ok(Ok(format!("CAUGHT {display}: [{}] {} finding(s)", slug, findings.len())))
    } else {
        let got: Vec<&str> = findings.iter().map(|f| f.rule.slug()).collect();
        Ok(Err(format!("MISSED {display}: expected [{slug}], got {got:?}")))
    }
}

/// Parse the `// lint-corpus: zone=<exact|serve|none>` header of an `.rs`
/// fixture into the [`Zone`] it should be scanned under.
fn fixture_zone(text: &str) -> Option<Zone> {
    let zone = text.lines().find_map(|l| l.split_once("lint-corpus:").map(|(_, r)| r))?;
    let zone = zone.split_once("zone=")?.1.split_whitespace().next()?;
    match zone {
        "exact" => Some(Zone { exact: true, serve: false, unsafe_ok: false }),
        "serve" => Some(Zone { exact: false, serve: true, unsafe_ok: false }),
        "none" => Some(Zone { exact: false, serve: false, unsafe_ok: false }),
        _ => None,
    }
}

/// All `.rs` files under `dir`, recursively, sorted for stable output.
fn rust_sources(dir: &Path) -> Result<Vec<PathBuf>, String> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let entries = std::fs::read_dir(&d).map_err(|e| format!("{}: {e}", d.display()))?;
        for entry in entries {
            let path = entry.map_err(|e| format!("{}: {e}", d.display()))?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|x| x == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// File names (not paths) at the top level of `root`.
fn top_level_files(root: &Path) -> Vec<String> {
    let mut names: Vec<String> = std::fs::read_dir(root)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter(|e| e.path().is_file())
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .collect()
        })
        .unwrap_or_default();
    names.sort();
    names
}

/// Committed `*.plan` files: top-level plus anything under `results/`.
fn plan_files(root: &Path) -> Vec<PathBuf> {
    files_by_suffix(root, &[".plan"])
}

/// Dumped obs artifacts (`*.obs.json` snapshots, `*.trace.jsonl` traces):
/// top-level plus anything under `results/`, the same sweep as plans.
fn obs_files(root: &Path) -> Vec<PathBuf> {
    files_by_suffix(root, &[".obs.json", ".trace.jsonl"])
}

/// Packed `.dpz` model artifacts: top-level plus anything under `results/`,
/// the same sweep as plans.
fn artifact_files(root: &Path) -> Vec<PathBuf> {
    files_by_suffix(root, &[".dpz"])
}

/// Top-level files plus everything under `results/` whose name ends with
/// one of `suffixes`, sorted for stable output.
fn files_by_suffix(root: &Path, suffixes: &[&str]) -> Vec<PathBuf> {
    let matches = |n: &str| suffixes.iter().any(|s| n.ends_with(s));
    let mut out: Vec<PathBuf> =
        top_level_files(root).into_iter().filter(|n| matches(n)).map(|n| root.join(n)).collect();
    let results = root.join("results");
    if results.is_dir() {
        let mut stack = vec![results];
        while let Some(d) = stack.pop() {
            if let Ok(entries) = std::fs::read_dir(&d) {
                for path in entries.filter_map(|e| e.ok().map(|e| e.path())) {
                    if path.is_dir() {
                        stack.push(path);
                    } else if path.file_name().is_some_and(|n| matches(&n.to_string_lossy())) {
                        out.push(path);
                    }
                }
            }
        }
    }
    out.sort();
    out
}

/// `path` rendered relative to `root` with forward slashes.
fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root).unwrap_or(path).to_string_lossy().replace('\\', "/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zone_map_matches_design() {
        assert!(classify("rust/src/formats/emac.rs").exact);
        assert!(classify("rust/src/accel/positron.rs").exact);
        assert!(!classify("rust/src/formats/posit.rs").exact);
        assert!(classify("rust/src/serve/worker.rs").serve);
        assert!(classify("rust/src/serve/router.rs").serve);
        assert!(!classify("rust/src/serve/metrics.rs").serve);
        assert!(classify("rust/src/util/pool.rs").unsafe_ok);
        assert!(!classify("rust/src/main.rs").unsafe_ok);
    }

    #[test]
    fn slugs_round_trip() {
        for slug in [
            "float-in-exact-zone",
            "unsafe-outside-allowlist",
            "panic-on-serve-path",
            "bad-annotation",
            "bench-unwired",
            "orphan-bench-baseline",
            "bench-log-invalid",
            "plan-invalid",
            "plan-quire-overflow",
            "plan-bad-provenance",
            "obs-snapshot-invalid",
            "obs-trace-invalid",
            "artifact-invalid",
            "artifact-quire-overflow",
        ] {
            assert_eq!(LintRule::from_slug(slug).expect(slug).slug(), slug);
        }
        assert!(LintRule::from_slug("bogus").is_none());
    }

    #[test]
    fn findings_render_file_line_rule() {
        let f = Finding::new("rust/src/x.rs", 7, LintRule::FloatInExactZone, "no".to_string());
        assert_eq!(f.to_string(), "rust/src/x.rs:7: [float-in-exact-zone] no");
    }
}
