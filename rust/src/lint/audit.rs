//! Layer 2 of `repro lint`: the artifact auditor.
//!
//! Validates the repository's committed artifacts *at rest*, without
//! compiling or executing a model:
//!
//! - **bench wiring** — every `rust/benches/*.rs` file has a `[[bench]]`
//!   entry in Cargo.toml (with the matching `path =`), CI compiles benches
//!   (`cargo bench --no-run`), and every bench that records a perf
//!   trajectory (`record_and_gate`) is both run in CI (`--bench <name>`)
//!   and has its committed `BENCH_<name>.json` baseline; a baseline with no
//!   recording bench is an orphan;
//! - **bench logs** — each `BENCH_*.json` parses under the strict
//!   hand-rolled codec ([`crate::util::bench_log::BenchLog::from_json`]),
//!   its `bench` field matches its filename, its entry names are unique,
//!   and its gate tolerance (when recorded) is a sane fraction;
//! - **tune plans** — `*.plan` text re-parses field by field: required
//!   keys, dims/IR agreement via real shape inference
//!   ([`crate::accel::NetIr::parse`]), every [`crate::formats::MixedSpec`]
//!   layer name, accuracy in `[0, 1]`, pruning provenance well-formedness,
//!   and the Eq. (2) quire width of every weighted layer recomputed from
//!   the `ir=` line — a plan whose quire cannot fit the `i128` path would
//!   only explode at serve-compile time without this check;
//! - **model artifacts** — packed `*.dpz` deployables re-validated under
//!   the strict [`crate::artifact::Artifact`] codec (magic/version, the
//!   trailing whole-file CRC, per-field stream checksums, topology/format
//!   agreement), with every weighted layer's Eq. (2) quire width re-derived
//!   independently of the parser — a corrupted or overflowing artifact is
//!   caught at rest, not at serve-boot;
//! - **obs artifacts** — dumped `*.obs.json` snapshots and `*.trace.jsonl`
//!   flight-recorder traces re-validated against the strict exporter /
//!   recorder codecs ([`crate::obs::ObsSnapshot::from_json`],
//!   [`crate::obs::recorder::parse_dump`]): schema pins, exact key sets,
//!   quantile monotonicity, and the per-event phase-sum invariant.

use std::path::Path;

use super::{Finding, LintRule};
use crate::accel::NetIr;
use crate::artifact::Artifact;
use crate::formats::emac::DecodeLut;
use crate::formats::{FormatSpec, MixedSpec};
use crate::obs::recorder::parse_dump;
use crate::obs::ObsSnapshot;
use crate::tune::TunePlan;
use crate::util::bench_log::BenchLog;

/// Usable `i128` quire bits — the bound `assert_quire_fits` enforces when a
/// plan is compiled; the auditor applies the same bound statically.
const QUIRE_BITS_LIMIT: u32 = 126;

/// Audit every bench source under `rust/benches/` against Cargo.toml, the
/// CI workflow, and the committed baselines, then sweep `BENCH_*.json` for
/// orphans.
pub fn audit_bench_wiring(root: &Path) -> Vec<Finding> {
    let mut findings = Vec::new();
    let cargo = read_or_finding(root, "Cargo.toml", &mut findings).unwrap_or_default();
    let ci_rel = ".github/workflows/ci.yml";
    let ci = read_or_finding(root, ci_rel, &mut findings).unwrap_or_default();
    if !ci.is_empty() && !ci.contains("cargo bench --no-run") {
        let msg = "CI never compiles the benches (`cargo bench --no-run` missing) — perf gates can rot".to_string();
        findings.push(Finding::new(ci_rel, 1, LintRule::BenchUnwired, msg));
    }

    let bench_dir = root.join("rust/benches");
    let mut bench_names = Vec::new();
    for entry in sorted_dir(&bench_dir) {
        let Some(name) = entry.strip_suffix(".rs") else { continue };
        bench_names.push(name.to_string());
        let rel = format!("rust/benches/{entry}");
        match std::fs::read_to_string(bench_dir.join(&entry)) {
            Ok(src) => findings.extend(audit_bench_source(root, &rel, name, &src, &cargo, &ci)),
            Err(e) => findings.push(Finding::new(&rel, 1, LintRule::BenchUnwired, format!("unreadable: {e}"))),
        }
    }

    for entry in sorted_dir(root) {
        let Some(name) = entry.strip_prefix("BENCH_").and_then(|n| n.strip_suffix(".json")) else { continue };
        if !bench_records(root, name) {
            let msg = format!("no bench under rust/benches/ records `{name}` — stale baseline, delete or re-wire it");
            findings.push(Finding::new(&entry, 1, LintRule::OrphanBenchBaseline, msg));
        }
    }
    findings
}

/// Audit one bench source file (named `bench_name`, displayed as `rel`)
/// against the given Cargo.toml and CI workflow texts.
pub fn audit_bench_source(
    root: &Path,
    rel: &str,
    bench_name: &str,
    src: &str,
    cargo: &str,
    ci: &str,
) -> Vec<Finding> {
    let mut findings = Vec::new();
    if !cargo_bench_names(cargo).iter().any(|n| n == bench_name) {
        let msg = format!("no `[[bench]]` entry named \"{bench_name}\" in Cargo.toml — the bench never builds");
        findings.push(Finding::new(rel, 1, LintRule::BenchUnwired, msg));
    }
    if src.contains("record_and_gate") {
        if !ci.contains(&format!("--bench {bench_name}")) {
            let msg = format!("records a perf trajectory but CI never runs `cargo bench --bench {bench_name}`");
            findings.push(Finding::new(rel, 1, LintRule::BenchUnwired, msg));
        }
        if !root.join(format!("BENCH_{bench_name}.json")).is_file() {
            let msg =
                format!("records a perf trajectory but BENCH_{bench_name}.json is not committed — gate is unarmed");
            findings.push(Finding::new(rel, 1, LintRule::BenchUnwired, msg));
        }
    }
    findings
}

/// Whether a bench source named `name` exists under `rust/benches/` and
/// records a perf trajectory (calls `record_and_gate`).
pub fn bench_records(root: &Path, name: &str) -> bool {
    std::fs::read_to_string(root.join(format!("rust/benches/{name}.rs")))
        .map(|src| src.contains("record_and_gate"))
        .unwrap_or(false)
}

/// The `name = "..."` values of every `[[bench]]` section in a Cargo.toml
/// text (a line-oriented scan — the manifest is ours and machine-written).
fn cargo_bench_names(cargo: &str) -> Vec<String> {
    let mut names = Vec::new();
    let mut in_bench = false;
    for line in cargo.lines() {
        let line = line.trim();
        if line.starts_with('[') {
            in_bench = line == "[[bench]]";
            continue;
        }
        if in_bench {
            if let Some(rest) = line.strip_prefix("name = \"") {
                if let Some(name) = rest.strip_suffix('"') {
                    names.push(name.to_string());
                }
            }
        }
    }
    names
}

/// Audit one `BENCH_*.json` text. `rel` is the display path; `filename` is
/// the basename the `bench` field must agree with.
pub fn audit_bench_json(rel: &str, filename: &str, text: &str) -> Vec<Finding> {
    let mut findings = Vec::new();
    let log = match BenchLog::from_json(text) {
        Ok(log) => log,
        Err(e) => {
            findings.push(Finding::new(rel, 1, LintRule::BenchLogInvalid, e.to_string()));
            return findings;
        }
    };
    if let Some(name) = filename.strip_prefix("BENCH_").and_then(|n| n.strip_suffix(".json")) {
        if log.bench != name {
            let msg = format!(
                "\"bench\": {:?} disagrees with filename ({name}) — the gate would load a different file",
                log.bench
            );
            findings.push(Finding::new(rel, 1, LintRule::BenchLogInvalid, msg));
        }
    }
    for (i, e) in log.entries.iter().enumerate() {
        if log.entries[..i].iter().any(|p| p.name == e.name) {
            let msg = format!("duplicate entry name {:?} — the comparator gates only the first", e.name);
            findings.push(Finding::new(rel, 1, LintRule::BenchLogInvalid, msg));
        }
    }
    findings
}

/// Audit one tune-plan text, field by field, re-deriving every invariant
/// the serve path will rely on. Granular on purpose: `TunePlan::parse`
/// answers yes/no, the auditor says *which line* is wrong and why.
pub fn audit_plan(rel: &str, text: &str) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut fields: Vec<(usize, &str, &str)> = Vec::new();
    for (idx, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match line.split_once('=') {
            Some((k, v)) => {
                if fields.iter().any(|(_, key, _)| *key == k) {
                    findings.push(Finding::new(rel, idx + 1, LintRule::PlanInvalid, format!("duplicate key `{k}`")));
                }
                fields.push((idx + 1, k, v));
            }
            None => {
                let msg = format!("not a `key=value` line: {line:?}");
                findings.push(Finding::new(rel, idx + 1, LintRule::PlanInvalid, msg));
            }
        }
    }
    let field = |key: &str| fields.iter().find(|(_, k, _)| *k == key).map(|&(ln, _, v)| (ln, v));
    for key in ["dataset", "dims", "layers", "accuracy", "feasible"] {
        if field(key).is_none() {
            findings.push(Finding::new(rel, 1, LintRule::PlanInvalid, format!("missing required key `{key}`")));
        }
    }

    let dims: Option<Vec<usize>> = field("dims").and_then(|(ln, v)| {
        let parsed: Option<Vec<usize>> = v.split(',').map(|d| d.parse().ok()).collect();
        match parsed {
            Some(d) if d.len() >= 2 && d.iter().all(|&w| w >= 1) => Some(d),
            Some(_) => {
                let msg = "dims needs at least [in, out], all >= 1".to_string();
                findings.push(Finding::new(rel, ln, LintRule::PlanInvalid, msg));
                None
            }
            None => {
                findings.push(Finding::new(rel, ln, LintRule::PlanInvalid, format!("unparseable dims {v:?}")));
                None
            }
        }
    });

    // Re-run shape inference over the declared topology (Layer 2's core:
    // the IR line is re-derived, not trusted).
    let ir: Option<NetIr> = match field("ir") {
        Some((ln, v)) => match NetIr::parse(v) {
            Some(ir) => Some(ir),
            None => {
                let msg = format!("ir {v:?} fails shape inference (NetIr::parse)");
                findings.push(Finding::new(rel, ln, LintRule::PlanInvalid, msg));
                None
            }
        },
        None => dims.as_ref().and_then(|d| NetIr::try_dense(d).ok()),
    };
    if let (Some((ln, _)), Some(ir), Some(dims)) = (field("ir"), ir.as_ref(), dims.as_ref()) {
        if &ir.dims() != dims {
            let msg = format!("ir flattens to dims {:?} but the dims line says {:?}", ir.dims(), dims);
            findings.push(Finding::new(rel, ln, LintRule::PlanInvalid, msg));
        }
    }

    let assignment: Option<MixedSpec> = field("layers").and_then(|(ln, v)| {
        for name in v.split('+') {
            if FormatSpec::parse(name).is_none_or(|s| !s.is_supported()) {
                let msg = format!("unparseable or unsupported format name {name:?} in layers");
                findings.push(Finding::new(rel, ln, LintRule::PlanInvalid, msg));
                return None;
            }
        }
        MixedSpec::parse(v)
    });
    if let (Some((ln, _)), Some(m), Some(ir)) = (field("layers"), assignment.as_ref(), ir.as_ref()) {
        if m.len() != ir.len() {
            let msg = format!("{} format assignments for {} IR layers", m.len(), ir.len());
            findings.push(Finding::new(rel, ln, LintRule::PlanInvalid, msg));
        }
    }

    if let Some((ln, v)) = field("accuracy") {
        match v.parse::<f64>() {
            Ok(a) if (0.0..=1.0).contains(&a) => {}
            _ => {
                let msg = format!("accuracy {v:?} is not a fraction in [0, 1]");
                findings.push(Finding::new(rel, ln, LintRule::PlanInvalid, msg));
            }
        }
    }
    if let Some((ln, v)) = field("feasible") {
        if v.parse::<bool>().is_err() {
            findings.push(Finding::new(rel, ln, LintRule::PlanInvalid, format!("feasible {v:?} is not a bool")));
        }
    }
    if let Some((ln, v)) = field("pruned") {
        if let Err(why) = check_provenance(v) {
            findings.push(Finding::new(rel, ln, LintRule::PlanBadProvenance, why));
        }
    }

    // Eq. (2) recomputation: per weighted layer, the assigned format's quire
    // must absorb the layer's accumulation length within the i128 path.
    if let (Some(ir), Some(m)) = (ir.as_ref(), assignment.as_ref()) {
        if m.len() == ir.len() {
            let ln = field("layers").map(|(ln, _)| ln).unwrap_or(1);
            for (li, (geom, &spec)) in ir.geoms().iter().zip(m.layers()).enumerate() {
                let k = geom.eq2_k();
                if k < 2 {
                    continue; // weightless wiring (flatten) accumulates nothing
                }
                let need = DecodeLut::shared(spec).quire_bits_needed(k);
                if need > QUIRE_BITS_LIMIT {
                    let msg = format!(
                        "layer {li} ({}) under {}: Eq. (2) quire needs {need} bits for k={k} (> {QUIRE_BITS_LIMIT}) — compile would abort",
                        geom.node_name(),
                        spec.name(),
                    );
                    findings.push(Finding::new(rel, ln, LintRule::PlanQuireOverflow, msg));
                }
            }
        }
    }

    // Cross-check: a plan the auditor passes must also pass the production
    // parser (and vice versa — an unaudited rejection reason is a lint gap).
    if findings.is_empty() && TunePlan::parse(text).is_none() {
        let msg = "TunePlan::parse rejects this plan for a reason the auditor does not model".to_string();
        findings.push(Finding::new(rel, 1, LintRule::PlanInvalid, msg));
    }
    findings
}

/// Validate a `pruned=` provenance line against the grammar
/// [`crate::tune::SensitivityTable::provenance`] emits:
/// `sensitivity drop<=<float>% floors=<u32,...> screen_rows=<int>`.
fn check_provenance(v: &str) -> Result<(), String> {
    let rest = v
        .strip_prefix("sensitivity drop<=")
        .ok_or_else(|| format!("provenance must start with `sensitivity drop<=`, got {v:?}"))?;
    let (drop, rest) = rest
        .split_once("% floors=")
        .ok_or_else(|| "provenance is missing the `% floors=` section".to_string())?;
    let d: f64 = drop.parse().map_err(|_| format!("drop budget {drop:?} is not a number"))?;
    if !d.is_finite() || d < 0.0 {
        return Err(format!("drop budget {d} must be a finite non-negative percentage"));
    }
    let (floors, rows) = rest
        .split_once(" screen_rows=")
        .ok_or_else(|| "provenance is missing the ` screen_rows=` section".to_string())?;
    if floors.is_empty() || floors.split(',').any(|f| f.parse::<u32>().is_err()) {
        return Err(format!("floors {floors:?} is not a comma-joined list of bit-widths"));
    }
    if rows.parse::<usize>().is_err() {
        return Err(format!("screen_rows {rows:?} is not an integer"));
    }
    Ok(())
}

/// Audit one packed `.dpz` model artifact against the strict
/// [`crate::artifact::Artifact`] codec, then re-derive the Eq. (2) quire
/// width of every weighted layer from the parsed header — the same
/// recomputation [`audit_plan`] does for tune plans, so the lint's quire
/// bound cannot silently drift from the parser's.
pub fn audit_artifact(rel: &str, text: &str) -> Vec<Finding> {
    let art = match Artifact::parse(text) {
        Ok(art) => art,
        Err(e) => {
            // The parser rejects quire overflows from the header alone (its
            // message names the quire); every other rejection is framing,
            // checksum, or field shape.
            let rule =
                if e.contains("quire") { LintRule::ArtifactQuireOverflow } else { LintRule::ArtifactInvalid };
            return vec![Finding::new(rel, 1, rule, e)];
        }
    };
    let mut findings = Vec::new();
    for (li, (geom, &spec)) in art.ir().geoms().iter().zip(art.mixed().layers()).enumerate() {
        let k = geom.eq2_k();
        if k < 2 {
            continue;
        }
        let need = DecodeLut::shared(spec).quire_bits_needed(k);
        if need > QUIRE_BITS_LIMIT {
            let msg = format!(
                "layer {li} ({}) under {}: Eq. (2) quire needs {need} bits for k={k} (> {QUIRE_BITS_LIMIT}) — compile would abort",
                geom.node_name(),
                spec.name(),
            );
            findings.push(Finding::new(rel, 1, LintRule::ArtifactQuireOverflow, msg));
        }
    }
    findings
}

/// Audit one dumped obs snapshot (`*.obs.json`) against the strict
/// exporter codec: pinned schema version, exact key sets at every level,
/// and p50 ≤ p95 ≤ p99 quantile monotonicity per shard.
pub fn audit_obs_snapshot(rel: &str, text: &str) -> Vec<Finding> {
    match ObsSnapshot::from_json(text) {
        Ok(_) => Vec::new(),
        Err(e) => vec![Finding::new(rel, 1, LintRule::ObsSnapshotInvalid, e)],
    }
}

/// Audit one dumped flight-recorder trace (`*.trace.jsonl`) against the
/// strict recorder codec: header schema/kind pin, exact per-event key set,
/// and the `queue + compute + reply == total` phase-sum invariant (the
/// codec's error message carries the offending line number).
pub fn audit_trace_dump(rel: &str, text: &str) -> Vec<Finding> {
    match parse_dump(text) {
        Ok(_) => Vec::new(),
        Err(e) => vec![Finding::new(rel, 1, LintRule::ObsTraceInvalid, e)],
    }
}

/// Read `rel` under `root`, pushing an [`LintRule::BenchUnwired`] finding
/// when the file that anchors bench wiring is missing entirely.
fn read_or_finding(root: &Path, rel: &str, findings: &mut Vec<Finding>) -> Option<String> {
    match std::fs::read_to_string(root.join(rel)) {
        Ok(text) => Some(text),
        Err(e) => {
            findings.push(Finding::new(rel, 1, LintRule::BenchUnwired, format!("unreadable: {e}")));
            None
        }
    }
}

/// Sorted file names (not paths) of a directory; empty when unreadable.
fn sorted_dir(dir: &Path) -> Vec<String> {
    let mut names: Vec<String> = std::fs::read_dir(dir)
        .map(|rd| rd.filter_map(|e| e.ok().map(|e| e.file_name().to_string_lossy().into_owned())).collect())
        .unwrap_or_default();
    names.sort();
    names
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD_PLAN: &str = "dataset=iris\ndims=4,10,3\nir=4:dense10+dense3\nlayers=posit8es1+posit7es1\naccuracy=0.95\nfeasible=true\npruned=sensitivity drop<=5.0% floors=6,5 screen_rows=32\n";

    #[test]
    fn a_good_plan_is_clean() {
        let fs = audit_plan("p.plan", GOOD_PLAN);
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn plan_findings_are_granular_and_line_anchored() {
        let bad = GOOD_PLAN.replace("accuracy=0.95", "accuracy=1.7");
        let fs = audit_plan("p.plan", &bad);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, LintRule::PlanInvalid);
        assert_eq!(fs[0].line, 5);

        let bad = GOOD_PLAN.replace("ir=4:dense10+dense3", "ir=4:dense10+conv3k2x2s1");
        let fs = audit_plan("p.plan", &bad);
        assert!(fs.iter().any(|f| f.rule == LintRule::PlanInvalid && f.line == 3), "{fs:?}");

        let bad = GOOD_PLAN.replace("floors=6,5", "floors=six");
        let fs = audit_plan("p.plan", &bad);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, LintRule::PlanBadProvenance);
        assert_eq!(fs[0].line, 7);
    }

    #[test]
    fn quire_overflow_is_recomputed_from_the_ir_line() {
        let plan =
            "dataset=synth\ndims=100000,10\nir=100000:dense10\nlayers=posit16es1\naccuracy=0.9\nfeasible=true\n";
        let fs = audit_plan("p.plan", plan);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, LintRule::PlanQuireOverflow);
        assert!(fs[0].message.contains("posit16es1"), "{}", fs[0].message);
        // The same topology under a narrow format fits comfortably.
        let ok = plan.replace("posit16es1", "posit8es1");
        assert!(audit_plan("p.plan", &ok).is_empty());
    }

    #[test]
    fn bench_json_audit_catches_mismatch_and_duplicates() {
        let mut log = BenchLog::new("ghost");
        log.push("a", 1.0).unwrap();
        let fs = audit_bench_json("BENCH_real.json", "BENCH_real.json", &log.to_json());
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert!(fs[0].message.contains("disagrees with filename"), "{}", fs[0].message);

        let mut dup = BenchLog::new("real");
        dup.push("a", 1.0).unwrap();
        dup.push("a", 2.0).unwrap();
        let fs = audit_bench_json("BENCH_real.json", "BENCH_real.json", &dup.to_json());
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert!(fs[0].message.contains("duplicate entry"), "{}", fs[0].message);

        let fs = audit_bench_json("BENCH_real.json", "BENCH_real.json", "{not json");
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, LintRule::BenchLogInvalid);
    }

    #[test]
    fn cargo_bench_names_reads_only_bench_sections() {
        let cargo = "[package]\nname = \"x\"\n\n[[test]]\nname = \"serve\"\n\n[[bench]]\nname = \"batch\"\npath = \"rust/benches/batch.rs\"\n";
        assert_eq!(cargo_bench_names(cargo), vec!["batch".to_string()]);
    }

    #[test]
    fn obs_artifact_audits_delegate_to_the_strict_codecs() {
        let good_snap = ObsSnapshot::default().to_json();
        assert!(audit_obs_snapshot("s.obs.json", &good_snap).is_empty());
        let bad_schema = good_snap.replace("\"schema\": 1", "\"schema\": 99");
        let fs = audit_obs_snapshot("s.obs.json", &bad_schema);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, LintRule::ObsSnapshotInvalid);

        let good_trace = "{\"schema\":1,\"kind\":\"deep-positron-trace\"}\n{\"trace\":1,\"shard\":\"a/b\",\
                          \"worker\":0,\"rows\":2,\"queue_ns\":10,\"compute_ns\":20,\"reply_ns\":30,\
                          \"total_ns\":60}\n";
        assert!(audit_trace_dump("t.trace.jsonl", good_trace).is_empty());
        let broken = good_trace.replace("\"total_ns\":60", "\"total_ns\":61");
        let fs = audit_trace_dump("t.trace.jsonl", &broken);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, LintRule::ObsTraceInvalid);
        assert!(fs[0].message.contains("phase sum"), "{}", fs[0].message);
    }

    #[test]
    fn artifact_audit_delegates_and_rederives_quire() {
        use crate::accel::{DeepPositron, Mlp};
        use crate::formats::pack::crc32;
        use crate::util::Rng;
        let mlp = Mlp::new(&[4, 6, 3], &mut Rng::new(3));
        let dp = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 });
        let good = Artifact::from_network("iris", &dp).to_text();
        assert!(audit_artifact("m.dpz", &good).is_empty());

        // Corrupted trailing checksum: a framing finding, not a quire one.
        let bad = format!("{}crc=00000000\n", good.rsplit_once("crc=").unwrap().0);
        let fs = audit_artifact("m.dpz", &bad);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, LintRule::ArtifactInvalid);
        assert!(fs[0].message.contains("crc"), "{}", fs[0].message);

        // Header-only overflow: rejected from the ir=/layers= lines alone,
        // no payload needed — the same bound the plan auditor applies.
        let body = "deep-positron dpz v1\ndataset=synth\nir=100000:dense10\nlayers=posit16es1\n";
        let sealed = format!("{body}crc={:08x}\n", crc32(body.as_bytes()));
        let fs = audit_artifact("m.dpz", &sealed);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, LintRule::ArtifactQuireOverflow);
        assert!(fs[0].message.contains("quire"), "{}", fs[0].message);
    }

    #[test]
    fn provenance_grammar_round_trips_the_emitter() {
        assert!(check_provenance("sensitivity drop<=2.5% floors=8,6,5 screen_rows=128").is_ok());
        for bad in [
            "sensitivity drop<=x% floors=6 screen_rows=1",
            "drop<=1.0% floors=6 screen_rows=1",
            "sensitivity drop<=1.0% floors= screen_rows=1",
            "sensitivity drop<=1.0% floors=6",
        ] {
            assert!(check_provenance(bad).is_err(), "{bad}");
        }
    }
}
