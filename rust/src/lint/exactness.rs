//! Layer 1 of `repro lint`: the token-level exactness scan.
//!
//! Files are classified into zones by [`super::classify`]: the quire
//! accumulation paths (`formats::emac`, `accel::positron`) ban float
//! arithmetic, float literals and `as f64`/`to_f64` casts; the serve
//! request path bans `panic!`/`unwrap`/`expect`; `unsafe` is banned
//! everywhere outside the allowlist (`util::pool`). Declared boundaries are
//! annotated in source:
//!
//! ```text
//! // exact-lint: allow(float, terminal readout rounds once by design)
//! ```
//!
//! A *trailing* annotation (code on the same line) covers that line only. A
//! *standalone* annotation line at brace depth `D` covers the following
//! code lines until the first covered line whose end depth returns to `<=
//! D` — i.e. the next item or block. The reason is mandatory; an
//! annotation without one is itself a finding. `#[cfg(test)] mod` blocks
//! are skipped: tests may use floats freely to state expectations.

use super::lexer::{self, CodeLine};
use super::{Finding, LintRule, Zone};

/// Which ban an `exact-lint: allow(...)` annotation lifts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllowKind {
    /// Float types, literals and conversions in an exact zone.
    Float,
    /// An `unsafe` block or fn outside the allowlist.
    Unsafe,
    /// `panic!`/`unwrap`/`expect` on the serve request path.
    Panic,
}

/// A standalone annotation waiting for (or covering) a code block.
struct BlockAllow {
    kind: AllowKind,
    depth: i32,
    armed: bool,
}

/// Scan one file's source under its zone classification.
pub fn scan_file(rel: &str, src: &str, zone: Zone) -> Vec<Finding> {
    let lines = lexer::scan(src);
    let mut findings = Vec::new();
    let mut pending_test_attr = false;
    let mut test_skip: Option<i32> = None;
    let mut block_allows: Vec<BlockAllow> = Vec::new();

    for cl in &lines {
        let has_code = cl.has_code();
        let mut line_allows: Vec<AllowKind> = Vec::new();

        if let Some(comment) = &cl.comment {
            match parse_allow(comment) {
                None => {}
                Some(Err(msg)) => {
                    findings.push(Finding::new(rel, cl.line, LintRule::BadAnnotation, msg));
                }
                Some(Ok(kind)) => {
                    if has_code {
                        line_allows.push(kind);
                    } else {
                        block_allows.push(BlockAllow { kind, depth: cl.depth_start, armed: false });
                    }
                }
            }
        }

        // `#[cfg(test)] mod …` blocks are exempt from every token rule.
        if let Some(d) = test_skip {
            if has_code && cl.depth_end <= d {
                test_skip = None;
            }
            continue;
        }
        if has_code {
            let trimmed = cl.code.trim();
            if trimmed.contains("#[cfg(test)]") {
                pending_test_attr = true;
            }
            if pending_test_attr && lexer::has_word(&cl.code, "mod") {
                pending_test_attr = false;
                if cl.depth_end > cl.depth_start {
                    test_skip = Some(cl.depth_start);
                }
                continue;
            }
            if pending_test_attr && !trimmed.starts_with('#') && !trimmed.contains("#[cfg(test)]") {
                pending_test_attr = false;
            }
        }

        // Arm standalone annotations on the first code line they cover.
        if has_code {
            for allow in &mut block_allows {
                allow.armed = true;
            }
        }
        let allowed = |kind: AllowKind| {
            line_allows.contains(&kind) || block_allows.iter().any(|a| a.armed && a.kind == kind)
        };

        if has_code {
            if zone.exact && !allowed(AllowKind::Float) {
                if let Some((col, what)) = float_token(&cl.code) {
                    let msg = format!("{what} in exact zone (col {}) — quire paths are integer-only", col + 1);
                    findings.push(Finding::new(rel, cl.line, LintRule::FloatInExactZone, msg));
                }
            }
            if !zone.unsafe_ok && !allowed(AllowKind::Unsafe) && lexer::has_word(&cl.code, "unsafe") {
                let msg = "`unsafe` outside the allowlist (util::pool is the only allowlisted module)".to_string();
                findings.push(Finding::new(rel, cl.line, LintRule::UnsafeOutsideAllowlist, msg));
            }
            if zone.serve && !allowed(AllowKind::Panic) {
                if let Some(what) = panic_token(&cl.code) {
                    let msg = format!("{what} on the serve request path — shed load, never abort the worker");
                    findings.push(Finding::new(rel, cl.line, LintRule::PanicOnServePath, msg));
                }
            }
        }

        // A covered code line that closes back to the annotation's depth
        // ends that annotation's coverage (it covers itself first).
        if has_code {
            block_allows.retain(|a| !(a.armed && cl.depth_end <= a.depth));
        }
    }
    findings
}

/// Parse an `exact-lint:` annotation out of a line comment. Returns `None`
/// when the comment is not an annotation at all, `Some(Err)` when it is one
/// but malformed (unknown rule, missing reason, bad syntax). Only comments
/// that *begin* with `exact-lint:` count — prose that merely mentions the
/// grammar (docs, examples) is not an annotation.
pub fn parse_allow(comment: &str) -> Option<Result<AllowKind, String>> {
    let rest = comment.strip_prefix("exact-lint:")?.trim();
    let Some(body) = rest.strip_prefix("allow(") else {
        return Some(Err(format!("expected `allow(<rule>, <reason>)` after `exact-lint:`, got `{rest}`")));
    };
    let Some(body) = body.strip_suffix(')') else {
        return Some(Err("annotation is missing its closing `)`".to_string()));
    };
    let (rule, reason) = match body.split_once(',') {
        Some((r, reason)) => (r.trim(), reason.trim()),
        None => (body.trim(), ""),
    };
    let kind = match rule {
        "float" => AllowKind::Float,
        "unsafe" => AllowKind::Unsafe,
        "panic" => AllowKind::Panic,
        other => {
            return Some(Err(format!("unknown exact-lint rule `{other}` (expected float, unsafe or panic)")));
        }
    };
    if reason.is_empty() {
        return Some(Err(format!("exact-lint allow({rule}) has no reason — boundaries must say why")));
    }
    Some(Ok(kind))
}

/// First float token on a stripped code line: a float-typed word, a
/// float-returning conversion, or a float literal.
fn float_token(code: &str) -> Option<(usize, &'static str)> {
    let words: [(&str, &'static str); 4] = [
        ("f64", "`f64`"),
        ("f32", "`f32`"),
        ("to_f64", "`to_f64` conversion"),
        ("from_f64", "`from_f64` conversion"),
    ];
    let mut best: Option<(usize, &'static str)> = None;
    for (w, label) in words {
        if let Some(col) = lexer::word_at(code, w) {
            if best.is_none_or(|(b, _)| col < b) {
                best = Some((col, label));
            }
        }
    }
    if let Some(col) = lexer::float_literal_at(code) {
        if best.is_none_or(|(b, _)| col < b) {
            best = Some((col, "float literal"));
        }
    }
    best
}

/// First panicking token on a stripped code line.
fn panic_token(code: &str) -> Option<&'static str> {
    for (mac, label) in [
        ("panic", "`panic!`"),
        ("unreachable", "`unreachable!`"),
        ("todo", "`todo!`"),
        ("unimplemented", "`unimplemented!`"),
    ] {
        if let Some(col) = lexer::word_at(code, mac) {
            if code[col + mac.len()..].starts_with('!') {
                return Some(label);
            }
        }
    }
    for (m, label) in [("unwrap", "`.unwrap()`"), ("expect", "`.expect()`")] {
        if let Some(col) = lexer::word_at(code, m) {
            if col > 0 && code.as_bytes()[col - 1] == b'.' {
                return Some(label);
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXACT: Zone = Zone { exact: true, serve: false, unsafe_ok: false };
    const SERVE: Zone = Zone { exact: false, serve: true, unsafe_ok: false };
    const PLAIN: Zone = Zone { exact: false, serve: false, unsafe_ok: false };

    fn rules(findings: &[Finding]) -> Vec<LintRule> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn float_cast_in_exact_zone_is_flagged() {
        let src = "fn f(k: usize) -> i128 {\n    let w = k as f64;\n    w as i128\n}\n";
        let fs = scan_file("z.rs", src, EXACT);
        assert_eq!(rules(&fs), vec![LintRule::FloatInExactZone]);
        assert_eq!(fs[0].line, 2);
    }

    #[test]
    fn trailing_allow_covers_only_its_line() {
        let src = "fn f(x: f64) -> u16 { // exact-lint: allow(float, boundary signature)\n    let y = 1.5;\n    0\n}\n";
        let fs = scan_file("z.rs", src, EXACT);
        assert_eq!(rules(&fs), vec![LintRule::FloatInExactZone]);
        assert_eq!(fs[0].line, 2, "body line is not covered by the trailing allow");
    }

    #[test]
    fn standalone_allow_covers_the_following_block() {
        let src = "// exact-lint: allow(float, dequantized readout is float by contract)\nfn readout(q: i128) -> f64 {\n    q as f64 * 0.5\n}\nfn next() -> f64 { 0.0 }\n";
        let fs = scan_file("z.rs", src, EXACT);
        assert_eq!(rules(&fs), vec![LintRule::FloatInExactZone]);
        assert_eq!(fs[0].line, 5, "coverage ends with the annotated block");
    }

    #[test]
    fn blank_and_comment_lines_do_not_end_block_coverage() {
        let src = "// exact-lint: allow(float, readout)\n\n// explains the fn\nfn readout(q: i128) -> f64 {\n    q as f64\n}\n";
        let fs = scan_file("z.rs", src, EXACT);
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn annotation_without_reason_is_a_finding() {
        let src = "// exact-lint: allow(float)\nfn f() {}\n";
        let fs = scan_file("z.rs", src, PLAIN);
        assert_eq!(rules(&fs), vec![LintRule::BadAnnotation]);
    }

    #[test]
    fn annotation_with_unknown_rule_is_a_finding() {
        let src = "let x = 0; // exact-lint: allow(everything, because)\n";
        let fs = scan_file("z.rs", src, PLAIN);
        assert_eq!(rules(&fs), vec![LintRule::BadAnnotation]);
    }

    #[test]
    fn cfg_test_modules_are_exempt() {
        let src = "fn f() -> u32 { 1 }\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let x = 1.5_f64;\n        assert!(x.is_finite());\n    }\n}\n";
        assert!(scan_file("z.rs", src, EXACT).is_empty());
    }

    #[test]
    fn code_after_a_test_module_is_scanned_again() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { let x = 1.5; }\n}\nfn after() { let y = 2.5; }\n";
        let fs = scan_file("z.rs", src, EXACT);
        assert_eq!(rules(&fs), vec![LintRule::FloatInExactZone]);
        assert_eq!(fs[0].line, 5);
    }

    #[test]
    fn unsafe_is_flagged_outside_the_allowlist() {
        let src = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        let fs = scan_file("z.rs", src, PLAIN);
        assert_eq!(rules(&fs), vec![LintRule::UnsafeOutsideAllowlist]);
        let ok = Zone { unsafe_ok: true, ..PLAIN };
        assert!(scan_file("z.rs", src, ok).is_empty());
    }

    #[test]
    fn serve_path_panics_are_flagged_and_allowable() {
        let src = "fn f(m: &Mutex<u32>) {\n    *m.lock().unwrap() += 1;\n    let _ = m.lock().unwrap(); // exact-lint: allow(panic, poisoned lock means a worker already died)\n}\n";
        let fs = scan_file("z.rs", src, SERVE);
        assert_eq!(rules(&fs), vec![LintRule::PanicOnServePath]);
        assert_eq!(fs[0].line, 2);
    }

    #[test]
    fn doc_comment_mentions_never_count() {
        let src = "/// Never `panic!`s; 1.5x faster than `unsafe` f64 paths.\nfn f() -> u32 { 0 }\n";
        let fs = scan_file("z.rs", src, Zone { exact: true, serve: true, unsafe_ok: false });
        assert!(fs.is_empty(), "{fs:?}");
    }
}
