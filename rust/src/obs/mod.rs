//! Observability for the serving + tuning stack (DESIGN.md §15).
//!
//! Three pillars, all zero-dependency and bounded-memory:
//!
//! * [`hist`] — lock-free log-linear latency histograms: fixed atomic
//!   buckets, mergeable, with nearest-rank quantile extraction consistent
//!   with `util::stats::percentile`. These replace the serving engine's
//!   unbounded latency sample vectors and its `Mutex<ShardMetrics>` hot-path
//!   locks (see `serve::ShardStats`).
//! * [`recorder`] — per-request trace ids and the fixed-capacity
//!   flight-recorder ring buffer: each served request leaves a per-phase
//!   nanosecond breakdown (queue → compute → reply, telescoping exactly to
//!   the end-to-end total), and the ring dumps itself as a strict-schema
//!   JSONL snapshot when shed/expired counters spike. [`timing`] adds
//!   optional (`obs-layer-timing` feature) per-layer kernel attribution.
//! * [`export`] — the snapshot exporter: engine + pool + tuner + LUT-cache
//!   counters rendered as versioned strict JSON and Prometheus-style text,
//!   via `ServeEngine::observe()` and `repro serve --obs-out FILE`.
//!
//! This module is deliberately outside the serve-path lint zone: everything
//! it is handed is already counted, and every lock it takes (the recorder
//! ring) is poison-tolerant — an observer never becomes a failure source.

pub mod export;
pub mod hist;
pub mod recorder;
pub mod timing;

pub use export::{ObsSnapshot, OBS_SCHEMA_VERSION};
pub use hist::{HistSnapshot, LogHistogram};
pub use recorder::{FlightRecorder, TraceEvent, TraceId, TRACE_SCHEMA_VERSION};
