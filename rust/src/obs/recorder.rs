//! Request tracing and the flight-recorder ring buffer (DESIGN.md §15).
//!
//! Every accepted request carries a process-unique [`TraceId`] from
//! `ServeEngine::submit*` through admission, the batcher's deadline heap,
//! flush, batched EMAC execution, and the reply send. The worker records one
//! [`TraceEvent`] per served request — a per-phase nanosecond breakdown whose
//! phases telescope exactly (`queue + compute + reply == total` by
//! construction, because all four are differences of the same monotonic
//! anchor instants) — into a fixed-capacity [`FlightRecorder`] ring.
//!
//! The ring holds the most recent `capacity` events and never allocates past
//! it. When the engine's shed/expired drop counter crosses an armed
//! threshold (an overload spike — exactly the moment the recent history is
//! worth keeping), the recorder dumps itself once as a JSONL trace snapshot:
//! one strict-schema header line, then one event object per line, written by
//! the same hand-rolled codec family as `util::bench_log` and re-validated
//! by `repro lint`'s artifact audit.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::bench_log::{json_string, Json};

/// Trace dump schema version (bumped on any line-format change).
pub const TRACE_SCHEMA_VERSION: u32 = 1;

/// The `kind` tag every trace dump header carries.
pub const TRACE_KIND: &str = "deep-positron-trace";

static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);

/// A process-unique request identifier, allocated at submit time and
/// threaded through every serving phase to the reply.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TraceId(pub u64);

impl TraceId {
    /// Allocate the next id (a relaxed counter — cheap enough for the
    /// admission hot path).
    pub fn next() -> TraceId {
        TraceId(NEXT_TRACE.fetch_add(1, Ordering::Relaxed))
    }
}

/// One served request's per-phase timing breakdown.
///
/// Invariant (enforced by the codec and the lint audit):
/// `queue_ns + compute_ns + reply_ns == total_ns`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// The request's [`TraceId`].
    pub trace: u64,
    /// Owning shard's key name (e.g. `iris/posit8es0`).
    pub shard: String,
    /// Worker index within the shard.
    pub worker: u64,
    /// Rows in the batch this request was flushed with.
    pub rows: u64,
    /// Submit → batch flush (admission + channel + deadline-heap wait).
    pub queue_ns: u64,
    /// Batch flush → batched EMAC inference complete (shared by the batch).
    pub compute_ns: u64,
    /// Inference complete → this request's reply sent.
    pub reply_ns: u64,
    /// Submit → reply sent (always the exact phase sum).
    pub total_ns: u64,
}

impl TraceEvent {
    /// Render as one JSONL line (no trailing newline).
    pub fn to_line(&self) -> String {
        format!(
            "{{\"trace\":{},\"shard\":{},\"worker\":{},\"rows\":{},\"queue_ns\":{},\"compute_ns\":{},\
             \"reply_ns\":{},\"total_ns\":{}}}",
            self.trace,
            json_string(&self.shard),
            self.worker,
            self.rows,
            self.queue_ns,
            self.compute_ns,
            self.reply_ns,
            self.total_ns
        )
    }

    /// Strict inverse of [`TraceEvent::to_line`]: every key required, no
    /// unknown keys, integers only, and the phase-sum invariant must hold.
    pub fn parse_line(line: &str) -> Result<TraceEvent, String> {
        let fields = parse_object(line)?;
        let mut ev = TraceEvent {
            trace: 0,
            shard: String::new(),
            worker: 0,
            rows: 0,
            queue_ns: 0,
            compute_ns: 0,
            reply_ns: 0,
            total_ns: 0,
        };
        let mut seen = [false; 8];
        for (key, value) in fields {
            let slot = match key.as_str() {
                "trace" => {
                    ev.trace = num_u64(&value, "trace")?;
                    0
                }
                "shard" => {
                    ev.shard = match value {
                        Json::Str(s) => s,
                        _ => return Err("field 'shard' must be a string".into()),
                    };
                    1
                }
                "worker" => {
                    ev.worker = num_u64(&value, "worker")?;
                    2
                }
                "rows" => {
                    ev.rows = num_u64(&value, "rows")?;
                    3
                }
                "queue_ns" => {
                    ev.queue_ns = num_u64(&value, "queue_ns")?;
                    4
                }
                "compute_ns" => {
                    ev.compute_ns = num_u64(&value, "compute_ns")?;
                    5
                }
                "reply_ns" => {
                    ev.reply_ns = num_u64(&value, "reply_ns")?;
                    6
                }
                "total_ns" => {
                    ev.total_ns = num_u64(&value, "total_ns")?;
                    7
                }
                other => return Err(format!("unknown trace field '{other}'")),
            };
            if seen[slot] {
                return Err(format!("duplicate trace field '{key}'"));
            }
            seen[slot] = true;
        }
        if let Some(missing) = seen.iter().position(|&s| !s) {
            const NAMES: [&str; 8] =
                ["trace", "shard", "worker", "rows", "queue_ns", "compute_ns", "reply_ns", "total_ns"];
            return Err(format!("missing trace field '{}'", NAMES[missing]));
        }
        let sum = ev
            .queue_ns
            .checked_add(ev.compute_ns)
            .and_then(|s| s.checked_add(ev.reply_ns))
            .ok_or("phase nanoseconds overflow u64")?;
        if sum != ev.total_ns {
            return Err(format!(
                "phase sum {} (queue {} + compute {} + reply {}) != total_ns {}",
                sum, ev.queue_ns, ev.compute_ns, ev.reply_ns, ev.total_ns
            ));
        }
        if ev.rows == 0 {
            return Err("rows must be >= 1 (an event records a served request)".into());
        }
        Ok(ev)
    }
}

/// Render a full dump: header line, then one line per event.
pub fn dump_to_string(events: &[TraceEvent]) -> String {
    let mut out = format!("{{\"schema\":{TRACE_SCHEMA_VERSION},\"kind\":{}}}\n", json_string(TRACE_KIND));
    for ev in events {
        out.push_str(&ev.to_line());
        out.push('\n');
    }
    out
}

/// Strict inverse of [`dump_to_string`]: validates the header (schema +
/// kind), every event line, and each line's phase-sum invariant. This is
/// what the §14 lint artifact audit calls on committed/dumped traces.
pub fn parse_dump(text: &str) -> Result<Vec<TraceEvent>, String> {
    let mut lines = text.lines();
    let header = lines.next().ok_or("empty trace dump (missing header line)")?;
    let fields = parse_object(header)?;
    let mut schema = None;
    let mut kind = None;
    for (key, value) in fields {
        match key.as_str() {
            "schema" => schema = Some(num_u64(&value, "schema")?),
            "kind" => {
                kind = Some(match value {
                    Json::Str(s) => s,
                    _ => return Err("header 'kind' must be a string".into()),
                })
            }
            other => return Err(format!("unknown header field '{other}'")),
        }
    }
    match schema {
        Some(v) if v == TRACE_SCHEMA_VERSION as u64 => {}
        Some(v) => return Err(format!("unsupported trace schema {v} (expected {TRACE_SCHEMA_VERSION})")),
        None => return Err("header missing 'schema'".into()),
    }
    match kind.as_deref() {
        Some(TRACE_KIND) => {}
        Some(k) => return Err(format!("unexpected trace kind '{k}'")),
        None => return Err("header missing 'kind'".into()),
    }
    let mut events = Vec::new();
    for (i, line) in lines.enumerate() {
        if line.trim().is_empty() {
            return Err(format!("blank line {} inside trace dump", i + 2));
        }
        events.push(TraceEvent::parse_line(line).map_err(|e| format!("line {}: {e}", i + 2))?);
    }
    Ok(events)
}

/// Parse one line as a strict JSON object and return its fields (shared
/// with the `obs::export` snapshot codec).
pub(crate) fn parse_object(line: &str) -> Result<Vec<(String, Json)>, String> {
    match Json::parse(line).map_err(|e| e.to_string())? {
        Json::Obj(fields) => Ok(fields),
        _ => Err("expected a JSON object".into()),
    }
}

/// Require an integral, non-negative, exactly-representable number.
pub(crate) fn num_u64(v: &Json, key: &str) -> Result<u64, String> {
    match v {
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 9_007_199_254_740_992.0 => Ok(*n as u64),
        Json::Num(_) => Err(format!("field '{key}' must be a non-negative integer within 2^53")),
        _ => Err(format!("field '{key}' must be a number")),
    }
}

/// Ring state behind the recorder's single short lock (taken once per
/// flushed batch, off the admission path — see module docs).
struct Ring {
    buf: Vec<Option<TraceEvent>>,
    next: usize,
    total: u64,
}

/// The fixed-capacity flight recorder: keeps the most recent trace events
/// and dumps them as JSONL when the drop counter spikes.
pub struct FlightRecorder {
    inner: Mutex<Ring>,
    capacity: usize,
    drops: AtomicU64,
    dump_threshold: AtomicU64,
    dumped: AtomicBool,
    dump_path: Mutex<Option<PathBuf>>,
}

impl FlightRecorder {
    /// Recorder holding the most recent `capacity` events (min 1).
    pub fn new(capacity: usize) -> FlightRecorder {
        let capacity = capacity.max(1);
        FlightRecorder {
            inner: Mutex::new(Ring { buf: vec![None; capacity], next: 0, total: 0 }),
            capacity,
            drops: AtomicU64::new(0),
            dump_threshold: AtomicU64::new(0),
            dumped: AtomicBool::new(false),
            dump_path: Mutex::new(None),
        }
    }

    /// Maximum events retained.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Record a batch of events (one short lock per flushed batch). A
    /// poisoned lock silently drops the batch — the recorder is an
    /// observer, never a failure source.
    pub fn push_batch(&self, events: &[TraceEvent]) {
        if events.is_empty() {
            return;
        }
        if let Ok(mut ring) = self.inner.lock() {
            for ev in events {
                let slot = ring.next;
                ring.buf[slot] = Some(ev.clone());
                ring.next = (ring.next + 1) % self.capacity;
                ring.total += 1;
            }
        }
    }

    /// Total events ever recorded (recent `capacity` of them retained).
    pub fn total_recorded(&self) -> u64 {
        self.inner.lock().map(|r| r.total).unwrap_or(0)
    }

    /// Retained events, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        let Ok(ring) = self.inner.lock() else {
            return Vec::new();
        };
        let mut out = Vec::with_capacity(self.capacity);
        for i in 0..self.capacity {
            if let Some(ev) = &ring.buf[(ring.next + i) % self.capacity] {
                out.push(ev.clone());
            }
        }
        out
    }

    /// Arm the spike dump: once `threshold` total sheds/expiries have been
    /// noted via [`FlightRecorder::note_drop`], the retained events are
    /// written to `path` exactly once. `threshold` 0 disarms.
    pub fn arm_dump(&self, path: &Path, threshold: u64) {
        if let Ok(mut p) = self.dump_path.lock() {
            *p = Some(path.to_path_buf());
        }
        self.dump_threshold.store(threshold, Ordering::Relaxed);
        self.dumped.store(false, Ordering::Relaxed);
    }

    /// Note one shed or expired request. Called from the serve hot path:
    /// one relaxed `fetch_add`, plus the one-shot dump on the arming
    /// threshold's exact crossing.
    pub fn note_drop(&self) {
        let n = self.drops.fetch_add(1, Ordering::Relaxed) + 1;
        let threshold = self.dump_threshold.load(Ordering::Relaxed);
        if threshold != 0 && n >= threshold && !self.dumped.swap(true, Ordering::Relaxed) {
            let path = self.dump_path.lock().ok().and_then(|p| p.clone());
            if let Some(path) = path {
                let _ = self.dump_to(&path);
            }
        }
    }

    /// Sheds/expiries noted so far.
    pub fn drops(&self) -> u64 {
        self.drops.load(Ordering::Relaxed)
    }

    /// True once the armed spike dump has fired.
    pub fn spike_dumped(&self) -> bool {
        self.dumped.load(Ordering::Relaxed)
    }

    /// Render the retained events as a JSONL dump string.
    pub fn dump_string(&self) -> String {
        dump_to_string(&self.events())
    }

    /// Write the retained events to `path` (manual dump; the CLI calls this
    /// at end of run so every `--obs-out` session leaves a trace).
    pub fn dump_to(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.dump_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(trace: u64, q: u64, c: u64, r: u64) -> TraceEvent {
        TraceEvent {
            trace,
            shard: "iris/posit8es0".into(),
            worker: 0,
            rows: 4,
            queue_ns: q,
            compute_ns: c,
            reply_ns: r,
            total_ns: q + c + r,
        }
    }

    #[test]
    fn line_round_trips() {
        let e = ev(7, 1200, 3400, 56);
        assert_eq!(TraceEvent::parse_line(&e.to_line()).unwrap(), e);
    }

    #[test]
    fn parse_rejects_bad_lines() {
        let good = ev(1, 10, 20, 30);
        let mut broken = good.clone();
        broken.total_ns += 1;
        assert!(TraceEvent::parse_line(&broken.to_line()).unwrap_err().contains("phase sum"));
        assert!(TraceEvent::parse_line("{\"trace\":1}").unwrap_err().contains("missing"));
        let with_extra = good.to_line().replace("\"total_ns\"", "\"junk\":0,\"total_ns\"");
        assert!(TraceEvent::parse_line(&with_extra).unwrap_err().contains("unknown"));
        assert!(TraceEvent::parse_line("{\"trace\":1.5}").is_err());
    }

    #[test]
    fn dump_round_trips_and_checks_header() {
        let events = vec![ev(1, 1, 2, 3), ev(2, 4, 5, 6)];
        let text = dump_to_string(&events);
        assert_eq!(parse_dump(&text).unwrap(), events);
        assert!(parse_dump("").is_err());
        assert!(parse_dump("{\"schema\":99,\"kind\":\"deep-positron-trace\"}\n").is_err());
        assert!(parse_dump("{\"schema\":1,\"kind\":\"other\"}\n").is_err());
    }

    #[test]
    fn ring_keeps_latest_in_order() {
        let rec = FlightRecorder::new(4);
        for i in 0..10 {
            rec.push_batch(&[ev(i, 1, 2, 3)]);
        }
        let kept = rec.events();
        assert_eq!(kept.len(), 4);
        assert_eq!(kept.iter().map(|e| e.trace).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
        assert_eq!(rec.total_recorded(), 10);
    }

    #[test]
    fn spike_dump_fires_once_at_threshold() {
        let dir = std::env::temp_dir().join(format!("obs_rec_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("spike.trace.jsonl");
        let rec = FlightRecorder::new(8);
        rec.push_batch(&[ev(1, 1, 2, 3)]);
        rec.arm_dump(&path, 3);
        rec.note_drop();
        rec.note_drop();
        assert!(!path.exists());
        rec.note_drop();
        assert!(rec.spike_dumped());
        let dumped = parse_dump(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(dumped.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trace_ids_are_unique() {
        let a = TraceId::next();
        let b = TraceId::next();
        assert_ne!(a, b);
    }
}
