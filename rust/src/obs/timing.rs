//! Process-wide per-layer kernel timing aggregation (DESIGN.md §15).
//!
//! The batched EMAC kernel in `accel::positron` carries `cfg`-gated hooks
//! (cargo feature `obs-layer-timing`) that time each `LayerPlan`'s pass over
//! a batch and feed the elapsed nanoseconds here. The aggregation arrays are
//! always compiled — fixed atomic counters, no allocation — so the exporter
//! can render them unconditionally; without the feature they simply stay
//! zero and the snapshot's `layers` section is empty. The hooks themselves
//! are integer-only (`Instant` differences), so enabling them never
//! perturbs the exact zone's arithmetic.
//!
//! Counters aggregate across every compiled network in the process, keyed by
//! layer index; deeper layers than [`MAX_LAYERS`] fold into the last slot.

use std::sync::atomic::{AtomicU64, Ordering};

/// Tracked layer slots (slot `MAX_LAYERS - 1` absorbs any deeper layers).
pub const MAX_LAYERS: usize = 32;

#[allow(clippy::declare_interior_mutable_const)] // const used only as an array initializer
const ZERO: AtomicU64 = AtomicU64::new(0);
static LAYER_NS: [AtomicU64; MAX_LAYERS] = [ZERO; MAX_LAYERS];
static LAYER_CALLS: [AtomicU64; MAX_LAYERS] = [ZERO; MAX_LAYERS];

/// Record one timed pass of layer `layer` taking `ns` nanoseconds.
pub fn record_layer(layer: usize, ns: u64) {
    let slot = layer.min(MAX_LAYERS - 1);
    LAYER_NS[slot].fetch_add(ns, Ordering::Relaxed);
    LAYER_CALLS[slot].fetch_add(1, Ordering::Relaxed);
}

/// Non-zero `(layer, calls, total_ns)` rows, ascending by layer index.
pub fn layer_totals() -> Vec<(usize, u64, u64)> {
    (0..MAX_LAYERS)
        .filter_map(|i| {
            let calls = LAYER_CALLS[i].load(Ordering::Relaxed);
            if calls == 0 {
                None
            } else {
                Some((i, calls, LAYER_NS[i].load(Ordering::Relaxed)))
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_fold_into_slots() {
        // Use high slots other tests won't touch (process-wide statics).
        record_layer(MAX_LAYERS - 2, 100);
        record_layer(MAX_LAYERS - 2, 50);
        record_layer(MAX_LAYERS + 7, 10); // folds into the last slot
        let totals = layer_totals();
        let row = totals.iter().find(|&&(l, _, _)| l == MAX_LAYERS - 2).copied().unwrap();
        assert_eq!(row.1, 2);
        assert_eq!(row.2, 150);
        assert!(totals.iter().any(|&(l, _, _)| l == MAX_LAYERS - 1));
    }
}
