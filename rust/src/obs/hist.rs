//! Lock-free log-linear latency histograms (DESIGN.md §15).
//!
//! A [`LogHistogram`] is a fixed array of atomic `u64` bucket counters over
//! nanosecond values: the first [`SUB_BUCKETS`] buckets are exact (width 1),
//! and every octave above that is split into [`SUB_BUCKETS`] linear
//! sub-buckets, so the recorded value is always within one part in
//! `SUB_BUCKETS` of its bucket's lower bound. Recording is a single relaxed
//! `fetch_add` — no locks, no allocation, O(1) memory no matter how many
//! samples land — and two histograms built from the same sample multiset are
//! bit-identical regardless of thread interleaving, because relaxed integer
//! adds commute.
//!
//! Quantile extraction mirrors `util::stats::percentile`'s ceil-based
//! nearest-rank semantics exactly (`rank = ⌈p/100 · n⌉`, clamped to
//! `[1, n]`): walk the cumulative bucket counts to the bucket holding that
//! rank and report its lower bound. For samples below [`SUB_BUCKETS`]·2 the
//! answer is exact; above that it understates the true sample by at most one
//! bucket's width (relative error ≤ 1/[`SUB_BUCKETS`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// log2 of the linear sub-bucket count per octave.
pub const SUB_BUCKET_BITS: u32 = 4;

/// Linear sub-buckets per octave: bucketing relative error is `1/SUB_BUCKETS`.
pub const SUB_BUCKETS: usize = 1 << SUB_BUCKET_BITS;

/// Total bucket count — covers the full `u64` nanosecond range.
/// `u64::MAX` lands in bucket `(63 - SUB_BUCKET_BITS + 1) · SUB_BUCKETS + (SUB_BUCKETS - 1) = 975`.
pub const NUM_BUCKETS: usize = (64 - SUB_BUCKET_BITS as usize + 1) * SUB_BUCKETS;

/// Bucket index for a nanosecond value (log-linear; monotone in `v`).
pub fn bucket_of(v: u64) -> usize {
    if v < SUB_BUCKETS as u64 {
        v as usize
    } else {
        let h = 63 - v.leading_zeros(); // floor(log2 v) >= SUB_BUCKET_BITS
        let sub = ((v >> (h - SUB_BUCKET_BITS)) as usize) & (SUB_BUCKETS - 1);
        (h - SUB_BUCKET_BITS + 1) as usize * SUB_BUCKETS + sub
    }
}

/// Smallest nanosecond value that lands in bucket `idx` (the value a
/// quantile query reports for that bucket).
pub fn bucket_low(idx: usize) -> u64 {
    if idx < SUB_BUCKETS {
        idx as u64
    } else {
        let h = (idx / SUB_BUCKETS - 1) as u32 + SUB_BUCKET_BITS;
        let sub = (idx % SUB_BUCKETS) as u64;
        (SUB_BUCKETS as u64 + sub) << (h - SUB_BUCKET_BITS)
    }
}

/// Width of bucket `idx` in nanoseconds: every sample in the bucket is within
/// `bucket_width(idx) - 1` of [`bucket_low`]`(idx)`.
pub fn bucket_width(idx: usize) -> u64 {
    if idx < SUB_BUCKETS {
        1
    } else {
        let h = (idx / SUB_BUCKETS - 1) as u32 + SUB_BUCKET_BITS;
        1u64 << (h - SUB_BUCKET_BITS)
    }
}

/// A bounded, mergeable, lock-free latency histogram (see module docs).
///
/// Memory is a fixed ~7.6 KiB of atomic counters regardless of sample count
/// — this is what replaces the serving engine's unbounded `Vec<f64>` latency
/// logs (the ISSUE-9 leak fix).
pub struct LogHistogram {
    buckets: [AtomicU64; NUM_BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)] // const used only as an array initializer
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Self { buckets: [ZERO; NUM_BUCKETS], count: AtomicU64::new(0), sum_ns: AtomicU64::new(0) }
    }

    /// Record one nanosecond sample (relaxed; safe from any thread).
    pub fn record(&self, ns: u64) {
        self.buckets[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record a [`Duration`] sample, saturating at `u64::MAX` nanoseconds.
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Total recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Fold another histogram into this one (bucket-wise add — associative
    /// and commutative, so shard-level merges are order-independent).
    pub fn merge(&self, other: &LogHistogram) {
        for (b, o) in self.buckets.iter().zip(other.buckets.iter()) {
            let n = o.load(Ordering::Relaxed);
            if n != 0 {
                b.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_ns.fetch_add(other.sum_ns.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// An owned point-in-time copy of the counters (the type embedded in
    /// `serve::ShardMetrics` snapshots).
    pub fn snapshot(&self) -> HistSnapshot {
        let counts = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        HistSnapshot {
            counts,
            count: self.count.load(Ordering::Relaxed),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
        }
    }

    /// Nearest-rank quantile in nanoseconds (see module docs); 0 when empty.
    pub fn quantile_ns(&self, p: f64) -> u64 {
        self.snapshot().quantile_ns(p)
    }
}

/// A plain (non-atomic) copy of a [`LogHistogram`]'s counters: `Clone` +
/// `Default` + `PartialEq`, so metric snapshots stay value types.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    counts: Vec<u64>,
    count: u64,
    sum_ns: u64,
}

impl HistSnapshot {
    /// Total recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all recorded nanoseconds (wrapping only past ~584 years of it).
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns
    }

    /// Mean sample in nanoseconds; 0 when empty.
    pub fn mean_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.sum_ns / self.count
        }
    }

    /// Number of bucket slots held (fixed at [`NUM_BUCKETS`] once any sample
    /// has been recorded — the O(1)-memory regression tests key on this).
    pub fn len_buckets(&self) -> usize {
        self.counts.len()
    }

    /// Nearest-rank quantile in nanoseconds, matching
    /// `util::stats::percentile`'s `⌈p/100 · n⌉` rank semantics on the
    /// multiset of bucket lower bounds; 0 when empty.
    pub fn quantile_ns(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil() as u64;
        let rank = rank.clamp(1, self.count);
        let mut seen = 0u64;
        for (idx, &n) in self.counts.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return bucket_low(idx);
            }
        }
        // Unreachable when counts sum to `count`; fall back to the top bucket.
        bucket_low(NUM_BUCKETS - 1)
    }

    /// Nearest-rank quantile in (approximate) seconds; 0.0 when empty.
    pub fn quantile_secs(&self, p: f64) -> f64 {
        self.quantile_ns(p) as f64 * 1e-9
    }

    /// Fold another snapshot into this one (bucket-wise add).
    pub fn merge_from(&mut self, other: &HistSnapshot) {
        if self.counts.len() < other.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (b, &o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *b += o;
        }
        self.count += other.count;
        self.sum_ns = self.sum_ns.wrapping_add(other.sum_ns);
    }

    /// Non-empty `(bucket_low, count)` pairs, ascending — the trace/export
    /// codecs serialize this sparse view.
    pub fn nonzero(&self) -> Vec<(u64, u64)> {
        self.counts.iter().enumerate().filter(|(_, &n)| n != 0).map(|(i, &n)| (bucket_low(i), n)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_inverts() {
        let mut prev = 0usize;
        for v in 0..4096u64 {
            let idx = bucket_of(v);
            assert!(idx >= prev, "index not monotone at {v}");
            prev = idx;
            let low = bucket_low(idx);
            let w = bucket_width(idx);
            assert!(low <= v && v < low + w, "v={v} idx={idx} low={low} w={w}");
        }
        assert_eq!(bucket_of(u64::MAX), NUM_BUCKETS - 1);
    }

    #[test]
    fn small_values_are_exact() {
        let h = LogHistogram::new();
        for v in [0u64, 1, 7, 15, 16, 31] {
            h.record(v);
        }
        // Values below 2·SUB_BUCKETS sit in width-1 buckets: quantiles are exact.
        assert_eq!(h.snapshot().quantile_ns(100.0), 31);
        assert_eq!(h.snapshot().quantile_ns(1.0), 0);
    }

    #[test]
    fn quantile_matches_nearest_rank_on_bucket_lows() {
        let h = LogHistogram::new();
        let samples: Vec<u64> = (0..100).map(|i| (i * 37 + 11) % 5000).collect();
        for &s in &samples {
            h.record(s);
        }
        let snap = h.snapshot();
        let mut lows: Vec<f64> = samples.iter().map(|&s| bucket_low(bucket_of(s)) as f64).collect();
        lows.sort_by(f64::total_cmp);
        for p in [1.0, 25.0, 50.0, 90.0, 99.0, 100.0] {
            let rank = ((p / 100.0) * lows.len() as f64).ceil() as usize;
            let expect = lows[rank.saturating_sub(1).min(lows.len() - 1)] as u64;
            assert_eq!(snap.quantile_ns(p), expect, "p={p}");
        }
    }

    #[test]
    fn merge_adds_counts() {
        let a = LogHistogram::new();
        let b = LogHistogram::new();
        a.record(100);
        b.record(200);
        b.record(300);
        a.merge(&b);
        let snap = a.snapshot();
        assert_eq!(snap.count(), 3);
        assert_eq!(snap.sum_ns(), 600);
    }

    #[test]
    fn empty_histogram_reports_zeroes() {
        let snap = LogHistogram::new().snapshot();
        assert_eq!(snap.quantile_ns(50.0), 0);
        assert_eq!(snap.mean_ns(), 0);
        assert_eq!(HistSnapshot::default().quantile_ns(99.0), 0);
    }
}
