//! The metrics snapshot exporter (DESIGN.md §15).
//!
//! [`ObsSnapshot::collect`] gathers one point-in-time view of the whole
//! stack: per-shard serving counters + latency quantiles (from
//! `serve::ShardMetrics`), worker-pool fan-out counters (`util::pool`),
//! tuner memoization counters (`tune::search`), the shared decode-LUT build
//! counter (`formats::emac::DecodeLut::shared_builds`), and any per-layer
//! kernel timings aggregated by [`crate::obs::timing`]. It renders two
//! ways: versioned strict JSON ([`ObsSnapshot::to_json`] /
//! [`ObsSnapshot::from_json`], the artifact the §14 lint audit re-validates)
//! and Prometheus-style text ([`ObsSnapshot::to_prometheus`]). `repro serve
//! --obs-out FILE` and `ServeEngine::observe()` are the entry points;
//! benches and the tune smoke consume the same schema so perf numbers and
//! their phase breakdown land in one artifact.

use crate::obs::recorder::{num_u64, parse_object};
use crate::obs::timing;
use crate::serve::ShardMetrics;
use crate::util::bench_log::{json_string, Json};

/// Snapshot schema version (bumped on any field change).
pub const OBS_SCHEMA_VERSION: u32 = 1;

/// One shard's exported counters and latency quantiles (nanoseconds).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ShardObs {
    /// Shard label, `dataset/format`.
    pub name: String,
    /// Requests served.
    pub served: u64,
    /// Requests shed at admission.
    pub shed: u64,
    /// Deadline-expired drops.
    pub expired: u64,
    /// Batches executed.
    pub batches: u64,
    /// Largest executed batch.
    pub max_batch: u64,
    /// Workers on the PJRT/XLA fast path.
    pub xla_workers: u64,
    /// Latency samples recorded (== served on a clean shutdown).
    pub samples: u64,
    /// Mean end-to-end latency, ns.
    pub mean_ns: u64,
    /// p50 end-to-end latency, ns (histogram bucket lower bound).
    pub p50_ns: u64,
    /// p95 end-to-end latency, ns.
    pub p95_ns: u64,
    /// p99 end-to-end latency, ns.
    pub p99_ns: u64,
}

/// One layer's aggregated kernel timing row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerObs {
    /// Layer index (see [`crate::obs::timing::MAX_LAYERS`]).
    pub layer: u64,
    /// Timed passes.
    pub calls: u64,
    /// Total nanoseconds across those passes.
    pub total_ns: u64,
}

/// A full observability snapshot (see module docs).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ObsSnapshot {
    /// Per-shard serving counters, in engine shard order.
    pub shards: Vec<ShardObs>,
    /// Jobs submitted through `util::pool::WorkerPool::run`/`run_map`.
    pub pool_jobs: u64,
    /// Thread chunks those jobs were partitioned into.
    pub pool_chunks: u64,
    /// Fan-outs that ran inline on the caller (pool width 1 or single job).
    pub pool_inline: u64,
    /// Tuner evaluator memo hits.
    pub tuner_memo_hits: u64,
    /// Tuner evaluator memo misses (actual evaluations).
    pub tuner_memo_misses: u64,
    /// Candidate evaluations skipped by the §13 sensitivity pruner.
    pub tuner_evals_pruned: u64,
    /// Process-wide shared decode-LUT builds (cache fills).
    pub lut_shared_builds: u64,
    /// Per-layer kernel timings (empty unless the `obs-layer-timing`
    /// feature compiled the hooks in).
    pub layers: Vec<LayerObs>,
}

impl ObsSnapshot {
    /// Collect a snapshot from shard metric snapshots plus the process-wide
    /// pool / tuner / LUT / layer-timing counters.
    pub fn collect(shards: &[ShardMetrics]) -> ObsSnapshot {
        let (pool_jobs, pool_chunks, pool_inline) = crate::util::pool::fanout_counters();
        let (tuner_memo_hits, tuner_memo_misses, tuner_evals_pruned) = crate::tune::search::memo_counters();
        ObsSnapshot {
            shards: shards.iter().map(shard_obs).collect(),
            pool_jobs,
            pool_chunks,
            pool_inline,
            tuner_memo_hits,
            tuner_memo_misses,
            tuner_evals_pruned,
            lut_shared_builds: crate::formats::emac::DecodeLut::shared_builds() as u64,
            layers: timing::layer_totals()
                .into_iter()
                .map(|(layer, calls, total_ns)| LayerObs { layer: layer as u64, calls, total_ns })
                .collect(),
        }
    }

    /// Render as canonical, versioned JSON (strict inverse:
    /// [`ObsSnapshot::from_json`]).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"schema\": {OBS_SCHEMA_VERSION},\n"));
        out.push_str("  \"shards\": [");
        for (i, s) in self.shards.iter().enumerate() {
            let sep = if i + 1 < self.shards.len() { "," } else { "" };
            out.push_str(&format!(
                "\n    {{\"name\": {}, \"served\": {}, \"shed\": {}, \"expired\": {}, \"batches\": {}, \
                 \"max_batch\": {}, \"xla_workers\": {}, \"samples\": {}, \"mean_ns\": {}, \"p50_ns\": {}, \
                 \"p95_ns\": {}, \"p99_ns\": {}}}{sep}",
                json_string(&s.name),
                s.served,
                s.shed,
                s.expired,
                s.batches,
                s.max_batch,
                s.xla_workers,
                s.samples,
                s.mean_ns,
                s.p50_ns,
                s.p95_ns,
                s.p99_ns
            ));
        }
        out.push_str(if self.shards.is_empty() { "],\n" } else { "\n  ],\n" });
        out.push_str(&format!(
            "  \"pool\": {{\"jobs\": {}, \"chunks\": {}, \"inline_runs\": {}}},\n",
            self.pool_jobs, self.pool_chunks, self.pool_inline
        ));
        out.push_str(&format!(
            "  \"tuner\": {{\"memo_hits\": {}, \"memo_misses\": {}, \"evals_pruned\": {}}},\n",
            self.tuner_memo_hits, self.tuner_memo_misses, self.tuner_evals_pruned
        ));
        out.push_str(&format!("  \"lut_shared_builds\": {},\n", self.lut_shared_builds));
        out.push_str("  \"layers\": [");
        for (i, l) in self.layers.iter().enumerate() {
            let sep = if i + 1 < self.layers.len() { "," } else { "" };
            out.push_str(&format!(
                "\n    {{\"layer\": {}, \"calls\": {}, \"total_ns\": {}}}{sep}",
                l.layer, l.calls, l.total_ns
            ));
        }
        out.push_str(if self.layers.is_empty() { "]\n" } else { "\n  ]\n" });
        out.push_str("}\n");
        out
    }

    /// Strict parse: exact key set at every level, integers only, schema
    /// version pinned, and quantiles monotone (`p50 ≤ p95 ≤ p99`). Used by
    /// the lint artifact audit on dumped/committed `*.obs.json`.
    pub fn from_json(text: &str) -> Result<ObsSnapshot, String> {
        let fields = parse_object(text)?;
        let mut snap = ObsSnapshot::default();
        let mut seen_schema = false;
        let mut seen = [false; 5];
        for (key, value) in fields {
            match key.as_str() {
                "schema" => {
                    let v = num_u64(&value, "schema")?;
                    if v != OBS_SCHEMA_VERSION as u64 {
                        return Err(format!("unsupported obs schema {v} (expected {OBS_SCHEMA_VERSION})"));
                    }
                    seen_schema = true;
                }
                "shards" => {
                    let Json::Arr(items) = value else {
                        return Err("'shards' must be an array".into());
                    };
                    for item in items {
                        snap.shards.push(parse_shard(item)?);
                    }
                    seen[0] = true;
                }
                "pool" => {
                    let [jobs, chunks, inline_runs] =
                        nested_counters(value, "pool", ["jobs", "chunks", "inline_runs"])?;
                    snap.pool_jobs = jobs;
                    snap.pool_chunks = chunks;
                    snap.pool_inline = inline_runs;
                    seen[1] = true;
                }
                "tuner" => {
                    let [hits, misses, pruned] =
                        nested_counters(value, "tuner", ["memo_hits", "memo_misses", "evals_pruned"])?;
                    snap.tuner_memo_hits = hits;
                    snap.tuner_memo_misses = misses;
                    snap.tuner_evals_pruned = pruned;
                    seen[2] = true;
                }
                "lut_shared_builds" => {
                    snap.lut_shared_builds = num_u64(&value, "lut_shared_builds")?;
                    seen[3] = true;
                }
                "layers" => {
                    let Json::Arr(items) = value else {
                        return Err("'layers' must be an array".into());
                    };
                    for item in items {
                        snap.layers.push(parse_layer(item)?);
                    }
                    seen[4] = true;
                }
                other => return Err(format!("unknown obs field '{other}'")),
            }
        }
        if !seen_schema {
            return Err("missing 'schema'".into());
        }
        const NAMES: [&str; 5] = ["shards", "pool", "tuner", "lut_shared_builds", "layers"];
        if let Some(missing) = seen.iter().position(|&s| !s) {
            return Err(format!("missing obs field '{}'", NAMES[missing]));
        }
        Ok(snap)
    }

    /// Render as Prometheus-style exposition text (counters and gauges with
    /// `shard`/`quantile`/`layer` labels).
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (metric, help, pick) in SHARD_COUNTERS {
            out.push_str(&format!("# HELP {metric} {help}\n# TYPE {metric} counter\n"));
            for s in &self.shards {
                out.push_str(&format!("{metric}{{shard={}}} {}\n", json_string(&s.name), pick(s)));
            }
        }
        out.push_str(
            "# HELP deep_positron_latency_ns End-to-end latency quantiles (histogram bucket lower bounds).\n\
             # TYPE deep_positron_latency_ns gauge\n",
        );
        for s in &self.shards {
            for (q, v) in [("0.5", s.p50_ns), ("0.95", s.p95_ns), ("0.99", s.p99_ns)] {
                out.push_str(&format!(
                    "deep_positron_latency_ns{{shard={},quantile=\"{q}\"}} {v}\n",
                    json_string(&s.name)
                ));
            }
        }
        for (metric, help, v) in [
            ("deep_positron_pool_jobs", "Jobs submitted to the worker pool.", self.pool_jobs),
            ("deep_positron_pool_chunks", "Thread chunks the pool fanned jobs into.", self.pool_chunks),
            ("deep_positron_pool_inline_runs", "Pool fan-outs that ran inline.", self.pool_inline),
            ("deep_positron_tuner_memo_hits", "Tuner evaluator memo hits.", self.tuner_memo_hits),
            ("deep_positron_tuner_memo_misses", "Tuner evaluator memo misses.", self.tuner_memo_misses),
            ("deep_positron_tuner_evals_pruned", "Tuner evaluations skipped by pruning.", self.tuner_evals_pruned),
            ("deep_positron_lut_shared_builds", "Shared decode-LUT cache fills.", self.lut_shared_builds),
        ] {
            out.push_str(&format!("# HELP {metric} {help}\n# TYPE {metric} counter\n{metric} {v}\n"));
        }
        if !self.layers.is_empty() {
            out.push_str(
                "# HELP deep_positron_layer_ns Batched-kernel time per layer (obs-layer-timing feature).\n\
                 # TYPE deep_positron_layer_ns counter\n",
            );
            for l in &self.layers {
                out.push_str(&format!("deep_positron_layer_ns{{layer=\"{}\"}} {}\n", l.layer, l.total_ns));
            }
        }
        out
    }
}

type ShardPick = fn(&ShardObs) -> u64;
const SHARD_COUNTERS: [(&str, &str, ShardPick); 6] = [
    ("deep_positron_served_total", "Requests served.", |s| s.served),
    ("deep_positron_shed_total", "Requests shed at admission.", |s| s.shed),
    ("deep_positron_expired_total", "Deadline-expired drops.", |s| s.expired),
    ("deep_positron_batches_total", "Batches executed.", |s| s.batches),
    ("deep_positron_xla_workers", "Workers on the XLA fast path.", |s| s.xla_workers),
    ("deep_positron_latency_samples", "Latency samples recorded.", |s| s.samples),
];

fn shard_obs(m: &ShardMetrics) -> ShardObs {
    ShardObs {
        name: m.shard.clone(),
        served: m.served as u64,
        shed: m.shed as u64,
        expired: m.expired as u64,
        batches: m.batches as u64,
        max_batch: m.max_batch as u64,
        xla_workers: m.xla_workers as u64,
        samples: m.latency.count(),
        mean_ns: m.latency.mean_ns(),
        p50_ns: m.latency.quantile_ns(50.0),
        p95_ns: m.latency.quantile_ns(95.0),
        p99_ns: m.latency.quantile_ns(99.0),
    }
}

fn parse_shard(item: Json) -> Result<ShardObs, String> {
    let Json::Obj(fields) = item else {
        return Err("shard entry must be an object".into());
    };
    let mut s = ShardObs::default();
    let mut seen = [false; 12];
    const NAMES: [&str; 12] = [
        "name",
        "served",
        "shed",
        "expired",
        "batches",
        "max_batch",
        "xla_workers",
        "samples",
        "mean_ns",
        "p50_ns",
        "p95_ns",
        "p99_ns",
    ];
    for (key, value) in fields {
        let slot = NAMES
            .iter()
            .position(|n| *n == key.as_str())
            .ok_or_else(|| format!("unknown shard field '{key}'"))?;
        if seen[slot] {
            return Err(format!("duplicate shard field '{key}'"));
        }
        seen[slot] = true;
        if slot == 0 {
            let Json::Str(name) = value else {
                return Err("shard 'name' must be a string".into());
            };
            s.name = name;
        } else {
            let v = num_u64(&value, &key)?;
            match slot {
                1 => s.served = v,
                2 => s.shed = v,
                3 => s.expired = v,
                4 => s.batches = v,
                5 => s.max_batch = v,
                6 => s.xla_workers = v,
                7 => s.samples = v,
                8 => s.mean_ns = v,
                9 => s.p50_ns = v,
                10 => s.p95_ns = v,
                _ => s.p99_ns = v,
            }
        }
    }
    if let Some(missing) = seen.iter().position(|&b| !b) {
        return Err(format!("shard entry missing '{}'", NAMES[missing]));
    }
    if !(s.p50_ns <= s.p95_ns && s.p95_ns <= s.p99_ns) {
        return Err(format!(
            "shard '{}' quantiles not monotone: p50 {} p95 {} p99 {}",
            s.name, s.p50_ns, s.p95_ns, s.p99_ns
        ));
    }
    Ok(s)
}

/// Strict parse of a nested `{a: u64, b: u64, c: u64}` counter object with
/// exactly `keys` (the `pool` / `tuner` sections).
fn nested_counters(value: Json, ctx: &str, keys: [&str; 3]) -> Result<[u64; 3], String> {
    let Json::Obj(fields) = value else {
        return Err(format!("'{ctx}' must be an object"));
    };
    let mut out = [0u64; 3];
    let mut seen = [false; 3];
    for (key, v) in fields {
        let slot = keys
            .iter()
            .position(|k| *k == key.as_str())
            .ok_or_else(|| format!("unknown {ctx} field '{key}'"))?;
        if seen[slot] {
            return Err(format!("duplicate {ctx} field '{key}'"));
        }
        seen[slot] = true;
        out[slot] = num_u64(&v, &key)?;
    }
    if let Some(missing) = seen.iter().position(|&b| !b) {
        return Err(format!("{ctx} missing '{}'", keys[missing]));
    }
    Ok(out)
}

fn parse_layer(item: Json) -> Result<LayerObs, String> {
    let Json::Obj(fields) = item else {
        return Err("layer entry must be an object".into());
    };
    let mut l = LayerObs { layer: 0, calls: 0, total_ns: 0 };
    let mut seen = [false; 3];
    for (key, value) in fields {
        let slot = match key.as_str() {
            "layer" => 0,
            "calls" => 1,
            "total_ns" => 2,
            other => return Err(format!("unknown layer field '{other}'")),
        };
        if seen[slot] {
            return Err(format!("duplicate layer field '{key}'"));
        }
        seen[slot] = true;
        let v = num_u64(&value, &key)?;
        match slot {
            0 => l.layer = v,
            1 => l.calls = v,
            _ => l.total_ns = v,
        }
    }
    if let Some(missing) = seen.iter().position(|&b| !b) {
        const NAMES: [&str; 3] = ["layer", "calls", "total_ns"];
        return Err(format!("layer entry missing '{}'", NAMES[missing]));
    }
    if l.calls == 0 {
        return Err("layer entry with zero calls must be omitted".into());
    }
    Ok(l)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ObsSnapshot {
        ObsSnapshot {
            shards: vec![ShardObs {
                name: "iris/posit8es0".into(),
                served: 10,
                shed: 2,
                expired: 1,
                batches: 3,
                max_batch: 4,
                xla_workers: 0,
                samples: 10,
                mean_ns: 1500,
                p50_ns: 1000,
                p95_ns: 3000,
                p99_ns: 3000,
            }],
            pool_jobs: 7,
            pool_chunks: 3,
            pool_inline: 2,
            tuner_memo_hits: 5,
            tuner_memo_misses: 9,
            tuner_evals_pruned: 4,
            lut_shared_builds: 2,
            layers: vec![LayerObs { layer: 0, calls: 3, total_ns: 900 }],
        }
    }

    #[test]
    fn json_round_trips() {
        let s = sample();
        assert_eq!(ObsSnapshot::from_json(&s.to_json()).unwrap(), s);
        let empty = ObsSnapshot::default();
        assert_eq!(ObsSnapshot::from_json(&empty.to_json()).unwrap(), empty);
    }

    #[test]
    fn parser_is_strict() {
        let s = sample();
        let good = s.to_json();
        assert!(ObsSnapshot::from_json(&good.replace("\"schema\": 1", "\"schema\": 9")).is_err());
        assert!(ObsSnapshot::from_json(&good.replace("\"pool\"", "\"poool\"")).is_err());
        let non_monotone = good.replace("\"p99_ns\": 3000", "\"p99_ns\": 10");
        assert!(ObsSnapshot::from_json(&non_monotone).is_err(), "non-monotone quantiles must be rejected");
        assert!(ObsSnapshot::from_json("{}").is_err());
        assert!(ObsSnapshot::from_json(&good.replace("\"served\": 10, ", "")).is_err());
    }

    #[test]
    fn prometheus_text_has_all_families() {
        let text = sample().to_prometheus();
        for needle in [
            "deep_positron_served_total{shard=\"iris/posit8es0\"} 10",
            "deep_positron_latency_ns{shard=\"iris/posit8es0\",quantile=\"0.99\"} 3000",
            "deep_positron_pool_jobs 7",
            "deep_positron_tuner_memo_hits 5",
            "deep_positron_lut_shared_builds 2",
            "deep_positron_layer_ns{layer=\"0\"} 900",
        ] {
            assert!(text.contains(needle), "missing {needle}:\n{text}");
        }
    }

    #[test]
    fn collect_reads_process_counters() {
        let m = crate::serve::ShardMetrics { shard: "t/x".into(), served: 3, ..Default::default() };
        let snap = ObsSnapshot::collect(&[m]);
        assert_eq!(snap.shards.len(), 1);
        assert_eq!(snap.shards[0].served, 3);
        // Process-wide counters are monotone; collect again and compare.
        let again = ObsSnapshot::collect(&[]);
        assert!(again.pool_jobs >= snap.pool_jobs);
    }
}
