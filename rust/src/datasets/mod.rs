//! The five classification tasks of the paper's Table 1, with deterministic
//! offline generation (see DESIGN.md §Substitutions for the real-vs-synthetic
//! mapping) and the train/test protocol the evaluation uses.

pub mod fashion;
pub mod mnist;
pub mod raster;
pub mod tabular;

use crate::util::Rng;

/// One loaded task: flattened row-major features + integer labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Task name (one of [`ALL`]).
    pub name: String,
    /// Features per row.
    pub num_features: usize,
    /// Distinct class labels.
    pub num_classes: usize,
    /// Training features, row-major.
    pub x_train: Vec<f64>,
    /// Training labels.
    pub y_train: Vec<u32>,
    /// Test features, row-major.
    pub x_test: Vec<f64>,
    /// Test labels.
    pub y_test: Vec<u32>,
}

/// Generation scale for the image tasks (tabular tasks are fixed-size).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Paper-sized evaluation: 10 000 test images (Table 1's
    /// "Inference Size"), 12 000 train.
    Full,
    /// Small smoke-test scale for unit/integration tests.
    Small,
}

impl Scale {
    fn image_sizes(self) -> (usize, usize) {
        match self {
            Scale::Full => (12_000, 10_000),
            Scale::Small => (1_500, 500),
        }
    }
}

/// All dataset names, in the paper's Table 1 order.
pub const ALL: [&str; 5] = ["wdbc", "iris", "mushroom", "mnist", "fashion"];

/// Whether training uses a z-scored view of this task (folded back into the
/// first layer for deployment). True for the tabular tasks, whose features
/// live on wildly different natural scales; image pixels are already [0, 1]
/// and train raw (per-pixel z-scoring explodes folded weights on
/// near-constant border pixels).
pub fn normalizes_for_training(name: &str) -> bool {
    matches!(name, "wdbc" | "iris" | "mushroom")
}

/// The MLP topology used for each task (hidden layers only; input/output
/// widths come from the data). Matches the paper's "three- or four-layer"
/// feedforward networks — see DESIGN.md §6.
pub fn hidden_layers(name: &str) -> Vec<usize> {
    match name {
        "wdbc" => vec![16, 8],
        "iris" => vec![10, 8],
        "mushroom" => vec![32],
        "mnist" | "fashion" => vec![100],
        _ => panic!("unknown dataset {name}"),
    }
}

impl Dataset {
    /// Training rows.
    pub fn train_len(&self) -> usize {
        self.y_train.len()
    }

    /// Test rows.
    pub fn test_len(&self) -> usize {
        self.y_test.len()
    }

    /// One test row.
    pub fn test_row(&self, i: usize) -> &[f64] {
        &self.x_test[i * self.num_features..(i + 1) * self.num_features]
    }

    /// One training row.
    pub fn train_row(&self, i: usize) -> &[f64] {
        &self.x_train[i * self.num_features..(i + 1) * self.num_features]
    }

    /// Per-feature (mean, std) of the training split. Deployment keeps raw
    /// features (Deep Positron quantizes the inputs the network actually
    /// sees — the WDBC dynamic-range stress of Table 1 depends on this);
    /// training normalizes internally and folds the transform back into the
    /// first layer ([`crate::accel::mlp::fold_input_normalization`]).
    pub fn feature_stats(&self) -> (Vec<f64>, Vec<f64>) {
        let f = self.num_features;
        let n = self.train_len();
        let mut means = vec![0.0; f];
        let mut stds = vec![0.0; f];
        for j in 0..f {
            let mut mean = 0.0;
            for i in 0..n {
                mean += self.x_train[i * f + j];
            }
            mean /= n as f64;
            let mut var = 0.0;
            for i in 0..n {
                let d = self.x_train[i * f + j] - mean;
                var += d * d;
            }
            means[j] = mean;
            stds[j] = (var / n as f64).sqrt().max(1e-6);
        }
        (means, stds)
    }

    /// A z-score-normalized copy (training-time view of the task).
    pub fn normalized(&self) -> (Dataset, Vec<f64>, Vec<f64>) {
        let (means, stds) = self.feature_stats();
        let f = self.num_features;
        let mut out = self.clone();
        for (i, v) in out.x_train.iter_mut().enumerate() {
            *v = (*v - means[i % f]) / stds[i % f];
        }
        for (i, v) in out.x_test.iter_mut().enumerate() {
            *v = (*v - means[i % f]) / stds[i % f];
        }
        (out, means, stds)
    }
}

/// Split flattened (x, y) into train/test with a shuffled permutation.
fn split(
    x: Vec<f64>,
    y: Vec<u32>,
    f: usize,
    test_len: usize,
    rng: &mut Rng,
) -> (Vec<f64>, Vec<u32>, Vec<f64>, Vec<u32>) {
    let n = y.len();
    assert!(test_len < n);
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut xtr = Vec::with_capacity((n - test_len) * f);
    let mut ytr = Vec::with_capacity(n - test_len);
    let mut xte = Vec::with_capacity(test_len * f);
    let mut yte = Vec::with_capacity(test_len);
    for (rank, &i) in order.iter().enumerate() {
        let row = &x[i * f..(i + 1) * f];
        if rank < test_len {
            xte.extend_from_slice(row);
            yte.push(y[i]);
        } else {
            xtr.extend_from_slice(row);
            ytr.push(y[i]);
        }
    }
    (xtr, ytr, xte, yte)
}

/// Generate an image task (balanced classes) at the given scale.
fn image_task(name: &str, seed: u64, scale: Scale) -> Dataset {
    let (train_n, test_n) = scale.image_sizes();
    let render: fn(u32, &mut Rng) -> raster::Canvas = match name {
        "mnist" => mnist::render_digit,
        "fashion" => fashion::render_garment,
        _ => unreachable!(),
    };
    let mut make = |count: usize, rng: &mut Rng| -> (Vec<f64>, Vec<u32>) {
        let mut x = Vec::with_capacity(count * raster::PIXELS);
        let mut y = Vec::with_capacity(count);
        for i in 0..count {
            let class = (i % 10) as u32;
            let c = render(class, rng);
            x.extend_from_slice(&c.px);
            y.push(class);
        }
        // Shuffle rows so batches are class-mixed.
        let mut order: Vec<usize> = (0..count).collect();
        rng.shuffle(&mut order);
        let mut xs = Vec::with_capacity(count * raster::PIXELS);
        let mut ys = Vec::with_capacity(count);
        for &i in &order {
            xs.extend_from_slice(&x[i * raster::PIXELS..(i + 1) * raster::PIXELS]);
            ys.push(y[i]);
        }
        (xs, ys)
    };
    let mut rng_train = Rng::new(seed ^ 0xA11CE);
    let mut rng_test = Rng::new(seed ^ 0xB0B);
    let (x_train, y_train) = make(train_n, &mut rng_train);
    let (x_test, y_test) = make(test_n, &mut rng_test);
    Dataset {
        name: name.to_string(),
        num_features: raster::PIXELS,
        num_classes: 10,
        x_train,
        y_train,
        x_test,
        y_test,
    }
}

/// Load a task by name. Deterministic in (name, seed, scale). Test-split
/// sizes for the tabular tasks match Table 1's "Inference Size" column
/// (WDBC 190, Iris 50, Mushroom 2708).
pub fn load(name: &str, seed: u64, scale: Scale) -> Dataset {
    let mut rng = Rng::new(seed ^ fxhash(name));
    let ds = match name {
        "iris" => {
            let (x, y, f) = tabular::iris(&mut rng);
            let (xtr, ytr, xte, yte) = split(x, y, f, 50, &mut rng);
            Dataset {
                name: name.into(),
                num_features: f,
                num_classes: 3,
                x_train: xtr,
                y_train: ytr,
                x_test: xte,
                y_test: yte,
            }
        }
        "wdbc" => {
            let (x, y, f) = tabular::wdbc(&mut rng);
            let (xtr, ytr, xte, yte) = split(x, y, f, 190, &mut rng);
            Dataset {
                name: name.into(),
                num_features: f,
                num_classes: 2,
                x_train: xtr,
                y_train: ytr,
                x_test: xte,
                y_test: yte,
            }
        }
        "mushroom" => {
            let (x, y, f) = tabular::mushroom(&mut rng);
            let (xtr, ytr, xte, yte) = split(x, y, f, 2708, &mut rng);
            Dataset {
                name: name.into(),
                num_features: f,
                num_classes: 2,
                x_train: xtr,
                y_train: ytr,
                x_test: xte,
                y_test: yte,
            }
        }
        "mnist" | "fashion" => return image_task(name, seed, scale),
        _ => panic!("unknown dataset {name}"),
    };
    ds
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tabular_sizes_match_table1() {
        assert_eq!(load("iris", 1, Scale::Small).test_len(), 50);
        assert_eq!(load("wdbc", 1, Scale::Small).test_len(), 190);
        assert_eq!(load("mushroom", 1, Scale::Small).test_len(), 2708);
    }

    #[test]
    fn image_sizes_by_scale() {
        let small = load("mnist", 1, Scale::Small);
        assert_eq!(small.test_len(), 500);
        assert_eq!(small.num_features, 784);
        assert_eq!(small.num_classes, 10);
    }

    #[test]
    fn deterministic_loads() {
        let a = load("iris", 42, Scale::Small);
        let b = load("iris", 42, Scale::Small);
        assert_eq!(a.x_train, b.x_train);
        assert_eq!(a.y_test, b.y_test);
        let c = load("iris", 43, Scale::Small);
        assert_ne!(a.x_train, c.x_train);
    }

    #[test]
    fn normalized_copy_is_zero_mean_unit_var() {
        let ds = load("wdbc", 7, Scale::Small);
        let (norm, means, stds) = ds.normalized();
        assert_eq!(means.len(), 30);
        let f = norm.num_features;
        let n = norm.train_len();
        for j in [0, 15, 29] {
            let mean: f64 = (0..n).map(|i| norm.x_train[i * f + j]).sum::<f64>() / n as f64;
            let var: f64 = (0..n).map(|i| (norm.x_train[i * f + j] - mean).powi(2)).sum::<f64>() / n as f64;
            assert!(mean.abs() < 1e-9);
            assert!((var - 1.0).abs() < 1e-6);
            assert!(stds[j] > 0.0);
        }
    }

    #[test]
    fn wdbc_is_raw_scale_with_wide_dynamic_range() {
        // The Table 1 fixed-point-collapse depends on un-normalized inputs:
        // the feature magnitudes must span several orders of magnitude.
        let ds = load("wdbc", 7, Scale::Small);
        let f = ds.num_features;
        let col_mean = |j: usize| -> f64 {
            (0..ds.train_len()).map(|i| ds.x_train[i * f + j].abs()).sum::<f64>() / ds.train_len() as f64
        };
        let biggest = (0..f).map(col_mean).fold(0.0f64, f64::max);
        let smallest = (0..f).map(col_mean).fold(f64::INFINITY, f64::min);
        assert!(biggest / smallest > 1e3, "dynamic range only {:.1}×", biggest / smallest);
    }

    #[test]
    fn images_stay_in_unit_range() {
        let ds = load("fashion", 3, Scale::Small);
        assert!(ds.x_train.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn hidden_layer_registry_covers_all() {
        for name in ALL {
            assert!(!hidden_layers(name).is_empty());
        }
    }

    #[test]
    fn train_test_label_coverage() {
        for name in ALL {
            let ds = load(name, 9, Scale::Small);
            let classes: std::collections::HashSet<u32> = ds.y_test.iter().copied().collect();
            assert_eq!(classes.len(), ds.num_classes, "{name} test split missing classes");
        }
    }
}
