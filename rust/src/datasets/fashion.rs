//! Procedural Fashion-MNIST-like garment-silhouette task.
//!
//! Ten classes matching Xiao et al.'s label set (t-shirt, trouser, pullover,
//! dress, coat, sandal, shirt, sneaker, bag, ankle boot), rendered as filled
//! silhouettes with jitter. Classes 0/2/4/6 (t-shirt/pullover/coat/shirt)
//! share body shape and differ in sleeves/collar/front-opening details —
//! reproducing the real dataset's confusable upper-wear cluster and its
//! harder (~90%) baseline relative to digits.

use super::raster::Canvas;
use crate::util::Rng;

/// Class labels in Fashion-MNIST order (Xiao et al.).
pub const CLASS_NAMES: [&str; 10] =
    ["t-shirt", "trouser", "pullover", "dress", "coat", "sandal", "shirt", "sneaker", "bag", "ankle-boot"];

/// Render one garment with the given jitter RNG.
pub fn render_garment(class: u32, rng: &mut Rng) -> Canvas {
    let mut c = Canvas::new();
    let ink = rng.range(0.55, 1.0);
    draw_garment(&mut c, class, ink, rng);
    // Heavy jitter: anisotropic "fit" variation + rotation + translation —
    // this is what keeps the upper-wear cluster confusable (~90% MLP
    // ceiling, like the real Fashion-MNIST).
    let mut out = c.affine_aniso(
        rng.range(-0.16, 0.16),
        rng.range(0.72, 1.18),
        rng.range(0.78, 1.15),
        rng.range(-2.2, 2.2),
        rng.range(-2.2, 2.2),
    );
    out.blur(1);
    out.noise(rng, 0.12);
    out.clamp();
    out
}

fn draw_garment(c: &mut Canvas, class: u32, ink: f64, rng: &mut Rng) {
    match class {
        // ---- upper-wear cluster: shared torso, varying details ----
        0 => {
            // t-shirt: torso + SHORT sleeves
            torso(c, ink, rng.range(-0.8, 0.8), rng.range(-0.8, 0.8));
            c.fill_poly(&[(4.0, 8.0), (9.0, 7.0), (9.0, 13.0), (3.5, 12.5)], ink); // short L sleeve
            c.fill_poly(&[(19.0, 7.0), (24.0, 8.0), (24.5, 12.5), (19.0, 13.0)], ink);
        }
        2 => {
            // pullover: torso + LONG sleeves
            torso(c, ink, rng.range(-0.8, 0.8), rng.range(-0.8, 0.8));
            c.fill_poly(&[(4.0, 8.0), (9.0, 7.0), (9.0, 22.0), (4.5, 22.0)], ink);
            c.fill_poly(&[(19.0, 7.0), (24.0, 8.0), (23.5, 22.0), (19.0, 22.0)], ink);
        }
        4 => {
            // coat: long torso + long sleeves + front opening (dark seam) —
            // the opening is missing in a third of instances (real coats
            // photograph closed), deepening the confusion with pullover.
            torso_tall(c, ink, rng.range(-0.8, 0.8));
            c.fill_poly(&[(4.0, 8.0), (9.0, 7.0), (9.0, 23.0), (4.5, 23.0)], ink);
            c.fill_poly(&[(19.0, 7.0), (24.0, 8.0), (23.5, 23.0), (19.0, 23.0)], ink);
            if rng.chance(0.65) {
                carve_column(c, 14, 8, 24); // front opening
            }
        }
        6 => {
            // shirt: torso + long sleeves + collar notch + button seam dots;
            // cues appear probabilistically (the class is genuinely hard in
            // the real data — ~60-70% per-class accuracy).
            torso(c, ink, rng.range(-0.8, 0.8), rng.range(-0.8, 0.8));
            c.fill_poly(&[(4.5, 8.0), (9.0, 7.0), (9.0, 20.0), (5.0, 20.0)], ink);
            c.fill_poly(&[(19.0, 7.0), (23.5, 8.0), (23.0, 20.0), (19.0, 20.0)], ink);
            if rng.chance(0.7) {
                carve_pixel(c, 13, 7);
                carve_pixel(c, 15, 7);
            }
            if rng.chance(0.6) {
                for y in (10..22).step_by(3) {
                    carve_pixel(c, 14, y);
                }
            }
        }
        1 => {
            // trouser: two legs from a waistband
            c.fill_poly(
                &[(9.0 + rng.range(-0.6, 0.6), 6.0), (19.0 + rng.range(-0.6, 0.6), 6.0), (19.0, 9.0), (9.0, 9.0)],
                ink,
            );
            c.fill_poly(
                &[(9.0, 9.0), (13.2, 9.0), (12.5 + rng.range(-0.6, 0.6), 24.0), (8.5 + rng.range(-0.6, 0.6), 24.0)],
                ink,
            );
            c.fill_poly(
                &[(14.8, 9.0), (19.0, 9.0), (19.5 + rng.range(-0.6, 0.6), 24.0), (15.5 + rng.range(-0.6, 0.6), 24.0)],
                ink,
            );
        }
        3 => {
            // dress: fitted top flaring to a wide hem
            c.fill_poly(
                &[
                    (11.0 + rng.range(-0.5, 0.5), 5.0),
                    (17.0 + rng.range(-0.5, 0.5), 5.0),
                    (16.0, 11.0),
                    (20.5 + rng.range(-0.8, 0.8), 24.0),
                    (7.5 + rng.range(-0.8, 0.8), 24.0),
                    (12.0, 11.0),
                ],
                ink,
            );
        }
        5 => {
            // sandal: thin sole + strap lines (sparse, low mass — like the
            // real class)
            c.fill_poly(
                &[(5.0 + rng.range(-0.5, 0.5), 20.0), (23.0 + rng.range(-0.5, 0.5), 18.5), (23.5, 21.0), (5.0, 22.5)],
                ink,
            );
            c.line(7.0, 20.5, 13.0 + rng.range(-0.8, 0.8), 13.0 + rng.range(-0.8, 0.8), 1.3, ink);
            c.line(13.0, 13.0, 19.0, 19.0, 1.3, ink);
            c.line(10.0, 20.0, 17.0 + rng.range(-0.8, 0.8), 14.5, 1.2, ink);
        }
        7 => {
            // sneaker: low wedge profile
            c.fill_poly(
                &[
                    (4.5 + rng.range(-0.5, 0.5), 21.5),
                    (13.0, 20.5),
                    (18.0, 15.5 + rng.range(-0.6, 0.6)),
                    (23.5, 17.0),
                    (23.5, 22.0),
                    (4.5, 23.0),
                ],
                ink,
            );
            carve_pixel(c, 9, 21);
            carve_pixel(c, 12, 20);
        }
        8 => {
            // bag: trapezoid body + handle arc
            c.fill_poly(
                &[(6.0 + rng.range(-0.5, 0.5), 12.0), (22.0 + rng.range(-0.5, 0.5), 12.0), (23.5, 23.0), (4.5, 23.0)],
                ink,
            );
            c.arc(14.0, 12.0, 5.0 + rng.range(-0.5, 0.5), 5.5, std::f64::consts::PI, std::f64::consts::TAU, 1.6, ink);
        }
        9 => {
            // ankle boot: sole + shaft
            c.fill_poly(
                &[
                    (8.0 + rng.range(-0.5, 0.5), 8.0),
                    (15.0 + rng.range(-0.5, 0.5), 8.0),
                    (15.5, 16.0),
                    (22.5, 18.0),
                    (23.0, 22.5),
                    (7.5, 22.5),
                ],
                ink,
            );
        }
        _ => panic!("fashion class out of range: {class}"),
    }
}

/// Shared upper-wear torso.
fn torso(c: &mut Canvas, ink: f64, jx: f64, jy: f64) {
    c.fill_poly(
        &[
            (9.0 + jx, 6.5 + jy),
            (19.0 + jx, 6.5),
            (20.0, 22.0 + jy),
            (8.0, 22.0),
        ],
        ink,
    );
}

fn torso_tall(c: &mut Canvas, ink: f64, jx: f64) {
    c.fill_poly(&[(9.0 + jx, 6.0), (19.0 + jx, 6.0), (20.5, 24.5), (7.5, 24.5)], ink);
}

/// Remove ink along a 1-px column (garment front openings).
fn carve_column(c: &mut Canvas, x: usize, y0: usize, y1: usize) {
    for y in y0..y1.min(super::raster::SIDE) {
        c.px[y * super::raster::SIDE + x] *= 0.15;
    }
}

fn carve_pixel(c: &mut Canvas, x: usize, y: usize) {
    c.px[y * super::raster::SIDE + x] *= 0.2;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_classes() {
        let mut rng = Rng::new(11);
        for class in 0..10 {
            let c = render_garment(class, &mut rng);
            assert!(c.mass() > 8.0, "{} nearly blank", CLASS_NAMES[class as usize]);
        }
    }

    #[test]
    fn trouser_and_tshirt_differ_strongly() {
        let mut rng = Rng::new(5);
        let a = render_garment(0, &mut rng);
        let b = render_garment(1, &mut rng);
        let d: f64 = a.px.iter().zip(b.px.iter()).map(|(x, y)| (x - y).powi(2)).sum();
        assert!(d > 20.0);
    }

    #[test]
    fn upper_wear_cluster_is_confusable() {
        // shirt vs pullover (both long-sleeved torsos) should be far closer
        // than shirt vs trouser — the property that makes this task harder
        // than digits.
        let mean_image = |class: u32| -> Vec<f64> {
            let mut rng = Rng::new(40 + class as u64);
            let mut acc = vec![0.0; super::super::raster::PIXELS];
            let n = 64;
            for _ in 0..n {
                let c = render_garment(class, &mut rng);
                for (a, p) in acc.iter_mut().zip(c.px.iter()) {
                    *a += p / n as f64;
                }
            }
            acc
        };
        let dist = |a: &[f64], b: &[f64]| -> f64 { a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum() };
        let shirt = mean_image(6);
        let pullover = mean_image(2);
        let trouser = mean_image(1);
        assert!(
            dist(&shirt, &pullover) * 2.0 < dist(&shirt, &trouser),
            "pullover ({}) should be much closer to shirt than trouser ({}) is",
            dist(&shirt, &pullover),
            dist(&shirt, &trouser)
        );
    }
}
