//! A tiny 28×28 grayscale rasterizer used by the procedural MNIST- and
//! Fashion-MNIST-like generators (the real datasets are downloads; the
//! offline testbed synthesizes statistically-similar tasks — DESIGN.md
//! §Substitutions).
//!
//! Primitives: thick anti-aliased-ish line segments, elliptical arcs, filled
//! convex polygons, box blur, additive noise, and affine jitter. Pixels are
//! f64 in [0, 1], row-major.

use crate::util::Rng;

/// Image side length, pixels.
pub const SIDE: usize = 28;
/// Pixels per image (`SIDE²` = the image tasks' feature count).
pub const PIXELS: usize = SIDE * SIDE;

/// A 28×28 grayscale canvas.
#[derive(Clone)]
pub struct Canvas {
    /// Row-major pixel intensities in [0, 1].
    pub px: [f64; PIXELS],
}

impl Default for Canvas {
    fn default() -> Self {
        Canvas { px: [0.0; PIXELS] }
    }
}

impl Canvas {
    /// A blank (all-zero) canvas.
    pub fn new() -> Canvas {
        Canvas::default()
    }

    #[inline]
    fn put(&mut self, x: i32, y: i32, v: f64) {
        if (0..SIDE as i32).contains(&x) && (0..SIDE as i32).contains(&y) {
            let p = &mut self.px[y as usize * SIDE + x as usize];
            *p = p.max(v);
        }
    }

    /// Stamp a filled disc (the "pen") at a floating-point position.
    fn stamp(&mut self, cx: f64, cy: f64, radius: f64, ink: f64) {
        let r = radius.max(0.3);
        let lo_x = (cx - r - 1.0).floor() as i32;
        let hi_x = (cx + r + 1.0).ceil() as i32;
        let lo_y = (cy - r - 1.0).floor() as i32;
        let hi_y = (cy + r + 1.0).ceil() as i32;
        for y in lo_y..=hi_y {
            for x in lo_x..=hi_x {
                let d = ((x as f64 - cx).powi(2) + (y as f64 - cy).powi(2)).sqrt();
                // Soft-edged pen: full ink inside, linear falloff over 1px.
                let v = ink * (1.0 - (d - r).clamp(0.0, 1.0));
                if v > 0.0 {
                    self.put(x, y, v);
                }
            }
        }
    }

    /// Thick line segment from (x0,y0) to (x1,y1).
    pub fn line(&mut self, x0: f64, y0: f64, x1: f64, y1: f64, thickness: f64, ink: f64) {
        let len = ((x1 - x0).powi(2) + (y1 - y0).powi(2)).sqrt();
        let steps = (len * 3.0).ceil().max(1.0) as usize;
        for i in 0..=steps {
            let t = i as f64 / steps as f64;
            self.stamp(x0 + (x1 - x0) * t, y0 + (y1 - y0) * t, thickness / 2.0, ink);
        }
    }

    /// Elliptical arc centered (cx,cy), radii (rx,ry), angles in radians
    /// from `a0` to `a1` (counter-clockwise, a1 > a0).
    pub fn arc(&mut self, cx: f64, cy: f64, rx: f64, ry: f64, a0: f64, a1: f64, thickness: f64, ink: f64) {
        let span = a1 - a0;
        let steps = (span.abs() * rx.max(ry) * 2.0).ceil().max(4.0) as usize;
        for i in 0..=steps {
            let a = a0 + span * i as f64 / steps as f64;
            self.stamp(cx + rx * a.cos(), cy + ry * a.sin(), thickness / 2.0, ink);
        }
    }

    /// Filled polygon (scanline; handles convex and mildly concave shapes).
    pub fn fill_poly(&mut self, pts: &[(f64, f64)], ink: f64) {
        for y in 0..SIDE as i32 {
            let fy = y as f64;
            let mut xs: Vec<f64> = Vec::new();
            for i in 0..pts.len() {
                let (x0, y0) = pts[i];
                let (x1, y1) = pts[(i + 1) % pts.len()];
                if (y0 <= fy && y1 > fy) || (y1 <= fy && y0 > fy) {
                    xs.push(x0 + (fy - y0) / (y1 - y0) * (x1 - x0));
                }
            }
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for pair in xs.chunks(2) {
                if let [a, b] = pair {
                    for x in a.round() as i32..=b.round() as i32 {
                        self.put(x, y, ink);
                    }
                }
            }
        }
    }

    /// 3×3 box blur, `passes` times (approximates gaussian smoothing).
    ///
    /// Implemented separably — a 3×1 horizontal pass then a 1×3 vertical
    /// pass — at 6 taps per pixel instead of 9. Edge renormalization is
    /// per-axis: the horizontal pass records each window's tap count
    /// (2 at the left/right border, 3 inside), the vertical pass sums
    /// those counts over its own valid rows, and ONE division by the
    /// product count happens at the end. Because the 2-D box's neighbor
    /// count factorizes (`n = nx·ny`), this computes exactly the same
    /// renormalized average as the old 3×3 loop — same term set, same
    /// single division — with the summation merely regrouped per row
    /// (bit-equal whenever the row-sum regrouping incurs no extra f64
    /// rounding; see the regression test below).
    pub fn blur(&mut self, passes: usize) {
        for _ in 0..passes {
            // Pass 1 (3×1): raw horizontal window sums + per-window tap
            // counts. No division yet — deferring it keeps a single
            // rounding point, like the original 2-D loop.
            let mut row_sum = [0.0f64; PIXELS];
            let mut row_n = [0u32; PIXELS];
            for y in 0..SIDE {
                for x in 0..SIDE as i32 {
                    let mut acc = 0.0;
                    let mut n = 0u32;
                    for dx in -1..=1 {
                        let xx = x + dx;
                        if (0..SIDE as i32).contains(&xx) {
                            acc += self.px[y * SIDE + xx as usize];
                            n += 1;
                        }
                    }
                    row_sum[y * SIDE + x as usize] = acc;
                    row_n[y * SIDE + x as usize] = n;
                }
            }
            // Pass 2 (1×3): combine the row sums vertically; the summed tap
            // counts reproduce the 2-D box's edge renormalization exactly
            // (the horizontal count depends only on x, so Σ_dy nx = ny·nx).
            for y in 0..SIDE as i32 {
                for x in 0..SIDE {
                    let mut acc = 0.0;
                    let mut n = 0u32;
                    for dy in -1..=1 {
                        let yy = y + dy;
                        if (0..SIDE as i32).contains(&yy) {
                            acc += row_sum[yy as usize * SIDE + x];
                            n += row_n[yy as usize * SIDE + x];
                        }
                    }
                    self.px[y as usize * SIDE + x] = acc / n as f64;
                }
            }
        }
    }

    /// Additive pixel noise, clamped to [0,1].
    pub fn noise(&mut self, rng: &mut Rng, amplitude: f64) {
        for p in self.px.iter_mut() {
            *p = (*p + rng.range(-amplitude, amplitude)).clamp(0.0, 1.0);
        }
    }

    /// Clamp all pixels to [0,1].
    pub fn clamp(&mut self) {
        for p in self.px.iter_mut() {
            *p = p.clamp(0.0, 1.0);
        }
    }

    /// Apply an affine jitter: rotate by `theta`, scale, and translate —
    /// resampled with bilinear interpolation around the canvas center.
    pub fn affine(&self, theta: f64, scale: f64, dx: f64, dy: f64) -> Canvas {
        self.affine_aniso(theta, scale, scale, dx, dy)
    }

    /// Anisotropic affine: separate x/y scales (garment "fit" variation).
    pub fn affine_aniso(&self, theta: f64, scale_x: f64, scale_y: f64, dx: f64, dy: f64) -> Canvas {
        let mut out = Canvas::new();
        let c = (SIDE as f64 - 1.0) / 2.0;
        let (sin, cos) = theta.sin_cos();
        for y in 0..SIDE {
            for x in 0..SIDE {
                // Inverse map output pixel -> source coordinates.
                let ox = x as f64 - c - dx;
                let oy = y as f64 - c - dy;
                let sx = (cos * ox + sin * oy) / scale_x + c;
                let sy = (-sin * ox + cos * oy) / scale_y + c;
                out.px[y * SIDE + x] = self.bilinear(sx, sy);
            }
        }
        out
    }

    fn bilinear(&self, x: f64, y: f64) -> f64 {
        let x0 = x.floor();
        let y0 = y.floor();
        let fx = x - x0;
        let fy = y - y0;
        let sample = |xi: f64, yi: f64| -> f64 {
            let (xi, yi) = (xi as i32, yi as i32);
            if (0..SIDE as i32).contains(&xi) && (0..SIDE as i32).contains(&yi) {
                self.px[yi as usize * SIDE + xi as usize]
            } else {
                0.0
            }
        };
        sample(x0, y0) * (1.0 - fx) * (1.0 - fy)
            + sample(x0 + 1.0, y0) * fx * (1.0 - fy)
            + sample(x0, y0 + 1.0) * (1.0 - fx) * fy
            + sample(x0 + 1.0, y0 + 1.0) * fx * fy
    }

    /// Total ink (useful for sanity tests).
    pub fn mass(&self) -> f64 {
        self.px.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_canvas_has_no_mass() {
        assert_eq!(Canvas::new().mass(), 0.0);
    }

    #[test]
    fn line_leaves_ink_along_path() {
        let mut c = Canvas::new();
        c.line(4.0, 14.0, 24.0, 14.0, 2.0, 1.0);
        assert!(c.px[14 * SIDE + 14] > 0.9);
        assert!(c.px[14 * SIDE + 4] > 0.5);
        assert_eq!(c.px[0], 0.0);
    }

    #[test]
    fn fill_poly_fills_interior() {
        let mut c = Canvas::new();
        c.fill_poly(&[(6.0, 6.0), (22.0, 6.0), (22.0, 22.0), (6.0, 22.0)], 1.0);
        assert!(c.px[14 * SIDE + 14] > 0.9); // center filled
        assert_eq!(c.px[2 * SIDE + 2], 0.0); // outside untouched
    }

    /// The pre-separable 3×3 box blur, verbatim — the regression reference
    /// for the separable rewrite.
    fn box3_reference(c: &mut Canvas, passes: usize) {
        for _ in 0..passes {
            let src = c.px;
            for y in 0..SIDE as i32 {
                for x in 0..SIDE as i32 {
                    let mut acc = 0.0;
                    let mut n = 0.0;
                    for dy in -1..=1 {
                        for dx in -1..=1 {
                            let (xx, yy) = (x + dx, y + dy);
                            if (0..SIDE as i32).contains(&xx) && (0..SIDE as i32).contains(&yy) {
                                acc += src[yy as usize * SIDE + xx as usize];
                                n += 1.0;
                            }
                        }
                    }
                    c.px[y as usize * SIDE + x as usize] = acc / n;
                }
            }
        }
    }

    #[test]
    fn separable_blur_is_bit_equal_to_the_3x3_box() {
        // A drawn-and-noised canvas, snapped to a dyadic grid (multiples of
        // 2^-12). On that grid every 9-term window sum is EXACT in f64
        // regardless of association, so the separable pass's per-row
        // regrouping provably incurs zero extra rounding and the single
        // final division matches the reference bit for bit — this checks
        // the term set and the renormalization, the two things the rewrite
        // could get wrong. (On arbitrary reals the two summation orders may
        // differ in the last ulp; both are equally valid roundings of the
        // same exact average.)
        // One pass per canvas: a blur pass divides by 9, leaving the grid,
        // so exactness is argued per pass — several differently-noised
        // canvases stand in for depth.
        for seed in [42u64, 7, 1234] {
            let mut rng = Rng::new(seed);
            let mut c = Canvas::new();
            c.line(4.0, 6.0, 24.0, 20.0, 2.5, 1.0);
            c.arc(14.0, 14.0, 7.0, 9.0, 0.0, std::f64::consts::TAU, 1.5, 0.8);
            c.noise(&mut rng, 0.2);
            for p in c.px.iter_mut() {
                *p = (*p * 4096.0).round() / 4096.0; // snap to the dyadic grid
            }
            assert!(c.mass() > 10.0, "test canvas unexpectedly blank");
            let mut separable = c.clone();
            separable.blur(1);
            let mut reference = c;
            box3_reference(&mut reference, 1);
            for (i, (a, b)) in separable.px.iter().zip(reference.px.iter()).enumerate() {
                assert!(a.to_bits() == b.to_bits(), "seed={seed} pixel {i}: separable {a:?} != 3x3 box {b:?}");
            }
        }
    }

    #[test]
    fn blur_conserves_roughly_and_smooths() {
        let mut c = Canvas::new();
        c.px[14 * SIDE + 14] = 1.0;
        let before = c.mass();
        c.blur(1);
        assert!(c.px[14 * SIDE + 14] < 0.5);
        assert!(c.px[13 * SIDE + 14] > 0.0);
        assert!((c.mass() - before).abs() < 0.2);
    }

    #[test]
    fn affine_identity_preserves_image() {
        let mut c = Canvas::new();
        c.line(6.0, 6.0, 20.0, 20.0, 2.0, 1.0);
        let moved = c.affine(0.0, 1.0, 0.0, 0.0);
        let diff: f64 = c.px.iter().zip(moved.px.iter()).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff < 1e-9);
    }

    #[test]
    fn affine_translation_moves_mass() {
        let mut c = Canvas::new();
        c.stamp(10.0, 10.0, 2.0, 1.0);
        let moved = c.affine(0.0, 1.0, 5.0, 3.0);
        assert!(moved.px[13 * SIDE + 15] > 0.5);
        assert!(moved.px[10 * SIDE + 10] < 0.5);
    }

    #[test]
    fn arcs_draw_circles() {
        let mut c = Canvas::new();
        c.arc(14.0, 14.0, 8.0, 8.0, 0.0, std::f64::consts::TAU, 2.0, 1.0);
        assert!(c.px[14 * SIDE + 22] > 0.5); // right edge of circle
        assert!(c.px[14 * SIDE + 14] < 0.1); // hollow center
    }
}
