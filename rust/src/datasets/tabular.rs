//! The three low-dimensional tabular tasks of Table 1: Wisconsin Breast
//! Cancer (WDBC), Iris, and Mushroom.
//!
//! The originals are UCI downloads; offline we synthesize statistically
//! faithful equivalents (DESIGN.md §Substitutions): same dimensionality,
//! class balance, and baseline-accuracy regime. What the paper measures —
//! accuracy *drop* when a trained MLP is quantized — depends on task
//! geometry, which these generators preserve.

use crate::util::Rng;

/// Synthesize the Iris analogue: 150 samples, 4 features, 3 classes, from
/// the published per-class feature means/standard deviations of Fisher's
/// data (setosa linearly separable; versicolor/virginica overlapping).
pub fn iris(rng: &mut Rng) -> (Vec<f64>, Vec<u32>, usize) {
    // (mean, std) per class × feature: sepal len, sepal wid, petal len, petal wid.
    #[rustfmt::skip]
    const STATS: [[(f64, f64); 4]; 3] = [
        [(5.01, 0.35), (3.43, 0.38), (1.46, 0.17), (0.25, 0.11)], // setosa
        [(5.94, 0.52), (2.77, 0.31), (4.26, 0.40), (1.33, 0.17)], // versicolor
        [(6.59, 0.64), (2.97, 0.32), (5.55, 0.48), (2.03, 0.23)], // virginica
    ];
    let mut x = Vec::with_capacity(150 * 4);
    let mut y = Vec::with_capacity(150);
    for class in 0..3u32 {
        for _ in 0..50 {
            // Correlate petal length/width (strongly correlated in the real
            // data) via a shared latent factor.
            let latent = rng.gaussian();
            for (f, &(m, s)) in STATS[class as usize].iter().enumerate() {
                let z = if f >= 2 { 0.75 * latent + 0.66 * rng.gaussian() } else { rng.gaussian() };
                x.push((m + s * z).max(0.05));
            }
            y.push(class);
        }
    }
    (x, y, 4)
}

/// Per-feature scale of the WDBC analogue. The real WDBC features live on
/// wildly different natural scales (area ~650, radius ~14, smoothness ~0.1,
/// fractal dimension ~0.06); Deep Positron quantizes the raw inputs, so an
/// 8-bit format must cover this whole dynamic range at once. This is
/// exactly why the paper's Table 1 shows fixed-point collapsing to 57.8%
/// on WDBC while posit (wide tapered range) holds 85.9%.
#[rustfmt::skip]
const WDBC_SCALES: [f64; 10] = [14.0, 19.0, 92.0, 655.0, 0.1, 0.1, 0.08, 0.05, 0.18, 0.06];

/// Synthesize the WDBC analogue: 569 samples (357 benign / 212 malignant),
/// 30 real-valued features on their NATURAL scales (un-normalized). The
/// 3 × 10 layout mirrors the real data: "mean" features (informative),
/// "SE" features (weak), "worst" features (most informative, correlated).
pub fn wdbc(rng: &mut Rng) -> (Vec<f64>, Vec<u32>, usize) {
    const N_BENIGN: usize = 357;
    const N_MALIGNANT: usize = 212;
    const F: usize = 30;
    let mut x = Vec::with_capacity((N_BENIGN + N_MALIGNANT) * F);
    let mut y = Vec::with_capacity(N_BENIGN + N_MALIGNANT);
    for (count, label) in [(N_BENIGN, 0u32), (N_MALIGNANT, 1u32)] {
        for _ in 0..count {
            let severity = if label == 1 { rng.normal(1.0, 0.45) } else { rng.normal(0.0, 0.35) };
            for f in 0..F {
                let (sep, noise) = match f / 10 {
                    0 => (0.9, 0.55),  // mean features: informative
                    1 => (0.25, 0.9),  // SE features: weak
                    _ => (1.1, 0.6),   // worst features: most informative
                };
                let rel = 1.0 + 0.42 * sep * severity + 0.27 * noise * rng.gaussian();
                let scale = WDBC_SCALES[f % 10] * if f / 10 == 1 { 0.1 } else { 1.0 };
                x.push((rel * scale).max(scale * 0.05));
            }
            y.push(label);
        }
    }
    (x, y, F)
}

/// Number of one-hot features for Mushroom (22 categorical attributes with
/// the real dataset's category counts).
pub const MUSHROOM_FEATURES: usize = 117;

/// Category counts of the 22 UCI Mushroom attributes (sums to 117 after
/// one-hot expansion, mirroring the real attribute arities).
#[rustfmt::skip]
const MUSHROOM_ARITY: [usize; 22] = [6, 4, 10, 2, 9, 2, 2, 2, 12, 2, 5, 4, 4, 9, 9, 1, 4, 3, 5, 9, 6, 7];

/// Synthesize the Mushroom analogue: 8124 samples, 22 categorical
/// attributes one-hot encoded to 117 binary features. Edibility is
/// near-deterministic in a few attributes (odor dominates, as in the real
/// data) with a small ambiguous region — the real task is ~100% separable;
/// the paper's MLP reaches 96.8%.
pub fn mushroom(rng: &mut Rng) -> (Vec<f64>, Vec<u32>, usize) {
    const N: usize = 8124;
    let mut x = Vec::with_capacity(N * MUSHROOM_FEATURES);
    let mut y = Vec::with_capacity(N);
    for _ in 0..N {
        let poisonous = rng.chance(0.482); // real class balance: 48.2% poisonous
        let mut cats = [0usize; 22];
        for (a, &arity) in MUSHROOM_ARITY.iter().enumerate() {
            // Attribute 4 ("odor", arity 9 at index 4): nearly determines the
            // class. Attributes 8 (gill-color) and 19 (spore-print) carry
            // secondary signal; the rest are class-independent.
            cats[a] = match a {
                4 => {
                    if poisonous {
                        // poisonous odors: indices 0..4 mostly
                        if rng.chance(0.975) { rng.below(4) } else { 4 + rng.below(5) }
                    } else {
                        // edible: none/almond/anise -> indices 4..9
                        if rng.chance(0.975) { 4 + rng.below(5) } else { rng.below(4) }
                    }
                }
                8 => {
                    if poisonous == rng.chance(0.82) { rng.below(6) } else { 6 + rng.below(6) }
                }
                19 => {
                    if poisonous == rng.chance(0.8) { rng.below(4) } else { 4 + rng.below(5) }
                }
                _ => rng.below(arity),
            };
        }
        for (a, &arity) in MUSHROOM_ARITY.iter().enumerate() {
            for v in 0..arity {
                x.push(if cats[a] == v { 1.0 } else { 0.0 });
            }
        }
        y.push(poisonous as u32);
    }
    (x, y, MUSHROOM_FEATURES)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iris_shapes_and_balance() {
        let mut rng = Rng::new(1);
        let (x, y, f) = iris(&mut rng);
        assert_eq!(f, 4);
        assert_eq!(x.len(), 150 * 4);
        assert_eq!(y.len(), 150);
        for c in 0..3 {
            assert_eq!(y.iter().filter(|&&l| l == c).count(), 50);
        }
        assert!(x.iter().all(|&v| v > 0.0 && v < 12.0));
    }

    #[test]
    fn iris_setosa_separable_on_petal_length() {
        let mut rng = Rng::new(2);
        let (x, y, _) = iris(&mut rng);
        // Petal length (feature 2): setosa < 3 in virtually all samples.
        let mut worst_setosa: f64 = 0.0;
        let mut best_other = f64::INFINITY;
        for (i, &label) in y.iter().enumerate() {
            let pl = x[i * 4 + 2];
            if label == 0 {
                worst_setosa = worst_setosa.max(pl);
            } else {
                best_other = best_other.min(pl);
            }
        }
        assert!(worst_setosa < 3.0, "setosa petal length too large: {worst_setosa}");
        assert!(best_other > 2.2, "non-setosa petal length too small: {best_other}");
    }

    #[test]
    fn wdbc_shapes_and_signal() {
        let mut rng = Rng::new(3);
        let (x, y, f) = wdbc(&mut rng);
        assert_eq!(f, 30);
        assert_eq!(y.len(), 569);
        assert_eq!(y.iter().filter(|&&l| l == 1).count(), 212);
        // Informative feature (f=20, a "worst" feature) should separate class
        // means by over one pooled std.
        let col = |i: usize, label: u32| -> Vec<f64> {
            y.iter().enumerate().filter(|&(_, &l)| l == label).map(|(s, _)| x[s * 30 + i]).collect()
        };
        let benign = col(20, 0);
        let malignant = col(20, 1);
        let mb = crate::util::stats::mean(&benign);
        let mm = crate::util::stats::mean(&malignant);
        let sd = crate::util::stats::std_dev(&benign);
        assert!((mm - mb) / sd > 1.0, "WDBC signal too weak: {}", (mm - mb) / sd);
    }

    #[test]
    fn mushroom_shapes_one_hot() {
        let mut rng = Rng::new(4);
        let (x, y, f) = mushroom(&mut rng);
        assert_eq!(f, MUSHROOM_FEATURES);
        assert_eq!(MUSHROOM_ARITY.iter().sum::<usize>(), MUSHROOM_FEATURES);
        assert_eq!(y.len(), 8124);
        // Every attribute block is exactly one-hot.
        for s in 0..50 {
            let mut off = 0;
            for &arity in MUSHROOM_ARITY.iter() {
                let ones: f64 = x[s * f + off..s * f + off + arity].iter().sum();
                assert_eq!(ones, 1.0);
                off += arity;
            }
        }
        // Class balance near 48.2%.
        let frac = y.iter().filter(|&&l| l == 1).count() as f64 / y.len() as f64;
        assert!((frac - 0.482).abs() < 0.03, "imbalance: {frac}");
    }

    #[test]
    fn mushroom_odor_is_predictive() {
        let mut rng = Rng::new(5);
        let (x, y, f) = mushroom(&mut rng);
        // Odor block starts after attrs 0..4 => offset 6+4+10+2 = 22, arity 9.
        let off: usize = MUSHROOM_ARITY[..4].iter().sum();
        // Predict poisonous iff odor index < 4; should beat 85%.
        let mut correct = 0;
        for (s, &label) in y.iter().enumerate() {
            let odor = (0..9).find(|&v| x[s * f + off + v] == 1.0).unwrap();
            let pred = (odor < 4) as u32;
            correct += (pred == label) as usize;
        }
        let acc = correct as f64 / y.len() as f64;
        assert!(acc > 0.93, "odor rule only {acc}");
    }
}
