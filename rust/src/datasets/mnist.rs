//! Procedural MNIST-like handwritten-digit task.
//!
//! The real MNIST is a download; this generator synthesizes 28×28 grayscale
//! digits with stroke-level structure (per-class stroke programs + random
//! affine jitter, pen-width variation, blur, and pixel noise). The resulting
//! task has MNIST-like statistics — sparse [0,1] pixels, ~98% 32-bit-float
//! MLP baseline — which is what the paper's quantization study needs (see
//! DESIGN.md §Substitutions).

use super::raster::Canvas;
use crate::util::Rng;

/// Render one digit with the given jitter RNG.
pub fn render_digit(class: u32, rng: &mut Rng) -> Canvas {
    let mut c = Canvas::new();
    let t = rng.range(1.6, 2.6); // pen thickness
    let ink = rng.range(0.85, 1.0);
    draw_glyph(&mut c, class, t, ink, rng);
    // Affine jitter: small rotation, scale, translation.
    let mut out = c.affine(rng.range(-0.16, 0.16), rng.range(0.82, 1.08), rng.range(-2.2, 2.2), rng.range(-2.2, 2.2));
    out.blur(1);
    out.noise(rng, 0.04);
    out.clamp();
    out
}

fn draw_glyph(c: &mut Canvas, class: u32, t: f64, ink: f64, rng: &mut Rng) {
    use std::f64::consts::PI;
    // Small per-stroke waviness.
    let mut j = |amt: f64| rng.range(-amt, amt);
    match class {
        0 => {
            c.arc(14.0 + j(0.8), 14.0 + j(0.8), 6.0 + j(1.0), 8.5 + j(1.0), 0.0, 2.0 * PI, t, ink);
        }
        1 => {
            let x = 14.0 + j(1.0);
            c.line(x - 4.0, 9.0 + j(1.0), x, 5.5 + j(0.6), t, ink); // flag
            c.line(x, 5.5, x + j(0.8), 22.5 + j(0.8), t, ink); // stem
        }
        2 => {
            c.arc(14.0 + j(0.6), 9.5, 5.5 + j(0.6), 4.5, -PI, 0.35, t, ink); // top hook
            c.line(18.5 + j(0.8), 11.5, 8.5 + j(0.8), 22.0, t, ink); // diagonal
            c.line(8.5, 22.0, 20.5 + j(0.8), 22.0 + j(0.5), t, ink); // base
        }
        3 => {
            c.arc(13.0 + j(0.6), 9.5, 5.0, 4.0 + j(0.5), -PI * 0.9, PI * 0.5, t, ink);
            c.arc(13.0 + j(0.6), 18.0, 5.5, 4.5 + j(0.5), -PI * 0.5, PI * 0.9, t, ink);
        }
        4 => {
            let xv = 17.0 + j(0.8);
            c.line(15.0 + j(0.8), 5.5, 8.0 + j(0.8), 16.5, t, ink); // left diagonal
            c.line(8.0, 16.5, 20.5 + j(0.6), 16.5 + j(0.5), t, ink); // crossbar
            c.line(xv, 10.0 + j(1.0), xv + j(0.8), 22.5, t, ink); // vertical
        }
        5 => {
            c.line(18.5 + j(0.6), 6.0 + j(0.5), 10.0 + j(0.6), 6.0, t, ink); // top bar
            c.line(10.0, 6.0, 9.2 + j(0.5), 13.0, t, ink); // left drop
            c.arc(13.5 + j(0.6), 17.0, 5.5, 5.0 + j(0.6), -PI * 0.6, PI * 0.8, t, ink); // belly
        }
        6 => {
            c.arc(14.5 + j(0.6), 12.0, 6.5, 7.5, PI * 0.55, PI * 1.45, t, ink); // spine
            c.arc(13.5 + j(0.6), 17.5, 4.5, 4.5 + j(0.5), 0.0, 2.0 * PI, t, ink); // loop
        }
        7 => {
            c.line(8.5 + j(0.6), 6.5 + j(0.5), 20.0 + j(0.6), 6.5, t, ink); // top bar
            c.line(20.0, 6.5, 12.0 + j(1.0), 22.5 + j(0.6), t, ink); // diagonal
        }
        8 => {
            c.arc(14.0 + j(0.5), 9.5, 4.3 + j(0.4), 4.0, 0.0, 2.0 * PI, t, ink);
            c.arc(14.0 + j(0.5), 18.0, 5.2 + j(0.4), 4.6, 0.0, 2.0 * PI, t, ink);
        }
        9 => {
            c.arc(14.5 + j(0.6), 10.0, 4.6, 4.4 + j(0.4), 0.0, 2.0 * PI, t, ink); // head loop
            c.line(19.0 + j(0.5), 10.5, 16.5 + j(1.0), 22.5 + j(0.6), t, ink); // tail
        }
        _ => panic!("digit class out of range: {class}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_classes_with_ink() {
        let mut rng = Rng::new(1);
        for class in 0..10 {
            let c = render_digit(class, &mut rng);
            assert!(c.mass() > 10.0, "digit {class} nearly blank");
            assert!(c.px.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = render_digit(5, &mut Rng::new(99));
        let b = render_digit(5, &mut Rng::new(99));
        assert_eq!(a.px.to_vec(), b.px.to_vec());
    }

    #[test]
    fn jitter_varies_instances() {
        let mut rng = Rng::new(4);
        let a = render_digit(3, &mut rng);
        let b = render_digit(3, &mut rng);
        let diff: f64 = a.px.iter().zip(b.px.iter()).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1.0, "two renders identical — jitter broken");
    }

    #[test]
    fn classes_are_distinguishable_in_pixel_space() {
        // Mean images of distinct classes should differ a lot more than
        // instances within a class — a weak separability check.
        let mean_image = |class: u32| -> Vec<f64> {
            let mut rng = Rng::new(7 + class as u64);
            let mut acc = vec![0.0; super::super::raster::PIXELS];
            for _ in 0..24 {
                let c = render_digit(class, &mut rng);
                for (a, p) in acc.iter_mut().zip(c.px.iter()) {
                    *a += p / 24.0;
                }
            }
            acc
        };
        let m1 = mean_image(1);
        let m0 = mean_image(0);
        let d01: f64 = m0.iter().zip(m1.iter()).map(|(a, b)| (a - b).powi(2)).sum();
        assert!(d01 > 5.0, "digit 0 and 1 means too close: {d01}");
    }
}
