//! Structural synthesis model of the three EMAC soft cores (paper Figs. 2–4).
//!
//! Composes the [`components`](super::components) primitives exactly the way
//! each RTL design instantiates them, stage by stage (the paper's EMACs are
//! pipelined into multiplication / accumulation / rounding, §4.1). The
//! critical path of the widest stage sets Fmax; per-op switched energy and
//! the energy-delay product follow.

use super::components::{self as c, clog2, Cost};
use crate::formats::{quire_width_bits, Fixed, Float, FormatSpec, Posit};
use crate::formats::Format;

/// Synthesis estimate for one EMAC configuration.
#[derive(Debug, Clone)]
pub struct SynthReport {
    /// The synthesized format configuration.
    pub spec: FormatSpec,
    /// Dot-product length the accumulator is sized for (Eq. 2's k).
    pub k: usize,
    /// Accumulator (quire) width per Eq. (2).
    pub quire_bits: u32,
    /// Look-up tables consumed.
    pub luts: f64,
    /// Flip-flops consumed.
    pub ffs: f64,
    /// DSP slices consumed.
    pub dsps: f64,
    /// Per-pipeline-stage propagation delays, ns.
    pub stage_delays_ns: Vec<f64>,
    /// Critical path = slowest pipeline stage, ns. This is what Vivado's
    /// timing report calls "delay" and what the paper's Fig. 7 (left)
    /// plots; Fmax is its reciprocal.
    pub critical_path_ns: f64,
    /// Pipeline fill latency: sum of stage delays, ns.
    pub latency_ns: f64,
    /// Max operating frequency = 1 / critical path, MHz.
    pub fmax_mhz: f64,
    /// Switched energy per MAC operation, pJ.
    pub energy_pj: f64,
    /// Dynamic power at Fmax, mW.
    pub dynamic_power_mw: f64,
    /// Energy-delay product, pJ·ns (Fig. 6's x-axis).
    pub edp_pj_ns: f64,
}

/// Synthesize (model) the EMAC for `spec`, sized for dot products of length
/// `k`.
pub fn synthesize(spec: FormatSpec, k: usize) -> SynthReport {
    let (stages, quire_bits) = match spec {
        FormatSpec::Fixed { n, q } => fixed_emac(Fixed::new(n, q), k),
        FormatSpec::Float { n, we } => float_emac(Float::new(n, we), k),
        FormatSpec::Posit { n, es } => posit_emac(Posit::new(n, es), k),
    };
    let total = stages.iter().fold(Cost::default(), |acc, s| acc.then(*s));
    let stage_delays_ns: Vec<f64> = stages.iter().map(|s| s.delay_ns).collect();
    let critical_path_ns = stage_delays_ns.iter().cloned().fold(0.0f64, f64::max);
    let latency_ns = stage_delays_ns.iter().sum();
    let fmax_mhz = 1e3 / critical_path_ns;
    let energy_pj = total.energy_pj;
    SynthReport {
        spec,
        k,
        quire_bits,
        luts: total.luts,
        ffs: total.ffs,
        dsps: total.dsps,
        stage_delays_ns,
        critical_path_ns,
        latency_ns,
        fmax_mhz,
        energy_pj,
        dynamic_power_mw: energy_pj * fmax_mhz * 1e-3,
        edp_pj_ns: energy_pj * critical_path_ns,
    }
}

/// Fixed-point EMAC (Fig. 2, Algorithm 1): n×n multiply → wide accumulate →
/// round + clip + normalize shift.
fn fixed_emac(fmt: Fixed, k: usize) -> (Vec<Cost>, u32) {
    let n = fmt.n();
    let wa = quire_width_bits(k, fmt.max_value(), fmt.min_pos());
    // Stage 1: signed n×n multiplier.
    let s1 = c::multiplier(n, n).then(c::pipeline_reg(2 * n));
    // Stage 2: sign-extended accumulate into the w_a register.
    let s2 = c::adder(wa).then(c::pipeline_reg(wa));
    // Stage 3: overflow detect (AND/OR over the top bits), clip mux,
    // round-to-nearest-even, normalize shift-right by Q (fixed wiring).
    let s3 = c::reduce(wa - n)
        .beside(c::reduce(wa - n))
        .then(c::rounder(n + 2))
        .then(c::mux2(n))
        .then(c::pipeline_reg(n));
    (vec![s1, s2, s3], wa)
}

/// Floating-point EMAC (Fig. 3, Algorithm 2): unpack + mantissa multiply /
/// shift into fixed-point + accumulate / LZD + normalize + round + pack.
fn float_emac(fmt: Float, k: usize) -> (Vec<Cost>, u32) {
    let we = fmt.we();
    let wf = fmt.wf();
    let wa = quire_width_bits(k, fmt.max_value(), fmt.min_pos());
    let mant = wf + 1; // hidden bit
    // Stage 1: subnormal detect (OR over e), hidden-bit insert, (wf+1)²
    // multiplier, exponent add.
    let s1 = c::reduce(we)
        .beside(c::reduce(we))
        .then(c::multiplier(mant, mant))
        .beside(c::adder(we + 2))
        .then(c::pipeline_reg(2 * mant + we + 3));
    // Stage 2: two's complement of the product, barrel shift to fixed-point
    // alignment (shift range = w_a), wide accumulate.
    let s2 = c::twos_complement(2 * mant)
        .then(c::barrel_shifter(wa, wa))
        .then(c::adder(wa))
        .then(c::pipeline_reg(wa));
    // Stage 3: sign-magnitude (two's comp), LZD, normalize shift, round
    // (guard/sticky), pack.
    let s3 = c::twos_complement(wa)
        .then(c::lzd(wa))
        .then(c::barrel_shifter(wa, wa))
        .then(c::rounder(wf + 3))
        .then(c::mux2(fmt.n()))
        .then(c::pipeline_reg(fmt.n()));
    (vec![s1, s2, s3], wa)
}

/// Posit EMAC (Fig. 4, Algorithms 3–4): regime/exponent/fraction decode per
/// operand + fraction multiply / shift into quire + accumulate / LZD +
/// regime re-encode + round.
fn posit_emac(fmt: Posit, k: usize) -> (Vec<Cost>, u32) {
    let n = fmt.n();
    let es = fmt.es();
    let wa = quire_width_bits(k, fmt.max_value(), fmt.min_pos());
    let frac = n - 2 - es.min(n - 3); // fraction incl. hidden bit
    // Per-operand decode (Algorithm 3): 2's complement, regime LZD, regime
    // shift-out, sign/exp extract. Two operands in parallel.
    let decode_one = c::twos_complement(n).then(c::lzd(n)).then(c::barrel_shifter(n, n));
    // Stage 1: decode both operands + fraction multiply + scale-factor add.
    let s1 = decode_one
        .beside(decode_one)
        .then(c::multiplier(frac, frac))
        .beside(c::adder(clog2(n) + es + 2))
        .then(c::pipeline_reg(2 * frac + clog2(n) + es + 3));
    // Stage 2: two's complement of product, shift into quire position,
    // accumulate (Algorithm 4 "Accumulation").
    let s2 = c::twos_complement(2 * frac)
        .then(c::barrel_shifter(wa, wa))
        .then(c::adder(wa))
        .then(c::pipeline_reg(wa));
    // Stage 3: sign extract, LZD over the quire, fraction/sf extraction
    // shift (Algorithm 4 "Fraction & SF Extraction"). The posit design
    // (Fig. 4) registers extraction separately from encoding — a deeper
    // pipeline than float's Fig. 3, which is how the posit EMAC sustains a
    // higher Fmax than float despite the extra regime machinery (§5).
    let s3 = c::twos_complement(wa).then(c::lzd(wa)).then(c::barrel_shifter(wa, wa)).then(c::pipeline_reg(n + es + 8));
    // Stage 4: convergent rounding + regime RE-ENCODE (the posit-specific
    // cost: building the run-length regime needs another shifter + the
    // overflow muxes of Algorithm 4 lines 25–42) and final 2's complement.
    let s4 = c::rounder(n + 2)
        .then(c::barrel_shifter(n + es + 2, n)) // regime construction
        .then(c::mux2(n + es + 2))
        .then(c::mux2(n))
        .then(c::twos_complement(n))
        .then(c::pipeline_reg(n));
    (vec![s1, s2, s3, s4], wa)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(s: &str) -> FormatSpec {
        FormatSpec::parse(s).unwrap()
    }

    #[test]
    fn quire_widths_match_eq2() {
        let r = synthesize(spec("posit8es0"), 256);
        assert_eq!(r.quire_bits, 34); // 8 + 2*12 + 2
        let rf = synthesize(spec("fixed8q5"), 256);
        assert_eq!(rf.quire_bits, 8 + 2 * 7 + 2);
    }

    #[test]
    fn fixed_is_cheapest_and_fastest() {
        // §5: "The fixed-point EMAC, obviously, is uncontested with its
        // resource utilization and latency."
        for n in 5..=8u32 {
            let fx = synthesize(FormatSpec::Fixed { n, q: n - 3 }, 256);
            let fl = synthesize(FormatSpec::Float { n, we: 4.min(n - 3) }, 256);
            let po = synthesize(FormatSpec::Posit { n, es: 1 }, 256);
            assert!(fx.luts < fl.luts && fx.luts < po.luts, "n={n}");
            assert!(fx.latency_ns < fl.latency_ns && fx.latency_ns < po.latency_ns, "n={n}");
            assert!(fx.edp_pj_ns < fl.edp_pj_ns && fx.edp_pj_ns < po.edp_pj_ns, "n={n}");
        }
    }

    #[test]
    fn posit_uses_more_resources_than_float_at_same_width() {
        // §5: posit "using more resources for the same bit-precision" than
        // float (decode/encode of the run-length regime).
        for n in 6..=8u32 {
            let fl = synthesize(FormatSpec::Float { n, we: 4.min(n - 3) }, 256);
            let po = synthesize(FormatSpec::Posit { n, es: 1 }, 256);
            assert!(po.luts > fl.luts, "n={n}: posit {} ≤ float {}", po.luts, fl.luts);
        }
    }

    #[test]
    fn edp_grows_with_es() {
        // §5.1: EDP(es=0) < EDP(es=1) < EDP(es=2).
        let e0 = synthesize(spec("posit8es0"), 256).edp_pj_ns;
        let e1 = synthesize(spec("posit8es1"), 256).edp_pj_ns;
        let e2 = synthesize(spec("posit8es2"), 256).edp_pj_ns;
        assert!(e0 < e1 && e1 < e2, "EDP ordering broken: {e0} {e1} {e2}");
        // Paper reports ≈1.4× and ≈3×; accept the same ballpark (±60%).
        assert!(e1 / e0 > 1.1 && e1 / e0 < 2.4, "es1/es0 = {}", e1 / e0);
        assert!(e2 / e0 > 1.8 && e2 / e0 < 5.5, "es2/es0 = {}", e2 / e0);
    }

    #[test]
    fn wider_formats_cost_more() {
        for fam in ["posit", "float", "fixed"] {
            let mut prev: Option<SynthReport> = None;
            for n in 5..=8u32 {
                let s = match fam {
                    "posit" => FormatSpec::Posit { n, es: 1 },
                    "float" => FormatSpec::Float { n, we: 3 },
                    _ => FormatSpec::Fixed { n, q: n / 2 },
                };
                let r = synthesize(s, 256);
                if let Some(p) = prev {
                    assert!(r.luts > p.luts, "{fam} LUTs not monotone at n={n}");
                    assert!(r.edp_pj_ns > p.edp_pj_ns, "{fam} EDP not monotone at n={n}");
                }
                prev = Some(r);
            }
        }
    }

    #[test]
    fn accumulator_grows_with_k() {
        let small = synthesize(spec("posit8es1"), 32);
        let big = synthesize(spec("posit8es1"), 1024);
        assert!(big.quire_bits > small.quire_bits);
        assert!(big.latency_ns > small.latency_ns);
    }

    #[test]
    fn fmax_is_reciprocal_of_slowest_stage() {
        let r = synthesize(spec("float8we4"), 256);
        let slowest = r.stage_delays_ns.iter().cloned().fold(0.0f64, f64::max);
        assert!((r.fmax_mhz - 1e3 / slowest).abs() < 1e-9);
        assert_eq!(r.stage_delays_ns.len(), 3);
    }
}
