//! RTL-component cost primitives for a Virtex-7-class (28 nm) FPGA fabric.
//!
//! The paper synthesizes its EMACs with Vivado 2017.2 on xc7vx485t-2; this
//! module is the offline substitute (DESIGN.md §Substitutions): each
//! hardware building block the three EMAC designs instantiate (Figs. 2–4)
//! is costed structurally — LUTs, flip-flops, DSP slices, propagation
//! delay, and switched energy. Constants are calibrated to
//! Virtex-7-plausible values; the experiments consume *relative* orderings
//! (fixed < float ≈ posit, EDP growth with es, …), which emerge from the
//! structure (accumulator widths, shifter depths) rather than the constants.

/// Resource + timing + energy cost of one component (or a composition).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Cost {
    /// Look-up tables consumed.
    pub luts: f64,
    /// Flip-flops consumed.
    pub ffs: f64,
    /// DSP slices consumed.
    pub dsps: f64,
    /// Propagation delay through the component, ns.
    pub delay_ns: f64,
    /// Switched energy per operation, pJ.
    pub energy_pj: f64,
}

impl Cost {
    /// Series composition: delays add (same pipeline stage).
    pub fn then(self, next: Cost) -> Cost {
        Cost {
            luts: self.luts + next.luts,
            ffs: self.ffs + next.ffs,
            dsps: self.dsps + next.dsps,
            delay_ns: self.delay_ns + next.delay_ns,
            energy_pj: self.energy_pj + next.energy_pj,
        }
    }

    /// Parallel composition: delays max, resources add.
    pub fn beside(self, other: Cost) -> Cost {
        Cost {
            luts: self.luts + other.luts,
            ffs: self.ffs + other.ffs,
            dsps: self.dsps + other.dsps,
            delay_ns: self.delay_ns.max(other.delay_ns),
            energy_pj: self.energy_pj + other.energy_pj,
        }
    }
}

/// ceil(log2(x)), made **total**: `clog2(0)` and `clog2(1)` both return 0.
///
/// Contract: a structure with zero or one entries needs no index bits. The
/// previous implementation `debug_assert!`ed `x >= 1` — in release builds
/// the assert vanishes and `x - 1` wrapped to `u32::MAX`, silently
/// returning 32 for `clog2(0)` and corrupting every downstream width.
pub fn clog2(x: u32) -> u32 {
    if x <= 1 {
        return 0;
    }
    32 - (x - 1).leading_zeros()
}

// ---- calibration constants (Virtex-7 -2 speed grade ballpark) ----
const T_LUT_NS: f64 = 0.22; // one LUT6 level incl. local route
const T_CARRY_BASE_NS: f64 = 0.55; // carry-chain entry/exit
const T_CARRY_PER_BIT_NS: f64 = 0.032;
const E_LUT_PJ: f64 = 0.014; // switched energy per active LUT
const E_FF_PJ: f64 = 0.004;
const E_DSP_PJ: f64 = 0.9;
/// Activity factor: fraction of a component's LUTs toggling per op.
const ACTIVITY: f64 = 0.35;

fn lut_energy(luts: f64) -> f64 {
    luts * E_LUT_PJ * ACTIVITY
}

/// W-bit carry-chain adder/subtractor.
pub fn adder(w: u32) -> Cost {
    let luts = w as f64;
    Cost {
        luts,
        ffs: 0.0,
        dsps: 0.0,
        delay_ns: T_CARRY_BASE_NS + T_CARRY_PER_BIT_NS * w as f64,
        energy_pj: lut_energy(luts),
    }
}

/// W-bit two's-complement negate (conditional invert + increment).
pub fn twos_complement(w: u32) -> Cost {
    adder(w).then(Cost {
        luts: w as f64 / 2.0,
        delay_ns: T_LUT_NS,
        energy_pj: lut_energy(w as f64 / 2.0),
        ..Cost::default()
    })
}

/// A×B multiplier. Mantissa multipliers of ≤8-bit formats are small enough
/// that Vivado maps them to fabric (LUTs); ≥11×11 would go to DSP48s.
pub fn multiplier(a: u32, b: u32) -> Cost {
    if a <= 10 && b <= 10 {
        let luts = (a * b) as f64 * 0.85;
        Cost {
            luts,
            ffs: 0.0,
            dsps: 0.0,
            // Array multiplier: ~max(a,b) partial-product rows of carry.
            delay_ns: 0.7 + 0.075 * a.max(b) as f64,
            energy_pj: lut_energy(luts) * 1.6, // high toggle rate in PP array
        }
    } else {
        Cost { luts: 12.0, ffs: 0.0, dsps: 1.0, delay_ns: 2.6, energy_pj: E_DSP_PJ }
    }
}

/// W-bit barrel shifter over P shift positions (log2(P) mux levels).
pub fn barrel_shifter(w: u32, positions: u32) -> Cost {
    let levels = clog2(positions.max(2)) as f64;
    let luts = w as f64 * levels / 2.0; // LUT6 as 4:1 mux → ~2 bits/level/LUT
    Cost {
        luts,
        ffs: 0.0,
        dsps: 0.0,
        delay_ns: 0.25 + (T_LUT_NS + 0.05) * levels,
        energy_pj: lut_energy(luts),
    }
}

/// W-bit leading-zeros detector (binary-tree priority encoder).
pub fn lzd(w: u32) -> Cost {
    let luts = w as f64 * 0.75;
    Cost {
        luts,
        ffs: 0.0,
        dsps: 0.0,
        delay_ns: 0.2 + T_LUT_NS * clog2(w.max(2)) as f64,
        energy_pj: lut_energy(luts),
    }
}

/// W-bit OR/AND reduction tree.
pub fn reduce(w: u32) -> Cost {
    let luts = (w as f64 / 5.0).ceil();
    Cost {
        luts,
        ffs: 0.0,
        dsps: 0.0,
        delay_ns: T_LUT_NS * (clog2(w.max(2)) as f64 / 2.5).ceil(),
        energy_pj: lut_energy(luts),
    }
}

/// Rounding logic (guard/sticky extraction + increment) on W bits.
pub fn rounder(w: u32) -> Cost {
    reduce(w).then(adder(w))
}

/// Pipeline register of W bits (adds FFs and register energy, no delay —
/// it *defines* stage boundaries).
pub fn pipeline_reg(w: u32) -> Cost {
    Cost { luts: 0.0, ffs: w as f64, dsps: 0.0, delay_ns: 0.0, energy_pj: w as f64 * E_FF_PJ * ACTIVITY }
}

/// W-bit 2:1 mux bank.
pub fn mux2(w: u32) -> Cost {
    let luts = w as f64 / 2.0;
    Cost { luts, ffs: 0.0, dsps: 0.0, delay_ns: T_LUT_NS, energy_pj: lut_energy(luts) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clog2_values() {
        assert_eq!(clog2(0), 0, "clog2 is total: zero entries need no index bits");
        assert_eq!(clog2(1), 0);
        assert_eq!(clog2(2), 1);
        assert_eq!(clog2(3), 2);
        assert_eq!(clog2(8), 3);
        assert_eq!(clog2(9), 4);
        assert_eq!(clog2(1024), 10);
    }

    #[test]
    fn adder_scales_linearly() {
        let a8 = adder(8);
        let a32 = adder(32);
        assert!(a32.luts == 4.0 * a8.luts);
        assert!(a32.delay_ns > a8.delay_ns);
        assert!(a32.delay_ns < 4.0 * a8.delay_ns, "carry chain is sublinear-ish via base term");
    }

    #[test]
    fn small_mult_uses_fabric_big_uses_dsp() {
        assert_eq!(multiplier(6, 6).dsps, 0.0);
        assert_eq!(multiplier(12, 12).dsps, 1.0);
    }

    #[test]
    fn barrel_depth_grows_with_positions() {
        let s8 = barrel_shifter(32, 8);
        let s64 = barrel_shifter(32, 64);
        assert!(s64.delay_ns > s8.delay_ns);
        assert!(s64.luts > s8.luts);
    }

    #[test]
    fn composition_rules() {
        let a = adder(8);
        let b = lzd(16);
        let series = a.then(b);
        assert!((series.delay_ns - (a.delay_ns + b.delay_ns)).abs() < 1e-12);
        assert_eq!(series.luts, a.luts + b.luts);
        let par = a.beside(b);
        assert_eq!(par.delay_ns, a.delay_ns.max(b.delay_ns));
        assert_eq!(par.luts, a.luts + b.luts);
    }

    #[test]
    fn registers_cost_ffs_not_delay() {
        let r = pipeline_reg(32);
        assert_eq!(r.ffs, 32.0);
        assert_eq!(r.delay_ns, 0.0);
        assert!(r.energy_pj > 0.0);
    }
}
