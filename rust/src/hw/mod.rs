//! FPGA synthesis cost model (Virtex-7-class) for the three EMAC designs —
//! the offline substitute for the paper's Vivado 2017.2 runs (DESIGN.md
//! §Substitutions). Produces the hardware axes of Figs. 6 and 7, the §5
//! synthesis prose, the §5.1 es-parameter study, and this work's row of
//! Table 2.

pub mod components;
pub mod emac_model;

pub use emac_model::{synthesize, SynthReport};

use crate::formats::FormatSpec;

/// Default dot-product length the paper-style synthesis sizes Eq. (2) for
/// (the largest layer fan-in across the five tasks is MNIST's 784). The
/// standalone `synth-report` CLI uses this; the accuracy×hardware sweeps
/// and the tuner derive `k` from the swept tasks' actual fan-ins instead
/// (`coordinator::experiments::eq2_k`, `crate::tune`).
pub const DEFAULT_K: usize = 784;

/// Synthesis sweep over every format config at bit-widths `ns`.
pub fn sweep(ns: &[u32], k: usize) -> Vec<SynthReport> {
    let mut out = Vec::new();
    for &n in ns {
        for spec in FormatSpec::sweep(n) {
            out.push(synthesize(spec, k));
        }
    }
    out
}

/// §5.1 energy-delay-product ratios between posit es values at one
/// bit-width: returns (EDP(es1)/EDP(es0), EDP(es2)/EDP(es0)).
pub fn es_edp_ratios(n: u32, k: usize) -> (f64, f64) {
    let e0 = synthesize(FormatSpec::Posit { n, es: 0 }, k).edp_pj_ns;
    let e1 = synthesize(FormatSpec::Posit { n, es: 1 }, k).edp_pj_ns;
    let e2 = synthesize(FormatSpec::Posit { n, es: 2 }, k).edp_pj_ns;
    (e1 / e0, e2 / e0)
}

/// Render a synthesis table (markdown) for a list of reports.
pub fn render_table(reports: &[SynthReport]) -> String {
    let mut s = String::new();
    s.push_str(
        "| config | k | quire | LUTs | FFs | DSPs | delay (ns) | Fmax (MHz) | fill (ns) | energy (pJ) \
         | power (mW) | EDP (pJ·ns) |\n",
    );
    s.push_str("|---|---|---|---|---|---|---|---|---|---|---|---|\n");
    for r in reports {
        s.push_str(&format!(
            "| {} | {} | {} | {:.0} | {:.0} | {:.0} | {:.2} | {:.0} | {:.2} | {:.2} | {:.2} | {:.1} |\n",
            r.spec.name(),
            r.k,
            r.quire_bits,
            r.luts,
            r.ffs,
            r.dsps,
            r.critical_path_ns,
            r.fmax_mhz,
            r.latency_ns,
            r.energy_pj,
            r.dynamic_power_mw,
            r.edp_pj_ns
        ));
    }
    s
}

/// The "This Work" row of the paper's Table 2, plus the comparison rows
/// quoted from prior art (static metadata, for the table2 report).
pub fn table2_rows() -> Vec<[String; 7]> {
    let hdr = |a: &str, b: &str, c: &str, d: &str, e: &str, f: &str, g: &str| {
        [a.to_string(), b.to_string(), c.to_string(), d.to_string(), e.to_string(), f.to_string(), g.to_string()]
    };
    vec![
        hdr("Design", "Device", "Task", "Dataset", "Bit-precision", "Operations", "Language"),
        hdr("[17] Jaiswal & So", "Virtex-6 FPGA/ASIC", "-", "-", "All", "Mul,Add/Sub", "Verilog"),
        hdr("[3] Chaurasiya et al.", "Zynq-7000 SoC/ASIC", "FIR Filter", "-", "All", "Mul,Add/Sub", "Verilog"),
        hdr("[25] Podobas & Matsuoka", "Stratix V FPGA", "-", "-", "All", "Mul,Add/Sub", "C++/OpenCL"),
        hdr("[4] Chen et al.", "Virtex7 & Ultrascale+", "-", "-", "32", "Quire", "Verilog"),
        hdr("[23] Lehóczky et al.", "Artix-7 FPGA", "-", "-", "All", "Quire", "C#"),
        hdr("[18] Johnson", "ASIC", "Image Classification", "ImageNet", "All, emph. 8", "Quire", "OpenCL"),
        hdr(
            "This Work (model)",
            "Virtex-7 xc7vx485t (cost model)",
            "Image Classification",
            "WDBC, Iris, Mushroom, MNIST, Fashion MNIST",
            "All, emph. [5,8]",
            "Quire",
            "Rust + JAX/Pallas",
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_sizes() {
        let reports = sweep(&[5, 6, 7, 8], 256);
        // Per-n: posit 3 + float (we 2..=min(5,n-2)) + fixed (n-2) configs.
        assert!(reports.len() > 40);
        assert!(reports.iter().all(|r| r.fmax_mhz > 50.0 && r.fmax_mhz < 2000.0));
    }

    #[test]
    fn es_ratios_in_paper_ballpark() {
        let (r1, r2) = es_edp_ratios(8, DEFAULT_K);
        // Paper §5.1: es=0 EDP ≈ 1.4× (vs es=1) and 3× (vs es=2) smaller.
        assert!(r1 > 1.05 && r1 < 2.5, "es1/es0 = {r1}");
        assert!(r2 > 1.5 && r2 < 6.0, "es2/es0 = {r2}");
        assert!(r2 > r1);
    }

    #[test]
    fn table_renders_all_rows() {
        let reports = sweep(&[8], 256);
        let t = render_table(&reports);
        assert_eq!(t.lines().count(), reports.len() + 2);
        assert!(t.contains("posit8es1"));
    }

    #[test]
    fn table2_has_this_work_row() {
        let rows = table2_rows();
        assert!(rows.last().unwrap()[0].contains("This Work"));
        assert_eq!(rows[0].len(), 7);
    }
}
