//! The `.dpz` deployable model artifact (DESIGN.md §16): one line-oriented,
//! checksummed file carrying everything a serving shard needs to cold-start
//! — the [`NetIr`] topology, the per-layer [`FormatSpec`] assignment,
//! bit-packed weight and bias codes, and (optionally) the tuning provenance
//! of the plan that produced it. No dataset, no trainer, no f64 weight pass:
//! [`Artifact::compile`] feeds the codes straight into
//! [`DeepPositron::compile_from_codes`].
//!
//! ## Layout (strict; text-framed UTF-8)
//!
//! ```text
//! deep-positron dpz v1                      magic + version, exact
//! dataset=iris                              task label (shard routing key)
//! ir=4:dense10+dense8+dense3                NetIr::name topology
//! layers=posit8es1+posit8es1+posit8es1      MixedSpec::name assignment
//! accuracy=0.953333                         optional TunePlan provenance
//! pruned=sensitivity drop<=1.0% ...         optional TunePlan provenance
//! w0=5:40:<hex of packed bytes>:<crc32>     per weighted layer, ascending
//! b0=5:10:<hex>:<crc32>
//! ...
//! crc=<crc32 over every preceding byte>     final line
//! ```
//!
//! Each `w<i>`/`b<i>` field is `width:count:hex:crc32` — a
//! [`PackedCodes`] stream (MSB-first, 1-bit final padding, per-field
//! CRC-32) holding `count` codes of exactly the layer format's bit-width.
//! Weightless layers (pool/flatten) carry no fields. All checksums are the
//! standard `zlib.crc32`, so external tooling can verify a `.dpz` without
//! this crate.
//!
//! The reader is strict: unknown or duplicated keys, a wrong magic line, a
//! non-final or mismatching `crc=`, width/count disagreements with the
//! declared geometry, non-canonical codes, and Eq. (2) quire overflows all
//! come back as typed errors, never panics — artifacts are deployment
//! inputs and deployment inputs are untrusted. The `repro lint` artifact
//! audit (DESIGN.md §14) re-derives the same invariants over committed
//! `.dpz` files.

use crate::accel::{DeepPositron, NetIr};
use crate::formats::emac::DecodeLut;
use crate::formats::pack::{crc32, from_hex, to_hex, PackedCodes};
use crate::formats::MixedSpec;

/// Magic + version line every `.dpz` file must start with.
pub const DPZ_MAGIC: &str = "deep-positron dpz v1";

/// Eq. (2) quire budget (DESIGN.md §6): the largest quire the EMAC model
/// provisions. A parsed artifact whose (format, fan-in) pair needs more is
/// rejected here — mirroring the `assert_quire_fits` the compiler would
/// otherwise hit — so a bad artifact errors instead of panicking a worker.
const QUIRE_BITS_LIMIT: u32 = 126;

/// A parsed (or about-to-be-written) `.dpz` model artifact: validated
/// topology + format assignment + packed parameter codes. Every constructor
/// path establishes the same invariants, so [`Artifact::compile`] is
/// infallible.
#[derive(Debug, Clone, PartialEq)]
pub struct Artifact {
    dataset: String,
    ir: NetIr,
    mixed: MixedSpec,
    accuracy: Option<f64>,
    pruned: Option<String>,
    /// Per-IR-layer weight codes (empty for weightless kinds).
    weight_codes: Vec<Vec<u16>>,
    /// Per-IR-layer bias codes (empty for weightless kinds).
    bias_codes: Vec<Vec<u16>>,
}

impl Artifact {
    /// Snapshot a compiled accelerator instance into an artifact. `dataset`
    /// becomes the serving routing key; it must be a non-empty single line
    /// without `=` (the writer's framing characters).
    pub fn from_network(dataset: &str, dp: &DeepPositron) -> Artifact {
        assert!(
            !dataset.is_empty() && !dataset.contains(['\n', '=']),
            "dataset label must be a non-empty single line without '='"
        );
        Artifact {
            dataset: dataset.to_string(),
            ir: dp.ir(),
            mixed: dp.mixed().clone(),
            accuracy: None,
            pruned: None,
            weight_codes: dp.weight_codes().to_vec(),
            bias_codes: dp.bias_codes(),
        }
    }

    /// Attach tuning provenance (a [`crate::tune::TunePlan`]'s validation
    /// accuracy and optional sensitivity-pruning summary) — rides through
    /// the text codec so a deployed shard can always say where its plan
    /// came from. `accuracy` must be a fraction in `[0, 1]`.
    pub fn with_provenance(mut self, accuracy: f64, pruned: Option<String>) -> Artifact {
        assert!((0.0..=1.0).contains(&accuracy), "accuracy must be a fraction");
        if let Some(p) = &pruned {
            assert!(!p.is_empty() && !p.contains('\n'), "pruned provenance must be a non-empty single line");
        }
        self.accuracy = Some(accuracy);
        self.pruned = pruned;
        self
    }

    /// Task label the artifact was built for (the shard routing key).
    pub fn dataset(&self) -> &str {
        &self.dataset
    }

    /// The network topology.
    pub fn ir(&self) -> &NetIr {
        &self.ir
    }

    /// The per-layer format assignment.
    pub fn mixed(&self) -> &MixedSpec {
        &self.mixed
    }

    /// Tuning-provenance validation accuracy, if recorded.
    pub fn accuracy(&self) -> Option<f64> {
        self.accuracy
    }

    /// Tuning-provenance pruning summary, if recorded.
    pub fn pruned(&self) -> Option<&str> {
        self.pruned.as_deref()
    }

    /// Per-IR-layer weight codes (empty entries for weightless kinds).
    pub fn weight_codes(&self) -> &[Vec<u16>] {
        &self.weight_codes
    }

    /// Per-IR-layer bias codes (empty entries for weightless kinds).
    pub fn bias_codes(&self) -> &[Vec<u16>] {
        &self.bias_codes
    }

    /// Serialize to the `.dpz` text form (see the module layout spec).
    pub fn to_text(&self) -> String {
        let mut s = format!(
            "{DPZ_MAGIC}\ndataset={}\nir={}\nlayers={}\n",
            self.dataset,
            self.ir.name(),
            self.mixed.name()
        );
        if let Some(acc) = self.accuracy {
            s.push_str(&format!("accuracy={acc:.6}\n"));
        }
        if let Some(p) = &self.pruned {
            s.push_str(&format!("pruned={p}\n"));
        }
        for (li, (geom, spec)) in self.ir.geoms().iter().zip(self.mixed.layers()).enumerate() {
            if geom.num_weights() == 0 {
                continue;
            }
            let field = |codes: &[u16]| {
                let p = PackedCodes::pack(codes, spec.n());
                format!("{}:{}:{}:{:08x}", p.width(), p.len(), to_hex(p.bytes()), p.crc())
            };
            s.push_str(&format!("w{li}={}\n", field(&self.weight_codes[li])));
            s.push_str(&format!("b{li}={}\n", field(&self.bias_codes[li])));
        }
        s.push_str(&format!("crc={:08x}\n", crc32(s.as_bytes())));
        s
    }

    /// Parse and fully validate the `.dpz` text form. Artifacts are
    /// untrusted deployment inputs: every invariant the compiler would
    /// assert is checked here first, so success means
    /// [`Artifact::compile`] cannot panic.
    pub fn parse(text: &str) -> Result<Artifact, String> {
        // 1. The trailing whole-file checksum: the final line must be
        //    `crc=XXXXXXXX` over every byte before it.
        let crc_at = text.rfind("\ncrc=").ok_or("missing trailing crc= line")? + 1;
        let (body, crc_line) = text.split_at(crc_at);
        let declared = crc_line
            .trim_end_matches('\n')
            .strip_prefix("crc=")
            .and_then(parse_hex32)
            .ok_or_else(|| format!("malformed crc line {:?}", crc_line.trim_end()))?;
        if crc_line.trim_end_matches('\n').contains('\n') {
            return Err("crc= must be the final line".into());
        }
        let got = crc32(body.as_bytes());
        if got != declared {
            return Err(format!("file crc {got:08x} != declared {declared:08x}"));
        }
        // 2. Magic + version, exact.
        let mut lines = body.lines();
        if lines.next() != Some(DPZ_MAGIC) {
            return Err(format!("not a {DPZ_MAGIC:?} file"));
        }
        // 3. key=value scan with duplicate detection.
        let mut fields: Vec<(&str, &str)> = Vec::new();
        for line in lines {
            let (k, v) = line.split_once('=').ok_or_else(|| format!("malformed line {line:?}"))?;
            if fields.iter().any(|&(fk, _)| fk == k) {
                return Err(format!("duplicate key {k:?}"));
            }
            fields.push((k, v));
        }
        let field = |key: &str| fields.iter().find(|&&(k, _)| k == key).map(|&(_, v)| v);
        let dataset = field("dataset").ok_or("missing dataset=")?.to_string();
        if dataset.is_empty() {
            return Err("empty dataset label".into());
        }
        let ir_text = field("ir").ok_or("missing ir=")?;
        let ir = NetIr::parse(ir_text).ok_or_else(|| format!("unparseable ir {ir_text:?}"))?;
        let layers_text = field("layers").ok_or("missing layers=")?;
        let mixed = MixedSpec::parse(layers_text).ok_or_else(|| format!("unparseable layers {layers_text:?}"))?;
        if mixed.len() != ir.len() {
            return Err(format!("{} format(s) for {} layer(s)", mixed.len(), ir.len()));
        }
        if let Some(spec) = mixed.layers().iter().find(|s| !s.is_supported()) {
            return Err(format!("unsupported format {}", spec.name()));
        }
        let accuracy = match field("accuracy") {
            None => None,
            Some(a) => {
                let acc: f64 = a.parse().map_err(|_| format!("unparseable accuracy {a:?}"))?;
                if !(0.0..=1.0).contains(&acc) {
                    return Err(format!("accuracy {acc} outside [0, 1]"));
                }
                Some(acc)
            }
        };
        let pruned = field("pruned").map(str::to_string);
        // 4. Eq. (2) quire budget, re-derived per layer BEFORE touching any
        //    payload — the same order the compiler checks in, so an
        //    overflowing artifact is rejected by its header alone.
        for (li, (geom, &spec)) in ir.geoms().iter().zip(mixed.layers()).enumerate() {
            let k = geom.eq2_k();
            if k < 2 {
                continue;
            }
            let need = DecodeLut::shared(spec).quire_bits_needed(k);
            if need > QUIRE_BITS_LIMIT {
                return Err(format!(
                    "layer {li} ({}, k={k}) needs a {need}-bit quire, over the {QUIRE_BITS_LIMIT}-bit budget",
                    spec.name()
                ));
            }
        }
        // 5. Per-layer packed parameter fields: present exactly for
        //    weighted layers, at the layer format's width, with the
        //    declared counts, valid framing, and canonical codes only.
        let mut weight_codes = Vec::with_capacity(ir.len());
        let mut bias_codes = Vec::with_capacity(ir.len());
        let mut seen_fields = 3 + usize::from(accuracy.is_some()) + usize::from(pruned.is_some());
        for (li, (geom, &spec)) in ir.geoms().iter().zip(mixed.layers()).enumerate() {
            if geom.num_weights() == 0 {
                for key in [format!("w{li}"), format!("b{li}")] {
                    if field(&key).is_some() {
                        return Err(format!("{key}= on weightless layer {li}"));
                    }
                }
                weight_codes.push(Vec::new());
                bias_codes.push(Vec::new());
                continue;
            }
            let lut = DecodeLut::shared(spec);
            let mut tensor = |key: String, want: usize| -> Result<Vec<u16>, String> {
                let raw = field(&key).ok_or_else(|| format!("missing {key}="))?;
                let codes = parse_packed_field(raw, spec.n(), want).map_err(|e| format!("{key}: {e}"))?;
                if let Some(&bad) = codes.iter().find(|&&c| lut.op(c).is_invalid()) {
                    return Err(format!("{key}: non-canonical {} code {bad:#x}", spec.name()));
                }
                Ok(codes)
            };
            weight_codes.push(tensor(format!("w{li}"), geom.num_weights())?);
            bias_codes.push(tensor(format!("b{li}"), geom.num_biases())?);
            seen_fields += 2;
        }
        // 6. No unrecognized keys may ride along (strict reader).
        if fields.len() != seen_fields {
            let known = |k: &str| {
                matches!(k, "dataset" | "ir" | "layers" | "accuracy" | "pruned")
                    || (0..ir.len()).any(|li| k == format!("w{li}") || k == format!("b{li}"))
            };
            let extra: Vec<&str> = fields.iter().map(|&(k, _)| k).filter(|k| !known(k)).collect();
            return Err(format!("unknown key(s) {extra:?}"));
        }
        Ok(Artifact { dataset, ir, mixed, accuracy, pruned, weight_codes, bias_codes })
    }

    /// Compile the artifact into a runnable accelerator instance — the
    /// millisecond cold-start path. Infallible after [`Artifact::parse`]
    /// (every compile-time assertion was already validated as a parse
    /// error).
    pub fn compile(&self) -> DeepPositron {
        DeepPositron::compile_from_codes(&self.ir, self.mixed.clone(), self.weight_codes.clone(), &self.bias_codes)
    }

    /// Write the artifact to disk (the `repro pack` output path).
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_text())
    }

    /// Read and parse an artifact file (the `repro serve --artifact` input
    /// path); IO and validation failures both come back as strings.
    pub fn load(path: &std::path::Path) -> Result<Artifact, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Artifact::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    }
}

/// Parse one `width:count:hex:crc32` packed-tensor field into codes,
/// enforcing the declared format width and geometry-derived count.
fn parse_packed_field(raw: &str, want_width: u32, want_count: usize) -> Result<Vec<u16>, String> {
    let parts: Vec<&str> = raw.split(':').collect();
    let [width, count, hex, crc] = parts.as_slice() else {
        return Err(format!("expected width:count:hex:crc32, got {raw:?}"));
    };
    let width: u32 = width.parse().map_err(|_| format!("unparseable width {width:?}"))?;
    if width != want_width {
        return Err(format!("width {width} != format width {want_width}"));
    }
    let count: usize = count.parse().map_err(|_| format!("unparseable count {count:?}"))?;
    if count != want_count {
        return Err(format!("{count} code(s) declared, geometry needs {want_count}"));
    }
    let bytes = from_hex(hex).ok_or("payload is not valid hex")?;
    let crc = parse_hex32(crc).ok_or_else(|| format!("malformed field crc {crc:?}"))?;
    Ok(PackedCodes::from_parts(width, count, bytes, crc)?.unpack())
}

/// Exactly eight lowercase/uppercase hex digits → u32.
fn parse_hex32(s: &str) -> Option<u32> {
    (s.len() == 8).then(|| u32::from_str_radix(s, 16).ok()).flatten()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Mlp;
    use crate::formats::FormatSpec;
    use crate::util::Rng;

    fn artifact() -> (Artifact, DeepPositron) {
        // An untrained random net quantizes just like a trained one; the
        // codec has no opinion about accuracy.
        let mut rng = Rng::new(11);
        let mlp = Mlp::new(&[4, 10, 8, 3], &mut rng);
        let dp = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 });
        (Artifact::from_network("iris", &dp), dp)
    }

    #[test]
    fn text_round_trips_and_compiles_bit_identically() {
        let (art, dp) = artifact();
        let text = art.to_text();
        let parsed = Artifact::parse(&text).expect("round trip");
        assert_eq!(parsed, art);
        let compiled = parsed.compile();
        let mut rng = Rng::new(3);
        for _ in 0..8 {
            let x: Vec<f64> = (0..4).map(|_| rng.range(-2.0, 2.0)).collect();
            assert_eq!(compiled.forward_codes(&x), dp.forward_codes(&x));
        }
    }

    #[test]
    fn provenance_rides_through_the_codec() {
        let (art, _) = artifact();
        let art = art.with_provenance(0.95, Some("sensitivity drop<=1.0% floors=5,5,5 screen_rows=64".into()));
        let parsed = Artifact::parse(&art.to_text()).expect("round trip");
        assert_eq!(parsed.accuracy(), Some(0.95));
        assert_eq!(parsed.pruned(), Some("sensitivity drop<=1.0% floors=5,5,5 screen_rows=64"));
    }

    #[test]
    fn mixed_and_conv_artifacts_round_trip() {
        use crate::accel::{Layer, Shape};
        let mut rng = Rng::new(7);
        let conv = Layer::conv2d(Shape::Chw { c: 1, h: 8, w: 8 }, 3, 3, 3, 1, &mut rng);
        let pool = Layer::avg_pool(conv.out_shape, 2, 2);
        let flat = Layer::flatten(pool.out_shape);
        let dense = Layer::dense(flat.out_dim, 4, &mut rng);
        let mlp = Mlp::from_layers(vec![conv, pool, flat, dense]);
        let mixed = MixedSpec::parse("posit8es1+float7we3+posit7es1+fixed6q3").unwrap();
        let dp = DeepPositron::compile_mixed(&mlp, mixed.clone());
        let art = Artifact::from_network("toy", &dp);
        let parsed = Artifact::parse(&art.to_text()).expect("round trip");
        assert_eq!(parsed.mixed(), &mixed);
        assert_eq!(parsed.ir(), &mlp.ir());
        // Weightless layers carry no fields but keep their (empty) slots.
        assert!(parsed.weight_codes()[1].is_empty() && parsed.weight_codes()[2].is_empty());
        let compiled = parsed.compile();
        let x: Vec<f64> = (0..64).map(|_| rng.range(0.0, 1.0)).collect();
        assert_eq!(compiled.forward_codes(&x), dp.forward_codes(&x));
    }

    #[test]
    fn parse_rejects_framing_violations() {
        let (art, _) = artifact();
        let text = art.to_text();
        // Corrupted trailing CRC.
        let bad = text.replace("crc=", "crc=0");
        let bad = format!("{}\n", &bad[..bad.len() - 2]);
        assert!(Artifact::parse(&bad).is_err());
        // A flipped payload nibble breaks BOTH the field and file CRCs.
        let flipped = if text.contains(":a") { text.replacen(":a", ":b", 1) } else { text.replacen(":0", ":1", 1) };
        assert!(Artifact::parse(&flipped).is_err());
        // Wrong magic.
        assert!(Artifact::parse(&text.replacen("v1", "v9", 1)).is_err());
        // Missing crc line entirely.
        let stripped = &text[..text.rfind("crc=").unwrap()];
        assert!(Artifact::parse(stripped).is_err());
        // Empty input.
        assert!(Artifact::parse("").is_err());
    }

    #[test]
    fn parse_rejects_semantic_violations() {
        // Hand-build headers with a correct trailing CRC so the validation
        // under test (not the checksum) is what rejects them.
        let sealed = |body: &str| format!("{body}crc={:08x}\n", crc32(body.as_bytes()));
        // Eq. (2) quire overflow, rejected from the header alone — no
        // parameter payload required (the same case the plan auditor's
        // fixture covers: posit16es1 at k=100001 needs a >126-bit quire).
        let overflow = sealed(&format!(
            "{DPZ_MAGIC}\ndataset=synth\nir=100000:dense10\nlayers=posit16es1\n"
        ));
        let err = Artifact::parse(&overflow).unwrap_err();
        assert!(err.contains("quire"), "{err}");
        // Same topology at 8 bits fits the quire but now (correctly)
        // demands the missing parameter fields.
        let fits = sealed(&format!("{DPZ_MAGIC}\ndataset=synth\nir=100000:dense10\nlayers=posit8es1\n"));
        let err = Artifact::parse(&fits).unwrap_err();
        assert!(err.contains("missing w0"), "{err}");
        // Assignment length must match the IR.
        let mismatch = sealed(&format!("{DPZ_MAGIC}\ndataset=synth\nir=4:dense3\nlayers=posit8es1+posit8es1\n"));
        assert!(Artifact::parse(&mismatch).is_err());
        // Unknown keys are rejected (strict reader).
        let (art, _) = artifact();
        let extra = sealed(&format!("{}extra=1\n", &art.to_text()[..art.to_text().rfind("crc=").unwrap()]));
        let err = Artifact::parse(&extra).unwrap_err();
        assert!(err.contains("unknown key"), "{err}");
        // Duplicate keys are rejected.
        let dup = sealed(&format!("{DPZ_MAGIC}\ndataset=synth\ndataset=synth2\nir=4:dense3\nlayers=posit8es1\n"));
        assert!(Artifact::parse(&dup).unwrap_err().contains("duplicate"));
    }

    #[test]
    fn parse_rejects_payload_violations() {
        let (art, _) = artifact();
        let text = art.to_text();
        let sealed = |body: &str| format!("{body}crc={:08x}\n", crc32(body.as_bytes()));
        let body = &text[..text.rfind("crc=").unwrap()];
        // Wrong declared width for the layer format.
        let bad_width = sealed(&body.replacen("w0=8:", "w0=7:", 1));
        assert!(Artifact::parse(&bad_width).unwrap_err().contains("width"));
        // Wrong declared count for the geometry.
        let bad_count = sealed(&body.replacen("w0=8:40:", "w0=8:39:", 1));
        assert!(Artifact::parse(&bad_count).unwrap_err().contains("code(s) declared"));
    }
}
