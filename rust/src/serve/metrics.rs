//! Serving metrics: per-shard throughput, batch occupancy, latency
//! percentiles (p50/p95/p99), and overload accounting (requests shed at
//! admission, deadline-expired drops, live queue depths), aggregated
//! engine-wide on shutdown.
//!
//! Workers append into one shared [`ShardMetrics`] per shard (a brief mutex
//! hold per executed batch — negligible next to EMAC compute); the router
//! counts sheds on the same struct.
//! [`crate::serve::ServeEngine::shard_metrics`] returns a live snapshot with
//! queue depths stamped; [`crate::serve::ServeEngine::shutdown`] stamps the
//! wall-clock and returns the full [`EngineMetrics`] snapshot. On a clean
//! shutdown every submission is accounted for exactly once:
//! `served + shed + expired` equals the number of accepted-or-shed
//! submissions (dimension-rejected requests are never counted).

use crate::util::stats::{mean, percentile};

/// Aggregated serving metrics for one shard (summed over its workers).
#[derive(Debug, Clone, Default)]
pub struct ShardMetrics {
    /// Shard label, `dataset/format` (e.g. `iris/posit8es1`).
    pub shard: String,
    /// Total requests served.
    pub served: usize,
    /// Requests shed at admission because the routed worker's queue was at
    /// [`max_queue`](crate::serve::WorkerConfig::max_queue); they were never
    /// enqueued and never computed.
    pub shed: usize,
    /// Accepted requests dropped at flush time because their deadline had
    /// already passed — no compute was spent on them.
    pub expired: usize,
    /// Batches executed.
    pub batches: usize,
    /// Per-request end-to-end latency (queue + batch wait + compute), seconds.
    pub latencies_s: Vec<f64>,
    /// Rows in each executed batch.
    pub batch_sizes: Vec<usize>,
    /// Requests served by each worker (index = worker id within the shard).
    pub per_worker: Vec<usize>,
    /// Per-worker queue depth at snapshot time (a live gauge — nonzero only
    /// on [`shard_metrics`](crate::serve::ServeEngine::shard_metrics)
    /// snapshots taken under load; always zero after shutdown drains).
    pub queue_depths: Vec<usize>,
    /// Workers that run the PJRT/XLA fast path (the rest fell back to Sim).
    pub xla_workers: usize,
    /// Engine start → shutdown wall clock, seconds (stamped on shutdown).
    pub wall_seconds: f64,
}

impl ShardMetrics {
    /// Served requests per wall-clock second (0 before shutdown stamps the
    /// wall time).
    pub fn throughput(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.served as f64 / self.wall_seconds
        } else {
            0.0
        }
    }

    /// Mean rows per executed batch (the batcher's fill level).
    pub fn occupancy(&self) -> f64 {
        mean(&self.batch_sizes.iter().map(|&b| b as f64).collect::<Vec<_>>())
    }

    /// Latency percentile in seconds, `p` in [0, 100] (0 when nothing was
    /// served). Nearest-rank (ceil-based), so p100 is the max observed.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        if self.latencies_s.is_empty() {
            0.0
        } else {
            percentile(&self.latencies_s, p)
        }
    }

    /// Every submission that reached this shard's admission gate: served +
    /// shed + expired (dimension-rejected requests never reach admission).
    pub fn submissions(&self) -> usize {
        self.served + self.shed + self.expired
    }

    /// Human-readable per-shard report (latency in ms, throughput in req/s).
    pub fn render(&self) -> String {
        if self.latencies_s.is_empty() && self.submissions() == 0 {
            return format!("[{}] no requests served", self.shard);
        }
        format!(
            "[{}] served {} requests in {} batches ({:.1} req/s)\n\
             \x20 latency p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms (mean {:.2} ms)\n\
             \x20 batch occupancy {:.2} | workers {} ({} xla) | per-worker {:?}\n\
             \x20 admission: shed {} | expired {} | queue depths {:?}",
            self.shard,
            self.served,
            self.batches,
            self.throughput(),
            self.latency_percentile(50.0) * 1e3,
            self.latency_percentile(95.0) * 1e3,
            self.latency_percentile(99.0) * 1e3,
            mean(&self.latencies_s) * 1e3,
            self.occupancy(),
            self.per_worker.len(),
            self.xla_workers,
            self.per_worker,
            self.shed,
            self.expired,
            self.queue_depths,
        )
    }
}

/// Engine-wide final metrics: one entry per shard, sorted by shard label.
#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    /// Per-shard metrics.
    pub shards: Vec<ShardMetrics>,
}

impl EngineMetrics {
    /// Requests served across every shard.
    pub fn total_served(&self) -> usize {
        self.shards.iter().map(|s| s.served).sum()
    }

    /// Requests shed at admission across every shard.
    pub fn total_shed(&self) -> usize {
        self.shards.iter().map(|s| s.shed).sum()
    }

    /// Deadline-expired drops across every shard.
    pub fn total_expired(&self) -> usize {
        self.shards.iter().map(|s| s.expired).sum()
    }

    /// Aggregate requests per second over the engine's lifetime.
    pub fn throughput(&self) -> f64 {
        let wall = self.shards.iter().map(|s| s.wall_seconds).fold(0.0f64, f64::max);
        if wall > 0.0 {
            self.total_served() as f64 / wall
        } else {
            0.0
        }
    }

    /// Render every shard plus an aggregate line.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for shard in &self.shards {
            s.push_str(&shard.render());
            s.push('\n');
        }
        s.push_str(&format!(
            "aggregate: {} served / {} shed / {} expired across {} shard(s), {:.1} req/s",
            self.total_served(),
            self.total_shed(),
            self.total_expired(),
            self.shards.len(),
            self.throughput()
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ShardMetrics {
        ShardMetrics {
            shard: "iris/posit8es1".into(),
            served: 4,
            shed: 2,
            expired: 1,
            batches: 2,
            latencies_s: vec![0.001, 0.002, 0.003, 0.004],
            batch_sizes: vec![3, 1],
            per_worker: vec![3, 1],
            queue_depths: vec![0, 0],
            xla_workers: 0,
            wall_seconds: 2.0,
        }
    }

    #[test]
    fn shard_derived_stats() {
        let m = sample();
        assert_eq!(m.throughput(), 2.0);
        assert_eq!(m.occupancy(), 2.0);
        // Ceil-based nearest-rank over 4 samples: p50 is the 2nd-ranked
        // value, p95 and p99 the 4th (the max) — high percentiles are never
        // understated.
        assert_eq!(m.latency_percentile(50.0), 0.002);
        assert_eq!(m.latency_percentile(95.0), 0.004);
        assert_eq!(m.latency_percentile(99.0), 0.004);
        assert_eq!(m.submissions(), 7);
        let r = m.render();
        assert!(r.contains("req/s") && r.contains("p99"));
        assert!(r.contains("shed 2") && r.contains("expired 1"), "{r}");
    }

    #[test]
    fn empty_shard_is_safe() {
        let m = ShardMetrics::default();
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.latency_percentile(99.0), 0.0);
        assert!(m.render().contains("no requests"));
    }

    #[test]
    fn all_shed_shard_still_renders_accounting() {
        let m = ShardMetrics { shard: "iris/posit8es1".into(), shed: 5, ..Default::default() };
        assert_eq!(m.submissions(), 5);
        assert!(m.render().contains("shed 5"), "a shard that shed everything must still report it");
    }

    #[test]
    fn engine_aggregates() {
        let e = EngineMetrics { shards: vec![sample(), sample()] };
        assert_eq!(e.total_served(), 8);
        assert_eq!(e.total_shed(), 4);
        assert_eq!(e.total_expired(), 2);
        assert_eq!(e.throughput(), 4.0);
        assert!(e.render().contains("aggregate"));
    }
}
