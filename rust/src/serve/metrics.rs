//! Serving metrics: per-shard throughput, batch occupancy, latency
//! percentiles (p50/p95/p99), and overload accounting (requests shed at
//! admission, deadline-expired drops, live queue depths), aggregated
//! engine-wide on shutdown.
//!
//! Since ISSUE 9 the hot path is lock-free: workers and the router update
//! one shared [`ShardStats`] per shard — plain atomic counters plus a
//! bounded [`LogHistogram`] for latency — so there is no metrics mutex to
//! poison and no per-sample allocation to leak (the pre-obs design appended
//! every latency into an unbounded `Vec<f64>`; a sustained open-loop serve
//! session grew without limit).
//! [`crate::serve::ServeEngine::shard_metrics`] snapshots the counters into
//! a plain-value [`ShardMetrics`] with queue depths stamped;
//! [`crate::serve::ServeEngine::shutdown`] stamps the wall-clock and returns
//! the full [`EngineMetrics`] snapshot. On a clean shutdown every submission
//! is accounted for exactly once: `served + shed + expired` equals the
//! number of accepted-or-shed submissions (dimension-rejected requests are
//! never counted).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use crate::obs::hist::{HistSnapshot, LogHistogram};

/// Live, lock-free counters for one shard, shared by its router entry and
/// every worker. All updates are relaxed atomic adds (commutative, so
/// snapshots are deterministic for a given multiset of events); latency goes
/// into a bounded log-linear histogram instead of a sample vector.
#[derive(Default)]
pub struct ShardStats {
    served: AtomicUsize,
    shed: AtomicUsize,
    expired: AtomicUsize,
    batches: AtomicUsize,
    xla_workers: AtomicUsize,
    max_batch: AtomicUsize,
    per_worker: Vec<AtomicUsize>,
    latency: LogHistogram,
}

impl ShardStats {
    /// Fresh stats for a shard with `workers` workers (the per-worker slots
    /// are fixed at spawn, so worker-side updates never resize anything).
    pub fn new(workers: usize) -> ShardStats {
        ShardStats { per_worker: (0..workers).map(|_| AtomicUsize::new(0)).collect(), ..Default::default() }
    }

    /// Count one request shed at admission.
    pub fn note_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one accepted request dropped at flush because its deadline had
    /// already passed.
    pub fn note_expired(&self) {
        self.expired.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one worker that came up on the PJRT/XLA fast path.
    pub fn note_xla_worker(&self) {
        self.xla_workers.fetch_add(1, Ordering::Relaxed);
    }

    /// Account one executed batch of `rows` rows on worker `worker`.
    pub fn note_batch(&self, worker: usize, rows: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.served.fetch_add(rows, Ordering::Relaxed);
        self.max_batch.fetch_max(rows, Ordering::Relaxed);
        if let Some(slot) = self.per_worker.get(worker) {
            slot.fetch_add(rows, Ordering::Relaxed);
        }
    }

    /// Record one served request's end-to-end latency.
    pub fn record_latency(&self, latency: Duration) {
        self.latency.record_duration(latency);
    }

    /// Requests served so far (relaxed read).
    pub fn served(&self) -> usize {
        self.served.load(Ordering::Relaxed)
    }

    /// Requests shed plus deadline-expired so far (the overload-spike signal
    /// the flight recorder's dump trigger watches).
    pub fn dropped(&self) -> usize {
        self.shed.load(Ordering::Relaxed) + self.expired.load(Ordering::Relaxed)
    }

    /// Point-in-time plain-value snapshot with the shard label, live queue
    /// depths, and wall clock stamped on.
    pub fn snapshot(&self, shard: &str, queue_depths: Vec<usize>, wall_seconds: f64) -> ShardMetrics {
        ShardMetrics {
            shard: shard.to_string(),
            served: self.served.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            max_batch: self.max_batch.load(Ordering::Relaxed),
            latency: self.latency.snapshot(),
            per_worker: self.per_worker.iter().map(|w| w.load(Ordering::Relaxed)).collect(),
            queue_depths,
            xla_workers: self.xla_workers.load(Ordering::Relaxed),
            wall_seconds,
        }
    }
}

/// Aggregated serving metrics for one shard (summed over its workers) — a
/// plain value snapshot of a [`ShardStats`].
#[derive(Debug, Clone, Default)]
pub struct ShardMetrics {
    /// Shard label, `dataset/format` (e.g. `iris/posit8es1`).
    pub shard: String,
    /// Total requests served.
    pub served: usize,
    /// Requests shed at admission because the routed worker's queue was at
    /// [`max_queue`](crate::serve::WorkerConfig::max_queue); they were never
    /// enqueued and never computed.
    pub shed: usize,
    /// Accepted requests dropped at flush time because their deadline had
    /// already passed — no compute was spent on them.
    pub expired: usize,
    /// Batches executed.
    pub batches: usize,
    /// Largest batch executed (evidence the batcher actually coalesced).
    pub max_batch: usize,
    /// Bounded end-to-end latency histogram (queue + batch wait + compute),
    /// nanosecond buckets — O(1) memory at any request volume.
    pub latency: HistSnapshot,
    /// Requests served by each worker (index = worker id within the shard).
    pub per_worker: Vec<usize>,
    /// Per-worker queue depth at snapshot time (a live gauge — nonzero only
    /// on [`shard_metrics`](crate::serve::ServeEngine::shard_metrics)
    /// snapshots taken under load; always zero after shutdown drains).
    pub queue_depths: Vec<usize>,
    /// Workers that run the PJRT/XLA fast path (the rest fell back to Sim).
    pub xla_workers: usize,
    /// Engine start → shutdown wall clock, seconds (stamped on shutdown).
    pub wall_seconds: f64,
}

impl ShardMetrics {
    /// Served requests per wall-clock second (0 before shutdown stamps the
    /// wall time).
    pub fn throughput(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.served as f64 / self.wall_seconds
        } else {
            0.0
        }
    }

    /// Mean rows per executed batch (the batcher's fill level): every served
    /// row belongs to exactly one batch, so this is `served / batches`.
    pub fn occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.served as f64 / self.batches as f64
        }
    }

    /// Latency percentile in seconds, `p` in [0, 100] (0 when nothing was
    /// served). Nearest-rank (ceil-based) over the histogram buckets —
    /// within one bucket (relative error ≤ 1/16) of the exact
    /// `util::stats::percentile` on the underlying samples, exact on the
    /// sub-32 ns buckets, and p100 never exceeds the max observed bucket.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        self.latency.quantile_secs(p)
    }

    /// Mean end-to-end latency in seconds (0 when nothing was served).
    pub fn latency_mean(&self) -> f64 {
        self.latency.mean_ns() as f64 * 1e-9
    }

    /// Every submission that reached this shard's admission gate: served +
    /// shed + expired (dimension-rejected requests never reach admission).
    pub fn submissions(&self) -> usize {
        self.served + self.shed + self.expired
    }

    /// Human-readable per-shard report (latency in ms, throughput in req/s).
    pub fn render(&self) -> String {
        if self.latency.count() == 0 && self.submissions() == 0 {
            return format!("[{}] no requests served", self.shard);
        }
        format!(
            "[{}] served {} requests in {} batches ({:.1} req/s)\n\
             \x20 latency p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms (mean {:.2} ms)\n\
             \x20 batch occupancy {:.2} (max {}) | workers {} ({} xla) | per-worker {:?}\n\
             \x20 admission: shed {} | expired {} | queue depths {:?}",
            self.shard,
            self.served,
            self.batches,
            self.throughput(),
            self.latency_percentile(50.0) * 1e3,
            self.latency_percentile(95.0) * 1e3,
            self.latency_percentile(99.0) * 1e3,
            self.latency_mean() * 1e3,
            self.occupancy(),
            self.max_batch,
            self.per_worker.len(),
            self.xla_workers,
            self.per_worker,
            self.shed,
            self.expired,
            self.queue_depths,
        )
    }
}

/// Engine-wide final metrics: one entry per shard, sorted by shard label.
#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    /// Per-shard metrics.
    pub shards: Vec<ShardMetrics>,
}

impl EngineMetrics {
    /// Requests served across every shard.
    pub fn total_served(&self) -> usize {
        self.shards.iter().map(|s| s.served).sum()
    }

    /// Requests shed at admission across every shard.
    pub fn total_shed(&self) -> usize {
        self.shards.iter().map(|s| s.shed).sum()
    }

    /// Deadline-expired drops across every shard.
    pub fn total_expired(&self) -> usize {
        self.shards.iter().map(|s| s.expired).sum()
    }

    /// Aggregate requests per second over the engine's lifetime.
    pub fn throughput(&self) -> f64 {
        let wall = self.shards.iter().map(|s| s.wall_seconds).fold(0.0f64, f64::max);
        if wall > 0.0 {
            self.total_served() as f64 / wall
        } else {
            0.0
        }
    }

    /// Render every shard plus an aggregate line.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for shard in &self.shards {
            s.push_str(&shard.render());
            s.push('\n');
        }
        s.push_str(&format!(
            "aggregate: {} served / {} shed / {} expired across {} shard(s), {:.1} req/s",
            self.total_served(),
            self.total_shed(),
            self.total_expired(),
            self.shards.len(),
            self.throughput()
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ShardMetrics {
        let s = ShardStats::new(2);
        s.note_batch(0, 3);
        s.note_batch(1, 1);
        for ms in [1u64, 2, 3, 4] {
            s.record_latency(Duration::from_millis(ms));
        }
        s.note_shed();
        s.note_shed();
        s.note_expired();
        s.snapshot("iris/posit8es1", vec![0, 0], 2.0)
    }

    #[test]
    fn shard_derived_stats() {
        let m = sample();
        assert_eq!(m.throughput(), 2.0);
        assert_eq!(m.occupancy(), 2.0);
        assert_eq!(m.max_batch, 3);
        assert_eq!(m.per_worker, vec![3, 1]);
        // Ceil-based nearest-rank over 4 samples: p50 is the 2nd-ranked
        // value (2 ms), p95 and p99 the 4th (the 4 ms max). The histogram
        // reports bucket lower bounds, so each quantile may understate the
        // exact sample by at most one part in 16 and never overstates it.
        for (p, exact) in [(50.0, 0.002), (95.0, 0.004), (99.0, 0.004)] {
            let q = m.latency_percentile(p);
            assert!(q <= exact && q >= exact * (1.0 - 1.0 / 16.0), "p{p}: {q} vs exact {exact}");
        }
        assert_eq!(m.submissions(), 7);
        let r = m.render();
        assert!(r.contains("req/s") && r.contains("p99"));
        assert!(r.contains("shed 2") && r.contains("expired 1"), "{r}");
    }

    #[test]
    fn stats_are_lock_free_and_bounded() {
        // Concurrent recording from several threads must produce exactly the
        // serial counts (atomic adds commute) without growing any memory.
        let s = std::sync::Arc::new(ShardStats::new(1));
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let s = std::sync::Arc::clone(&s);
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        s.record_latency(Duration::from_nanos(t * 1000 + i));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let snap = s.snapshot("x", vec![], 0.0);
        assert_eq!(snap.latency.count(), 4000);
    }

    #[test]
    fn empty_shard_is_safe() {
        let m = ShardMetrics::default();
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.latency_percentile(99.0), 0.0);
        assert!(m.render().contains("no requests"));
    }

    #[test]
    fn all_shed_shard_still_renders_accounting() {
        let m = ShardMetrics { shard: "iris/posit8es1".into(), shed: 5, ..Default::default() };
        assert_eq!(m.submissions(), 5);
        assert!(m.render().contains("shed 5"), "a shard that shed everything must still report it");
    }

    #[test]
    fn engine_aggregates() {
        let e = EngineMetrics { shards: vec![sample(), sample()] };
        assert_eq!(e.total_served(), 8);
        assert_eq!(e.total_shed(), 4);
        assert_eq!(e.total_expired(), 2);
        assert_eq!(e.throughput(), 4.0);
        assert!(e.render().contains("aggregate"));
    }
}
