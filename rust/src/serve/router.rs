//! Request routing: a [`ServeEngine`] owns one shard per (dataset, format)
//! pair; each shard owns a pool of warm workers. Requests address a shard by
//! [`ShardKey`] and are spread across its workers round-robin, or pinned by
//! an affinity hash (sticky sessions).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::accel::Mlp;
use crate::coordinator::experiments::Engine;
use crate::datasets::Dataset;
use crate::formats::FormatSpec;
use crate::serve::metrics::{EngineMetrics, ShardMetrics};
use crate::serve::worker::{self, Control, InferReply, Request, ServeError, WorkerConfig, WorkerHandle, WorkerSpec};

/// Routing key: one shard serves one (dataset, format) pair.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ShardKey {
    /// Dataset (model/topology) name, e.g. `iris`.
    pub dataset: String,
    /// Format name as produced by [`FormatSpec::name`], e.g. `posit8es1`.
    pub format: String,
}

impl ShardKey {
    /// Key for a dataset × format pair.
    pub fn new(dataset: &str, spec: FormatSpec) -> ShardKey {
        ShardKey { dataset: dataset.to_string(), format: spec.name() }
    }

    /// `dataset/format` label used in metrics and traces.
    pub fn label(&self) -> String {
        format!("{}/{}", self.dataset, self.format)
    }
}

/// Configuration of one shard: a quantized model replicated across workers.
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// Dataset name (routing-key half + AOT-artifact lookup key).
    pub dataset: String,
    /// Input feature count; requests are validated against this.
    pub num_features: usize,
    /// Output class count.
    pub num_classes: usize,
    /// The trained f64 network this shard serves (quantized per `spec`).
    pub mlp: Mlp,
    /// Numeric format the shard quantizes to (routing-key half).
    pub spec: FormatSpec,
    /// Preferred engine; workers fall back to Sim when PJRT or the compiled
    /// artifact is missing.
    pub engine: Engine,
    /// Worker replicas (each owns its own engine instance).
    pub workers: usize,
    /// Batching knobs shared by the workers.
    pub worker: WorkerConfig,
}

impl ShardConfig {
    /// Shard for a loaded dataset and trained model: 1 worker, Sim engine,
    /// default batching.
    pub fn new(ds: &Dataset, mlp: Mlp, spec: FormatSpec) -> ShardConfig {
        ShardConfig {
            dataset: ds.name.clone(),
            num_features: ds.num_features,
            num_classes: ds.num_classes,
            mlp,
            spec,
            engine: Engine::Sim,
            workers: 1,
            worker: WorkerConfig::default(),
        }
    }

    /// Set the worker-replica count (min 1).
    pub fn with_workers(mut self, n: usize) -> ShardConfig {
        self.workers = n.max(1);
        self
    }

    /// Set the preferred engine.
    pub fn with_engine(mut self, engine: Engine) -> ShardConfig {
        self.engine = engine;
        self
    }
}

struct Shard {
    key: ShardKey,
    num_features: usize,
    workers: Vec<WorkerHandle>,
    next: AtomicUsize,
    metrics: Arc<Mutex<ShardMetrics>>,
}

impl Shard {
    fn submit(&self, worker_idx: usize, x: Vec<f64>) -> Result<mpsc::Receiver<InferReply>, ServeError> {
        if x.len() != self.num_features {
            return Err(ServeError::BadRequest { got: x.len(), want: self.num_features });
        }
        let (tx, rx) = mpsc::channel();
        self.workers[worker_idx]
            .tx
            .send(Control::Req(Request { x, submitted: Instant::now(), resp: tx }))
            .map_err(|_| ServeError::Closed)?;
        Ok(rx)
    }
}

/// The sharded, multi-worker serving engine.
///
/// One shard per (dataset, format); N warm workers per shard, each owning
/// its own engine (Sim or PJRT) and running deadline-based dynamic batching;
/// quantization tables shared process-wide
/// ([`crate::formats::Quantizer::shared`]); per-shard metrics collected on
/// [`ServeEngine::shutdown`].
///
/// ```no_run
/// use deep_positron::coordinator::experiments::train_model;
/// use deep_positron::datasets::{self, Scale};
/// use deep_positron::formats::FormatSpec;
/// use deep_positron::serve::{ServeEngine, ShardConfig, ShardKey};
///
/// let ds = datasets::load("iris", 7, Scale::Small);
/// let mlp = train_model(&ds, 7);
/// // Two format shards over the same model, four workers each.
/// let shards = ["posit8es1", "fixed8q5"]
///     .iter()
///     .map(|f| ShardConfig::new(&ds, mlp.clone(), FormatSpec::parse(f).unwrap()).with_workers(4))
///     .collect();
/// let engine = ServeEngine::start(shards).unwrap();
/// let key = ShardKey::new("iris", FormatSpec::parse("posit8es1").unwrap());
/// let reply = engine.submit(&key, ds.test_row(0).to_vec()).unwrap().recv().unwrap();
/// println!("class {} in {:.2} ms", reply.class, reply.latency_s * 1e3);
/// println!("{}", engine.shutdown().render());
/// ```
pub struct ServeEngine {
    shards: HashMap<ShardKey, Shard>,
    started: Instant,
}

impl ServeEngine {
    /// Start every shard and block until all workers are warm, so no
    /// request ever pays compile time. Every worker of every shard spawns
    /// first and warm-up runs in parallel; readiness is collected after.
    /// Duplicate (dataset, format) configs collapse onto one shard (last
    /// wins; the superseded workers shut down when their channels close).
    pub fn start(shards: Vec<ShardConfig>) -> Result<ServeEngine, ServeError> {
        // Phase 1: spawn everything, no waiting.
        let mut staged = Vec::with_capacity(shards.len());
        for cfg in shards {
            let key = ShardKey { dataset: cfg.dataset.clone(), format: cfg.spec.name() };
            let nworkers = cfg.workers.max(1);
            let metrics = Arc::new(Mutex::new(ShardMetrics {
                shard: key.label(),
                per_worker: vec![0; nworkers],
                ..Default::default()
            }));
            let mut workers = Vec::with_capacity(nworkers);
            let mut readies = Vec::with_capacity(nworkers);
            for index in 0..nworkers {
                let (handle, ready) = worker::spawn(WorkerSpec {
                    shard: key.label(),
                    dataset: cfg.dataset.clone(),
                    index,
                    mlp: cfg.mlp.clone(),
                    spec: cfg.spec,
                    engine: cfg.engine,
                    classes: cfg.num_classes,
                    cfg: cfg.worker.clone(),
                    metrics: Arc::clone(&metrics),
                });
                workers.push(handle);
                readies.push(ready);
            }
            staged.push((key, cfg.num_features, workers, readies, metrics));
        }
        // Phase 2: collect readiness (a dead worker thread drops its sender).
        let mut map = HashMap::new();
        for (key, num_features, workers, readies, metrics) in staged {
            for ready in readies {
                match ready.recv() {
                    Ok(xla_active) => {
                        if xla_active {
                            metrics.lock().unwrap().xla_workers += 1;
                        }
                    }
                    Err(_) => return Err(ServeError::Closed),
                }
            }
            map.insert(key.clone(), Shard { key, num_features, workers, next: AtomicUsize::new(0), metrics });
        }
        Ok(ServeEngine { shards: map, started: Instant::now() })
    }

    /// All registered shard keys, sorted by label for stable iteration.
    pub fn shard_keys(&self) -> Vec<ShardKey> {
        let mut keys: Vec<ShardKey> = self.shards.keys().cloned().collect();
        keys.sort_by_key(|k| k.label());
        keys
    }

    fn shard(&self, key: &ShardKey) -> Result<&Shard, ServeError> {
        self.shards.get(key).ok_or_else(|| ServeError::UnknownShard(key.label()))
    }

    /// Submit one feature vector to a shard; round-robins across its
    /// workers. Returns the receiver the reply will arrive on.
    pub fn submit(&self, key: &ShardKey, x: Vec<f64>) -> Result<mpsc::Receiver<InferReply>, ServeError> {
        let shard = self.shard(key)?;
        let w = shard.next.fetch_add(1, Ordering::Relaxed) % shard.workers.len();
        shard.submit(w, x)
    }

    /// Submit with an affinity hash: requests carrying the same `affinity`
    /// (session id, user id, …) always land on the same worker of the shard,
    /// keeping per-session batches warm on one engine.
    pub fn submit_with_affinity(
        &self,
        key: &ShardKey,
        affinity: u64,
        x: Vec<f64>,
    ) -> Result<mpsc::Receiver<InferReply>, ServeError> {
        let shard = self.shard(key)?;
        let w = (mix64(affinity) % shard.workers.len() as u64) as usize;
        shard.submit(w, x)
    }

    /// Live metrics snapshot for one shard (wall clock stamped as of now).
    pub fn shard_metrics(&self, key: &ShardKey) -> Option<ShardMetrics> {
        self.shards.get(key).map(|s| {
            let mut m = s.metrics.lock().unwrap().clone();
            m.wall_seconds = self.started.elapsed().as_secs_f64();
            m
        })
    }

    /// Stop every worker — each serves whatever is already queued first —
    /// and return the final per-shard metrics.
    pub fn shutdown(self) -> EngineMetrics {
        let wall = self.started.elapsed().as_secs_f64();
        let mut shards: Vec<Shard> = self.shards.into_values().collect();
        shards.sort_by_key(|s| s.key.label());
        let mut out = Vec::with_capacity(shards.len());
        for shard in &mut shards {
            for w in &shard.workers {
                let (tx, rx) = mpsc::channel();
                if w.tx.send(Control::Shutdown(tx)).is_ok() {
                    let _ = rx.recv();
                }
            }
            for w in &mut shard.workers {
                if let Some(join) = w.join.take() {
                    let _ = join.join();
                }
            }
            let mut m = shard.metrics.lock().unwrap().clone();
            m.wall_seconds = wall;
            out.push(m);
        }
        EngineMetrics { shards: out }
    }
}

/// SplitMix64 finalizer: spreads low-entropy affinity keys across workers.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_key_label_and_eq() {
        let spec = FormatSpec::Posit { n: 8, es: 1 };
        let a = ShardKey::new("iris", spec);
        let b = ShardKey { dataset: "iris".into(), format: "posit8es1".into() };
        assert_eq!(a, b);
        assert_eq!(a.label(), "iris/posit8es1");
    }

    #[test]
    fn mix64_spreads_small_keys() {
        let hits: std::collections::HashSet<u64> = (0..16).map(|k| mix64(k) % 4).collect();
        assert!(hits.len() > 1, "all affinity keys mapped to one worker");
    }
}
