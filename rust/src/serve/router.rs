//! Request routing: a [`ServeEngine`] owns one shard per (dataset, format)
//! pair; each shard owns a pool of warm workers. Requests address a shard by
//! [`ShardKey`]. Within a shard the router picks the **least-loaded of two
//! candidate workers** (power-of-two-choices over the per-worker queue
//! depths, tie going to the round-robin candidate), or pins by an affinity
//! hash (sticky sessions). Admission is **bounded**: once the picked
//! worker's queue depth reaches [`WorkerConfig::max_queue`] the submission
//! is shed with [`ServeError::Overloaded`] instead of queueing without
//! limit (DESIGN.md §9).
//!
//! Accounting is lock-free (DESIGN.md §15): the router and workers update a
//! shared atomic [`ShardStats`] per shard, every accepted request carries a
//! [`TraceId`] into the engine-wide [`FlightRecorder`], and
//! [`ServeEngine::observe`] exports the whole stack's counters as an
//! [`ObsSnapshot`]. There is no mutex on the submit path and therefore no
//! poisoned-lock panic path — the serve lint zone holds with zero
//! exemptions.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::accel::Mlp;
use crate::artifact::Artifact;
use crate::coordinator::experiments::Engine;
use crate::datasets::Dataset;
use crate::formats::{FormatSpec, MixedSpec};
use crate::obs::export::ObsSnapshot;
use crate::obs::recorder::{FlightRecorder, TraceId};
use crate::serve::metrics::{EngineMetrics, ShardMetrics, ShardStats};
use crate::serve::worker::{self, Control, InferReply, Request, ServeError, WorkerConfig, WorkerHandle, WorkerSpec};

/// Flight-recorder capacity: the most recent trace events retained
/// engine-wide (a few MiB at most, fixed at start).
pub const RECORDER_CAPACITY: usize = 4096;

/// Routing key: one shard serves one (dataset, format) pair.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ShardKey {
    /// Dataset (model/topology) name, e.g. `iris`.
    pub dataset: String,
    /// Format name as produced by [`FormatSpec::name`], e.g. `posit8es1`.
    pub format: String,
}

impl ShardKey {
    /// Key for a dataset × format pair.
    pub fn new(dataset: &str, spec: FormatSpec) -> ShardKey {
        ShardKey { dataset: dataset.to_string(), format: spec.name() }
    }

    /// Key for a dataset × tuned per-layer assignment (the format half is
    /// the assignment's `+`-joined name).
    pub fn for_mixed(dataset: &str, mixed: &MixedSpec) -> ShardKey {
        ShardKey { dataset: dataset.to_string(), format: mixed.name() }
    }

    /// `dataset/format` label used in metrics and traces.
    pub fn label(&self) -> String {
        format!("{}/{}", self.dataset, self.format)
    }
}

/// Configuration of one shard: a quantized model replicated across workers.
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// Dataset name (routing-key half + AOT-artifact lookup key).
    pub dataset: String,
    /// Input feature count; requests are validated against this, and this
    /// is validated against the model topology at [`ServeEngine::start`].
    pub num_features: usize,
    /// Output class count (validated against the model topology at start).
    pub num_classes: usize,
    /// The trained f64 network this shard serves (quantized per `spec`).
    pub mlp: Mlp,
    /// Numeric format the shard quantizes to (routing-key half, unless a
    /// mixed assignment overrides it).
    pub spec: FormatSpec,
    /// Optional per-layer format assignment (a tuned deployment plan,
    /// DESIGN.md §10): when set, workers compile the heterogeneous
    /// execution plan instead of the uniform `spec`, the routing key
    /// carries the assignment's `+`-joined name, and the shard always runs
    /// the bit-exact Sim engine (the AOT artifact is uniform-only).
    pub mixed: Option<MixedSpec>,
    /// Optional packed `.dpz` model artifact (DESIGN.md §16): when set,
    /// workers compile their execution plan straight from the packed codes
    /// — no dataset, no trainer, no f64 weight pass — which is the
    /// millisecond cold-start path. `mlp` then carries only the topology
    /// shell ([`Mlp::skeleton`]) for request/response validation, and the
    /// shard always runs the bit-exact Sim engine.
    pub artifact: Option<Arc<Artifact>>,
    /// Preferred engine; workers fall back to Sim when PJRT or the compiled
    /// artifact is missing.
    pub engine: Engine,
    /// Worker replicas (each owns its own engine instance).
    pub workers: usize,
    /// Batching + admission knobs shared by the workers.
    pub worker: WorkerConfig,
}

impl ShardConfig {
    /// Shard for a loaded dataset and trained model: 1 worker, Sim engine,
    /// default batching and admission bounds.
    pub fn new(ds: &Dataset, mlp: Mlp, spec: FormatSpec) -> ShardConfig {
        ShardConfig {
            dataset: ds.name.clone(),
            num_features: ds.num_features,
            num_classes: ds.num_classes,
            mlp,
            spec,
            mixed: None,
            artifact: None,
            engine: Engine::Sim,
            workers: 1,
            worker: WorkerConfig::default(),
        }
    }

    /// Shard that serves a packed `.dpz` artifact (DESIGN.md §16): the
    /// topology shell, feature/class widths, dataset routing key, and the
    /// format half of the routing key all come from the artifact itself —
    /// no dataset load, no training, no f64 weights. A uniform assignment
    /// routes under the plain format name (so artifact shards and
    /// compile-from-f64 shards of the same config share a [`ShardKey`]); a
    /// heterogeneous one routes under the `+`-joined assignment name.
    pub fn from_artifact(artifact: Arc<Artifact>) -> ShardConfig {
        let ir = artifact.ir();
        let (spec, mixed) = match artifact.mixed().is_uniform() {
            Some(spec) => (spec, None),
            None => (artifact.mixed().layers()[0], Some(artifact.mixed().clone())),
        };
        ShardConfig {
            dataset: artifact.dataset().to_string(),
            num_features: ir.input().len(),
            num_classes: ir.output().len(),
            mlp: Mlp::skeleton(ir),
            spec,
            mixed,
            artifact: Some(artifact),
            engine: Engine::Sim,
            workers: 1,
            worker: WorkerConfig::default(),
        }
    }

    /// Deploy a per-layer format assignment on this shard — typically a
    /// tuned plan (`crate::tune::TunePlan::shard_config` builds this for
    /// you). The assignment must carry one format per model layer
    /// (validated at [`ServeEngine::start`]).
    pub fn with_mixed(mut self, mixed: MixedSpec) -> ShardConfig {
        self.mixed = Some(mixed);
        self
    }

    /// The routing-key format label: the uniform spec's name, or the
    /// `+`-joined assignment name when a mixed plan is attached.
    pub fn format_name(&self) -> String {
        match &self.mixed {
            Some(m) => m.name(),
            None => self.spec.name(),
        }
    }

    /// Set the worker-replica count (min 1).
    pub fn with_workers(mut self, n: usize) -> ShardConfig {
        self.workers = n.max(1);
        self
    }

    /// Set the preferred engine.
    pub fn with_engine(mut self, engine: Engine) -> ShardConfig {
        self.engine = engine;
        self
    }

    /// Set the per-worker admission bound; see [`WorkerConfig::max_queue`].
    /// A bound of 0 is rejected as [`ServeError::BadShard`] at
    /// [`ServeEngine::start`] rather than silently rewritten.
    pub fn with_max_queue(mut self, max_queue: usize) -> ShardConfig {
        self.worker.max_queue = max_queue;
        self
    }

    /// Reject configs whose redundant fields disagree with the model
    /// topology — a mismatch would validate requests against the wrong
    /// dimension or slice logits out of bounds at serve time. Validation
    /// runs on the model's typed layer IR (DESIGN.md §11): the shape chain
    /// itself must infer cleanly (a broken conv/pool chain is a BadShard,
    /// not a worker panic), and the request/response widths are the IR's
    /// input and output shapes.
    fn validate(&self, label: &str) -> Result<(), ServeError> {
        let bad = |reason: String| ServeError::BadShard { shard: label.to_string(), reason };
        if self.mlp.layers.is_empty() {
            return Err(bad("model has no layers".into()));
        }
        self.mlp.check_shapes().map_err(|e| bad(format!("layer IR rejected: {e}")))?;
        let ir = self.mlp.ir();
        if self.num_features != ir.input().len() {
            return Err(bad(format!("num_features {} != model input dim {}", self.num_features, ir.input().len())));
        }
        if self.num_classes != ir.output().len() {
            return Err(bad(format!("num_classes {} != model output dim {}", self.num_classes, ir.output().len())));
        }
        if self.worker.max_queue == 0 {
            return Err(bad("max_queue must be >= 1 (0 would shed every request)".into()));
        }
        if self.worker.sim_batch == 0 {
            return Err(bad("sim_batch must be >= 1".into()));
        }
        if let Some(m) = &self.mixed {
            if m.len() != self.mlp.layers.len() {
                return Err(bad(format!(
                    "mixed assignment carries {} formats for a {}-layer model",
                    m.len(),
                    self.mlp.layers.len()
                )));
            }
        }
        if let Some(art) = &self.artifact {
            if *art.ir() != ir {
                return Err(bad(format!(
                    "artifact topology {} disagrees with the shard model {}",
                    art.ir().name(),
                    ir.name()
                )));
            }
        }
        Ok(())
    }
}

struct Shard {
    key: ShardKey,
    num_features: usize,
    max_queue: usize,
    workers: Vec<WorkerHandle>,
    next: AtomicUsize,
    stats: Arc<ShardStats>,
    recorder: Arc<FlightRecorder>,
}

impl Shard {
    /// Pick a worker: round-robin candidate vs. a hashed second candidate,
    /// take whichever has the shallower queue (power-of-two-choices). Ties
    /// go to the round-robin candidate, so an idle shard still cycles its
    /// workers deterministically; under skew, one slow worker stops
    /// attracting new requests as soon as its queue is deeper.
    fn pick(&self) -> usize {
        let n = self.workers.len();
        let seq = self.next.fetch_add(1, Ordering::Relaxed);
        let a = seq % n;
        if n == 1 {
            return a;
        }
        let mut b = (mix64(seq as u64) % n as u64) as usize;
        if b == a {
            b = (a + 1) % n;
        }
        if self.workers[b].depth.load(Ordering::Relaxed) < self.workers[a].depth.load(Ordering::Relaxed) {
            b
        } else {
            a
        }
    }

    /// Bounded admission: reserve a queue slot on the worker (shed with
    /// [`ServeError::Overloaded`] when its depth is at `max_queue`), then
    /// enqueue. The worker releases the slot when the request leaves its
    /// queue (for execution or deadline expiry).
    fn submit(
        &self,
        worker_idx: usize,
        x: Vec<f64>,
        deadline: Option<Instant>,
    ) -> Result<mpsc::Receiver<InferReply>, ServeError> {
        if x.len() != self.num_features {
            return Err(ServeError::BadRequest { got: x.len(), want: self.num_features });
        }
        let worker = &self.workers[worker_idx];
        let admit = worker.depth.fetch_update(Ordering::AcqRel, Ordering::Relaxed, |d| {
            if d < self.max_queue {
                Some(d + 1)
            } else {
                None
            }
        });
        if let Err(depth) = admit {
            self.stats.note_shed();
            self.recorder.note_drop();
            return Err(ServeError::Overloaded { shard: self.key.label(), depth });
        }
        let (tx, rx) = mpsc::channel();
        let req = Request { trace: TraceId::next(), x, submitted: Instant::now(), deadline, resp: tx };
        if worker.tx.send(Control::Req(req)).is_err() {
            worker.depth.fetch_sub(1, Ordering::Release);
            return Err(ServeError::Closed);
        }
        Ok(rx)
    }

    fn queue_depths(&self) -> Vec<usize> {
        self.workers.iter().map(|w| w.depth.load(Ordering::Relaxed)).collect()
    }
}

/// The sharded, multi-worker serving engine.
///
/// One shard per (dataset, format); N warm workers per shard, each owning
/// its own engine (Sim or PJRT) and running deadline-heap dynamic batching;
/// bounded admission with load shedding ([`ServeError::Overloaded`]) and
/// least-loaded two-choice routing; quantization tables shared process-wide
/// ([`crate::formats::Quantizer::shared`]); per-shard metrics collected on
/// [`ServeEngine::shutdown`].
///
/// ```no_run
/// use deep_positron::coordinator::experiments::train_model;
/// use deep_positron::datasets::{self, Scale};
/// use deep_positron::formats::FormatSpec;
/// use deep_positron::serve::{ServeEngine, ShardConfig, ShardKey};
///
/// let ds = datasets::load("iris", 7, Scale::Small);
/// let mlp = train_model(&ds, 7);
/// // Two format shards over the same model, four workers each.
/// let shards = ["posit8es1", "fixed8q5"]
///     .iter()
///     .map(|f| ShardConfig::new(&ds, mlp.clone(), FormatSpec::parse(f).unwrap()).with_workers(4))
///     .collect();
/// let engine = ServeEngine::start(shards).unwrap();
/// let key = ShardKey::new("iris", FormatSpec::parse("posit8es1").unwrap());
/// let reply = engine.submit(&key, ds.test_row(0).to_vec()).unwrap().recv().unwrap();
/// println!("class {} in {:.2} ms", reply.class, reply.latency_s * 1e3);
/// println!("{}", engine.shutdown().render());
/// ```
pub struct ServeEngine {
    shards: HashMap<ShardKey, Shard>,
    recorder: Arc<FlightRecorder>,
    started: Instant,
}

impl ServeEngine {
    /// Start every shard and block until all workers are warm, so no
    /// request ever pays compile time. Configs are validated against their
    /// model topology first ([`ServeError::BadShard`]); then every worker
    /// of every shard spawns and warm-up runs in parallel; readiness is
    /// collected after. Duplicate (dataset, format) configs collapse onto
    /// one shard (last wins; the superseded workers shut down when their
    /// channels close).
    pub fn start(shards: Vec<ShardConfig>) -> Result<ServeEngine, ServeError> {
        // Phase 0: validate every config before any thread spawns, so a bad
        // config is rejected side-effect-free (no live workers mid-compile
        // abandoned behind an Err).
        for cfg in &shards {
            let key = ShardKey { dataset: cfg.dataset.clone(), format: cfg.format_name() };
            cfg.validate(&key.label())?;
        }
        // Phase 1: spawn everything, no waiting. One flight recorder serves
        // the whole engine — traces from every shard interleave in arrival
        // order, which is exactly what an overload post-mortem wants.
        let recorder = Arc::new(FlightRecorder::new(RECORDER_CAPACITY));
        let mut staged = Vec::with_capacity(shards.len());
        for cfg in shards {
            let key = ShardKey { dataset: cfg.dataset.clone(), format: cfg.format_name() };
            let nworkers = cfg.workers.max(1);
            let stats = Arc::new(ShardStats::new(nworkers));
            let mut workers = Vec::with_capacity(nworkers);
            let mut readies = Vec::with_capacity(nworkers);
            for index in 0..nworkers {
                let (handle, ready) = worker::spawn(WorkerSpec {
                    shard: key.label(),
                    dataset: cfg.dataset.clone(),
                    index,
                    mlp: cfg.mlp.clone(),
                    spec: cfg.spec,
                    mixed: cfg.mixed.clone(),
                    artifact: cfg.artifact.clone(),
                    engine: cfg.engine,
                    classes: cfg.num_classes,
                    cfg: cfg.worker.clone(),
                    stats: Arc::clone(&stats),
                    recorder: Arc::clone(&recorder),
                });
                workers.push(handle);
                readies.push(ready);
            }
            staged.push((key, cfg.num_features, cfg.worker.max_queue, workers, readies, stats));
        }
        // Phase 2: collect readiness (a dead worker thread drops its sender).
        let mut map = HashMap::new();
        for (key, num_features, max_queue, workers, readies, stats) in staged {
            for ready in readies {
                match ready.recv() {
                    Ok(xla_active) => {
                        if xla_active {
                            stats.note_xla_worker();
                        }
                    }
                    Err(_) => return Err(ServeError::Closed),
                }
            }
            let shard = Shard {
                key: key.clone(),
                num_features,
                max_queue,
                workers,
                next: AtomicUsize::new(0),
                stats,
                recorder: Arc::clone(&recorder),
            };
            map.insert(key, shard);
        }
        Ok(ServeEngine { shards: map, recorder, started: Instant::now() })
    }

    /// All registered shard keys, sorted by label for stable iteration.
    pub fn shard_keys(&self) -> Vec<ShardKey> {
        let mut keys: Vec<ShardKey> = self.shards.keys().cloned().collect();
        keys.sort_by_key(|k| k.label());
        keys
    }

    fn shard(&self, key: &ShardKey) -> Result<&Shard, ServeError> {
        self.shards.get(key).ok_or_else(|| ServeError::UnknownShard(key.label()))
    }

    /// Submit one feature vector to a shard; routes to the least-loaded of
    /// two candidate workers (round-robin order when idle). Returns the
    /// receiver the reply will arrive on, or sheds with
    /// [`ServeError::Overloaded`] when the picked worker's queue is full.
    pub fn submit(&self, key: &ShardKey, x: Vec<f64>) -> Result<mpsc::Receiver<InferReply>, ServeError> {
        let shard = self.shard(key)?;
        shard.submit(shard.pick(), x, None)
    }

    /// [`submit`](ServeEngine::submit) with a latency budget: if the request
    /// is still queued once `budget` has elapsed, the worker drops it
    /// WITHOUT computing it (the reply channel closes, so `recv` errors and
    /// the shard's `expired` count grows). Use this so stale work — clients
    /// that have already timed out — never occupies the accelerator.
    pub fn submit_with_deadline(
        &self,
        key: &ShardKey,
        x: Vec<f64>,
        budget: Duration,
    ) -> Result<mpsc::Receiver<InferReply>, ServeError> {
        let shard = self.shard(key)?;
        shard.submit(shard.pick(), x, Some(Instant::now() + budget))
    }

    /// Submit with an affinity hash: requests carrying the same `affinity`
    /// (session id, user id, …) always land on the same worker of the shard,
    /// keeping per-session batches warm on one engine. Affinity overrides
    /// least-loaded routing, but admission stays bounded: a full pinned
    /// worker sheds with [`ServeError::Overloaded`].
    pub fn submit_with_affinity(
        &self,
        key: &ShardKey,
        affinity: u64,
        x: Vec<f64>,
    ) -> Result<mpsc::Receiver<InferReply>, ServeError> {
        let shard = self.shard(key)?;
        let w = (mix64(affinity) % shard.workers.len() as u64) as usize;
        shard.submit(w, x, None)
    }

    /// Live per-worker queue depths for one shard, straight off the
    /// admission atomics — the cheap overload gauge (no metrics-mutex
    /// hold, no latency-history clone). `None` for an unknown key.
    pub fn queue_depths(&self, key: &ShardKey) -> Option<Vec<usize>> {
        self.shards.get(key).map(|s| s.queue_depths())
    }

    /// Live metrics snapshot for one shard: wall clock and per-worker queue
    /// depths stamped as of now. Reads the lock-free counters — safe to call
    /// at any rate from any thread.
    pub fn shard_metrics(&self, key: &ShardKey) -> Option<ShardMetrics> {
        self.shards
            .get(key)
            .map(|s| s.stats.snapshot(&s.key.label(), s.queue_depths(), self.started.elapsed().as_secs_f64()))
    }

    /// One observability snapshot across the whole engine (every shard, in
    /// label order) plus the process-wide pool / tuner / LUT / layer-timing
    /// counters — the `repro serve --obs-out` payload (DESIGN.md §15).
    pub fn observe(&self) -> ObsSnapshot {
        let metrics: Vec<ShardMetrics> =
            self.shard_keys().into_iter().filter_map(|k| self.shard_metrics(&k)).collect();
        ObsSnapshot::collect(&metrics)
    }

    /// The engine-wide flight recorder: arm its spike dump, inspect retained
    /// trace events, or dump manually at end of run.
    pub fn recorder(&self) -> &FlightRecorder {
        &self.recorder
    }

    /// Arm the flight recorder's automatic JSONL dump: once `threshold`
    /// requests have been shed or expired engine-wide, the retained traces
    /// are written to `path` exactly once (the overload post-mortem).
    pub fn arm_trace_dump(&self, path: &Path, threshold: u64) {
        self.recorder.arm_dump(path, threshold);
    }

    /// Stop every worker — each serves whatever is already queued first —
    /// and return the final per-shard metrics.
    pub fn shutdown(self) -> EngineMetrics {
        let wall = self.started.elapsed().as_secs_f64();
        let mut shards: Vec<Shard> = self.shards.into_values().collect();
        shards.sort_by_key(|s| s.key.label());
        let mut out = Vec::with_capacity(shards.len());
        for shard in &mut shards {
            for w in &shard.workers {
                let (tx, rx) = mpsc::channel();
                if w.tx.send(Control::Shutdown(tx)).is_ok() {
                    let _ = rx.recv();
                }
            }
            for w in &mut shard.workers {
                if let Some(join) = w.join.take() {
                    let _ = join.join();
                }
            }
            out.push(shard.stats.snapshot(&shard.key.label(), shard.queue_depths(), wall));
        }
        EngineMetrics { shards: out }
    }
}

/// SplitMix64 finalizer: spreads low-entropy affinity keys across workers.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_key_label_and_eq() {
        let spec = FormatSpec::Posit { n: 8, es: 1 };
        let a = ShardKey::new("iris", spec);
        let b = ShardKey { dataset: "iris".into(), format: "posit8es1".into() };
        assert_eq!(a, b);
        assert_eq!(a.label(), "iris/posit8es1");
    }

    #[test]
    fn mix64_spreads_small_keys() {
        let hits: std::collections::HashSet<u64> = (0..16).map(|k| mix64(k) % 4).collect();
        assert!(hits.len() > 1, "all affinity keys mapped to one worker");
    }
}
