//! Shard workers: each worker thread owns its inference engine (bit-exact
//! Sim, or the PJRT/XLA fast path when artifacts exist) and runs the
//! deadline-based dynamic batcher extracted from the original
//! single-worker server (`coordinator::server`).
//!
//! Both engines execute the flushed batch as a batch: XLA through the
//! compiled fixed-shape executables, Sim through the accelerator's compiled
//! execution plan ([`DeepPositron::forward_batch`] via
//! [`DeepPositron::predict_batch`]) — so the batcher's coalescing pays off
//! on the bit-exact path too, instead of degenerating into a per-sample
//! loop (DESIGN.md §8).
//!
//! Engine-per-thread is load-bearing: XLA handles are not `Send`, so all
//! device-side state lives and dies on one worker thread. Worker replicas of
//! the same format do NOT pay the quantization-table build N times — tables
//! come from the process-wide [`Quantizer::shared`](crate::formats::Quantizer::shared)
//! cache via [`DeepPositron::compile`].
//!
//! Fallback ladder (the router never has to care): requested `Engine::Xla`
//! degrades to Sim when the PJRT runtime cannot start, when the dataset has
//! no compiled `q_infer` artifact, or — per batch — when an execution fails.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::accel::{argmax, DeepPositron, Mlp};
use crate::coordinator::experiments::Engine;
use crate::formats::FormatSpec;
use crate::runtime::{artifacts_dir, FormatTables, Kind, Runtime};
use crate::serve::metrics::ShardMetrics;

/// One served prediction.
#[derive(Debug, Clone)]
pub struct InferReply {
    /// Predicted class index.
    pub class: usize,
    /// Queue + batch-wait + compute latency, seconds.
    pub latency_s: f64,
    /// Worker (within the shard) that served the request.
    pub worker: usize,
}

/// Errors surfaced by the serving engine's client API.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// No shard is registered under the requested key.
    UnknownShard(String),
    /// The request's feature dimension does not match the shard's model.
    BadRequest {
        /// Features in the submitted vector.
        got: usize,
        /// Features the shard's model expects.
        want: usize,
    },
    /// The engine (or the routed worker) has already shut down.
    Closed,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownShard(key) => write!(f, "no shard registered for {key}"),
            ServeError::BadRequest { got, want } => {
                write!(f, "bad request: {got} features submitted, shard expects {want}")
            }
            ServeError::Closed => write!(f, "serving engine is shut down"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Batching knobs shared by a shard's workers.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Max time the batcher waits to fill a batch before executing it.
    pub max_batch_wait: Duration,
    /// Batch cap when no compiled artifact dictates one (Sim engine).
    pub sim_batch: usize,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig { max_batch_wait: Duration::from_millis(2), sim_batch: 64 }
    }
}

pub(crate) struct Request {
    pub x: Vec<f64>,
    pub submitted: Instant,
    pub resp: mpsc::Sender<InferReply>,
}

pub(crate) enum Control {
    Req(Request),
    Shutdown(mpsc::Sender<()>),
}

pub(crate) struct WorkerHandle {
    pub tx: mpsc::Sender<Control>,
    pub join: Option<JoinHandle<()>>,
}

/// Everything a worker needs to start; moved onto its thread.
pub(crate) struct WorkerSpec {
    pub shard: String,
    pub dataset: String,
    pub index: usize,
    pub mlp: Mlp,
    pub spec: FormatSpec,
    pub engine: Engine,
    pub classes: usize,
    pub cfg: WorkerConfig,
    pub metrics: Arc<Mutex<ShardMetrics>>,
}

/// Spawn one worker WITHOUT waiting for warm-up; the returned receiver
/// fires once the worker is warm (model quantized, every XLA executable
/// compiled and exercised once), carrying whether the XLA fast path is
/// active. Callers spawn every worker first and then collect readiness, so
/// warm-up runs in parallel across the whole engine.
pub(crate) fn spawn(ws: WorkerSpec) -> (WorkerHandle, mpsc::Receiver<bool>) {
    let (tx, rx) = mpsc::channel::<Control>();
    let (ready_tx, ready_rx) = mpsc::channel::<bool>();
    let join = std::thread::spawn(move || worker_loop(rx, ready_tx, ws));
    (WorkerHandle { tx, join: Some(join) }, ready_rx)
}

/// Per-worker XLA fast-path state (thread-local by construction).
struct XlaState {
    rt: Runtime,
    weights: Vec<Vec<f64>>,
    biases: Vec<Vec<f64>>,
    tables: FormatTables,
    batches: Vec<usize>,
}

/// Try to stand up the fast path; any failure means Sim.
fn build_xla(shard: &str, dataset: &str, dp: &DeepPositron, mlp: &Mlp, spec: FormatSpec) -> Option<XlaState> {
    let rt = match Runtime::new(&artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("serve[{shard}]: PJRT unavailable, falling back to Sim ({e})");
            return None;
        }
    };
    let batches = rt.batches(Kind::QInfer, dataset);
    if batches.is_empty() {
        eprintln!("serve[{shard}]: no q_infer artifact for {dataset}, falling back to Sim");
        return None;
    }
    let (weights, biases) = python_layout(dp, mlp);
    let tables = FormatTables::new(spec, dp.quantizer());
    Some(XlaState { rt, weights, biases, tables, batches })
}

fn worker_loop(rx: mpsc::Receiver<Control>, ready_tx: mpsc::Sender<bool>, ws: WorkerSpec) {
    let dp = DeepPositron::compile(&ws.mlp, ws.spec);
    let xla = if ws.engine == Engine::Xla { build_xla(&ws.shard, &ws.dataset, &dp, &ws.mlp, ws.spec) } else { None };
    let batch_sizes: Vec<usize> = match &xla {
        Some(x) => x.batches.clone(),
        None => vec![ws.cfg.sim_batch.max(1)],
    };
    let max_batch = *batch_sizes.last().expect("batch size list is never empty");
    // Pre-warm: compile every batch-size executable and push one padded batch
    // through each BEFORE accepting traffic.
    if let Some(x) = &xla {
        let in_dim = ws.mlp.layers[0].in_dim;
        for &b in &x.batches {
            let zeros = vec![0.0; in_dim];
            if let Ok(exe) = x.rt.quantized_infer(&ws.dataset, b) {
                let _ = exe.run(&zeros, 1, &x.weights, &x.biases, &x.tables);
            }
        }
    }
    let _ = ready_tx.send(xla.is_some());
    if std::env::var("SERVE_TRACE").is_ok() {
        eprintln!(
            "[trace] worker {}#{} ready: engine={:?} xla={} batch_sizes={batch_sizes:?}",
            ws.shard,
            ws.index,
            ws.engine,
            xla.is_some()
        );
    }
    let mut pending: Vec<Request> = Vec::new();
    loop {
        // Block for the first request (or control message).
        if pending.is_empty() {
            match rx.recv() {
                Ok(Control::Req(r)) => pending.push(r),
                Ok(Control::Shutdown(done)) => {
                    finish(&rx, &mut pending, &ws, &dp, &xla, max_batch);
                    let _ = done.send(());
                    return;
                }
                Err(_) => return,
            }
        }
        // Coalesce until the batch fills or the wait deadline passes.
        let deadline = Instant::now() + ws.cfg.max_batch_wait;
        let mut shutdown: Option<mpsc::Sender<()>> = None;
        while pending.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Control::Req(r)) => pending.push(r),
                Ok(Control::Shutdown(done)) => {
                    shutdown = Some(done);
                    break;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        execute(&mut pending, &ws, &dp, &xla, max_batch);
        if let Some(done) = shutdown {
            finish(&rx, &mut pending, &ws, &dp, &xla, max_batch);
            let _ = done.send(());
            return;
        }
    }
}

/// Drain whatever is already queued and serve it before acknowledging
/// shutdown: every request submitted before `Shutdown` gets a reply.
fn finish(
    rx: &mpsc::Receiver<Control>,
    pending: &mut Vec<Request>,
    ws: &WorkerSpec,
    dp: &DeepPositron,
    xla: &Option<XlaState>,
    max_batch: usize,
) {
    while let Ok(ctl) = rx.try_recv() {
        if let Control::Req(r) = ctl {
            pending.push(r);
        }
    }
    execute(pending, ws, dp, xla, max_batch);
}

/// Execute everything in `pending` (in chunks of at most `max_batch`),
/// reply per request, and record shard metrics.
fn execute(
    pending: &mut Vec<Request>,
    ws: &WorkerSpec,
    dp: &DeepPositron,
    xla: &Option<XlaState>,
    max_batch: usize,
) {
    while !pending.is_empty() {
        let take = pending.len().min(max_batch);
        let batch: Vec<Request> = pending.drain(..take).collect();
        let rows = batch.len();
        let preds: Vec<usize> = match xla {
            Some(x) => {
                // Smallest compiled batch that fits (pad the remainder).
                let b = *x.batches.iter().find(|&&s| s >= rows).unwrap_or(&max_batch);
                let mut flat = Vec::with_capacity(rows * batch[0].x.len());
                for r in &batch {
                    flat.extend_from_slice(&r.x);
                }
                let t_exec = Instant::now();
                match x
                    .rt
                    .quantized_infer(&ws.dataset, b)
                    .and_then(|exe| exe.run(&flat, rows, &x.weights, &x.biases, &x.tables))
                {
                    Ok(logits) => {
                        if std::env::var("SERVE_TRACE").is_ok() {
                            let dt = t_exec.elapsed();
                            eprintln!("[trace] {}#{} batch rows={rows} pad={b} exec={dt:?}", ws.shard, ws.index);
                        }
                        (0..rows).map(|r| argmax(&logits[r * ws.classes..(r + 1) * ws.classes])).collect()
                    }
                    Err(e) => {
                        eprintln!("serve[{}#{}]: batch failed ({e}); using Sim", ws.shard, ws.index);
                        sim_predict_batch(dp, &batch)
                    }
                }
            }
            None => sim_predict_batch(dp, &batch),
        };
        // Reply (and compute latencies) OUTSIDE the shard-metrics lock, so
        // workers finishing batches concurrently never serialize on reply
        // delivery; then record the whole batch under one short lock.
        let mut latencies = Vec::with_capacity(rows);
        for (req, class) in batch.into_iter().zip(preds) {
            let latency_s = req.submitted.elapsed().as_secs_f64();
            latencies.push(latency_s);
            let _ = req.resp.send(InferReply { class, latency_s, worker: ws.index });
        }
        let mut m = ws.metrics.lock().unwrap();
        m.batches += 1;
        m.batch_sizes.push(rows);
        m.served += rows;
        if let Some(count) = m.per_worker.get_mut(ws.index) {
            *count += rows;
        }
        m.latencies_s.extend_from_slice(&latencies);
    }
}

/// Execute one flushed batch on the Sim engine: a single compiled-plan walk
/// for the whole batch, bit-identical to per-sample submission.
fn sim_predict_batch(dp: &DeepPositron, batch: &[Request]) -> Vec<usize> {
    let rows: Vec<&[f64]> = batch.iter().map(|r| r.x.as_slice()).collect();
    dp.predict_batch(&rows)
}

/// Transpose accel (out × in) weights into the AOT artifact's (in × out)
/// layout; biases pass through dequantized.
fn python_layout(dp: &DeepPositron, mlp: &Mlp) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let wq = dp.dequantized_weights();
    let bq = dp.dequantized_biases();
    let mut weights = Vec::with_capacity(wq.len());
    for (l, w) in mlp.layers.iter().zip(&wq) {
        let mut wio = vec![0.0; l.in_dim * l.out_dim];
        for o in 0..l.out_dim {
            for i in 0..l.in_dim {
                wio[i * l.out_dim + o] = w[o * l.in_dim + i];
            }
        }
        weights.push(wio);
    }
    (weights, bq)
}
