//! Shard workers: each worker thread owns its inference engine (bit-exact
//! Sim, or the PJRT/XLA fast path when artifacts exist) and runs the
//! deadline-heap dynamic batcher evolved from the original single-worker
//! server (`coordinator::server`).
//!
//! Both engines execute the flushed batch as a batch: XLA through the
//! compiled fixed-shape executables, Sim through the accelerator's compiled
//! execution plan ([`DeepPositron::forward_batch`] via
//! [`DeepPositron::predict_batch`]) — so the batcher's coalescing pays off
//! on the bit-exact path too, instead of degenerating into a per-sample
//! loop (DESIGN.md §8). Large flushed batches additionally fan out inside
//! `predict_batch` across the process-wide
//! [`WorkerPool`](crate::util::pool::WorkerPool) — ONE shared parallelism
//! budget for serve workers and batched inference, so `shards × workers`
//! threads plus within-batch fan-out never oversubscribe the machine
//! (DESIGN.md §12; `DEEP_POSITRON_POOL=1` pins every batch to its worker
//! thread).
//!
//! Overload semantics (DESIGN.md §9): each worker carries an atomic queue
//! depth, incremented by the router at admission and decremented here the
//! moment a request leaves the queue for execution (or for the floor, when
//! its deadline has passed). The router sheds with
//! [`ServeError::Overloaded`] once the depth reaches
//! [`WorkerConfig::max_queue`], so worker memory is bounded no matter how
//! hard clients flood.
//!
//! The batcher keeps pending requests in a min-heap keyed by each request's
//! *flush-by* instant: `submitted + max_batch_wait`, tightened by the
//! request's own deadline when one was set. The coalesce timer always waits
//! on the heap top, so (a) the window is anchored to the **oldest** pending
//! request — requests that queued during a slow batch are not made to wait a
//! fresh full window — and (b) an expired deadline wakes the worker to drop
//! the request (no compute, queue slot freed, client unblocked by the
//! dropped reply channel) instead of letting it ride to the next flush.
//!
//! Engine-per-thread is load-bearing: XLA handles are not `Send`, so all
//! device-side state lives and dies on one worker thread. Worker replicas of
//! the same format do NOT pay the quantization-table build N times — tables
//! come from the process-wide [`Quantizer::shared`](crate::formats::Quantizer::shared)
//! cache via [`DeepPositron::compile`].
//!
//! Fallback ladder (the router never has to care): requested `Engine::Xla`
//! degrades to Sim when the PJRT runtime cannot start, when the dataset has
//! no compiled `q_infer` artifact, or — per batch — when an execution fails.

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::accel::{argmax, DeepPositron, Mlp};
use crate::artifact::Artifact;
use crate::coordinator::experiments::Engine;
use crate::formats::{FormatSpec, MixedSpec};
use crate::obs::recorder::{FlightRecorder, TraceEvent, TraceId};
use crate::runtime::{artifacts_dir, FormatTables, Kind, Runtime};
use crate::serve::metrics::ShardStats;

/// One served prediction.
#[derive(Debug, Clone)]
pub struct InferReply {
    /// Predicted class index.
    pub class: usize,
    /// Queue + batch-wait + compute latency, seconds.
    pub latency_s: f64,
    /// Worker (within the shard) that served the request.
    pub worker: usize,
    /// The request's trace id (matches the flight recorder's
    /// [`TraceEvent::trace`] for per-request phase attribution).
    pub trace: u64,
}

/// Errors surfaced by the serving engine's client API.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// No shard is registered under the requested key.
    UnknownShard(String),
    /// The request's feature dimension does not match the shard's model.
    BadRequest {
        /// Features in the submitted vector.
        got: usize,
        /// Features the shard's model expects.
        want: usize,
    },
    /// The routed worker's queue is full: the request was shed at admission
    /// instead of being queued without bound. Back off and retry, or route
    /// elsewhere — nothing was enqueued.
    Overloaded {
        /// Shard label (`dataset/format`) that shed the request.
        shard: String,
        /// Worker queue depth observed at admission time (= `max_queue`).
        depth: usize,
    },
    /// A shard configuration was rejected at [`start`] time because it is
    /// internally inconsistent (feature/class counts that disagree with the
    /// model topology, a zero queue bound, …).
    ///
    /// [`start`]: crate::serve::ServeEngine::start
    BadShard {
        /// Shard label (`dataset/format`) of the rejected config.
        shard: String,
        /// What was inconsistent.
        reason: String,
    },
    /// The engine (or the routed worker) has already shut down.
    Closed,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownShard(key) => write!(f, "no shard registered for {key}"),
            ServeError::BadRequest { got, want } => {
                write!(f, "bad request: {got} features submitted, shard expects {want}")
            }
            ServeError::Overloaded { shard, depth } => {
                write!(f, "shard {shard} overloaded: worker queue full at depth {depth}, request shed")
            }
            ServeError::BadShard { shard, reason } => write!(f, "bad shard config {shard}: {reason}"),
            ServeError::Closed => write!(f, "serving engine is shut down"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Batching and admission knobs shared by a shard's workers.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Max time the batcher waits to fill a batch, measured from the oldest
    /// pending request's submission instant.
    pub max_batch_wait: Duration,
    /// Batch cap when no compiled artifact dictates one (Sim engine).
    pub sim_batch: usize,
    /// Admission bound: max requests a single worker may hold queued
    /// (channel + batcher heap, not yet executing). Submissions beyond this
    /// depth fail fast with [`ServeError::Overloaded`] instead of growing
    /// the queue — bounded memory and bounded queueing delay under
    /// sustained overload.
    pub max_queue: usize,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig { max_batch_wait: Duration::from_millis(2), sim_batch: 64, max_queue: 1024 }
    }
}

pub(crate) struct Request {
    /// Process-unique trace id, allocated at admission and threaded through
    /// to the reply + flight recorder.
    pub trace: TraceId,
    pub x: Vec<f64>,
    pub submitted: Instant,
    /// Serve-by instant; at flush time an expired request is dropped
    /// uncomputed (its reply channel closes, so the client's `recv` errors).
    pub deadline: Option<Instant>,
    pub resp: mpsc::Sender<InferReply>,
}

pub(crate) enum Control {
    Req(Request),
    Shutdown(mpsc::Sender<()>),
}

pub(crate) struct WorkerHandle {
    pub tx: mpsc::Sender<Control>,
    /// Admitted-but-not-yet-flushed request count; the router's admission
    /// gate and the power-of-two-choices load signal.
    pub depth: Arc<AtomicUsize>,
    pub join: Option<JoinHandle<()>>,
}

/// Everything a worker needs to start; moved onto its thread.
pub(crate) struct WorkerSpec {
    pub shard: String,
    pub dataset: String,
    pub index: usize,
    pub mlp: Mlp,
    pub spec: FormatSpec,
    /// Per-layer assignment of a tuned shard; `None` = uniform `spec`.
    pub mixed: Option<MixedSpec>,
    /// Packed `.dpz` artifact of a serve-from-artifact shard; when set, the
    /// execution plan compiles straight from the packed codes (millisecond
    /// cold start, DESIGN.md §16) and `mlp` is only the topology shell.
    pub artifact: Option<Arc<Artifact>>,
    pub engine: Engine,
    pub classes: usize,
    pub cfg: WorkerConfig,
    /// Lock-free shared shard counters (no mutex on any worker path).
    pub stats: Arc<ShardStats>,
    /// Engine-wide flight recorder for per-request phase traces.
    pub recorder: Arc<FlightRecorder>,
}

/// Spawn one worker WITHOUT waiting for warm-up; the returned receiver
/// fires once the worker is warm (model quantized, every XLA executable
/// compiled and exercised once), carrying whether the XLA fast path is
/// active. Callers spawn every worker first and then collect readiness, so
/// warm-up runs in parallel across the whole engine.
pub(crate) fn spawn(ws: WorkerSpec) -> (WorkerHandle, mpsc::Receiver<bool>) {
    let (tx, rx) = mpsc::channel::<Control>();
    let (ready_tx, ready_rx) = mpsc::channel::<bool>();
    let depth = Arc::new(AtomicUsize::new(0));
    let worker_depth = Arc::clone(&depth);
    let join = std::thread::spawn(move || worker_loop(rx, ready_tx, worker_depth, ws));
    (WorkerHandle { tx, depth, join: Some(join) }, ready_rx)
}

/// Per-worker XLA fast-path state (thread-local by construction).
struct XlaState {
    rt: Runtime,
    weights: Vec<Vec<f64>>,
    biases: Vec<Vec<f64>>,
    tables: FormatTables,
    batches: Vec<usize>,
}

/// Try to stand up the fast path; any failure means Sim.
fn build_xla(shard: &str, dataset: &str, dp: &DeepPositron, mlp: &Mlp, spec: FormatSpec) -> Option<XlaState> {
    let rt = match Runtime::new(&artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("serve[{shard}]: PJRT unavailable, falling back to Sim ({e})");
            return None;
        }
    };
    // Ascending + deduped by `Runtime::batches`'s contract — load-bearing
    // for padded-executable selection (`find(|s| s >= rows)`, `last()`).
    let batches = rt.batches(Kind::QInfer, dataset);
    if batches.is_empty() {
        eprintln!("serve[{shard}]: no q_infer artifact for {dataset}, falling back to Sim");
        return None;
    }
    let (weights, biases) = python_layout(dp, mlp);
    let tables = FormatTables::new(spec, dp.quantizer());
    Some(XlaState { rt, weights, biases, tables, batches })
}

/// One queued request plus its flush-by instant: the coalesce anchor
/// (`submitted + max_batch_wait`), tightened by the request deadline.
struct Pending {
    flush_by: Instant,
    /// Arrival tiebreak so equal instants stay FIFO.
    seq: u64,
    req: Request,
}

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        self.flush_by == other.flush_by && self.seq == other.seq
    }
}

impl Eq for Pending {}

impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Pending {
    /// Reversed on purpose: `BinaryHeap` is a max-heap, so the greatest
    /// element must be the EARLIEST flush-by (with the lowest seq).
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.flush_by.cmp(&self.flush_by).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Everything the flush path needs, bundled so the batcher's helpers stay
/// readable.
struct BatchCtx<'a> {
    ws: &'a WorkerSpec,
    depth: &'a AtomicUsize,
    dp: &'a DeepPositron,
    xla: &'a Option<XlaState>,
    max_batch: usize,
}

fn push_pending(pending: &mut BinaryHeap<Pending>, seq: &mut u64, wait: Duration, req: Request) {
    let mut flush_by = req.submitted + wait;
    if let Some(d) = req.deadline {
        flush_by = flush_by.min(d);
    }
    pending.push(Pending { flush_by, seq: *seq, req });
    *seq += 1;
}

fn worker_loop(rx: mpsc::Receiver<Control>, ready_tx: mpsc::Sender<bool>, depth: Arc<AtomicUsize>, ws: WorkerSpec) {
    // An artifact shard compiles straight from its packed codes — no f64
    // weight pass, which is the whole cold-start point. Otherwise a tuned
    // shard compiles the heterogeneous plan, and the uniform path is the
    // classic single-format compile (bit-identical for all-equal
    // assignments, so every arm executes the same math in the batcher).
    let dp = match (&ws.artifact, &ws.mixed) {
        (Some(art), _) => art.compile(),
        (None, Some(m)) => DeepPositron::compile_mixed(&ws.mlp, m.clone()),
        (None, None) => DeepPositron::compile(&ws.mlp, ws.spec),
    };
    let xla = if ws.engine == Engine::Xla && ws.artifact.is_none() && ws.mixed.is_none() && ws.mlp.is_dense() {
        build_xla(&ws.shard, &ws.dataset, &dp, &ws.mlp, ws.spec)
    } else {
        if ws.engine == Engine::Xla && ws.artifact.is_some() {
            eprintln!("serve[{}]: packed-artifact shards are Sim-native (no AOT executable), using Sim", ws.shard);
        } else if ws.engine == Engine::Xla && ws.mixed.is_some() {
            eprintln!("serve[{}]: mixed-precision plans are Sim-only (uniform AOT artifact), using Sim", ws.shard);
        } else if ws.engine == Engine::Xla {
            eprintln!("serve[{}]: conv layer IR is Sim-native (the AOT artifact is dense-only), using Sim", ws.shard);
        }
        None
    };
    let batch_sizes: Vec<usize> = match &xla {
        Some(x) => x.batches.clone(),
        None => vec![ws.cfg.sim_batch.max(1)],
    };
    // Both arms above yield at least one entry; the 1 fallback keeps this
    // total without a panic path (the serve lint zone bans them outright).
    let max_batch = batch_sizes.last().copied().unwrap_or(1);
    // Pre-warm: compile every batch-size executable and push one padded batch
    // through each BEFORE accepting traffic.
    if let Some(x) = &xla {
        let in_dim = ws.mlp.layers[0].in_dim;
        for &b in &x.batches {
            let zeros = vec![0.0; in_dim];
            if let Ok(exe) = x.rt.quantized_infer(&ws.dataset, b) {
                let _ = exe.run(&zeros, 1, &x.weights, &x.biases, &x.tables);
            }
        }
    }
    let _ = ready_tx.send(xla.is_some());
    if std::env::var("SERVE_TRACE").is_ok() {
        eprintln!(
            "[trace] worker {}#{} ready: engine={:?} xla={} batch_sizes={batch_sizes:?} max_queue={}",
            ws.shard,
            ws.index,
            ws.engine,
            xla.is_some(),
            ws.cfg.max_queue
        );
    }
    let wait = ws.cfg.max_batch_wait;
    let ctx = BatchCtx { ws: &ws, depth: &depth, dp: &dp, xla: &xla, max_batch };
    let mut pending: BinaryHeap<Pending> = BinaryHeap::new();
    let mut seq = 0u64;
    loop {
        // Block for the first request (or control message).
        if pending.is_empty() {
            match rx.recv() {
                Ok(Control::Req(r)) => push_pending(&mut pending, &mut seq, wait, r),
                Ok(Control::Shutdown(done)) => {
                    finish(&rx, &mut pending, &ctx);
                    let _ = done.send(());
                    return;
                }
                Err(_) => return,
            }
            continue;
        }
        // Coalesce until the batch fills or the heap's earliest flush-by
        // passes. The top of the heap is the oldest pending request's
        // coalesce anchor — or a sooner per-request deadline.
        let mut shutdown: Option<mpsc::Sender<()>> = None;
        let mut disconnected = false;
        while pending.len() < max_batch {
            // Non-empty by the branch above, but stay panic-free by
            // construction: an (impossible) empty heap just flushes early.
            let Some(top) = pending.peek() else { break };
            let wake = top.flush_by;
            let now = Instant::now();
            if now >= wake {
                break;
            }
            match rx.recv_timeout(wake - now) {
                Ok(Control::Req(r)) => push_pending(&mut pending, &mut seq, wait, r),
                Ok(Control::Shutdown(done)) => {
                    shutdown = Some(done);
                    break;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        if let Some(done) = shutdown {
            finish(&rx, &mut pending, &ctx);
            let _ = done.send(());
            return;
        }
        if disconnected {
            // Engine dropped without a shutdown handshake: serve what we
            // hold best-effort, then exit (re-entering coalesce would spin
            // on the dead channel).
            while !pending.is_empty() {
                flush(&mut pending, &ctx, true);
            }
            return;
        }
        // Batch full ⇒ forced flush; otherwise the coalesce timer fired.
        let force = pending.len() >= max_batch;
        flush(&mut pending, &ctx, force);
    }
}

/// Drain whatever is already queued and serve it before acknowledging
/// shutdown: every request *accepted* before `Shutdown` gets a reply
/// (expired-deadline requests are still dropped, same as any flush).
fn finish(rx: &mpsc::Receiver<Control>, pending: &mut BinaryHeap<Pending>, ctx: &BatchCtx<'_>) {
    let mut seq = u64::MAX / 2; // after any live seq; only relative order matters
    while let Ok(ctl) = rx.try_recv() {
        if let Control::Req(r) = ctl {
            push_pending(pending, &mut seq, ctx.ws.cfg.max_batch_wait, r);
        }
    }
    while !pending.is_empty() {
        flush(pending, ctx, true);
    }
}

/// Pop one heap entry into `batch` or, if its deadline already passed,
/// onto the floor (no compute; the client's `recv` errors when the reply
/// sender drops). Either way the request leaves the queue here — so
/// admission sees the slot free before any reply lands. Returns the
/// expired increment (0 or 1).
fn pop_into(pending: &mut BinaryHeap<Pending>, batch: &mut Vec<Request>, ctx: &BatchCtx<'_>, now: Instant) -> usize {
    let Some(p) = pending.pop() else { return 0 };
    ctx.depth.fetch_sub(1, Ordering::Release);
    if matches!(p.req.deadline, Some(d) if now >= d) {
        1
    } else {
        batch.push(p.req);
        0
    }
}

/// Flush one batch of up to `max_batch` requests in flush-by order.
///
/// `force` (batch full, shutdown drain, dead channel) pops
/// unconditionally. A timer-fired flush (`force == false`) pops only the
/// due prefix (`flush_by` ≤ now) — expired requests are dropped on the
/// way, and only once a *live* due request seeds the batch may everything
/// still pending ride along, so an expired deadline alone frees its queue
/// slot and unblocks its client without dragging younger requests into an
/// early, under-filled batch.
fn flush(pending: &mut BinaryHeap<Pending>, ctx: &BatchCtx<'_>, force: bool) {
    let now = Instant::now();
    let mut batch: Vec<Request> = Vec::with_capacity(pending.len().min(ctx.max_batch));
    let mut expired = 0usize;
    while batch.len() < ctx.max_batch
        && pending.peek().is_some_and(|p| force || !batch.is_empty() || p.flush_by <= now)
    {
        expired += pop_into(pending, &mut batch, ctx, now);
    }
    for _ in 0..expired {
        ctx.ws.stats.note_expired();
        ctx.ws.recorder.note_drop();
    }
    if !batch.is_empty() {
        // `now` is the batch's flush anchor: every popped request's queue
        // phase ends here and the shared compute phase starts here.
        execute(batch, ctx, now);
    }
}

/// Exact nanoseconds from `a` to `b` (0 if `b` is not after `a`): the trace
/// phases are differences of the same monotonic anchors, so they telescope
/// to the total without drift.
fn ns_between(a: Instant, b: Instant) -> u64 {
    b.saturating_duration_since(a).as_nanos().min(u64::MAX as u128) as u64
}

/// Execute one already-popped batch on the fast path (or Sim), reply per
/// request, and record shard stats + one flight-recorder trace event per
/// served request. `flushed_at` is the batch's flush anchor: the boundary
/// between every member's queue phase and the shared compute phase.
fn execute(batch: Vec<Request>, ctx: &BatchCtx<'_>, flushed_at: Instant) {
    let ws = ctx.ws;
    let rows = batch.len();
    let preds: Vec<usize> = match ctx.xla {
        Some(x) => {
            // Smallest compiled batch that fits (pad the remainder).
            let b = *x.batches.iter().find(|&&s| s >= rows).unwrap_or(&ctx.max_batch);
            let mut flat = Vec::with_capacity(rows * batch[0].x.len());
            for r in &batch {
                flat.extend_from_slice(&r.x);
            }
            let t_exec = Instant::now();
            match x
                .rt
                .quantized_infer(&ws.dataset, b)
                .and_then(|exe| exe.run(&flat, rows, &x.weights, &x.biases, &x.tables))
            {
                Ok(logits) => {
                    if std::env::var("SERVE_TRACE").is_ok() {
                        let dt = t_exec.elapsed();
                        eprintln!("[trace] {}#{} batch rows={rows} pad={b} exec={dt:?}", ws.shard, ws.index);
                    }
                    (0..rows).map(|r| argmax(&logits[r * ws.classes..(r + 1) * ws.classes])).collect()
                }
                Err(e) => {
                    eprintln!("serve[{}#{}]: batch failed ({e}); using Sim", ws.shard, ws.index);
                    sim_predict_batch(ctx.dp, &batch)
                }
            }
        }
        None => sim_predict_batch(ctx.dp, &batch),
    };
    // Inference is done for the whole batch: the shared compute phase ends
    // here; each member's reply phase runs from this anchor to its own send.
    let inferred_at = Instant::now();
    let compute_ns = ns_between(flushed_at, inferred_at);
    // Reply first, then record: stats are relaxed atomics and the recorder
    // takes one short poison-tolerant lock per batch, so workers finishing
    // batches concurrently never serialize on reply delivery.
    let mut events = Vec::with_capacity(rows);
    for (req, class) in batch.into_iter().zip(preds) {
        let latency = req.submitted.elapsed();
        let _ = req.resp.send(InferReply {
            class,
            latency_s: latency.as_secs_f64(),
            worker: ws.index,
            trace: req.trace.0,
        });
        let queue_ns = ns_between(req.submitted, flushed_at);
        let reply_ns = ns_between(inferred_at, Instant::now());
        ws.stats.record_latency(latency);
        events.push(TraceEvent {
            trace: req.trace.0,
            shard: ws.shard.clone(),
            worker: ws.index as u64,
            rows: rows as u64,
            queue_ns,
            compute_ns,
            reply_ns,
            total_ns: queue_ns + compute_ns + reply_ns,
        });
    }
    ws.stats.note_batch(ws.index, rows);
    ws.recorder.push_batch(&events);
}

/// Execute one flushed batch on the Sim engine: a single compiled-plan walk
/// for the whole batch, bit-identical to per-sample submission.
fn sim_predict_batch(dp: &DeepPositron, batch: &[Request]) -> Vec<usize> {
    let rows: Vec<&[f64]> = batch.iter().map(|r| r.x.as_slice()).collect();
    dp.predict_batch(&rows)
}

/// Transpose accel (out × in) weights into the AOT artifact's (in × out)
/// layout; biases pass through dequantized.
fn python_layout(dp: &DeepPositron, mlp: &Mlp) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let wq = dp.dequantized_weights();
    let bq = dp.dequantized_biases();
    let mut weights = Vec::with_capacity(wq.len());
    for (l, w) in mlp.layers.iter().zip(&wq) {
        let mut wio = vec![0.0; l.in_dim * l.out_dim];
        for o in 0..l.out_dim {
            for i in 0..l.in_dim {
                wio[i * l.out_dim + o] = w[o * l.in_dim + i];
            }
        }
        weights.push(wio);
    }
    (weights, bq)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_error_display_covers_new_variants() {
        let e = ServeError::Overloaded { shard: "iris/posit8es1".into(), depth: 64 };
        let s = e.to_string();
        assert!(s.contains("iris/posit8es1") && s.contains("64") && s.contains("shed"), "{s}");
        let e = ServeError::BadShard { shard: "iris/posit8es1".into(), reason: "num_features 5 != 4".into() };
        assert!(e.to_string().contains("num_features 5 != 4"));
    }

    #[test]
    fn default_worker_config_is_bounded() {
        let cfg = WorkerConfig::default();
        assert!(cfg.max_queue >= cfg.sim_batch, "queue bound should hold at least one full batch");
        assert!(cfg.max_queue < usize::MAX, "default admission must be bounded");
    }

    #[test]
    fn pending_heap_orders_by_flush_by_then_seq() {
        let t0 = Instant::now();
        let mk = |offset_ms: u64, seq: u64| {
            let (tx, _rx) = mpsc::channel();
            Pending {
                flush_by: t0 + Duration::from_millis(offset_ms),
                seq,
                req: Request { trace: TraceId(0), x: vec![], submitted: t0, deadline: None, resp: tx },
            }
        };
        let mut heap = BinaryHeap::new();
        heap.push(mk(30, 0));
        heap.push(mk(10, 1));
        heap.push(mk(10, 2));
        heap.push(mk(20, 3));
        let mut order = Vec::new();
        while let Some(p) = heap.pop() {
            order.push(((p.flush_by - t0).as_millis() as u64, p.seq));
        }
        assert_eq!(order, vec![(10, 1), (10, 2), (20, 3), (30, 0)], "min flush-by first, FIFO on ties");
    }

    #[test]
    fn push_pending_tightens_flush_by_with_deadline() {
        let t0 = Instant::now();
        let wait = Duration::from_millis(50);
        let (tx, _rx) = mpsc::channel();
        let mut heap = BinaryHeap::new();
        let mut seq = 0;
        let req = Request {
            trace: TraceId(0),
            x: vec![],
            submitted: t0,
            deadline: Some(t0 + Duration::from_millis(5)),
            resp: tx,
        };
        push_pending(&mut heap, &mut seq, wait, req);
        assert_eq!(heap.peek().unwrap().flush_by, t0 + Duration::from_millis(5));
        let (tx, _rx) = mpsc::channel();
        let req = Request { trace: TraceId(0), x: vec![], submitted: t0, deadline: None, resp: tx };
        push_pending(&mut heap, &mut seq, wait, req);
        assert_eq!(heap.len(), 2);
        // The deadline-tightened entry stays on top of the no-deadline one.
        assert_eq!(heap.peek().unwrap().flush_by, t0 + Duration::from_millis(5));
    }
}
