//! The sharded, multi-worker serving engine — the system the paper's
//! edge-deployment motivation scales up to.
//!
//! Architecture (DESIGN.md §7):
//!
//! * **Router** ([`router`]) — requests address a [`ShardKey`] (one shard
//!   per dataset × numeric format, the deployment-time choice Deep Positron
//!   makes per model); within a shard, requests go to the least-loaded of
//!   two candidate workers (power-of-two-choices over live queue depths) or
//!   pin to one via an affinity hash. Admission is bounded: a full worker
//!   queue sheds with [`ServeError::Overloaded`] instead of queueing
//!   without limit, so the engine degrades gracefully under sustained
//!   overload (DESIGN.md §9).
//! * **Worker pool** ([`worker`]) — each worker thread owns its engine (the
//!   bit-exact Sim datapath, or the PJRT/XLA fast path when artifacts
//!   exist; XLA handles are not `Send`) and runs deadline-heap dynamic
//!   batching: the coalesce window is anchored to the oldest pending
//!   request, and per-request deadlines
//!   ([`ServeEngine::submit_with_deadline`]) drop expired work at flush
//!   time without computing it. A shard with a format that has no compiled
//!   artifact degrades to Sim automatically.
//! * **Shared tables** — workers obtain quantization tables from the
//!   process-wide [`crate::formats::Quantizer::shared`] cache, so N replicas
//!   of one format build the sorted value/boundary tables once, not N times.
//! * **Tuned shards** — a shard may deploy a per-layer format assignment
//!   ([`ShardConfig::with_mixed`], typically built from a
//!   `crate::tune::TunePlan`): its workers compile the heterogeneous
//!   execution plan and its routing key is the assignment's `+`-joined
//!   name (DESIGN.md §10). Mixed shards always run the bit-exact Sim
//!   engine — the AOT artifact bakes in a uniform table shape. Plans tuned
//!   under sensitivity pruning carry their provenance (the `pruned=` line
//!   of the plan codec, DESIGN.md §13) through deployment: the serialized
//!   plan a shard was started from always says what the search pruned
//!   away and at what drop budget.
//! * **Metrics** ([`metrics`]) — per-shard throughput, batch occupancy,
//!   p50/p95/p99 latency, and overload accounting (shed / expired / live
//!   queue depths). All hot-path accounting is lock-free: workers record
//!   into [`ShardStats`] (relaxed atomics + a bounded
//!   [`crate::obs::LogHistogram`]), and [`ShardMetrics`] is an immutable
//!   snapshot taken on demand ([`ServeEngine::shard_metrics`]) or at
//!   shutdown (DESIGN.md §15).
//! * **Tracing** — every admitted request carries a process-unique trace id
//!   (returned in [`InferReply::trace`]); workers append a per-phase
//!   nanosecond breakdown (queue → compute → reply) to the engine-wide
//!   flight recorder ([`crate::obs::FlightRecorder`]), which dumps a
//!   strict-schema JSONL snapshot automatically when shed/expired counts
//!   spike past an armed threshold ([`ServeEngine::arm_trace_dump`]).
//!   [`ServeEngine::observe`] exports the whole engine (plus pool / tuner /
//!   LUT-cache counters) as an [`crate::obs::ObsSnapshot`].
//!
//! The single-shard server the repository started with lives on as a thin
//! facade over this engine in [`crate::coordinator::server`]. The scaling
//! behaviour (1 → 4 workers) is demonstrated by
//! `rust/benches/serve_throughput.rs`; the overload behaviour (bounded
//! depth, shedding, p99 under 4× offered load vs an unbounded queue) by
//! `rust/benches/serve_overload.rs`.

pub mod metrics;
pub mod router;
pub mod worker;

pub use metrics::{EngineMetrics, ShardMetrics, ShardStats};
pub use router::{ServeEngine, ShardConfig, ShardKey, RECORDER_CAPACITY};
pub use worker::{InferReply, ServeError, WorkerConfig};
