//! The sharded, multi-worker serving engine — the system the paper's
//! edge-deployment motivation scales up to.
//!
//! Architecture (DESIGN.md §7):
//!
//! * **Router** ([`router`]) — requests address a [`ShardKey`] (one shard
//!   per dataset × numeric format, the deployment-time choice Deep Positron
//!   makes per model); within a shard, requests spread round-robin across
//!   workers or pin to one via an affinity hash.
//! * **Worker pool** ([`worker`]) — each worker thread owns its engine (the
//!   bit-exact Sim datapath, or the PJRT/XLA fast path when artifacts
//!   exist; XLA handles are not `Send`) and runs deadline-based dynamic
//!   batching. A shard with a format that has no compiled artifact degrades
//!   to Sim automatically.
//! * **Shared tables** — workers obtain quantization tables from the
//!   process-wide [`crate::formats::Quantizer::shared`] cache, so N replicas
//!   of one format build the sorted value/boundary tables once, not N times.
//! * **Metrics** ([`metrics`]) — per-shard throughput, batch occupancy, and
//!   p50/p95/p99 latency, aggregated on shutdown.
//!
//! The single-shard server the repository started with lives on as a thin
//! facade over this engine in [`crate::coordinator::server`]. The scaling
//! behaviour (1 → 4 workers) is demonstrated by
//! `rust/benches/serve_throughput.rs`.

pub mod metrics;
pub mod router;
pub mod worker;

pub use metrics::{EngineMetrics, ShardMetrics};
pub use router::{ServeEngine, ShardConfig, ShardKey};
pub use worker::{InferReply, ServeError, WorkerConfig};
