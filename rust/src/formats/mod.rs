//! Bit-exact software implementations of the three numerical formats the
//! paper compares at equal bit-width — **posit(n, es)**, **floating
//! point(w_e, w_f)** (subnormal-capable, no NaN/Inf, per §4.3), and
//! **fixed-point(n, Q)** (§4.2) — plus the exact multiply-and-accumulate
//! (EMAC, §4.1) built on a Kulisch-style quire.
//!
//! These are the golden reference for the whole repository: the table-driven
//! quantizer ([`tables::Quantizer`]), the Deep Positron accelerator simulator
//! (`crate::accel`), and the AOT/XLA fast path are all validated against the
//! decode/encode/EMAC semantics defined here.

pub mod emac;
pub mod exact;
pub mod fixed;
pub mod float;
pub mod ops;
pub mod pack;
pub mod posit;
pub mod tables;

pub use emac::{quire_width_bits, DecodeLut, DecodedOp, Emac};
pub use pack::{BitReader, BitWriter, PackedCodes};
pub use exact::Exact;
pub use fixed::Fixed;
pub use float::Float;
pub use posit::Posit;
pub use tables::Quantizer;

/// Result of decoding a code word.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decoded {
    /// The (single, for posit/fixed) zero pattern.
    Zero,
    /// Posit "Not a Real" (`10...0`). Never produced by Deep Positron
    /// datapaths (all DNN tensors are real-valued, §4.4) but decodable.
    NaR,
    /// A finite nonzero value `(-1)^sign × mag × 2^exp`, exactly.
    Finite(Exact),
}

impl Decoded {
    /// The value as f64 (exact for all ≤16-bit formats). NaR maps to NaN.
    pub fn to_f64(&self) -> f64 {
        match self {
            Decoded::Zero => 0.0,
            Decoded::NaR => f64::NAN,
            Decoded::Finite(e) => e.to_f64(),
        }
    }

    /// The value as an [`Exact`]; NaR panics, Zero is exact zero.
    pub fn to_exact(&self) -> Exact {
        match self {
            Decoded::Zero => Exact::ZERO,
            Decoded::NaR => panic!("NaR has no exact value"),
            Decoded::Finite(e) => *e,
        }
    }
}

/// A low-precision numerical format: a total bit-width `n ≤ 16` plus a
/// bijection between (most) n-bit code words and real values.
///
/// Encoding (round-to-nearest, ties-to-even-code — the rounding the paper
/// uses for direct quantization, §5) is provided by [`tables::Quantizer`],
/// which works uniformly for any `Format` via its sorted value table.
pub trait Format {
    /// Total bit-width n (2..=16).
    fn n(&self) -> u32;

    /// Short machine name, e.g. `posit8es1`, `float8we4`, `fixed8q5`.
    fn name(&self) -> String;

    /// Decode an n-bit code word (stored in the low n bits of `code`).
    fn decode(&self, code: u16) -> Decoded;

    /// Does this code word denote a usable finite value (including zero)?
    /// Excludes NaR, reserved patterns, and redundant encodings (e.g. the
    /// IEEE-style negative zero, which the paper lists among float's
    /// deficiencies).
    fn is_canonical(&self, code: u16) -> bool;

    /// Largest finite magnitude.
    fn max_value(&self) -> f64;

    /// Smallest nonzero magnitude.
    fn min_pos(&self) -> f64;

    /// Whether a nonzero real rounds to zero when below `min_pos/2`
    /// (floats and fixed underflow; posits clamp to ±minpos instead).
    fn underflows_to_zero(&self) -> bool;

    /// Number of code words, `2^n`.
    fn num_codes(&self) -> u32 {
        1u32 << self.n()
    }

    /// Mask of the low n bits.
    fn mask(&self) -> u16 {
        if self.n() >= 16 {
            u16::MAX
        } else {
            ((1u32 << self.n()) - 1) as u16
        }
    }
}

/// A dynamically-typed format descriptor: the unit of sweeping in the
/// paper's evaluation (format family × bit-width × sub-parameter).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[allow(missing_docs)] // variant fields n/es/we/q are the paper's notation
pub enum FormatSpec {
    /// Posit(n, es) — §3.2.
    Posit { n: u32, es: u32 },
    /// Float(n, w_e) — §4.3.
    Float { n: u32, we: u32 },
    /// Fixed(n, Q) — §4.2.
    Fixed { n: u32, q: u32 },
}

impl FormatSpec {
    /// Instantiate the codec.
    pub fn build(&self) -> Box<dyn Format + Send + Sync> {
        match *self {
            FormatSpec::Posit { n, es } => Box::new(Posit::new(n, es)),
            FormatSpec::Float { n, we } => Box::new(Float::new(n, we)),
            FormatSpec::Fixed { n, q } => Box::new(Fixed::new(n, q)),
        }
    }

    /// Total bit-width n.
    pub fn n(&self) -> u32 {
        match *self {
            FormatSpec::Posit { n, .. } | FormatSpec::Float { n, .. } | FormatSpec::Fixed { n, .. } => n,
        }
    }

    /// The family label used in the paper's tables/figures.
    pub fn family(&self) -> &'static str {
        match self {
            FormatSpec::Posit { .. } => "posit",
            FormatSpec::Float { .. } => "float",
            FormatSpec::Fixed { .. } => "fixed",
        }
    }

    /// The sub-parameter the paper sweeps (es, w_e, or Q).
    pub fn sub_param(&self) -> u32 {
        match *self {
            FormatSpec::Posit { es, .. } => es,
            FormatSpec::Float { we, .. } => we,
            FormatSpec::Fixed { q, .. } => q,
        }
    }

    /// Machine name, e.g. `posit8es1` (parseable by [`FormatSpec::parse`]).
    pub fn name(&self) -> String {
        self.build().name()
    }

    /// Parse names like `posit8es1`, `float6we3`, `fixed8q5`.
    pub fn parse(s: &str) -> Option<FormatSpec> {
        fn split(s: &str, mid: &str) -> Option<(u32, u32)> {
            let idx = s.find(mid)?;
            let a = s[..idx].parse().ok()?;
            let b = s[idx + mid.len()..].parse().ok()?;
            Some((a, b))
        }
        if let Some(rest) = s.strip_prefix("posit") {
            let (n, es) = split(rest, "es")?;
            return Some(FormatSpec::Posit { n, es });
        }
        if let Some(rest) = s.strip_prefix("float") {
            let (n, we) = split(rest, "we")?;
            return Some(FormatSpec::Float { n, we });
        }
        if let Some(rest) = s.strip_prefix("fixed") {
            let (n, q) = split(rest, "q")?;
            return Some(FormatSpec::Fixed { n, q });
        }
        None
    }

    /// Whether [`FormatSpec::build`] (and the EMAC cost model) can actually
    /// instantiate this spec. `parse` accepts any syntactically-valid name
    /// (`posit64es9` parses fine), but the constructors assert their width
    /// bounds — callers holding untrusted names (plan files, CLI args) must
    /// check this before building, or they turn a bad input into a panic.
    pub fn is_supported(&self) -> bool {
        match *self {
            // Posit::new allows n >= 2, but the EMAC model's exponent
            // arithmetic needs the regime terminator + fraction split of
            // n >= 3; es beyond 4 is outside the paper's sweep and the LUTs.
            FormatSpec::Posit { n, es } => (3..=16).contains(&n) && es <= 4,
            FormatSpec::Float { n, we } => (3..=16).contains(&n) && we >= 1 && we + 2 <= n,
            FormatSpec::Fixed { n, q } => (2..=16).contains(&n) && q < n,
        }
    }

    /// The sweep grid the paper evaluates (§5): for a given bit-width,
    /// posit es ∈ {0,1,2}, float w_e ∈ {2..=5}, fixed Q ∈ {1..=n-2}.
    /// (es is capped at n−3 so the regime terminator + es bits fit; at
    /// n ≥ 5 the full paper range {0,1,2} is available.)
    ///
    /// ```
    /// use deep_positron::formats::FormatSpec;
    ///
    /// let grid = FormatSpec::sweep(8);
    /// // 3 posit + 4 float + 6 fixed configs at 8 bits.
    /// assert_eq!(grid.len(), 13);
    /// assert!(grid.contains(&FormatSpec::Posit { n: 8, es: 1 }));
    /// assert!(grid.iter().all(|spec| spec.n() == 8));
    /// // Every entry round-trips through its machine name.
    /// for spec in &grid {
    ///     assert_eq!(FormatSpec::parse(&spec.name()), Some(*spec));
    /// }
    /// ```
    pub fn sweep(n: u32) -> Vec<FormatSpec> {
        let mut v = Vec::new();
        for es in 0..=2u32.min(n.saturating_sub(3)) {
            v.push(FormatSpec::Posit { n, es });
        }
        for we in 2..=5u32.min(n.saturating_sub(2)) {
            v.push(FormatSpec::Float { n, we });
        }
        for q in 1..=n.saturating_sub(2) {
            v.push(FormatSpec::Fixed { n, q });
        }
        v
    }

    /// All specs of one family at bit-width n.
    pub fn sweep_family(n: u32, family: &str) -> Vec<FormatSpec> {
        Self::sweep(n).into_iter().filter(|s| s.family() == family).collect()
    }
}

impl std::fmt::Display for FormatSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// A per-layer format assignment — the unit the mixed-precision auto-tuner
/// (`crate::tune`) searches over and the heterogeneous accelerator compiles
/// (DESIGN.md §10).
///
/// Invariants: one [`FormatSpec`] per dense layer (never empty); layer `i`'s
/// weights, incoming activation codes, and quire all live in `layers()[i]`;
/// the *recode at the layer boundary* is layer `i`'s terminal round, which
/// rounds the exact quire value once, directly into layer `i + 1`'s format
/// (the last layer rounds into its own format). A uniform assignment is
/// therefore bit-identical to the classic single-format accelerator — the
/// recode target equals the layer format everywhere.
///
/// ```
/// use deep_positron::formats::{FormatSpec, MixedSpec};
///
/// let m = MixedSpec::parse("posit8es1+float6we3+fixed5q3").unwrap();
/// assert_eq!(m.len(), 3);
/// assert_eq!(m.name(), "posit8es1+float6we3+fixed5q3");
/// assert_eq!(m.is_uniform(), None);
/// let u = MixedSpec::uniform(FormatSpec::Posit { n: 8, es: 1 }, 3);
/// assert_eq!(u.is_uniform(), Some(FormatSpec::Posit { n: 8, es: 1 }));
/// ```
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct MixedSpec {
    layers: Vec<FormatSpec>,
}

impl MixedSpec {
    /// Assignment from an explicit per-layer list (panics if empty).
    pub fn new(layers: Vec<FormatSpec>) -> MixedSpec {
        assert!(!layers.is_empty(), "a MixedSpec needs at least one layer");
        MixedSpec { layers }
    }

    /// The all-layers-equal assignment — the classic uniform accelerator.
    pub fn uniform(spec: FormatSpec, num_layers: usize) -> MixedSpec {
        MixedSpec::new(vec![spec; num_layers])
    }

    /// The per-layer formats, input layer first.
    pub fn layers(&self) -> &[FormatSpec] {
        &self.layers
    }

    /// Number of layers.
    pub fn len(&self) -> usize {
        self.layers.len()
    }

    /// Always false (the constructor rejects empty assignments).
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// `Some(spec)` when every layer carries the same format.
    pub fn is_uniform(&self) -> Option<FormatSpec> {
        let first = self.layers[0];
        self.layers.iter().all(|&s| s == first).then_some(first)
    }

    /// A copy with layer `i` reassigned — the tuner's per-layer search move.
    pub fn with_layer(&self, i: usize, spec: FormatSpec) -> MixedSpec {
        let mut layers = self.layers.clone();
        layers[i] = spec;
        MixedSpec { layers }
    }

    /// Machine name: the per-layer names joined with `+`, e.g.
    /// `posit8es1+float6we3+fixed5q3` (parseable by [`MixedSpec::parse`];
    /// doubles as the serving engine's routing-key label for tuned shards).
    pub fn name(&self) -> String {
        self.layers.iter().map(FormatSpec::name).collect::<Vec<_>>().join("+")
    }

    /// Parse a `+`-joined assignment name (inverse of [`MixedSpec::name`]).
    pub fn parse(s: &str) -> Option<MixedSpec> {
        if s.is_empty() {
            return None;
        }
        let layers = s.split('+').map(FormatSpec::parse).collect::<Option<Vec<_>>>()?;
        Some(MixedSpec::new(layers))
    }
}

impl std::fmt::Display for MixedSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips() {
        for s in ["posit8es1", "posit5es0", "float8we4", "fixed8q5", "fixed6q3"] {
            let spec = FormatSpec::parse(s).unwrap();
            assert_eq!(spec.name(), s);
        }
        assert!(FormatSpec::parse("posit8").is_none());
        assert!(FormatSpec::parse("bogus8es1").is_none());
    }

    #[test]
    fn sweep_covers_all_families() {
        let specs = FormatSpec::sweep(8);
        assert!(specs.iter().any(|s| s.family() == "posit"));
        assert!(specs.iter().any(|s| s.family() == "float"));
        assert!(specs.iter().any(|s| s.family() == "fixed"));
        // posit es 0..=2, float we 2..=5, fixed q 1..=6
        assert_eq!(specs.len(), 3 + 4 + 6);
    }

    #[test]
    fn sweep_family_filters() {
        assert!(FormatSpec::sweep_family(8, "posit").iter().all(|s| s.family() == "posit"));
        assert_eq!(FormatSpec::sweep_family(8, "posit").len(), 3);
    }

    #[test]
    fn mixed_spec_round_trips_and_uniformity() {
        let m = MixedSpec::parse("posit8es1+float6we3+fixed5q3").unwrap();
        assert_eq!(MixedSpec::parse(&m.name()), Some(m.clone()));
        assert_eq!(m.is_uniform(), None);
        assert_eq!(m.len(), 3);
        let u = MixedSpec::uniform(FormatSpec::Float { n: 7, we: 3 }, 4);
        assert_eq!(u.is_uniform(), Some(FormatSpec::Float { n: 7, we: 3 }));
        assert_eq!(u.name(), "float7we3+float7we3+float7we3+float7we3");
        assert!(MixedSpec::parse("").is_none());
        assert!(MixedSpec::parse("posit8es1+bogus").is_none());
    }

    #[test]
    fn mixed_spec_with_layer_replaces_one_slot() {
        let u = MixedSpec::uniform(FormatSpec::Posit { n: 8, es: 1 }, 3);
        let m = u.with_layer(1, FormatSpec::Fixed { n: 5, q: 3 });
        assert_eq!(m.layers()[0], FormatSpec::Posit { n: 8, es: 1 });
        assert_eq!(m.layers()[1], FormatSpec::Fixed { n: 5, q: 3 });
        assert_eq!(m.layers()[2], FormatSpec::Posit { n: 8, es: 1 });
        // The original is untouched (value semantics for search moves).
        assert_eq!(u.is_uniform(), Some(FormatSpec::Posit { n: 8, es: 1 }));
    }
}
