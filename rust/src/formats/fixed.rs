//! Two's-complement fixed-point, §4.2 of the paper.
//!
//! Parameterized by total bits `n` and fractional bits `Q` (`n > Q`):
//! a code is a signed n-bit integer scaled by `2^−Q`. Characteristics:
//!
//! ```text
//! max = 2^−Q × (2^(n−1) − 1)
//! min = 2^−Q                      (smallest nonzero magnitude)
//! ```
//!
//! Arithmetic saturates (Algorithm 1 clips to the most positive / most
//! negative code on accumulator overflow).

use super::exact::Exact;
use super::{Decoded, Format};

/// Fixed-point format descriptor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Fixed {
    n: u32,
    q: u32,
}

impl Fixed {
    /// Fixed-point format with `n` total bits and `q` fractional bits.
    pub fn new(n: u32, q: u32) -> Fixed {
        assert!((2..=16).contains(&n), "fixed n out of range: {n}");
        assert!(q < n, "fixed Q must satisfy Q < n: q={q}, n={n}");
        Fixed { n, q }
    }

    /// Fractional bit count Q.
    pub fn q(&self) -> u32 {
        self.q
    }

    /// Interpret a code as the signed integer it stores.
    pub fn to_int(&self, code: u16) -> i32 {
        let code = (code & self.mask()) as i32;
        let sign_bit = 1i32 << (self.n - 1);
        if code & sign_bit != 0 {
            code - (1i32 << self.n)
        } else {
            code
        }
    }

    /// Pack a signed integer (must fit) into a code.
    pub fn from_int(&self, v: i32) -> u16 {
        debug_assert!(v >= -(1i32 << (self.n - 1)) && v < (1i32 << (self.n - 1)));
        (v as u32 as u16) & self.mask()
    }

    /// Most positive / most negative stored integers.
    pub fn int_max(&self) -> i32 {
        (1i32 << (self.n - 1)) - 1
    }

    /// Most negative stored integer, `−2^(n−1)`.
    pub fn int_min(&self) -> i32 {
        -(1i32 << (self.n - 1))
    }
}

impl Format for Fixed {
    fn n(&self) -> u32 {
        self.n
    }

    fn name(&self) -> String {
        format!("fixed{}q{}", self.n, self.q)
    }

    fn decode(&self, code: u16) -> Decoded {
        let v = self.to_int(code);
        if v == 0 {
            return Decoded::Zero;
        }
        Decoded::Finite(Exact::new(v < 0, v.unsigned_abs() as u128, -(self.q as i32)).canonical())
    }

    /// Every fixed-point pattern is a value.
    fn is_canonical(&self, _code: u16) -> bool {
        true
    }

    fn max_value(&self) -> f64 {
        self.int_max() as f64 * super::exact::pow2(-(self.q as i32))
    }

    fn min_pos(&self) -> f64 {
        super::exact::pow2(-(self.q as i32))
    }

    fn underflows_to_zero(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed8_q5_known_values() {
        let f = Fixed::new(8, 5);
        assert_eq!(f.decode(0).to_f64(), 0.0);
        assert_eq!(f.decode(32).to_f64(), 1.0); // 32 × 2^-5
        assert_eq!(f.decode(1).to_f64(), 1.0 / 32.0);
        assert_eq!(f.decode(0x7F).to_f64(), 127.0 / 32.0);
        assert_eq!(f.decode(0x80).to_f64(), -4.0); // -128 × 2^-5
        assert_eq!(f.decode(0xFF).to_f64(), -1.0 / 32.0);
        assert_eq!(f.max_value(), 127.0 / 32.0);
        assert_eq!(f.min_pos(), 1.0 / 32.0);
    }

    #[test]
    fn signed_roundtrip() {
        let f = Fixed::new(8, 4);
        for v in -128..=127 {
            assert_eq!(f.to_int(f.from_int(v)), v);
        }
    }

    #[test]
    fn monotone_in_signed_order() {
        let f = Fixed::new(6, 3);
        let mut prev = f64::NEG_INFINITY;
        for v in f.int_min()..=f.int_max() {
            let x = f.decode(f.from_int(v)).to_f64();
            assert!(x > prev);
            prev = x;
        }
    }

    #[test]
    fn q_zero_is_integers() {
        let f = Fixed::new(8, 0);
        assert_eq!(f.decode(5).to_f64(), 5.0);
        assert_eq!(f.max_value(), 127.0);
    }
}
