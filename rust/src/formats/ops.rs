//! Correctly-rounded scalar arithmetic (add/sub/mul, fused dot) for any
//! [`Format`].
//!
//! The paper's Table 2 situates this work among posit arithmetic-unit
//! generators (add/sub/mul units, [3, 16, 17, 23, 25]); these operations
//! give the repository the same capability in software — exact integer
//! arithmetic on decoded operands followed by a single
//! round-to-nearest-even, i.e. the result every correct hardware unit must
//! produce. They also serve as the oracle for EMAC edge-case tests.

use super::exact::Exact;
use super::tables::Quantizer;

/// A scalar ALU for one format. NaR/non-canonical inputs are rejected by
/// `decode` (DNN datapaths are real-valued, §4.4); [`ScalarAlu::is_nar`]
/// lets callers screen first.
pub struct ScalarAlu<'q> {
    q: &'q Quantizer,
}

impl<'q> ScalarAlu<'q> {
    /// ALU over one format's quantization tables.
    pub fn new(q: &'q Quantizer) -> ScalarAlu<'q> {
        ScalarAlu { q }
    }

    /// Whether `code` is NaR / non-canonical (no real value).
    pub fn is_nar(&self, code: u16) -> bool {
        self.q.decode(code).is_none()
    }

    fn get(&self, code: u16) -> Exact {
        self.q.decode(code).unwrap_or_else(|| panic!("{}: non-value code {code:#x}", self.q.name()))
    }

    /// Correctly-rounded sum of two code words.
    pub fn add(&self, a: u16, b: u16) -> u16 {
        let v = self.get(a).add(self.get(b));
        self.q.quantize_exact(&v).0
    }

    /// Correctly-rounded difference.
    pub fn sub(&self, a: u16, b: u16) -> u16 {
        let v = self.get(a).add(self.get(b).neg());
        self.q.quantize_exact(&v).0
    }

    /// Correctly-rounded product.
    pub fn mul(&self, a: u16, b: u16) -> u16 {
        let v = self.get(a).mul(self.get(b));
        self.q.quantize_exact(&v).0
    }

    /// Correctly-rounded quotient. Division is not closed over dyadic
    /// rationals, so the exact-value trick doesn't apply directly; instead
    /// we long-divide to `PREC` extra quotient bits and fold the remainder
    /// into a sticky bit — enough precision that round-to-nearest over the
    /// ≤16-bit target format is exact. Division by zero panics (posit
    /// hardware would produce NaR; Deep Positron datapaths never divide).
    pub fn div(&self, a: u16, b: u16) -> u16 {
        let num = self.get(a);
        let den = self.get(b);
        assert!(!den.is_zero(), "{}: division by zero", self.q.name());
        if num.is_zero() {
            return self.q.quantize_exact(&Exact::ZERO).0;
        }
        // Normalize: quotient of magnitudes with PREC fractional bits.
        const PREC: u32 = 40; // > 2×(16-bit significand) + guard
        let n = num.canonical();
        let d = den.canonical();
        let q_mag = ((n.mag as u128) << PREC) / d.mag as u128;
        let rem = ((n.mag as u128) << PREC) % d.mag as u128;
        // Sticky: if the remainder is nonzero the true quotient lies
        // strictly above q_mag×2^-PREC; nudge by half a ulp of the
        // low-order guard range so ties can never be hit spuriously.
        let sticky = (rem != 0) as u128;
        let v = Exact::new(n.sign ^ d.sign, (q_mag << 1) | sticky, n.exp - d.exp - PREC as i32 - 1);
        self.q.quantize_exact(&v).0
    }

    /// Inexact (per-step-rounded) MAC chain — the conventional unit the EMAC
    /// is compared against. Rounds after every product AND every addition,
    /// exactly like a fused-multiply-round/add-round pipeline.
    pub fn inexact_dot(&self, weights: &[u16], activations: &[u16]) -> u16 {
        assert_eq!(weights.len(), activations.len());
        let zero = self.q.quantize_f64(0.0).0;
        let mut acc = zero;
        for (&w, &a) in weights.iter().zip(activations) {
            let p = self.mul(w, a);
            acc = self.add(acc, p);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Fixed, Float, Format, Posit, Quantizer};
    use super::*;

    #[test]
    fn posit_add_known() {
        let q = Quantizer::new(&Posit::new(8, 0));
        let alu = ScalarAlu::new(&q);
        let (one, _) = q.quantize_f64(1.0);
        let (two, _) = q.quantize_f64(2.0);
        let (three, _) = q.quantize_f64(3.0);
        assert_eq!(alu.add(one, two), three);
        assert_eq!(alu.sub(three, two), one);
        assert_eq!(alu.mul(one, two), two);
    }

    #[test]
    fn add_commutes_and_mul_commutes() {
        let q = Quantizer::new(&Float::new(8, 4));
        let alu = ScalarAlu::new(&q);
        let samples: Vec<u16> = (0..=255u16).filter(|&c| q.decode(c).is_some()).step_by(7).collect();
        for &a in &samples {
            for &b in &samples {
                assert_eq!(alu.add(a, b), alu.add(b, a));
                assert_eq!(alu.mul(a, b), alu.mul(b, a));
            }
        }
    }

    #[test]
    fn mul_matches_f64_when_exact() {
        // Products of 8-bit float values are exact in f64 → correctly-rounded
        // result == quantize(f64 product).
        let q = Quantizer::new(&Float::new(8, 3));
        let alu = ScalarAlu::new(&q);
        for a in 0..=255u16 {
            for b in (0..=255u16).step_by(5) {
                let (Some(va), Some(vb)) = (q.decode(a), q.decode(b)) else { continue };
                let expect = q.quantize_f64(va.to_f64() * vb.to_f64()).0;
                assert_eq!(alu.mul(a, b), expect, "{a:#x} × {b:#x}");
            }
        }
    }

    #[test]
    fn div_exact_cases() {
        let q = Quantizer::new(&Posit::new(8, 1));
        let alu = ScalarAlu::new(&q);
        let code = |x: f64| q.quantize_f64(x).0;
        assert_eq!(alu.div(code(1.0), code(2.0)), code(0.5));
        assert_eq!(alu.div(code(3.0), code(2.0)), code(1.5));
        assert_eq!(alu.div(code(-1.0), code(4.0)), code(-0.25));
        assert_eq!(alu.div(code(0.0), code(3.0)), code(0.0));
    }

    #[test]
    fn div_is_correctly_rounded_vs_f64() {
        // For ≤8-bit operands the f64 quotient is within 2^-52 relative of
        // the true one while format boundaries are ≥2^-18 apart, so
        // rounding the f64 quotient is the correct answer except on exact
        // boundaries — which only occur for exactly-representable
        // quotients, handled exactly by both paths.
        for spec in ["posit8es0", "posit8es2", "float8we4", "fixed8q4"] {
            let fmt = crate::formats::FormatSpec::parse(spec).unwrap().build();
            let q = Quantizer::new(fmt.as_ref());
            let alu = ScalarAlu::new(&q);
            for a in (0..=255u16).step_by(3) {
                for b in (0..=255u16).step_by(7) {
                    let (Some(va), Some(vb)) = (q.decode(a), q.decode(b)) else { continue };
                    if vb.is_zero() {
                        continue;
                    }
                    let expect = q.quantize_f64(va.to_f64() / vb.to_f64()).0;
                    assert_eq!(alu.div(a, b), expect, "{spec}: {a:#x} / {b:#x}");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "division by zero")]
    fn div_by_zero_panics() {
        let q = Quantizer::new(&Posit::new(8, 0));
        let alu = ScalarAlu::new(&q);
        let one = q.quantize_f64(1.0).0;
        let _ = alu.div(one, 0);
    }

    #[test]
    fn inexact_dot_loses_what_emac_keeps() {
        // 64 × (minpos·minpos) : inexact chain rounds each product to… posit
        // never rounds to zero, so each product becomes minpos and the sum
        // GROWS too fast; fixed-point rounds each product to zero and the sum
        // stays zero; the EMAC gets both exactly right.
        let fixed = Fixed::new(8, 5);
        let qf = Quantizer::new(&fixed);
        let alu = ScalarAlu::new(&qf);
        let (minc, minv) = qf.quantize_f64(fixed.min_pos());
        assert_eq!(minv, fixed.min_pos());
        let w = vec![minc; 64];
        let acc = alu.inexact_dot(&w, &w);
        assert_eq!(qf.decode(acc).unwrap().to_f64(), 0.0, "per-step rounding must lose min²");

        let mut emac = super::super::Emac::new(&fixed, &qf, 64);
        let exact = emac.dot(&w, &w, None, false);
        // 64 × (2^-5)² = 2^-4 = 2 × minpos: representable.
        assert_eq!(qf.decode(exact).unwrap().to_f64(), 1.0 / 16.0);
    }
}
