//! The posit numerical format (Type III unum), §3.2 of the paper.
//!
//! An n-bit posit with `es` exponent bits encodes, per Eq. (1):
//!
//! ```text
//! (-1)^s × (2^(2^es))^k × 2^e × 1.f
//! ```
//!
//! where `k` is the signed run-length-encoded regime, `e` the unsigned
//! exponent, and `1.f` the fraction with hidden bit. Two patterns are
//! reserved: `00…0` (zero) and `10…0` ("Not a Real"). Negative posits are
//! decoded after two's complement. Decode mirrors the paper's Algorithm 3.

use super::exact::Exact;
use super::{Decoded, Format};

/// Posit format descriptor. Supports `2 ≤ n ≤ 16`, `es ≤ 4`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Posit {
    n: u32,
    es: u32,
}

impl Posit {
    /// Posit format with `n` total bits and `es` exponent bits.
    pub fn new(n: u32, es: u32) -> Posit {
        assert!((2..=16).contains(&n), "posit n out of range: {n}");
        assert!(es <= 4, "posit es out of range: {es}");
        Posit { n, es }
    }

    /// Exponent bit count es.
    pub fn es(&self) -> u32 {
        self.es
    }

    /// `useed = 2^(2^es)`, the regime scale-factor base.
    pub fn useed_log2(&self) -> i32 {
        1i32 << self.es
    }

    /// Scale factor (power of two) of the largest finite value:
    /// `max = useed^(n-2)`.
    pub fn max_sf(&self) -> i32 {
        (self.n as i32 - 2) * self.useed_log2()
    }

    /// The NaR pattern `10…0`.
    pub fn nar_code(&self) -> u16 {
        1u16 << (self.n - 1)
    }

    /// Decode the regime/exponent/fraction of a *positive* posit body
    /// (the low n-1 bits after sign handling). Returns (sf, frac_num,
    /// frac_bits): value = 2^sf × frac_num / 2^frac_bits, frac_num with
    /// hidden bit set.
    fn decode_body(&self, body: u16) -> (i32, u64, u32) {
        let nb = self.n - 1; // number of body bits
        debug_assert!(body != 0, "zero body handled by caller");
        let lead = (body >> (nb - 1)) & 1; // leading regime bit
        // Count the run of bits equal to `lead` starting at the top.
        let mut run = 0u32;
        for i in (0..nb).rev() {
            if (body >> i) & 1 == lead {
                run += 1;
            } else {
                break;
            }
        }
        let k: i32 = if lead == 1 { run as i32 - 1 } else { -(run as i32) };
        // Bits after the regime terminator (if any).
        let used = run + 1; // regime run + terminator
        let rem_bits = nb.saturating_sub(used);
        let rem = if rem_bits == 0 { 0u16 } else { body & (((1u32 << rem_bits) - 1) as u16) };
        // Exponent: the first `es` of the remaining bits (zero-padded on the
        // right if truncated by the regime).
        let (e, frac, frac_bits) = if rem_bits >= self.es {
            let fb = rem_bits - self.es;
            let e = (rem >> fb) as i32;
            let frac = rem & (((1u32 << fb) - 1) as u16);
            (e, frac as u64, fb)
        } else {
            // Exponent field truncated: the available bits are the HIGH bits
            // of e; missing low bits are zero.
            let e = ((rem as u32) << (self.es - rem_bits)) as i32;
            (e, 0u64, 0u32)
        };
        let sf = k * self.useed_log2() + e;
        let hidden = 1u64 << frac_bits;
        (sf, hidden | frac, frac_bits)
    }
}

impl Format for Posit {
    fn n(&self) -> u32 {
        self.n
    }

    fn name(&self) -> String {
        format!("posit{}es{}", self.n, self.es)
    }

    fn decode(&self, code: u16) -> Decoded {
        let code = code & self.mask();
        if code == 0 {
            return Decoded::Zero;
        }
        if code == self.nar_code() {
            return Decoded::NaR;
        }
        let sign = (code >> (self.n - 1)) & 1 == 1;
        // Negative posits: two's complement before decoding (Algorithm 3).
        let body = if sign {
            (code.wrapping_neg() & self.mask()) & !(1u16 << (self.n - 1))
        } else {
            code
        };
        let (sf, frac, frac_bits) = self.decode_body(body);
        // value = ±frac × 2^(sf - frac_bits)
        Decoded::Finite(Exact::new(sign, frac as u128, sf - frac_bits as i32).canonical())
    }

    fn is_canonical(&self, code: u16) -> bool {
        (code & self.mask()) != self.nar_code()
    }

    fn max_value(&self) -> f64 {
        super::exact::pow2(self.max_sf())
    }

    fn min_pos(&self) -> f64 {
        super::exact::pow2(-self.max_sf())
    }

    /// Posits never round a nonzero real to zero: they clamp to ±minpos.
    fn underflows_to_zero(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn val(p: &Posit, code: u16) -> f64 {
        p.decode(code).to_f64()
    }

    #[test]
    fn posit8_es0_known_values() {
        let p = Posit::new(8, 0);
        assert_eq!(val(&p, 0x00), 0.0);
        assert!(val(&p, 0x80).is_nan()); // NaR
        assert_eq!(val(&p, 0x40), 1.0); // 0100_0000
        assert_eq!(val(&p, 0x50), 1.5); // regime k=0, frac .1000
        assert_eq!(val(&p, 0x48), 1.25);
        assert_eq!(val(&p, 0x60), 2.0); // regime k=1
        assert_eq!(val(&p, 0x70), 4.0); // regime k=2
        assert_eq!(val(&p, 0x7F), 64.0); // maxpos = useed^(n-2) = 2^6
        assert_eq!(val(&p, 0x01), 1.0 / 64.0); // minpos
        assert_eq!(val(&p, 0x20), 0.5); // regime k=-1
        // Negatives: two's complement symmetry.
        assert_eq!(val(&p, 0xC0), -1.0); // -(0x40)
        assert_eq!(val(&p, 0xB0), -1.5);
        assert_eq!(val(&p, 0x81), -64.0); // most negative
    }

    #[test]
    fn posit8_es1_known_values() {
        let p = Posit::new(8, 1);
        assert_eq!(p.useed_log2(), 2); // useed = 4
        assert_eq!(val(&p, 0x40), 1.0);
        assert_eq!(val(&p, 0x50), 2.0); // e=1
        assert_eq!(val(&p, 0x60), 4.0); // k=1
        assert_eq!(val(&p, 0x7F), 4096.0); // useed^6 = 4^6
        assert_eq!(val(&p, 0x01), 1.0 / 4096.0);
        assert_eq!(val(&p, 0x48), 1.5); // frac bits: 0100_1000 -> k=0,e=0,f=.100
    }

    #[test]
    fn posit8_es2_extremes() {
        let p = Posit::new(8, 2);
        assert_eq!(p.max_value(), (16.0f64).powi(6)); // 2^24
        assert_eq!(p.min_pos(), (16.0f64).powi(-6));
        assert_eq!(val(&p, 0x7F), p.max_value());
        assert_eq!(val(&p, 0x01), p.min_pos());
    }

    #[test]
    fn posit16_es1_sample() {
        let p = Posit::new(16, 1);
        assert_eq!(val(&p, 0x4000), 1.0);
        assert_eq!(val(&p, 0x5000), 2.0);
        // maxpos = useed^14 = 4^14 = 2^28
        assert_eq!(val(&p, 0x7FFF), (2.0f64).powi(28));
    }

    #[test]
    fn decode_is_monotone_in_signed_code_order() {
        // Posits are ordered like 2's-complement integers — the property that
        // makes them compare "as if integers" in hardware.
        for es in 0..=2 {
            let p = Posit::new(8, es);
            let mut prev: Option<f64> = None;
            // Signed order: 0x81..=0xFF (negatives ascending), 0x00..=0x7F.
            let signed_order = (0x81u16..=0xFF).chain(0x00..=0x7F);
            for code in signed_order {
                let v = p.decode(code).to_f64();
                if let Some(pv) = prev {
                    assert!(v > pv, "posit8es{es} not monotone at code {code:#04x}: {pv} !< {v}");
                }
                prev = Some(v);
            }
        }
    }

    #[test]
    fn negation_is_twos_complement() {
        for es in 0..=2 {
            let p = Posit::new(8, es);
            for code in 1u16..=0xFF {
                if code == p.nar_code() {
                    continue;
                }
                let neg = code.wrapping_neg() & 0xFF;
                assert_eq!(
                    p.decode(code).to_f64(),
                    -p.decode(neg).to_f64(),
                    "2's complement negation failed for code {code:#04x} (es={es})"
                );
            }
        }
    }

    #[test]
    fn small_widths_decode() {
        // 5-bit posits (the paper's lower sweep bound).
        let p = Posit::new(5, 0);
        assert_eq!(val(&p, 0x08), 1.0); // 01000
        assert_eq!(val(&p, 0x0F), 8.0); // maxpos = 2^3
        assert_eq!(val(&p, 0x01), 0.125);
        let pe = Posit::new(5, 1);
        assert_eq!(val(&pe, 0x0F), 64.0); // 4^3
    }
}
