//! Exact (error-free) scaled-integer values.
//!
//! Every finite value representable by the ≤16-bit formats studied in the
//! paper is of the form `(-1)^sign × mag × 2^exp` with a small integer
//! magnitude. Representing decoded numbers this way lets the quire (EMAC)
//! accumulate **exactly** — the defining property of the paper's
//! exact-multiply-and-accumulate unit — and lets terminal rounding compare
//! against round-to-nearest decision boundaries without any floating-point
//! error.

use std::cmp::Ordering;

/// A sign-magnitude exact value `(-1)^sign × mag × 2^exp`.
///
/// `mag == 0` is canonical zero (sign must be `false`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Exact {
    /// Sign bit (`true` = negative).
    pub sign: bool,
    /// Integer magnitude.
    pub mag: u128,
    /// Power-of-two scale.
    pub exp: i32,
}

impl Exact {
    /// Canonical zero.
    pub const ZERO: Exact = Exact { sign: false, mag: 0, exp: 0 };

    /// Construct, normalizing zero.
    pub fn new(sign: bool, mag: u128, exp: i32) -> Exact {
        if mag == 0 {
            Exact::ZERO
        } else {
            Exact { sign, mag, exp }
        }
    }

    /// Canonicalize so that `mag` is odd (minimal mag, maximal exp).
    /// Zero is returned unchanged.
    pub fn canonical(self) -> Exact {
        if self.mag == 0 {
            return Exact::ZERO;
        }
        let tz = self.mag.trailing_zeros();
        Exact { sign: self.sign, mag: self.mag >> tz, exp: self.exp + tz as i32 }
    }

    /// Whether this is (canonical) zero.
    pub fn is_zero(&self) -> bool {
        self.mag == 0
    }

    /// Exact product. Panics on u128 overflow (cannot happen for decoded
    /// format values, whose magnitudes fit in ≤ 16 bits).
    pub fn mul(self, rhs: Exact) -> Exact {
        Exact::new(self.sign ^ rhs.sign, self.mag.checked_mul(rhs.mag).expect("exact mul overflow"), self.exp + rhs.exp)
    }

    /// Exact sum. Panics if the aligned magnitudes overflow u128.
    pub fn add(self, rhs: Exact) -> Exact {
        if self.is_zero() {
            return rhs;
        }
        if rhs.is_zero() {
            return self;
        }
        let e = self.exp.min(rhs.exp);
        let a = align_mag(self.mag, (self.exp - e) as u32);
        let b = align_mag(rhs.mag, (rhs.exp - e) as u32);
        match (self.sign, rhs.sign) {
            (false, false) => Exact::new(false, a + b, e),
            (true, true) => Exact::new(true, a + b, e),
            (sa, _sb) => match a.cmp(&b) {
                Ordering::Equal => Exact::ZERO,
                Ordering::Greater => Exact::new(sa, a - b, e),
                Ordering::Less => Exact::new(!sa, b - a, e),
            },
        }
    }

    /// Exact negation (zero stays canonical).
    pub fn neg(self) -> Exact {
        if self.is_zero() {
            self
        } else {
            Exact { sign: !self.sign, ..self }
        }
    }

    /// Absolute value.
    pub fn abs(self) -> Exact {
        Exact { sign: false, ..self }
    }

    /// Convert to f64. Exact whenever `mag` has ≤ 53 significant bits and the
    /// exponent is in range — always true for decoded format values and their
    /// pairwise sums (rounding boundaries).
    pub fn to_f64(self) -> f64 {
        let m = self.mag as f64; // exact for mag < 2^53
        debug_assert!(self.mag < (1u128 << 53), "Exact::to_f64 would round");
        let v = m * pow2(self.exp);
        if self.sign {
            -v
        } else {
            v
        }
    }

    /// Exact comparison of signed values.
    pub fn cmp_exact(&self, rhs: &Exact) -> Ordering {
        match (self.is_zero(), rhs.is_zero()) {
            (true, true) => Ordering::Equal,
            (true, false) => {
                if rhs.sign {
                    Ordering::Greater
                } else {
                    Ordering::Less
                }
            }
            (false, true) => {
                if self.sign {
                    Ordering::Less
                } else {
                    Ordering::Greater
                }
            }
            (false, false) => match (self.sign, rhs.sign) {
                (false, true) => Ordering::Greater,
                (true, false) => Ordering::Less,
                (false, false) => cmp_mag(self, rhs),
                (true, true) => cmp_mag(rhs, self),
            },
        }
    }

    /// Compare magnitudes: |self| vs |rhs|, exactly.
    pub fn cmp_mag(&self, rhs: &Exact) -> Ordering {
        if self.is_zero() || rhs.is_zero() {
            return self.mag.cmp(&rhs.mag);
        }
        cmp_mag(self, rhs)
    }

    /// Parse an f64 into an exact value. Panics on NaN/Inf.
    pub fn from_f64(x: f64) -> Exact {
        assert!(x.is_finite(), "Exact::from_f64 of non-finite");
        if x == 0.0 {
            return Exact::ZERO;
        }
        let bits = x.to_bits();
        let sign = bits >> 63 == 1;
        let biased = ((bits >> 52) & 0x7ff) as i32;
        let frac = bits & ((1u64 << 52) - 1);
        let (mag, exp) = if biased == 0 {
            (frac as u128, -1074)
        } else {
            ((frac | (1 << 52)) as u128, biased - 1075)
        };
        Exact::new(sign, mag, exp).canonical()
    }
}

/// Magnitude comparison for two nonzero values.
fn cmp_mag(a: &Exact, b: &Exact) -> Ordering {
    // Compare a.mag × 2^a.exp vs b.mag × 2^b.exp without overflow: compare
    // "bit positions" first, then aligned magnitudes.
    let top_a = a.exp as i64 + (128 - a.mag.leading_zeros()) as i64;
    let top_b = b.exp as i64 + (128 - b.mag.leading_zeros()) as i64;
    if top_a != top_b {
        return top_a.cmp(&top_b);
    }
    // Same magnitude order; align to common exponent. The shift is bounded by
    // the difference of leading-zero counts (< 128) and cannot overflow after
    // canonicalization for format-derived values; guard anyway.
    let a = a.canonical();
    let b = b.canonical();
    let e = a.exp.min(b.exp);
    let sa = (a.exp - e) as u32;
    let sb = (b.exp - e) as u32;
    if sa >= 128 || a.mag.leading_zeros() < sa {
        return Ordering::Greater; // a needs more headroom than exists => larger
    }
    if sb >= 128 || b.mag.leading_zeros() < sb {
        return Ordering::Less;
    }
    (a.mag << sa).cmp(&(b.mag << sb))
}

fn align_mag(mag: u128, shift: u32) -> u128 {
    assert!(shift < 128 && mag.leading_zeros() >= shift, "Exact::add alignment overflow");
    mag << shift
}

/// 2^e as f64 (exact), including the subnormal range.
pub fn pow2(e: i32) -> f64 {
    if (-1022..=1023).contains(&e) {
        f64::from_bits(((e + 1023) as u64) << 52)
    } else if (-1074..-1022).contains(&e) {
        f64::from_bits(1u64 << (e + 1074) as u32)
    } else if e < -1074 {
        0.0
    } else {
        f64::INFINITY
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_is_canonical() {
        assert_eq!(Exact::new(true, 0, 5), Exact::ZERO);
        assert!(Exact::ZERO.is_zero());
    }

    #[test]
    fn canonicalize_strips_trailing_zeros() {
        let v = Exact::new(false, 48, -3).canonical();
        assert_eq!(v, Exact { sign: false, mag: 3, exp: 1 });
        assert_eq!(v.to_f64(), 6.0);
    }

    #[test]
    fn add_mixed_signs() {
        let a = Exact::new(false, 3, 0); // 3
        let b = Exact::new(true, 1, 1); // -2
        assert_eq!(a.add(b).to_f64(), 1.0);
        assert_eq!(b.add(a).to_f64(), 1.0);
        assert_eq!(a.add(a.neg()), Exact::ZERO);
    }

    #[test]
    fn mul_signs_and_exponents() {
        let a = Exact::new(true, 3, -2); // -0.75
        let b = Exact::new(false, 5, 1); // 10
        assert_eq!(a.mul(b).to_f64(), -7.5);
    }

    #[test]
    fn cmp_across_scales() {
        let small = Exact::new(false, 1, -40);
        let big = Exact::new(false, 1, 40);
        assert_eq!(small.cmp_exact(&big), Ordering::Less);
        assert_eq!(big.cmp_exact(&small), Ordering::Greater);
        assert_eq!(big.neg().cmp_exact(&small), Ordering::Less);
        assert_eq!(Exact::ZERO.cmp_exact(&small.neg()), Ordering::Greater);
    }

    #[test]
    fn cmp_equal_after_alignment() {
        let a = Exact::new(false, 4, 0);
        let b = Exact::new(false, 1, 2);
        assert_eq!(a.cmp_exact(&b), Ordering::Equal);
    }

    #[test]
    fn from_f64_round_trips() {
        for &x in &[1.0, -1.5, 0.0, 0.09375, -1024.0, 0.1] {
            let e = Exact::from_f64(x);
            if x == 0.1 {
                // 0.1 is not a dyadic rational but from_f64 captures its exact
                // f64 bit value.
                assert_eq!(e.to_f64(), 0.1);
            } else {
                assert_eq!(e.to_f64(), x);
            }
        }
    }

    #[test]
    fn pow2_in_normal_range() {
        assert_eq!(pow2(0), 1.0);
        assert_eq!(pow2(10), 1024.0);
        assert_eq!(pow2(-10), 1.0 / 1024.0);
    }
}
