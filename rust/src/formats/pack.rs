//! Bit-packed code streams: the storage layer behind packed execution
//! plans and the `.dpz` model artifact (DESIGN.md §16).
//!
//! Every format the paper sweeps is ≤8 bits wide, yet quantized weights
//! historically travelled as `u16` codes inside `f64`-shaped containers — an
//! 8× memory tax on a datapath the paper argues is cache- and energy-bound.
//! This module provides the dense alternative: an MSB-first [`BitWriter`] /
//! [`BitReader`] pair over arbitrary ≤8-bit fields, and [`PackedCodes`], a
//! checksummed buffer holding `len` fixed-width code words in
//! `ceil(len·width/8)` bytes.
//!
//! Framing rules (shared with the artifact reader, which must reject any
//! stream this module would not produce):
//!
//! * fields are written most-significant-bit first, packed back to back
//!   with no alignment between fields;
//! * code widths above 8 (the 9..=16-bit formats) are split into two
//!   fields per code: the high `width − 8` bits, then the low 8 bits;
//! * the final byte is padded to a byte boundary with **1-bits** (a value
//!   no all-zero padding bug can fake), and a strict reader verifies the
//!   padding as well as the CRC;
//! * the checksum is CRC-32 (IEEE, reflected, polynomial `0xEDB88320`) over
//!   the packed bytes — the same function that seals whole `.dpz` files.

/// CRC-32 (IEEE 802.3) lookup table for the reflected polynomial
/// `0xEDB88320`, built at compile time.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 checksum (IEEE, reflected, init/xorout `0xFFFFFFFF`) — the
/// standard `zlib.crc32` function, so fixtures and external tooling can
/// reproduce every checksum in a `.dpz` file with stock libraries.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Lowercase-hex encoding of a byte string (two characters per byte) —
/// the `.dpz` payload encoding, chosen so artifacts stay line-oriented
/// UTF-8 text that diffs, greps, and survives `read_to_string`.
pub fn to_hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        s.push(char::from_digit((b >> 4) as u32, 16).expect("nibble < 16"));
        s.push(char::from_digit((b & 0xF) as u32, 16).expect("nibble < 16"));
    }
    s
}

/// Strict inverse of [`to_hex`]: `None` on odd length or any non-hex-digit
/// character (uppercase is accepted; whitespace is not).
pub fn from_hex(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    let digits: Option<Vec<u8>> = s.chars().map(|c| c.to_digit(16).map(|d| d as u8)).collect();
    let digits = digits?;
    Some(digits.chunks_exact(2).map(|p| (p[0] << 4) | p[1]).collect())
}

/// MSB-first bit-stream writer over arbitrary 1..=8-bit fields.
///
/// Fields are packed back to back with no alignment; [`BitWriter::finish`]
/// pads the final partial byte with 1-bits so every stream is a whole
/// number of bytes. The matching [`BitReader`] is told the data length in
/// bits and will refuse to hand padding back as data.
#[derive(Debug, Default)]
pub struct BitWriter {
    bytes: Vec<u8>,
    cur: u8,
    used: u32,
}

impl BitWriter {
    /// An empty stream.
    pub fn new() -> BitWriter {
        BitWriter::default()
    }

    /// Append the low `width` bits of `value` (1..=8 bits, value must fit).
    pub fn write(&mut self, value: u8, width: u32) {
        assert!((1..=8).contains(&width), "field width {width} outside 1..=8");
        assert!(width == 8 || (value as u32) < (1u32 << width), "value {value} does not fit in {width} bits");
        let v = value as u32;
        let mut left = width;
        while left > 0 {
            let take = left.min(8 - self.used);
            let chunk = (v >> (left - take)) & ((1u32 << take) - 1);
            self.cur = (self.cur << take) | chunk as u8;
            self.used += take;
            left -= take;
            if self.used == 8 {
                self.bytes.push(self.cur);
                self.cur = 0;
                self.used = 0;
            }
        }
    }

    /// Total data bits written so far (excluding any future padding).
    pub fn bits_written(&self) -> usize {
        self.bytes.len() * 8 + self.used as usize
    }

    /// Flush to a byte boundary, padding the final partial byte with
    /// 1-bits, and return the packed bytes.
    pub fn finish(mut self) -> Vec<u8> {
        if self.used > 0 {
            let pad = 8 - self.used;
            self.bytes.push((self.cur << pad) | ((1u8 << pad) - 1));
        }
        self.bytes
    }
}

/// MSB-first bit-stream reader: the strict inverse of [`BitWriter`].
///
/// Constructed with the *data* length in bits, so reads past the data —
/// into the 1-bit padding or beyond the buffer — fail with `None` instead
/// of fabricating codes.
#[derive(Debug)]
pub struct BitReader<'a> {
    bytes: &'a [u8],
    bit: usize,
    limit: usize,
}

impl<'a> BitReader<'a> {
    /// Reader over `bytes` holding exactly `data_bits` bits of data
    /// (the rest of the final byte being padding). `None` if the buffer
    /// cannot hold that many bits.
    pub fn new(bytes: &'a [u8], data_bits: usize) -> Option<BitReader<'a>> {
        if data_bits > bytes.len() * 8 {
            return None;
        }
        Some(BitReader { bytes, bit: 0, limit: data_bits })
    }

    /// Data bits left to read.
    pub fn remaining(&self) -> usize {
        self.limit - self.bit
    }

    /// Read the next `width`-bit field (1..=8); `None` once the field
    /// would cross into padding.
    pub fn read(&mut self, width: u32) -> Option<u8> {
        assert!((1..=8).contains(&width), "field width {width} outside 1..=8");
        if self.bit + width as usize > self.limit {
            return None;
        }
        let mut v = 0u32;
        for _ in 0..width {
            let bit = (self.bytes[self.bit / 8] >> (7 - (self.bit % 8))) & 1;
            v = (v << 1) | bit as u32;
            self.bit += 1;
        }
        Some(v as u8)
    }
}

/// A checksummed buffer of `len` fixed-width code words, bit-packed into
/// `ceil(len·width/8)` bytes — the unit the `.dpz` artifact stores per
/// weight/bias tensor.
///
/// Widths 1..=16 are supported; codes wider than 8 bits are split into a
/// high `width − 8`-bit field followed by a low 8-bit field (MSB-first, so
/// the byte stream reads in numeric order).
///
/// ```
/// use deep_positron::formats::pack::PackedCodes;
///
/// let codes = [0b10110u16, 0, 0b11111, 7];
/// let p = PackedCodes::pack(&codes, 5);
/// assert_eq!(p.bytes().len(), 3); // 20 bits of data, 4 bits of padding
/// assert_eq!(p.unpack(), codes);
/// let reparsed = PackedCodes::from_parts(5, 4, p.bytes().to_vec(), p.crc()).unwrap();
/// assert_eq!(reparsed.unpack(), codes);
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedCodes {
    width: u32,
    len: usize,
    bytes: Vec<u8>,
    crc: u32,
}

impl PackedCodes {
    /// Pack `codes` at `width` bits per code (1..=16; every code must fit).
    pub fn pack(codes: &[u16], width: u32) -> PackedCodes {
        assert!((1..=16).contains(&width), "code width {width} outside 1..=16");
        let mut w = BitWriter::new();
        for &c in codes {
            assert!(width == 16 || (c as u32) < (1u32 << width), "code {c} does not fit in {width} bits");
            if width > 8 {
                w.write((c >> 8) as u8, width - 8);
                w.write((c & 0xFF) as u8, 8);
            } else {
                w.write(c as u8, width);
            }
        }
        let bytes = w.finish();
        let crc = crc32(&bytes);
        PackedCodes { width, len: codes.len(), bytes, crc }
    }

    /// Rebuild from stored parts (the artifact-reader path), verifying
    /// every framing invariant: width in range, byte count exactly
    /// `ceil(len·width/8)`, all padding bits 1, and the CRC matching.
    pub fn from_parts(width: u32, len: usize, bytes: Vec<u8>, crc: u32) -> Result<PackedCodes, String> {
        if !(1..=16).contains(&width) {
            return Err(format!("code width {width} outside 1..=16"));
        }
        let data_bits = len * width as usize;
        let want_bytes = data_bits.div_ceil(8);
        if bytes.len() != want_bytes {
            return Err(format!("{} byte(s) for {len} codes of {width} bits (want {want_bytes})", bytes.len()));
        }
        let pad = want_bytes * 8 - data_bits;
        if pad > 0 {
            let mask = (1u8 << pad) - 1;
            let last = *bytes.last().expect("padding implies a final byte");
            if last & mask != mask {
                return Err(format!("final-byte padding {:#04x} is not all-ones in the low {pad} bit(s)", last));
            }
        }
        let got = crc32(&bytes);
        if got != crc {
            return Err(format!("payload crc {got:08x} != declared {crc:08x}"));
        }
        Ok(PackedCodes { width, len, bytes, crc })
    }

    /// Unpack back into code words (always `len` of them; lossless).
    pub fn unpack(&self) -> Vec<u16> {
        let mut r = BitReader::new(&self.bytes, self.len * self.width as usize)
            .expect("constructors guarantee the buffer holds len*width bits");
        let mut out = Vec::with_capacity(self.len);
        for _ in 0..self.len {
            let code = if self.width > 8 {
                let hi = r.read(self.width - 8).expect("in-bounds by construction") as u16;
                let lo = r.read(8).expect("in-bounds by construction") as u16;
                (hi << 8) | lo
            } else {
                r.read(self.width).expect("in-bounds by construction") as u16
            };
            out.push(code);
        }
        out
    }

    /// Bits per code word.
    pub fn width(&self) -> u32 {
        self.width
    }

    /// Number of code words.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the stream holds zero codes.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The packed bytes (padding included).
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// CRC-32 of the packed bytes.
    pub fn crc(&self) -> u32 {
        self.crc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_the_standard_check_value() {
        // The canonical CRC-32 test vector ("123456789" -> 0xCBF43926),
        // i.e. zlib.crc32 — fixtures are generated against that library.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn hex_round_trips_and_rejects_garbage() {
        let bytes = [0x00, 0xFF, 0x5A, 0x0B];
        assert_eq!(to_hex(&bytes), "00ff5a0b");
        assert_eq!(from_hex("00ff5a0b").as_deref(), Some(&bytes[..]));
        assert_eq!(from_hex("00FF5A0B").as_deref(), Some(&bytes[..]));
        assert_eq!(from_hex(""), Some(vec![]));
        assert!(from_hex("0").is_none(), "odd length");
        assert!(from_hex("0g").is_none(), "non-hex digit");
        assert!(from_hex("00 ff").is_none(), "whitespace");
    }

    #[test]
    fn bit_writer_is_msb_first() {
        // 0b101 · 0b01 · 0b1 · 0b00 -> 0b1010_1100 exactly one byte.
        let mut w = BitWriter::new();
        w.write(0b101, 3);
        w.write(0b01, 2);
        w.write(0b1, 1);
        w.write(0b00, 2);
        assert_eq!(w.bits_written(), 8);
        assert_eq!(w.finish(), vec![0b1010_1100]);
    }

    #[test]
    fn bit_writer_pads_with_ones_and_reader_stops_at_data() {
        let mut w = BitWriter::new();
        w.write(0b00000, 5); // an all-zero field, so padding is visible
        let bytes = w.finish();
        assert_eq!(bytes, vec![0b0000_0111]);
        let mut r = BitReader::new(&bytes, 5).unwrap();
        assert_eq!(r.read(5), Some(0));
        assert_eq!(r.remaining(), 0);
        assert_eq!(r.read(1), None, "padding must not read back as data");
    }

    #[test]
    fn fields_cross_byte_boundaries() {
        // Three 7-bit fields span 21 bits = 3 bytes with 3 padding bits.
        let mut w = BitWriter::new();
        for v in [0x55u8, 0x2A, 0x7F] {
            w.write(v, 7);
        }
        let bytes = w.finish();
        assert_eq!(bytes.len(), 3);
        let mut r = BitReader::new(&bytes, 21).unwrap();
        assert_eq!(r.read(7), Some(0x55));
        assert_eq!(r.read(7), Some(0x2A));
        assert_eq!(r.read(7), Some(0x7F));
        assert_eq!(r.read(7), None);
    }

    #[test]
    fn reader_rejects_oversized_data_lengths() {
        assert!(BitReader::new(&[0xFF], 9).is_none());
        assert!(BitReader::new(&[], 1).is_none());
        assert!(BitReader::new(&[], 0).is_some());
    }

    #[test]
    fn packed_codes_round_trip_across_widths() {
        for width in 1..=16u32 {
            let max = if width == 16 { u16::MAX as u32 } else { (1u32 << width) - 1 };
            let codes: Vec<u16> =
                (0..97u32).map(|i| ((i * 2_654_435_761u32.wrapping_mul(i + 1)) % (max + 1)) as u16).collect();
            let p = PackedCodes::pack(&codes, width);
            assert_eq!(p.width(), width);
            assert_eq!(p.len(), codes.len());
            assert_eq!(p.bytes().len(), (codes.len() * width as usize).div_ceil(8));
            assert_eq!(p.unpack(), codes, "width {width}");
            let q = PackedCodes::from_parts(width, p.len(), p.bytes().to_vec(), p.crc()).unwrap();
            assert_eq!(q, p);
        }
    }

    #[test]
    fn packed_codes_zero_length() {
        let p = PackedCodes::pack(&[], 5);
        assert!(p.is_empty());
        assert!(p.bytes().is_empty());
        assert_eq!(p.crc(), 0);
        assert_eq!(p.unpack(), Vec::<u16>::new());
        assert!(PackedCodes::from_parts(5, 0, vec![], 0).is_ok());
    }

    #[test]
    fn from_parts_rejects_every_framing_violation() {
        let p = PackedCodes::pack(&[0b10110, 0b00001, 0b11111], 5);
        // Flipped payload bit -> CRC mismatch.
        let mut bad = p.bytes().to_vec();
        bad[0] ^= 0x01;
        assert!(PackedCodes::from_parts(5, 3, bad, p.crc()).is_err());
        // Declared CRC wrong.
        assert!(PackedCodes::from_parts(5, 3, p.bytes().to_vec(), p.crc() ^ 1).is_err());
        // Wrong byte count for the declared (len, width).
        assert!(PackedCodes::from_parts(5, 4, p.bytes().to_vec(), p.crc()).is_err());
        // Zeroed padding bit (writer pads with ones).
        let mut unpadded = p.bytes().to_vec();
        *unpadded.last_mut().unwrap() &= !1;
        let crc = crc32(&unpadded);
        assert!(PackedCodes::from_parts(5, 3, unpadded, crc).is_err());
        // Width out of range.
        assert!(PackedCodes::from_parts(0, 3, vec![], 0).is_err());
        assert!(PackedCodes::from_parts(17, 3, vec![], 0).is_err());
    }

    #[test]
    fn wide_codes_split_hi_then_lo() {
        // A 16-bit code is stored as its big-endian byte pair.
        let p = PackedCodes::pack(&[0xBEEF], 16);
        assert_eq!(p.bytes(), &[0xBE, 0xEF]);
        assert_eq!(p.unpack(), vec![0xBEEF]);
        // At 12 bits the high nibble leads, MSB-first.
        let p = PackedCodes::pack(&[0xABC], 12);
        assert_eq!(p.bytes(), &[0xAB, 0xCF], "4 padding 1-bits close the stream");
        assert_eq!(p.unpack(), vec![0xABC]);
    }
}
