//! Low-precision IEEE-754-style floating point, §4.3 of the paper.
//!
//! Parameterized by `w_e` exponent bits and `w_f = n − 1 − w_e` fraction
//! bits. Matching the paper's Deep Positron implementation, NaN and ±Inf are
//! **not** representable: the all-ones exponent field is left unused (the
//! biased exponent saturates at `exp_max = 2^w_e − 2`), and the redundant
//! negative-zero pattern is non-canonical. Subnormals (biased exponent 0)
//! are supported. Characteristics (paper §4.3):
//!
//! ```text
//! bias    = 2^(w_e − 1) − 1
//! exp_max = 2^w_e − 2
//! max     = 2^(exp_max − bias) × (2 − 2^−w_f)
//! min     = 2^(1 − bias) × 2^−w_f          (smallest subnormal)
//! ```

use super::exact::Exact;
use super::{Decoded, Format};

/// Float format descriptor: n total bits, `we` exponent bits,
/// `wf = n - 1 - we` fraction bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Float {
    n: u32,
    we: u32,
}

impl Float {
    /// Float format with `n` total bits and `we` exponent bits.
    pub fn new(n: u32, we: u32) -> Float {
        assert!((3..=16).contains(&n), "float n out of range: {n}");
        assert!(we >= 1 && we <= n - 2, "float we out of range: we={we}, n={n}");
        Float { n, we }
    }

    /// Exponent bit count w_e.
    pub fn we(&self) -> u32 {
        self.we
    }

    /// Fraction bit count `w_f = n − 1 − w_e`.
    pub fn wf(&self) -> u32 {
        self.n - 1 - self.we
    }

    /// Exponent bias, `2^(w_e−1) − 1`.
    pub fn bias(&self) -> i32 {
        (1i32 << (self.we - 1)) - 1
    }

    /// Largest *used* biased exponent (`2^w_e − 2`; all-ones is reserved).
    pub fn exp_max(&self) -> i32 {
        (1i32 << self.we) - 2
    }

    fn fields(&self, code: u16) -> (bool, u32, u32) {
        let code = code & self.mask();
        let sign = (code >> (self.n - 1)) & 1 == 1;
        let e = ((code >> self.wf()) & (((1u32 << self.we) - 1) as u16)) as u32;
        let f = (code & (((1u32 << self.wf()) - 1) as u16)) as u32;
        (sign, e, f)
    }

    /// Assemble a code from fields.
    pub fn pack(&self, sign: bool, e: u32, f: u32) -> u16 {
        debug_assert!(e < (1 << self.we) && f < (1 << self.wf()));
        (((sign as u32) << (self.n - 1)) | (e << self.wf()) | f) as u16
    }
}

impl Format for Float {
    fn n(&self) -> u32 {
        self.n
    }

    fn name(&self) -> String {
        format!("float{}we{}", self.n, self.we)
    }

    fn decode(&self, code: u16) -> Decoded {
        let (sign, e, f) = self.fields(code);
        let wf = self.wf();
        if e == 0 {
            // Subnormal: (-1)^s × 0.f × 2^(1-bias)
            if f == 0 {
                return Decoded::Zero; // ±0 both decode to zero
            }
            let exp = 1 - self.bias() - wf as i32;
            return Decoded::Finite(Exact::new(sign, f as u128, exp).canonical());
        }
        // Normal: (-1)^s × 1.f × 2^(e-bias). The reserved all-ones exponent
        // still *decodes* by the same formula (it is merely never encoded);
        // is_canonical excludes it.
        let mag = (1u128 << wf) | f as u128;
        let exp = e as i32 - self.bias() - wf as i32;
        Decoded::Finite(Exact::new(sign, mag, exp).canonical())
    }

    fn is_canonical(&self, code: u16) -> bool {
        let (sign, e, f) = self.fields(code);
        if e == ((1u32 << self.we) - 1) {
            return false; // reserved (would-be Inf/NaN) exponent
        }
        if e == 0 && f == 0 && sign {
            return false; // negative zero is redundant
        }
        true
    }

    fn max_value(&self) -> f64 {
        let wf = self.wf();
        super::exact::pow2(self.exp_max() - self.bias()) * (2.0 - super::exact::pow2(-(wf as i32)))
    }

    fn min_pos(&self) -> f64 {
        super::exact::pow2(1 - self.bias() - self.wf() as i32)
    }

    fn underflows_to_zero(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn val(f: &Float, code: u16) -> f64 {
        f.decode(code).to_f64()
    }

    #[test]
    fn float8_we4_known_values() {
        // we=4, wf=3, bias=7 — the classic "IEEE-like" 8-bit float (E4M3
        // field layout, but with no Inf/NaN per the paper).
        let f = Float::new(8, 4);
        assert_eq!(f.bias(), 7);
        assert_eq!(f.wf(), 3);
        assert_eq!(val(&f, f.pack(false, 7, 0)), 1.0);
        assert_eq!(val(&f, f.pack(false, 7, 4)), 1.5);
        assert_eq!(val(&f, f.pack(false, 8, 0)), 2.0);
        assert_eq!(val(&f, f.pack(true, 7, 0)), -1.0);
        // Subnormals: 0.f × 2^-6
        assert_eq!(val(&f, f.pack(false, 0, 1)), 2.0f64.powi(-9)); // minpos
        assert_eq!(val(&f, f.pack(false, 0, 7)), 7.0 * 2.0f64.powi(-9));
        // max = 2^(14-7) × (2 - 2^-3) = 128 × 1.875 = 240
        assert_eq!(f.max_value(), 240.0);
        assert_eq!(val(&f, f.pack(false, 14, 7)), 240.0);
        assert_eq!(f.min_pos(), 2.0f64.powi(-9));
    }

    #[test]
    fn float8_we5_range() {
        let f = Float::new(8, 5);
        assert_eq!(f.bias(), 15);
        assert_eq!(f.wf(), 2);
        // max = 2^(30-15) × (2 - 2^-2) = 32768 × 1.75
        assert_eq!(f.max_value(), 57344.0);
        assert_eq!(f.min_pos(), 2.0f64.powi(-16));
    }

    #[test]
    fn zero_codes() {
        let f = Float::new(8, 4);
        assert_eq!(f.decode(0x00), Decoded::Zero);
        assert_eq!(f.decode(0x80), Decoded::Zero); // -0 decodes to 0
        assert!(f.is_canonical(0x00));
        assert!(!f.is_canonical(0x80)); // but is not canonical
    }

    #[test]
    fn reserved_exponent_not_canonical() {
        let f = Float::new(8, 4);
        for frac in 0..8u32 {
            assert!(!f.is_canonical(f.pack(false, 15, frac)));
            assert!(!f.is_canonical(f.pack(true, 15, frac)));
        }
        // Canonical code count: 2^8 - 2×2^3 (reserved exp) - 1 (neg zero)
        let count = (0u16..256).filter(|&c| f.is_canonical(c)).count();
        assert_eq!(count, 256 - 16 - 1);
    }

    #[test]
    fn positive_codes_monotone() {
        for we in 2..=5 {
            let f = Float::new(8, we);
            let mut prev = -1.0;
            for code in 0..(1u16 << 7) {
                if !f.is_canonical(code) {
                    continue;
                }
                let v = val(&f, code);
                assert!(v > prev, "float8we{we} not monotone at {code:#04x}");
                prev = v;
            }
        }
    }

    #[test]
    fn small_float_5bit() {
        // n=5, we=2, wf=2, bias=1
        let f = Float::new(5, 2);
        assert_eq!(val(&f, f.pack(false, 1, 0)), 1.0);
        assert_eq!(val(&f, f.pack(false, 2, 2)), 3.0);
        assert_eq!(f.max_value(), 2.0 * 1.75);
    }
}
