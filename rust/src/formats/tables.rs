//! Table-driven, format-generic quantization.
//!
//! For any [`Format`] we enumerate the canonical codes, sort their exact
//! values, and precompute round-to-nearest decision boundaries (midpoints)
//! plus tie directions ("ties to even **code**", the rounding the paper uses
//! when directly quantizing 32-bit-float parameters, §5). This gives one
//! uniform, provably-correct encoder for posit/float/fixed at any bit-width,
//! and it is exactly the representation the AOT'd XLA graphs consume: the
//! quantized-inference artifact takes `(values, boundaries, tie_up)` tables
//! as runtime inputs, so ONE artifact per network topology serves every
//! format — see DESIGN.md §2.
//!
//! All boundary comparisons can also be made in exact integer arithmetic
//! ([`Quantizer::quantize_exact`]), which is what the EMAC's terminal
//! rounding uses: the quire value never touches f64.

use std::cmp::Ordering;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::{Arc, Mutex, OnceLock};

use super::exact::Exact;
use super::{Decoded, Format, FormatSpec};

/// Process-wide cache behind [`Quantizer::shared`].
static SHARED_TABLES: OnceLock<Mutex<HashMap<FormatSpec, Arc<Quantizer>>>> = OnceLock::new();
/// Count of cache-miss table builds (observable in tests/benches).
static SHARED_BUILDS: AtomicUsize = AtomicUsize::new(0);

/// Precomputed quantization tables for one format instance.
#[derive(Debug, Clone)]
pub struct Quantizer {
    name: String,
    n: u32,
    /// Sorted (ascending) distinct finite values, as exact f64.
    values: Vec<f64>,
    /// Exact form of each value.
    exacts: Vec<Exact>,
    /// Code word for each value.
    codes: Vec<u16>,
    /// Midpoints between adjacent values (`len = values.len()-1`), exact f64.
    bounds: Vec<f64>,
    /// Exact `v_i + v_{i+1}` (twice the midpoint) for error-free tie tests.
    bound_sums: Vec<Exact>,
    /// On an exact tie at `bounds[i]`: round up to `values[i+1]`?
    /// (Chosen so the selected code has even LSB.)
    tie_up: Vec<bool>,
    /// Code → table index (None for non-canonical codes).
    code_index: Vec<Option<u32>>,
    /// Index of value 0.0.
    zero_idx: usize,
    underflows_to_zero: bool,
    min_pos: f64,
    max_value: f64,
}

impl Quantizer {
    /// Build tables by exhaustively decoding every canonical code.
    pub fn new(fmt: &dyn Format) -> Quantizer {
        let ncodes = fmt.num_codes();
        let mut entries: Vec<(Exact, u16)> = Vec::with_capacity(ncodes as usize);
        for code in 0..ncodes {
            let code = code as u16;
            if !fmt.is_canonical(code) {
                continue;
            }
            match fmt.decode(code) {
                Decoded::Zero => entries.push((Exact::ZERO, code)),
                Decoded::Finite(e) => entries.push((e, code)),
                Decoded::NaR => unreachable!("NaR must be non-canonical"),
            }
        }
        entries.sort_by(|a, b| a.0.cmp_exact(&b.0));
        // Values must be strictly increasing (canonical codes are distinct).
        for w in entries.windows(2) {
            assert_eq!(
                w[0].0.cmp_exact(&w[1].0),
                Ordering::Less,
                "{}: duplicate canonical values for codes {:#x}, {:#x}",
                fmt.name(),
                w[0].1,
                w[1].1
            );
        }
        let exacts: Vec<Exact> = entries.iter().map(|e| e.0).collect();
        let codes: Vec<u16> = entries.iter().map(|e| e.1).collect();
        let values: Vec<f64> = exacts.iter().map(|e| e.to_f64()).collect();
        let zero_idx = exacts.iter().position(|e| e.is_zero()).expect("no zero value in format");

        let mut bounds = Vec::with_capacity(values.len() - 1);
        let mut bound_sums = Vec::with_capacity(values.len() - 1);
        let mut tie_up = Vec::with_capacity(values.len() - 1);
        for i in 0..values.len() - 1 {
            let sum = exacts[i].add(exacts[i + 1]).canonical();
            // Midpoint = sum/2: exact in f64 because adjacent format values
            // have nearby exponents and few significant bits.
            bounds.push(Exact::new(sum.sign, sum.mag, sum.exp - 1).to_f64());
            bound_sums.push(sum);
            // Ties go to the even code ("round to nearest, ties to even").
            let up_even = codes[i + 1] & 1 == 0;
            let down_even = codes[i] & 1 == 0;
            debug_assert!(
                up_even != down_even || !up_even,
                "{}: adjacent codes {:#x},{:#x} have identical parity",
                fmt.name(),
                codes[i],
                codes[i + 1]
            );
            tie_up.push(up_even);
        }

        let mut code_index = vec![None; ncodes as usize];
        for (i, &c) in codes.iter().enumerate() {
            code_index[c as usize] = Some(i as u32);
        }

        Quantizer {
            name: fmt.name(),
            n: fmt.n(),
            values,
            exacts,
            codes,
            bounds,
            bound_sums,
            tie_up,
            code_index,
            zero_idx,
            underflows_to_zero: fmt.underflows_to_zero(),
            min_pos: fmt.min_pos(),
            max_value: fmt.max_value(),
        }
    }

    /// The process-wide shared table for `spec`: built once, then handed out
    /// as cheap `Arc` clones. This is the serving engine's table cache — N
    /// workers of the same format share one sorted value/boundary table
    /// instead of rebuilding it N times ([`crate::serve`]).
    pub fn shared(spec: FormatSpec) -> Arc<Quantizer> {
        let cache = SHARED_TABLES.get_or_init(|| Mutex::new(HashMap::new()));
        let mut map = cache.lock().unwrap();
        if let Some(q) = map.get(&spec) {
            return Arc::clone(q);
        }
        SHARED_BUILDS.fetch_add(1, AtomicOrdering::Relaxed);
        let q = Arc::new(Quantizer::new(spec.build().as_ref()));
        map.insert(spec, Arc::clone(&q));
        q
    }

    /// How many cache-miss builds [`Quantizer::shared`] has performed so far
    /// in this process (monotone; used to assert table reuse in tests).
    pub fn shared_builds() -> usize {
        SHARED_BUILDS.load(AtomicOrdering::Relaxed)
    }

    /// The format's machine name, e.g. `posit8es1`.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Total bit-width n of the format.
    pub fn n(&self) -> u32 {
        self.n
    }

    /// Number of distinct finite values (canonical codes).
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// Whether the table is empty (never true for a valid format).
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Sorted (ascending) distinct finite values.
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Code word for each entry of [`Quantizer::values`].
    pub fn codes(&self) -> &[u16] {
        &self.codes
    }

    /// Round-to-nearest decision boundaries (midpoints between adjacent
    /// values), `len() - 1` entries.
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Tie direction at each boundary: round up to the higher value?
    pub fn tie_up(&self) -> &[bool] {
        &self.tie_up
    }

    /// Largest finite magnitude of the format.
    pub fn max_value(&self) -> f64 {
        self.max_value
    }

    /// Smallest nonzero magnitude of the format.
    pub fn min_pos(&self) -> f64 {
        self.min_pos
    }

    /// Code word of value 0.0 — the ReLU clamp target and the inexact-MAC
    /// accumulator seed (identical to `quantize_exact(&Exact::ZERO).0`,
    /// without the boundary search).
    pub fn zero_code(&self) -> u16 {
        self.codes[self.zero_idx]
    }

    /// Exact value of a canonical code (None otherwise).
    pub fn decode(&self, code: u16) -> Option<Exact> {
        self.code_index.get(code as usize).copied().flatten().map(|i| self.exacts[i as usize])
    }

    /// Table index of a canonical code.
    pub fn index_of(&self, code: u16) -> Option<usize> {
        self.code_index.get(code as usize).copied().flatten().map(|i| i as usize)
    }

    /// Round-to-nearest (ties to even code) quantization of an f64.
    /// Returns (code, dequantized value). Saturates at ±max; formats with
    /// `underflows_to_zero() == false` (posit) clamp small nonzero inputs to
    /// ±minpos instead of rounding them to zero.
    pub fn quantize_f64(&self, x: f64) -> (u16, f64) {
        assert!(!x.is_nan(), "cannot quantize NaN");
        // partition_point: first i with bounds[i] >= x; x rounds above every
        // gap strictly below the midpoint.
        let mut idx = self.bounds.partition_point(|&b| b < x);
        if idx < self.bounds.len() && self.bounds[idx] == x {
            // Exact tie.
            if self.tie_up[idx] {
                idx += 1;
            }
        }
        self.finish(idx, x != 0.0, x > 0.0)
    }

    /// Quantize an exact value (the quire datapath — no f64 anywhere).
    pub fn quantize_exact(&self, x: &Exact) -> (u16, f64) {
        let two_x = if x.is_zero() { *x } else { Exact { exp: x.exp + 1, ..*x } };
        // Monotone predicate: "x rounds strictly above gap i".
        let idx = partition_point(self.bound_sums.len(), |i| {
            match two_x.cmp_exact(&self.bound_sums[i]) {
                Ordering::Greater => true,
                Ordering::Equal => self.tie_up[i],
                Ordering::Less => false,
            }
        });
        self.finish(idx, !x.is_zero(), !x.sign && !x.is_zero())
    }

    fn finish(&self, mut idx: usize, nonzero: bool, positive: bool) -> (u16, f64) {
        if !self.underflows_to_zero && nonzero && idx == self.zero_idx {
            // Posit: nonzero reals never round to zero — clamp to ±minpos.
            idx = if positive { self.zero_idx + 1 } else { self.zero_idx - 1 };
        }
        (self.codes[idx], self.values[idx])
    }

    /// Quantize a slice; returns (codes, dequantized values).
    pub fn quantize_slice(&self, xs: &[f64]) -> (Vec<u16>, Vec<f64>) {
        let mut codes = Vec::with_capacity(xs.len());
        let mut vals = Vec::with_capacity(xs.len());
        for &x in xs {
            let (c, v) = self.quantize_f64(x);
            codes.push(c);
            vals.push(v);
        }
        (codes, vals)
    }

    /// Dequantize a slice of codes (non-canonical codes panic).
    pub fn dequantize_slice(&self, codes: &[u16]) -> Vec<f64> {
        codes
            .iter()
            .map(|&c| {
                let i = self.index_of(c).unwrap_or_else(|| panic!("{}: non-canonical code {c:#x}", self.name));
                self.values[i]
            })
            .collect()
    }

    /// Tables padded to `cap` entries for fixed-shape HLO inputs:
    /// (values padded with max, boundaries padded with +inf, tie flags as
    /// 0.0/1.0 padded with 0). `cap` must be ≥ `len()`.
    pub fn padded_tables(&self, cap: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        assert!(cap >= self.len(), "{}: cap {cap} < table size {}", self.name, self.len());
        let mut v = self.values.clone();
        v.resize(cap, *self.values.last().unwrap());
        let mut b: Vec<f64> = self.bounds.clone();
        b.resize(cap - 1, f64::INFINITY);
        let mut t: Vec<f64> = self.tie_up.iter().map(|&u| if u { 1.0 } else { 0.0 }).collect();
        t.resize(cap - 1, 0.0);
        (v, b, t)
    }

    /// Mean-squared quantization error of a tensor (paper Eq. 3).
    pub fn mse(&self, xs: &[f64]) -> f64 {
        let mut acc = 0.0;
        for &x in xs {
            let (_, v) = self.quantize_f64(x);
            let d = x - v;
            acc += d * d;
        }
        acc / xs.len() as f64
    }
}

fn partition_point(len: usize, pred: impl Fn(usize) -> bool) -> usize {
    let mut lo = 0usize;
    let mut hi = len;
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if pred(mid) {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::super::{Fixed, Float, Posit};
    use super::*;

    #[test]
    fn posit8_table_size_and_extremes() {
        let q = Quantizer::new(&Posit::new(8, 0));
        assert_eq!(q.len(), 255); // 256 codes minus NaR
        assert_eq!(q.values()[0], -64.0);
        assert_eq!(*q.values().last().unwrap(), 64.0);
        assert_eq!(q.values()[q.len() / 2], 0.0);
    }

    #[test]
    fn quantize_representable_is_identity() {
        for spec in ["posit8es1", "float8we4", "fixed8q5"] {
            let fmt = super::super::FormatSpec::parse(spec).unwrap().build();
            let q = Quantizer::new(fmt.as_ref());
            for i in 0..q.len() {
                let x = q.values()[i];
                let (c, v) = q.quantize_f64(x);
                assert_eq!(v, x, "{spec}: representable {x} not fixed");
                assert_eq!(c, q.codes()[i]);
            }
        }
    }

    #[test]
    fn quantize_picks_nearest() {
        let q = Quantizer::new(&Posit::new(8, 0));
        // 1.26 is between 1.25 (0x48) and 1.28125? posit8es0 neighbors of
        // 1.25: 1.28125 does not exist; next is 1.3125 (frac step 1/16 at
        // sf=0 => 1/32? n=8,es=0: k=0 leaves 5 frac bits => step 1/32).
        let (c, v) = q.quantize_f64(1.26);
        assert_eq!(v, 1.25);
        assert_eq!(c, 0x48);
        let (_, v) = q.quantize_f64(1.27);
        assert_eq!(v, 1.28125);
    }

    #[test]
    fn ties_go_to_even_code() {
        let q = Quantizer::new(&Fixed::new(8, 4));
        // step = 1/16; 3/32 is exactly between 1/16 (code 1) and 2/16
        // (code 2): even code 2 wins.
        let (c, v) = q.quantize_f64(3.0 / 32.0);
        assert_eq!(c, 2);
        assert_eq!(v, 2.0 / 16.0);
        // 5/32 between codes 2 and 3: even code 2 wins (round down).
        let (c, v) = q.quantize_f64(5.0 / 32.0);
        assert_eq!(c, 2);
        assert_eq!(v, 2.0 / 16.0);
    }

    #[test]
    fn saturation_at_extremes() {
        for spec in ["posit8es0", "float8we4", "fixed8q5"] {
            let fmt = super::super::FormatSpec::parse(spec).unwrap().build();
            let q = Quantizer::new(fmt.as_ref());
            let (_, v) = q.quantize_f64(1.0e30);
            assert_eq!(v, q.max_value(), "{spec}");
            // Negative clamp goes to the most-negative value — for 2's
            // complement fixed-point that is −2^(n−1)·2^−Q, NOT −max
            // (Algorithm 1 clips to "min neg value").
            let (_, v) = q.quantize_f64(-1.0e30);
            assert_eq!(v, q.values()[0], "{spec}");
        }
    }

    #[test]
    fn posit_never_underflows_to_zero() {
        let q = Quantizer::new(&Posit::new(8, 0));
        let (_, v) = q.quantize_f64(1e-300);
        assert_eq!(v, q.min_pos());
        let (_, v) = q.quantize_f64(-1e-300);
        assert_eq!(v, -q.min_pos());
        // but exact zero stays zero
        let (c, v) = q.quantize_f64(0.0);
        assert_eq!((c, v), (0, 0.0));
    }

    #[test]
    fn float_and_fixed_underflow_to_zero() {
        for spec in ["float8we4", "fixed8q5"] {
            let fmt = super::super::FormatSpec::parse(spec).unwrap().build();
            let q = Quantizer::new(fmt.as_ref());
            let (_, v) = q.quantize_f64(q.min_pos() / 8.0);
            assert_eq!(v, 0.0, "{spec}");
        }
    }

    #[test]
    fn exact_and_f64_quantize_agree() {
        for spec in ["posit8es2", "float8we5", "fixed8q3", "posit5es0", "float6we3"] {
            let fmt = super::super::FormatSpec::parse(spec).unwrap().build();
            let q = Quantizer::new(fmt.as_ref());
            let mut x = -300.0f64;
            while x < 300.0 {
                let a = q.quantize_f64(x);
                let b = q.quantize_exact(&Exact::from_f64(x));
                assert_eq!(a, b, "{spec} at {x}");
                x += 0.37;
            }
        }
    }

    #[test]
    fn padded_tables_shapes() {
        let q = Quantizer::new(&Posit::new(6, 1));
        let (v, b, t) = q.padded_tables(256);
        assert_eq!(v.len(), 256);
        assert_eq!(b.len(), 255);
        assert_eq!(t.len(), 255);
        assert_eq!(v[q.len()..].iter().filter(|&&x| x == q.max_value()).count(), 256 - q.len());
        assert!(b[q.len() - 1..].iter().all(|&x| x.is_infinite()));
    }

    #[test]
    fn mse_zero_for_representable() {
        let q = Quantizer::new(&Float::new(8, 4));
        let vals: Vec<f64> = q.values().to_vec();
        assert_eq!(q.mse(&vals), 0.0);
    }

    #[test]
    fn zero_code_matches_exact_zero_quantization() {
        for spec in ["posit8es1", "float8we4", "fixed8q5"] {
            let fmt = super::super::FormatSpec::parse(spec).unwrap().build();
            let q = Quantizer::new(fmt.as_ref());
            assert_eq!(q.zero_code(), q.quantize_exact(&Exact::ZERO).0, "{spec}");
            assert_eq!(q.decode(q.zero_code()).unwrap(), Exact::ZERO, "{spec}");
        }
    }

    #[test]
    fn shared_cache_builds_once_per_spec() {
        // Use a spec no other test path is likely to have warmed, then show
        // repeat lookups are build-free pointer-equal clones.
        let spec = FormatSpec::parse("posit9es2").unwrap();
        let a = Quantizer::shared(spec);
        let b = Quantizer::shared(spec);
        // Pointer equality proves the second lookup reused the first build —
        // a rebuild would produce a distinct Arc. (The global build counter
        // is shared with concurrently running tests, so no counter-delta
        // assertion is possible here; `shared_builds` stays monotone and is
        // reported by the serving bench.)
        assert!(std::sync::Arc::ptr_eq(&a, &b), "shared() must reuse the cached table");
        assert!(Quantizer::shared_builds() >= 1);
        assert_eq!(a.name(), "posit9es2");
    }
}
