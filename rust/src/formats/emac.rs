//! The Exact Multiply-and-Accumulate unit (paper §4.1, Algorithms 1, 2, 4).
//!
//! Each DNN neuron computes a weighted sum of its inputs. A conventional MAC
//! rounds after every product, accumulating error that becomes substantial at
//! ≤8-bit precision. The EMAC instead implements a variant of the Kulisch
//! accumulator: every product is converted **exactly** to a wide fixed-point
//! register (the *quire*), summed without rounding, and a single
//! round-to-nearest (ties to even) happens in a deferred terminal stage.
//!
//! The accumulator width required for `k` products is Eq. (2):
//!
//! ```text
//! w_a = ceil(log2(k)) + 2*ceil(log2(max/min)) + 2
//! ```
//!
//! This module implements the *semantics* of the paper's three RTL designs
//! (Figs. 2–4) rather than transliterating their pipeline signals: decoded
//! operands are exact scaled integers (`mag × 2^exp`), products accumulate in
//! an `i128` quire whose LSB weight is the smallest possible product unit,
//! and the terminal stage rounds via [`Quantizer::quantize_exact`] — the
//! identical mathematical function the RTL computes with its LZD/shift/round
//! pipeline. Construction fails loudly if Eq. (2)'s width (plus fraction
//! guard bits) exceeds the 127 usable quire bits; every format in the paper's
//! [5, 8]-bit sweep fits.

use super::exact::Exact;
use super::tables::Quantizer;
use super::Format;

/// Paper Eq. (2): accumulator width for `k` products of a format with the
/// given max/min magnitude ratio.
pub fn quire_width_bits(k: usize, max: f64, min: f64) -> u32 {
    let k = k.max(2);
    let range = (max / min).log2().ceil() as u32;
    (k as f64).log2().ceil() as u32 + 2 * range + 2
}

/// An exact multiply-and-accumulate unit bound to one format.
///
/// Usage mirrors the hardware: [`Emac::mac`] per (weight, activation) code
/// pair, then [`Emac::result`] for the deferred round (+ optional ReLU for
/// hidden layers), which also clears the quire for the next neuron.
pub struct Emac<'q> {
    quantizer: &'q Quantizer,
    /// Decoded value per code, flattened for the hot loop (perf pass
    /// iteration 3 — EXPERIMENTS.md §Perf): magnitude (0 ⇒ zero operand,
    /// which annihilates the product), exponent relative to the quire LSB,
    /// and sign. Non-canonical codes (NaR) carry `mag = u64::MAX` as a
    /// debug-checked trap.
    lut: Vec<PodVal>,
    /// The quire: fixed-point accumulator in units of 2^lsb_exp.
    quire: i128,
    /// LSB weight exponent: 2 × (smallest canonical-value exponent).
    lsb_exp: i32,
    /// Products accumulated since the last `result()` (for width auditing).
    count: usize,
    /// Max products supported by the width check at construction.
    max_k: usize,
    /// Optional artificial quire narrowing (ablation study): accumulator
    /// wraps two's-complement at this many bits, emulating an
    /// under-provisioned register versus Eq. (2)'s sizing.
    width_limit: Option<u32>,
}

/// Flattened decoded code word (hot-loop layout).
#[derive(Debug, Clone, Copy)]
struct PodVal {
    /// Odd magnitude (canonical); 0 = value zero; u64::MAX = non-canonical.
    mag: u64,
    /// Binary exponent of the value.
    exp: i32,
    neg: bool,
}

const POD_INVALID: PodVal = PodVal { mag: u64::MAX, exp: 0, neg: false };

impl<'q> Emac<'q> {
    /// Build an EMAC for `fmt`, sized (and width-checked) for dot products of
    /// length ≤ `max_k`.
    pub fn new(fmt: &dyn Format, quantizer: &'q Quantizer, max_k: usize) -> Emac<'q> {
        assert_eq!(fmt.name(), quantizer.name(), "format/quantizer mismatch");
        let mut lut: Vec<PodVal> = vec![POD_INVALID; fmt.num_codes() as usize];
        let mut min_exp = i32::MAX;
        let mut max_top = i32::MIN;
        for code in 0..fmt.num_codes() {
            let code = code as u16;
            if let Some(e) = quantizer.decode(code) {
                if !e.is_zero() {
                    let c = e.canonical();
                    min_exp = min_exp.min(c.exp);
                    max_top = max_top.max(c.exp + (128 - c.mag.leading_zeros()) as i32);
                    debug_assert!(c.mag < u64::MAX as u128);
                    lut[code as usize] = PodVal { mag: c.mag as u64, exp: c.exp, neg: c.sign };
                } else {
                    lut[code as usize] = PodVal { mag: 0, exp: 0, neg: false };
                }
            }
        }
        let lsb_exp = 2 * min_exp;
        // Worst case |quire| < k × (2^max_top)^2; required bits relative to
        // the LSB weight:
        let need = (2 * max_top - lsb_exp) as u32 + (max_k.max(2) as f64).log2().ceil() as u32 + 1;
        assert!(
            need <= 126,
            "{}: quire needs {need} bits (> i128) for k={max_k}; paper Eq.(2) gives {}",
            fmt.name(),
            quire_width_bits(max_k, fmt.max_value(), fmt.min_pos()),
        );
        Emac { quantizer, lut, quire: 0, lsb_exp, count: 0, max_k, width_limit: None }
    }

    /// Narrow the quire to `bits` (ablation: what happens when the
    /// accumulator is smaller than Eq. (2) requires — it wraps, exactly as
    /// an undersized two's-complement register would).
    pub fn set_width_limit(&mut self, bits: u32) {
        assert!((2..=127).contains(&bits));
        self.width_limit = Some(bits);
    }

    #[inline]
    fn wrap(&mut self) {
        if let Some(w) = self.width_limit {
            let shift = 128 - w;
            self.quire = (self.quire << shift) >> shift;
        }
    }

    /// One multiply-accumulate of two code words. Exact: no rounding happens
    /// here (the defining EMAC property).
    #[inline]
    pub fn mac(&mut self, weight: u16, activation: u16) {
        let w = self.lut[weight as usize];
        let a = self.lut[activation as usize];
        debug_assert!(w.mag != u64::MAX, "non-canonical weight code {weight:#x}");
        debug_assert!(a.mag != u64::MAX, "non-canonical activation code {activation:#x}");
        #[cfg(debug_assertions)]
        {
            self.count += 1;
            assert!(self.count <= self.max_k, "EMAC overran its sized k");
        }
        if w.mag == 0 || a.mag == 0 {
            return;
        }
        // Canonical magnitudes are ≤16-bit: the product fits u64 (u64×u64
        // would be a 128-bit multiply — the narrower one is the hot-loop
        // win of perf iteration 3).
        let mag = w.mag * a.mag;
        let shift = (w.exp + a.exp - self.lsb_exp) as u32;
        let term = (mag as i128) << shift;
        self.quire += if w.neg ^ a.neg { -term } else { term };
        self.wrap();
    }

    /// Accumulate a raw pre-decoded exact value (used for biases, which Deep
    /// Positron adds in the same exact domain before rounding).
    #[inline]
    pub fn accumulate_exact(&mut self, v: Exact) {
        if v.is_zero() {
            return;
        }
        let shift = v.exp - self.lsb_exp;
        assert!(shift >= 0, "bias finer than quire LSB");
        let term = (v.mag as i128) << shift as u32;
        self.quire += if v.sign { -term } else { term };
        self.wrap();
    }

    /// Current quire contents as an exact value (no rounding).
    pub fn quire_value(&self) -> Exact {
        Exact::new(self.quire < 0, self.quire.unsigned_abs(), self.lsb_exp)
    }

    /// Terminal stage: deferred round-to-nearest-even (+ ReLU for hidden
    /// layers, applied to the rounded value as in the paper's fourth pipeline
    /// stage). Returns the output code and clears the quire.
    pub fn result(&mut self, relu: bool) -> u16 {
        let v = self.quire_value();
        self.quire = 0;
        self.count = 0;
        if relu && v.sign {
            // ReLU(x) = max(x, 0): negative sums clamp to the zero code.
            let (c, _) = self.quantizer.quantize_exact(&Exact::ZERO);
            return c;
        }
        let (c, _) = self.quantizer.quantize_exact(&v);
        c
    }

    /// Convenience: full dot product + optional ReLU in one call.
    pub fn dot(&mut self, weights: &[u16], activations: &[u16], bias: Option<Exact>, relu: bool) -> u16 {
        assert_eq!(weights.len(), activations.len());
        for (&w, &a) in weights.iter().zip(activations) {
            self.mac(w, a);
        }
        if let Some(b) = bias {
            self.accumulate_exact(b);
        }
        self.result(relu)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Fixed, Float, FormatSpec, Posit};
    use super::*;

    #[test]
    fn eq2_matches_paper_example() {
        // posit(8,0): max/min = 2^6/2^-6 = 2^12; k=256:
        // w_a = 8 + 2*12 + 2 = 34
        assert_eq!(quire_width_bits(256, 64.0, 1.0 / 64.0), 34);
    }

    #[test]
    fn emac_is_exact_where_f64_is() {
        // Sum of products must equal f64 reference when f64 is exact
        // (posit8 es=0 products span ≤ 34 bits).
        let fmt = Posit::new(8, 0);
        let q = Quantizer::new(&fmt);
        let mut emac = Emac::new(&fmt, &q, 64);
        let mut rng = 0x12345678u64;
        for _ in 0..50 {
            let mut wcodes = Vec::new();
            let mut acodes = Vec::new();
            let mut reference = 0.0f64;
            for _ in 0..64 {
                rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let w = (rng >> 16) as u16 & 0xFF;
                rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let a = (rng >> 16) as u16 & 0xFF;
                let (w, a) = (if w == 0x80 { 0x7F } else { w }, if a == 0x80 { 0x7F } else { a });
                reference += fmt.decode(w).to_f64() * fmt.decode(a).to_f64();
                wcodes.push(w);
                acodes.push(a);
            }
            let code = emac.dot(&wcodes, &acodes, None, false);
            let expected = q.quantize_f64(reference).0;
            assert_eq!(code, expected, "EMAC disagrees with exact f64 reference");
        }
    }

    #[test]
    fn deferred_rounding_beats_per_step_rounding() {
        // The motivating EMAC property: accumulating many small products that
        // would individually round away still contributes to the final sum.
        let fmt = Posit::new(8, 0);
        let q = Quantizer::new(&fmt);
        let mut emac = Emac::new(&fmt, &q, 200);
        // 64 products of minpos*minpos = 2^-12 each; sum = 64 × 2^-12 = 2^-6
        // = minpos exactly.
        for _ in 0..64 {
            emac.mac(0x01, 0x01);
        }
        let code = emac.result(false);
        assert_eq!(q.decode(code).unwrap().to_f64(), 1.0 / 64.0);
        // Per-step rounding would have produced 0 at every step for a
        // non-exact 8-bit MAC (minpos² << minpos/2 is representable… the
        // quire keeps it).
    }

    #[test]
    fn relu_clamps_negative_sums() {
        let fmt = Float::new(8, 4);
        let q = Quantizer::new(&fmt);
        let mut emac = Emac::new(&fmt, &q, 8);
        let (one, _) = q.quantize_f64(1.0);
        let (neg_two, _) = q.quantize_f64(-2.0);
        emac.mac(one, neg_two);
        let code = emac.result(true);
        assert_eq!(q.decode(code).unwrap().to_f64(), 0.0);
        // Without ReLU:
        emac.mac(one, neg_two);
        let code = emac.result(false);
        assert_eq!(q.decode(code).unwrap().to_f64(), -2.0);
    }

    #[test]
    fn fixed_emac_saturates_at_terminal_round() {
        // Algorithm 1's clip: sums beyond the format range clamp to ±max.
        let fmt = Fixed::new(8, 5);
        let q = Quantizer::new(&fmt);
        let mut emac = Emac::new(&fmt, &q, 64);
        let (two, _) = q.quantize_f64(2.0);
        for _ in 0..10 {
            emac.mac(two, two); // 10 × 4 = 40 >> max (3.97)
        }
        let code = emac.result(false);
        assert_eq!(q.decode(code).unwrap().to_f64(), q.max_value());
    }

    #[test]
    fn bias_accumulates_exactly() {
        let fmt = Posit::new(8, 1);
        let q = Quantizer::new(&fmt);
        let mut emac = Emac::new(&fmt, &q, 8);
        let (one, _) = q.quantize_f64(1.0);
        emac.mac(one, one);
        emac.accumulate_exact(Exact::from_f64(0.5));
        let code = emac.result(false);
        assert_eq!(q.decode(code).unwrap().to_f64(), 1.5);
    }

    #[test]
    fn all_paper_formats_fit_i128_at_k784() {
        // MNIST first layer: k = 784. Every swept format must construct.
        for n in 5..=8 {
            for spec in FormatSpec::sweep(n) {
                let fmt = spec.build();
                let q = Quantizer::new(fmt.as_ref());
                let _ = Emac::new(fmt.as_ref(), &q, 784);
            }
        }
    }

    #[test]
    fn posit_es2_wide_range_exactness() {
        // posit8 es=2 has the widest quire (~108+ bits, beyond f64): check a
        // cancellation case f64 would get wrong.
        let fmt = Posit::new(8, 2);
        let q = Quantizer::new(&fmt);
        let mut emac = Emac::new(&fmt, &q, 16);
        let (max_c, maxv) = q.quantize_f64(fmt.max_value());
        assert_eq!(maxv, fmt.max_value());
        let (min_c, minv) = q.quantize_f64(fmt.min_pos());
        assert_eq!(minv, fmt.min_pos());
        let (neg_max, _) = q.quantize_f64(-fmt.max_value());
        // max² + min² − max² = min² = 2^-48 exactly in the quire — far below
        // f64's 53-bit window around max² (an inexact MAC loses min² here).
        // min² < minpos/2, and posits never round nonzero to zero, so the
        // terminal round clamps to +minpos.
        emac.mac(max_c, max_c);
        emac.mac(min_c, min_c);
        emac.mac(neg_max, max_c); // −max²
        assert_eq!(emac.quire_value().canonical(), Exact::from_f64(fmt.min_pos()).mul(Exact::from_f64(fmt.min_pos())).canonical());
        let code = emac.result(false);
        assert_eq!(q.decode(code).unwrap().to_f64(), fmt.min_pos());
    }
}
