//! The Exact Multiply-and-Accumulate unit (paper §4.1, Algorithms 1, 2, 4).
//!
//! Each DNN neuron computes a weighted sum of its inputs. A conventional MAC
//! rounds after every product, accumulating error that becomes substantial at
//! ≤8-bit precision. The EMAC instead implements a variant of the Kulisch
//! accumulator: every product is converted **exactly** to a wide fixed-point
//! register (the *quire*), summed without rounding, and a single
//! round-to-nearest (ties to even) happens in a deferred terminal stage.
//!
//! The accumulator width required for `k` products is Eq. (2):
//!
//! ```text
//! w_a = ceil(log2(k)) + 2*ceil(log2(max/min)) + 2
//! ```
//!
//! This module implements the *semantics* of the paper's three RTL designs
//! (Figs. 2–4) rather than transliterating their pipeline signals: decoded
//! operands are exact scaled integers (`mag × 2^exp`), products accumulate in
//! an `i128` quire whose LSB weight is the smallest possible product unit,
//! and the terminal stage rounds via [`Quantizer::quantize_exact`] — the
//! identical mathematical function the RTL computes with its LZD/shift/round
//! pipeline. Construction fails loudly if Eq. (2)'s width (plus fraction
//! guard bits) exceeds the 127 usable quire bits; every format in the paper's
//! [5, 8]-bit sweep fits.
//!
//! The decoded-operand table lives in a [`DecodeLut`] shared process-wide
//! per format ([`DecodeLut::shared`], an `Arc` cache alongside
//! [`Quantizer::shared`]): [`Emac`] construction no longer walks the format's
//! code space, and `accel`'s compiled execution plans (DESIGN.md §8)
//! pre-decode whole weight tensors through the same table.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::{Arc, Mutex, OnceLock};

use super::exact::Exact;
use super::tables::Quantizer;
use super::{Format, FormatSpec};

/// Process-wide cache behind [`DecodeLut::shared`].
static SHARED_LUTS: OnceLock<Mutex<HashMap<FormatSpec, Arc<DecodeLut>>>> = OnceLock::new();
/// Count of cache-miss LUT builds (observable in tests/benches).
static SHARED_LUT_BUILDS: AtomicUsize = AtomicUsize::new(0);

/// Paper Eq. (2): accumulator width for `k` products of a format with the
/// given max/min magnitude ratio.
// exact-lint: allow(float, Eq. (2) sizes the quire from the format's value range — analysis of the datapath, not part of it)
pub fn quire_width_bits(k: usize, max: f64, min: f64) -> u32 {
    let k = k.max(2);
    let range = (max / min).log2().ceil() as u32;
    (k as f64).log2().ceil() as u32 + 2 * range + 2
}

/// A decoded code word in the EMAC's flattened hot-loop layout: magnitude
/// (0 ⇒ zero operand, which annihilates the product), binary exponent, and
/// sign. Non-canonical codes (NaR) carry `mag = u64::MAX` as a
/// debug-checked trap ([`DecodedOp::is_invalid`]).
#[derive(Debug, Clone, Copy)]
pub struct DecodedOp {
    /// Odd magnitude (canonical); 0 = value zero; `u64::MAX` = non-canonical.
    pub mag: u64,
    /// Binary exponent of the value.
    pub exp: i32,
    /// Sign (`true` = negative).
    pub neg: bool,
}

impl DecodedOp {
    /// The non-canonical (NaR / reserved code) marker entry.
    pub const INVALID: DecodedOp = DecodedOp { mag: u64::MAX, exp: 0, neg: false };

    /// Whether this entry denotes no real value (NaR / reserved code).
    #[inline]
    pub fn is_invalid(&self) -> bool {
        self.mag == u64::MAX
    }
}

/// The decoded-operand table of one format: every code word flattened to a
/// [`DecodedOp`], plus the quire geometry derived from the format's value
/// range. Built once per format per process via [`DecodeLut::shared`] and
/// handed out as cheap `Arc` clones — the compile-once half of the
/// compile-once / run-many execution plans (DESIGN.md §8).
#[derive(Debug)]
pub struct DecodeLut {
    name: String,
    ops: Vec<DecodedOp>,
    /// The ≤8-bit monomorphized table: the same operands as `ops`, padded
    /// with [`DecodedOp::INVALID`] to exactly 256 entries so a `u8` index
    /// can never be out of bounds and the optimizer drops the bounds check
    /// from the tiled inner loops (DESIGN.md §12). `None` for formats wider
    /// than 8 bits, which keep the generic slice path.
    ops8: Option<Box<[DecodedOp; 256]>>,
    /// Quire LSB weight exponent: 2 × (smallest canonical-value exponent).
    lsb_exp: i32,
    /// Highest set-bit position of any canonical value (exp + mag bits).
    max_top: i32,
    max_value: f64, // exact-lint: allow(float, format range metadata for Eq. (2) sizing, never accumulated)
    min_pos: f64, // exact-lint: allow(float, format range metadata for Eq. (2) sizing, never accumulated)
}

impl DecodeLut {
    /// Build the table by decoding every code of `fmt`. Prefer
    /// [`DecodeLut::shared`], which performs this walk once per format per
    /// process.
    pub fn new(fmt: &dyn Format, quantizer: &Quantizer) -> DecodeLut {
        assert_eq!(fmt.name(), quantizer.name(), "format/quantizer mismatch");
        let mut ops: Vec<DecodedOp> = vec![DecodedOp::INVALID; fmt.num_codes() as usize];
        let mut min_exp = i32::MAX;
        let mut max_top = i32::MIN;
        for code in 0..fmt.num_codes() {
            let code = code as u16;
            if let Some(e) = quantizer.decode(code) {
                if !e.is_zero() {
                    let c = e.canonical();
                    min_exp = min_exp.min(c.exp);
                    max_top = max_top.max(c.exp + (128 - c.mag.leading_zeros()) as i32);
                    debug_assert!(c.mag < u64::MAX as u128);
                    ops[code as usize] = DecodedOp { mag: c.mag as u64, exp: c.exp, neg: c.sign };
                } else {
                    ops[code as usize] = DecodedOp { mag: 0, exp: 0, neg: false };
                }
            }
        }
        let ops8 = (ops.len() <= 256).then(|| {
            let mut table = Box::new([DecodedOp::INVALID; 256]);
            table[..ops.len()].copy_from_slice(&ops);
            table
        });
        DecodeLut {
            name: fmt.name(),
            ops,
            ops8,
            lsb_exp: 2 * min_exp,
            max_top,
            max_value: quantizer.max_value(),
            min_pos: quantizer.min_pos(),
        }
    }

    /// The process-wide shared table for `spec`: built once, then handed out
    /// as cheap `Arc` clones — the reason [`Emac::new`] is allocation-free
    /// on the inference hot path.
    pub fn shared(spec: FormatSpec) -> Arc<DecodeLut> {
        let cache = SHARED_LUTS.get_or_init(|| Mutex::new(HashMap::new()));
        let mut map = cache.lock().unwrap();
        if let Some(l) = map.get(&spec) {
            return Arc::clone(l);
        }
        SHARED_LUT_BUILDS.fetch_add(1, AtomicOrdering::Relaxed);
        let q = Quantizer::shared(spec);
        let l = Arc::new(DecodeLut::new(spec.build().as_ref(), &q));
        map.insert(spec, Arc::clone(&l));
        l
    }

    /// How many cache-miss builds [`DecodeLut::shared`] has performed so far
    /// in this process (monotone; used to assert no per-sample rebuilds).
    pub fn shared_builds() -> usize {
        SHARED_LUT_BUILDS.load(AtomicOrdering::Relaxed)
    }

    /// The format's machine name, e.g. `posit8es1`.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Quire LSB weight exponent (the unit every product/bias term is
    /// shifted into).
    pub fn lsb_exp(&self) -> i32 {
        self.lsb_exp
    }

    /// The decoded operand of one code word.
    #[inline]
    pub fn op(&self, code: u16) -> DecodedOp {
        self.ops[code as usize]
    }

    /// All decoded operands, indexed by code word (the batched kernel's
    /// activation lookup).
    pub fn ops(&self) -> &[DecodedOp] {
        &self.ops
    }

    /// The monomorphized ≤8-bit operand table: always exactly 256 entries
    /// (code space padded with [`DecodedOp::INVALID`]), so indexing with
    /// `code as u8 as usize` is bounds-check free by construction. `None`
    /// for formats wider than 8 bits; callers fall back to [`DecodeLut::ops`].
    #[inline]
    pub fn ops8(&self) -> Option<&[DecodedOp; 256]> {
        self.ops8.as_deref()
    }

    /// Quire bits needed for dot products of length ≤ `max_k`, relative to
    /// the LSB weight (worst case `|quire| < k × (2^max_top)²` plus sign).
    pub fn quire_bits_needed(&self, max_k: usize) -> u32 {
        (2 * self.max_top - self.lsb_exp) as u32 + (max_k.max(2) as f64).log2().ceil() as u32 + 1 // exact-lint: allow(float, ceil(log2 k) width analysis, not accumulation)
    }

    /// Panic unless dot products of length ≤ `max_k` fit the 127 usable
    /// `i128` quire bits (the construction-time guard of [`Emac::new`] and
    /// `DeepPositron::compile`).
    pub fn assert_quire_fits(&self, max_k: usize) {
        let need = self.quire_bits_needed(max_k);
        assert!(
            need <= 126,
            "{}: quire needs {need} bits (> i128) for k={max_k}; paper Eq.(2) gives {}",
            self.name,
            quire_width_bits(max_k, self.max_value, self.min_pos),
        );
    }

    /// Pre-shift an exact value into quire units (`2^lsb_exp`) — how compiled
    /// plans stage biases so the batched kernel seeds the quire with a single
    /// integer load.
    pub fn to_quire(&self, v: &Exact) -> i128 {
        if v.is_zero() {
            return 0;
        }
        let shift = v.exp - self.lsb_exp;
        assert!(shift >= 0, "{}: value finer than the quire LSB", self.name);
        debug_assert!(v.mag < 1u128 << 64, "quire term magnitude overflow");
        let term = (v.mag as i128) << shift as u32;
        if v.sign {
            -term
        } else {
            term
        }
    }
}

/// An exact multiply-and-accumulate unit bound to one format.
///
/// Usage mirrors the hardware: [`Emac::mac`] per (weight, activation) code
/// pair, then [`Emac::result`] for the deferred round (+ optional ReLU for
/// hidden layers), which also clears the quire for the next neuron.
pub struct Emac<'q> {
    quantizer: &'q Quantizer,
    /// Shared decoded-operand table ([`DecodeLut::shared`]) — construction
    /// is an `Arc` clone, not a table build.
    lut: Arc<DecodeLut>,
    /// The quire: fixed-point accumulator in units of 2^lsb_exp.
    quire: i128,
    /// LSB weight exponent (copied out of the LUT for the hot loop).
    lsb_exp: i32,
    /// Products accumulated since the last `result()` (width auditing —
    /// debug builds only, so release builds carry no dead field).
    #[cfg(debug_assertions)]
    count: usize,
    /// Max products supported by the width check at construction.
    #[cfg(debug_assertions)]
    max_k: usize,
    /// Optional artificial quire narrowing (ablation study): accumulator
    /// wraps two's-complement at this many bits, emulating an
    /// under-provisioned register versus Eq. (2)'s sizing.
    width_limit: Option<u32>,
}

impl<'q> Emac<'q> {
    /// Build an EMAC for `fmt`, sized (and width-checked) for dot products of
    /// length ≤ `max_k`. Built-in formats (whose names round-trip through
    /// [`FormatSpec::parse`]) draw the decoded-operand table from the
    /// process-wide [`DecodeLut::shared`] cache, so construction no longer
    /// allocates or rebuilds it; a custom [`Format`] impl falls back to a
    /// private per-instance build — the pre-cache behavior.
    pub fn new(fmt: &dyn Format, quantizer: &'q Quantizer, max_k: usize) -> Emac<'q> {
        let lut = match FormatSpec::parse(&fmt.name()) {
            Some(spec) => DecodeLut::shared(spec),
            None => Arc::new(DecodeLut::new(fmt, quantizer)),
        };
        Emac::with_lut(lut, quantizer, max_k)
    }

    /// [`Emac::new`] with a caller-provided decoded-operand table — the
    /// allocation-free constructor for callers that already hold the shared
    /// table (tests and benches asserting zero rebuilds use it; the batched
    /// plan kernel in `accel` reads the same [`DecodeLut`] directly instead
    /// of constructing per-neuron EMACs). `lut` must have been built for
    /// `quantizer`'s format.
    pub fn with_lut(lut: Arc<DecodeLut>, quantizer: &'q Quantizer, max_k: usize) -> Emac<'q> {
        assert_eq!(lut.name(), quantizer.name(), "format/quantizer mismatch");
        lut.assert_quire_fits(max_k);
        let lsb_exp = lut.lsb_exp();
        Emac {
            quantizer,
            lut,
            quire: 0,
            lsb_exp,
            #[cfg(debug_assertions)]
            count: 0,
            #[cfg(debug_assertions)]
            max_k,
            width_limit: None,
        }
    }

    /// Narrow the quire to `bits` (ablation: what happens when the
    /// accumulator is smaller than Eq. (2) requires — it wraps, exactly as
    /// an undersized two's-complement register would).
    pub fn set_width_limit(&mut self, bits: u32) {
        assert!((2..=127).contains(&bits));
        self.width_limit = Some(bits);
    }

    #[inline]
    fn wrap(&mut self) {
        if let Some(w) = self.width_limit {
            let shift = 128 - w;
            self.quire = (self.quire << shift) >> shift;
        }
    }

    /// One multiply-accumulate of two code words. Exact: no rounding happens
    /// here (the defining EMAC property).
    #[inline]
    pub fn mac(&mut self, weight: u16, activation: u16) {
        let w = self.lut.op(weight);
        let a = self.lut.op(activation);
        debug_assert!(!w.is_invalid(), "non-canonical weight code {weight:#x}");
        debug_assert!(!a.is_invalid(), "non-canonical activation code {activation:#x}");
        #[cfg(debug_assertions)]
        {
            self.count += 1;
            assert!(self.count <= self.max_k, "EMAC overran its sized k");
        }
        if w.mag == 0 || a.mag == 0 {
            return;
        }
        // Canonical magnitudes are ≤16-bit: the product fits u64 (u64×u64
        // would be a 128-bit multiply — the narrower one is the hot-loop
        // win of perf iteration 3).
        let mag = w.mag * a.mag;
        let shift = (w.exp + a.exp - self.lsb_exp) as u32;
        let term = (mag as i128) << shift;
        self.quire += if w.neg ^ a.neg { -term } else { term };
        self.wrap();
    }

    /// Accumulate a raw pre-decoded exact value (used for biases, which Deep
    /// Positron adds in the same exact domain before rounding).
    #[inline]
    pub fn accumulate_exact(&mut self, v: Exact) {
        self.quire += self.lut.to_quire(&v);
        self.wrap();
    }

    /// Current quire contents as an exact value (no rounding).
    pub fn quire_value(&self) -> Exact {
        Exact::new(self.quire < 0, self.quire.unsigned_abs(), self.lsb_exp)
    }

    /// Terminal stage: deferred round-to-nearest-even (+ ReLU for hidden
    /// layers, applied to the rounded value as in the paper's fourth pipeline
    /// stage). Returns the output code and clears the quire.
    pub fn result(&mut self, relu: bool) -> u16 {
        let v = self.quire_value();
        self.quire = 0;
        #[cfg(debug_assertions)]
        {
            self.count = 0;
        }
        if relu && v.sign {
            // ReLU(x) = max(x, 0): negative sums clamp to the zero code.
            return self.quantizer.zero_code();
        }
        let (c, _) = self.quantizer.quantize_exact(&v);
        c
    }

    /// Convenience: full dot product + optional ReLU in one call.
    pub fn dot(&mut self, weights: &[u16], activations: &[u16], bias: Option<Exact>, relu: bool) -> u16 {
        assert_eq!(weights.len(), activations.len());
        for (&w, &a) in weights.iter().zip(activations) {
            self.mac(w, a);
        }
        if let Some(b) = bias {
            self.accumulate_exact(b);
        }
        self.result(relu)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Fixed, Float, FormatSpec, Posit};
    use super::*;

    #[test]
    fn eq2_matches_paper_example() {
        // posit(8,0): max/min = 2^6/2^-6 = 2^12; k=256:
        // w_a = 8 + 2*12 + 2 = 34
        assert_eq!(quire_width_bits(256, 64.0, 1.0 / 64.0), 34);
    }

    #[test]
    fn emac_is_exact_where_f64_is() {
        // Sum of products must equal f64 reference when f64 is exact
        // (posit8 es=0 products span ≤ 34 bits).
        let fmt = Posit::new(8, 0);
        let q = Quantizer::new(&fmt);
        let mut emac = Emac::new(&fmt, &q, 64);
        let mut rng = 0x12345678u64;
        for _ in 0..50 {
            let mut wcodes = Vec::new();
            let mut acodes = Vec::new();
            let mut reference = 0.0f64;
            for _ in 0..64 {
                rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let w = (rng >> 16) as u16 & 0xFF;
                rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let a = (rng >> 16) as u16 & 0xFF;
                let (w, a) = (if w == 0x80 { 0x7F } else { w }, if a == 0x80 { 0x7F } else { a });
                reference += fmt.decode(w).to_f64() * fmt.decode(a).to_f64();
                wcodes.push(w);
                acodes.push(a);
            }
            let code = emac.dot(&wcodes, &acodes, None, false);
            let expected = q.quantize_f64(reference).0;
            assert_eq!(code, expected, "EMAC disagrees with exact f64 reference");
        }
    }

    #[test]
    fn deferred_rounding_beats_per_step_rounding() {
        // The motivating EMAC property: accumulating many small products that
        // would individually round away still contributes to the final sum.
        let fmt = Posit::new(8, 0);
        let q = Quantizer::new(&fmt);
        let mut emac = Emac::new(&fmt, &q, 200);
        // 64 products of minpos*minpos = 2^-12 each; sum = 64 × 2^-12 = 2^-6
        // = minpos exactly.
        for _ in 0..64 {
            emac.mac(0x01, 0x01);
        }
        let code = emac.result(false);
        assert_eq!(q.decode(code).unwrap().to_f64(), 1.0 / 64.0);
        // Per-step rounding would have produced 0 at every step for a
        // non-exact 8-bit MAC (minpos² << minpos/2 is representable… the
        // quire keeps it).
    }

    #[test]
    fn relu_clamps_negative_sums() {
        let fmt = Float::new(8, 4);
        let q = Quantizer::new(&fmt);
        let mut emac = Emac::new(&fmt, &q, 8);
        let (one, _) = q.quantize_f64(1.0);
        let (neg_two, _) = q.quantize_f64(-2.0);
        emac.mac(one, neg_two);
        let code = emac.result(true);
        assert_eq!(q.decode(code).unwrap().to_f64(), 0.0);
        // Without ReLU:
        emac.mac(one, neg_two);
        let code = emac.result(false);
        assert_eq!(q.decode(code).unwrap().to_f64(), -2.0);
    }

    #[test]
    fn fixed_emac_saturates_at_terminal_round() {
        // Algorithm 1's clip: sums beyond the format range clamp to ±max.
        let fmt = Fixed::new(8, 5);
        let q = Quantizer::new(&fmt);
        let mut emac = Emac::new(&fmt, &q, 64);
        let (two, _) = q.quantize_f64(2.0);
        for _ in 0..10 {
            emac.mac(two, two); // 10 × 4 = 40 >> max (3.97)
        }
        let code = emac.result(false);
        assert_eq!(q.decode(code).unwrap().to_f64(), q.max_value());
    }

    #[test]
    fn bias_accumulates_exactly() {
        let fmt = Posit::new(8, 1);
        let q = Quantizer::new(&fmt);
        let mut emac = Emac::new(&fmt, &q, 8);
        let (one, _) = q.quantize_f64(1.0);
        emac.mac(one, one);
        emac.accumulate_exact(Exact::from_f64(0.5));
        let code = emac.result(false);
        assert_eq!(q.decode(code).unwrap().to_f64(), 1.5);
    }

    #[test]
    fn all_paper_formats_fit_i128_at_k784() {
        // MNIST first layer: k = 784. Every swept format must construct.
        for n in 5..=8 {
            for spec in FormatSpec::sweep(n) {
                let fmt = spec.build();
                let q = Quantizer::new(fmt.as_ref());
                let _ = Emac::new(fmt.as_ref(), &q, 784);
            }
        }
    }

    #[test]
    fn shared_lut_is_pointer_stable() {
        // Two EMACs of the same format must attach to the SAME cached decode
        // table — `Emac::new` is an Arc clone, never a table rebuild.
        let spec = FormatSpec::parse("posit7es1").unwrap();
        let a = DecodeLut::shared(spec);
        let b = DecodeLut::shared(spec);
        assert!(Arc::ptr_eq(&a, &b), "shared() must reuse the cached decode LUT");
        assert!(DecodeLut::shared_builds() >= 1);
        assert_eq!(a.name(), "posit7es1");
    }

    #[test]
    fn ops8_mirrors_ops_padded_with_invalid() {
        // Every swept (≤8-bit) format gets the monomorphized 256-entry table;
        // real codes agree bit-for-bit with the generic slice, padding traps.
        for n in 5..=8 {
            for spec in FormatSpec::sweep(n) {
                let lut = DecodeLut::shared(spec);
                let t = lut.ops8().expect("≤8-bit formats must monomorphize");
                for (i, op) in lut.ops().iter().enumerate() {
                    assert_eq!((t[i].mag, t[i].exp, t[i].neg), (op.mag, op.exp, op.neg), "{spec} code {i}");
                }
                for pad in &t[lut.ops().len()..] {
                    assert!(pad.is_invalid(), "{spec}: padding must be INVALID");
                }
            }
        }
    }

    #[test]
    fn lut_to_quire_matches_mac_semantics() {
        // Seeding the quire with `to_quire(bias)` must equal accumulating the
        // bias through `accumulate_exact` (the plan-time bias pre-shift).
        let fmt = Posit::new(8, 1);
        let q = Quantizer::new(&fmt);
        let lut = DecodeLut::shared(FormatSpec::parse("posit8es1").unwrap());
        for x in [0.0, 0.5, -1.25, 3.0, -0.0625] {
            let v = Exact::from_f64(x);
            let mut emac = Emac::with_lut(Arc::clone(&lut), &q, 4);
            emac.accumulate_exact(v);
            assert_eq!(
                emac.quire_value().cmp_exact(&Exact::new(x < 0.0, lut.to_quire(&v).unsigned_abs(), lut.lsb_exp())),
                std::cmp::Ordering::Equal,
                "to_quire({x}) disagrees with accumulate_exact"
            );
        }
    }

    #[test]
    fn posit_es2_wide_range_exactness() {
        // posit8 es=2 has the widest quire (~108+ bits, beyond f64): check a
        // cancellation case f64 would get wrong.
        let fmt = Posit::new(8, 2);
        let q = Quantizer::new(&fmt);
        let mut emac = Emac::new(&fmt, &q, 16);
        let (max_c, maxv) = q.quantize_f64(fmt.max_value());
        assert_eq!(maxv, fmt.max_value());
        let (min_c, minv) = q.quantize_f64(fmt.min_pos());
        assert_eq!(minv, fmt.min_pos());
        let (neg_max, _) = q.quantize_f64(-fmt.max_value());
        // max² + min² − max² = min² = 2^-48 exactly in the quire — far below
        // f64's 53-bit window around max² (an inexact MAC loses min² here).
        // min² < minpos/2, and posits never round nonzero to zero, so the
        // terminal round clamps to +minpos.
        emac.mac(max_c, max_c);
        emac.mac(min_c, min_c);
        emac.mac(neg_max, max_c); // −max²
        assert_eq!(
            emac.quire_value().canonical(),
            Exact::from_f64(fmt.min_pos()).mul(Exact::from_f64(fmt.min_pos())).canonical()
        );
        let code = emac.result(false);
        assert_eq!(q.decode(code).unwrap().to_f64(), fmt.min_pos());
    }
}
