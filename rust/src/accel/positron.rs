//! The Deep Positron accelerator simulator (paper §4).
//!
//! Bit-exact software model of the FPGA datapath: a trained network's
//! weights/biases and all inter-layer activations live as n-bit format
//! codes; every neuron's weighted sum runs through the format's EMAC
//! (exact quire accumulation, single deferred round, ReLU stage for hidden
//! layers). This is the golden path Table 1's low-precision columns are
//! measured on; the AOT/XLA fast path is validated against it.
//!
//! Execution follows a compile-once / run-many plan (DESIGN.md §8): at
//! [`DeepPositron::compile`] time every layer's weight codes are pre-decoded
//! into flat EMAC operands and biases are pre-shifted into quire units, so
//! [`DeepPositron::forward_batch`] walks each layer once per batch — the
//! weight row streams across all samples, one quire/activation buffer set is
//! reused, and nothing is decoded or allocated per sample. The scalar
//! [`DeepPositron::forward_codes_with`] is the batch-of-one special case and
//! is bit-identical to the old per-sample EMAC loop (asserted by
//! `tests/batch_parity.rs` against an independent scalar oracle).
//!
//! Plans are **heterogeneous** (DESIGN.md §10): [`DeepPositron::compile_mixed`]
//! accepts a per-layer [`MixedSpec`], each layer carrying its own shared
//! `Quantizer`/`DecodeLut` pair — the layer-wise EMAC banks of Deep Positron,
//! with the inter-layer recode folded into each layer's single terminal round
//! (the quire value rounds once, directly into the next layer's format).
//! The uniform [`DeepPositron::compile`] is the all-layers-equal case and
//! stays bit-identical to the pre-mixed accelerator.

use std::sync::Arc;

use super::mlp::Mlp;
use crate::datasets::Dataset;
use crate::formats::emac::{DecodeLut, DecodedOp};
use crate::formats::ops::ScalarAlu;
use crate::formats::{Exact, FormatSpec, MixedSpec, Quantizer};

/// Test-set evaluation batch size: large enough to amortize per-batch
/// setup, small enough to keep the feature-major activation blocks
/// cache-resident.
pub const EVAL_BATCH: usize = 64;

/// Which multiply-accumulate datapath the accelerator uses (ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Datapath {
    /// The paper's EMAC: exact quire accumulation, one deferred round.
    Emac,
    /// A conventional unit: round after EVERY multiply and EVERY add —
    /// what the EMAC is designed to beat (§4.1).
    InexactMac,
    /// EMAC with an artificially narrowed quire (wraps at `bits`) —
    /// quantifies why Eq. (2)'s sizing matters.
    NarrowQuire(u32),
}

/// One layer of the compiled execution plan (DESIGN.md §8): weight codes
/// pre-decoded into flat EMAC operands and biases pre-shifted into quire
/// units, ready for the batched kernel. Each layer carries its own shared
/// table set — the heterogeneous (mixed-precision) case of DESIGN.md §10;
/// uniform networks simply hold `Arc` clones of one table set everywhere.
struct LayerPlan {
    /// Fan-in of the layer.
    in_dim: usize,
    /// Fan-out of the layer.
    out_dim: usize,
    /// Decoded-operand table of the layer's own format: decodes both the
    /// pre-quantized weights and the incoming activation codes (which the
    /// previous layer's terminal round already emitted in this format).
    lut: Arc<DecodeLut>,
    /// The layer format's quantization tables (weight/bias quantization;
    /// the inexact-MAC ablation's per-step rounder).
    quantizer: Arc<Quantizer>,
    /// Terminal rounder: the exact quire value rounds ONCE, directly into
    /// the NEXT layer's format — the recode-at-boundary of DESIGN.md §10.
    /// (The last layer rounds into its own format; uniform networks recode
    /// into the same format, reducing bit-for-bit to the single-format
    /// terminal round.)
    out_q: Arc<Quantizer>,
    /// Zero code of the layer format (inexact-MAC accumulator seed).
    zero: u16,
    /// Zero code of the OUTPUT format (ReLU clamp target).
    out_zero: u16,
    /// Pre-decoded weight operands, row-major `[out][in]`.
    w_ops: Vec<DecodedOp>,
    /// Per-output bias, pre-shifted into quire units (`2^lsb_exp`).
    bias_q: Vec<i128>,
    /// Hidden layers apply ReLU in format space at the terminal round.
    relu: bool,
}

/// A network instantiated on Deep Positron with one numeric format per
/// layer (a uniform network is the all-layers-equal special case).
pub struct DeepPositron {
    /// The per-layer format assignment this instance was compiled for.
    mixed: MixedSpec,
    /// Input-layer quantization tables (requests quantize into the first
    /// layer's format), shared process-wide ([`Quantizer::shared`]).
    quantizer: Arc<Quantizer>,
    /// Per-layer weight codes, row-major `[out][in]` (consumed by the
    /// inexact-MAC ablation and the dequantized accessors).
    weights: Vec<Vec<u16>>,
    /// Per-layer bias values, kept exact (the accelerator feeds biases into
    /// the quire directly, after their own quantization to the layer
    /// format).
    biases: Vec<Vec<Exact>>,
    /// The compiled execution plan, one entry per layer.
    plan: Vec<LayerPlan>,
    dims: Vec<usize>,
}

impl DeepPositron {
    /// Quantize a trained f64 network onto the accelerator with one format
    /// everywhere, drawing the quantization tables from the process-wide
    /// shared cache.
    pub fn compile(mlp: &Mlp, spec: FormatSpec) -> DeepPositron {
        DeepPositron::compile_with(mlp, spec, Quantizer::shared(spec))
    }

    /// [`DeepPositron::compile`] with caller-provided tables — the injection
    /// point for serving workers (or tests) that manage table sharing
    /// themselves. `quantizer` must have been built for `spec`.
    pub fn compile_with(mlp: &Mlp, spec: FormatSpec, quantizer: Arc<Quantizer>) -> DeepPositron {
        let mixed = MixedSpec::uniform(spec, mlp.layers.len());
        DeepPositron::build(mlp, mixed, &|s| {
            if s == spec {
                Arc::clone(&quantizer)
            } else {
                Quantizer::shared(s)
            }
        })
    }

    /// Quantize a trained f64 network onto the accelerator with a per-layer
    /// format assignment (DESIGN.md §10). Layer `i`'s weights, incoming
    /// activations, and quire live in `mixed.layers()[i]`; each layer's
    /// terminal round recodes directly into layer `i + 1`'s format. Panics
    /// unless the assignment has exactly one format per dense layer.
    pub fn compile_mixed(mlp: &Mlp, mixed: MixedSpec) -> DeepPositron {
        DeepPositron::build(mlp, mixed, &Quantizer::shared)
    }

    fn build(mlp: &Mlp, mixed: MixedSpec, tables: &dyn Fn(FormatSpec) -> Arc<Quantizer>) -> DeepPositron {
        assert_eq!(mixed.len(), mlp.layers.len(), "mixed assignment must carry exactly one format per layer");
        let dims = mlp.dims();
        let specs = mixed.layers();
        let last = mlp.layers.len() - 1;
        let mut weights = Vec::with_capacity(mlp.layers.len());
        let mut biases = Vec::with_capacity(mlp.layers.len());
        let mut plan = Vec::with_capacity(mlp.layers.len());
        for (li, layer) in mlp.layers.iter().enumerate() {
            let spec = specs[li];
            let quantizer = tables(spec);
            let lut = DecodeLut::shared(spec);
            // Eq. (2) width check, once at compile time per layer (it used
            // to run inside every per-sample Emac construction): this
            // layer's dot-product length + 1 bias term.
            lut.assert_quire_fits(dims[li] + 1);
            let (codes, _) = quantizer.quantize_slice(&layer.w);
            let bias_exact: Vec<Exact> = layer
                .b
                .iter()
                .map(|&b| {
                    let (code, _) = quantizer.quantize_f64(b);
                    quantizer.decode(code).unwrap_or(Exact::ZERO)
                })
                .collect();
            let w_ops: Vec<DecodedOp> = codes.iter().map(|&c| lut.op(c)).collect();
            debug_assert!(w_ops.iter().all(|op| !op.is_invalid()), "non-canonical weight code");
            let out_spec = specs.get(li + 1).copied().unwrap_or(spec);
            let out_q = if out_spec == spec { Arc::clone(&quantizer) } else { tables(out_spec) };
            plan.push(LayerPlan {
                in_dim: dims[li],
                out_dim: dims[li + 1],
                zero: quantizer.zero_code(),
                out_zero: out_q.zero_code(),
                bias_q: bias_exact.iter().map(|b| lut.to_quire(b)).collect(),
                relu: li < last,
                w_ops,
                lut,
                out_q,
                quantizer,
            });
            weights.push(codes);
            biases.push(bias_exact);
        }
        let quantizer = Arc::clone(&plan[0].quantizer);
        DeepPositron { mixed, quantizer, weights, biases, plan, dims }
    }

    /// The network's input-layer format. Uniform networks (compiled via
    /// [`DeepPositron::compile`]) carry this format everywhere; the full
    /// per-layer assignment is [`DeepPositron::mixed`].
    pub fn spec(&self) -> FormatSpec {
        self.mixed.layers()[0]
    }

    /// The per-layer format assignment this instance was compiled for.
    pub fn mixed(&self) -> &MixedSpec {
        &self.mixed
    }

    /// The (shared) input-layer quantization tables backing this instance —
    /// the tables requests quantize through. Mixed networks carry further
    /// per-layer tables inside their execution plan.
    pub fn quantizer(&self) -> &Quantizer {
        &self.quantizer
    }

    /// The quantizer of the network's OUTPUT codes (the last layer's
    /// terminal-round target — equal to [`DeepPositron::quantizer`] for
    /// uniform networks).
    fn output_quantizer(&self) -> &Quantizer {
        &self.plan.last().expect("plan has layers").out_q
    }

    /// The dequantized weight values per layer (what the XLA fast path
    /// consumes as its `weights` input).
    pub fn dequantized_weights(&self) -> Vec<Vec<f64>> {
        self.plan.iter().zip(&self.weights).map(|(lp, codes)| lp.quantizer.dequantize_slice(codes)).collect()
    }

    /// The dequantized bias values per layer (fast-path input).
    pub fn dequantized_biases(&self) -> Vec<Vec<f64>> {
        self.biases.iter().map(|bs| bs.iter().map(|b| b.to_f64()).collect()).collect()
    }

    /// Run one sample through the EMAC datapath; returns the output-layer
    /// codes (pre-argmax "logits" in format space).
    pub fn forward_codes(&self, x: &[f64]) -> Vec<u16> {
        self.forward_codes_with(x, Datapath::Emac)
    }

    /// Run one sample through a selected datapath — the batch-of-one case of
    /// [`DeepPositron::forward_batch`].
    pub fn forward_codes_with(&self, x: &[f64], mode: Datapath) -> Vec<u16> {
        self.forward_batch(&[x], mode).pop().expect("one row in, one row out")
    }

    /// Run a batch of samples through a selected datapath, walking every
    /// layer once for the whole batch. Bit-identical to running each sample
    /// through the scalar EMAC loop: quire accumulation is exact integer
    /// addition (order-free), the narrow-quire wrap is a homomorphism mod
    /// 2^bits (so one terminal wrap equals the scalar per-step wrap), and the
    /// inexact path keeps the scalar per-sample operation order.
    pub fn forward_batch(&self, rows: &[&[f64]], mode: Datapath) -> Vec<Vec<u16>> {
        for row in rows {
            assert_eq!(row.len(), self.dims[0], "feature dim mismatch");
        }
        if rows.is_empty() {
            return Vec::new();
        }
        match mode {
            Datapath::Emac => self.batch_emac(rows, None),
            Datapath::NarrowQuire(bits) => {
                assert!((2..=127).contains(&bits));
                self.batch_emac(rows, Some(bits))
            }
            Datapath::InexactMac => self.batch_inexact(rows),
        }
    }

    /// Quantize input rows into a feature-major code block (`[feature][sample]`
    /// — the layout that keeps the batched kernels' sample loops contiguous).
    fn quantize_block(&self, rows: &[&[f64]], act: &mut [u16]) {
        let b = rows.len();
        for (s, row) in rows.iter().enumerate() {
            for (i, &x) in row.iter().enumerate() {
                act[i * b + s] = self.quantizer.quantize_f64(x).0;
            }
        }
    }

    /// Transpose the final feature-major activation block back into one code
    /// row per sample.
    fn gather_rows(&self, act: &[u16], b: usize) -> Vec<Vec<u16>> {
        let out_dim = *self.dims.last().unwrap();
        (0..b).map(|s| (0..out_dim).map(|o| act[o * b + s]).collect()).collect()
    }

    /// The batched EMAC kernel: per output neuron, seed every sample's quire
    /// with the pre-shifted bias, stream the pre-decoded weight row across
    /// the batch, and round once at the terminal stage — directly into the
    /// next layer's format (the §10 boundary recode; a no-op change of
    /// target for uniform networks).
    fn batch_emac(&self, rows: &[&[f64]], width_limit: Option<u32>) -> Vec<Vec<u16>> {
        let b = rows.len();
        let max_dim = *self.dims.iter().max().unwrap();
        let mut act = vec![0u16; b * max_dim];
        let mut next = vec![0u16; b * max_dim];
        let mut quires = vec![0i128; b];
        self.quantize_block(rows, &mut act);
        for lp in &self.plan {
            let lsb = lp.lut.lsb_exp();
            let ops = lp.lut.ops();
            for o in 0..lp.out_dim {
                let wrow = &lp.w_ops[o * lp.in_dim..(o + 1) * lp.in_dim];
                quires.fill(lp.bias_q[o]);
                for (i, w) in wrow.iter().enumerate() {
                    if w.mag == 0 {
                        continue; // zero weight annihilates the whole column
                    }
                    let acol = &act[i * b..(i + 1) * b];
                    for (s, &code) in acol.iter().enumerate() {
                        let a = ops[code as usize];
                        debug_assert!(!a.is_invalid(), "non-canonical activation code {code:#x}");
                        if a.mag == 0 {
                            continue;
                        }
                        // The exact product term of `Emac::mac`: magnitudes
                        // are ≤16-bit, so the product fits u64.
                        let mag = w.mag * a.mag;
                        let shift = (w.exp + a.exp - lsb) as u32;
                        let term = (mag as i128) << shift;
                        quires[s] += if w.neg ^ a.neg { -term } else { term };
                    }
                }
                let out = &mut next[o * b..(o + 1) * b];
                for (s, out_code) in out.iter_mut().enumerate() {
                    let mut q = quires[s];
                    if let Some(bits) = width_limit {
                        // Two's-complement wrap of the undersized register.
                        // Wrapping once here is bit-identical to the scalar
                        // per-step wrap: sign extension picks the same
                        // representative of the sum mod 2^bits.
                        let sh = 128 - bits;
                        q = (q << sh) >> sh;
                    }
                    *out_code = if lp.relu && q < 0 {
                        // ReLU(x) = max(x, 0): negative sums clamp to the
                        // output format's zero code.
                        lp.out_zero
                    } else {
                        lp.out_q.quantize_exact(&Exact::new(q < 0, q.unsigned_abs(), lsb)).0
                    };
                }
            }
            std::mem::swap(&mut act, &mut next);
        }
        self.gather_rows(&act, b)
    }

    /// The batched conventional-MAC ablation: round after every multiply and
    /// every add, preserving the scalar per-sample operation order exactly.
    /// Under a mixed assignment each layer's ALU rounds in that layer's
    /// format and the finished sum recodes into the next layer's format —
    /// identity for uniform networks (quantize of a representable value).
    fn batch_inexact(&self, rows: &[&[f64]]) -> Vec<Vec<u16>> {
        let b = rows.len();
        let max_dim = *self.dims.iter().max().unwrap();
        let mut act = vec![0u16; b * max_dim];
        let mut next = vec![0u16; b * max_dim];
        let mut accs = vec![0u16; b];
        self.quantize_block(rows, &mut act);
        for (lp, (codes, biases)) in self.plan.iter().zip(self.weights.iter().zip(&self.biases)) {
            let alu = ScalarAlu::new(&lp.quantizer);
            for o in 0..lp.out_dim {
                let wrow = &codes[o * lp.in_dim..(o + 1) * lp.in_dim];
                accs.fill(lp.zero);
                for (i, &wc) in wrow.iter().enumerate() {
                    let acol = &act[i * b..(i + 1) * b];
                    for (s, &ac) in acol.iter().enumerate() {
                        accs[s] = alu.add(accs[s], alu.mul(wc, ac));
                    }
                }
                let (bcode, _) = lp.quantizer.quantize_exact(&biases[o]);
                let out = &mut next[o * b..(o + 1) * b];
                for (s, out_code) in out.iter_mut().enumerate() {
                    let acc = alu.add(accs[s], bcode);
                    let v = lp.quantizer.decode(acc).expect("rounded code decodes");
                    *out_code = if lp.relu && v.sign { lp.out_zero } else { lp.out_q.quantize_exact(&v).0 };
                }
            }
            std::mem::swap(&mut act, &mut next);
        }
        self.gather_rows(&act, b)
    }

    /// Argmax over the decoded values of an output-code row (decoded through
    /// the last layer's output format). Returns `None` when no code decodes
    /// to a real value (an all-NaR row) — callers must not mistake an
    /// undecodable row for class 0.
    pub fn decoded_argmax(&self, codes: &[u16]) -> Option<usize> {
        let out_q = self.output_quantizer();
        let mut best: Option<(usize, f64)> = None;
        for (i, &c) in codes.iter().enumerate() {
            if let Some(e) = out_q.decode(c) {
                let v = e.to_f64();
                if best.map_or(true, |(_, bv)| v > bv) {
                    best = Some((i, v));
                }
            }
        }
        best.map(|(i, _)| i)
    }

    /// Predicted class for one sample: argmax over the decoded output codes.
    /// Posit codes could be compared as signed integers directly (the posit
    /// monotonicity property); decoding keeps this uniform across formats.
    /// Panics on an all-NaR output row (never produced by the datapaths,
    /// whose terminal rounds emit canonical codes only).
    pub fn predict(&self, x: &[f64]) -> usize {
        self.decoded_argmax(&self.forward_codes(x)).expect("output row decoded to no real value")
    }

    /// Batched predictions on the EMAC datapath — one compiled-plan walk for
    /// the whole batch (the serving engine's Sim execution path).
    pub fn predict_batch(&self, rows: &[&[f64]]) -> Vec<usize> {
        self.forward_batch(rows, Datapath::Emac)
            .iter()
            .map(|out| self.decoded_argmax(out).expect("output row decoded to no real value"))
            .collect()
    }

    /// Accuracy over the first `rows.min(test_len)` test rows under a
    /// selected datapath — the capped batched evaluator the auto-tuner
    /// ([`crate::tune`]) scores candidate assignments with. Chunks of
    /// [`EVAL_BATCH`] samples per plan walk; undecodable output rows count
    /// as wrong, never as class 0.
    pub fn accuracy_on(&self, ds: &Dataset, mode: Datapath, rows: usize) -> f64 {
        let total = ds.test_len().min(rows.max(1));
        let mut correct = 0usize;
        let mut i = 0;
        while i < total {
            let take = EVAL_BATCH.min(total - i);
            let rows: Vec<&[f64]> = (i..i + take).map(|j| ds.test_row(j)).collect();
            for (j, out) in self.forward_batch(&rows, mode).iter().enumerate() {
                if self.decoded_argmax(out) == Some(ds.y_test[i + j] as usize) {
                    correct += 1;
                }
            }
            i += take;
        }
        correct as f64 / total as f64
    }

    /// Test accuracy under a selected datapath, evaluated through
    /// [`DeepPositron::forward_batch`] over the whole test split
    /// (the uncapped case of [`DeepPositron::accuracy_on`]).
    pub fn accuracy_with(&self, ds: &Dataset, mode: Datapath) -> f64 {
        self.accuracy_on(ds, mode, usize::MAX)
    }

    /// Test-set accuracy on the EMAC datapath (batched evaluation).
    pub fn accuracy(&self, ds: &Dataset) -> f64 {
        self.accuracy_with(ds, Datapath::Emac)
    }

    /// Reference forward pass with *dequantized* weights and table-rounded
    /// activations in f64 — the semantics of the XLA artifact. Where f64
    /// accumulation is exact (every format here except the widest posit
    /// quires), this matches [`Self::forward_codes`] bit for bit.
    pub fn forward_dequantized(&self, x: &[f64]) -> Vec<f64> {
        let (_, mut act) = self.quantizer.quantize_slice(x);
        for (lp, (w, b)) in self.plan.iter().zip(self.weights.iter().zip(&self.biases)) {
            let wv = lp.quantizer.dequantize_slice(w);
            let mut next = Vec::with_capacity(lp.out_dim);
            for o in 0..lp.out_dim {
                let mut acc = b[o].to_f64();
                for i in 0..lp.in_dim {
                    acc += wv[o * lp.in_dim + i] * act[i];
                }
                // Terminal round into the output (next-layer) format — same
                // target the EMAC's boundary recode rounds into.
                let (_, rounded) = lp.out_q.quantize_f64(acc);
                next.push(if lp.relu { rounded.max(0.0) } else { rounded });
            }
            act = next;
        }
        act
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::mlp::{train, TrainConfig};
    use crate::datasets::{self, Scale};
    use crate::util::Rng;

    fn trained_iris() -> (Mlp, crate::datasets::Dataset) {
        let ds = datasets::load("iris", 5, Scale::Small);
        let (norm, means, stds) = ds.normalized();
        let mut rng = Rng::new(2);
        let mut mlp = Mlp::new(&[4, 10, 8, 3], &mut rng);
        train(&mut mlp, &norm, &TrainConfig { epochs: 80, ..Default::default() });
        super::super::mlp::fold_input_normalization(&mut mlp, &means, &stds);
        (mlp, ds)
    }

    #[test]
    fn posit8_tracks_f64_baseline_on_iris() {
        let (mlp, ds) = trained_iris();
        let base = mlp.accuracy(&ds);
        let dp = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 });
        let acc = dp.accuracy(&ds);
        assert!(acc >= base - 0.06, "posit8 lost too much: {acc} vs {base}");
    }

    #[test]
    fn emac_path_matches_dequantized_f64_path() {
        // For formats whose quire fits f64's exact window, the two paths are
        // identical (DESIGN.md §2 exactness argument).
        let (mlp, ds) = trained_iris();
        for spec in ["posit8es1", "float8we4", "fixed8q4"] {
            let dp = DeepPositron::compile(&mlp, FormatSpec::parse(spec).unwrap());
            for i in 0..20 {
                let codes = dp.forward_codes(ds.test_row(i));
                let vals: Vec<f64> = codes.iter().map(|&c| dp.quantizer().decode(c).unwrap().to_f64()).collect();
                let ref_vals = dp.forward_dequantized(ds.test_row(i));
                assert_eq!(vals, ref_vals, "{spec} sample {i}");
            }
        }
    }

    #[test]
    fn forward_batch_matches_per_sample_calls() {
        // Quick in-crate parity check; the exhaustive sweep (every format ×
        // every datapath × an independent scalar oracle) lives in
        // `tests/batch_parity.rs`.
        let (mlp, ds) = trained_iris();
        let dp = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 });
        for mode in [Datapath::Emac, Datapath::InexactMac, Datapath::NarrowQuire(24)] {
            let rows: Vec<&[f64]> = (0..10).map(|i| ds.test_row(i)).collect();
            let batched = dp.forward_batch(&rows, mode);
            for (i, row) in rows.iter().enumerate() {
                assert_eq!(batched[i], dp.forward_codes_with(row, mode), "{mode:?} sample {i}");
            }
        }
    }

    #[test]
    fn decoded_argmax_rejects_all_nar_rows() {
        let (mlp, _) = trained_iris();
        let dp = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 });
        // 0x80 is posit NaR: an all-NaR row has no argmax (NOT class 0).
        assert_eq!(dp.decoded_argmax(&[0x80, 0x80, 0x80]), None);
        // A single decodable code wins regardless of position.
        let one = dp.quantizer().quantize_f64(1.0).0;
        assert_eq!(dp.decoded_argmax(&[0x80, one, 0x80]), Some(1));
        let neg = dp.quantizer().quantize_f64(-2.0).0;
        assert_eq!(dp.decoded_argmax(&[0x80, neg]), Some(1));
    }

    #[test]
    fn lower_precision_degrades_gracefully() {
        let (mlp, ds) = trained_iris();
        let acc8 = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 }).accuracy(&ds);
        let acc5 = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 5, es: 1 }).accuracy(&ds);
        assert!(acc8 >= acc5, "8-bit ({acc8}) should beat 5-bit ({acc5})");
        assert!(acc5 > 0.3, "5-bit posit collapsed entirely: {acc5}");
    }

    #[test]
    fn fixed_point_suffers_most_at_low_bits() {
        // Table 1's qualitative story on a small task: best-of-sweep posit
        // should be ≥ best-of-sweep fixed at 8 bits.
        let (mlp, ds) = trained_iris();
        let best = |family: &str| -> f64 {
            FormatSpec::sweep_family(8, family)
                .into_iter()
                .map(|s| DeepPositron::compile(&mlp, s).accuracy(&ds))
                .fold(0.0, f64::max)
        };
        let posit = best("posit");
        let fixed = best("fixed");
        assert!(posit >= fixed, "posit {posit} < fixed {fixed}");
    }

    #[test]
    fn mixed_assignment_compiles_and_tracks_uniform() {
        // The exhaustive uniform-parity sweep lives in `tests/tune.rs`; this
        // is the in-crate smoke test: a genuinely mixed plan runs end to
        // end, recodes at every boundary, and stays in the accuracy
        // ballpark of its widest uniform member.
        let (mlp, ds) = trained_iris();
        let mixed = MixedSpec::new(vec![
            FormatSpec::Posit { n: 8, es: 1 },
            FormatSpec::Float { n: 7, we: 3 },
            FormatSpec::Posit { n: 6, es: 1 },
        ]);
        let dp = DeepPositron::compile_mixed(&mlp, mixed.clone());
        assert_eq!(dp.mixed(), &mixed);
        assert_eq!(dp.spec(), FormatSpec::Posit { n: 8, es: 1 });
        let acc = dp.accuracy(&ds);
        let acc8 = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 }).accuracy(&ds);
        assert!(acc >= acc8 - 0.2, "mixed plan collapsed: {acc} vs uniform {acc8}");
        // Scalar == batched on the mixed plan too (batch-of-one wrapper).
        let rows: Vec<&[f64]> = (0..6).map(|i| ds.test_row(i)).collect();
        for mode in [Datapath::Emac, Datapath::InexactMac, Datapath::NarrowQuire(32)] {
            let batched = dp.forward_batch(&rows, mode);
            for (i, row) in rows.iter().enumerate() {
                assert_eq!(batched[i], dp.forward_codes_with(row, mode), "{mode:?} sample {i}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "one format per layer")]
    fn mixed_assignment_must_match_layer_count() {
        let (mlp, _) = trained_iris();
        let _ = DeepPositron::compile_mixed(&mlp, MixedSpec::uniform(FormatSpec::Posit { n: 8, es: 1 }, 2));
    }

    #[test]
    fn weights_roundtrip_through_tables() {
        let (mlp, _) = trained_iris();
        let dp = DeepPositron::compile(&mlp, FormatSpec::Float { n: 8, we: 4 });
        let w = dp.dequantized_weights();
        assert_eq!(w.len(), 3);
        assert_eq!(w[0].len(), 4 * 10);
        // Every dequantized weight must be representable (quantize = id).
        for &v in w[0].iter() {
            let (_, round) = dp.quantizer().quantize_f64(v);
            assert_eq!(round, v);
        }
    }
}
