//! The Deep Positron accelerator simulator (paper §4).
//!
//! Bit-exact software model of the FPGA datapath: a trained network's
//! weights/biases and all inter-layer activations live as n-bit format
//! codes; every neuron's weighted sum runs through the format's EMAC
//! (exact quire accumulation, single deferred round, ReLU stage for hidden
//! layers). This is the golden path Table 1's low-precision columns are
//! measured on; the AOT/XLA fast path is validated against it.

use std::sync::Arc;

use super::mlp::{argmax, Mlp};
use crate::datasets::Dataset;
use crate::formats::ops::ScalarAlu;
use crate::formats::{Emac, Exact, Format, FormatSpec, Quantizer};

/// Which multiply-accumulate datapath the accelerator uses (ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Datapath {
    /// The paper's EMAC: exact quire accumulation, one deferred round.
    Emac,
    /// A conventional unit: round after EVERY multiply and EVERY add —
    /// what the EMAC is designed to beat (§4.1).
    InexactMac,
    /// EMAC with an artificially narrowed quire (wraps at `bits`) —
    /// quantifies why Eq. (2)'s sizing matters.
    NarrowQuire(u32),
}

/// A network instantiated on Deep Positron with one numeric format.
pub struct DeepPositron {
    spec: FormatSpec,
    fmt: Box<dyn Format + Send + Sync>,
    /// Shared, read-only quantization tables (one build per format per
    /// process — [`Quantizer::shared`]).
    quantizer: Arc<Quantizer>,
    /// Per-layer weight codes, row-major `[out][in]`.
    weights: Vec<Vec<u16>>,
    /// Per-layer bias values, kept exact (the accelerator feeds biases into
    /// the quire directly, after their own quantization to the format).
    biases: Vec<Vec<Exact>>,
    dims: Vec<usize>,
}

impl DeepPositron {
    /// Quantize a trained f64 network onto the accelerator, drawing the
    /// quantization tables from the process-wide shared cache.
    pub fn compile(mlp: &Mlp, spec: FormatSpec) -> DeepPositron {
        DeepPositron::compile_with(mlp, spec, Quantizer::shared(spec))
    }

    /// [`DeepPositron::compile`] with caller-provided tables — the injection
    /// point for serving workers (or tests) that manage table sharing
    /// themselves. `quantizer` must have been built for `spec`.
    pub fn compile_with(mlp: &Mlp, spec: FormatSpec, quantizer: Arc<Quantizer>) -> DeepPositron {
        let fmt = spec.build();
        let mut weights = Vec::with_capacity(mlp.layers.len());
        let mut biases = Vec::with_capacity(mlp.layers.len());
        for layer in &mlp.layers {
            let (codes, _) = quantizer.quantize_slice(&layer.w);
            weights.push(codes);
            let bias_exact = layer
                .b
                .iter()
                .map(|&b| {
                    let (code, _) = quantizer.quantize_f64(b);
                    quantizer.decode(code).unwrap_or(Exact::ZERO)
                })
                .collect();
            biases.push(bias_exact);
        }
        DeepPositron { spec, fmt, quantizer, weights, biases, dims: mlp.dims() }
    }

    /// The format this instance was compiled for.
    pub fn spec(&self) -> FormatSpec {
        self.spec
    }

    /// The (shared) quantization tables backing this instance.
    pub fn quantizer(&self) -> &Quantizer {
        &self.quantizer
    }

    /// The dequantized weight values per layer (what the XLA fast path
    /// consumes as its `weights` input).
    pub fn dequantized_weights(&self) -> Vec<Vec<f64>> {
        self.weights.iter().map(|codes| self.quantizer.dequantize_slice(codes)).collect()
    }

    /// The dequantized bias values per layer (fast-path input).
    pub fn dequantized_biases(&self) -> Vec<Vec<f64>> {
        self.biases.iter().map(|bs| bs.iter().map(|b| b.to_f64()).collect()).collect()
    }

    /// Run one sample through the EMAC datapath; returns the output-layer
    /// codes (pre-argmax "logits" in format space).
    pub fn forward_codes(&self, x: &[f64]) -> Vec<u16> {
        self.forward_codes_with(x, Datapath::Emac)
    }

    /// Run one sample through a selected datapath (ablation studies).
    pub fn forward_codes_with(&self, x: &[f64], mode: Datapath) -> Vec<u16> {
        assert_eq!(x.len(), self.dims[0]);
        let (mut act, _) = self.quantizer.quantize_slice(x);
        let max_k = *self.dims.iter().max().unwrap();
        let mut emac = Emac::new(self.fmt.as_ref(), &self.quantizer, max_k + 1);
        if let Datapath::NarrowQuire(bits) = mode {
            emac.set_width_limit(bits);
        }
        let alu = ScalarAlu::new(&self.quantizer);
        let zero = self.quantizer.quantize_f64(0.0).0;
        let last = self.weights.len() - 1;
        for (li, (w, b)) in self.weights.iter().zip(&self.biases).enumerate() {
            let in_dim = self.dims[li];
            let out_dim = self.dims[li + 1];
            let relu = li < last;
            let mut next = Vec::with_capacity(out_dim);
            for o in 0..out_dim {
                let row = &w[o * in_dim..(o + 1) * in_dim];
                let code = match mode {
                    Datapath::Emac | Datapath::NarrowQuire(_) => emac.dot(row, &act, Some(b[o]), relu),
                    Datapath::InexactMac => {
                        // Conventional pipeline: round after every op.
                        let mut acc = alu.inexact_dot(row, &act);
                        let (bcode, _) = self.quantizer.quantize_exact(&b[o]);
                        acc = alu.add(acc, bcode);
                        let v = self.quantizer.decode(acc).unwrap();
                        if relu && v.sign {
                            zero
                        } else {
                            acc
                        }
                    }
                };
                next.push(code);
            }
            act = next;
        }
        act
    }

    /// Test accuracy under a selected datapath.
    pub fn accuracy_with(&self, ds: &Dataset, mode: Datapath) -> f64 {
        let mut correct = 0usize;
        for i in 0..ds.test_len() {
            let out = self.forward_codes_with(ds.test_row(i), mode);
            let vals: Vec<f64> =
                out.iter().map(|&c| self.quantizer.decode(c).map_or(f64::NAN, |e| e.to_f64())).collect();
            if argmax(&vals) == ds.y_test[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / ds.test_len() as f64
    }

    /// Predicted class for one sample: argmax over the decoded output codes.
    /// Posit codes could be compared as signed integers directly (the posit
    /// monotonicity property); decoding keeps this uniform across formats.
    pub fn predict(&self, x: &[f64]) -> usize {
        let out = self.forward_codes(x);
        let vals: Vec<f64> = out.iter().map(|&c| self.quantizer.decode(c).map_or(f64::NAN, |e| e.to_f64())).collect();
        argmax(&vals)
    }

    /// Test-set accuracy on the EMAC datapath.
    pub fn accuracy(&self, ds: &Dataset) -> f64 {
        let mut correct = 0usize;
        for i in 0..ds.test_len() {
            if self.predict(ds.test_row(i)) == ds.y_test[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / ds.test_len() as f64
    }

    /// Reference forward pass with *dequantized* weights and table-rounded
    /// activations in f64 — the semantics of the XLA artifact. Where f64
    /// accumulation is exact (every format here except the widest posit
    /// quires), this matches [`Self::forward_codes`] bit for bit.
    pub fn forward_dequantized(&self, x: &[f64]) -> Vec<f64> {
        let (_, mut act) = self.quantizer.quantize_slice(x);
        let last = self.weights.len() - 1;
        for (li, (w, b)) in self.weights.iter().zip(&self.biases).enumerate() {
            let in_dim = self.dims[li];
            let out_dim = self.dims[li + 1];
            let wv = self.quantizer.dequantize_slice(w);
            let mut next = Vec::with_capacity(out_dim);
            for o in 0..out_dim {
                let mut acc = b[o].to_f64();
                for i in 0..in_dim {
                    acc += wv[o * in_dim + i] * act[i];
                }
                let (_, rounded) = self.quantizer.quantize_f64(acc);
                next.push(if li < last { rounded.max(0.0) } else { rounded });
            }
            act = next;
        }
        act
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::mlp::{train, TrainConfig};
    use crate::datasets::{self, Scale};
    use crate::util::Rng;

    fn trained_iris() -> (Mlp, crate::datasets::Dataset) {
        let ds = datasets::load("iris", 5, Scale::Small);
        let (norm, means, stds) = ds.normalized();
        let mut rng = Rng::new(2);
        let mut mlp = Mlp::new(&[4, 10, 8, 3], &mut rng);
        train(&mut mlp, &norm, &TrainConfig { epochs: 80, ..Default::default() });
        super::super::mlp::fold_input_normalization(&mut mlp, &means, &stds);
        (mlp, ds)
    }

    #[test]
    fn posit8_tracks_f64_baseline_on_iris() {
        let (mlp, ds) = trained_iris();
        let base = mlp.accuracy(&ds);
        let dp = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 });
        let acc = dp.accuracy(&ds);
        assert!(acc >= base - 0.06, "posit8 lost too much: {acc} vs {base}");
    }

    #[test]
    fn emac_path_matches_dequantized_f64_path() {
        // For formats whose quire fits f64's exact window, the two paths are
        // identical (DESIGN.md §2 exactness argument).
        let (mlp, ds) = trained_iris();
        for spec in ["posit8es1", "float8we4", "fixed8q4"] {
            let dp = DeepPositron::compile(&mlp, FormatSpec::parse(spec).unwrap());
            for i in 0..20 {
                let codes = dp.forward_codes(ds.test_row(i));
                let vals: Vec<f64> = codes.iter().map(|&c| dp.quantizer().decode(c).unwrap().to_f64()).collect();
                let ref_vals = dp.forward_dequantized(ds.test_row(i));
                assert_eq!(vals, ref_vals, "{spec} sample {i}");
            }
        }
    }

    #[test]
    fn lower_precision_degrades_gracefully() {
        let (mlp, ds) = trained_iris();
        let acc8 = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 }).accuracy(&ds);
        let acc5 = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 5, es: 1 }).accuracy(&ds);
        assert!(acc8 >= acc5, "8-bit ({acc8}) should beat 5-bit ({acc5})");
        assert!(acc5 > 0.3, "5-bit posit collapsed entirely: {acc5}");
    }

    #[test]
    fn fixed_point_suffers_most_at_low_bits() {
        // Table 1's qualitative story on a small task: best-of-sweep posit
        // should be ≥ best-of-sweep fixed at 8 bits.
        let (mlp, ds) = trained_iris();
        let best = |family: &str| -> f64 {
            FormatSpec::sweep_family(8, family)
                .into_iter()
                .map(|s| DeepPositron::compile(&mlp, s).accuracy(&ds))
                .fold(0.0, f64::max)
        };
        let posit = best("posit");
        let fixed = best("fixed");
        assert!(posit >= fixed, "posit {posit} < fixed {fixed}");
    }

    #[test]
    fn weights_roundtrip_through_tables() {
        let (mlp, _) = trained_iris();
        let dp = DeepPositron::compile(&mlp, FormatSpec::Float { n: 8, we: 4 });
        let w = dp.dequantized_weights();
        assert_eq!(w.len(), 3);
        assert_eq!(w[0].len(), 4 * 10);
        // Every dequantized weight must be representable (quantize = id).
        for &v in w[0].iter() {
            let (_, round) = dp.quantizer().quantize_f64(v);
            assert_eq!(round, v);
        }
    }
}
