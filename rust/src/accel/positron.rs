//! The Deep Positron accelerator simulator (paper §4), generalized over the
//! typed layer IR (DESIGN.md §11).
//!
//! Bit-exact software model of the FPGA datapath: a trained network's
//! weights/biases and all inter-layer activations live as n-bit format
//! codes; every output element's weighted sum runs through the format's
//! EMAC (exact quire accumulation, single deferred round, ReLU stage for
//! hidden weighted layers). This is the golden path Table 1's low-precision
//! columns are measured on; the AOT/XLA fast path is validated against it
//! (dense topologies only — conv networks are Sim-native).
//!
//! Execution follows a compile-once / run-many plan (DESIGN.md §8): at
//! [`DeepPositron::compile`] time every layer's weight codes are staged as
//! **packed dense `u8` codes** (decoded on the fly through the format's
//! monomorphized 256-entry table — an 8× smaller working set than
//! pre-decoded operands; formats wider than 8 bits keep pre-decoded
//! operands) and biases are pre-shifted into quire units, so
//! [`DeepPositron::forward_batch`] walks each layer once per batch — the
//! weight row streams across all samples, one quire/activation buffer set is
//! reused, and nothing is decoded or allocated per sample. The scalar
//! [`DeepPositron::forward_codes_with`] is the batch-of-one special case and
//! is bit-identical to the old per-sample EMAC loop (asserted by
//! `tests/batch_parity.rs` against an independent scalar oracle).
//!
//! The EMAC kernels are tiled and monomorphized (DESIGN.md §12):
//!
//! * each layer's incoming activation codes decode **once** into a flat
//!   [`DecodedOp`] block (instead of one LUT hit per weight×activation
//!   pair — a factor of fan-out fewer lookups), through the 256-entry
//!   [`DecodeLut::ops8`] table whose `u8` indexing is bounds-check free by
//!   construction for every ≤8-bit paper format;
//! * the inner loops run over [`ROW_TILE`] weight rows × [`LANE_BLOCK`]
//!   batch lanes, so one decoded activation column feeds several output
//!   quires while the live quire tile (4 × 32 × 16 B) stays L1-resident;
//! * outputs land in caller-reused flat buffers
//!   ([`DeepPositron::forward_batch_into`] — no per-row `Vec` allocations),
//!   and large batches fan out across the process-wide
//!   [`WorkerPool`] as independent contiguous sample chunks.
//!
//! All of this is bit-identity preserving: quire accumulation is exact
//! integer addition (order-free), the narrow-quire wrap happens once at the
//! terminal stage (a homomorphism mod 2^bits), and chunking a batch never
//! changes any sample's own operation order. The inexact-MAC ablation keeps
//! its per-sample, per-step rounding order untouched.
//!
//! Per layer kind (DESIGN.md §11, the Cheetah-style conv mapping):
//!
//! * **Dense** — one quire per output neuron, seeded with the bias,
//!   accumulating the full input row (the classic Deep Positron dataflow).
//! * **Conv2d** — one quire per *output pixel*, seeded with the channel
//!   bias, accumulating the `kh·kw·in_ch` receptive field exactly; the
//!   Eq. (2) width check runs at `k = kh·kw·in_ch + 1` per layer.
//! * **AvgPool** — accumulate the `k²` window in the quire (no products),
//!   then divide by `k²` as an exact exponent shift at the terminal round
//!   (window areas are powers of two by IR construction).
//! * **Flatten** — pure wiring; under a mixed per-layer assignment it is a
//!   recode point (each code rounds once into the next layer's format),
//!   otherwise a copy.
//!
//! Plans are **heterogeneous** (DESIGN.md §10): [`DeepPositron::compile_mixed`]
//! accepts a per-layer [`MixedSpec`], each layer carrying its own shared
//! `Quantizer`/`DecodeLut` pair — the layer-wise EMAC banks of Deep Positron,
//! with the inter-layer recode folded into each layer's single terminal round
//! (the quire value rounds once, directly into the next layer's format).
//! The uniform [`DeepPositron::compile`] is the all-layers-equal case and
//! stays bit-identical to the pre-mixed accelerator.

use std::sync::Arc;

use super::ir::{LayerGeom, LayerKind, NetIr, Shape};
use super::mlp::{Layer, Mlp};
use crate::datasets::Dataset;
use crate::formats::emac::{DecodeLut, DecodedOp};
use crate::formats::ops::ScalarAlu;
use crate::formats::{Exact, FormatSpec, MixedSpec, Quantizer};
use crate::util::pool::WorkerPool;

/// Test-set evaluation batch size: large enough to amortize per-batch
/// setup, small enough to keep the feature-major activation blocks
/// cache-resident.
pub const EVAL_BATCH: usize = 64;

/// Weight rows (dense neurons / conv output channels) processed per tile:
/// each decoded activation column loaded once feeds this many quire rows.
pub const ROW_TILE: usize = 4;

/// Batch lanes per tile: the live quire tile is `ROW_TILE × LANE_BLOCK`
/// i128s (2 KiB) — comfortably L1-resident alongside the activation column.
pub const LANE_BLOCK: usize = 32;

/// Smallest batch worth fanning out across the shared worker pool (scoped
/// thread spawns are microseconds; tiny batches run inline).
const PAR_MIN_ROWS: usize = 16;

/// Which multiply-accumulate datapath the accelerator uses (ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Datapath {
    /// The paper's EMAC: exact quire accumulation, one deferred round.
    Emac,
    /// A conventional unit: round after EVERY multiply and EVERY add —
    /// what the EMAC is designed to beat (§4.1).
    InexactMac,
    /// EMAC with an artificially narrowed quire (wraps at `bits`) —
    /// quantifies why Eq. (2)'s sizing matters.
    NarrowQuire(u32),
}

/// Compiled weight storage for one plan layer (DESIGN.md §16).
///
/// Every ≤8-bit paper format stores its weights **packed**: one dense `u8`
/// code per weight, decoded on the fly through the layer's monomorphized
/// 256-entry [`DecodeLut::ops8`] table. That is an 8× smaller working set
/// than pre-decoded 24-byte [`DecodedOp`]s — the whole weight store of a
/// tabular network fits in a few cache lines next to the live quire tile —
/// and the `u8` index makes every lookup bounds-check free by construction.
/// Formats wider than 8 bits (no `ops8` table) keep the classic pre-decoded
/// operand vector. Both arms are bit-identical: the packed arm reads the
/// same table `decode_block` decodes activations through.
#[derive(Clone)]
enum PlanWeights {
    /// Dense `u8` weight codes for a format with a monomorphized table.
    Packed(Vec<u8>),
    /// Pre-decoded operands for formats wider than the table.
    Wide(Vec<DecodedOp>),
}

/// Uniform weight-operand access the tiled kernels monomorphize over: one
/// instantiation of each kernel reads packed codes through the 256-entry
/// table, the other reads pre-decoded operands — no per-element branch in
/// either copy.
trait WeightFetch {
    /// Decoded operand of weight `idx` (plan layout order).
    fn op(&self, idx: usize) -> DecodedOp;
}

/// Packed-code fetch: `table[codes[idx]]` — a `u8` index into a 256-entry
/// table can never be out of bounds, so the optimizer drops the check.
struct PackedW<'a> {
    table: &'a [DecodedOp; 256],
    codes: &'a [u8],
}

impl WeightFetch for PackedW<'_> {
    #[inline(always)]
    fn op(&self, idx: usize) -> DecodedOp {
        self.table[self.codes[idx] as usize]
    }
}

/// Pre-decoded fetch for formats wider than the monomorphized table.
struct WideW<'a>(&'a [DecodedOp]);

impl WeightFetch for WideW<'_> {
    #[inline(always)]
    fn op(&self, idx: usize) -> DecodedOp {
        self.0[idx]
    }
}

/// One layer of the compiled execution plan (DESIGN.md §8): weight codes
/// pre-decoded into flat EMAC operands and biases pre-shifted into quire
/// units, ready for the batched kernel. Each layer carries its own shared
/// table set — the heterogeneous (mixed-precision) case of DESIGN.md §10;
/// uniform networks simply hold `Arc` clones of one table set everywhere.
/// `Clone` is cheap relative to compilation (table handles are `Arc`s; the
/// operand/bias vectors are flat memcpys, no re-quantization) — what
/// [`DeepPositron::recompile_mixed`] leans on to reuse unchanged layers.
#[derive(Clone)]
struct LayerPlan {
    /// The IR node this plan entry executes.
    kind: LayerKind,
    /// Shape of the incoming activation block.
    in_shape: Shape,
    /// Shape of the produced activation block.
    out_shape: Shape,
    /// Flat fan-in of the layer (`in_shape.len()`).
    in_dim: usize,
    /// Flat fan-out of the layer (`out_shape.len()`).
    out_dim: usize,
    /// Decoded-operand table of the layer's own format: decodes both the
    /// pre-quantized weights and the incoming activation codes (which the
    /// previous layer's terminal round already emitted in this format).
    lut: Arc<DecodeLut>,
    /// The layer format's quantization tables (weight/bias quantization;
    /// the inexact-MAC ablation's per-step rounder).
    quantizer: Arc<Quantizer>,
    /// Terminal rounder: the exact quire value rounds ONCE, directly into
    /// the NEXT layer's format — the recode-at-boundary of DESIGN.md §10.
    /// (The last layer rounds into its own format; uniform networks recode
    /// into the same format, reducing bit-for-bit to the single-format
    /// terminal round.)
    out_q: Arc<Quantizer>,
    /// Zero code of the layer format (inexact-MAC accumulator seed).
    zero: u16,
    /// Zero code of the OUTPUT format (ReLU clamp target).
    out_zero: u16,
    /// Weight operands (dense: row-major `[out][in]`; conv:
    /// `[out_ch][in_ch][kh][kw]`; empty for weightless kinds), packed as
    /// dense `u8` codes whenever the format has a monomorphized table.
    w: PlanWeights,
    /// Per-output-neuron (dense) / per-output-channel (conv) bias,
    /// pre-shifted into quire units (`2^lsb_exp`).
    bias_q: Vec<i128>,
    /// Hidden weighted layers apply ReLU in format space at the terminal
    /// round; weightless wiring (pool/flatten) never does.
    relu: bool,
}

/// A network instantiated on Deep Positron with one numeric format per
/// layer (a uniform network is the all-layers-equal special case).
pub struct DeepPositron {
    /// The per-layer format assignment this instance was compiled for.
    mixed: MixedSpec,
    /// Input-layer quantization tables (requests quantize into the first
    /// layer's format), shared process-wide ([`Quantizer::shared`]).
    quantizer: Arc<Quantizer>,
    /// Per-layer weight codes (same layout as `LayerPlan::w_ops`; consumed
    /// by the inexact-MAC ablation and the dequantized accessors).
    weights: Vec<Vec<u16>>,
    /// Per-layer bias values, kept exact (the accelerator feeds biases into
    /// the quire directly, after their own quantization to the layer
    /// format).
    biases: Vec<Vec<Exact>>,
    /// The compiled execution plan, one entry per layer.
    plan: Vec<LayerPlan>,
    dims: Vec<usize>,
}

impl DeepPositron {
    /// Quantize a trained f64 network onto the accelerator with one format
    /// everywhere, drawing the quantization tables from the process-wide
    /// shared cache.
    pub fn compile(mlp: &Mlp, spec: FormatSpec) -> DeepPositron {
        DeepPositron::compile_with(mlp, spec, Quantizer::shared(spec))
    }

    /// [`DeepPositron::compile`] with caller-provided tables — the injection
    /// point for serving workers (or tests) that manage table sharing
    /// themselves. `quantizer` must have been built for `spec`.
    pub fn compile_with(mlp: &Mlp, spec: FormatSpec, quantizer: Arc<Quantizer>) -> DeepPositron {
        let mixed = MixedSpec::uniform(spec, mlp.layers.len());
        DeepPositron::build(mlp, mixed, &|s| {
            if s == spec {
                Arc::clone(&quantizer)
            } else {
                Quantizer::shared(s)
            }
        })
    }

    /// Quantize a trained f64 network onto the accelerator with a per-layer
    /// format assignment (DESIGN.md §10). Layer `i`'s weights, incoming
    /// activations, and quire live in `mixed.layers()[i]`; each layer's
    /// terminal round recodes directly into layer `i + 1`'s format. Panics
    /// unless the assignment has exactly one format per IR layer (weightless
    /// wiring layers count — they are recode points).
    pub fn compile_mixed(mlp: &Mlp, mixed: MixedSpec) -> DeepPositron {
        DeepPositron::build(mlp, mixed, &Quantizer::shared)
    }

    /// Recompile `mlp` under a new per-layer assignment, reusing this
    /// instance's compiled layers wherever the plan is unchanged. Layer `i`'s
    /// plan depends on `mixed.layers()[i]` (its own tables, weight operands)
    /// AND on layer `i + 1`'s format (the terminal round recodes into the
    /// next layer's format), so entry `i` is reused exactly when both match
    /// this instance's assignment; changed layers rebuild from scratch
    /// through the shared table cache. Bit-identical to
    /// [`DeepPositron::compile_mixed`] on the same `(mlp, mixed)` — reuse is
    /// a memcpy of already-correct plan entries, never an approximation.
    /// `mlp` must be the network this instance was compiled from (same
    /// topology AND same trained parameters; debug-asserted on dims). This
    /// is the plan-prefix reuse the tuner's descent rounds lean on: a
    /// single-layer perturbation recompiles at most two layers.
    pub fn recompile_mixed(&self, mlp: &Mlp, mixed: MixedSpec) -> DeepPositron {
        assert_eq!(mixed.len(), mlp.layers.len(), "mixed assignment must carry exactly one format per layer");
        debug_assert_eq!(self.dims, mlp.dims(), "recompile_mixed requires the network this instance was compiled from");
        let dims = mlp.dims();
        let specs = mixed.layers();
        let old = self.mixed.layers();
        let mut weights = Vec::with_capacity(mlp.layers.len());
        let mut biases = Vec::with_capacity(mlp.layers.len());
        let mut plan = Vec::with_capacity(mlp.layers.len());
        for (li, layer) in mlp.layers.iter().enumerate() {
            let spec = specs[li];
            let out_spec = specs.get(li + 1).copied().unwrap_or(spec);
            let old_out = old.get(li + 1).copied().unwrap_or(old[li]);
            if spec == old[li] && out_spec == old_out {
                plan.push(self.plan[li].clone());
                weights.push(self.weights[li].clone());
                biases.push(self.biases[li].clone());
            } else {
                let (codes, bias_exact, entry) =
                    DeepPositron::build_layer(layer, li, &dims, mlp.layers.len() - 1, spec, out_spec, &Quantizer::shared);
                plan.push(entry);
                weights.push(codes);
                biases.push(bias_exact);
            }
        }
        let quantizer = Arc::clone(&plan[0].quantizer);
        DeepPositron { mixed, quantizer, weights, biases, plan, dims }
    }

    fn build(mlp: &Mlp, mixed: MixedSpec, tables: &dyn Fn(FormatSpec) -> Arc<Quantizer>) -> DeepPositron {
        assert_eq!(mixed.len(), mlp.layers.len(), "mixed assignment must carry exactly one format per layer");
        let dims = mlp.dims();
        let specs = mixed.layers();
        let last = mlp.layers.len() - 1;
        let mut weights = Vec::with_capacity(mlp.layers.len());
        let mut biases = Vec::with_capacity(mlp.layers.len());
        let mut plan = Vec::with_capacity(mlp.layers.len());
        for (li, layer) in mlp.layers.iter().enumerate() {
            let spec = specs[li];
            let out_spec = specs.get(li + 1).copied().unwrap_or(spec);
            let (codes, bias_exact, entry) = DeepPositron::build_layer(layer, li, &dims, last, spec, out_spec, tables);
            plan.push(entry);
            weights.push(codes);
            biases.push(bias_exact);
        }
        let quantizer = Arc::clone(&plan[0].quantizer);
        DeepPositron { mixed, quantizer, weights, biases, plan, dims }
    }

    /// Compile ONE layer onto the accelerator: quantize its parameters into
    /// `spec`, pre-decode the EMAC operands, and point the terminal round at
    /// `out_spec` (the §10 boundary recode). The per-layer unit both
    /// [`DeepPositron::build`] and [`DeepPositron::recompile_mixed`] compose.
    fn build_layer(
        layer: &Layer,
        li: usize,
        dims: &[usize],
        last: usize,
        spec: FormatSpec,
        out_spec: FormatSpec,
        tables: &dyn Fn(FormatSpec) -> Arc<Quantizer>,
    ) -> (Vec<u16>, Vec<Exact>, LayerPlan) {
        let quantizer = tables(spec);
        let (codes, _) = quantizer.quantize_slice(&layer.w);
        let bias_exact: Vec<Exact> = layer
            .b
            .iter()
            .map(|&b| {
                let (code, _) = quantizer.quantize_f64(b);
                quantizer.decode(code).unwrap_or(Exact::ZERO)
            })
            .collect();
        let relu = layer.kind.has_weights() && li < last;
        let entry =
            DeepPositron::plan_entry(&layer.geom(), dims[li], dims[li + 1], relu, spec, out_spec, &codes, &bias_exact, tables);
        (codes, bias_exact, entry)
    }

    /// Assemble one [`LayerPlan`] from already-quantized parameters: the
    /// shared tail of [`DeepPositron::build_layer`] (which quantizes from
    /// f64 first) and [`DeepPositron::compile_from_codes`] (which starts
    /// from artifact codes and never sees an f64 weight).
    #[allow(clippy::too_many_arguments)]
    fn plan_entry(
        geom: &LayerGeom,
        in_dim: usize,
        out_dim: usize,
        relu: bool,
        spec: FormatSpec,
        out_spec: FormatSpec,
        codes: &[u16],
        bias_exact: &[Exact],
        tables: &dyn Fn(FormatSpec) -> Arc<Quantizer>,
    ) -> LayerPlan {
        let quantizer = tables(spec);
        let lut = DecodeLut::shared(spec);
        // Eq. (2) width check, once at compile time per layer, at the
        // layer's OWN accumulation length: receptive-field fan-in + 1
        // bias term for weighted layers (dense: in_dim + 1, exactly the
        // pre-IR bound; conv: kh·kw·in_ch + 1 — the conv EMAC no longer
        // provisions an input-width quire).
        lut.assert_quire_fits(geom.eq2_k());
        debug_assert!(codes.iter().all(|&c| !lut.op(c).is_invalid()), "non-canonical weight code");
        // Packed storage whenever the format has a monomorphized table
        // (every ≤8-bit paper format): one dense byte per weight, decoded
        // on the fly. Wider formats pre-decode as before.
        let w = if lut.ops8().is_some() {
            PlanWeights::Packed(codes.iter().map(|&c| c as u8).collect())
        } else {
            PlanWeights::Wide(codes.iter().map(|&c| lut.op(c)).collect())
        };
        let out_q = if out_spec == spec { Arc::clone(&quantizer) } else { tables(out_spec) };
        LayerPlan {
            kind: geom.kind,
            in_shape: geom.in_shape,
            out_shape: geom.out_shape,
            in_dim,
            out_dim,
            zero: quantizer.zero_code(),
            out_zero: out_q.zero_code(),
            bias_q: bias_exact.iter().map(|b| lut.to_quire(b)).collect(),
            relu,
            w,
            lut,
            out_q,
            quantizer,
        }
    }

    /// Compile an accelerator instance **directly from quantized codes** —
    /// the `.dpz` artifact fast path (DESIGN.md §16): no dataset, no
    /// trainer, no f64 weight pass. `weight_codes`/`bias_codes` carry one
    /// entry per IR layer (empty vectors for weightless kinds), in the
    /// layer format of `mixed`; every code must be canonical in its layer's
    /// format ([`crate::artifact::Artifact::parse`] validates this before
    /// calling, so the serve-from-artifact path never panics here).
    ///
    /// Bit-identical to [`DeepPositron::compile`] /
    /// [`DeepPositron::compile_mixed`] on the network the codes came from:
    /// both paths feed the same codes through the same plan assembly.
    pub fn compile_from_codes(
        ir: &NetIr,
        mixed: MixedSpec,
        weight_codes: Vec<Vec<u16>>,
        bias_codes: &[Vec<u16>],
    ) -> DeepPositron {
        assert_eq!(mixed.len(), ir.len(), "mixed assignment must carry exactly one format per layer");
        assert_eq!(weight_codes.len(), ir.len(), "one weight-code tensor per layer");
        assert_eq!(bias_codes.len(), ir.len(), "one bias-code tensor per layer");
        let dims = ir.dims();
        let specs = mixed.layers();
        let last = ir.len() - 1;
        let mut biases = Vec::with_capacity(ir.len());
        let mut plan = Vec::with_capacity(ir.len());
        for (li, geom) in ir.geoms().iter().enumerate() {
            let spec = specs[li];
            let out_spec = specs.get(li + 1).copied().unwrap_or(spec);
            let quantizer = Quantizer::shared(spec);
            assert_eq!(weight_codes[li].len(), geom.num_weights(), "layer {li} weight count");
            assert_eq!(bias_codes[li].len(), geom.num_biases(), "layer {li} bias count");
            let bias_exact: Vec<Exact> =
                bias_codes[li].iter().map(|&c| quantizer.decode(c).unwrap_or(Exact::ZERO)).collect();
            let relu = geom.kind.has_weights() && li < last;
            let entry = DeepPositron::plan_entry(
                geom,
                dims[li],
                dims[li + 1],
                relu,
                spec,
                out_spec,
                &weight_codes[li],
                &bias_exact,
                &Quantizer::shared,
            );
            plan.push(entry);
            biases.push(bias_exact);
        }
        let quantizer = Arc::clone(&plan[0].quantizer);
        DeepPositron { mixed, quantizer, weights: weight_codes, biases, plan, dims }
    }

    /// Per-layer quantized weight codes (plan layout order; empty entries
    /// for weightless layers) — what the `.dpz` artifact writer packs.
    pub fn weight_codes(&self) -> &[Vec<u16>] {
        &self.weights
    }

    /// Per-layer bias codes, re-quantized from the stored exact biases
    /// (identity: each stored bias is the decoded value of a canonical
    /// code, so quantizing it back returns that code).
    pub fn bias_codes(&self) -> Vec<Vec<u16>> {
        self.plan
            .iter()
            .zip(&self.biases)
            .map(|(lp, bs)| bs.iter().map(|b| lp.quantizer.quantize_exact(b).0).collect())
            .collect()
    }

    /// The network's typed IR, rebuilt from the compiled plan — lets an
    /// artifact be written from a compiled instance alone.
    pub fn ir(&self) -> NetIr {
        NetIr::new(
            self.plan
                .iter()
                .map(|lp| LayerGeom { kind: lp.kind, in_shape: lp.in_shape, out_shape: lp.out_shape })
                .collect(),
        )
    }

    /// The network's input-layer format. Uniform networks (compiled via
    /// [`DeepPositron::compile`]) carry this format everywhere; the full
    /// per-layer assignment is [`DeepPositron::mixed`].
    pub fn spec(&self) -> FormatSpec {
        self.mixed.layers()[0]
    }

    /// The per-layer format assignment this instance was compiled for.
    pub fn mixed(&self) -> &MixedSpec {
        &self.mixed
    }

    /// The (shared) input-layer quantization tables backing this instance —
    /// the tables requests quantize through. Mixed networks carry further
    /// per-layer tables inside their execution plan.
    pub fn quantizer(&self) -> &Quantizer {
        &self.quantizer
    }

    /// The quantizer of the network's OUTPUT codes (the last layer's
    /// terminal-round target — equal to [`DeepPositron::quantizer`] for
    /// uniform networks).
    fn output_quantizer(&self) -> &Quantizer {
        &self.plan.last().expect("plan has layers").out_q
    }

    /// The dequantized weight values per layer (what the XLA fast path
    /// consumes as its `weights` input; empty entries for weightless
    /// layers).
    pub fn dequantized_weights(&self) -> Vec<Vec<f64>> { // exact-lint: allow(float, XLA fast-path export, off the quire path)
        self.plan.iter().zip(&self.weights).map(|(lp, codes)| lp.quantizer.dequantize_slice(codes)).collect()
    }

    /// The dequantized bias values per layer (fast-path input).
    // exact-lint: allow(float, XLA fast-path export, off the quire path)
    pub fn dequantized_biases(&self) -> Vec<Vec<f64>> {
        self.biases.iter().map(|bs| bs.iter().map(|b| b.to_f64()).collect()).collect()
    }

    /// Run one sample through the EMAC datapath; returns the output-layer
    /// codes (pre-argmax "logits" in format space).
    pub fn forward_codes(&self, x: &[f64]) -> Vec<u16> { // exact-lint: allow(float, ingress boundary: raw sample quantized once here)
        self.forward_codes_with(x, Datapath::Emac)
    }

    /// Run one sample through a selected datapath — the batch-of-one case of
    /// [`DeepPositron::forward_batch`].
    pub fn forward_codes_with(&self, x: &[f64], mode: Datapath) -> Vec<u16> { // exact-lint: allow(float, ingress boundary: raw sample quantized once here)
        self.forward_batch(&[x], mode).pop().expect("one row in, one row out")
    }

    /// Flat fan-out of the network: the length of one output-code row.
    pub fn out_dim(&self) -> usize {
        *self.dims.last().expect("network has layers")
    }

    /// Run a batch of samples through a selected datapath, walking every
    /// layer once for the whole batch. Bit-identical to running each sample
    /// through the scalar EMAC loop: quire accumulation is exact integer
    /// addition (order-free), the narrow-quire wrap is a homomorphism mod
    /// 2^bits (so one terminal wrap equals the scalar per-step wrap), and the
    /// inexact path keeps the scalar per-sample operation order.
    ///
    /// Convenience wrapper over [`DeepPositron::forward_batch_into`] that
    /// allocates one `Vec` per row; hot callers (serving, evaluation) use
    /// the flat-buffer entry point directly.
    pub fn forward_batch(&self, rows: &[&[f64]], mode: Datapath) -> Vec<Vec<u16>> { // exact-lint: allow(float, ingress boundary: raw rows quantized once)
        let mut flat = Vec::new();
        self.forward_batch_into(rows, mode, &mut flat);
        flat.chunks(self.out_dim()).map(<[u16]>::to_vec).collect()
    }

    /// [`DeepPositron::forward_batch`] into a caller-reused flat buffer:
    /// `out` is cleared and filled sample-major (sample `s`'s output codes
    /// occupy `out[s * out_dim .. (s + 1) * out_dim]`), with no per-row
    /// allocations. Batches of at least `PAR_MIN_ROWS` fan out across the
    /// process-wide [`WorkerPool`] as independent contiguous sample chunks —
    /// results are bit-identical at any pool width.
    pub fn forward_batch_into(&self, rows: &[&[f64]], mode: Datapath, out: &mut Vec<u16>) { // exact-lint: allow(float, ingress boundary: raw rows quantized once)
        let pool = WorkerPool::global();
        if pool.threads() > 1 && rows.len() >= PAR_MIN_ROWS {
            self.forward_batch_into_with(rows, mode, pool, out);
        } else {
            self.prepare_out(rows, out);
            if !rows.is_empty() {
                self.run_block(rows, mode, out);
            }
        }
    }

    /// [`DeepPositron::forward_batch_into`] through an explicit pool (the
    /// injection point for tests and for callers managing their own
    /// parallelism budget). Always chunks by the pool's width — a pool wider
    /// than the batch simply runs one-sample chunks.
    pub fn forward_batch_into_with(&self, rows: &[&[f64]], mode: Datapath, pool: &WorkerPool, out: &mut Vec<u16>) { // exact-lint: allow(float, ingress boundary: raw rows quantized once)
        self.prepare_out(rows, out);
        if rows.is_empty() {
            return;
        }
        let chunk = rows.len().div_ceil(pool.threads());
        let jobs: Vec<_> = rows
            .chunks(chunk)
            .zip(out.chunks_mut(chunk * self.out_dim()))
            .map(|(rchunk, ochunk)| move || self.run_block(rchunk, mode, ochunk))
            .collect();
        pool.run(jobs);
    }

    /// Validate the batch and size the flat output buffer (`b × out_dim`).
    fn prepare_out(&self, rows: &[&[f64]], out: &mut Vec<u16>) { // exact-lint: allow(float, sizing helper over the raw ingress rows)
        for row in rows {
            assert_eq!(row.len(), self.dims[0], "feature dim mismatch");
        }
        out.clear();
        out.resize(rows.len() * self.out_dim(), 0);
    }

    /// One contiguous sample chunk through the selected datapath (the unit
    /// of worker-pool fan-out). `out` is the chunk's sample-major region.
    fn run_block(&self, rows: &[&[f64]], mode: Datapath, out: &mut [u16]) { // exact-lint: allow(float, dispatch over the raw ingress rows)
        match mode {
            Datapath::Emac => self.batch_emac(rows, None, out),
            Datapath::NarrowQuire(bits) => {
                assert!((2..=127).contains(&bits));
                self.batch_emac(rows, Some(bits), out)
            }
            Datapath::InexactMac => self.batch_inexact(rows, out),
        }
    }

    /// Quantize input rows into a feature-major code block (`[feature][sample]`
    /// — the layout that keeps the batched kernels' sample loops contiguous).
    fn quantize_block(&self, rows: &[&[f64]], act: &mut [u16]) { // exact-lint: allow(float, THE ingress quantization point: f64 in, codes out)
        let b = rows.len();
        for (s, row) in rows.iter().enumerate() {
            for (i, &x) in row.iter().enumerate() {
                act[i * b + s] = self.quantizer.quantize_f64(x).0;
            }
        }
    }

    /// Transpose the final feature-major activation block into the flat
    /// sample-major output region (no per-row allocations).
    fn gather_into(&self, act: &[u16], b: usize, out: &mut [u16]) {
        let out_dim = self.out_dim();
        for (s, orow) in out.chunks_mut(out_dim).enumerate().take(b) {
            for (o, code) in orow.iter_mut().enumerate() {
                *code = act[o * b + s];
            }
        }
    }

    /// The tiled, monomorphized batched EMAC kernel (DESIGN.md §12): per
    /// layer, decode the incoming activation block ONCE through the
    /// monomorphized table, then walk [`ROW_TILE`] weight rows ×
    /// [`LANE_BLOCK`] batch lanes — each decoded activation column feeds
    /// the whole row tile while the quire tile stays register/L1 resident —
    /// and round once at the terminal stage, directly into the next layer's
    /// format (the §10 boundary recode; a no-op change of target for
    /// uniform networks).
    fn batch_emac(&self, rows: &[&[f64]], width_limit: Option<u32>, out: &mut [u16]) { // exact-lint: allow(float, raw rows enter here; the body is integer-only)
        let b = rows.len();
        let max_dim = *self.dims.iter().max().unwrap();
        let mut act = vec![0u16; b * max_dim];
        let mut next = vec![0u16; b * max_dim];
        let mut dec = vec![DecodedOp::INVALID; b * max_dim];
        // The live quire tile: ROW_TILE rows at a fixed LANE_BLOCK stride
        // (2 KiB total) — reused across every tile of every layer.
        let mut quires = [0i128; ROW_TILE * LANE_BLOCK];
        self.quantize_block(rows, &mut act);
        // Per-layer wall-clock attribution (DESIGN.md §15). Feature-gated so
        // the default build's exact zone carries zero timing overhead; the
        // hook only reads clocks and bumps process-wide atomics — it never
        // touches the numeric datapath.
        #[cfg(feature = "obs-layer-timing")]
        let mut layer_idx = 0usize;
        for lp in &self.plan {
            #[cfg(feature = "obs-layer-timing")]
            let layer_t0 = std::time::Instant::now();
            let lsb = lp.lut.lsb_exp();
            if !matches!(lp.kind, LayerKind::Flatten) {
                // One decode per input element per layer — the tiles below
                // reuse these operands fan-out many times.
                decode_block(&lp.lut, &act[..lp.in_dim * b], &mut dec[..lp.in_dim * b]);
            }
            match lp.kind {
                // Each weighted kernel is monomorphized twice over the
                // weight-fetch strategy: the packed arm streams dense u8
                // codes through the 256-entry table, the wide arm reads
                // pre-decoded operands. Same loops, same bits, either way.
                LayerKind::Dense => match &lp.w {
                    PlanWeights::Packed(codes) => {
                        let table = lp.lut.ops8().expect("packed weights imply a monomorphized table");
                        dense_emac(lp, &PackedW { table, codes }, b, lsb, width_limit, &dec, &mut next, &mut quires);
                    }
                    PlanWeights::Wide(ops) => {
                        dense_emac(lp, &WideW(ops), b, lsb, width_limit, &dec, &mut next, &mut quires);
                    }
                },
                LayerKind::Conv2d { .. } => match &lp.w {
                    PlanWeights::Packed(codes) => {
                        let table = lp.lut.ops8().expect("packed weights imply a monomorphized table");
                        conv_emac(lp, &PackedW { table, codes }, b, lsb, width_limit, &dec, &mut next, &mut quires);
                    }
                    PlanWeights::Wide(ops) => {
                        conv_emac(lp, &WideW(ops), b, lsb, width_limit, &dec, &mut next, &mut quires);
                    }
                },
                LayerKind::AvgPool { k, stride } => {
                    let (ih, iw) = lp.in_shape.hw();
                    let (oh, ow) = lp.out_shape.hw();
                    let c = lp.in_shape.channels();
                    // k is a power of two (IR invariant), so dividing the
                    // window sum by k² is an exact exponent down-shift.
                    let down = (k * k).trailing_zeros() as i32;
                    for ch in 0..c {
                        for oy in 0..oh {
                            for ox in 0..ow {
                                for s0 in (0..b).step_by(LANE_BLOCK) {
                                    let lanes = LANE_BLOCK.min(b - s0);
                                    quires[..lanes].fill(0);
                                    for ky in 0..k {
                                        for kx in 0..k {
                                            let i = ch * ih * iw + (oy * stride + ky) * iw + (ox * stride + kx);
                                            sum_lane(&mut quires[..lanes], &dec[i * b + s0..i * b + s0 + lanes], lsb);
                                        }
                                    }
                                    let o = ch * oh * ow + oy * ow + ox;
                                    round_lane(
                                        lp,
                                        lsb,
                                        down,
                                        width_limit,
                                        &quires[..lanes],
                                        &mut next[o * b + s0..o * b + s0 + lanes],
                                    );
                                }
                            }
                        }
                    }
                }
                LayerKind::Flatten => {
                    recode_columns(lp, &act[..lp.in_dim * b], &mut next[..lp.in_dim * b]);
                }
            }
            #[cfg(feature = "obs-layer-timing")]
            {
                let layer_ns = layer_t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
                crate::obs::timing::record_layer(layer_idx, layer_ns);
                layer_idx += 1;
            }
            std::mem::swap(&mut act, &mut next);
        }
        self.gather_into(&act, b, out);
    }

    /// The batched conventional-MAC ablation: round after every multiply and
    /// every add, preserving the scalar per-sample operation order exactly.
    /// Under a mixed assignment each layer's ALU rounds in that layer's
    /// format and the finished sum recodes into the next layer's format —
    /// identity for uniform networks (quantize of a representable value).
    /// Average pooling multiplies the window sum by the rounded code of
    /// `1/k²` (a conventional unit has no exact shift); flatten recodes.
    fn batch_inexact(&self, rows: &[&[f64]], out: &mut [u16]) { // exact-lint: allow(float, raw rows enter the width-limited ablation path)
        let b = rows.len();
        let max_dim = *self.dims.iter().max().unwrap();
        let mut act = vec![0u16; b * max_dim];
        let mut next = vec![0u16; b * max_dim];
        let mut accs = vec![0u16; b];
        self.quantize_block(rows, &mut act);
        for (lp, (codes, biases)) in self.plan.iter().zip(self.weights.iter().zip(&self.biases)) {
            let alu = ScalarAlu::new(&lp.quantizer);
            match lp.kind {
                LayerKind::Dense => {
                    for o in 0..lp.out_dim {
                        let wrow = &codes[o * lp.in_dim..(o + 1) * lp.in_dim];
                        accs.fill(lp.zero);
                        for (i, &wc) in wrow.iter().enumerate() {
                            let acol = &act[i * b..(i + 1) * b];
                            for (s, &ac) in acol.iter().enumerate() {
                                accs[s] = alu.add(accs[s], alu.mul(wc, ac));
                            }
                        }
                        let (bcode, _) = lp.quantizer.quantize_exact(&biases[o]);
                        let out = &mut next[o * b..(o + 1) * b];
                        for (s, out_code) in out.iter_mut().enumerate() {
                            let acc = alu.add(accs[s], bcode);
                            let v = lp.quantizer.decode(acc).expect("rounded code decodes");
                            *out_code = if lp.relu && v.sign { lp.out_zero } else { lp.out_q.quantize_exact(&v).0 };
                        }
                    }
                }
                LayerKind::Conv2d { kh, kw, stride, in_ch, out_ch } => {
                    let (ih, iw) = lp.in_shape.hw();
                    let (oh, ow) = lp.out_shape.hw();
                    for oc in 0..out_ch {
                        let wrow = &codes[oc * in_ch * kh * kw..(oc + 1) * in_ch * kh * kw];
                        let (bcode, _) = lp.quantizer.quantize_exact(&biases[oc]);
                        for oy in 0..oh {
                            for ox in 0..ow {
                                accs.fill(lp.zero);
                                for ic in 0..in_ch {
                                    for ky in 0..kh {
                                        for kx in 0..kw {
                                            let wc = wrow[ic * kh * kw + ky * kw + kx];
                                            let i = ic * ih * iw + (oy * stride + ky) * iw + (ox * stride + kx);
                                            let acol = &act[i * b..(i + 1) * b];
                                            for (s, &ac) in acol.iter().enumerate() {
                                                accs[s] = alu.add(accs[s], alu.mul(wc, ac));
                                            }
                                        }
                                    }
                                }
                                let o = oc * oh * ow + oy * ow + ox;
                                let out = &mut next[o * b..(o + 1) * b];
                                for (s, out_code) in out.iter_mut().enumerate() {
                                    let acc = alu.add(accs[s], bcode);
                                    let v = lp.quantizer.decode(acc).expect("rounded code decodes");
                                    *out_code =
                                        if lp.relu && v.sign { lp.out_zero } else { lp.out_q.quantize_exact(&v).0 };
                                }
                            }
                        }
                    }
                }
                LayerKind::AvgPool { k, stride } => {
                    let (ih, iw) = lp.in_shape.hw();
                    let (oh, ow) = lp.out_shape.hw();
                    let c = lp.in_shape.channels();
                    let (recip, _) = lp.quantizer.quantize_f64(1.0 / (k * k) as f64); // exact-lint: allow(float, pool reciprocal staged as a quantized code)
                    for ch in 0..c {
                        for oy in 0..oh {
                            for ox in 0..ow {
                                accs.fill(lp.zero);
                                for ky in 0..k {
                                    for kx in 0..k {
                                        let i = ch * ih * iw + (oy * stride + ky) * iw + (ox * stride + kx);
                                        let acol = &act[i * b..(i + 1) * b];
                                        for (s, &ac) in acol.iter().enumerate() {
                                            accs[s] = alu.add(accs[s], ac);
                                        }
                                    }
                                }
                                let o = ch * oh * ow + oy * ow + ox;
                                let out = &mut next[o * b..(o + 1) * b];
                                for (s, out_code) in out.iter_mut().enumerate() {
                                    let acc = alu.mul(accs[s], recip);
                                    let v = lp.quantizer.decode(acc).expect("rounded code decodes");
                                    *out_code = lp.out_q.quantize_exact(&v).0;
                                }
                            }
                        }
                    }
                }
                LayerKind::Flatten => {
                    recode_columns(lp, &act[..lp.in_dim * b], &mut next[..lp.in_dim * b]);
                }
            }
            std::mem::swap(&mut act, &mut next);
        }
        self.gather_into(&act, b, out);
    }

    /// Argmax over the decoded values of an output-code row (decoded through
    /// the last layer's output format). Returns `None` when no code decodes
    /// to a real value (an all-NaR row) — callers must not mistake an
    /// undecodable row for class 0.
    // exact-lint: allow(float, terminal readout: codes decode to values once, after all accumulation)
    pub fn decoded_argmax(&self, codes: &[u16]) -> Option<usize> {
        let out_q = self.output_quantizer();
        let mut best: Option<(usize, f64)> = None;
        for (i, &c) in codes.iter().enumerate() {
            if let Some(e) = out_q.decode(c) {
                let v = e.to_f64();
                if best.map_or(true, |(_, bv)| v > bv) {
                    best = Some((i, v));
                }
            }
        }
        best.map(|(i, _)| i)
    }

    /// Predicted class for one sample: argmax over the decoded output codes.
    /// Posit codes could be compared as signed integers directly (the posit
    /// monotonicity property); decoding keeps this uniform across formats.
    /// Panics on an all-NaR output row (never produced by the datapaths,
    /// whose terminal rounds emit canonical codes only).
    pub fn predict(&self, x: &[f64]) -> usize { // exact-lint: allow(float, ingress boundary: raw sample in)
        self.decoded_argmax(&self.forward_codes(x)).expect("output row decoded to no real value")
    }

    /// Batched predictions on the EMAC datapath — one compiled-plan walk for
    /// the whole batch through the flat-buffer fast path (the serving
    /// engine's Sim execution path).
    pub fn predict_batch(&self, rows: &[&[f64]]) -> Vec<usize> { // exact-lint: allow(float, ingress boundary: raw rows in)
        let mut flat = Vec::new();
        self.forward_batch_into(rows, Datapath::Emac, &mut flat);
        flat.chunks(self.out_dim())
            .map(|codes| self.decoded_argmax(codes).expect("output row decoded to no real value"))
            .collect()
    }

    /// Accuracy over the first `rows.min(test_len)` test rows under a
    /// selected datapath — the capped batched evaluator the auto-tuner
    /// ([`crate::tune`]) scores candidate assignments with. Chunks of
    /// [`EVAL_BATCH`] samples per plan walk; undecodable output rows count
    /// as wrong, never as class 0.
    pub fn accuracy_on(&self, ds: &Dataset, mode: Datapath, rows: usize) -> f64 { // exact-lint: allow(float, accuracy readout, not accumulation)
        self.accuracy_loop(ds, mode, rows, None)
    }

    /// [`DeepPositron::accuracy_on`] through an explicit worker pool —
    /// the injection point for callers that manage their own parallelism
    /// budget (the tuner's candidate-level fan-out runs each evaluation's
    /// batches inline on a width-1 pool rather than nesting fan-outs).
    /// Bit-identical to `accuracy_on` at any pool width: batched EMAC
    /// results never depend on chunking (exact quire addition).
    pub fn accuracy_on_with(&self, ds: &Dataset, mode: Datapath, rows: usize, pool: &WorkerPool) -> f64 { // exact-lint: allow(float, accuracy readout, not accumulation)
        self.accuracy_loop(ds, mode, rows, Some(pool))
    }

    /// Shared accuracy loop: `pool` `None` routes through the global-pool
    /// heuristics of [`DeepPositron::forward_batch_into`]; `Some` pins every
    /// batch to the given pool.
    // exact-lint: allow(float, accuracy readout over test rows — consumes datapath outputs, never feeds them)
    fn accuracy_loop(&self, ds: &Dataset, mode: Datapath, rows: usize, pool: Option<&WorkerPool>) -> f64 {
        let total = ds.test_len().min(rows.max(1));
        let mut correct = 0usize;
        let mut i = 0;
        let mut flat = Vec::new();
        while i < total {
            let take = EVAL_BATCH.min(total - i);
            let rows: Vec<&[f64]> = (i..i + take).map(|j| ds.test_row(j)).collect();
            match pool {
                Some(pool) => self.forward_batch_into_with(&rows, mode, pool, &mut flat),
                None => self.forward_batch_into(&rows, mode, &mut flat),
            }
            for (j, codes) in flat.chunks(self.out_dim()).enumerate() {
                if self.decoded_argmax(codes) == Some(ds.y_test[i + j] as usize) {
                    correct += 1;
                }
            }
            i += take;
        }
        correct as f64 / total as f64
    }

    /// Test accuracy under a selected datapath, evaluated through
    /// [`DeepPositron::forward_batch`] over the whole test split
    /// (the uncapped case of [`DeepPositron::accuracy_on`]).
    pub fn accuracy_with(&self, ds: &Dataset, mode: Datapath) -> f64 { // exact-lint: allow(float, accuracy readout, not accumulation)
        self.accuracy_on(ds, mode, usize::MAX)
    }

    /// Test-set accuracy on the EMAC datapath (batched evaluation).
    pub fn accuracy(&self, ds: &Dataset) -> f64 { // exact-lint: allow(float, accuracy readout, not accumulation)
        self.accuracy_with(ds, Datapath::Emac)
    }

    /// Reference forward pass with *dequantized* weights and table-rounded
    /// activations in f64 — the semantics of the XLA artifact (and, for
    /// conv layers, the independent oracle `tests/conv.rs` checks against).
    /// Where f64 accumulation is exact (every format here except the widest
    /// posit quires), this matches [`Self::forward_codes`] bit for bit.
    // exact-lint: allow(float, deliberate f64 REFERENCE path — the oracle the exact datapath is checked against)
    pub fn forward_dequantized(&self, x: &[f64]) -> Vec<f64> {
        let (_, mut act) = self.quantizer.quantize_slice(x);
        for (lp, (w, b)) in self.plan.iter().zip(self.weights.iter().zip(&self.biases)) {
            let round = |acc: f64, relu: bool| -> f64 {
                let (_, rounded) = lp.out_q.quantize_f64(acc);
                if relu {
                    rounded.max(0.0)
                } else {
                    rounded
                }
            };
            let mut next = Vec::with_capacity(lp.out_dim);
            match lp.kind {
                LayerKind::Dense => {
                    let wv = lp.quantizer.dequantize_slice(w);
                    for o in 0..lp.out_dim {
                        let mut acc = b[o].to_f64();
                        for i in 0..lp.in_dim {
                            acc += wv[o * lp.in_dim + i] * act[i];
                        }
                        // Terminal round into the output (next-layer)
                        // format — same target the EMAC's boundary recode
                        // rounds into.
                        next.push(round(acc, lp.relu));
                    }
                }
                LayerKind::Conv2d { kh, kw, stride, in_ch, out_ch } => {
                    let wv = lp.quantizer.dequantize_slice(w);
                    let (ih, iw) = lp.in_shape.hw();
                    let (oh, ow) = lp.out_shape.hw();
                    next.resize(lp.out_dim, 0.0);
                    for oc in 0..out_ch {
                        for oy in 0..oh {
                            for ox in 0..ow {
                                let mut acc = b[oc].to_f64();
                                for ic in 0..in_ch {
                                    for ky in 0..kh {
                                        for kx in 0..kw {
                                            let i = ic * ih * iw + (oy * stride + ky) * iw + (ox * stride + kx);
                                            acc += wv[oc * in_ch * kh * kw + ic * kh * kw + ky * kw + kx] * act[i];
                                        }
                                    }
                                }
                                next[oc * oh * ow + oy * ow + ox] = round(acc, lp.relu);
                            }
                        }
                    }
                }
                LayerKind::AvgPool { k, stride } => {
                    let (ih, iw) = lp.in_shape.hw();
                    let (oh, ow) = lp.out_shape.hw();
                    let c = lp.in_shape.channels();
                    next.resize(lp.out_dim, 0.0);
                    for ch in 0..c {
                        for oy in 0..oh {
                            for ox in 0..ow {
                                let mut acc = 0.0;
                                for ky in 0..k {
                                    for kx in 0..k {
                                        acc += act[ch * ih * iw + (oy * stride + ky) * iw + (ox * stride + kx)];
                                    }
                                }
                                // k² is a power of two: the division is
                                // exact in f64, mirroring the quire shift.
                                next[ch * oh * ow + oy * ow + ox] = round(acc / (k * k) as f64, false);
                            }
                        }
                    }
                }
                LayerKind::Flatten => {
                    for &v in &act {
                        next.push(lp.out_q.quantize_f64(v).1);
                    }
                }
            }
            act = next;
        }
        act
    }
}

/// Decode one activation-code block into flat EMAC operands — once per
/// layer, instead of once per weight×activation pair. For every ≤8-bit
/// format the monomorphized 256-entry [`DecodeLut::ops8`] table is indexed
/// with `code as u8`, which can never be out of bounds, so the optimizer
/// drops the bounds check from this loop; wider formats keep the generic
/// slice path.
#[inline]
fn decode_block(lut: &DecodeLut, act: &[u16], dec: &mut [DecodedOp]) {
    if let Some(table) = lut.ops8() {
        for (d, &code) in dec.iter_mut().zip(act) {
            debug_assert!(code < 256, "code wider than the monomorphized table");
            *d = table[code as u8 as usize];
            debug_assert!(!d.is_invalid(), "non-canonical activation code {code:#x}");
        }
    } else {
        let ops = lut.ops();
        for (d, &code) in dec.iter_mut().zip(act) {
            *d = ops[code as usize];
            debug_assert!(!d.is_invalid(), "non-canonical activation code {code:#x}");
        }
    }
}

/// The tiled dense EMAC kernel, generic over the weight-fetch strategy
/// (packed u8 codes vs pre-decoded operands — see [`PlanWeights`]). The
/// loop structure is identical for both monomorphizations: [`ROW_TILE`]
/// weight rows × [`LANE_BLOCK`] batch lanes, bias-seeded quires, one
/// terminal round per output lane.
#[allow(clippy::too_many_arguments)]
fn dense_emac<W: WeightFetch>(
    lp: &LayerPlan,
    w: &W,
    b: usize,
    lsb: i32,
    width_limit: Option<u32>,
    dec: &[DecodedOp],
    next: &mut [u16],
    quires: &mut [i128; ROW_TILE * LANE_BLOCK],
) {
    for o0 in (0..lp.out_dim).step_by(ROW_TILE) {
        let o1 = (o0 + ROW_TILE).min(lp.out_dim);
        for s0 in (0..b).step_by(LANE_BLOCK) {
            let lanes = LANE_BLOCK.min(b - s0);
            for (r, o) in (o0..o1).enumerate() {
                quires[r * LANE_BLOCK..r * LANE_BLOCK + lanes].fill(lp.bias_q[o]);
            }
            for i in 0..lp.in_dim {
                let acol = &dec[i * b + s0..i * b + s0 + lanes];
                for (r, o) in (o0..o1).enumerate() {
                    let wop = w.op(o * lp.in_dim + i);
                    if wop.mag == 0 {
                        continue; // zero weight annihilates the lane
                    }
                    mac_lane(&mut quires[r * LANE_BLOCK..r * LANE_BLOCK + lanes], wop, acol, lsb);
                }
            }
            for (r, o) in (o0..o1).enumerate() {
                round_lane(
                    lp,
                    lsb,
                    0,
                    width_limit,
                    &quires[r * LANE_BLOCK..r * LANE_BLOCK + lanes],
                    &mut next[o * b + s0..o * b + s0 + lanes],
                );
            }
        }
    }
}

/// The tiled conv2d EMAC kernel, generic over the weight-fetch strategy
/// (the conv twin of [`dense_emac`]): one quire per output pixel, seeded
/// with the channel bias, accumulating the `kh·kw·in_ch` receptive field
/// across [`ROW_TILE`] output channels × [`LANE_BLOCK`] batch lanes.
/// Panics if `lp.kind` is not conv (callers dispatch on the kind).
#[allow(clippy::too_many_arguments)]
fn conv_emac<W: WeightFetch>(
    lp: &LayerPlan,
    w: &W,
    b: usize,
    lsb: i32,
    width_limit: Option<u32>,
    dec: &[DecodedOp],
    next: &mut [u16],
    quires: &mut [i128; ROW_TILE * LANE_BLOCK],
) {
    let LayerKind::Conv2d { kh, kw, stride, in_ch, out_ch } = lp.kind else {
        panic!("conv_emac on a non-conv layer");
    };
    let (ih, iw) = lp.in_shape.hw();
    let (oh, ow) = lp.out_shape.hw();
    let ksz = in_ch * kh * kw;
    for oc0 in (0..out_ch).step_by(ROW_TILE) {
        let oc1 = (oc0 + ROW_TILE).min(out_ch);
        for oy in 0..oh {
            for ox in 0..ow {
                for s0 in (0..b).step_by(LANE_BLOCK) {
                    let lanes = LANE_BLOCK.min(b - s0);
                    for (r, oc) in (oc0..oc1).enumerate() {
                        quires[r * LANE_BLOCK..r * LANE_BLOCK + lanes].fill(lp.bias_q[oc]);
                    }
                    for ic in 0..in_ch {
                        for ky in 0..kh {
                            for kx in 0..kw {
                                let i = ic * ih * iw + (oy * stride + ky) * iw + (ox * stride + kx);
                                let acol = &dec[i * b + s0..i * b + s0 + lanes];
                                let koff = ic * kh * kw + ky * kw + kx;
                                for (r, oc) in (oc0..oc1).enumerate() {
                                    let wop = w.op(oc * ksz + koff);
                                    if wop.mag == 0 {
                                        continue;
                                    }
                                    mac_lane(&mut quires[r * LANE_BLOCK..r * LANE_BLOCK + lanes], wop, acol, lsb);
                                }
                            }
                        }
                    }
                    for (r, oc) in (oc0..oc1).enumerate() {
                        let o = oc * oh * ow + oy * ow + ox;
                        round_lane(
                            lp,
                            lsb,
                            0,
                            width_limit,
                            &quires[r * LANE_BLOCK..r * LANE_BLOCK + lanes],
                            &mut next[o * b + s0..o * b + s0 + lanes],
                        );
                    }
                }
            }
        }
    }
}

/// Accumulate one pre-decoded weight against one pre-decoded activation
/// lane — the exact product term of `Emac::mac` (canonical magnitudes are
/// ≤16-bit, so the product fits u64). The zip over equal-length lanes keeps
/// the loop bounds-check free.
#[inline]
fn mac_lane(quires: &mut [i128], w: DecodedOp, acol: &[DecodedOp], lsb: i32) {
    for (q, a) in quires.iter_mut().zip(acol) {
        if a.mag == 0 {
            continue;
        }
        let mag = w.mag * a.mag;
        let shift = (w.exp + a.exp - lsb) as u32;
        let term = (mag as i128) << shift;
        *q += if w.neg ^ a.neg { -term } else { term };
    }
}

/// Accumulate one pre-decoded activation lane directly (weightless pooling
/// sum): the value itself shifts into quire units, no product.
#[inline]
fn sum_lane(quires: &mut [i128], acol: &[DecodedOp], lsb: i32) {
    for (q, a) in quires.iter_mut().zip(acol) {
        if a.mag == 0 {
            continue;
        }
        let shift = (a.exp - lsb) as u32;
        let term = (a.mag as i128) << shift;
        *q += if a.neg { -term } else { term };
    }
}

/// Terminal stage for one output lane: optional narrow-quire wrap, then
/// one deferred round straight into the NEXT layer's format. `down` shifts
/// the quire exponent for the exact pool average (0 everywhere else, which
/// reduces to the classic dense terminal round bit for bit).
#[inline]
fn round_lane(lp: &LayerPlan, lsb: i32, down: i32, width_limit: Option<u32>, quires: &[i128], out: &mut [u16]) {
    for (&q0, out_code) in quires.iter().zip(out.iter_mut()) {
        let mut q = q0;
        if let Some(bits) = width_limit {
            // Two's-complement wrap of the undersized register. Wrapping
            // once here is bit-identical to the scalar per-step wrap: sign
            // extension picks the same representative of the sum mod
            // 2^bits.
            let sh = 128 - bits;
            q = (q << sh) >> sh;
        }
        *out_code = if lp.relu && q < 0 {
            // ReLU(x) = max(x, 0): negative sums clamp to the output
            // format's zero code.
            lp.out_zero
        } else {
            lp.out_q.quantize_exact(&Exact::new(q < 0, q.unsigned_abs(), lsb - down)).0
        };
    }
}

/// Flatten as a recode point: when the layer and output formats coincide
/// (uniform networks) the codes copy through untouched; otherwise every
/// code rounds once into the next layer's format — the same
/// recode-at-boundary semantics as a weighted layer's terminal round.
fn recode_columns(lp: &LayerPlan, act: &[u16], next: &mut [u16]) {
    if lp.quantizer.name() == lp.out_q.name() {
        next.copy_from_slice(act);
        return;
    }
    for (&code, out_code) in act.iter().zip(next.iter_mut()) {
        let v = lp.quantizer.decode(code).expect("canonical activation code");
        *out_code = lp.out_q.quantize_exact(&v).0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::ir::Shape;
    use crate::accel::mlp::{train, Layer, TrainConfig};
    use crate::datasets::{self, Scale};
    use crate::util::Rng;

    fn trained_iris() -> (Mlp, crate::datasets::Dataset) {
        let ds = datasets::load("iris", 5, Scale::Small);
        let (norm, means, stds) = ds.normalized();
        let mut rng = Rng::new(2);
        let mut mlp = Mlp::new(&[4, 10, 8, 3], &mut rng);
        train(&mut mlp, &norm, &TrainConfig { epochs: 80, ..Default::default() });
        super::super::mlp::fold_input_normalization(&mut mlp, &means, &stds);
        (mlp, ds)
    }

    /// A small random conv net on an 1×8×8 block (fast enough for in-crate
    /// tests; the full 28×28 conv MNIST coverage lives in `tests/conv.rs`).
    fn tiny_conv_net() -> Mlp {
        let input = Shape::Chw { c: 1, h: 8, w: 8 };
        let mut rng = Rng::new(17);
        let conv = Layer::conv2d(input, 3, 3, 3, 1, &mut rng);
        let pool = Layer::avg_pool(conv.out_shape, 2, 2);
        let flat = Layer::flatten(pool.out_shape);
        let dense = Layer::dense(flat.out_dim, 4, &mut rng);
        Mlp::from_layers(vec![conv, pool, flat, dense])
    }

    #[test]
    fn posit8_tracks_f64_baseline_on_iris() {
        let (mlp, ds) = trained_iris();
        let base = mlp.accuracy(&ds);
        let dp = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 });
        let acc = dp.accuracy(&ds);
        assert!(acc >= base - 0.06, "posit8 lost too much: {acc} vs {base}");
    }

    #[test]
    fn emac_path_matches_dequantized_f64_path() {
        // For formats whose quire fits f64's exact window, the two paths are
        // identical (DESIGN.md §2 exactness argument).
        let (mlp, ds) = trained_iris();
        for spec in ["posit8es1", "float8we4", "fixed8q4"] {
            let dp = DeepPositron::compile(&mlp, FormatSpec::parse(spec).unwrap());
            for i in 0..20 {
                let codes = dp.forward_codes(ds.test_row(i));
                let vals: Vec<f64> = codes.iter().map(|&c| dp.quantizer().decode(c).unwrap().to_f64()).collect();
                let ref_vals = dp.forward_dequantized(ds.test_row(i));
                assert_eq!(vals, ref_vals, "{spec} sample {i}");
            }
        }
    }

    #[test]
    fn forward_batch_matches_per_sample_calls() {
        // Quick in-crate parity check; the exhaustive sweep (every format ×
        // every datapath × an independent scalar oracle) lives in
        // `tests/batch_parity.rs`.
        let (mlp, ds) = trained_iris();
        let dp = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 });
        for mode in [Datapath::Emac, Datapath::InexactMac, Datapath::NarrowQuire(24)] {
            let rows: Vec<&[f64]> = (0..10).map(|i| ds.test_row(i)).collect();
            let batched = dp.forward_batch(&rows, mode);
            for (i, row) in rows.iter().enumerate() {
                assert_eq!(batched[i], dp.forward_codes_with(row, mode), "{mode:?} sample {i}");
            }
        }
    }

    #[test]
    fn conv_plan_batch_matches_per_sample_calls() {
        // In-crate smoke parity for the conv kernels (exhaustive format ×
        // datapath coverage + the independent oracle live in tests/conv.rs).
        let mlp = tiny_conv_net();
        let dp = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 });
        let mut rng = Rng::new(3);
        let inputs: Vec<Vec<f64>> = (0..6).map(|_| (0..64).map(|_| rng.range(0.0, 1.0)).collect()).collect();
        let rows: Vec<&[f64]> = inputs.iter().map(Vec::as_slice).collect();
        for mode in [Datapath::Emac, Datapath::InexactMac, Datapath::NarrowQuire(32)] {
            let batched = dp.forward_batch(&rows, mode);
            for (i, row) in rows.iter().enumerate() {
                assert_eq!(batched[i], dp.forward_codes_with(row, mode), "{mode:?} sample {i}");
            }
        }
    }

    #[test]
    fn conv_emac_matches_dequantized_f64_path() {
        // Conv quire accumulation vs the independent f64 reference with
        // dequantized weights (exact for these narrow-quire formats).
        let mlp = tiny_conv_net();
        let mut rng = Rng::new(4);
        let x: Vec<f64> = (0..64).map(|_| rng.range(0.0, 1.0)).collect();
        for spec in ["posit8es1", "float8we4", "fixed8q4"] {
            let dp = DeepPositron::compile(&mlp, FormatSpec::parse(spec).unwrap());
            let codes = dp.forward_codes(&x);
            let vals: Vec<f64> = codes.iter().map(|&c| dp.quantizer().decode(c).unwrap().to_f64()).collect();
            assert_eq!(vals, dp.forward_dequantized(&x), "{spec}");
        }
    }

    #[test]
    fn recompile_mixed_matches_fresh_compile() {
        // Plan-prefix reuse must be invisible: recompiling from any base
        // assignment is bit-identical to compiling the target from scratch —
        // including the out_q subtlety (layer i's plan also depends on layer
        // i+1's format, so a single-layer perturbation rebuilds two entries).
        let (mlp, ds) = trained_iris();
        let base = DeepPositron::compile_mixed(&mlp, MixedSpec::uniform(FormatSpec::Posit { n: 8, es: 1 }, 3));
        for name in [
            "posit8es1+posit8es1+posit8es1", // no-op: every layer reused
            "posit8es1+posit6es1+posit8es1", // middle move: layers 0 and 1 rebuild
            "posit8es1+posit8es1+fixed7q3",  // tail move: layers 1 and 2 rebuild
            "posit5es1+float8we4+fixed7q3",  // everything changes
        ] {
            let mixed = MixedSpec::parse(name).unwrap();
            let re = base.recompile_mixed(&mlp, mixed.clone());
            let fresh = DeepPositron::compile_mixed(&mlp, mixed);
            assert_eq!(re.mixed(), fresh.mixed(), "{name}");
            for i in 0..12 {
                assert_eq!(re.forward_codes(ds.test_row(i)), fresh.forward_codes(ds.test_row(i)), "{name} sample {i}");
            }
        }
    }

    #[test]
    fn accuracy_on_with_matches_accuracy_on_at_any_width() {
        let (mlp, ds) = trained_iris();
        let dp = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 7, es: 1 });
        let want = dp.accuracy_on(&ds, Datapath::Emac, 24);
        for threads in [1, 2, 8] {
            let pool = crate::util::pool::WorkerPool::new(threads);
            assert_eq!(dp.accuracy_on_with(&ds, Datapath::Emac, 24, &pool), want, "width {threads}");
        }
    }

    #[test]
    fn flat_buffer_and_pooled_entry_points_match_nested() {
        // forward_batch_into (flat, sample-major, buffer-reusing) and the
        // explicit-pool variant must agree bit-for-bit with the nested
        // wrapper — including a batch crossing LANE_BLOCK (33 > 32) and a
        // pool wider than the batch.
        let (mlp, ds) = trained_iris();
        let dp = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 });
        let rows: Vec<&[f64]> = (0..33).map(|i| ds.test_row(i % ds.test_len())).collect();
        let pool = crate::util::pool::WorkerPool::new(8);
        let mut flat = vec![0xFFFFu16; 3]; // stale contents must be cleared
        let mut pooled = Vec::new();
        for mode in [Datapath::Emac, Datapath::InexactMac, Datapath::NarrowQuire(24)] {
            let nested = dp.forward_batch(&rows, mode);
            dp.forward_batch_into(&rows, mode, &mut flat);
            dp.forward_batch_into_with(&rows, mode, &pool, &mut pooled);
            assert_eq!(flat.len(), rows.len() * dp.out_dim());
            assert_eq!(flat, pooled, "{mode:?}: pool width must not change results");
            for (i, row) in nested.iter().enumerate() {
                assert_eq!(&flat[i * dp.out_dim()..(i + 1) * dp.out_dim()], &row[..], "{mode:?} sample {i}");
            }
        }
        // Zero-length batch: empty output, no panic, buffer cleared.
        dp.forward_batch_into(&[], Datapath::Emac, &mut flat);
        assert!(flat.is_empty());
        dp.forward_batch_into_with(&[], Datapath::Emac, &pool, &mut pooled);
        assert!(pooled.is_empty());
    }

    #[test]
    fn decoded_argmax_rejects_all_nar_rows() {
        let (mlp, _) = trained_iris();
        let dp = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 });
        // 0x80 is posit NaR: an all-NaR row has no argmax (NOT class 0).
        assert_eq!(dp.decoded_argmax(&[0x80, 0x80, 0x80]), None);
        // A single decodable code wins regardless of position.
        let one = dp.quantizer().quantize_f64(1.0).0;
        assert_eq!(dp.decoded_argmax(&[0x80, one, 0x80]), Some(1));
        let neg = dp.quantizer().quantize_f64(-2.0).0;
        assert_eq!(dp.decoded_argmax(&[0x80, neg]), Some(1));
    }

    #[test]
    fn lower_precision_degrades_gracefully() {
        let (mlp, ds) = trained_iris();
        let acc8 = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 }).accuracy(&ds);
        let acc5 = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 5, es: 1 }).accuracy(&ds);
        assert!(acc8 >= acc5, "8-bit ({acc8}) should beat 5-bit ({acc5})");
        assert!(acc5 > 0.3, "5-bit posit collapsed entirely: {acc5}");
    }

    #[test]
    fn fixed_point_suffers_most_at_low_bits() {
        // Table 1's qualitative story on a small task: best-of-sweep posit
        // should be ≥ best-of-sweep fixed at 8 bits.
        let (mlp, ds) = trained_iris();
        let best = |family: &str| -> f64 {
            FormatSpec::sweep_family(8, family)
                .into_iter()
                .map(|s| DeepPositron::compile(&mlp, s).accuracy(&ds))
                .fold(0.0, f64::max)
        };
        let posit = best("posit");
        let fixed = best("fixed");
        assert!(posit >= fixed, "posit {posit} < fixed {fixed}");
    }

    #[test]
    fn mixed_assignment_compiles_and_tracks_uniform() {
        // The exhaustive uniform-parity sweep lives in `tests/tune.rs`; this
        // is the in-crate smoke test: a genuinely mixed plan runs end to
        // end, recodes at every boundary, and stays in the accuracy
        // ballpark of its widest uniform member.
        let (mlp, ds) = trained_iris();
        let mixed = MixedSpec::new(vec![
            FormatSpec::Posit { n: 8, es: 1 },
            FormatSpec::Float { n: 7, we: 3 },
            FormatSpec::Posit { n: 6, es: 1 },
        ]);
        let dp = DeepPositron::compile_mixed(&mlp, mixed.clone());
        assert_eq!(dp.mixed(), &mixed);
        assert_eq!(dp.spec(), FormatSpec::Posit { n: 8, es: 1 });
        let acc = dp.accuracy(&ds);
        let acc8 = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 }).accuracy(&ds);
        assert!(acc >= acc8 - 0.2, "mixed plan collapsed: {acc} vs uniform {acc8}");
        // Scalar == batched on the mixed plan too (batch-of-one wrapper).
        let rows: Vec<&[f64]> = (0..6).map(|i| ds.test_row(i)).collect();
        for mode in [Datapath::Emac, Datapath::InexactMac, Datapath::NarrowQuire(32)] {
            let batched = dp.forward_batch(&rows, mode);
            for (i, row) in rows.iter().enumerate() {
                assert_eq!(batched[i], dp.forward_codes_with(row, mode), "{mode:?} sample {i}");
            }
        }
    }

    #[test]
    fn mixed_conv_assignment_recodes_at_every_boundary() {
        // A genuinely mixed conv plan (4 IR nodes incl. a flatten recode
        // point) runs end to end, scalar == batched on all datapaths.
        let mlp = tiny_conv_net();
        let mixed = MixedSpec::parse("posit8es1+float7we3+posit7es1+posit6es1").unwrap();
        let dp = DeepPositron::compile_mixed(&mlp, mixed.clone());
        assert_eq!(dp.mixed(), &mixed);
        let mut rng = Rng::new(5);
        let inputs: Vec<Vec<f64>> = (0..4).map(|_| (0..64).map(|_| rng.range(0.0, 1.0)).collect()).collect();
        let rows: Vec<&[f64]> = inputs.iter().map(Vec::as_slice).collect();
        for mode in [Datapath::Emac, Datapath::InexactMac, Datapath::NarrowQuire(40)] {
            let batched = dp.forward_batch(&rows, mode);
            for (i, row) in rows.iter().enumerate() {
                assert_eq!(batched[i], dp.forward_codes_with(row, mode), "{mode:?} sample {i}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "one format per layer")]
    fn mixed_assignment_must_match_layer_count() {
        let (mlp, _) = trained_iris();
        let _ = DeepPositron::compile_mixed(&mlp, MixedSpec::uniform(FormatSpec::Posit { n: 8, es: 1 }, 2));
    }

    #[test]
    fn compile_from_codes_matches_compile() {
        // The artifact fast path (codes in, no f64 weight pass) must be
        // bit-identical to the classic compile on the network the codes
        // came from — for uniform, genuinely mixed, and (Wide-arm) 16-bit
        // assignments alike.
        let (mlp, ds) = trained_iris();
        for name in ["posit8es1+posit8es1+posit8es1", "posit8es1+posit6es1+fixed7q3", "posit16es1+posit16es1+posit16es1"]
        {
            let mixed = MixedSpec::parse(name).unwrap();
            let dp = DeepPositron::compile_mixed(&mlp, mixed.clone());
            let re =
                DeepPositron::compile_from_codes(&dp.ir(), mixed, dp.weight_codes().to_vec(), &dp.bias_codes());
            assert_eq!(re.mixed(), dp.mixed(), "{name}");
            for i in 0..12 {
                assert_eq!(re.forward_codes(ds.test_row(i)), dp.forward_codes(ds.test_row(i)), "{name} sample {i}");
            }
        }
    }

    #[test]
    fn compile_from_codes_round_trips_a_conv_plan() {
        // Conv + pool + flatten geometries survive the codes round-trip too
        // (the ir() rebuild carries the full typed geometry, not just dims).
        let mlp = tiny_conv_net();
        let dp = DeepPositron::compile(&mlp, FormatSpec::Posit { n: 8, es: 1 });
        let ir = dp.ir();
        assert_eq!(ir, mlp.ir());
        let re = DeepPositron::compile_from_codes(&ir, dp.mixed().clone(), dp.weight_codes().to_vec(), &dp.bias_codes());
        let mut rng = Rng::new(23);
        for _ in 0..4 {
            let x: Vec<f64> = (0..64).map(|_| rng.range(0.0, 1.0)).collect();
            assert_eq!(re.forward_codes(&x), dp.forward_codes(&x));
        }
    }

    #[test]
    fn bias_codes_round_trip_through_quantization() {
        let (mlp, _) = trained_iris();
        let dp = DeepPositron::compile(&mlp, FormatSpec::Float { n: 8, we: 4 });
        // Every stored bias is the decoded value of a canonical code, so
        // re-quantizing is the identity the artifact writer relies on.
        for (codes, layer) in dp.bias_codes().iter().zip(&dp.plan) {
            for &c in codes {
                let v = layer.quantizer.decode(c).expect("canonical bias code");
                assert_eq!(layer.quantizer.quantize_exact(&v).0, c);
            }
        }
    }

    #[test]
    fn weights_roundtrip_through_tables() {
        let (mlp, _) = trained_iris();
        let dp = DeepPositron::compile(&mlp, FormatSpec::Float { n: 8, we: 4 });
        let w = dp.dequantized_weights();
        assert_eq!(w.len(), 3);
        assert_eq!(w[0].len(), 4 * 10);
        // Every dequantized weight must be representable (quantize = id).
        for &v in w[0].iter() {
            let (_, round) = dp.quantizer().quantize_f64(v);
            assert_eq!(round, v);
        }
    }
}
