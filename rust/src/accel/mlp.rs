//! Plain feedforward MLP substrate: 64-bit float reference forward pass and
//! an SGD-with-momentum trainer (softmax cross-entropy).
//!
//! This is the "trained with 32-bit floating point" baseline of the paper's
//! Table 1 (we train in f64 — bit-identical conclusions at these scales, and
//! the quantization experiments only consume the resulting weights). The
//! same training math is AOT-compiled to HLO by `python/compile/model.py`;
//! the Rust trainer is the dependency-free substrate used by tests and the
//! tabular tasks, and cross-validates the artifact path.

use crate::datasets::Dataset;
use crate::util::Rng;

/// One dense layer: row-major `w[out][in]`, bias `b[out]`.
#[derive(Debug, Clone)]
pub struct Layer {
    /// Input width (fan-in).
    pub in_dim: usize,
    /// Output width (fan-out).
    pub out_dim: usize,
    /// Weights, row-major `w[out][in]`.
    pub w: Vec<f64>,
    /// Biases, `b[out]`.
    pub b: Vec<f64>,
}

/// A feedforward network with ReLU hidden activations and linear output
/// (softmax applied in the loss), matching Deep Positron's dataflow.
#[derive(Debug, Clone)]
pub struct Mlp {
    /// Dense layers, input-first.
    pub layers: Vec<Layer>,
}

impl Mlp {
    /// He-initialized network: dims = [in, h1, ..., out].
    pub fn new(dims: &[usize], rng: &mut Rng) -> Mlp {
        assert!(dims.len() >= 2);
        let layers = dims
            .windows(2)
            .map(|d| {
                let (fan_in, fan_out) = (d[0], d[1]);
                let std = (2.0 / fan_in as f64).sqrt();
                Layer {
                    in_dim: fan_in,
                    out_dim: fan_out,
                    w: (0..fan_in * fan_out).map(|_| rng.normal(0.0, std)).collect(),
                    b: vec![0.0; fan_out],
                }
            })
            .collect();
        Mlp { layers }
    }

    /// Layer widths, `[in, h1, ..., out]`.
    pub fn dims(&self) -> Vec<usize> {
        let mut d: Vec<usize> = vec![self.layers[0].in_dim];
        d.extend(self.layers.iter().map(|l| l.out_dim));
        d
    }

    /// Largest layer fan-in — the Eq. (2) dot-product length `k` a deployed
    /// accelerator must size its accumulator for. The hardware sweeps and
    /// the per-layer tuner costing ([`crate::tune`]) derive `k` from this
    /// instead of the blanket MNIST-sized [`crate::hw::DEFAULT_K`].
    pub fn max_fan_in(&self) -> usize {
        self.layers.iter().map(|l| l.in_dim).max().expect("mlp has layers")
    }

    /// Forward pass of one sample; returns the pre-softmax logits.
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        let mut act = x.to_vec();
        for (li, layer) in self.layers.iter().enumerate() {
            let mut next = vec![0.0; layer.out_dim];
            for o in 0..layer.out_dim {
                let row = &layer.w[o * layer.in_dim..(o + 1) * layer.in_dim];
                let mut acc = layer.b[o];
                for (wi, ai) in row.iter().zip(&act) {
                    acc += wi * ai;
                }
                next[o] = if li + 1 < self.layers.len() { acc.max(0.0) } else { acc };
            }
            act = next;
        }
        act
    }

    /// Classification accuracy on the test split.
    pub fn accuracy(&self, ds: &Dataset) -> f64 {
        let mut correct = 0usize;
        for i in 0..ds.test_len() {
            let logits = self.forward(ds.test_row(i));
            if argmax(&logits) == ds.y_test[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / ds.test_len() as f64
    }

    /// All parameter tensors, named, for the quantization-error analysis
    /// (Fig. 5's rows; "dense" = fully-connected layer, per the paper).
    pub fn named_tensors(&self) -> Vec<crate::quant::NamedTensor> {
        let mut out = Vec::new();
        for (i, l) in self.layers.iter().enumerate() {
            let mut data = l.w.clone();
            data.extend_from_slice(&l.b);
            out.push(crate::quant::NamedTensor { name: format!("dense{}", i + 1), data });
        }
        // The paper's "avg" column: all parameters pooled.
        let mut all = Vec::new();
        for l in &self.layers {
            all.extend_from_slice(&l.w);
            all.extend_from_slice(&l.b);
        }
        out.push(crate::quant::NamedTensor { name: "avg".into(), data: all });
        out
    }
}

/// Fold a z-score input normalization into the first layer so the deployed
/// network consumes RAW features:
/// `Σ w·(x−μ)/σ + b  =  Σ (w/σ)·x + (b − Σ (w/σ)·μ)`.
/// This is the standard deployment transform — and the source of the
/// paper's WDBC dynamic-range stress: raw-scale inputs force tiny
/// first-layer weights that narrow formats cannot represent.
pub fn fold_input_normalization(mlp: &mut Mlp, means: &[f64], stds: &[f64]) {
    let l0 = &mut mlp.layers[0];
    assert_eq!(means.len(), l0.in_dim);
    for o in 0..l0.out_dim {
        let row = &mut l0.w[o * l0.in_dim..(o + 1) * l0.in_dim];
        let mut shift = 0.0;
        for i in 0..row.len() {
            row[i] /= stds[i];
            shift += row[i] * means[i];
        }
        l0.b[o] -= shift;
    }
}

/// Index of the largest element (first wins on ties).
pub fn argmax(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Training hyperparameters.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Passes over the training split.
    pub epochs: usize,
    /// Minibatch size.
    pub batch: usize,
    /// Learning rate.
    pub lr: f64,
    /// SGD momentum coefficient.
    pub momentum: f64,
    /// L2 weight decay.
    pub decay: f64,
    /// Shuffling seed.
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { epochs: 60, batch: 32, lr: 0.05, momentum: 0.9, decay: 1e-4, seed: 7 }
    }
}

/// Train with SGD + momentum on softmax cross-entropy. Returns the
/// per-epoch mean training loss (the "loss curve").
pub fn train(mlp: &mut Mlp, ds: &Dataset, cfg: &TrainConfig) -> Vec<f64> {
    let mut rng = Rng::new(cfg.seed);
    let mut vel: Vec<Layer> = mlp
        .layers
        .iter()
        .map(|l| Layer { in_dim: l.in_dim, out_dim: l.out_dim, w: vec![0.0; l.w.len()], b: vec![0.0; l.b.len()] })
        .collect();
    let n = ds.train_len();
    let mut order: Vec<usize> = (0..n).collect();
    let mut curve = Vec::with_capacity(cfg.epochs);
    for _epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0;
        for chunk in order.chunks(cfg.batch) {
            epoch_loss += train_batch(mlp, ds, chunk, cfg, &mut vel) * chunk.len() as f64;
        }
        curve.push(epoch_loss / n as f64);
    }
    curve
}

fn train_batch(mlp: &mut Mlp, ds: &Dataset, idx: &[usize], cfg: &TrainConfig, vel: &mut [Layer]) -> f64 {
    let nl = mlp.layers.len();
    // Accumulated gradients.
    let mut gw: Vec<Vec<f64>> = mlp.layers.iter().map(|l| vec![0.0; l.w.len()]).collect();
    let mut gb: Vec<Vec<f64>> = mlp.layers.iter().map(|l| vec![0.0; l.b.len()]).collect();
    let mut loss = 0.0;
    for &s in idx {
        // Forward, keeping activations.
        let mut acts: Vec<Vec<f64>> = vec![ds.train_row(s).to_vec()];
        for (li, layer) in mlp.layers.iter().enumerate() {
            let prev = &acts[li];
            let mut next = vec![0.0; layer.out_dim];
            for o in 0..layer.out_dim {
                let row = &layer.w[o * layer.in_dim..(o + 1) * layer.in_dim];
                let mut acc = layer.b[o];
                for (wi, ai) in row.iter().zip(prev) {
                    acc += wi * ai;
                }
                next[o] = if li + 1 < nl { acc.max(0.0) } else { acc };
            }
            acts.push(next);
        }
        // Softmax CE backward.
        let logits = acts.last().unwrap();
        let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = logits.iter().map(|&z| (z - m).exp()).collect();
        let zsum: f64 = exps.iter().sum();
        let label = ds.y_train[s] as usize;
        loss += zsum.ln() + m - logits[label];
        let mut delta: Vec<f64> = exps.iter().map(|&e| e / zsum).collect();
        delta[label] -= 1.0;
        for li in (0..nl).rev() {
            let layer = &mlp.layers[li];
            let prev = &acts[li];
            for o in 0..layer.out_dim {
                let d = delta[o];
                gb[li][o] += d;
                let grow = &mut gw[li][o * layer.in_dim..(o + 1) * layer.in_dim];
                for (g, &a) in grow.iter_mut().zip(prev) {
                    *g += d * a;
                }
            }
            if li > 0 {
                let mut next_delta = vec![0.0; layer.in_dim];
                for o in 0..layer.out_dim {
                    let d = delta[o];
                    let row = &layer.w[o * layer.in_dim..(o + 1) * layer.in_dim];
                    for (nd, &w) in next_delta.iter_mut().zip(row) {
                        *nd += d * w;
                    }
                }
                // ReLU mask on the pre-layer activation.
                for (nd, &a) in next_delta.iter_mut().zip(&acts[li]) {
                    if a <= 0.0 {
                        *nd = 0.0;
                    }
                }
                delta = next_delta;
            }
        }
    }
    // SGD + momentum step.
    let scale = 1.0 / idx.len() as f64;
    for li in 0..nl {
        let layer = &mut mlp.layers[li];
        for (i, g) in gw[li].iter().enumerate() {
            let v = &mut vel[li].w[i];
            *v = cfg.momentum * *v - cfg.lr * (g * scale + cfg.decay * layer.w[i]);
            layer.w[i] += *v;
        }
        for (i, g) in gb[li].iter().enumerate() {
            let v = &mut vel[li].b[i];
            *v = cfg.momentum * *v - cfg.lr * g * scale;
            layer.b[i] += *v;
        }
    }
    loss / idx.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, Scale};

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(1);
        let mlp = Mlp::new(&[4, 10, 3], &mut rng);
        assert_eq!(mlp.forward(&[0.1, -0.2, 0.3, 0.0]).len(), 3);
        assert_eq!(mlp.dims(), vec![4, 10, 3]);
        assert_eq!(mlp.max_fan_in(), 10);
    }

    #[test]
    fn training_reduces_loss_and_fits_iris() {
        let (ds, _, _) = datasets::load("iris", 5, Scale::Small).normalized();
        let mut rng = Rng::new(2);
        let mut mlp = Mlp::new(&[4, 10, 8, 3], &mut rng);
        let curve = train(&mut mlp, &ds, &TrainConfig { epochs: 80, ..Default::default() });
        assert!(curve.last().unwrap() < &(curve[0] * 0.5), "loss barely moved: {curve:?}");
        let acc = mlp.accuracy(&ds);
        assert!(acc >= 0.9, "iris accuracy only {acc}");
    }

    #[test]
    fn training_fits_wdbc() {
        let (ds, _, _) = datasets::load("wdbc", 5, Scale::Small).normalized();
        let mut rng = Rng::new(3);
        let mut mlp = Mlp::new(&[30, 16, 8, 2], &mut rng);
        train(&mut mlp, &ds, &TrainConfig { epochs: 40, ..Default::default() });
        let acc = mlp.accuracy(&ds);
        assert!(acc >= 0.85, "wdbc accuracy only {acc}");
    }

    #[test]
    fn folding_normalization_preserves_outputs() {
        let raw = datasets::load("wdbc", 5, Scale::Small);
        let (norm, means, stds) = raw.normalized();
        let mut rng = Rng::new(3);
        let mut mlp = Mlp::new(&[30, 16, 8, 2], &mut rng);
        train(&mut mlp, &norm, &TrainConfig { epochs: 10, ..Default::default() });
        let before: Vec<f64> = norm.test_row(0).to_vec();
        let out_norm = mlp.forward(&before);
        fold_input_normalization(&mut mlp, &means, &stds);
        let out_raw = mlp.forward(raw.test_row(0));
        for (a, b) in out_norm.iter().zip(&out_raw) {
            assert!((a - b).abs() < 1e-9, "folding changed outputs: {a} vs {b}");
        }
        // And accuracy on RAW inputs matches accuracy on the normalized view.
        assert_eq!(mlp.accuracy(&raw), {
            let mut m2 = Mlp::new(&[30, 16, 8, 2], &mut Rng::new(3));
            train(&mut m2, &norm, &TrainConfig { epochs: 10, ..Default::default() });
            m2.accuracy(&norm)
        });
    }

    #[test]
    fn named_tensors_include_avg() {
        let mut rng = Rng::new(4);
        let mlp = Mlp::new(&[4, 5, 3], &mut rng);
        let t = mlp.named_tensors();
        assert_eq!(t.len(), 3); // dense1, dense2, avg
        assert_eq!(t.last().unwrap().name, "avg");
        assert_eq!(t[2].data.len(), t[0].data.len() + t[1].data.len());
    }

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[-5.0, -1.0, -3.0]), 1);
    }
}
