//! Plain feedforward network substrate over the typed layer IR
//! ([`crate::accel::ir`]): 64-bit float reference forward pass and an
//! SGD-with-momentum trainer (softmax cross-entropy) for dense, conv2d,
//! average-pool, and flatten layers.
//!
//! This is the "trained with 32-bit floating point" baseline of the paper's
//! Table 1 (we train in f64 — bit-identical conclusions at these scales, and
//! the quantization experiments only consume the resulting weights). The
//! same training math is AOT-compiled to HLO by `python/compile/model.py`
//! for the dense topologies; the Rust trainer is the dependency-free
//! substrate used by tests and the tabular tasks, cross-validates the
//! artifact path, and is the only trainer for the conv topologies
//! (DESIGN.md §11).

use crate::accel::ir::{he_init, LayerGeom, LayerKind, NetIr, Shape};
use crate::datasets::Dataset;
use crate::util::Rng;

/// One network layer: its IR node plus (for weighted kinds) parameters.
///
/// Layout: dense weights are row-major `w[out][in]`; conv weights are
/// `w[out_ch][in_ch][kh][kw]` flattened row-major with one bias per output
/// channel; pool/flatten carry no parameters (`w`/`b` empty).
#[derive(Debug, Clone)]
pub struct Layer {
    /// Flat input width (`in_shape.len()`).
    pub in_dim: usize,
    /// Flat output width (`out_shape.len()`).
    pub out_dim: usize,
    /// Weights (see layout note above; empty for weightless kinds).
    pub w: Vec<f64>,
    /// Biases, one per output neuron (dense) or output channel (conv);
    /// empty for weightless kinds.
    pub b: Vec<f64>,
    /// What this layer computes.
    pub kind: LayerKind,
    /// Shape of the incoming activation block.
    pub in_shape: Shape,
    /// Shape of the produced activation block.
    pub out_shape: Shape,
}

impl Layer {
    /// He-initialized dense layer `in_dim → out_dim`.
    pub fn dense(in_dim: usize, out_dim: usize, rng: &mut Rng) -> Layer {
        Layer::dense_with(in_dim, out_dim, he_init(in_dim, in_dim * out_dim, rng), vec![0.0; out_dim])
    }

    /// Dense layer from explicit parameters (the PJRT state importer uses
    /// this). `w` must be row-major `[out][in]`.
    pub fn dense_with(in_dim: usize, out_dim: usize, w: Vec<f64>, b: Vec<f64>) -> Layer {
        assert_eq!(w.len(), in_dim * out_dim);
        assert_eq!(b.len(), out_dim);
        Layer {
            in_dim,
            out_dim,
            w,
            b,
            kind: LayerKind::Dense,
            in_shape: Shape::Flat(in_dim),
            out_shape: Shape::Flat(out_dim),
        }
    }

    /// He-initialized valid 2-D convolution over a `C×H×W` input block.
    pub fn conv2d(in_shape: Shape, out_ch: usize, kh: usize, kw: usize, stride: usize, rng: &mut Rng) -> Layer {
        let in_ch = match in_shape {
            Shape::Chw { c, .. } => c,
            Shape::Flat(_) => panic!("conv2d needs a CxHxW input shape"),
        };
        let kind = LayerKind::Conv2d { kh, kw, stride, in_ch, out_ch };
        let geom = LayerGeom::infer(kind, in_shape, 0).expect("conv2d shape inference failed");
        Layer {
            in_dim: in_shape.len(),
            out_dim: geom.out_shape.len(),
            w: he_init(kh * kw * in_ch, geom.num_weights(), rng),
            b: vec![0.0; out_ch],
            kind,
            in_shape,
            out_shape: geom.out_shape,
        }
    }

    /// Per-channel average pooling over `k×k` windows (k a power of two —
    /// the exact-datapath constraint, see [`LayerKind::AvgPool`]).
    pub fn avg_pool(in_shape: Shape, k: usize, stride: usize) -> Layer {
        let kind = LayerKind::AvgPool { k, stride };
        let geom = LayerGeom::infer(kind, in_shape, 0).expect("avg_pool shape inference failed");
        Layer {
            in_dim: in_shape.len(),
            out_dim: geom.out_shape.len(),
            w: Vec::new(),
            b: Vec::new(),
            kind,
            in_shape,
            out_shape: geom.out_shape,
        }
    }

    /// Shape cast `C×H×W → Flat` (identity on the underlying vector).
    pub fn flatten(in_shape: Shape) -> Layer {
        let n = in_shape.len();
        Layer {
            in_dim: n,
            out_dim: n,
            w: Vec::new(),
            b: Vec::new(),
            kind: LayerKind::Flatten,
            in_shape,
            out_shape: Shape::Flat(n),
        }
    }

    /// The layer's IR node.
    pub fn geom(&self) -> LayerGeom {
        LayerGeom { kind: self.kind, in_shape: self.in_shape, out_shape: self.out_shape }
    }

    /// Receptive-field fan-in — the dot-product length per output element
    /// (see [`LayerGeom::fan_in`]).
    pub fn fan_in(&self) -> usize {
        self.geom().fan_in()
    }

    /// The Eq. (2) accumulation length `k` (fan-in + bias term for weighted
    /// kinds) the layer's quire must absorb.
    pub fn eq2_k(&self) -> usize {
        self.geom().eq2_k()
    }

    /// Forward one activation vector through this layer (f64 reference
    /// semantics; `relu` clamps negative outputs for hidden weighted
    /// layers).
    pub fn forward_f64(&self, input: &[f64], relu: bool) -> Vec<f64> {
        debug_assert_eq!(input.len(), self.in_dim);
        match self.kind {
            LayerKind::Dense => {
                let mut next = vec![0.0; self.out_dim];
                for o in 0..self.out_dim {
                    let row = &self.w[o * self.in_dim..(o + 1) * self.in_dim];
                    let mut acc = self.b[o];
                    for (wi, ai) in row.iter().zip(input) {
                        acc += wi * ai;
                    }
                    next[o] = if relu { acc.max(0.0) } else { acc };
                }
                next
            }
            LayerKind::Conv2d { kh, kw, stride, in_ch, out_ch } => {
                let (ih, iw) = self.in_shape.hw();
                let (oh, ow) = self.out_shape.hw();
                let mut next = vec![0.0; self.out_dim];
                for oc in 0..out_ch {
                    let wrow = &self.w[oc * in_ch * kh * kw..(oc + 1) * in_ch * kh * kw];
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let mut acc = self.b[oc];
                            for ic in 0..in_ch {
                                for ky in 0..kh {
                                    for kx in 0..kw {
                                        let i = ic * ih * iw + (oy * stride + ky) * iw + (ox * stride + kx);
                                        acc += wrow[ic * kh * kw + ky * kw + kx] * input[i];
                                    }
                                }
                            }
                            next[oc * oh * ow + oy * ow + ox] = if relu { acc.max(0.0) } else { acc };
                        }
                    }
                }
                next
            }
            LayerKind::AvgPool { k, stride } => {
                let (ih, iw) = self.in_shape.hw();
                let (oh, ow) = self.out_shape.hw();
                let c = self.in_shape.channels();
                let area = (k * k) as f64;
                let mut next = vec![0.0; self.out_dim];
                for ch in 0..c {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let mut acc = 0.0;
                            for ky in 0..k {
                                for kx in 0..k {
                                    acc += input[ch * ih * iw + (oy * stride + ky) * iw + (ox * stride + kx)];
                                }
                            }
                            next[ch * oh * ow + oy * ow + ox] = acc / area;
                        }
                    }
                }
                next
            }
            LayerKind::Flatten => input.to_vec(),
        }
    }
}

/// A feedforward network over the typed layer IR, with ReLU hidden
/// activations on weighted layers and a linear output (softmax applied in
/// the loss), matching Deep Positron's dataflow. Dense-only networks are
/// exactly the pre-IR `Mlp`.
#[derive(Debug, Clone)]
pub struct Mlp {
    /// Layers, input-first.
    pub layers: Vec<Layer>,
}

impl Mlp {
    /// He-initialized dense network: dims = [in, h1, ..., out].
    pub fn new(dims: &[usize], rng: &mut Rng) -> Mlp {
        assert!(dims.len() >= 2);
        let layers = dims.windows(2).map(|d| Layer::dense(d[0], d[1], rng)).collect();
        Mlp { layers }
    }

    /// A network from an explicit layer chain (the conv-capable
    /// constructor). Panics on a broken shape chain.
    pub fn from_layers(layers: Vec<Layer>) -> Mlp {
        let mlp = Mlp { layers };
        if let Err(e) = mlp.check_shapes() {
            panic!("invalid layer chain: {e}");
        }
        mlp
    }

    /// Validate the layer chain's shape inference (see [`NetIr::check`]).
    pub fn check_shapes(&self) -> Result<(), String> {
        if self.layers.is_empty() {
            return Err("network has no layers".into());
        }
        for (li, l) in self.layers.iter().enumerate() {
            let g = l.geom();
            if l.in_dim != l.in_shape.len() || l.out_dim != l.out_shape.len() {
                return Err(format!("layer {li}: dims disagree with shapes"));
            }
            if l.w.len() != g.num_weights() || l.b.len() != g.num_biases() {
                return Err(format!("layer {li} ({}): parameter count disagrees with geometry", g.node_name()));
            }
        }
        NetIr::try_new(self.layers.iter().map(Layer::geom).collect())?;
        Ok(())
    }

    /// The network's typed IR (geometry only — what costing, serving
    /// validation, and plan serialization consume).
    pub fn ir(&self) -> NetIr {
        NetIr::new(self.layers.iter().map(Layer::geom).collect())
    }

    /// A zero-parameter network with the given IR's geometry — the
    /// serve-from-artifact shell (DESIGN.md §16). Workers compiling from a
    /// `.dpz` artifact never read `w`/`b` (the codes come from the artifact),
    /// but the shard plumbing still carries a shape-checked network for
    /// validation and routing, and this builds one without a dataset or a
    /// trainer in sight.
    pub fn skeleton(ir: &NetIr) -> Mlp {
        let layers = ir
            .geoms()
            .iter()
            .map(|g| Layer {
                in_dim: g.in_shape.len(),
                out_dim: g.out_shape.len(),
                w: vec![0.0; g.num_weights()],
                b: vec![0.0; g.num_biases()],
                kind: g.kind,
                in_shape: g.in_shape,
                out_shape: g.out_shape,
            })
            .collect();
        Mlp::from_layers(layers)
    }

    /// Whether every layer is dense (the XLA fast path covers exactly this).
    pub fn is_dense(&self) -> bool {
        self.layers.iter().all(|l| l.kind == LayerKind::Dense)
    }

    /// Whether layer `li` applies ReLU at its output: weighted hidden
    /// layers do; the output layer and weightless wiring (pool/flatten)
    /// never do. Dense-only networks reduce to the classic
    /// `li < last` rule.
    pub fn relu_at(&self, li: usize) -> bool {
        self.layers[li].kind.has_weights() && li + 1 < self.layers.len()
    }

    /// Flat layer widths, `[in, l1, ..., out]`.
    pub fn dims(&self) -> Vec<usize> {
        let mut d: Vec<usize> = vec![self.layers[0].in_dim];
        d.extend(self.layers.iter().map(|l| l.out_dim));
        d
    }

    /// Largest Eq. (2) dot-product length any layer presents — the
    /// receptive-field fan-in a deployed accelerator must size its
    /// accumulator for (a conv layer contributes `kh·kw·in_ch`, NOT its
    /// flat input width). The hardware sweeps and the per-layer tuner
    /// costing ([`crate::tune`]) derive `k` from this instead of the
    /// blanket MNIST-sized [`crate::hw::DEFAULT_K`]. Dense layers
    /// contribute their input width, so dense-only networks are unchanged.
    pub fn max_fan_in(&self) -> usize {
        self.layers.iter().map(Layer::fan_in).max().expect("mlp has layers")
    }

    /// Forward pass of one sample; returns the pre-softmax logits.
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        let mut act = x.to_vec();
        for (li, layer) in self.layers.iter().enumerate() {
            act = layer.forward_f64(&act, self.relu_at(li));
        }
        act
    }

    /// Classification accuracy on the test split.
    pub fn accuracy(&self, ds: &Dataset) -> f64 {
        let mut correct = 0usize;
        for i in 0..ds.test_len() {
            let logits = self.forward(ds.test_row(i));
            if argmax(&logits) == ds.y_test[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / ds.test_len() as f64
    }

    /// All parameter tensors, named, for the quantization-error analysis
    /// (Fig. 5's rows; "dense" = fully-connected layer, per the paper —
    /// conv layers report as `conv{i}`; weightless layers carry no
    /// tensors).
    pub fn named_tensors(&self) -> Vec<crate::quant::NamedTensor> {
        let mut out = Vec::new();
        for (i, l) in self.layers.iter().enumerate() {
            if !l.kind.has_weights() {
                continue;
            }
            let mut data = l.w.clone();
            data.extend_from_slice(&l.b);
            out.push(crate::quant::NamedTensor { name: format!("{}{}", l.geom().kind_label(), i + 1), data });
        }
        // The paper's "avg" column: all parameters pooled.
        let mut all = Vec::new();
        for l in &self.layers {
            all.extend_from_slice(&l.w);
            all.extend_from_slice(&l.b);
        }
        out.push(crate::quant::NamedTensor { name: "avg".into(), data: all });
        out
    }
}

/// Fold a z-score input normalization into the first layer so the deployed
/// network consumes RAW features:
/// `Σ w·(x−μ)/σ + b  =  Σ (w/σ)·x + (b − Σ (w/σ)·μ)`.
/// This is the standard deployment transform — and the source of the
/// paper's WDBC dynamic-range stress: raw-scale inputs force tiny
/// first-layer weights that narrow formats cannot represent. Dense input
/// layers only (the image tasks train on raw pixels).
pub fn fold_input_normalization(mlp: &mut Mlp, means: &[f64], stds: &[f64]) {
    let l0 = &mut mlp.layers[0];
    assert_eq!(l0.kind, LayerKind::Dense, "normalization folding needs a dense input layer");
    assert_eq!(means.len(), l0.in_dim);
    for o in 0..l0.out_dim {
        let row = &mut l0.w[o * l0.in_dim..(o + 1) * l0.in_dim];
        let mut shift = 0.0;
        for i in 0..row.len() {
            row[i] /= stds[i];
            shift += row[i] * means[i];
        }
        l0.b[o] -= shift;
    }
}

/// Index of the largest element (first wins on ties).
pub fn argmax(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Training hyperparameters.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Passes over the training split.
    pub epochs: usize,
    /// Minibatch size.
    pub batch: usize,
    /// Learning rate.
    pub lr: f64,
    /// SGD momentum coefficient.
    pub momentum: f64,
    /// L2 weight decay.
    pub decay: f64,
    /// Shuffling seed.
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { epochs: 60, batch: 32, lr: 0.05, momentum: 0.9, decay: 1e-4, seed: 7 }
    }
}

/// Train with SGD + momentum on softmax cross-entropy. Returns the
/// per-epoch mean training loss (the "loss curve"). Works for any layer
/// chain the IR admits; dense-only training is numerically identical to
/// the pre-IR trainer.
pub fn train(mlp: &mut Mlp, ds: &Dataset, cfg: &TrainConfig) -> Vec<f64> {
    let mut rng = Rng::new(cfg.seed);
    let mut vel: Vec<Layer> =
        mlp.layers.iter().map(|l| Layer { w: vec![0.0; l.w.len()], b: vec![0.0; l.b.len()], ..l.clone() }).collect();
    let n = ds.train_len();
    let mut order: Vec<usize> = (0..n).collect();
    let mut curve = Vec::with_capacity(cfg.epochs);
    for _epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0;
        for chunk in order.chunks(cfg.batch) {
            epoch_loss += train_batch(mlp, ds, chunk, cfg, &mut vel) * chunk.len() as f64;
        }
        curve.push(epoch_loss / n as f64);
    }
    curve
}

/// Accumulate one sample's parameter gradients for `layer` and (when
/// `want_input_delta`) return the loss gradient w.r.t. the layer's input.
/// `delta` is the gradient w.r.t. this layer's (pre-ReLU) output.
fn backward_layer(
    layer: &Layer,
    prev: &[f64],
    delta: &[f64],
    gw: &mut [f64],
    gb: &mut [f64],
    want_input_delta: bool,
) -> Option<Vec<f64>> {
    match layer.kind {
        LayerKind::Dense => {
            for o in 0..layer.out_dim {
                let d = delta[o];
                gb[o] += d;
                let grow = &mut gw[o * layer.in_dim..(o + 1) * layer.in_dim];
                for (g, &a) in grow.iter_mut().zip(prev) {
                    *g += d * a;
                }
            }
            if !want_input_delta {
                return None;
            }
            let mut next_delta = vec![0.0; layer.in_dim];
            for o in 0..layer.out_dim {
                let d = delta[o];
                let row = &layer.w[o * layer.in_dim..(o + 1) * layer.in_dim];
                for (nd, &w) in next_delta.iter_mut().zip(row) {
                    *nd += d * w;
                }
            }
            Some(next_delta)
        }
        LayerKind::Conv2d { kh, kw, stride, in_ch, out_ch } => {
            let (ih, iw) = layer.in_shape.hw();
            let (oh, ow) = layer.out_shape.hw();
            let mut next_delta = if want_input_delta { Some(vec![0.0; layer.in_dim]) } else { None };
            for oc in 0..out_ch {
                let wbase = oc * in_ch * kh * kw;
                for oy in 0..oh {
                    for ox in 0..ow {
                        let d = delta[oc * oh * ow + oy * ow + ox];
                        gb[oc] += d;
                        for ic in 0..in_ch {
                            for ky in 0..kh {
                                for kx in 0..kw {
                                    let i = ic * ih * iw + (oy * stride + ky) * iw + (ox * stride + kx);
                                    gw[wbase + ic * kh * kw + ky * kw + kx] += d * prev[i];
                                    if let Some(nd) = next_delta.as_mut() {
                                        nd[i] += d * layer.w[wbase + ic * kh * kw + ky * kw + kx];
                                    }
                                }
                            }
                        }
                    }
                }
            }
            next_delta
        }
        LayerKind::AvgPool { k, stride } => {
            if !want_input_delta {
                return None;
            }
            let (ih, iw) = layer.in_shape.hw();
            let (oh, ow) = layer.out_shape.hw();
            let c = layer.in_shape.channels();
            let scale = 1.0 / (k * k) as f64;
            let mut next_delta = vec![0.0; layer.in_dim];
            for ch in 0..c {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let d = delta[ch * oh * ow + oy * ow + ox] * scale;
                        for ky in 0..k {
                            for kx in 0..k {
                                next_delta[ch * ih * iw + (oy * stride + ky) * iw + (ox * stride + kx)] += d;
                            }
                        }
                    }
                }
            }
            Some(next_delta)
        }
        LayerKind::Flatten => want_input_delta.then(|| delta.to_vec()),
    }
}

fn train_batch(mlp: &mut Mlp, ds: &Dataset, idx: &[usize], cfg: &TrainConfig, vel: &mut [Layer]) -> f64 {
    let nl = mlp.layers.len();
    // Accumulated gradients.
    let mut gw: Vec<Vec<f64>> = mlp.layers.iter().map(|l| vec![0.0; l.w.len()]).collect();
    let mut gb: Vec<Vec<f64>> = mlp.layers.iter().map(|l| vec![0.0; l.b.len()]).collect();
    let mut loss = 0.0;
    for &s in idx {
        // Forward, keeping activations.
        let mut acts: Vec<Vec<f64>> = vec![ds.train_row(s).to_vec()];
        for (li, layer) in mlp.layers.iter().enumerate() {
            let next = layer.forward_f64(&acts[li], mlp.relu_at(li));
            acts.push(next);
        }
        // Softmax CE backward.
        let logits = acts.last().unwrap();
        let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = logits.iter().map(|&z| (z - m).exp()).collect();
        let zsum: f64 = exps.iter().sum();
        let label = ds.y_train[s] as usize;
        loss += zsum.ln() + m - logits[label];
        let mut delta: Vec<f64> = exps.iter().map(|&e| e / zsum).collect();
        delta[label] -= 1.0;
        for li in (0..nl).rev() {
            let layer = &mlp.layers[li];
            let next_delta = backward_layer(layer, &acts[li], &delta, &mut gw[li], &mut gb[li], li > 0);
            if li > 0 {
                let mut next_delta = next_delta.expect("input delta requested");
                // ReLU mask on the pre-layer activation (only when the
                // producing layer applied ReLU — always, in dense nets).
                if mlp.relu_at(li - 1) {
                    for (nd, &a) in next_delta.iter_mut().zip(&acts[li]) {
                        if a <= 0.0 {
                            *nd = 0.0;
                        }
                    }
                }
                delta = next_delta;
            }
        }
    }
    // SGD + momentum step.
    let scale = 1.0 / idx.len() as f64;
    for li in 0..nl {
        let layer = &mut mlp.layers[li];
        for (i, g) in gw[li].iter().enumerate() {
            let v = &mut vel[li].w[i];
            *v = cfg.momentum * *v - cfg.lr * (g * scale + cfg.decay * layer.w[i]);
            layer.w[i] += *v;
        }
        for (i, g) in gb[li].iter().enumerate() {
            let v = &mut vel[li].b[i];
            *v = cfg.momentum * *v - cfg.lr * g * scale;
            layer.b[i] += *v;
        }
    }
    loss / idx.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{self, Scale};

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(1);
        let mlp = Mlp::new(&[4, 10, 3], &mut rng);
        assert_eq!(mlp.forward(&[0.1, -0.2, 0.3, 0.0]).len(), 3);
        assert_eq!(mlp.dims(), vec![4, 10, 3]);
        assert_eq!(mlp.max_fan_in(), 10);
        assert!(mlp.is_dense());
        assert_eq!(mlp.ir(), NetIr::dense(&[4, 10, 3]));
    }

    #[test]
    fn training_reduces_loss_and_fits_iris() {
        let (ds, _, _) = datasets::load("iris", 5, Scale::Small).normalized();
        let mut rng = Rng::new(2);
        let mut mlp = Mlp::new(&[4, 10, 8, 3], &mut rng);
        let curve = train(&mut mlp, &ds, &TrainConfig { epochs: 80, ..Default::default() });
        assert!(curve.last().unwrap() < &(curve[0] * 0.5), "loss barely moved: {curve:?}");
        let acc = mlp.accuracy(&ds);
        assert!(acc >= 0.9, "iris accuracy only {acc}");
    }

    #[test]
    fn training_fits_wdbc() {
        let (ds, _, _) = datasets::load("wdbc", 5, Scale::Small).normalized();
        let mut rng = Rng::new(3);
        let mut mlp = Mlp::new(&[30, 16, 8, 2], &mut rng);
        train(&mut mlp, &ds, &TrainConfig { epochs: 40, ..Default::default() });
        let acc = mlp.accuracy(&ds);
        assert!(acc >= 0.85, "wdbc accuracy only {acc}");
    }

    #[test]
    fn folding_normalization_preserves_outputs() {
        let raw = datasets::load("wdbc", 5, Scale::Small);
        let (norm, means, stds) = raw.normalized();
        let mut rng = Rng::new(3);
        let mut mlp = Mlp::new(&[30, 16, 8, 2], &mut rng);
        train(&mut mlp, &norm, &TrainConfig { epochs: 10, ..Default::default() });
        let before: Vec<f64> = norm.test_row(0).to_vec();
        let out_norm = mlp.forward(&before);
        fold_input_normalization(&mut mlp, &means, &stds);
        let out_raw = mlp.forward(raw.test_row(0));
        for (a, b) in out_norm.iter().zip(&out_raw) {
            assert!((a - b).abs() < 1e-9, "folding changed outputs: {a} vs {b}");
        }
        // And accuracy on RAW inputs matches accuracy on the normalized view.
        assert_eq!(mlp.accuracy(&raw), {
            let mut m2 = Mlp::new(&[30, 16, 8, 2], &mut Rng::new(3));
            train(&mut m2, &norm, &TrainConfig { epochs: 10, ..Default::default() });
            m2.accuracy(&norm)
        });
    }

    #[test]
    fn named_tensors_include_avg() {
        let mut rng = Rng::new(4);
        let mlp = Mlp::new(&[4, 5, 3], &mut rng);
        let t = mlp.named_tensors();
        assert_eq!(t.len(), 3); // dense1, dense2, avg
        assert_eq!(t.last().unwrap().name, "avg");
        assert_eq!(t[2].data.len(), t[0].data.len() + t[1].data.len());
    }

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[-5.0, -1.0, -3.0]), 1);
    }

    /// A tiny conv chain on a 1×4×4 block with hand-checkable numbers.
    fn tiny_conv() -> Mlp {
        let input = Shape::Chw { c: 1, h: 4, w: 4 };
        let mut rng = Rng::new(9);
        let mut conv = Layer::conv2d(input, 2, 3, 3, 1, &mut rng);
        // Overwrite the random init with a known kernel: channel 0 sums the
        // 3×3 window, channel 1 picks the center.
        conv.w = vec![1.0; 9].into_iter().chain((0..9).map(|i| if i == 4 { 1.0 } else { 0.0 })).collect();
        conv.b = vec![0.5, 0.0];
        let pool = Layer::avg_pool(conv.out_shape, 2, 2);
        let flat = Layer::flatten(pool.out_shape);
        let dense = Layer::dense_with(2, 2, vec![1.0, 0.0, 0.0, 1.0], vec![0.0, 0.0]);
        Mlp::from_layers(vec![conv, pool, flat, dense])
    }

    #[test]
    fn conv_forward_matches_hand_computation() {
        let mlp = tiny_conv();
        // Input: all ones. Conv ch0: 9·1 + 0.5 = 9.5 at every output pixel;
        // ch1: 1.0. Pool over the single 2×2 window: unchanged averages.
        let out = mlp.forward(&[1.0; 16]);
        assert_eq!(out, vec![9.5, 1.0]);
        assert_eq!(mlp.dims(), vec![16, 8, 2, 2, 2]);
        assert_eq!(mlp.max_fan_in(), 9);
        assert!(!mlp.is_dense());
        assert_eq!(mlp.ir().name(), "1x4x4:conv2k3x3s1+pool2s2+flatten+dense2");
    }

    #[test]
    fn avg_pool_averages_windows() {
        let input = Shape::Chw { c: 1, h: 2, w: 2 };
        let pool = Layer::avg_pool(input, 2, 2);
        assert_eq!(pool.forward_f64(&[1.0, 2.0, 3.0, 6.0], false), vec![3.0]);
    }

    #[test]
    fn conv_training_reduces_loss_on_a_toy_task() {
        // 2-class toy: class 0 = bright left half, class 1 = bright right
        // half, 1×4×4 images. A conv net must fit this quickly.
        let mut x_train = Vec::new();
        let mut y_train = Vec::new();
        let mut rng = Rng::new(11);
        for i in 0..64 {
            let class = (i % 2) as u32;
            let mut img = [0.0f64; 16];
            for y in 0..4 {
                for x in 0..4 {
                    let lit = if class == 0 { x < 2 } else { x >= 2 };
                    img[y * 4 + x] = if lit { rng.range(0.7, 1.0) } else { rng.range(0.0, 0.2) };
                }
            }
            x_train.extend_from_slice(&img);
            y_train.push(class);
        }
        let ds = Dataset {
            name: "toy".into(),
            num_features: 16,
            num_classes: 2,
            x_train: x_train.clone(),
            y_train: y_train.clone(),
            x_test: x_train,
            y_test: y_train,
        };
        let input = Shape::Chw { c: 1, h: 4, w: 4 };
        let mut rng = Rng::new(5);
        let conv = Layer::conv2d(input, 3, 3, 3, 1, &mut rng);
        let pool = Layer::avg_pool(conv.out_shape, 2, 1);
        let flat = Layer::flatten(pool.out_shape);
        let dense = Layer::dense(flat.out_dim, 2, &mut rng);
        let mut mlp = Mlp::from_layers(vec![conv, pool, flat, dense]);
        let curve = train(&mut mlp, &ds, &TrainConfig { epochs: 40, batch: 8, ..Default::default() });
        assert!(
            curve.last().unwrap() < &(curve[0] * 0.5),
            "conv training barely moved: {} -> {}",
            curve[0],
            curve.last().unwrap()
        );
        assert!(mlp.accuracy(&ds) >= 0.9, "toy conv accuracy {}", mlp.accuracy(&ds));
    }

    #[test]
    fn conv_gradients_match_finite_differences() {
        // Spot-check the conv/pool backward pass against numeric gradients
        // on a tiny random net and a one-sample "dataset".
        let input = Shape::Chw { c: 1, h: 4, w: 4 };
        let mut rng = Rng::new(21);
        let conv = Layer::conv2d(input, 2, 2, 2, 1, &mut rng);
        let pool = Layer::avg_pool(conv.out_shape, 2, 1);
        let flat = Layer::flatten(pool.out_shape);
        let dense = Layer::dense(flat.out_dim, 2, &mut rng);
        let mlp0 = Mlp::from_layers(vec![conv, pool, flat, dense]);
        let x: Vec<f64> = (0..16).map(|i| (i as f64) / 16.0 - 0.4).collect();
        let label = 1usize;
        let loss_of = |m: &Mlp| -> f64 {
            let logits = m.forward(&x);
            let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let zsum: f64 = logits.iter().map(|&z| (z - mx).exp()).sum();
            zsum.ln() + mx - logits[label]
        };
        // Analytic gradient via one zero-momentum, zero-decay SGD step of
        // lr = 1 on a single-sample batch: w' = w - g.
        let ds = Dataset {
            name: "one".into(),
            num_features: 16,
            num_classes: 2,
            x_train: x.clone(),
            y_train: vec![label as u32],
            x_test: x.clone(),
            y_test: vec![label as u32],
        };
        let mut stepped = mlp0.clone();
        train(
            &mut stepped,
            &ds,
            &TrainConfig { epochs: 1, batch: 1, lr: 1.0, momentum: 0.0, decay: 0.0, seed: 1 },
        );
        let eps = 1e-5;
        for li in [0usize, 3] {
            for wi in [0usize, 1, 3] {
                let analytic = mlp0.layers[li].w[wi] - stepped.layers[li].w[wi]; // = gradient
                let mut plus = mlp0.clone();
                plus.layers[li].w[wi] += eps;
                let mut minus = mlp0.clone();
                minus.layers[li].w[wi] -= eps;
                let numeric = (loss_of(&plus) - loss_of(&minus)) / (2.0 * eps);
                assert!(
                    (analytic - numeric).abs() < 1e-5,
                    "layer {li} w[{wi}]: analytic {analytic} vs numeric {numeric}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "invalid layer chain")]
    fn broken_shape_chain_is_rejected() {
        let mut rng = Rng::new(1);
        let a = Layer::dense(4, 5, &mut rng);
        let b = Layer::dense(6, 3, &mut rng); // 5 != 6
        let _ = Mlp::from_layers(vec![a, b]);
    }
}
