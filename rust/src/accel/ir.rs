//! The typed layer IR the whole stack compiles from (DESIGN.md §11).
//!
//! The repository started dense-only: `Mlp` was a bare `Vec` of
//! fully-connected layers, and every consumer — the EMAC compiler, the
//! hardware cost model, the tuner, serve-side validation — hard-coded the
//! dense assumptions (`fan-in == input width`, one EMAC per output). This
//! module generalizes the network representation into a small typed IR:
//!
//! * [`Shape`] — what an activation vector *is* (a flat feature vector, or
//!   a `C×H×W` image block);
//! * [`LayerKind`] — what a layer *does* (dense matmul, valid 2-D
//!   convolution, average pooling, flatten);
//! * [`LayerGeom`] — one IR node with its inferred input/output shapes,
//!   from which every derived quantity (receptive-field fan-in, the
//!   Eq. (2) accumulator length `k`, EMAC bank count, outputs per bank)
//!   is computed in ONE place;
//! * [`NetIr`] — the whole network's geometry, serializable
//!   ([`NetIr::name`] / [`NetIr::parse`]) so tuned deployment plans
//!   (`crate::tune::TunePlan`) can carry conv topologies through text.
//!
//! Deep Positron's dataflow maps onto the IR as in Cheetah (Langroudi et
//! al., 1908.02386): a dense layer is a bank of `out_dim` EMACs each firing
//! once per inference; a conv layer is a bank of `out_ch` EMACs each
//! sweeping its `oh×ow` output pixels, accumulating the `kh·kw·in_ch`
//! receptive field per pixel in the quire; average pooling reuses the
//! accumulate-only half of an EMAC (the divide by `k²` is an exact
//! exponent shift — window areas are constrained to powers of two);
//! flatten is pure wiring (a recode point under mixed per-layer formats,
//! otherwise free).

use crate::util::Rng;

/// Largest single dimension (and `CxHxW` element count) the text parsers
/// accept. Far beyond any network this repo trains, but small enough that
/// every derived quantity — `eq2_k`, `num_weights`, buffer sizes — stays
/// comfortably inside `usize` arithmetic, so untrusted plan text can never
/// drive geometry math into an overflow panic.
pub const MAX_PARSED_DIM: usize = 1 << 24;

/// Spatial interpretation of an activation vector.
///
/// The accelerator stores every activation block as a flat, feature-major
/// code vector; `Shape` is the metadata that says how spatial layers index
/// into it (`CHW` order: channel-major, then rows, then columns — so
/// [`Shape::Flat`] of the same length is exactly the flattened view).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Shape {
    /// A plain feature vector of the given width.
    Flat(usize),
    /// A channels × height × width image block, flattened channel-major.
    Chw {
        /// Channels.
        c: usize,
        /// Height, pixels.
        h: usize,
        /// Width, pixels.
        w: usize,
    },
}

impl Shape {
    /// Total element count (the flat width of the activation vector).
    pub fn len(&self) -> usize {
        match *self {
            Shape::Flat(n) => n,
            Shape::Chw { c, h, w } => c * h * w,
        }
    }

    /// Whether the shape holds no elements (never true for a valid layer).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spatial dims `(h, w)` of a `C×H×W` block. Panics on a flat shape —
    /// only spatial layers (conv/pool) ask, and shape inference has already
    /// rejected flat inputs for them.
    pub fn hw(&self) -> (usize, usize) {
        match *self {
            Shape::Chw { h, w, .. } => (h, w),
            Shape::Flat(_) => panic!("spatial access on a flat shape"),
        }
    }

    /// Channel count of a `C×H×W` block (panics on a flat shape, as
    /// [`Shape::hw`]).
    pub fn channels(&self) -> usize {
        match *self {
            Shape::Chw { c, .. } => c,
            Shape::Flat(_) => panic!("spatial access on a flat shape"),
        }
    }

    /// Machine name: `784` for flat, `1x28x28` for C×H×W (parseable by
    /// [`Shape::parse`]).
    pub fn name(&self) -> String {
        match *self {
            Shape::Flat(n) => n.to_string(),
            Shape::Chw { c, h, w } => format!("{c}x{h}x{w}"),
        }
    }

    /// Parse the [`Shape::name`] form. Every dimension must be in
    /// `1..=`[`MAX_PARSED_DIM`] and a `CxHxW` product must stay within the
    /// same cap — parsed shapes feed geometry arithmetic (`eq2_k`,
    /// `num_weights`), and an unbounded 19-digit dimension would turn a
    /// garbage plan file into an integer-overflow panic instead of `None`.
    pub fn parse(s: &str) -> Option<Shape> {
        fn dim(s: &str) -> Option<usize> {
            let n: usize = s.parse().ok()?;
            (1..=MAX_PARSED_DIM).contains(&n).then_some(n)
        }
        let parts: Vec<&str> = s.split('x').collect();
        match parts.as_slice() {
            [n] => Some(Shape::Flat(dim(n)?)),
            [c, h, w] => {
                let (c, h, w) = (dim(c)?, dim(h)?, dim(w)?);
                let len = c.checked_mul(h)?.checked_mul(w)?;
                (len <= MAX_PARSED_DIM).then_some(Shape::Chw { c, h, w })
            }
            _ => None,
        }
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// What one IR node computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// Fully-connected: `out = W·in + b` (weights row-major `[out][in]`).
    Dense,
    /// Valid (no-padding) 2-D convolution, weights `[out_ch][in_ch][kh][kw]`
    /// flattened row-major, one bias per output channel.
    Conv2d {
        /// Kernel height.
        kh: usize,
        /// Kernel width.
        kw: usize,
        /// Stride (same in both spatial dims).
        stride: usize,
        /// Input channels (must match the input shape's `c`).
        in_ch: usize,
        /// Output channels.
        out_ch: usize,
    },
    /// Per-channel average pooling over `k×k` windows. `k` must be a power
    /// of two so the divide by `k²` is an exact exponent shift in the quire
    /// (the datapaths never need a real divider).
    AvgPool {
        /// Window side length (power of two).
        k: usize,
        /// Stride.
        stride: usize,
    },
    /// Shape cast `C×H×W → Flat` — pure wiring (CHW flattening is the
    /// identity on the underlying vector), and a recode point when the next
    /// layer runs in a different numeric format.
    Flatten,
}

impl LayerKind {
    /// Whether this node carries trainable parameters (weights + biases).
    pub fn has_weights(&self) -> bool {
        matches!(self, LayerKind::Dense | LayerKind::Conv2d { .. })
    }

    /// Output shape for the given input shape. `None` when the input is
    /// incompatible — or for [`LayerKind::Dense`], whose output width is
    /// free (callers supply it; see [`LayerGeom::infer`]).
    pub fn infer(&self, input: Shape) -> Option<Shape> {
        match *self {
            LayerKind::Dense => None,
            LayerKind::Conv2d { kh, kw, stride, in_ch, out_ch } => {
                let Shape::Chw { c, h, w } = input else { return None };
                if c != in_ch || kh == 0 || kw == 0 || stride == 0 || out_ch == 0 || h < kh || w < kw {
                    return None;
                }
                Some(Shape::Chw { c: out_ch, h: (h - kh) / stride + 1, w: (w - kw) / stride + 1 })
            }
            LayerKind::AvgPool { k, stride } => {
                let Shape::Chw { c, h, w } = input else { return None };
                if k == 0 || !k.is_power_of_two() || stride == 0 || h < k || w < k {
                    return None;
                }
                Some(Shape::Chw { c, h: (h - k) / stride + 1, w: (w - k) / stride + 1 })
            }
            LayerKind::Flatten => Some(Shape::Flat(input.len())),
        }
    }
}

/// One IR node with its inferred shapes — the unit every derived geometry
/// question (fan-in, Eq. (2) `k`, EMAC banks, latency) is answered from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LayerGeom {
    /// What the node computes.
    pub kind: LayerKind,
    /// Shape of the incoming activation block.
    pub in_shape: Shape,
    /// Shape of the produced activation block.
    pub out_shape: Shape,
}

impl LayerGeom {
    /// Build a node, inferring the output shape. For [`LayerKind::Dense`]
    /// the free output width comes from `dense_out` (ignored otherwise).
    /// `None` when the kind rejects the input shape.
    pub fn infer(kind: LayerKind, in_shape: Shape, dense_out: usize) -> Option<LayerGeom> {
        let out_shape = match kind {
            LayerKind::Dense => {
                if in_shape.is_empty() || dense_out == 0 {
                    return None;
                }
                Shape::Flat(dense_out)
            }
            _ => kind.infer(in_shape)?,
        };
        Some(LayerGeom { kind, in_shape, out_shape })
    }

    /// Dot-product length each output element accumulates — the
    /// receptive-field fan-in. Dense: the input width; conv:
    /// `kh·kw·in_ch`; pool: the `k²` window; flatten: 0 (no arithmetic).
    pub fn fan_in(&self) -> usize {
        match self.kind {
            LayerKind::Dense => self.in_shape.len(),
            LayerKind::Conv2d { kh, kw, in_ch, .. } => kh * kw * in_ch,
            LayerKind::AvgPool { k, .. } => k * k,
            LayerKind::Flatten => 0,
        }
    }

    /// The Eq. (2) accumulation length `k` the layer's quire must absorb:
    /// the receptive-field fan-in plus one bias term for weighted layers.
    /// This is exactly what `DeepPositron` asserts the quire against at
    /// compile time and what the hardware costing sizes the accumulator
    /// for — a 26-term conv EMAC no longer pays for a 784-term quire.
    pub fn eq2_k(&self) -> usize {
        self.fan_in() + usize::from(self.kind.has_weights())
    }

    /// Parallel EMAC units the layer's bank instantiates: one per output
    /// neuron (dense), one per output channel (conv — each unit sweeps its
    /// own output pixels), one accumulate-only unit per channel (pool),
    /// none for flatten.
    pub fn banks(&self) -> usize {
        match self.kind {
            LayerKind::Dense => self.out_shape.len(),
            LayerKind::Conv2d { out_ch, .. } => out_ch,
            LayerKind::AvgPool { .. } => match self.out_shape {
                Shape::Chw { c, .. } => c,
                Shape::Flat(_) => 0,
            },
            LayerKind::Flatten => 0,
        }
    }

    /// Output elements each EMAC of the bank produces serially per
    /// inference (1 for dense; `oh·ow` for conv/pool; 0 for flatten).
    pub fn outputs_per_bank(&self) -> usize {
        match self.kind {
            LayerKind::Dense => 1,
            LayerKind::Conv2d { .. } | LayerKind::AvgPool { .. } => match self.out_shape {
                Shape::Chw { h, w, .. } => h * w,
                Shape::Flat(n) => n,
            },
            LayerKind::Flatten => 0,
        }
    }

    /// Trainable weight count (0 for weightless nodes).
    pub fn num_weights(&self) -> usize {
        match self.kind {
            LayerKind::Dense => self.in_shape.len() * self.out_shape.len(),
            LayerKind::Conv2d { kh, kw, in_ch, out_ch, .. } => out_ch * in_ch * kh * kw,
            _ => 0,
        }
    }

    /// Trainable bias count (0 for weightless nodes).
    pub fn num_biases(&self) -> usize {
        match self.kind {
            LayerKind::Dense => self.out_shape.len(),
            LayerKind::Conv2d { out_ch, .. } => out_ch,
            _ => 0,
        }
    }

    /// Short kind label for reports: `dense`, `conv`, `pool`, `flatten`.
    pub fn kind_label(&self) -> &'static str {
        match self.kind {
            LayerKind::Dense => "dense",
            LayerKind::Conv2d { .. } => "conv",
            LayerKind::AvgPool { .. } => "pool",
            LayerKind::Flatten => "flatten",
        }
    }

    /// Machine node name (parseable by [`NetIr::parse`]): `dense10`,
    /// `conv4k5x5s2`, `pool2s2`, `flatten`.
    pub fn node_name(&self) -> String {
        match self.kind {
            LayerKind::Dense => format!("dense{}", self.out_shape.len()),
            LayerKind::Conv2d { kh, kw, stride, out_ch, .. } => format!("conv{out_ch}k{kh}x{kw}s{stride}"),
            LayerKind::AvgPool { k, stride } => format!("pool{k}s{stride}"),
            LayerKind::Flatten => "flatten".to_string(),
        }
    }
}

/// The whole network's layer geometry: one [`LayerGeom`] per layer, with a
/// validated shape chain. This is what the hardware costing
/// (`crate::tune::cost::network_cost_ir`), serve-side shard validation, and
/// `TunePlan` serialization consume — derived from a trained network via
/// `Mlp::ir`, or parsed back from text.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetIr {
    geoms: Vec<LayerGeom>,
}

impl NetIr {
    /// Wrap a validated node list. Panics on an empty list or a broken
    /// shape chain (use [`NetIr::try_new`] for a fallible version).
    pub fn new(geoms: Vec<LayerGeom>) -> NetIr {
        match NetIr::try_new(geoms) {
            Ok(ir) => ir,
            Err(e) => panic!("invalid layer IR: {e}"),
        }
    }

    /// Fallible [`NetIr::new`]: returns the chain-validation error instead
    /// of panicking.
    pub fn try_new(geoms: Vec<LayerGeom>) -> Result<NetIr, String> {
        let ir = NetIr { geoms };
        ir.check()?;
        Ok(ir)
    }

    /// The classic dense-only chain for layer widths
    /// `dims = [in, h1, ..., out]`. Panics on invalid widths (use
    /// [`NetIr::try_dense`] for untrusted input).
    pub fn dense(dims: &[usize]) -> NetIr {
        match NetIr::try_dense(dims) {
            Ok(ir) => ir,
            Err(e) => panic!("invalid dense IR: {e}"),
        }
    }

    /// Fallible [`NetIr::dense`]: rejects chains with fewer than two widths
    /// or any zero width instead of panicking, so parsers of untrusted text
    /// (plan files) get a typed error path.
    pub fn try_dense(dims: &[usize]) -> Result<NetIr, String> {
        if dims.len() < 2 {
            return Err(format!("dense IR needs [in, out] at least, got {} width(s)", dims.len()));
        }
        if let Some(pos) = dims.iter().position(|&d| d == 0) {
            return Err(format!("dense IR width {pos} is zero"));
        }
        let geoms = dims
            .windows(2)
            .map(|d| LayerGeom {
                kind: LayerKind::Dense,
                in_shape: Shape::Flat(d[0]),
                out_shape: Shape::Flat(d[1]),
            })
            .collect();
        NetIr::try_new(geoms)
    }

    /// Validate the shape chain: non-empty, every node's inferred output
    /// matches its stored one, adjacent flat widths agree, and spatial
    /// consumers (conv/pool) see exactly the `C×H×W` block their geometry
    /// was built for.
    pub fn check(&self) -> Result<(), String> {
        if self.geoms.is_empty() {
            return Err("network has no layers".into());
        }
        for (li, g) in self.geoms.iter().enumerate() {
            if g.in_shape.is_empty() || g.out_shape.is_empty() {
                return Err(format!("layer {li} ({}) has an empty shape", g.node_name()));
            }
            match g.kind {
                LayerKind::Dense => {}
                _ => {
                    if g.kind.infer(g.in_shape) != Some(g.out_shape) {
                        return Err(format!("layer {li} ({}) shape inference mismatch", g.node_name()));
                    }
                }
            }
        }
        for (li, pair) in self.geoms.windows(2).enumerate() {
            let (a, b) = (&pair[0], &pair[1]);
            if a.out_shape.len() != b.in_shape.len() {
                return Err(format!(
                    "layer {li} produces {} elements but layer {} expects {}",
                    a.out_shape.len(),
                    li + 1,
                    b.in_shape.len()
                ));
            }
            let spatial = matches!(b.kind, LayerKind::Conv2d { .. } | LayerKind::AvgPool { .. });
            if spatial && a.out_shape != b.in_shape {
                return Err(format!(
                    "layer {} needs block {} but layer {li} produces {}",
                    li + 1,
                    b.in_shape,
                    a.out_shape
                ));
            }
        }
        Ok(())
    }

    /// The per-layer nodes, input-first.
    pub fn geoms(&self) -> &[LayerGeom] {
        &self.geoms
    }

    /// Number of layers.
    pub fn len(&self) -> usize {
        self.geoms.len()
    }

    /// Always false (constructors reject empty chains).
    pub fn is_empty(&self) -> bool {
        self.geoms.is_empty()
    }

    /// The network's input shape.
    pub fn input(&self) -> Shape {
        self.geoms[0].in_shape
    }

    /// The network's output shape.
    pub fn output(&self) -> Shape {
        self.geoms.last().expect("IR has layers").out_shape
    }

    /// Flat layer widths `[in, l1, ..., out]` — the dense-era view, still
    /// what buffers are sized from.
    pub fn dims(&self) -> Vec<usize> {
        let mut d = vec![self.geoms[0].in_shape.len()];
        d.extend(self.geoms.iter().map(|g| g.out_shape.len()));
        d
    }

    /// Whether every node is [`LayerKind::Dense`] (the XLA fast path and
    /// the pre-IR serialization cover exactly this case).
    pub fn is_dense(&self) -> bool {
        self.geoms.iter().all(|g| g.kind == LayerKind::Dense)
    }

    /// Machine name: `<input shape>:<node>+<node>+...`, e.g.
    /// `1x28x28:conv4k5x5s2+pool2s2+flatten+dense10` (parseable by
    /// [`NetIr::parse`]).
    pub fn name(&self) -> String {
        let nodes: Vec<String> = self.geoms.iter().map(LayerGeom::node_name).collect();
        format!("{}:{}", self.input().name(), nodes.join("+"))
    }

    /// Parse the [`NetIr::name`] form, re-running shape inference node by
    /// node. `None` on any malformed node or inference failure.
    pub fn parse(s: &str) -> Option<NetIr> {
        let (input, nodes) = s.split_once(':')?;
        let mut shape = Shape::parse(input)?;
        let mut geoms = Vec::new();
        for node in nodes.split('+') {
            let geom = parse_node(node, shape)?;
            shape = geom.out_shape;
            geoms.push(geom);
        }
        if geoms.is_empty() {
            return None;
        }
        let ir = NetIr { geoms };
        ir.check().ok()?;
        Some(ir)
    }
}

impl std::fmt::Display for NetIr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Parse one `dense10` / `conv4k5x5s2` / `pool2s2` / `flatten` node against
/// the current input shape. Output blocks above [`MAX_PARSED_DIM`] elements
/// are rejected so a parsed chain can never grow a shape whose derived
/// products (weight counts, buffer sizes) overflow.
fn parse_node(node: &str, in_shape: Shape) -> Option<LayerGeom> {
    let capped = |g: LayerGeom| (g.out_shape.len() <= MAX_PARSED_DIM).then_some(g);
    if node == "flatten" {
        return LayerGeom::infer(LayerKind::Flatten, in_shape, 0).and_then(capped);
    }
    if let Some(rest) = node.strip_prefix("dense") {
        let out: usize = rest.parse().ok()?;
        if out > MAX_PARSED_DIM {
            return None;
        }
        return LayerGeom::infer(LayerKind::Dense, in_shape, out).and_then(capped);
    }
    if let Some(rest) = node.strip_prefix("conv") {
        // conv<out_ch>k<kh>x<kw>s<stride>
        let (out_ch, rest) = rest.split_once('k')?;
        let (kh, rest) = rest.split_once('x')?;
        let (kw, stride) = rest.split_once('s')?;
        let in_ch = match in_shape {
            Shape::Chw { c, .. } => c,
            Shape::Flat(_) => return None,
        };
        let out_ch: usize = out_ch.parse().ok()?;
        if out_ch > MAX_PARSED_DIM {
            return None;
        }
        let kind = LayerKind::Conv2d {
            kh: kh.parse().ok()?,
            kw: kw.parse().ok()?,
            stride: stride.parse().ok()?,
            in_ch,
            out_ch,
        };
        return LayerGeom::infer(kind, in_shape, 0).and_then(capped);
    }
    if let Some(rest) = node.strip_prefix("pool") {
        let (k, stride) = rest.split_once('s')?;
        let kind = LayerKind::AvgPool { k: k.parse().ok()?, stride: stride.parse().ok()? };
        return LayerGeom::infer(kind, in_shape, 0).and_then(capped);
    }
    None
}

/// He-initialized weights for a layer with the given fan-in (the same
/// initializer the dense-only substrate always used).
pub(crate) fn he_init(fan_in: usize, count: usize, rng: &mut Rng) -> Vec<f64> {
    let std = (2.0 / fan_in as f64).sqrt();
    (0..count).map(|_| rng.normal(0.0, std)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const MNIST_IN: Shape = Shape::Chw { c: 1, h: 28, w: 28 };

    fn conv_ir() -> NetIr {
        let conv = LayerGeom::infer(LayerKind::Conv2d { kh: 5, kw: 5, stride: 2, in_ch: 1, out_ch: 4 }, MNIST_IN, 0)
            .unwrap();
        let pool = LayerGeom::infer(LayerKind::AvgPool { k: 2, stride: 2 }, conv.out_shape, 0).unwrap();
        let flat = LayerGeom::infer(LayerKind::Flatten, pool.out_shape, 0).unwrap();
        let dense = LayerGeom::infer(LayerKind::Dense, flat.out_shape, 10).unwrap();
        NetIr::new(vec![conv, pool, flat, dense])
    }

    #[test]
    fn shape_inference_on_the_conv_mnist_net() {
        let ir = conv_ir();
        assert_eq!(ir.dims(), vec![784, 576, 144, 144, 10]);
        assert_eq!(ir.geoms()[0].out_shape, Shape::Chw { c: 4, h: 12, w: 12 });
        assert_eq!(ir.geoms()[1].out_shape, Shape::Chw { c: 4, h: 6, w: 6 });
        assert_eq!(ir.output(), Shape::Flat(10));
        assert!(!ir.is_dense());
    }

    #[test]
    fn eq2_k_follows_the_receptive_field_not_the_input_width() {
        let ir = conv_ir();
        // conv: 5·5·1 products + 1 bias — NOT the 784-wide input.
        assert_eq!(ir.geoms()[0].eq2_k(), 26);
        assert_eq!(ir.geoms()[1].eq2_k(), 4); // 2×2 window, no bias
        assert_eq!(ir.geoms()[2].eq2_k(), 0); // flatten: wiring only
        assert_eq!(ir.geoms()[3].eq2_k(), 145); // 144 products + bias
    }

    #[test]
    fn banks_and_outputs_per_bank() {
        let ir = conv_ir();
        assert_eq!(ir.geoms()[0].banks(), 4);
        assert_eq!(ir.geoms()[0].outputs_per_bank(), 144);
        assert_eq!(ir.geoms()[1].banks(), 4);
        assert_eq!(ir.geoms()[1].outputs_per_bank(), 36);
        assert_eq!(ir.geoms()[2].banks(), 0);
        assert_eq!(ir.geoms()[3].banks(), 10);
        assert_eq!(ir.geoms()[3].outputs_per_bank(), 1);
    }

    #[test]
    fn ir_name_round_trips() {
        let ir = conv_ir();
        assert_eq!(ir.name(), "1x28x28:conv4k5x5s2+pool2s2+flatten+dense10");
        assert_eq!(NetIr::parse(&ir.name()), Some(ir));
        let dense = NetIr::dense(&[30, 16, 8, 2]);
        assert_eq!(dense.name(), "30:dense16+dense8+dense2");
        assert_eq!(NetIr::parse(&dense.name()), Some(dense.clone()));
        assert!(dense.is_dense());
        assert_eq!(dense.dims(), vec![30, 16, 8, 2]);
    }

    #[test]
    fn parse_rejects_malformed_chains() {
        assert!(NetIr::parse("784:").is_none());
        assert!(NetIr::parse("784:conv4k5x5s2").is_none(), "conv needs a CHW input");
        assert!(NetIr::parse("1x28x28:pool3s3").is_none(), "pool window must be a power of two");
        assert!(NetIr::parse("1x28x28:conv4k5x5s0").is_none(), "stride 0");
        assert!(NetIr::parse("1x28x28:dense0").is_none());
        assert!(NetIr::parse("bogus").is_none());
    }

    #[test]
    fn avg_pool_window_must_be_power_of_two() {
        let kind = LayerKind::AvgPool { k: 3, stride: 1 };
        assert_eq!(kind.infer(Shape::Chw { c: 1, h: 8, w: 8 }), None);
        let kind = LayerKind::AvgPool { k: 4, stride: 4 };
        assert_eq!(kind.infer(Shape::Chw { c: 2, h: 8, w: 8 }), Some(Shape::Chw { c: 2, h: 2, w: 2 }));
    }

    #[test]
    fn dense_ir_matches_dense_geometry() {
        let ir = NetIr::dense(&[4, 10, 3]);
        for (g, (fan_in, out)) in ir.geoms().iter().zip([(4usize, 10usize), (10, 3)]) {
            assert_eq!(g.fan_in(), fan_in);
            assert_eq!(g.eq2_k(), fan_in + 1);
            assert_eq!(g.banks(), out);
            assert_eq!(g.outputs_per_bank(), 1);
        }
    }
}
