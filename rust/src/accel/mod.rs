//! The Deep Positron accelerator (paper §4) and its substrates: a plain
//! f64 MLP (training + baseline inference) and the bit-exact EMAC datapath
//! simulator the low-precision results are measured on.

pub mod mlp;
pub mod positron;

pub use mlp::{argmax, train, Mlp, TrainConfig};
pub use positron::{Datapath, DeepPositron};
