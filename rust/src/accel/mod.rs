//! The Deep Positron accelerator (paper §4) and its substrates: the typed
//! layer IR ([`ir`] — dense / conv2d / avg-pool / flatten with shape
//! inference, DESIGN.md §11), a plain f64 network (training + baseline
//! inference) over that IR, and the bit-exact EMAC datapath simulator the
//! low-precision results are measured on.
//!
//! Inference compiles once into a per-layer execution plan (pre-decoded
//! weight operands, quire-staged biases — DESIGN.md §8; conv layers map to
//! per-output-pixel quire accumulation over the receptive field) and runs
//! many via [`DeepPositron::forward_batch`]; the scalar entry points are the
//! batch-of-one special case.

pub mod ir;
pub mod mlp;
pub mod positron;

pub use ir::{LayerGeom, LayerKind, NetIr, Shape};
pub use mlp::{argmax, train, Layer, Mlp, TrainConfig};
pub use positron::{Datapath, DeepPositron, EVAL_BATCH};
