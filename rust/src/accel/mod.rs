//! The Deep Positron accelerator (paper §4) and its substrates: a plain
//! f64 MLP (training + baseline inference) and the bit-exact EMAC datapath
//! simulator the low-precision results are measured on.
//!
//! Inference compiles once into a per-layer execution plan (pre-decoded
//! weight operands, quire-staged biases — DESIGN.md §8) and runs many via
//! [`DeepPositron::forward_batch`]; the scalar entry points are the
//! batch-of-one special case.

pub mod mlp;
pub mod positron;

pub use mlp::{argmax, train, Mlp, TrainConfig};
pub use positron::{Datapath, DeepPositron, EVAL_BATCH};
