//! A small, process-wide worker pool for within-batch parallelism
//! (DESIGN.md §12).
//!
//! The batched EMAC kernels ([`crate::accel::DeepPositron::forward_batch`])
//! split large batches into independent sample chunks; the serving engine's
//! Sim workers execute their flushed batches through the same kernels. Both
//! therefore draw from ONE shared parallelism budget — this pool — so a
//! machine running `shards × workers` serve threads plus batched inference
//! never oversubscribes its cores: the pool's width caps the *additional*
//! threads any single batch may fan out to, process-wide.
//!
//! Design notes:
//!
//! * **Scoped fan-out, not resident threads.** Jobs borrow their caller's
//!   stack data (activation blocks, output slices), so the pool runs them on
//!   [`std::thread::scope`] threads — safe with non-`'static` borrows and
//!   unsafe-free, at the cost of a spawn per job batch. The kernels only
//!   engage the pool for batches large enough to amortize that (microseconds
//!   against milliseconds of quire accumulation).
//! * **Determinism.** The pool only ever runs *independent* jobs (disjoint
//!   sample chunks writing disjoint output regions), so results are
//!   bit-identical to sequential execution regardless of width or
//!   scheduling. `tests/batch_parity.rs` asserts this including the
//!   more-threads-than-rows edge.
//! * **Sizing.** [`WorkerPool::global`] defaults to the machine's available
//!   parallelism capped at 8 (beyond that, the ≤8-bit kernels are
//!   memory-bound); `DEEP_POSITRON_POOL=n` overrides, and `n = 1` disables
//!   fan-out entirely (every job runs inline on the caller's thread).

// Unsafe allowlist (DESIGN.md §14): this module is the crate's ONE place
// `unsafe` may ever appear — `repro lint` and the crate-root
// `#![deny(unsafe_code)]` both point here. Audit (PR 8): the pool is
// currently **unsafe-free** — scoped threads ([`std::thread::scope`]) carry
// the non-`'static` borrows that a hand-rolled pool would need raw pointers
// for. If a future optimization does introduce `unsafe` (e.g. uninitialized
// output buffers), it must land in this module with its safety contract
// documented at the site, and nowhere else.
#![allow(unsafe_code)]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// Hard cap on the default pool width: the tiled kernels are cache/memory
/// bound well before this, and serve deployments already run one thread per
/// worker.
const DEFAULT_MAX_THREADS: usize = 8;

/// Process-wide pool behind [`WorkerPool::global`].
static GLOBAL_POOL: OnceLock<WorkerPool> = OnceLock::new();

// Process-wide fan-out occupancy counters (DESIGN.md §15): observability
// only — relaxed, monotone, never read back by the pool itself. Counted
// across EVERY pool instance so the obs snapshot reflects total within-batch
// parallelism pressure, not just the global pool.
static POOL_JOBS: AtomicU64 = AtomicU64::new(0);
static POOL_CHUNKS: AtomicU64 = AtomicU64::new(0);
static POOL_INLINE_RUNS: AtomicU64 = AtomicU64::new(0);

/// Cumulative pool occupancy since process start, for the obs snapshot
/// (`ObsSnapshot::collect`): `(jobs, chunks, inline_runs)` — jobs submitted
/// through [`WorkerPool::run`]/[`WorkerPool::run_map`], contiguous job
/// groups handed to scoped threads (the caller's own group included), and
/// whole batches that ran inline (width-1 pool or ≤ 1 job).
pub fn fanout_counters() -> (u64, u64, u64) {
    (
        POOL_JOBS.load(Ordering::Relaxed),
        POOL_CHUNKS.load(Ordering::Relaxed),
        POOL_INLINE_RUNS.load(Ordering::Relaxed),
    )
}

/// A bounded fan-out helper: runs a batch of independent jobs across at most
/// `threads` scoped threads (inline when `threads == 1` or there is a single
/// job). See the module docs for the sharing/determinism contract.
#[derive(Debug)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// A pool of the given width (clamped to at least 1).
    pub fn new(threads: usize) -> WorkerPool {
        WorkerPool { threads: threads.max(1) }
    }

    /// The process-wide shared pool: available parallelism capped at 8,
    /// overridable with `DEEP_POSITRON_POOL=n` (n ≥ 1; `1` forces inline
    /// execution everywhere).
    pub fn global() -> &'static WorkerPool {
        GLOBAL_POOL.get_or_init(|| {
            let default = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            let threads = std::env::var("DEEP_POSITRON_POOL")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or_else(|| default.min(DEFAULT_MAX_THREADS));
            WorkerPool::new(threads)
        })
    }

    /// The pool's width (≥ 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run every job and collect its return value, in submission order —
    /// the fan-out/merge primitive the mixed-precision tuner scores a
    /// descent round's candidates with. Scheduling never reorders results:
    /// each job writes its own pre-allocated slot, so `run_map` at any pool
    /// width returns exactly what a sequential `jobs.map(|j| j())` would.
    pub fn run_map<T: Send, F: FnOnce() -> T + Send>(&self, jobs: Vec<F>) -> Vec<T> {
        let mut out: Vec<Option<T>> = (0..jobs.len()).map(|_| None).collect();
        let tasks: Vec<_> = jobs
            .into_iter()
            .zip(out.iter_mut())
            .map(|(job, slot)| {
                move || {
                    *slot = Some(job());
                }
            })
            .collect();
        self.run(tasks);
        out.into_iter().map(|slot| slot.expect("every job ran to completion")).collect()
    }

    /// Run every job to completion. Jobs may borrow caller data (they only
    /// need to outlive this call); with a single job or a width-1 pool they
    /// run inline on the caller's thread. Jobs are partitioned round-free
    /// into at most `threads` contiguous groups, one scoped thread each —
    /// callers pass uniform chunks, so static partitioning balances. A
    /// panicking job propagates the panic to the caller (scope join).
    pub fn run<F: FnOnce() + Send>(&self, mut jobs: Vec<F>) {
        if jobs.is_empty() {
            return;
        }
        POOL_JOBS.fetch_add(jobs.len() as u64, Ordering::Relaxed);
        if self.threads == 1 || jobs.len() <= 1 {
            POOL_INLINE_RUNS.fetch_add(1, Ordering::Relaxed);
            for job in jobs {
                job();
            }
            return;
        }
        let groups = self.threads.min(jobs.len());
        POOL_CHUNKS.fetch_add(groups as u64, Ordering::Relaxed);
        let per = jobs.len().div_ceil(groups);
        std::thread::scope(|s| {
            while jobs.len() > per {
                let tail = jobs.split_off(jobs.len() - per);
                s.spawn(move || {
                    for job in tail {
                        job();
                    }
                });
            }
            // Run the first group on the caller's thread: one fewer spawn,
            // and a width-n pool uses exactly n threads including this one.
            for job in jobs {
                job();
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_job_exactly_once() {
        for threads in [1, 2, 4, 16] {
            let pool = WorkerPool::new(threads);
            let hits = AtomicUsize::new(0);
            let jobs: Vec<_> = (0..10)
                .map(|_| {
                    || {
                        hits.fetch_add(1, Ordering::Relaxed);
                    }
                })
                .collect();
            pool.run(jobs);
            assert_eq!(hits.load(Ordering::Relaxed), 10, "width {threads}");
        }
    }

    #[test]
    fn jobs_write_disjoint_borrowed_slices() {
        let mut out = vec![0usize; 24];
        let pool = WorkerPool::new(3);
        let jobs: Vec<_> = out
            .chunks_mut(7)
            .enumerate()
            .map(|(i, chunk)| {
                move || {
                    for (j, slot) in chunk.iter_mut().enumerate() {
                        *slot = i * 100 + j;
                    }
                }
            })
            .collect();
        pool.run(jobs);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i / 7) * 100 + i % 7);
        }
    }

    #[test]
    fn more_threads_than_jobs_is_fine() {
        let pool = WorkerPool::new(64);
        let hits = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..3)
            .map(|_| {
                || {
                    hits.fetch_add(1, Ordering::Relaxed);
                }
            })
            .collect();
        pool.run(jobs);
        assert_eq!(hits.load(Ordering::Relaxed), 3);
        // Zero jobs: a no-op, never a panic.
        pool.run(Vec::<fn()>::new());
    }

    #[test]
    fn width_clamps_to_one() {
        assert_eq!(WorkerPool::new(0).threads(), 1);
        assert!(WorkerPool::global().threads() >= 1);
    }

    #[test]
    fn fanout_counters_count_jobs_monotonically() {
        // Counters are process-wide (other tests bump them concurrently), so
        // assert monotone growth by at least this test's own contribution.
        let (j0, _, _) = fanout_counters();
        let pool = WorkerPool::new(2);
        pool.run((0..4).map(|_| || {}).collect::<Vec<_>>());
        let (j1, _, _) = fanout_counters();
        assert!(j1 >= j0 + 4, "jobs counter moved {j0} -> {j1}");
    }

    #[test]
    fn run_map_preserves_submission_order_at_every_width() {
        for threads in [1, 2, 3, 8, 64] {
            let pool = WorkerPool::new(threads);
            let jobs: Vec<_> = (0..23usize).map(|i| move || i * i).collect();
            let got = pool.run_map(jobs);
            let want: Vec<usize> = (0..23).map(|i| i * i).collect();
            assert_eq!(got, want, "width {threads}");
            // Empty and single-job batches are fine too.
            assert_eq!(pool.run_map(Vec::<fn() -> usize>::new()), Vec::<usize>::new());
            assert_eq!(pool.run_map(vec![|| 7usize]), vec![7]);
        }
    }
}
