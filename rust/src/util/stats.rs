//! Lightweight statistics helpers shared by benches, the cost model, and the
//! experiment reports.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (nearest-rank on a sorted copy), p in [0, 100].
///
/// Uses the ceil-based nearest-rank definition `⌈p/100 · n⌉`: the smallest
/// value with at least p% of the samples at or below it. The previous
/// `round(p/100 · (n−1))` variant was not nearest-rank at all — it
/// mis-ranked both ways (the median of 4 samples came back as the
/// 3rd-ranked value, while p-values landing between ranks rounded *down*
/// half the time, understating latency tails — the wrong direction for
/// SLOs).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.saturating_sub(1).min(v.len() - 1)]
}

/// Min/max of a slice.
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    (lo, hi)
}

/// Histogram with `bins` equal-width buckets over [lo, hi].
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    assert!(bins > 0 && hi > lo);
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x < lo || x > hi {
            continue;
        }
        let i = (((x - lo) / w) as usize).min(bins - 1);
        h[i] += 1;
    }
    h
}

/// Simple timer for the hand-rolled bench harness (criterion is unavailable
/// offline; see DESIGN.md §Substitutions).
pub struct BenchTimer {
    label: String,
    samples: Vec<f64>,
}

impl BenchTimer {
    /// Timer with a report label.
    pub fn new(label: &str) -> BenchTimer {
        BenchTimer { label: label.to_string(), samples: Vec::new() }
    }

    /// Time one invocation (seconds).
    pub fn sample<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = std::time::Instant::now();
        let out = f();
        self.samples.push(t0.elapsed().as_secs_f64());
        out
    }

    /// Run `f` repeatedly for at least `budget` seconds (min 3 samples).
    pub fn run(&mut self, budget: f64, mut f: impl FnMut()) {
        let t0 = std::time::Instant::now();
        while t0.elapsed().as_secs_f64() < budget || self.samples.len() < 3 {
            self.sample(&mut f);
        }
    }

    /// The collected per-invocation timings, seconds.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Render a criterion-style one-liner.
    pub fn report(&self) -> String {
        let m = mean(&self.samples);
        let sd = std_dev(&self.samples);
        format!(
            "{:<44} time: [{} ± {}]  n={}",
            self.label,
            fmt_time(m),
            fmt_time(sd),
            self.samples.len()
        )
    }
}

/// Human-format a duration in seconds.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std_dev(&[1.0, 2.0, 3.0]) - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn percentile_is_ceil_based_nearest_rank() {
        // Median of an even count is the lower-middle rank (⌈0.5·4⌉ = 2nd),
        // not the upper-middle the old round((n−1)·p) rule picked.
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 50.0), 2.0);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 75.0), 3.0);
        // Tail percentiles never understate: p99 of 100 samples is the
        // 99th-ranked value, anything above lands on the max.
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 99.0), 99.0);
        assert_eq!(percentile(&xs, 99.5), 100.0);
        // Degenerate inputs stay in range.
        assert_eq!(percentile(&[7.0], 50.0), 7.0);
        assert_eq!(percentile(&[7.0], 0.0), 7.0);
    }

    #[test]
    fn histogram_counts() {
        let h = histogram(&[0.1, 0.2, 0.9, 1.5], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 1]);
    }

    #[test]
    fn timer_collects_samples() {
        let mut t = BenchTimer::new("noop");
        t.run(0.001, || {
            std::hint::black_box(1 + 1);
        });
        assert!(t.samples().len() >= 3);
        assert!(t.report().contains("noop"));
    }
}
