//! Small self-contained utilities: a deterministic RNG (the offline build has
//! no `rand` crate), lightweight statistics, and a property-test driver used
//! by the test suites in lieu of `proptest`.

pub mod prop;
pub mod rng;
pub mod stats;

pub use rng::Rng;
