//! Small self-contained utilities: a deterministic RNG (the offline build has
//! no `rand` crate), lightweight statistics, a property-test driver used by
//! the test suites in lieu of `proptest`, the perf-trajectory bench log, and
//! the shared worker pool behind within-batch parallelism.

pub mod bench_log;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;

pub use rng::Rng;
