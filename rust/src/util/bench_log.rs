//! The recorded perf trajectory (DESIGN.md §12): schema-versioned
//! `BENCH_<name>.json` files at the repository root, written by the
//! throughput benches and gated against the committed baseline so a PR
//! cannot silently regress samples/s.
//!
//! The flow, per bench run ([`record_and_gate`]):
//!
//! 1. the bench measures its throughput figures and collects them into a
//!    [`BenchLog`] (one `samples_per_s` entry per labeled measurement);
//! 2. the committed baseline (`BENCH_<name>.json`) is loaded — a missing
//!    file is a **soft pass** (first run on a fresh checkout) and is
//!    written; a file with the wrong [`SCHEMA_VERSION`] is a hard error
//!    (regenerate it, don't guess);
//! 3. every baseline entry is compared against the fresh measurement of the
//!    same name: a drop of more than the tolerance (default
//!    [`DEFAULT_TOLERANCE`], 10%) **fails the bench**, improvements and
//!    small noise pass, and a baseline entry whose measurement disappeared
//!    entirely also fails (a gate must not rot away silently). A would-be
//!    failure is not final on one sample: the bench re-measures (up to
//!    [`GATE_SAMPLES`] samples total, lazily — a passing first sample pays
//!    for exactly one run) and gates on the per-entry **best**, so one
//!    noisy-neighbour run cannot fail CI while a real regression, which is
//!    slow every time, still does;
//! 4. on pass, the fresh numbers overwrite the file — committing that diff
//!    is how the baseline ratchets forward, and git history *is* the
//!    trajectory across PRs.
//!
//! Baseline entries with `samples_per_s = 0.0` are **seeds**: placeholders
//! marking a tracked measurement that has never been recorded on a real
//! machine (this repo's CI containers differ from dev boxes, so committed
//! absolute numbers start unmeasured). Any real measurement beats a seed,
//! so the first bench run arms the gate by overwriting it.
//!
//! The JSON codec is hand-rolled (the offline build has no `serde`,
//! DESIGN.md §Substitutions): the writer emits a fixed pretty layout and
//! the reader is a small recursive-descent parser over the JSON subset the
//! writer produces (objects, arrays, strings with basic escapes, finite
//! numbers) — strict enough to reject hand-edits that would corrupt the
//! gate.

use std::fmt;
use std::path::{Path, PathBuf};

/// Version stamp written into (and demanded from) every `BENCH_*.json`.
/// Bump it when the schema changes shape; old files then fail loudly with
/// [`BenchLogError::SchemaMismatch`] instead of being misread.
pub const SCHEMA_VERSION: u32 = 1;

/// Default regression tolerance: a tracked entry may lose up to 10% of its
/// baseline samples/s before the gate fails (machine noise passes, real
/// regressions don't).
pub const DEFAULT_TOLERANCE: f64 = 0.10;

/// One labeled throughput measurement (higher is better).
#[derive(Debug, Clone, PartialEq)]
pub struct BenchEntry {
    /// Measurement label, e.g. `mnist/forward_batch/B=32`.
    pub name: String,
    /// Throughput in samples per second; `0.0` marks an unmeasured seed.
    pub samples_per_s: f64,
}

/// A schema-versioned set of throughput measurements from one bench binary.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchLog {
    /// Schema version ([`SCHEMA_VERSION`] for logs built in-process).
    pub schema: u32,
    /// Bench name; the on-disk file is `BENCH_<bench>.json`.
    pub bench: String,
    /// The gate tolerance this baseline was recorded under, as a fraction in
    /// `[0, 1)`; `None` on logs that predate the field or were never gated.
    /// [`record_and_gate`] stamps it so the committed file documents how
    /// tight its own gate is (and `repro lint` can audit the claim).
    pub tolerance: Option<f64>,
    /// Measurements, in bench emission order.
    pub entries: Vec<BenchEntry>,
}

/// Errors loading, parsing, or building a bench log.
#[derive(Debug, Clone, PartialEq)]
pub enum BenchLogError {
    /// The file's schema version is not [`SCHEMA_VERSION`].
    SchemaMismatch {
        /// Version found in the file.
        found: u32,
        /// Version this build expects.
        want: u32,
    },
    /// The file is not the JSON shape the writer emits.
    Malformed(String),
    /// Filesystem error reading the file.
    Io(String),
    /// A measurement handed to [`BenchLog::push`] was NaN, infinite, or
    /// negative — always a harness bug, never a slow machine.
    BadSample {
        /// Label of the rejected measurement.
        name: String,
        /// The offending samples/s value.
        value: f64,
    },
}

impl fmt::Display for BenchLogError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BenchLogError::SchemaMismatch { found, want } => {
                write!(f, "bench log schema {found} != supported {want}; regenerate the file")
            }
            BenchLogError::Malformed(why) => write!(f, "malformed bench log: {why}"),
            BenchLogError::Io(why) => write!(f, "bench log io error: {why}"),
            BenchLogError::BadSample { name, value } => {
                write!(f, "bench entry {name}: samples/s must be finite and >= 0, got {value}")
            }
        }
    }
}

impl std::error::Error for BenchLogError {}

impl BenchLog {
    /// An empty log for `bench` at the current [`SCHEMA_VERSION`].
    pub fn new(bench: &str) -> BenchLog {
        BenchLog { schema: SCHEMA_VERSION, bench: bench.to_string(), tolerance: None, entries: Vec::new() }
    }

    /// Append one measurement. NaN, infinite, and negative samples/s are
    /// rejected with [`BenchLogError::BadSample`] — benches must not record
    /// them (that is always a harness bug, not a slow machine), and a typed
    /// error keeps the rejection testable instead of aborting the process.
    pub fn push(&mut self, name: &str, samples_per_s: f64) -> Result<(), BenchLogError> {
        if !samples_per_s.is_finite() || samples_per_s < 0.0 {
            return Err(BenchLogError::BadSample { name: name.to_string(), value: samples_per_s });
        }
        self.entries.push(BenchEntry { name: name.to_string(), samples_per_s });
        Ok(())
    }

    /// The entry named `name`, if recorded.
    pub fn entry(&self, name: &str) -> Option<&BenchEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// The on-disk home of a bench's baseline: `BENCH_<bench>.json` at the
    /// repository root (the crate manifest directory).
    pub fn path(bench: &str) -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join(format!("BENCH_{bench}.json"))
    }

    /// Load the committed baseline for `bench` from the repository root;
    /// `Ok(None)` when no file exists (first run — soft pass).
    pub fn load(bench: &str) -> Result<Option<BenchLog>, BenchLogError> {
        BenchLog::load_from(&BenchLog::path(bench))
    }

    /// [`BenchLog::load`] from an explicit path (tests point this at temp
    /// files).
    pub fn load_from(path: &Path) -> Result<Option<BenchLog>, BenchLogError> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(BenchLogError::Io(format!("{}: {e}", path.display()))),
        };
        BenchLog::from_json(&text).map(Some)
    }

    /// Write this log to its repository-root baseline path; returns the
    /// path written.
    pub fn save(&self) -> Result<PathBuf, BenchLogError> {
        let path = BenchLog::path(&self.bench);
        self.save_to(&path)?;
        Ok(path)
    }

    /// [`BenchLog::save`] to an explicit path.
    pub fn save_to(&self, path: &Path) -> Result<(), BenchLogError> {
        std::fs::write(path, self.to_json()).map_err(|e| BenchLogError::Io(format!("{}: {e}", path.display())))
    }

    /// Serialize to the canonical pretty JSON layout (ends with a newline,
    /// diff- and git-friendly: one entry per line).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"schema\": {},\n", self.schema));
        out.push_str(&format!("  \"bench\": {},\n", json_string(&self.bench)));
        if let Some(t) = self.tolerance {
            out.push_str(&format!("  \"tolerance\": {},\n", json_number(t)));
        }
        out.push_str("  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            let sep = if i + 1 == self.entries.len() { "" } else { "," };
            out.push_str(&format!(
                "    {{\"name\": {}, \"samples_per_s\": {}}}{sep}\n",
                json_string(&e.name),
                json_number(e.samples_per_s)
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Parse a bench log, enforcing the schema version and the writer's
    /// shape (unknown keys are rejected — a typo must not silently disarm
    /// the gate).
    pub fn from_json(text: &str) -> Result<BenchLog, BenchLogError> {
        let bad = |why: &str| BenchLogError::Malformed(why.to_string());
        let top = Json::parse(text)?;
        let Json::Obj(fields) = top else { return Err(bad("top level must be an object")) };
        let mut schema = None;
        let mut bench = None;
        let mut tolerance = None;
        let mut entries = None;
        for (key, value) in fields {
            match (key.as_str(), value) {
                ("schema", Json::Num(v)) if v >= 0.0 && v.fract() == 0.0 => schema = Some(v as u32),
                ("schema", _) => return Err(bad("\"schema\" must be a non-negative integer")),
                ("bench", Json::Str(s)) => bench = Some(s),
                ("bench", _) => return Err(bad("\"bench\" must be a string")),
                ("tolerance", Json::Num(v)) if (0.0..1.0).contains(&v) => tolerance = Some(v),
                ("tolerance", _) => return Err(bad("\"tolerance\" must be a fraction in [0, 1)")),
                ("entries", Json::Arr(items)) => {
                    let mut list = Vec::with_capacity(items.len());
                    for item in items {
                        list.push(parse_entry(item)?);
                    }
                    entries = Some(list);
                }
                ("entries", _) => return Err(bad("\"entries\" must be an array")),
                (other, _) => return Err(BenchLogError::Malformed(format!("unknown key {other:?}"))),
            }
        }
        let schema = schema.ok_or_else(|| bad("missing \"schema\""))?;
        if schema != SCHEMA_VERSION {
            return Err(BenchLogError::SchemaMismatch { found: schema, want: SCHEMA_VERSION });
        }
        Ok(BenchLog {
            schema,
            bench: bench.ok_or_else(|| bad("missing \"bench\""))?,
            tolerance,
            entries: entries.ok_or_else(|| bad("missing \"entries\""))?,
        })
    }
}

fn parse_entry(item: Json) -> Result<BenchEntry, BenchLogError> {
    let bad = |why: &str| BenchLogError::Malformed(why.to_string());
    let Json::Obj(fields) = item else { return Err(bad("entry must be an object")) };
    let mut name = None;
    let mut sps = None;
    for (key, value) in fields {
        match (key.as_str(), value) {
            ("name", Json::Str(s)) => name = Some(s),
            ("samples_per_s", Json::Num(v)) if v.is_finite() && v >= 0.0 => sps = Some(v),
            ("samples_per_s", _) => return Err(bad("\"samples_per_s\" must be a finite non-negative number")),
            (other, _) => return Err(BenchLogError::Malformed(format!("unknown entry key {other:?}"))),
        }
    }
    Ok(BenchEntry {
        name: name.ok_or_else(|| bad("entry missing \"name\""))?,
        samples_per_s: sps.ok_or_else(|| bad("entry missing \"samples_per_s\""))?,
    })
}

/// Compare fresh measurements against a committed baseline. `Ok` carries
/// one human-readable line per tracked entry; `Err` carries one line per
/// gate violation (regression beyond `tolerance`, or a baseline entry whose
/// measurement vanished). Seed entries (`0.0` baseline) always pass; fresh
/// entries with no baseline counterpart are reported but never fail (they
/// arm on the next baseline commit).
pub fn compare(current: &BenchLog, baseline: &BenchLog, tolerance: f64) -> Result<Vec<String>, Vec<String>> {
    assert!((0.0..1.0).contains(&tolerance), "tolerance is a fraction in [0, 1)");
    let mut report = Vec::new();
    let mut failures = Vec::new();
    for base in &baseline.entries {
        let Some(cur) = current.entry(&base.name) else {
            failures.push(format!("{}: tracked entry disappeared from the bench", base.name));
            continue;
        };
        if base.samples_per_s == 0.0 {
            report.push(format!("{}: {:.0}/s (seed baseline armed)", base.name, cur.samples_per_s));
            continue;
        }
        let ratio = cur.samples_per_s / base.samples_per_s;
        if ratio < 1.0 - tolerance {
            failures.push(format!(
                "{}: {:.0}/s is {:.1}% below baseline {:.0}/s (tolerance {:.0}%)",
                base.name,
                cur.samples_per_s,
                (1.0 - ratio) * 100.0,
                base.samples_per_s,
                tolerance * 100.0
            ));
        } else {
            report.push(format!(
                "{}: {:.0}/s vs baseline {:.0}/s ({:+.1}%)",
                base.name,
                cur.samples_per_s,
                base.samples_per_s,
                (ratio - 1.0) * 100.0
            ));
        }
    }
    for cur in &current.entries {
        if baseline.entry(&cur.name).is_none() {
            report.push(format!("{}: {:.0}/s (new, untracked until committed)", cur.name, cur.samples_per_s));
        }
    }
    if failures.is_empty() {
        Ok(report)
    } else {
        Err(failures)
    }
}

/// Samples the gate may draw per bench run: a would-be regression is
/// re-measured up to this many times **total** and gated on the per-entry
/// best. Sampling is lazy — a clean first measurement never re-runs the
/// bench, so the common CI case still pays for exactly one run.
pub const GATE_SAMPLES: usize = 3;

/// Fold one fresh sample into the running best: per entry name, keep the
/// max samples/s seen so far; entries appearing only in the new sample are
/// appended (emission order of the first sample wins for shared names).
fn merge_best(best: &mut BenchLog, sample: BenchLog) {
    for e in sample.entries {
        match best.entries.iter_mut().find(|b| b.name == e.name) {
            Some(b) => b.samples_per_s = b.samples_per_s.max(e.samples_per_s),
            None => best.entries.push(e),
        }
    }
}

/// Best-of-[`GATE_SAMPLES`] gating core: gate the first sample as-is, and
/// only when it would fail draw further samples from `resample`, merging
/// per-entry maxima and re-gating, until the gate passes or the sample
/// budget is spent. Returns the merged best log and the final verdict.
fn gate_best_of<F: FnMut() -> BenchLog>(
    first: BenchLog,
    baseline: &BenchLog,
    resample: &mut F,
    tolerance: f64,
) -> (BenchLog, Result<Vec<String>, Vec<String>>) {
    let mut best = first;
    let mut verdict = compare(&best, baseline, tolerance);
    let mut taken = 1;
    while verdict.is_err() && taken < GATE_SAMPLES {
        taken += 1;
        eprintln!(
            "bench_log[{}]: below baseline — re-measuring, sample {taken} of up to {GATE_SAMPLES}",
            best.bench
        );
        merge_best(&mut best, resample());
        verdict = compare(&best, baseline, tolerance);
    }
    (best, verdict)
}

/// The bench-side entry point: gate `current` against the committed
/// baseline at the default repository-root path, then persist the best
/// observed numbers. A measurement below tolerance is re-sampled via
/// `resample` (which must re-run the bench's measurement loop and return a
/// fresh [`BenchLog`]) up to [`GATE_SAMPLES`] times total, gating the
/// per-entry best — noise needs one good sample to pass, a real regression
/// is slow every time. Panics (failing the bench, and CI with it) when the
/// best-of still regresses beyond `tolerance`, or on an
/// unreadable/mis-versioned baseline; a missing baseline is a soft pass
/// that writes one.
pub fn record_and_gate<F: FnMut() -> BenchLog>(current: BenchLog, mut resample: F, tolerance: f64) {
    let bench = current.bench.clone();
    let best = match BenchLog::load(&bench) {
        Ok(Some(baseline)) => {
            let (best, verdict) = gate_best_of(current, &baseline, &mut resample, tolerance);
            match verdict {
                Ok(report) => {
                    for line in report {
                        println!("bench_log[{bench}]: {line}");
                    }
                    best
                }
                Err(failures) => {
                    for line in &failures {
                        eprintln!("bench_log[{bench}]: REGRESSION {line}");
                    }
                    panic!(
                        "bench_log[{bench}]: {} throughput regression(s) beyond tolerance \
                         after best-of-{GATE_SAMPLES} sampling",
                        failures.len()
                    );
                }
            }
        }
        Ok(None) => {
            println!("bench_log[{bench}]: no committed baseline — writing one (soft pass)");
            current
        }
        Err(e) => panic!("bench_log[{bench}]: cannot gate against baseline: {e}"),
    };
    // Stamp the gate's tolerance into the written baseline so the committed
    // file documents its own contract (audited by `repro lint`).
    let mut stamped = best;
    stamped.tolerance = Some(tolerance);
    let path = stamped.save().expect("bench log write");
    println!("bench_log[{bench}]: wrote {}", path.display());
}

/// Time budget for one bench timer, scaled by the `BENCH_BUDGET` env var
/// (a multiplier; CI sets a fraction like `0.25` so the three throughput
/// benches finish quickly, dev boxes default to 1.0 for steadier numbers).
pub fn bench_budget(default_secs: f64) -> f64 {
    let scale = std::env::var("BENCH_BUDGET").ok().and_then(|v| v.parse::<f64>().ok()).unwrap_or(1.0);
    let scale = if scale.is_finite() && scale > 0.0 { scale } else { 1.0 };
    default_secs * scale
}

/// JSON-escape a string (the writer side of the hand-rolled codec; shared
/// with the `obs` snapshot/trace codecs).
pub(crate) fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format a finite f64 as a JSON number (Rust's shortest round-trip form,
/// with a `.0` forced onto integral values so the type stays visibly
/// floating-point in diffs).
pub(crate) fn json_number(v: f64) -> String {
    debug_assert!(v.is_finite());
    let s = format!("{v}");
    if s.contains('.') || s.contains('e') || s.contains('E') {
        s
    } else {
        format!("{s}.0")
    }
}

/// The JSON subset the reader understands (exactly what the writer emits,
/// plus whitespace freedom for hand edits). Crate-visible so the `obs`
/// snapshot/trace codecs parse through the same strict grammar.
pub(crate) enum Json {
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub(crate) fn parse(text: &str) -> Result<Json, BenchLogError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data after the top-level value"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, why: &str) -> BenchLogError {
        BenchLogError::Malformed(format!("{why} (at byte {})", self.pos))
    }

    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, b: u8) -> Result<(), BenchLogError> {
        self.skip_ws();
        if self.bytes.get(self.pos) == Some(&b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, BenchLogError> {
        self.skip_ws();
        match self.bytes.get(self.pos) {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(c) if c.is_ascii_digit() || *c == b'-' => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, BenchLogError> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.bytes.get(self.pos) == Some(&b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.eat(b':')?;
            fields.push((key, self.value()?));
            self.skip_ws();
            match self.bytes.get(self.pos) {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, BenchLogError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.bytes.get(self.pos) == Some(&b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bytes.get(self.pos) {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, BenchLogError> {
        if self.bytes.get(self.pos) != Some(&b'"') {
            return Err(self.err("expected a string"));
        }
        self.pos += 1;
        let start = self.pos;
        let mut out = String::new();
        while let Some(&b) = self.bytes.get(self.pos) {
            match b {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let esc = self.bytes.get(self.pos).copied().ok_or_else(|| self.err("dangling escape"))?;
                    out.push(match esc {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex).unwrap_or("zz"), 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            char::from_u32(code).ok_or_else(|| self.err("bad \\u code point"))?
                        }
                        _ => return Err(self.err("unsupported escape")),
                    });
                    self.pos += 1;
                }
                _ => {
                    // UTF-8 passthrough: consume one whole char.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8 in string"))?;
                    let c = rest.chars().next().ok_or_else(|| self.err("unterminated string"))?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
        self.pos = start;
        Err(self.err("unterminated string"))
    }

    fn number(&mut self) -> Result<Json, BenchLogError> {
        let start = self.pos;
        while matches!(self.bytes.get(self.pos), Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).map_err(|_| self.err("bad number"))?;
        let v: f64 = text.parse().map_err(|_| self.err("bad number"))?;
        if !v.is_finite() {
            return Err(self.err("non-finite number"));
        }
        Ok(Json::Num(v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_log() -> BenchLog {
        let mut log = BenchLog::new("batch_forward");
        log.push("mnist/scalar", 812.5).unwrap();
        log.push("mnist/forward_batch/B=32", 9640.0).unwrap();
        log.push("iris/forward_batch/B=8", 125000.0).unwrap();
        log
    }

    #[test]
    fn json_round_trips_bit_exactly() {
        let log = sample_log();
        let text = log.to_json();
        let back = BenchLog::from_json(&text).expect("round trip");
        assert_eq!(back, log);
        // Canonical layout is stable: re-serializing the parse is identity.
        assert_eq!(back.to_json(), text);
        // Escapes survive too.
        let mut tricky = BenchLog::new("weird");
        tricky.push("a \"quoted\"\\name\nwith tabs\t", 1.0).unwrap();
        assert_eq!(BenchLog::from_json(&tricky.to_json()).unwrap(), tricky);
    }

    #[test]
    fn push_rejects_bad_samples_with_a_typed_error() {
        let mut log = BenchLog::new("batch_forward");
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1.0, -0.001] {
            match log.push("mnist/scalar", bad) {
                Err(BenchLogError::BadSample { name, value }) => {
                    assert_eq!(name, "mnist/scalar");
                    assert!(value.is_nan() == bad.is_nan() && (value.is_nan() || value == bad));
                }
                other => panic!("push({bad}) should be BadSample, got {other:?}"),
            }
        }
        // Nothing leaked into the log, and the error renders the value.
        assert!(log.entries.is_empty());
        let msg = BenchLogError::BadSample { name: "x".into(), value: -1.0 }.to_string();
        assert!(msg.contains("x") && msg.contains("-1"), "{msg}");
        // Zero (a seed) and ordinary positives still pass.
        log.push("seed", 0.0).unwrap();
        log.push("real", 42.5).unwrap();
        assert_eq!(log.entries.len(), 2);
    }

    #[test]
    fn tolerance_field_round_trips_and_is_validated() {
        let mut log = sample_log();
        log.tolerance = Some(0.1);
        let text = log.to_json();
        assert!(text.contains("\"tolerance\": 0.1"), "{text}");
        let back = BenchLog::from_json(&text).expect("round trip");
        assert_eq!(back, log);
        assert_eq!(back.to_json(), text);
        // Files without the field (pre-stamp logs) still parse as None.
        assert_eq!(BenchLog::from_json(&sample_log().to_json()).unwrap().tolerance, None);
        // Out-of-range or non-numeric tolerances are rejected.
        for bad in ["\"tolerance\": 1.0, ", "\"tolerance\": -0.1, ", "\"tolerance\": \"x\", "] {
            let t = text.replace("\"tolerance\": 0.1,\n  ", "").replace("\"entries\"", &format!("{bad}\"entries\""));
            assert!(
                matches!(BenchLog::from_json(&t), Err(BenchLogError::Malformed(_))),
                "should reject {bad:?}: {t}"
            );
        }
    }

    #[test]
    fn schema_mismatch_is_rejected_with_a_typed_error() {
        let text = sample_log().to_json().replace("\"schema\": 1", "\"schema\": 99");
        match BenchLog::from_json(&text) {
            Err(BenchLogError::SchemaMismatch { found: 99, want }) => assert_eq!(want, SCHEMA_VERSION),
            other => panic!("expected SchemaMismatch, got {other:?}"),
        }
    }

    #[test]
    fn malformed_logs_are_rejected() {
        for bad in [
            "",
            "[]",
            "{\"schema\": 1}",
            "{\"schema\": 1, \"bench\": \"x\", \"entries\": [{}]}",
            "{\"schema\": 1, \"bench\": \"x\", \"entries\": [{\"name\": \"a\", \"samples_per_s\": -1}]}",
            "{\"schema\": 1, \"bench\": \"x\", \"entries\": [], \"extra\": 1}",
            "{\"schema\": 1.5, \"bench\": \"x\", \"entries\": []}",
            "{\"schema\": 1, \"bench\": \"x\", \"entries\": []} trailing",
        ] {
            assert!(
                matches!(BenchLog::from_json(bad), Err(BenchLogError::Malformed(_))),
                "should reject: {bad:?}"
            );
        }
    }

    #[test]
    fn comparator_passes_improvements_and_noise() {
        let baseline = sample_log();
        let mut current = BenchLog::new("batch_forward");
        current.push("mnist/scalar", 812.5 * 1.4).unwrap(); // improvement
        current.push("mnist/forward_batch/B=32", 9640.0 * 0.95).unwrap(); // within 10%
        current.push("iris/forward_batch/B=8", 125000.0).unwrap();
        current.push("mnist/forward_batch/B=64", 15000.0).unwrap(); // new, untracked
        let report = compare(&current, &baseline, DEFAULT_TOLERANCE).expect("no regression");
        assert_eq!(report.len(), 4);
        assert!(report.iter().any(|l| l.contains("untracked")), "{report:?}");
    }

    #[test]
    fn comparator_fails_a_regression_beyond_tolerance() {
        let baseline = sample_log();
        let mut current = BenchLog::new("batch_forward");
        current.push("mnist/scalar", 812.5).unwrap();
        current.push("mnist/forward_batch/B=32", 9640.0 * 0.85).unwrap(); // >10% drop
        current.push("iris/forward_batch/B=8", 125000.0).unwrap();
        let failures = compare(&current, &baseline, DEFAULT_TOLERANCE).expect_err("must fail");
        assert_eq!(failures.len(), 1);
        assert!(failures[0].contains("mnist/forward_batch/B=32"), "{failures:?}");
        // A wider tolerance lets the same drop through.
        assert!(compare(&current, &baseline, 0.20).is_ok());
    }

    #[test]
    fn comparator_fails_when_a_tracked_entry_disappears() {
        let baseline = sample_log();
        let mut current = BenchLog::new("batch_forward");
        current.push("mnist/scalar", 900.0).unwrap();
        let failures = compare(&current, &baseline, DEFAULT_TOLERANCE).expect_err("must fail");
        assert_eq!(failures.len(), 2, "{failures:?}");
        assert!(failures.iter().all(|l| l.contains("disappeared")));
    }

    #[test]
    fn seed_baselines_always_pass_and_report_arming() {
        let mut baseline = BenchLog::new("batch_forward");
        baseline.push("mnist/scalar", 0.0).unwrap();
        let mut current = BenchLog::new("batch_forward");
        current.push("mnist/scalar", 3.0).unwrap(); // any real number beats a seed
        let report = compare(&current, &baseline, DEFAULT_TOLERANCE).expect("seeds never fail");
        assert!(report[0].contains("seed baseline armed"), "{report:?}");
    }

    fn one_entry(sps: f64) -> BenchLog {
        let mut log = BenchLog::new("unit");
        log.push("synth/x", sps).unwrap();
        log
    }

    #[test]
    fn gate_never_resamples_a_clean_first_measurement() {
        let baseline = one_entry(100.0);
        let mut calls = 0;
        let (best, verdict) = gate_best_of(
            one_entry(95.0),
            &baseline,
            &mut || {
                calls += 1;
                one_entry(1000.0)
            },
            DEFAULT_TOLERANCE,
        );
        assert!(verdict.is_ok());
        assert_eq!(calls, 0, "a passing first sample must not pay for re-measurement");
        assert_eq!(best.entry("synth/x").unwrap().samples_per_s, 95.0);
    }

    #[test]
    fn gate_lets_one_good_sample_rescue_a_noisy_first_one() {
        let baseline = one_entry(100.0);
        let mut calls = 0;
        let (best, verdict) = gate_best_of(
            one_entry(80.0), // 20% below: would fail on its own
            &baseline,
            &mut || {
                calls += 1;
                one_entry(105.0)
            },
            DEFAULT_TOLERANCE,
        );
        assert!(verdict.is_ok(), "{verdict:?}");
        assert_eq!(calls, 1, "the gate stops sampling as soon as the best-of passes");
        // The persisted baseline carries the best observation, not the blip.
        assert_eq!(best.entry("synth/x").unwrap().samples_per_s, 105.0);
    }

    #[test]
    fn gate_fails_a_consistent_regression_after_all_samples() {
        let baseline = one_entry(100.0);
        let mut calls = 0;
        let (best, verdict) = gate_best_of(
            one_entry(80.0),
            &baseline,
            &mut || {
                calls += 1;
                one_entry(78.0)
            },
            DEFAULT_TOLERANCE,
        );
        assert!(verdict.is_err(), "a real regression is slow every time and must still fail");
        assert_eq!(calls, GATE_SAMPLES - 1, "the whole sample budget is spent before giving up");
        assert_eq!(best.entry("synth/x").unwrap().samples_per_s, 80.0, "best-of keeps the max");
    }

    #[test]
    fn merge_best_keeps_per_entry_maxima_and_appends_new_entries() {
        let mut best = sample_log();
        let mut sample = BenchLog::new("batch_forward");
        sample.push("mnist/scalar", 900.0).unwrap(); // better
        sample.push("mnist/forward_batch/B=32", 9000.0).unwrap(); // worse
        sample.push("mnist/forward_batch/B=64", 15000.0).unwrap(); // new
        merge_best(&mut best, sample);
        assert_eq!(best.entry("mnist/scalar").unwrap().samples_per_s, 900.0);
        assert_eq!(best.entry("mnist/forward_batch/B=32").unwrap().samples_per_s, 9640.0);
        assert_eq!(best.entry("mnist/forward_batch/B=64").unwrap().samples_per_s, 15000.0);
        assert_eq!(best.entries.len(), 4);
    }

    #[test]
    fn load_save_round_trip_and_missing_file_soft_path() {
        let dir = std::env::temp_dir().join(format!("bench_log_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_unit.json");
        // Missing baseline: Ok(None), the record_and_gate soft-pass arm.
        assert_eq!(BenchLog::load_from(&path), Ok(None));
        let log = sample_log();
        log.save_to(&path).unwrap();
        assert_eq!(BenchLog::load_from(&path), Ok(Some(log)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_budget_scales_or_defaults() {
        // No env manipulation here (tests run in parallel); just the default
        // path and the numeric guard.
        let scale = std::env::var("BENCH_BUDGET").ok().and_then(|v| v.parse::<f64>().ok()).unwrap_or(1.0);
        let scale = if scale.is_finite() && scale > 0.0 { scale } else { 1.0 };
        assert_eq!(bench_budget(0.4), 0.4 * scale);
    }

    #[test]
    fn repo_root_path_shape() {
        let p = BenchLog::path("batch_forward");
        assert!(p.ends_with("BENCH_batch_forward.json"), "{}", p.display());
    }
}
