//! Deterministic xoshiro256** RNG.
//!
//! Every stochastic component in the repository (dataset synthesis, weight
//! init, property tests, workload generators) draws from this generator with
//! an explicit seed, so all experiments are bit-reproducible run-to-run —
//! a requirement for comparing quantization sweeps apples-to-apples.

/// xoshiro256** by Blackman & Vigna (public domain reference constants).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion (never yields the all-zero state).
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style multiply-shift; bias negligible for our n.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value).
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u = self.f64();
            let v = self.f64();
            if u > 1e-300 {
                let r = (-2.0 * u.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Laplace(0, b) — the sharply-peaked, heavy-tailed shape of trained DNN
    /// weight tensors (paper Fig. 1b).
    pub fn laplace(&mut self, b: f64) -> f64 {
        let u = self.f64() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).max(1e-300).ln()
    }

    /// Bernoulli with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A derived, independent stream (for parallel/per-component seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(42);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(42);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
