//! A tiny property-testing driver (the offline registry has no `proptest`;
//! DESIGN.md §Substitutions). Properties run against many seeded random
//! cases; on failure the driver re-reports the failing seed so the case can
//! be replayed deterministically.

use super::rng::Rng;

/// Number of cases per property (override with env `PROP_CASES`).
pub fn cases() -> u64 {
    std::env::var("PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(256)
}

/// Run `property` against `cases()` independently-seeded RNGs. Panics with
/// the seed of the first failing case.
pub fn forall(name: &str, mut property: impl FnMut(&mut Rng)) {
    for case in 0..cases() {
        let seed = 0xDEEB_0516_u64.wrapping_mul(case + 1) ^ case;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| property(&mut rng)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Draw a "interesting" f64: mixes uniform ranges, exact format-scale
/// dyadics, zeros, and extremes — the corners quantizers get wrong.
pub fn arb_f64(rng: &mut Rng) -> f64 {
    match rng.below(8) {
        0 => 0.0,
        1 => rng.range(-1.0, 1.0),
        2 => rng.range(-300.0, 300.0),
        3 => rng.range(-1e6, 1e6),
        4 => {
            // exact dyadic m × 2^e, the tie-prone inputs
            let m = rng.below(512) as f64 - 256.0;
            let e = rng.below(24) as i32 - 12;
            m * crate::formats::exact::pow2(e)
        }
        5 => rng.range(-1e-6, 1e-6),
        6 => if rng.chance(0.5) { 1e30 } else { -1e30 },
        _ => rng.gaussian(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("u64 is u64", |rng| {
            let _ = rng.next_u64();
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_reports_failures() {
        forall("always fails", |_| panic!("boom"));
    }

    #[test]
    fn arb_f64_is_finite() {
        forall("arb f64 finite", |rng| {
            assert!(arb_f64(rng).is_finite());
        });
    }
}
