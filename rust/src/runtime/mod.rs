//! PJRT runtime: loads the AOT'd HLO-text artifacts and executes them on the
//! request path. Python never runs here — the artifacts in `artifacts/` are
//! produced once by `make artifacts` (python/compile/aot.py) and this module
//! is the only bridge (per /opt/xla-example/load_hlo: HLO text →
//! `HloModuleProto::from_text_file` → compile → execute).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::formats::{FormatSpec, Quantizer};

/// Artifact kinds emitted by aot.py.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Kind {
    /// Quantized inference (table-driven datapath).
    QInfer,
    /// f32 baseline inference.
    F32Infer,
    /// One SGD-momentum training step.
    Train,
}

impl Kind {
    fn parse(s: &str) -> Result<Kind> {
        Ok(match s {
            "q_infer" => Kind::QInfer,
            "f32_infer" => Kind::F32Infer,
            "train" => Kind::Train,
            _ => bail!("unknown artifact kind {s}"),
        })
    }
}

/// One manifest entry.
#[derive(Debug, Clone)]
pub struct Artifact {
    /// Artifact kind.
    pub kind: Kind,
    /// Dataset (topology) the artifact was lowered for.
    pub dataset: String,
    /// Compiled batch size.
    pub batch: usize,
    /// Full layer dims, input..output.
    pub dims: Vec<usize>,
    /// HLO text file path.
    pub file: PathBuf,
}

/// Parse `artifacts/manifest.txt`.
pub fn parse_manifest(dir: &Path) -> Result<Vec<Artifact>> {
    let text = std::fs::read_to_string(dir.join("manifest.txt"))
        .with_context(|| format!("missing manifest in {dir:?}; run `make artifacts`"))?;
    let mut out = Vec::new();
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let mut kind = None;
        let mut dataset = None;
        let mut batch = None;
        let mut dims = None;
        let mut file = None;
        for tok in line.split_whitespace() {
            let (k, v) = tok.split_once('=').ok_or_else(|| anyhow!("bad manifest token {tok}"))?;
            match k {
                "kind" => kind = Some(Kind::parse(v)?),
                "dataset" => dataset = Some(v.to_string()),
                "batch" => batch = Some(v.parse::<usize>()?),
                "dims" => dims = Some(v.split('-').map(|d| d.parse::<usize>()).collect::<Result<Vec<_>, _>>()?),
                "file" => file = Some(dir.join(v)),
                _ => bail!("unknown manifest key {k}"),
            }
        }
        out.push(Artifact {
            kind: kind.ok_or_else(|| anyhow!("manifest line missing kind: {line}"))?,
            dataset: dataset.ok_or_else(|| anyhow!("missing dataset"))?,
            batch: batch.ok_or_else(|| anyhow!("missing batch"))?,
            dims: dims.ok_or_else(|| anyhow!("missing dims"))?,
            file: file.ok_or_else(|| anyhow!("missing file"))?,
        });
    }
    Ok(out)
}

/// Table capacity baked into the artifacts (quantize_lut.TABLE).
pub const TABLE: usize = 256;

/// The PJRT runtime: one CPU client + lazily compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: Vec<Artifact>,
    cache: Mutex<HashMap<(Kind, String, usize), usize>>, // -> slot in exes
    exes: Mutex<Vec<(usize, xla::PjRtLoadedExecutable)>>, // (artifact idx, exe)
}

impl Runtime {
    /// Create a runtime over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        let artifacts = parse_manifest(artifacts_dir)?;
        Ok(Runtime { client, artifacts, cache: Mutex::new(HashMap::new()), exes: Mutex::new(Vec::new()) })
    }

    /// The parsed artifact manifest.
    pub fn artifacts(&self) -> &[Artifact] {
        &self.artifacts
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn find(&self, kind: Kind, dataset: &str, batch: usize) -> Result<usize> {
        self.artifacts
            .iter()
            .position(|a| a.kind == kind && a.dataset == dataset && a.batch == batch)
            .ok_or_else(|| {
                anyhow!("no artifact kind={kind:?} dataset={dataset} batch={batch}; re-run `make artifacts`")
            })
    }

    /// Batch sizes available for a (kind, dataset), ascending and deduped.
    ///
    /// Callers depend on the order: the serve workers pick the smallest
    /// compiled batch ≥ the flushed rows with a linear `find`, and
    /// `eval_xla` takes `.last()` as the maximum — an unsorted manifest
    /// must never make them pick an undersized executable.
    pub fn batches(&self, kind: Kind, dataset: &str) -> Vec<usize> {
        sorted_batches(&self.artifacts, kind, dataset)
    }

    /// Compile (or fetch from cache) an executable; returns its slot.
    fn executable(&self, kind: Kind, dataset: &str, batch: usize) -> Result<(usize, usize)> {
        let key = (kind, dataset.to_string(), batch);
        if let Some(&slot) = self.cache.lock().unwrap().get(&key) {
            let idx = self.exes.lock().unwrap()[slot].0;
            return Ok((slot, idx));
        }
        let idx = self.find(kind, dataset, batch)?;
        let a = &self.artifacts[idx];
        let proto = xla::HloModuleProto::from_text_file(&a.file).map_err(|e| anyhow!("parse {:?}: {e}", a.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = comp.compile(&self.client).map_err(|e| anyhow!("compile {:?}: {e}", a.file))?;
        let mut exes = self.exes.lock().unwrap();
        exes.push((idx, exe));
        let slot = exes.len() - 1;
        self.cache.lock().unwrap().insert(key, slot);
        Ok((slot, idx))
    }

    fn run(&self, slot: usize, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exes = self.exes.lock().unwrap();
        let (_, exe) = &exes[slot];
        let result = exe.execute::<xla::Literal>(args).map_err(|e| anyhow!("execute: {e}"))?;
        let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("fetch: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e}"))
    }

    /// Build the quantized-inference handle for one dataset topology.
    pub fn quantized_infer(&self, dataset: &str, batch: usize) -> Result<QInfer<'_>> {
        let (slot, idx) = self.executable(Kind::QInfer, dataset, batch)?;
        let a = &self.artifacts[idx];
        Ok(QInfer { rt: self, slot, dims: a.dims.clone(), batch })
    }

    /// Build the f32 baseline-inference handle for one dataset topology.
    pub fn f32_infer(&self, dataset: &str, batch: usize) -> Result<F32Infer<'_>> {
        let (slot, idx) = self.executable(Kind::F32Infer, dataset, batch)?;
        let a = &self.artifacts[idx];
        Ok(F32Infer { rt: self, slot, dims: a.dims.clone(), batch })
    }

    /// Build the train-step handle for one dataset topology.
    pub fn train_step(&self, dataset: &str) -> Result<TrainStep<'_>> {
        let batch = *self
            .batches(Kind::Train, dataset)
            .first()
            .ok_or_else(|| anyhow!("no train artifact for {dataset}"))?;
        let (slot, idx) = self.executable(Kind::Train, dataset, batch)?;
        let a = &self.artifacts[idx];
        Ok(TrainStep { rt: self, slot, dims: a.dims.clone(), batch })
    }
}

/// Ascending, deduped batch sizes for a (kind, dataset) out of an artifact
/// list in arbitrary manifest order.
fn sorted_batches(artifacts: &[Artifact], kind: Kind, dataset: &str) -> Vec<usize> {
    let mut b: Vec<usize> =
        artifacts.iter().filter(|a| a.kind == kind && a.dataset == dataset).map(|a| a.batch).collect();
    b.sort_unstable();
    b.dedup();
    b
}

/// f64 tensor literal from a flat slice.
pub fn lit_f64(data: &[f64], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    assert_eq!(data.len(), n, "literal size mismatch");
    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
    xla::Literal::vec1(data).reshape(&d).map_err(|e| anyhow!("reshape: {e}"))
}

/// f32 tensor literal from a flat f64 slice (converted).
pub fn lit_f32(data: &[f64], dims: &[usize]) -> Result<xla::Literal> {
    let v: Vec<f32> = data.iter().map(|&x| x as f32).collect();
    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
    xla::Literal::vec1(&v).reshape(&d).map_err(|e| anyhow!("reshape: {e}"))
}

/// The per-format tables in the artifact's layout.
#[derive(Debug, Clone)]
pub struct FormatTables {
    /// Sorted format values, padded to [`TABLE`].
    pub values: Vec<f64>,
    /// Round-to-nearest boundaries, padded with `+inf`.
    pub bounds: Vec<f64>,
    /// Tie directions as 0.0/1.0, padded with 0.
    pub ties: Vec<f64>,
    /// `[is_posit, min_pos]` — the artifact's scalar format flags.
    pub flags: [f64; 2],
}

impl FormatTables {
    /// Build from a quantizer (pads to the artifact's 256-entry layout).
    pub fn new(spec: FormatSpec, q: &Quantizer) -> FormatTables {
        let (values, mut bounds, mut ties) = q.padded_tables(TABLE);
        // quantize_lut expects TABLE-length bounds/ties (padded +inf / 0).
        bounds.resize(TABLE, f64::INFINITY);
        ties.resize(TABLE, 0.0);
        let is_posit = matches!(spec, FormatSpec::Posit { .. });
        FormatTables { values, bounds, ties, flags: [if is_posit { 1.0 } else { 0.0 }, q.min_pos()] }
    }
}

/// Quantized-inference executable bound to (dataset topology, batch).
pub struct QInfer<'r> {
    rt: &'r Runtime,
    slot: usize,
    dims: Vec<usize>,
    batch: usize,
}

impl<'r> QInfer<'r> {
    /// Compiled batch size.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Layer dims, input..output.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Run up to `batch` rows (padded internally). `weights[i]` is the
    /// dequantized (in × out) matrix (python layout), `biases[i]` the
    /// dequantized bias. Returns `rows × classes` logits.
    pub fn run(
        &self,
        x: &[f64],
        rows: usize,
        weights: &[Vec<f64>],
        biases: &[Vec<f64>],
        tables: &FormatTables,
    ) -> Result<Vec<f64>> {
        let in_dim = self.dims[0];
        let out_dim = *self.dims.last().unwrap();
        assert!(rows <= self.batch && x.len() == rows * in_dim);
        let mut xp = x.to_vec();
        xp.resize(self.batch * in_dim, 0.0);
        let mut args = Vec::with_capacity(5 + 2 * weights.len());
        args.push(lit_f64(&xp, &[self.batch, in_dim])?);
        for (i, (w, b)) in weights.iter().zip(biases).enumerate() {
            args.push(lit_f64(w, &[self.dims[i], self.dims[i + 1]])?);
            args.push(lit_f64(b, &[self.dims[i + 1]])?);
        }
        args.push(lit_f64(&tables.values, &[TABLE])?);
        args.push(lit_f64(&tables.bounds, &[TABLE])?);
        args.push(lit_f64(&tables.ties, &[TABLE])?);
        args.push(lit_f64(&tables.flags, &[2])?);
        let out = self.rt.run(self.slot, &args)?;
        let logits: Vec<f64> = out[0].to_vec().map_err(|e| anyhow!("logits: {e}"))?;
        Ok(logits[..rows * out_dim].to_vec())
    }
}

/// f32 baseline inference executable.
pub struct F32Infer<'r> {
    rt: &'r Runtime,
    slot: usize,
    dims: Vec<usize>,
    batch: usize,
}

impl<'r> F32Infer<'r> {
    /// Compiled batch size.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Run up to `batch` rows (padded internally); returns `rows × classes`
    /// logits.
    pub fn run(&self, x: &[f64], rows: usize, weights: &[Vec<f64>], biases: &[Vec<f64>]) -> Result<Vec<f64>> {
        let in_dim = self.dims[0];
        let out_dim = *self.dims.last().unwrap();
        assert!(rows <= self.batch && x.len() == rows * in_dim);
        let mut xp = x.to_vec();
        xp.resize(self.batch * in_dim, 0.0);
        let mut args = Vec::new();
        args.push(lit_f32(&xp, &[self.batch, in_dim])?);
        for (i, (w, b)) in weights.iter().zip(biases).enumerate() {
            args.push(lit_f32(w, &[self.dims[i], self.dims[i + 1]])?);
            args.push(lit_f32(b, &[self.dims[i + 1]])?);
        }
        let out = self.rt.run(self.slot, &args)?;
        let logits: Vec<f32> = out[0].to_vec().map_err(|e| anyhow!("logits: {e}"))?;
        Ok(logits[..rows * out_dim].iter().map(|&v| v as f64).collect())
    }
}

/// Training-step executable.
pub struct TrainStep<'r> {
    rt: &'r Runtime,
    slot: usize,
    dims: Vec<usize>,
    batch: usize,
}

/// Flattened f32 training state (params + velocities), host side. Weight
/// matrices use the python (in × out) layout.
#[derive(Debug, Clone)]
pub struct TrainState {
    /// Layer dims, input..output.
    pub dims: Vec<usize>,
    /// w1, b1, w2, b2, ...
    pub params: Vec<Vec<f64>>,
    /// Momentum velocities, same layout as `params`.
    pub vels: Vec<Vec<f64>>,
}

impl TrainState {
    /// He-initialized state for a topology.
    pub fn init(dims: &[usize], seed: u64) -> TrainState {
        let mut rng = crate::util::Rng::new(seed);
        let mut params = Vec::new();
        let mut vels = Vec::new();
        for win in dims.windows(2) {
            let (i, o) = (win[0], win[1]);
            let std = (2.0 / i as f64).sqrt();
            params.push((0..i * o).map(|_| rng.normal(0.0, std)).collect());
            params.push(vec![0.0; o]);
            vels.push(vec![0.0; i * o]);
            vels.push(vec![0.0; o]);
        }
        TrainState { dims: dims.to_vec(), params, vels }
    }

    /// Convert to the accelerator's Mlp (f64, row-major (out, in) weights).
    pub fn to_mlp(&self) -> crate::accel::Mlp {
        let mut layers = Vec::new();
        for (li, win) in self.dims.windows(2).enumerate() {
            let (i, o) = (win[0], win[1]);
            let wio = &self.params[2 * li];
            let mut w = vec![0.0; i * o];
            for r in 0..i {
                for c in 0..o {
                    w[c * i + r] = wio[r * o + c];
                }
            }
            layers.push(crate::accel::mlp::Layer::dense_with(i, o, w, self.params[2 * li + 1].clone()));
        }
        crate::accel::Mlp { layers }
    }

    /// Build from an accelerator Mlp (transposes back to python layout).
    pub fn from_mlp(mlp: &crate::accel::Mlp) -> TrainState {
        let dims = mlp.dims();
        let mut params = Vec::new();
        let mut vels = Vec::new();
        for l in &mlp.layers {
            let mut w = vec![0.0; l.in_dim * l.out_dim];
            for o in 0..l.out_dim {
                for i in 0..l.in_dim {
                    w[i * l.out_dim + o] = l.w[o * l.in_dim + i];
                }
            }
            params.push(w);
            params.push(l.b.clone());
            vels.push(vec![0.0; l.in_dim * l.out_dim]);
            vels.push(vec![0.0; l.out_dim]);
        }
        TrainState { dims, params, vels }
    }
}

impl<'r> TrainStep<'r> {
    /// Compiled (exact) training batch size.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Layer dims, input..output.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// One SGD-momentum step; updates `state` in place, returns the loss.
    pub fn step(&self, state: &mut TrainState, x: &[f64], y_onehot: &[f64], lr: f64, momentum: f64) -> Result<f64> {
        let in_dim = self.dims[0];
        let classes = *self.dims.last().unwrap();
        assert_eq!(x.len(), self.batch * in_dim, "train batch must be exactly {}", self.batch);
        assert_eq!(y_onehot.len(), self.batch * classes);
        let mut args = Vec::new();
        args.push(lit_f32(x, &[self.batch, in_dim])?);
        args.push(lit_f32(y_onehot, &[self.batch, classes])?);
        args.push(xla::Literal::scalar(lr as f32));
        args.push(xla::Literal::scalar(momentum as f32));
        for (li, win) in self.dims.windows(2).enumerate() {
            args.push(lit_f32(&state.params[2 * li], &[win[0], win[1]])?);
            args.push(lit_f32(&state.params[2 * li + 1], &[win[1]])?);
        }
        for (li, win) in self.dims.windows(2).enumerate() {
            args.push(lit_f32(&state.vels[2 * li], &[win[0], win[1]])?);
            args.push(lit_f32(&state.vels[2 * li + 1], &[win[1]])?);
        }
        let out = self.rt.run(self.slot, &args)?;
        let loss: f32 = out[0].to_vec::<f32>().map_err(|e| anyhow!("loss: {e}"))?[0];
        let np = state.params.len();
        for i in 0..np {
            state.params[i] =
                out[1 + i].to_vec::<f32>().map_err(|e| anyhow!("param {i}: {e}"))?.iter().map(|&v| v as f64).collect();
        }
        for i in 0..np {
            state.vels[i] = out[1 + np + i]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("vel {i}: {e}"))?
                .iter()
                .map(|&v| v as f64)
                .collect();
        }
        Ok(loss as f64)
    }
}

/// Default artifacts directory: $REPRO_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("REPRO_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join("dp_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "kind=q_infer dataset=iris batch=64 dims=4-10-8-3 file=q_infer_iris_b64.hlo.txt\n\
             kind=train dataset=iris batch=128 dims=4-10-8-3 file=train_iris_b128.hlo.txt\n",
        )
        .unwrap();
        let arts = parse_manifest(&dir).unwrap();
        assert_eq!(arts.len(), 2);
        assert_eq!(arts[0].kind, Kind::QInfer);
        assert_eq!(arts[0].dims, vec![4, 10, 8, 3]);
        assert_eq!(arts[1].batch, 128);
    }

    #[test]
    fn batches_sort_and_dedup_an_unordered_manifest() {
        // Manifests are hand-editable text; the batch-size list must come
        // back ascending and unique no matter the on-disk line order, or
        // the serve workers' `find(|s| s >= rows)` picks an undersized
        // executable and `eval_xla`'s `.last()` is not the max.
        let mk = |kind, dataset: &str, batch| Artifact {
            kind,
            dataset: dataset.to_string(),
            batch,
            dims: vec![4, 3],
            file: PathBuf::from("x.hlo.txt"),
        };
        let arts = vec![
            mk(Kind::QInfer, "iris", 64),
            mk(Kind::QInfer, "iris", 1),
            mk(Kind::Train, "iris", 128),
            mk(Kind::QInfer, "iris", 16),
            mk(Kind::QInfer, "mnist", 8),
            mk(Kind::QInfer, "iris", 16), // duplicate entry
        ];
        assert_eq!(sorted_batches(&arts, Kind::QInfer, "iris"), vec![1, 16, 64]);
        assert_eq!(sorted_batches(&arts, Kind::QInfer, "mnist"), vec![8]);
        assert_eq!(sorted_batches(&arts, Kind::Train, "mnist"), Vec::<usize>::new());
    }

    #[test]
    fn train_state_roundtrip_to_mlp() {
        let st = TrainState::init(&[4, 3, 2], 1);
        assert_eq!(st.params.len(), 4);
        assert_eq!(st.params[0].len(), 12);
        let mlp = st.to_mlp();
        assert_eq!(mlp.dims(), vec![4, 3, 2]);
        // Transposition check: python w[r=1,c=0] == accel w[o=0][i=1].
        assert_eq!(st.params[0][1 * 3 + 0], mlp.layers[0].w[0 * 4 + 1]);
        // And back.
        let st2 = TrainState::from_mlp(&mlp);
        assert_eq!(st.params[0], st2.params[0]);
        assert_eq!(st.params[1], st2.params[1]);
    }

    #[test]
    fn format_tables_layout() {
        let spec = FormatSpec::parse("posit8es1").unwrap();
        let q = Quantizer::new(spec.build().as_ref());
        let t = FormatTables::new(spec, &q);
        assert_eq!(t.values.len(), TABLE);
        assert_eq!(t.bounds.len(), TABLE);
        assert_eq!(t.ties.len(), TABLE);
        assert_eq!(t.flags[0], 1.0);
        assert!(t.bounds[TABLE - 1].is_infinite());
    }
}
