//! Quantization-error analysis (paper Eq. 3, Figs. 1b and 5).
//!
//! Computes per-tensor and layer-wise mean-squared quantization error of
//! trained network parameters under every format, the "best sub-parameter"
//! selection the paper applies (sweeping es / w_e / Q at each bit-width),
//! and the Fig. 5 difference heatmaps (MSE_posit − MSE_fixed,
//! MSE_posit − MSE_float).

use std::collections::HashMap;

use crate::formats::{FormatSpec, Quantizer};

/// MSE of quantizing `xs` under `spec` (Eq. 3).
pub fn mse(spec: FormatSpec, xs: &[f64]) -> f64 {
    let fmt = spec.build();
    Quantizer::new(fmt.as_ref()).mse(xs)
}

/// Best (lowest-MSE) sub-parameter config of `family` at bit-width `n` for
/// the tensor `xs`. Returns (spec, mse).
pub fn best_config(family: &str, n: u32, xs: &[f64]) -> (FormatSpec, f64) {
    FormatSpec::sweep_family(n, family)
        .into_iter()
        .map(|s| (s, mse(s, xs)))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .expect("empty sweep")
}

/// A named parameter tensor (layer weights or biases).
#[derive(Debug, Clone)]
pub struct NamedTensor {
    /// Tensor name (Fig. 5 row label, e.g. `dense1`).
    pub name: String,
    /// Flattened parameter values.
    pub data: Vec<f64>,
}

/// One cell of the Fig. 5 heatmap: layer × bit-width.
#[derive(Debug, Clone)]
pub struct HeatCell {
    /// Layer (row) label.
    pub layer: String,
    /// Bit-width (column).
    pub n: u32,
    /// Best-of-sweep posit MSE.
    pub mse_posit: f64,
    /// Best-of-sweep float MSE.
    pub mse_float: f64,
    /// Best-of-sweep fixed MSE.
    pub mse_fixed: f64,
    /// The posit config achieving `mse_posit`.
    pub best_posit: FormatSpec,
    /// The float config achieving `mse_float`.
    pub best_float: FormatSpec,
    /// The fixed config achieving `mse_fixed`.
    pub best_fixed: FormatSpec,
}

impl HeatCell {
    /// Fig. 5 (a)/(c): MSE_posit − MSE_fixed.
    pub fn posit_minus_fixed(&self) -> f64 {
        self.mse_posit - self.mse_fixed
    }

    /// Fig. 5 (b)/(d): MSE_posit − MSE_float.
    pub fn posit_minus_float(&self) -> f64 {
        self.mse_posit - self.mse_float
    }
}

/// Layer-wise best-of-sweep quantization-error heatmap over bit-widths
/// `ns` — the data behind one Fig. 5 panel pair. The paper's last column
/// ("avg") aggregates all parameters of the network; pass the concatenated
/// tensor as the final entry to reproduce it.
pub fn heatmap(tensors: &[NamedTensor], ns: &[u32]) -> Vec<HeatCell> {
    let mut cells = Vec::new();
    for t in tensors {
        for &n in ns {
            let (bp, mp) = best_config("posit", n, &t.data);
            let (bf, mf) = best_config("float", n, &t.data);
            let (bx, mx) = best_config("fixed", n, &t.data);
            cells.push(HeatCell {
                layer: t.name.clone(),
                n,
                mse_posit: mp,
                mse_float: mf,
                mse_fixed: mx,
                best_posit: bp,
                best_float: bf,
                best_fixed: bx,
            });
        }
    }
    cells
}

/// Render a Fig. 5-style markdown table: rows = bit-widths, cols = layers,
/// values = the selected difference. Cells are indexed by `(layer, n)` once
/// up front (a full-scale MNIST grid made the old per-cell linear scan
/// quadratic in the cell count); duplicate keys keep the last cell.
pub fn render_heatmap(cells: &[HeatCell], ns: &[u32], diff: impl Fn(&HeatCell) -> f64, title: &str) -> String {
    let mut layers: Vec<&str> = Vec::new();
    let mut index: HashMap<(&str, u32), &HeatCell> = HashMap::with_capacity(cells.len());
    for c in cells {
        if !layers.contains(&c.layer.as_str()) {
            layers.push(&c.layer);
        }
        index.insert((c.layer.as_str(), c.n), c);
    }
    let mut s = format!("### {title}\n\n| bits | ");
    s.push_str(&layers.join(" | "));
    s.push_str(" |\n|---|");
    s.push_str(&"---|".repeat(layers.len()));
    s.push('\n');
    for &n in ns {
        s.push_str(&format!("| {n} | "));
        for &l in &layers {
            let cell = index.get(&(l, n)).copied().unwrap_or_else(|| panic!("heatmap missing cell ({l}, {n})"));
            s.push_str(&format!("{:+.2e} | ", diff(cell)));
        }
        s.push('\n');
    }
    s
}

/// Fig. 1a: the value distribution of a format (sorted values + a histogram
/// of their density across magnitude buckets in [-range, range]).
pub fn value_distribution(spec: FormatSpec, range: f64, bins: usize) -> Vec<usize> {
    let fmt = spec.build();
    let q = Quantizer::new(fmt.as_ref());
    crate::util::stats::histogram(q.values(), -range, range, bins)
}

/// Fig. 1b: histogram of parameters overlaid with per-bucket squared
/// quantization error. Returns (param histogram, per-bucket total sq-error).
pub fn param_error_profile(spec: FormatSpec, xs: &[f64], range: f64, bins: usize) -> (Vec<usize>, Vec<f64>) {
    let fmt = spec.build();
    let q = Quantizer::new(fmt.as_ref());
    let hist = crate::util::stats::histogram(xs, -range, range, bins);
    let mut err = vec![0.0; bins];
    let w = 2.0 * range / bins as f64;
    for &x in xs {
        if x < -range || x > range {
            continue;
        }
        let b = (((x + range) / w) as usize).min(bins - 1);
        let (_, v) = q.quantize_f64(x);
        err[b] += (x - v) * (x - v);
    }
    (hist, err)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn gaussian_tensor(n: usize, std: f64) -> Vec<f64> {
        let mut rng = Rng::new(17);
        (0..n).map(|_| rng.normal(0.0, std)).collect()
    }

    /// Trained DNN weights are sharply peaked at zero with heavy tails
    /// (paper Fig. 1b) — Laplace is the standard model for them.
    fn weight_like_tensor(n: usize) -> Vec<f64> {
        let mut rng = Rng::new(23);
        (0..n).map(|_| rng.laplace(0.15)).collect()
    }

    #[test]
    fn posit_beats_fixed_on_dnn_like_weights() {
        // The paper's core Fig. 5 claim: for weight-like (zero-peaked,
        // heavy-tailed) tensors, posit quantizes with less MSE than
        // fixed-point at every [5,8] width.
        let xs = weight_like_tensor(4000);
        for n in 5..=8 {
            let (_, mp) = best_config("posit", n, &xs);
            let (_, mx) = best_config("fixed", n, &xs);
            assert!(mp < mx, "n={n}: posit {mp} !< fixed {mx}");
        }
    }

    #[test]
    fn posit_at_least_matches_float_at_low_bits() {
        let xs = weight_like_tensor(4000);
        for n in 5..=8 {
            let (_, mp) = best_config("posit", n, &xs);
            let (_, mf) = best_config("float", n, &xs);
            assert!(mp <= mf * 1.05, "n={n}: posit {mp} vs float {mf}");
        }
    }

    #[test]
    fn mse_decreases_with_bits() {
        let xs = gaussian_tensor(2000, 0.5);
        for family in ["posit", "float", "fixed"] {
            let mut prev = f64::INFINITY;
            for n in 5..=8 {
                let (_, m) = best_config(family, n, &xs);
                assert!(m < prev, "{family} MSE not decreasing at n={n}");
                prev = m;
            }
        }
    }

    #[test]
    fn best_config_picks_minimum() {
        let xs = gaussian_tensor(500, 0.3);
        let (best, m) = best_config("fixed", 8, &xs);
        for s in FormatSpec::sweep_family(8, "fixed") {
            assert!(mse(s, &xs) >= m, "{s} better than reported best {best}");
        }
    }

    #[test]
    fn heatmap_covers_layers_and_bits() {
        let tensors = vec![
            NamedTensor { name: "dense1".into(), data: gaussian_tensor(300, 0.4) },
            NamedTensor { name: "dense2".into(), data: gaussian_tensor(300, 0.6) },
        ];
        let ns = [5, 6, 7, 8];
        let cells = heatmap(&tensors, &ns);
        assert_eq!(cells.len(), 8);
        let rendered = render_heatmap(&cells, &ns, HeatCell::posit_minus_fixed, "MSE_posit − MSE_fixed");
        assert!(rendered.contains("dense1") && rendered.contains("| 5 |"));
    }

    #[test]
    fn posit8_es0_density_peaks_near_zero() {
        // Fig. 1a: the posit8(es=0) value distribution is densest in
        // [-0.5, 0.5]... actually densest around ±[0.25,1]; the histogram
        // over [-8,8] must peak in the central bins.
        let h = value_distribution(FormatSpec::Posit { n: 8, es: 0 }, 8.0, 16);
        let center: usize = h[7] + h[8];
        let edge: usize = h[0] + h[15];
        assert!(center > 8 * edge, "posit density not tapered: center {center}, edge {edge}");
    }

    #[test]
    fn param_error_profile_shapes() {
        let xs = gaussian_tensor(1000, 0.4);
        let (h, e) = param_error_profile(FormatSpec::Posit { n: 8, es: 0 }, &xs, 2.0, 20);
        assert_eq!(h.len(), 20);
        assert_eq!(e.len(), 20);
        assert!(e.iter().all(|&x| x >= 0.0));
    }
}
