//! Single-shard serving facade — the original batched inference server's
//! API, now a thin wrapper over the sharded multi-worker engine in
//! [`crate::serve`].
//!
//! [`serve`] stands up a [`ServeEngine`](crate::serve::ServeEngine) with one
//! (dataset, format) shard and one worker: exactly the old behaviour
//! (deadline-based dynamic batching on a dedicated engine-owning thread),
//! same metrics, same blocking warm-up — plus the engine's bounded
//! admission: [`ServerHandle::submit`] now returns a `Result` and sheds
//! with [`ServeError::Overloaded`] instead of queueing without limit when
//! the worker is [`ServeConfig::max_queue`] deep. New code that wants
//! format sharding, worker pools, or affinity routing should use
//! [`crate::serve`] directly.

use std::sync::mpsc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::accel::Mlp;
use crate::coordinator::experiments::Engine;
use crate::datasets::Dataset;
use crate::formats::FormatSpec;
use crate::serve::{ServeEngine, ServeError, ShardConfig, ShardKey, WorkerConfig};

pub use crate::serve::metrics::ShardMetrics as ServeMetrics;
pub use crate::serve::worker::InferReply;

/// Server configuration (single shard, single worker).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Preferred engine (falls back to Sim when PJRT/artifacts are missing).
    pub engine: Engine,
    /// Numeric format the model is quantized to.
    pub spec: FormatSpec,
    /// Max time the batcher waits to fill a batch, anchored to the oldest
    /// pending request.
    pub max_batch_wait: Duration,
    /// Admission bound: submissions beyond this queue depth shed with
    /// [`ServeError::Overloaded`] (see
    /// [`WorkerConfig::max_queue`](crate::serve::WorkerConfig::max_queue)).
    pub max_queue: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            engine: Engine::Sim,
            spec: FormatSpec::Posit { n: 8, es: 1 },
            max_batch_wait: Duration::from_millis(2),
            max_queue: WorkerConfig::default().max_queue,
        }
    }
}

/// Client handle to a running single-shard server.
pub struct ServerHandle {
    engine: ServeEngine,
    key: ShardKey,
}

impl ServerHandle {
    /// Submit one feature vector; returns the reply receiver, or a typed
    /// error ([`ServeError::Overloaded`] when the worker queue is full,
    /// [`ServeError::BadRequest`] on a dimension mismatch).
    pub fn submit(&self, x: Vec<f64>) -> std::result::Result<mpsc::Receiver<InferReply>, ServeError> {
        self.engine.submit(&self.key, x)
    }

    /// Submit with a latency budget: if still queued once `budget` elapses,
    /// the request is dropped uncomputed and the receiver's `recv` errors.
    pub fn submit_with_deadline(
        &self,
        x: Vec<f64>,
        budget: Duration,
    ) -> std::result::Result<mpsc::Receiver<InferReply>, ServeError> {
        self.engine.submit_with_deadline(&self.key, x, budget)
    }

    /// Live metrics snapshot (queue depth and wall clock stamped as of now).
    pub fn metrics(&self) -> ServeMetrics {
        self.engine.shard_metrics(&self.key).unwrap_or_default()
    }

    /// Stop the server and collect metrics.
    pub fn shutdown(self) -> ServeMetrics {
        self.engine.shutdown().shards.into_iter().next().unwrap_or_default()
    }
}

/// Start a server for `ds` with a trained model. Blocks until the worker has
/// compiled + warmed every executable, so no request ever pays XLA compile
/// time. See [`crate::serve::ServeEngine`] for the multi-shard form.
pub fn serve(ds: &Dataset, mlp: Mlp, cfg: ServeConfig) -> Result<ServerHandle> {
    let mut shard = ShardConfig::new(ds, mlp, cfg.spec).with_engine(cfg.engine);
    shard.worker =
        WorkerConfig { max_batch_wait: cfg.max_batch_wait, max_queue: cfg.max_queue, ..WorkerConfig::default() };
    let key = ShardKey::new(&ds.name, cfg.spec);
    let engine = ServeEngine::start(vec![shard]).map_err(|e| anyhow!("serve: {e}"))?;
    Ok(ServerHandle { engine, key })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiments::train_model;
    use crate::datasets::{self, Scale};

    #[test]
    fn sim_server_round_trip() {
        let ds = datasets::load("iris", 3, Scale::Small);
        let mlp = train_model(&ds, 3);
        let handle = serve(&ds, mlp.clone(), ServeConfig::default()).unwrap();
        let mut correct = 0;
        let n = 30;
        let rxs: Vec<_> = (0..n).map(|i| (i, handle.submit(ds.test_row(i).to_vec()).unwrap())).collect();
        for (i, rx) in rxs {
            let reply = rx.recv().unwrap();
            if reply.class == ds.y_test[i] as usize {
                correct += 1;
            }
            assert!(reply.latency_s < 5.0);
        }
        let metrics = handle.shutdown();
        assert_eq!(metrics.served, n);
        assert_eq!(metrics.shed, 0, "well under max_queue, nothing may shed");
        assert!(metrics.batches >= 1 && metrics.batches <= n);
        assert!(correct as f64 / n as f64 > 0.6, "server predictions wrong: {correct}/{n}");
        assert!(metrics.render().contains("req/s"));
    }

    #[test]
    fn batcher_coalesces_under_load() {
        let ds = datasets::load("iris", 3, Scale::Small);
        let mlp = train_model(&ds, 3);
        let cfg = ServeConfig { max_batch_wait: Duration::from_millis(30), ..Default::default() };
        let handle = serve(&ds, mlp, cfg).unwrap();
        // Fire a burst; with the long wait they should coalesce into few
        // batches.
        let rxs: Vec<_> = (0..20).map(|i| handle.submit(ds.test_row(i % ds.test_len()).to_vec()).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let metrics = handle.shutdown();
        assert_eq!(metrics.served, 20);
        assert!(metrics.batches < 20, "no coalescing happened: {} batches", metrics.batches);
    }

    #[test]
    fn facade_surfaces_overload_and_live_depth() {
        let ds = datasets::load("iris", 3, Scale::Small);
        let mlp = train_model(&ds, 3);
        // A queue bound of 4 with a long coalesce window: the 5th
        // un-consumed submission must shed, and the live snapshot must see
        // the queued depth.
        let cfg = ServeConfig { max_batch_wait: Duration::from_millis(1500), max_queue: 4, ..Default::default() };
        let handle = serve(&ds, mlp, cfg).unwrap();
        let rxs: Vec<_> = (0..4).map(|i| handle.submit(ds.test_row(i).to_vec()).unwrap()).collect();
        let live = handle.metrics();
        assert_eq!(live.queue_depths, vec![4]);
        match handle.submit(ds.test_row(4).to_vec()) {
            Err(ServeError::Overloaded { depth, .. }) => assert_eq!(depth, 4),
            other => panic!("5th submission must shed, got {other:?}"),
        }
        let metrics = handle.shutdown();
        assert_eq!(metrics.served, 4);
        assert_eq!(metrics.shed, 1);
        for rx in rxs {
            rx.recv().expect("accepted requests are answered on shutdown");
        }
    }
}
