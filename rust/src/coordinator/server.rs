//! Batched inference server — the edge-deployment scenario the paper's
//! introduction motivates (low-precision DNNs on end-devices).
//!
//! vLLM-router-style dynamic batching, scaled to this system: a worker
//! thread owns the PJRT runtime (XLA handles are not `Send`; everything
//! device-side stays on one thread) and the quantized model; clients submit
//! feature vectors over a channel; the batcher coalesces requests up to the
//! largest AOT-compiled batch size or a wait deadline, pads to the smallest
//! compiled batch that fits, executes, and replies per-request. Latency and
//! batch-occupancy metrics are collected for the serving benchmark.

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::accel::{argmax, DeepPositron, Mlp};
use crate::coordinator::experiments::Engine;
use crate::datasets::Dataset;
use crate::formats::FormatSpec;
use crate::runtime::{artifacts_dir, FormatTables, Kind, Runtime};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub engine: Engine,
    pub spec: FormatSpec,
    /// Max time the batcher waits to fill a batch.
    pub max_batch_wait: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            engine: Engine::Sim,
            spec: FormatSpec::Posit { n: 8, es: 1 },
            max_batch_wait: Duration::from_millis(2),
        }
    }
}

struct Request {
    x: Vec<f64>,
    submitted: Instant,
    resp: mpsc::Sender<InferReply>,
}

/// One served prediction.
#[derive(Debug, Clone)]
pub struct InferReply {
    pub class: usize,
    /// Queue + batch + compute latency, seconds.
    pub latency_s: f64,
}

/// Serving metrics, returned on shutdown.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub served: usize,
    pub batches: usize,
    pub latencies_s: Vec<f64>,
    pub batch_sizes: Vec<usize>,
    pub wall_seconds: f64,
}

impl ServeMetrics {
    pub fn throughput(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.served as f64 / self.wall_seconds
        } else {
            0.0
        }
    }

    pub fn render(&self) -> String {
        use crate::util::stats::{mean, percentile};
        if self.latencies_s.is_empty() {
            return "no requests served".into();
        }
        format!(
            "served {} requests in {} batches ({:.1} req/s)\n\
             latency mean {:.2} ms | p50 {:.2} ms | p99 {:.2} ms\n\
             mean batch occupancy {:.1}",
            self.served,
            self.batches,
            self.throughput(),
            mean(&self.latencies_s) * 1e3,
            percentile(&self.latencies_s, 50.0) * 1e3,
            percentile(&self.latencies_s, 99.0) * 1e3,
            mean(&self.batch_sizes.iter().map(|&b| b as f64).collect::<Vec<_>>()),
        )
    }
}

enum Control {
    Req(Request),
    Shutdown(mpsc::Sender<ServeMetrics>),
}

/// Client handle to a running server.
pub struct ServerHandle {
    tx: mpsc::Sender<Control>,
    worker: Option<JoinHandle<()>>,
    num_features: usize,
}

impl ServerHandle {
    /// Submit one feature vector; returns the reply receiver.
    pub fn submit(&self, x: Vec<f64>) -> mpsc::Receiver<InferReply> {
        assert_eq!(x.len(), self.num_features, "feature dim mismatch");
        let (tx, rx) = mpsc::channel();
        self.tx.send(Control::Req(Request { x, submitted: Instant::now(), resp: tx })).expect("server gone");
        rx
    }

    /// Stop the server and collect metrics.
    pub fn shutdown(mut self) -> ServeMetrics {
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(Control::Shutdown(tx));
        let metrics = rx.recv().unwrap_or_default();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        metrics
    }
}

/// Start a server for `ds` with a trained model. The worker thread builds
/// its own PJRT runtime (XLA handles stay thread-local). Blocks until the
/// worker has compiled + warmed every executable, so no request ever pays
/// XLA compile time.
pub fn serve(ds: &Dataset, mlp: Mlp, cfg: ServeConfig) -> Result<ServerHandle> {
    let (tx, rx) = mpsc::channel::<Control>();
    let (ready_tx, ready_rx) = mpsc::channel::<()>();
    let dataset = ds.name.clone();
    let num_features = ds.num_features;
    let classes = ds.num_classes;
    let worker = std::thread::spawn(move || worker_loop(rx, ready_tx, dataset, mlp, cfg, classes));
    ready_rx.recv().map_err(|_| anyhow::anyhow!("server worker died during warm-up"))?;
    Ok(ServerHandle { tx, worker: Some(worker), num_features })
}

fn worker_loop(
    rx: mpsc::Receiver<Control>,
    ready_tx: mpsc::Sender<()>,
    dataset: String,
    mlp: Mlp,
    cfg: ServeConfig,
    classes: usize,
) {
    let dp = DeepPositron::compile(&mlp, cfg.spec);
    // XLA engine state (runtime + layouts), built once.
    let xla = if cfg.engine == Engine::Xla {
        match Runtime::new(&artifacts_dir()) {
            Ok(rt) => {
                let (weights, biases) = python_layout(&dp, &mlp);
                let tables = FormatTables::new(cfg.spec, dp.quantizer());
                Some((rt, weights, biases, tables))
            }
            Err(e) => {
                eprintln!("server: falling back to sim engine ({e})");
                None
            }
        }
    } else {
        None
    };
    let batch_sizes: Vec<usize> = match &xla {
        Some((rt, ..)) => rt.batches(Kind::QInfer, &dataset),
        None => vec![64],
    };
    let max_batch = *batch_sizes.last().unwrap_or(&64);
    // Pre-warm: compile every batch-size executable and run one padded
    // batch through each BEFORE accepting traffic, so no request pays the
    // XLA compile (perf pass iteration 2 — EXPERIMENTS.md §Perf).
    if let Some((rt, weights, biases, tables)) = &xla {
        let in_dim = mlp.layers[0].in_dim;
        for &b in &batch_sizes {
            let zeros = vec![0.0; in_dim];
            if let Ok(exe) = rt.quantized_infer(&dataset, b) {
                let _ = exe.run(&zeros, 1, weights, biases, tables);
            }
        }
    }
    let _ = ready_tx.send(());
    if std::env::var("SERVE_TRACE").is_ok() {
        eprintln!("[trace] worker ready: engine={:?} xla={} batch_sizes={batch_sizes:?}", cfg.engine, xla.is_some());
    }
    let mut metrics = ServeMetrics::default();
    let t0 = Instant::now();
    let mut pending: Vec<Request> = Vec::new();
    loop {
        // Block for the first request (or control message).
        if pending.is_empty() {
            match rx.recv() {
                Ok(Control::Req(r)) => pending.push(r),
                Ok(Control::Shutdown(done)) => {
                    metrics.wall_seconds = t0.elapsed().as_secs_f64();
                    let _ = done.send(metrics);
                    return;
                }
                Err(_) => return,
            }
        }
        // Coalesce until the batch fills or the wait deadline passes.
        let deadline = Instant::now() + cfg.max_batch_wait;
        let mut shutdown: Option<mpsc::Sender<ServeMetrics>> = None;
        while pending.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Control::Req(r)) => pending.push(r),
                Ok(Control::Shutdown(done)) => {
                    shutdown = Some(done);
                    break;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        // Execute the batch.
        let rows = pending.len();
        let preds: Vec<usize> = match &xla {
            Some((rt, weights, biases, tables)) => {
                // Smallest compiled batch that fits (pad the remainder).
                let b = *batch_sizes.iter().find(|&&b| b >= rows).unwrap_or(&max_batch);
                let mut x = Vec::with_capacity(b * pending[0].x.len());
                for r in &pending {
                    x.extend_from_slice(&r.x);
                }
                let t_exec = Instant::now();
                match rt.quantized_infer(&dataset, b).and_then(|exe| exe.run(&x, rows, weights, biases, tables)) {
                    Ok(logits) => {
                        if std::env::var("SERVE_TRACE").is_ok() {
                            eprintln!("[trace] batch rows={rows} pad={b} exec={:?}", t_exec.elapsed());
                        }
                        (0..rows).map(|r| argmax(&logits[r * classes..(r + 1) * classes])).collect()
                    }
                    Err(e) => {
                        eprintln!("server: batch failed ({e}); using sim");
                        pending.iter().map(|r| dp.predict(&r.x)).collect()
                    }
                }
            }
            None => pending.iter().map(|r| dp.predict(&r.x)).collect(),
        };
        metrics.batches += 1;
        metrics.batch_sizes.push(rows);
        for (req, class) in pending.drain(..).zip(preds) {
            let latency_s = req.submitted.elapsed().as_secs_f64();
            metrics.served += 1;
            metrics.latencies_s.push(latency_s);
            let _ = req.resp.send(InferReply { class, latency_s });
        }
        if let Some(done) = shutdown {
            metrics.wall_seconds = t0.elapsed().as_secs_f64();
            let _ = done.send(metrics);
            return;
        }
    }
}

fn python_layout(dp: &DeepPositron, mlp: &Mlp) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let wq = dp.dequantized_weights();
    let bq = dp.dequantized_biases();
    let mut weights = Vec::with_capacity(wq.len());
    for (l, w) in mlp.layers.iter().zip(&wq) {
        let mut wio = vec![0.0; l.in_dim * l.out_dim];
        for o in 0..l.out_dim {
            for i in 0..l.in_dim {
                wio[i * l.out_dim + o] = w[o * l.in_dim + i];
            }
        }
        weights.push(wio);
    }
    (weights, bq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiments::train_model;
    use crate::datasets::{self, Scale};

    #[test]
    fn sim_server_round_trip() {
        let ds = datasets::load("iris", 3, Scale::Small);
        let mlp = train_model(&ds, 3);
        let handle = serve(&ds, mlp.clone(), ServeConfig::default()).unwrap();
        let mut correct = 0;
        let n = 30;
        let rxs: Vec<_> = (0..n).map(|i| (i, handle.submit(ds.test_row(i).to_vec()))).collect();
        for (i, rx) in rxs {
            let reply = rx.recv().unwrap();
            if reply.class == ds.y_test[i] as usize {
                correct += 1;
            }
            assert!(reply.latency_s < 5.0);
        }
        let metrics = handle.shutdown();
        assert_eq!(metrics.served, n);
        assert!(metrics.batches >= 1 && metrics.batches <= n);
        assert!(correct as f64 / n as f64 > 0.6, "server predictions wrong: {correct}/{n}");
        assert!(metrics.render().contains("req/s"));
    }

    #[test]
    fn batcher_coalesces_under_load() {
        let ds = datasets::load("iris", 3, Scale::Small);
        let mlp = train_model(&ds, 3);
        let cfg = ServeConfig { max_batch_wait: Duration::from_millis(30), ..Default::default() };
        let handle = serve(&ds, mlp, cfg).unwrap();
        // Fire a burst; with the long wait they should coalesce into few
        // batches.
        let rxs: Vec<_> = (0..20).map(|i| handle.submit(ds.test_row(i % ds.test_len()).to_vec())).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let metrics = handle.shutdown();
        assert_eq!(metrics.served, 20);
        assert!(metrics.batches < 20, "no coalescing happened: {} batches", metrics.batches);
    }
}
