//! Experiment drivers: everything the paper's evaluation section reports,
//! runnable end-to-end from the CLI/benches (DESIGN.md §5 experiment index).

use anyhow::{bail, Result};

use crate::accel::{self, DeepPositron, Layer, Mlp, Shape};
use crate::datasets::{self, Dataset, Scale};
use crate::formats::FormatSpec;
use crate::hw;
use crate::quant;
use crate::runtime::{FormatTables, Runtime};
use crate::util::Rng;

/// Which engine evaluates the quantized network.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Bit-exact Rust EMAC simulator (golden path).
    Sim,
    /// AOT/XLA artifacts through PJRT (fast path).
    Xla,
}

/// Per-dataset training epochs for the Rust substrate trainer.
pub fn train_epochs(name: &str) -> usize {
    match name {
        "iris" => 80,
        "wdbc" => 60,
        "mushroom" => 12,
        "mnist" | "fashion" => 14,
        _ => 30,
    }
}

/// Train the baseline f64 MLP for a dataset (Rust substrate trainer).
/// Training runs on the z-scored view; the normalization is folded back
/// into the first layer so the returned network consumes RAW features —
/// the network Deep Positron actually quantizes (DESIGN.md §3).
pub fn train_model(ds: &Dataset, seed: u64) -> Mlp {
    let mut dims = vec![ds.num_features];
    dims.extend(datasets::hidden_layers(&ds.name));
    dims.push(ds.num_classes);
    let mut rng = Rng::new(seed);
    let mut mlp = Mlp::new(&dims, &mut rng);
    let cfg = accel::TrainConfig { epochs: train_epochs(&ds.name), seed: seed ^ 0x7e57, ..Default::default() };
    if datasets::normalizes_for_training(&ds.name) {
        let (norm, means, stds) = ds.normalized();
        accel::train(&mut mlp, &norm, &cfg);
        accel::mlp::fold_input_normalization(&mut mlp, &means, &stds);
    } else {
        accel::train(&mut mlp, ds, &cfg);
    }
    mlp
}

/// Quantized test accuracy on the bit-exact simulator: compile the network
/// once into its execution plan, then sweep the test split through
/// [`DeepPositron::accuracy`]'s batched evaluation (chunks of
/// [`crate::accel::EVAL_BATCH`] samples per plan walk — DESIGN.md §8). This
/// is what every Table 1 / Fig. 6–7 / es-study Sim sweep routes through.
pub fn eval_sim(mlp: &Mlp, ds: &Dataset, spec: FormatSpec) -> f64 {
    DeepPositron::compile(mlp, spec).accuracy(ds)
}

/// Transpose accel (out×in) weights into the artifact's (in×out) layout.
fn python_layout(dp: &DeepPositron, mlp: &Mlp) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let wq = dp.dequantized_weights();
    let bq = dp.dequantized_biases();
    let mut weights = Vec::with_capacity(wq.len());
    for (l, w) in mlp.layers.iter().zip(&wq) {
        let mut wio = vec![0.0; l.in_dim * l.out_dim];
        for o in 0..l.out_dim {
            for i in 0..l.in_dim {
                wio[i * l.out_dim + o] = w[o * l.in_dim + i];
            }
        }
        weights.push(wio);
    }
    (weights, bq)
}

/// Quantized test accuracy through the AOT/XLA artifacts (dense
/// topologies only — the artifact bakes in a dense table shape; conv
/// networks evaluate on the bit-exact Sim path).
pub fn eval_xla(rt: &Runtime, mlp: &Mlp, ds: &Dataset, spec: FormatSpec) -> Result<f64> {
    if !mlp.is_dense() {
        bail!("conv layer IR is Sim-native: no AOT artifact exists for non-dense topologies");
    }
    let dp = DeepPositron::compile(mlp, spec);
    let (weights, biases) = python_layout(&dp, mlp);
    let tables = FormatTables::new(spec, dp.quantizer());
    let batch = *rt.batches(crate::runtime::Kind::QInfer, &ds.name).last().expect("no q_infer artifact");
    let exe = rt.quantized_infer(&ds.name, batch)?;
    let classes = ds.num_classes;
    let mut correct = 0usize;
    let mut i = 0;
    while i < ds.test_len() {
        let rows = batch.min(ds.test_len() - i);
        let x = &ds.x_test[i * ds.num_features..(i + rows) * ds.num_features];
        let logits = exe.run(x, rows, &weights, &biases, &tables)?;
        for r in 0..rows {
            let row = &logits[r * classes..(r + 1) * classes];
            if accel::argmax(row) == ds.y_test[i + r] as usize {
                correct += 1;
            }
        }
        i += rows;
    }
    Ok(correct as f64 / ds.test_len() as f64)
}

/// Eq. (2) accumulator-sizing `k` for a set of trained tasks: the largest
/// receptive-field fan-in any of the networks presents — the dot-product
/// length the deployed EMACs must actually absorb (a conv layer
/// contributes `kh·kw·in_ch`, not its flat input width). The sweeps used
/// to pass [`hw::DEFAULT_K`] (MNIST's 784) for every task, which sized the
/// Fig. 6/7 hardware axes of 4–30-feature tabular tasks for an accumulator
/// they would never provision; the tuner ([`crate::tune`]) applies the
/// same fan-in rule per layer.
pub fn eq2_k<'a>(mlps: impl Iterator<Item = &'a Mlp>) -> usize {
    mlps.map(Mlp::max_fan_in).max().unwrap_or(hw::DEFAULT_K)
}

// ------------------------------------------------------------- conv study

/// Default training epochs for the conv substrate (slower per epoch than
/// the dense MLPs; the raster tasks converge in a handful of passes).
pub const CONV_EPOCHS: usize = 8;

/// The small convolutional topology for the 28×28 raster image tasks
/// (DESIGN.md §11): `conv(1→4, 5×5, stride 2) → avgpool(2, stride 2) →
/// flatten → dense(144→10)`, untrained. The conv EMAC's Eq. (2) check runs
/// at `k = 5·5·1 + 1 = 26` — the receptive field, not the 784-pixel input.
pub fn conv_model(seed: u64) -> Mlp {
    let input = Shape::Chw { c: 1, h: 28, w: 28 };
    let mut rng = Rng::new(seed ^ 0xC04F);
    let conv = Layer::conv2d(input, 4, 5, 5, 2, &mut rng);
    let pool = Layer::avg_pool(conv.out_shape, 2, 2);
    let flat = Layer::flatten(pool.out_shape);
    let dense = Layer::dense(flat.out_dim, 10, &mut rng);
    Mlp::from_layers(vec![conv, pool, flat, dense])
}

/// Train the conv topology on a raster image task (raw [0, 1] pixels — no
/// normalization folding, same protocol as the image MLPs).
pub fn train_conv_model(ds: &Dataset, seed: u64, epochs: usize) -> Mlp {
    assert_eq!(ds.num_features, 28 * 28, "the conv topology consumes 28x28 rasters");
    let mut mlp = conv_model(seed);
    let cfg = accel::TrainConfig { epochs, seed: seed ^ 0x7e57, ..Default::default() };
    accel::train(&mut mlp, ds, &cfg);
    mlp
}

/// The one model-selection switch the CLI tools (`repro tune` / `repro
/// serve`) share: the dataset's dense MLP ([`train_model`]) by default, or
/// the conv topology ([`train_conv_model`] at [`CONV_EPOCHS`]) when the
/// caller asked for `--model conv` on a 28×28 raster task.
pub fn model_for(ds: &Dataset, seed: u64, conv: bool) -> Mlp {
    if conv {
        train_conv_model(ds, seed, CONV_EPOCHS)
    } else {
        train_model(ds, seed)
    }
}

/// The conv analogue of Table 1 on the raster image tasks: train the conv
/// net, then report best-of-sweep 8-bit accuracy per format family through
/// the bit-exact conv EMAC datapath (Sim-native — no AOT artifact exists
/// for conv topologies).
pub fn conv_table(scale: Scale, seed: u64, task_names: &[&str]) -> Result<Vec<Table1Row>> {
    let mut rows = Vec::new();
    for name in task_names {
        let ds = datasets::load(name, seed, scale);
        let mlp = train_conv_model(&ds, seed, CONV_EPOCHS);
        let baseline = mlp.accuracy(&ds);
        let (pa, ps) = best_accuracy(Engine::Sim, None, &mlp, &ds, "posit", 8)?;
        let (fa, fs) = best_accuracy(Engine::Sim, None, &mlp, &ds, "float", 8)?;
        let (xa, xs) = best_accuracy(Engine::Sim, None, &mlp, &ds, "fixed", 8)?;
        rows.push(Table1Row {
            dataset: format!("{name} (conv)"),
            inference_size: ds.test_len(),
            posit: (pa, ps.sub_param()),
            float: (fa, fs.sub_param()),
            fixed: (xa, xs.sub_param()),
            baseline,
        });
    }
    Ok(rows)
}

/// Evaluate with the selected engine.
pub fn eval(engine: Engine, rt: Option<&Runtime>, mlp: &Mlp, ds: &Dataset, spec: FormatSpec) -> Result<f64> {
    match engine {
        Engine::Sim => Ok(eval_sim(mlp, ds, spec)),
        Engine::Xla => eval_xla(rt.expect("XLA engine needs a Runtime"), mlp, ds, spec),
    }
}

// ---------------------------------------------------------------- Table 1

/// One row of Table 1.
#[derive(Debug, Clone)]
pub struct Table1Row {
    /// Task name.
    pub dataset: String,
    /// Test-split size (the paper's "Inference Size" column).
    pub inference_size: usize,
    /// Best 8-bit posit accuracy and its es.
    pub posit: (f64, u32),
    /// Best 8-bit float accuracy and its w_e.
    pub float: (f64, u32),
    /// Best 8-bit fixed accuracy and its Q.
    pub fixed: (f64, u32),
    /// The f64-trained baseline accuracy.
    pub baseline: f64,
}

/// Best-of-sweep accuracy for one family at bit-width `n`. Each candidate
/// format compiles once and evaluates the whole test split batched
/// ([`eval_sim`]); the shared `Quantizer`/`DecodeLut` caches mean repeat
/// sweeps of a format pay no table rebuilds.
pub fn best_accuracy(
    engine: Engine,
    rt: Option<&Runtime>,
    mlp: &Mlp,
    ds: &Dataset,
    family: &str,
    n: u32,
) -> Result<(f64, FormatSpec)> {
    let mut best = (-1.0, FormatSpec::Fixed { n, q: 1 });
    for spec in FormatSpec::sweep_family(n, family) {
        let acc = eval(engine, rt, mlp, ds, spec)?;
        if acc > best.0 {
            best = (acc, spec);
        }
    }
    Ok((best.0, best.1))
}

/// Table 1: 8-bit EMAC accuracy on the five tasks.
pub fn table1(engine: Engine, rt: Option<&Runtime>, scale: Scale, seed: u64) -> Result<Vec<Table1Row>> {
    let mut rows = Vec::new();
    for name in datasets::ALL {
        let ds = datasets::load(name, seed, scale);
        let mlp = train_model(&ds, seed);
        let baseline = mlp.accuracy(&ds);
        let (pa, ps) = best_accuracy(engine, rt, &mlp, &ds, "posit", 8)?;
        let (fa, fs) = best_accuracy(engine, rt, &mlp, &ds, "float", 8)?;
        let (xa, xs) = best_accuracy(engine, rt, &mlp, &ds, "fixed", 8)?;
        rows.push(Table1Row {
            dataset: name.to_string(),
            inference_size: ds.test_len(),
            posit: (pa, ps.sub_param()),
            float: (fa, fs.sub_param()),
            fixed: (xa, xs.sub_param()),
            baseline,
        });
    }
    Ok(rows)
}

// ------------------------------------------------------------- Figs 6 / 7

/// One point of the Fig. 6/7 scatter: a (family, bit-width) pair evaluated
/// at its best sub-parameter, with hardware metrics attached.
#[derive(Debug, Clone)]
pub struct TradeoffPoint {
    /// The (family, n) config at its best sub-parameter.
    pub spec: FormatSpec,
    /// Mean accuracy degradation (baseline − quantized) over the tasks.
    pub avg_degradation: f64,
    /// Energy-delay product of the EMAC, pJ·ns (Fig. 6 x-axis).
    pub edp_pj_ns: f64,
    /// EMAC critical-path delay, ns (Fig. 7 left x-axis).
    pub delay_ns: f64,
    /// EMAC dynamic power, mW (Fig. 7 right x-axis).
    pub power_mw: f64,
    /// Lowest degradation among its family at this bit-width (the ★).
    pub star: bool,
}

/// The accuracy-vs-hardware trade-off sweep behind Figs. 6 and 7:
/// bit-widths 5–8 × three families; per (family, n) each sub-parameter is
/// evaluated on every task and the best-average config is reported.
pub fn tradeoff_sweep(
    engine: Engine,
    rt: Option<&Runtime>,
    scale: Scale,
    seed: u64,
    task_names: &[&str],
) -> Result<Vec<TradeoffPoint>> {
    // Train once per task.
    let mut tasks = Vec::new();
    for name in task_names {
        let ds = datasets::load(name, seed, scale);
        let mlp = train_model(&ds, seed);
        let baseline = mlp.accuracy(&ds);
        tasks.push((ds, mlp, baseline));
    }
    // Size the Eq. (2) accumulator for the largest fan-in among the tasks
    // actually swept, not a blanket MNIST-sized k.
    let k = eq2_k(tasks.iter().map(|(_, mlp, _)| mlp));
    let mut points = Vec::new();
    for n in 5..=8u32 {
        for family in ["posit", "float", "fixed"] {
            // Paper protocol: the sub-parameter (es / w_e / Q) is chosen
            // per task (Table 1 reports different es per dataset); the
            // figure's accuracy axis averages those per-task bests. The
            // hardware axis uses the modal (most-often-chosen) config.
            let sweep = FormatSpec::sweep_family(n, family);
            let mut deg = 0.0;
            let mut chosen: Vec<FormatSpec> = Vec::new();
            for (ds, mlp, baseline) in &tasks {
                let mut best: Option<(f64, FormatSpec)> = None;
                for &spec in &sweep {
                    let acc = eval(engine, rt, mlp, ds, spec)?;
                    if best.map_or(true, |(b, _)| acc > b) {
                        best = Some((acc, spec));
                    }
                }
                let (acc, spec) = best.unwrap();
                deg += (baseline - acc).max(-1.0);
                chosen.push(spec);
            }
            deg /= tasks.len() as f64;
            let spec = *chosen
                .iter()
                .max_by_key(|s| chosen.iter().filter(|c| c == s).count())
                .unwrap();
            let synth = hw::synthesize(spec, k);
            points.push(TradeoffPoint {
                spec,
                avg_degradation: deg,
                edp_pj_ns: synth.edp_pj_ns,
                delay_ns: synth.critical_path_ns,
                power_mw: synth.dynamic_power_mw,
                star: false,
            });
        }
    }
    // Stars: per bit-width, the lowest-degradation family point.
    for n in 5..=8u32 {
        let idx = points
            .iter()
            .enumerate()
            .filter(|(_, p)| p.spec.n() == n)
            .min_by(|a, b| a.1.avg_degradation.partial_cmp(&b.1.avg_degradation).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        points[idx].star = true;
    }
    Ok(points)
}

// ------------------------------------------------------------------ Fig 5

/// Fig. 5 heatmap for one dataset: train, then layer-wise best-of-sweep MSE
/// per format over bits 5–8.
pub fn fig5(dataset: &str, scale: Scale, seed: u64) -> Vec<quant::HeatCell> {
    let ds = datasets::load(dataset, seed, scale);
    let mlp = train_model(&ds, seed);
    quant::heatmap(&mlp.named_tensors(), &[5, 6, 7, 8])
}

// ----------------------------------------------------------------- §5.1

/// §5.1: the posit es trade-off. Average accuracy per es over the tasks and
/// bits [5,7], plus EDP ratios at n=8.
#[derive(Debug, Clone)]
pub struct EsStudy {
    /// avg accuracy (over tasks × bits 5..=7) per es ∈ {0,1,2}.
    pub avg_acc: [f64; 3],
    /// EDP(es)/EDP(0) at n=8.
    pub edp_ratio: [f64; 3],
}

/// Run the §5.1 es study over `task_names` (accuracy per es, EDP ratios).
pub fn es_study(engine: Engine, rt: Option<&Runtime>, scale: Scale, seed: u64, task_names: &[&str]) -> Result<EsStudy> {
    let mut tasks = Vec::new();
    for name in task_names {
        let ds = datasets::load(name, seed, scale);
        let mlp = train_model(&ds, seed);
        tasks.push((ds, mlp));
    }
    let mut avg_acc = [0.0f64; 3];
    let mut count = 0usize;
    for n in 5..=7u32 {
        for (ds, mlp) in &tasks {
            for es in 0..=2u32 {
                avg_acc[es as usize] += eval(engine, rt, mlp, ds, FormatSpec::Posit { n, es })?;
            }
            count += 1;
        }
    }
    for a in avg_acc.iter_mut() {
        *a /= count as f64;
    }
    // EDP ratios at the accumulator size the swept tasks actually need.
    let (r1, r2) = hw::es_edp_ratios(8, eq2_k(tasks.iter().map(|(_, mlp)| mlp)));
    Ok(EsStudy { avg_acc, edp_ratio: [1.0, r1, r2] })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_sim_small_iris_only() {
        // Full Table 1 runs in the bench; unit-test one task end-to-end.
        let ds = datasets::load("iris", 11, Scale::Small);
        let mlp = train_model(&ds, 11);
        let baseline = mlp.accuracy(&ds);
        assert!(baseline >= 0.9, "baseline {baseline}");
        let (acc, spec) = best_accuracy(Engine::Sim, None, &mlp, &ds, "posit", 8).unwrap();
        assert!(acc >= baseline - 0.08, "posit8 {acc} too far below {baseline}");
        assert_eq!(spec.family(), "posit");
    }

    #[test]
    fn degradation_grows_as_bits_shrink() {
        let ds = datasets::load("iris", 11, Scale::Small);
        let mlp = train_model(&ds, 11);
        let (acc8, _) = best_accuracy(Engine::Sim, None, &mlp, &ds, "posit", 8).unwrap();
        let (acc5, _) = best_accuracy(Engine::Sim, None, &mlp, &ds, "posit", 5).unwrap();
        assert!(acc8 >= acc5, "8-bit {acc8} vs 5-bit {acc5}");
    }

    #[test]
    fn fig5_produces_full_grid() {
        let cells = fig5("iris", Scale::Small, 3);
        // layers: dense1..3 + avg = 4 rows × 4 bit-widths.
        assert_eq!(cells.len(), 16);
        // Structural invariants (the posit-vs-fixed *shape* claim needs the
        // peaked weight distribution of the MNIST-scale nets — asserted in
        // the fig5 bench): MSEs are positive and shrink with bit-width.
        assert!(cells.iter().all(|c| c.mse_posit > 0.0 && c.mse_fixed > 0.0 && c.mse_float > 0.0));
        for layer in ["dense1", "avg"] {
            let at = |n: u32| cells.iter().find(|c| c.layer == layer && c.n == n).unwrap().mse_posit;
            assert!(at(8) < at(5), "{layer}: posit MSE not shrinking with bits");
        }
    }

    #[test]
    fn eq2_k_uses_task_fan_in_not_mnist() {
        let ds = datasets::load("iris", 11, Scale::Small);
        let mlp = train_model(&ds, 11);
        // iris: 4 → 10 → 8 → 3, so the widest dot product is 10 — not 784.
        assert_eq!(eq2_k([&mlp].into_iter()), 10);
        // No tasks ⇒ fall back to the paper-wide default.
        assert_eq!(eq2_k(std::iter::empty()), hw::DEFAULT_K);
    }

    #[test]
    fn es_study_runs_on_tiny_task() {
        let s = es_study(Engine::Sim, None, Scale::Small, 5, &["iris"]).unwrap();
        assert!(s.avg_acc.iter().all(|&a| a > 0.3));
        assert!(s.edp_ratio[1] > 1.0 && s.edp_ratio[2] > s.edp_ratio[1]);
    }
}
