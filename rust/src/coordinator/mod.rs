//! L3 coordinator: experiment orchestration, the PJRT training-loop driver,
//! the single-shard serving facade (the sharded engine itself lives in
//! [`crate::serve`]), and report rendering.
//!
//! The paper's contribution lives at L1/L2 (the numeric formats and EMAC
//! semantics); this layer is the system around them — it owns process
//! lifecycle, sweep scheduling, batching, metrics, and the CLI (DESIGN.md
//! §2 "thin driver" case).

pub mod experiments;
pub mod report;
pub mod server;
pub mod trainer;

pub use experiments::{es_study, eval, fig5, table1, tradeoff_sweep, Engine};
pub use server::{serve, ServeConfig, ServeMetrics, ServerHandle};
pub use trainer::{train_via_pjrt, LoopConfig, TrainLog};
